//! Backend conformance suite (PR 4): every engine behind the
//! `NumericsBackend` trait must agree.
//!
//! * The fixed-point hot path is **bit-identical** to the reference
//!   (seed edge-list) backend for all four presets *and* a depth-3
//!   custom `ModelSpec`, both driven directly through the trait and
//!   through the sharded pool on 1 and 4 shards.
//! * The PJRT backend joins the same matrix: with real artifacts it
//!   must match the Q4.12 datapath within quantization error; with the
//!   default stub executor every shard must still run (no shard-0
//!   pinning, no silent shard shrink), fall back to counted
//!   timing-only serving, and stay shard-count independent.

use grip::backend::{
    BackendChoice, BackendFactory, BackendScratch, Numerics, NumericsBackend, StagedFeatures,
};
use grip::config::ModelConfig;
use grip::coordinator::{Coordinator, InferenceRequest, InferenceResponse, ServeConfig};
use grip::graph::{generate, CsrGraph, GeneratorParams};
use grip::greta::{
    Activate, LayerSpec, ModelKey, ModelLibrary, ModelSpec, ProgramSpec, ReduceOp,
};
use grip::nodeflow::{Nodeflow, Sampler};
use grip::runtime::FeatureStore;
use grip::serve::{fixed_serving_args, ServeStats};

const WEIGHT_SEED: u64 = 0x5EED_5E4E;

fn small_mc() -> ModelConfig {
    ModelConfig { sample1: 4, sample2: 3, f_in: 12, f_hid: 10, f_out: 6 }
}

fn conformance_graph() -> CsrGraph {
    generate(&GeneratorParams { nodes: 1_200, mean_degree: 7.0, seed: 5, ..Default::default() })
}

/// A depth-3 mean-aggregate spec with dims unrelated to `ModelConfig`
/// (8 → 6 → 5 → 3) — the acceptance-criteria custom model.
fn depth3_spec() -> ModelSpec {
    ModelSpec::builder("tri3")
        .layer(LayerSpec::new(8, 6).sample(3).program(
            ProgramSpec::new("t0")
                .reduce(ReduceOp::Mean)
                .transform("t_w0", 8, 6)
                .activate(Activate::Relu),
        ))
        .layer(LayerSpec::new(6, 5).sample(2).program(
            ProgramSpec::new("t1")
                .reduce(ReduceOp::Mean)
                .transform("t_w1", 6, 5)
                .activate(Activate::Relu),
        ))
        .layer(LayerSpec::new(5, 3).sample(2).program(
            ProgramSpec::new("t2")
                .reduce(ReduceOp::Mean)
                .transform("t_w2", 5, 3)
                .activate(Activate::Relu),
        ))
        .build()
}

/// The conformance library: all four presets plus the depth-3 spec.
fn library() -> ModelLibrary {
    ModelLibrary::with_customs(&small_mc(), &[depth3_spec()]).expect("valid specs").0
}

/// Drive the same workload — every library model × every target —
/// straight through one backend instance (prepare once per model,
/// execute per nodeflow), returning each reply's embedding + tag.
fn run_direct(choice: BackendChoice, targets: &[u32]) -> Vec<(String, Vec<f32>, Numerics)> {
    let g = conformance_graph();
    let lib = library();
    let mut backend = BackendFactory::new(choice).build(0).expect("backend constructs");
    let sampler = Sampler::new(11);
    let mut scratch = BackendScratch::new();
    let mut staged = StagedFeatures::new();
    let mut out = Vec::new();
    for key in lib.keys() {
        let plan = lib.plan(key);
        let prepared =
            backend.prepare(plan, &fixed_serving_args(plan, WEIGHT_SEED)).expect("prepare");
        for &t in targets {
            let nf = Nodeflow::build_layers(&g, &sampler, &[t], lib.samples(key));
            let mut store = FeatureStore::new();
            // Edge-centric phase first (what a prefetch lane does),
            // then the vertex engine consumes the staged rows.
            staged.stage(&nf, plan.layers[0].in_dim, &mut store);
            let o = backend.execute(&prepared, &nf, &staged, &mut scratch, None).expect("execute");
            out.push((format!("{}@{t}", lib.name(key)), o.embeddings.to_vec(), o.numerics));
        }
    }
    out
}

#[test]
fn fixed_backend_bit_identical_to_reference_backend() {
    let targets: Vec<u32> = (0..6).map(|i| i * 97 % 1_200).collect();
    let fast = run_direct(BackendChoice::Fixed, &targets);
    let slow = run_direct(BackendChoice::Reference, &targets);
    assert_eq!(fast.len(), slow.len());
    assert_eq!(fast.len(), 5 * targets.len(), "4 presets + the depth-3 spec");
    for ((label_a, emb_a, num_a), (label_b, emb_b, num_b)) in fast.iter().zip(slow.iter()) {
        assert_eq!(label_a, label_b);
        assert_eq!(num_a, &Numerics::FixedQ412, "{label_a}");
        assert_eq!(num_b, &Numerics::FixedQ412, "{label_a}");
        assert!(!emb_a.is_empty(), "{label_a}: numeric reply expected");
        assert_eq!(emb_a, emb_b, "{label_a}: hot path diverged from the reference executor");
    }
    // The depth-3 spec really ran: its final layer is 3-wide.
    assert!(fast.iter().any(|(l, e, _)| l.starts_with("tri3@") && e.len() == 3));
}

/// Serve `reqs` through a coordinator with the given backend and shard
/// count; responses in request order, plus the pool stats.
fn serve_all(
    graph: &CsrGraph,
    backend: BackendChoice,
    shards: usize,
    reqs: &[(ModelKey, u32)],
) -> (Vec<InferenceResponse>, ServeStats) {
    let cfg = ServeConfig {
        backend,
        shards,
        builders: 3,
        model_cfg: small_mc(),
        custom_specs: vec![depth3_spec()],
        ..Default::default()
    };
    let coord = Coordinator::start(graph.clone(), 11, cfg).unwrap();
    let pending: Vec<_> = reqs
        .iter()
        .enumerate()
        .map(|(i, &(m, t))| coord.submit(InferenceRequest::single(i as u64, m, t)).unwrap())
        .collect();
    let responses = pending.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
    let stats = coord.serve_stats();
    (responses, stats)
}

/// Mixed preset + depth-3-spec request set over the conformance graph.
fn mixed_requests(n: usize) -> (CsrGraph, Vec<(ModelKey, u32)>) {
    let g = conformance_graph();
    let lib = library();
    let keys: Vec<ModelKey> = lib.keys().collect();
    let reqs = (0..n)
        .map(|i| (keys[i % keys.len()], (i as u32 * 131) % 1_200))
        .collect();
    (g, reqs)
}

#[test]
fn pool_bit_identity_one_vs_four_shards_fixed_and_reference() {
    let (g, reqs) = mixed_requests(20);
    let (fixed1, _) = serve_all(&g, BackendChoice::Fixed, 1, &reqs);
    let (fixed4, s4) = serve_all(&g, BackendChoice::Fixed, 4, &reqs);
    assert_eq!(s4.shards, 4);
    assert_eq!(s4.backend_fallbacks, 0);
    // Cross-backend, cross-shard-count: the reference pool must land on
    // the very same bits.
    let (ref1, _) = serve_all(&g, BackendChoice::Reference, 1, &reqs);
    for ((a, b), c) in fixed1.iter().zip(fixed4.iter()).zip(ref1.iter()) {
        assert_eq!(a.id, b.id);
        assert!(!a.timing_only);
        assert_eq!(a.embedding, b.embedding, "id {}: shard count changed numerics", a.id);
        assert_eq!(a.accel_us, b.accel_us);
        assert_eq!(a.embedding, c.embedding, "id {}: backend changed numerics", a.id);
    }
}

#[test]
fn pool_identity_covers_the_pjrt_stub_backend() {
    // `--backend pjrt --shards 4` must run all 4 shards whatever
    // happens to the runtime. Default builds compile the stub executor,
    // so construction fails per shard and is *counted*, not logged away.
    let (g, reqs) = mixed_requests(12);
    let (one, s1) = serve_all(&g, BackendChoice::Pjrt, 1, &reqs);
    let (four, s4) = serve_all(&g, BackendChoice::Pjrt, 4, &reqs);
    assert_eq!(s1.shards, 1);
    assert_eq!(s4.shards, 4, "PJRT must not pin the pool to one shard");
    assert_eq!(s4.shard_backends.len(), 4);
    if s4.backend_fallbacks > 0 {
        assert_eq!(s4.backend_fallbacks, 4, "every stub shard falls back");
        assert!(
            s4.shard_backends.iter().all(|s| s.starts_with("timing-only (fallback:")),
            "{:?}",
            s4.shard_backends
        );
        assert!(four.iter().all(|r| r.timing_only && r.embedding.is_empty()));
    } else {
        assert!(s4.shard_backends.iter().all(|s| s == "pjrt"), "{:?}", s4.shard_backends);
    }
    for (a, b) in one.iter().zip(four.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.timing_only, b.timing_only);
        assert_eq!(a.embedding, b.embedding, "id {}: shard count changed the reply", a.id);
    }
}

/// With real artifacts (`make artifacts` + `--features pjrt`), the
/// float backend must agree with the Q4.12 datapath within fixed-point
/// error when both serve the same device weights — the trait-level
/// version of `runtime_e2e`'s centerpiece. Skips (passes vacuously)
/// when the PJRT runtime is stubbed out or artifacts are missing.
#[test]
fn pjrt_backend_matches_fixed_backend_within_quantization_error() {
    use grip::backend::PjrtBackend;
    use grip::greta::{ExecArgs, ALL_MODELS};
    use grip::runtime::{serving_weights, Manifest};

    let Ok(mut pjrt) = PjrtBackend::load(&Manifest::default_dir()) else {
        eprintln!("skipping: PJRT runtime/artifacts unavailable");
        return;
    };
    let mc = ModelConfig::paper();
    let lib = ModelLibrary::presets(&mc);
    let g = conformance_graph();
    let sampler = Sampler::new(3);
    let nf = Nodeflow::build(&g, &sampler, &[42], &mc);
    let mut fixed = BackendFactory::new(BackendChoice::Fixed).build(0).unwrap();
    let mut scratch_p = BackendScratch::new();
    let mut scratch_f = BackendScratch::new();
    for model in ALL_MODELS {
        let plan = lib.plan(model.key());
        let prepared_p = pjrt.prepare(plan, &ExecArgs::new()).unwrap();
        // Feed the fixed-point backend the *PJRT serving weights* so
        // the two engines compute the same function.
        let artifact = pjrt.executor().model(model.name()).unwrap().artifact.clone();
        let mut args = ExecArgs::new();
        for (spec, w) in artifact.args[3..].iter().zip(serving_weights(&artifact)) {
            args.insert(spec.name.clone(), (spec.shape.clone(), w));
        }
        let prepared_f = fixed.prepare(plan, &args).unwrap();

        let mut store = FeatureStore::new();
        let mut staged = StagedFeatures::new();
        staged.stage(&nf, mc.f_in, &mut store);
        let float = {
            let o = pjrt.execute(&prepared_p, &nf, &staged, &mut scratch_p, None).unwrap();
            assert_eq!(o.numerics, Numerics::Float, "{model:?}");
            o.embeddings.to_vec()
        };
        let fx = {
            let o = fixed.execute(&prepared_f, &nf, &staged, &mut scratch_f, None).unwrap();
            assert_eq!(o.numerics, Numerics::FixedQ412, "{model:?}");
            o.embeddings.to_vec()
        };
        let f_out = mc.f_out;
        let mut max_err = 0f32;
        let mut max_mag = 0f32;
        for (a, b) in float[..f_out].iter().zip(fx[..f_out].iter()) {
            max_err = max_err.max((a - b).abs());
            max_mag = max_mag.max(a.abs());
        }
        let budget = 0.05 + 0.05 * max_mag;
        assert!(max_err < budget, "{model:?}: PJRT vs fixed backend max err {max_err}");
    }
}
