//! Property tests for the PR-9 weight-residency manager (hand-rolled
//! seeded cases, same style as `serve_props.rs`; the offline crate set
//! has no `proptest`).
//!
//! THE property: paging prepared models in and out of a byte-budgeted
//! per-shard store moves *when* `prepare` runs, never *what* executes.
//! For the same request stream — all four presets plus a generated
//! multi-tenant zoo — replies must be bit-identical (embeddings AND
//! simulated timing) across {unlimited, tight} budgets × every eviction
//! policy × {1, 4} shards, while the tight single-shard store actually
//! pages (misses, evictions, bounded resident bytes).

use grip::backend::BackendChoice;
use grip::config::ModelConfig;
use grip::coordinator::{Coordinator, InferenceRequest, InferenceResponse, ServeConfig};
use grip::graph::{generate, CsrGraph, GeneratorParams};
use grip::greta::{ModelKey, ModelLibrary};
use grip::residency::{plan_weight_bytes, split_weight_budget, tenant_zoo, EvictPolicy};
use grip::rng::SplitMix64;

fn serving_graph(seed: u64) -> CsrGraph {
    generate(&GeneratorParams { nodes: 1_500, mean_degree: 7.0, seed, ..Default::default() })
}

fn small_mc() -> ModelConfig {
    ModelConfig { sample1: 4, sample2: 3, f_in: 12, f_hid: 10, f_out: 6 }
}

/// Serve `reqs` through a fixed-point pool with the given weight budget
/// and eviction policy, a 3-tenant zoo registered after the presets.
fn serve_all_budgeted(
    graph: &CsrGraph,
    budget_bytes: usize,
    policy: EvictPolicy,
    shards: usize,
    reqs: &[(ModelKey, u32)],
) -> (Vec<InferenceResponse>, grip::serve::ServeStats) {
    let cfg = ServeConfig {
        backend: BackendChoice::Fixed,
        shards,
        builders: 3,
        model_cfg: small_mc(),
        custom_specs: tenant_zoo(3, &small_mc()),
        weight_budget_bytes: budget_bytes,
        evict: policy,
        ..Default::default()
    };
    let coord = Coordinator::start(graph.clone(), 11, cfg).unwrap();
    let pending: Vec<_> = reqs
        .iter()
        .enumerate()
        .map(|(i, &(m, t))| coord.submit(InferenceRequest::single(i as u64, m, t)).unwrap())
        .collect();
    let responses = pending.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
    let stats = coord.serve_stats();
    (responses, stats)
}

/// The largest single prepared model in the 4-preset + 3-tenant library
/// — a budget of `max + 1` admits any one model but never two.
fn one_model_budget() -> usize {
    let (lib, _) = ModelLibrary::with_customs(&small_mc(), &tenant_zoo(3, &small_mc())).unwrap();
    let seed = ServeConfig::default().weight_seed;
    lib.keys().map(|k| plan_weight_bytes(&lib, k, seed)).max().unwrap() + 1
}

#[test]
fn prop_paging_is_bit_identical_across_budgets_policies_and_shards() {
    let graph = serving_graph(29);
    let (lib, _) = ModelLibrary::with_customs(&small_mc(), &tenant_zoo(3, &small_mc())).unwrap();
    let keys: Vec<ModelKey> = lib.keys().collect();
    assert_eq!(keys.len(), 7, "4 presets + 3 tenants");
    let mut rng = SplitMix64::new(83);
    let reqs: Vec<(ModelKey, u32)> = (0..42)
        .map(|i| (keys[i % keys.len()], rng.gen_range(1_500) as u32))
        .collect();

    // Baseline: the unlimited eager store (budget 0), single shard.
    let (want, base_stats) = serve_all_budgeted(&graph, 0, EvictPolicy::Lru, 1, &reqs);
    assert!(want.iter().all(|r| !r.timing_only), "every tenant serves numerics");
    assert_eq!(base_stats.residency_budget_bytes, 0);
    assert_eq!(base_stats.residency_misses, 0, "eager store never pages");
    assert_eq!(base_stats.residency_evictions, 0);
    assert_eq!(base_stats.residency_policy, "", "no policy without a budget");

    let tight = one_model_budget();
    for policy in [EvictPolicy::Lru, EvictPolicy::Cost, EvictPolicy::SizeAware] {
        for shards in [1usize, 4] {
            // Scale the budget so each shard's split still fits exactly
            // one model — the maximum paging pressure at any width.
            let budget = tight * shards;
            let (got, stats) = serve_all_budgeted(&graph, budget, policy, shards, &reqs);
            assert_eq!(got.len(), want.len());
            for (a, b) in want.iter().zip(got.iter()) {
                assert_eq!(a.id, b.id);
                assert_eq!(
                    a.embedding, b.embedding,
                    "id {}: {} x {shards} shards changed numerics",
                    a.id,
                    policy.name()
                );
                assert_eq!(
                    a.accel_us, b.accel_us,
                    "id {}: {} x {shards} shards changed timing",
                    a.id,
                    policy.name()
                );
                assert_eq!(a.neighborhood, b.neighborhood);
                assert!(!b.timing_only);
            }
            assert_eq!(stats.residency_policy, policy.name());
            assert_eq!(stats.residency_budget_bytes, budget as u64);
            assert!(
                stats.residency_misses >= keys.len() as u64,
                "{} x {shards}: every model pages in at least once (got {} misses)",
                policy.name(),
                stats.residency_misses
            );
            assert!(
                stats.residency_evictions >= 1,
                "{} x {shards}: a one-model budget must evict",
                policy.name()
            );
            assert!(
                stats.residency_resident_bytes <= budget as u64,
                "{} x {shards}: resident bytes {} exceed the budget {budget}",
                policy.name(),
                stats.residency_resident_bytes
            );
            assert_eq!(stats.residency_prepare_failures, 0);
            assert_eq!(stats.backend_fallbacks, 0, "paging is not a fallback");
            assert_eq!(
                stats.residency_hits + stats.residency_misses,
                reqs.len() as u64,
                "{} x {shards}: every job looked its model up exactly once",
                policy.name()
            );
        }
    }
}

#[test]
fn prop_split_weight_budget_conserves_bytes() {
    // The shard split mirrors split_cache_rows: largest remainder,
    // total conserved, shares within one byte of each other.
    let mut rng = SplitMix64::new(0x5EED_B4D9);
    for case in 0..200 {
        let budget = rng.gen_range(1 << 20) + 1;
        let shards = rng.gen_range(8) + 1;
        let split = split_weight_budget(budget, shards);
        assert_eq!(split.len(), shards, "case {case}");
        assert_eq!(split.iter().sum::<usize>(), budget, "case {case}: bytes lost in the split");
        let min = *split.iter().min().unwrap();
        let max = *split.iter().max().unwrap();
        assert!(max - min <= 1, "case {case}: uneven split {split:?}");
    }
    assert_eq!(split_weight_budget(0, 4), vec![0; 4], "budget 0 splits to 0 everywhere");
}

#[test]
fn prop_generous_budget_stops_evicting_but_replies_never_move() {
    // Between "fits one model" and "fits everything" the only visible
    // change is counter traffic: a budget covering the whole zoo admits
    // every model once and never evicts, and replies still match the
    // eager store bit for bit.
    let graph = serving_graph(31);
    let (lib, _) = ModelLibrary::with_customs(&small_mc(), &tenant_zoo(3, &small_mc())).unwrap();
    let keys: Vec<ModelKey> = lib.keys().collect();
    let seed = ServeConfig::default().weight_seed;
    let total: usize = lib.keys().map(|k| plan_weight_bytes(&lib, k, seed)).sum();
    let mut rng = SplitMix64::new(59);
    let reqs: Vec<(ModelKey, u32)> = (0..21)
        .map(|i| (keys[i % keys.len()], rng.gen_range(1_500) as u32))
        .collect();

    let (want, _) = serve_all_budgeted(&graph, 0, EvictPolicy::Lru, 1, &reqs);
    let (got, stats) = serve_all_budgeted(&graph, total, EvictPolicy::Lru, 1, &reqs);
    for (a, b) in want.iter().zip(got.iter()) {
        assert_eq!(a.embedding, b.embedding, "id {}: generous budget changed numerics", a.id);
        assert_eq!(a.accel_us, b.accel_us);
    }
    assert_eq!(stats.residency_evictions, 0, "everything fits: nothing to evict");
    assert_eq!(stats.residency_misses, keys.len() as u64, "each model prepared exactly once");
    assert_eq!(stats.residency_resident_models, keys.len() as u64);
    assert_eq!(stats.residency_resident_bytes, total as u64);
}
