//! Property tests for the PR-7 request-lifecycle telemetry
//! (hand-rolled seeded cases, same style as `serve_props.rs`).
//!
//! * Every sampled span is stamped in exact pipeline order — all
//!   eleven stages present, timestamps non-decreasing — across
//!   {pipeline on/off} × {partition degree/off} × {1, 4} shards, and
//!   the Chrome exporter renders those spans as a parseable
//!   `trace_event` document with one slice per pipeline unit.
//! * Telemetry is bit-invisible: any `--trace-sample` (0 = off, 1 =
//!   every request, the 1-in-64 default) yields replies identical to
//!   telemetry-off — embeddings AND simulated timing — for all four
//!   presets plus a depth-3 custom spec. Observation may never change
//!   numerics.

use grip::backend::BackendChoice;
use grip::config::ModelConfig;
use grip::coordinator::{
    Coordinator, InferenceRequest, InferenceResponse, PipelineConfig, ServeConfig,
};
use grip::graph::{generate, CsrGraph, GeneratorParams, PartitionStrategy};
use grip::greta::{Activate, LayerSpec, ModelKey, ModelLibrary, ModelSpec, ProgramSpec, ReduceOp};
use grip::rng::SplitMix64;
use grip::telemetry::{chrome_trace_json, SpanTrace, STAGES};

fn serving_graph(seed: u64) -> CsrGraph {
    generate(&GeneratorParams { nodes: 1_500, mean_degree: 7.0, seed, ..Default::default() })
}

fn small_mc() -> ModelConfig {
    ModelConfig { sample1: 4, sample2: 3, f_in: 12, f_hid: 10, f_out: 6 }
}

fn depth3_spec() -> ModelSpec {
    ModelSpec::builder("tri3")
        .layer(LayerSpec::new(8, 6).sample(3).program(
            ProgramSpec::new("t0")
                .reduce(ReduceOp::Mean)
                .transform("t_w0", 8, 6)
                .activate(Activate::Relu),
        ))
        .layer(LayerSpec::new(6, 5).sample(2).program(
            ProgramSpec::new("t1")
                .reduce(ReduceOp::Mean)
                .transform("t_w1", 6, 5)
                .activate(Activate::Relu),
        ))
        .layer(LayerSpec::new(5, 3).sample(2).program(
            ProgramSpec::new("t2")
                .reduce(ReduceOp::Mean)
                .transform("t_w2", 5, 3)
                .activate(Activate::Relu),
        ))
        .build()
}

fn telemetry_cfg(
    shards: usize,
    pipeline: PipelineConfig,
    partition: PartitionStrategy,
    trace_sample: u64,
) -> ServeConfig {
    ServeConfig {
        backend: BackendChoice::Fixed,
        shards,
        builders: 3,
        model_cfg: small_mc(),
        pipeline,
        partition,
        cache_rows: 300,
        custom_specs: vec![depth3_spec()],
        trace_sample,
        ..Default::default()
    }
}

/// `n` requests cycling through all five model keys (4 presets +
/// tri3) with seeded targets.
fn mixed_reqs(n: usize, seed: u64) -> Vec<(ModelKey, u32)> {
    let (lib, _) = ModelLibrary::with_customs(&small_mc(), &[depth3_spec()]).unwrap();
    let keys: Vec<ModelKey> = lib.keys().collect();
    assert_eq!(keys.len(), 5, "4 presets + tri3");
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|i| (keys[i % keys.len()], rng.gen_range(1_500) as u32)).collect()
}

/// Serve `reqs` in order and return (replies, drained spans). Spans
/// are deposited before each reply is sent, so draining after the
/// last reply observes every sampled request.
fn serve_collect(
    graph: &CsrGraph,
    cfg: ServeConfig,
    reqs: &[(ModelKey, u32)],
) -> (Vec<InferenceResponse>, Vec<SpanTrace>) {
    let coord = Coordinator::start(graph.clone(), 11, cfg).unwrap();
    let pending: Vec<_> = reqs
        .iter()
        .enumerate()
        .map(|(i, &(m, t))| coord.submit(InferenceRequest::single(i as u64, m, t)).unwrap())
        .collect();
    let replies = pending.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
    let spans = coord.telemetry().take_spans();
    (replies, spans)
}

// --------------------------------------------- span stamp monotonicity
#[test]
fn prop_span_stamps_are_monotone_across_modes() {
    // THE tracing property: a request's stamps appear in exactly the
    // STAGES order regardless of how the pool is configured — phase
    // decoupling and partition routing reorder *work*, never a single
    // request's own lifecycle.
    let graph = serving_graph(31);
    let reqs = mixed_reqs(20, 53);
    for pipeline in [PipelineConfig::default(), PipelineConfig::off()] {
        for partition in [PartitionStrategy::Off, PartitionStrategy::Degree] {
            for shards in [1usize, 4] {
                let label = format!(
                    "pipeline={} partition={} shards={shards}",
                    pipeline.enabled,
                    partition.name()
                );
                let cfg = telemetry_cfg(shards, pipeline, partition, 1);
                let (replies, spans) = serve_collect(&graph, cfg, &reqs);
                assert_eq!(replies.len(), reqs.len(), "{label}: lost replies");
                assert_eq!(
                    spans.len(),
                    reqs.len(),
                    "{label}: trace-sample 1 must span every request"
                );
                for span in &spans {
                    let id = span.request_id;
                    let mut prev = f64::NEG_INFINITY;
                    for st in STAGES {
                        assert!(
                            span.get(st).is_some(),
                            "{label}: request {id} missing stage {}",
                            st.name()
                        );
                        let t = span.get(st).unwrap();
                        assert!(
                            t >= prev,
                            "{label}: request {id} stage {} out of order ({t} < {prev})",
                            st.name()
                        );
                        prev = t;
                    }
                    assert!(span.shard.is_some(), "{label}: request {id} executed on no shard");
                    let shard = span.shard.unwrap();
                    assert!(shard < shards, "{label}: shard {shard} out of range");
                    assert_eq!(
                        span.lane.is_some(),
                        pipeline.enabled,
                        "{label}: request {id} lane recorded iff pipelined"
                    );
                    assert!(
                        span.boundary_wait_us >= 0.0,
                        "{label}: request {id} negative boundary wait"
                    );
                    if partition == PartitionStrategy::Off {
                        assert_eq!(
                            span.boundary_wait_us, 0.0,
                            "{label}: request {id} boundary wait without partitioning"
                        );
                    }
                }
                // The exporter must turn these spans into a
                // Perfetto-loadable document with per-unit slices.
                let doc = chrome_trace_json(&[(label.clone(), spans)]);
                grip::runtime::json::parse(&doc)
                    .unwrap_or_else(|e| panic!("{label}: invalid trace JSON: {e}"));
                for slice in ["\"batch\"", "\"build\"", "\"prefetch\"", "\"execute\""] {
                    assert!(doc.contains(slice), "{label}: missing {slice} slices");
                }
            }
        }
    }
}

// ----------------------------------------------- observer bit-identity
#[test]
fn prop_replies_bit_identical_for_any_trace_sample() {
    // THE observability invariant: tracing rides the side of the
    // pipeline. Sampling every request, 1-in-64, or nothing must
    // produce byte-for-byte the replies of a telemetry-off run, for
    // the plain pool and the partitioned 4-shard pool alike.
    let graph = serving_graph(37);
    let reqs = mixed_reqs(30, 91);
    let pools = [
        (PipelineConfig::default(), PartitionStrategy::Off, 3usize),
        (PipelineConfig::default(), PartitionStrategy::Degree, 4usize),
    ];
    for (pipeline, partition, shards) in pools {
        let label = format!("partition={} shards={shards}", partition.name());
        let (base, off_spans) =
            serve_collect(&graph, telemetry_cfg(shards, pipeline, partition, 0), &reqs);
        assert!(off_spans.is_empty(), "{label}: trace-sample 0 must collect no spans");
        for sample in [1u64, 64] {
            let cfg = telemetry_cfg(shards, pipeline, partition, sample);
            let (got, spans) = serve_collect(&graph, cfg, &reqs);
            let expect = (0..reqs.len() as u64).filter(|i| i % sample == 0).count();
            assert_eq!(spans.len(), expect, "{label}: wrong span count at 1-in-{sample}");
            assert_eq!(base.len(), got.len(), "{label}: lost replies at 1-in-{sample}");
            for (a, b) in base.iter().zip(got.iter()) {
                assert_eq!(a.id, b.id);
                assert_eq!(
                    a.embedding, b.embedding,
                    "{label}: id {} trace-sample {sample} changed numerics",
                    a.id
                );
                assert_eq!(
                    a.accel_us, b.accel_us,
                    "{label}: id {} trace-sample {sample} changed simulated timing",
                    a.id
                );
                assert_eq!(a.neighborhood, b.neighborhood);
            }
        }
    }
}
