//! Property tests for the PR-10 cross-request activation memo
//! (hand-rolled seeded cases, same style as `residency_props.rs`; the
//! offline crate set has no `proptest`).
//!
//! THE property: memoizing interior-layer hub embeddings moves *work*
//! (sampling, gathering, staging, matmul width), never *bits*. For the
//! same request stream — all four presets plus a depth-3 custom spec,
//! every (model, target) pair requested twice so the second pass can
//! reuse the first — replies must be bit-identical across
//! {off, tight, generous} memo budgets × {1, 4} shards ×
//! {off, degree} partitioning × {pipelined, sequential} shards, while
//! the generous run demonstrably hits, prunes, and stages fewer
//! layer-0 rows. `accel_us` is asserted `<=` the baseline (never `==`):
//! a hit prunes the hit vertex's whole sampling subtree, so the
//! simulated pass legitimately shrinks — the embedding bytes are the
//! invariant the design hangs on.

use grip::backend::BackendChoice;
use grip::config::ModelConfig;
use grip::coordinator::{Coordinator, InferenceRequest, InferenceResponse, ServeConfig};
use grip::fixed::Fx16;
use grip::graph::{generate, CsrGraph, GeneratorParams, PartitionStrategy};
use grip::greta::{
    Activate, LayerSpec, ModelKey, ModelLibrary, ModelSpec, ProgramSpec, ReduceOp,
};
use grip::rng::SplitMix64;
use grip::serve::{
    split_cache_rows, DegreeClasses, MemoCache, MemoKey, PipelineConfig, ServeStats,
    MEMO_MIN_CLASS,
};
use std::cmp::Reverse;

/// Small enough to evict under the distinct hub rows one pass deposits.
const TIGHT: usize = 8;
/// Large enough that nothing admitted is ever evicted.
const GENEROUS: usize = 65_536;

fn serving_graph(seed: u64) -> CsrGraph {
    generate(&GeneratorParams { nodes: 1_500, mean_degree: 7.0, seed, ..Default::default() })
}

fn small_mc() -> ModelConfig {
    ModelConfig { sample1: 4, sample2: 3, f_in: 12, f_hid: 10, f_out: 6 }
}

/// A depth-3 mean-aggregate spec (8 → 6 → 5 → 3) so the matrix covers
/// a model whose interior has *two* memoizable layers.
fn depth3_spec() -> ModelSpec {
    ModelSpec::builder("memo3")
        .layer(LayerSpec::new(8, 6).sample(3).program(
            ProgramSpec::new("m0")
                .reduce(ReduceOp::Mean)
                .transform("m_w0", 8, 6)
                .activate(Activate::Relu),
        ))
        .layer(LayerSpec::new(6, 5).sample(2).program(
            ProgramSpec::new("m1")
                .reduce(ReduceOp::Mean)
                .transform("m_w1", 6, 5)
                .activate(Activate::Relu),
        ))
        .layer(LayerSpec::new(5, 3).sample(2).program(
            ProgramSpec::new("m2")
                .reduce(ReduceOp::Mean)
                .transform("m_w2", 5, 3)
                .activate(Activate::Relu),
        ))
        .build()
}

/// The generator draws power-law degrees *randomly per vertex* — low
/// ids are not hubs. Deterministic hits need the actual top of the
/// degree distribution as targets.
fn hub_targets(g: &CsrGraph, n: usize) -> Vec<u32> {
    let mut vs: Vec<u32> = (0..g.num_vertices() as u32).collect();
    vs.sort_by_key(|&v| Reverse(g.degree(v)));
    vs.truncate(n);
    vs
}

/// Two identical passes over model × hub: every (model, target) pair
/// repeats exactly once, so pass 2 re-requests what pass 1 deposited.
fn two_pass_hub_requests(keys: &[ModelKey], hubs: &[u32]) -> Vec<(ModelKey, u32)> {
    let mut reqs = Vec::with_capacity(2 * keys.len() * hubs.len());
    for _pass in 0..2 {
        for &h in hubs {
            for &k in keys {
                reqs.push((k, h));
            }
        }
    }
    reqs
}

/// Serve `reqs` through a fixed-point pool with the given memo budget,
/// shard count, partitioning, and pipeline mode. Requests are submitted
/// *serially* (await each reply before the next submit): the deposits
/// from request i are then deterministically visible to the build of
/// request i+1, whatever the shard/pipeline width.
fn serve_all_memo(
    graph: &CsrGraph,
    memo_rows: usize,
    shards: usize,
    partition: PartitionStrategy,
    pipeline: PipelineConfig,
    reqs: &[(ModelKey, u32)],
) -> (Vec<InferenceResponse>, ServeStats) {
    let cfg = ServeConfig {
        backend: BackendChoice::Fixed,
        shards,
        builders: 3,
        model_cfg: small_mc(),
        custom_specs: vec![depth3_spec()],
        partition,
        pipeline,
        memo_rows,
        ..Default::default()
    };
    let coord = Coordinator::start(graph.clone(), 11, cfg).unwrap();
    let responses: Vec<InferenceResponse> = reqs
        .iter()
        .enumerate()
        .map(|(i, &(m, t))| {
            coord.submit(InferenceRequest::single(i as u64, m, t)).unwrap().recv().unwrap().unwrap()
        })
        .collect();
    let stats = coord.serve_stats();
    (responses, stats)
}

#[test]
fn prop_memoization_is_bit_identical_across_budgets_shards_partition_pipeline() {
    let graph = serving_graph(29);
    let (lib, _) = ModelLibrary::with_customs(&small_mc(), &[depth3_spec()]).unwrap();
    let keys: Vec<ModelKey> = lib.keys().collect();
    assert_eq!(keys.len(), 5, "4 presets + the depth-3 spec");
    let hubs = hub_targets(&graph, 6);
    let reqs = two_pass_hub_requests(&keys, &hubs);

    // Baseline: memo off, single shard, shared queue, pipelined.
    let (want, base) = serve_all_memo(
        &graph,
        0,
        1,
        PartitionStrategy::Off,
        PipelineConfig::default(),
        &reqs,
    );
    assert!(want.iter().all(|r| !r.timing_only));
    assert_eq!(base.memo_rows_total, 0);
    assert_eq!(
        base.memo_hits + base.memo_misses + base.memo_deposits,
        0,
        "--memo-rows 0 keeps every memo counter silent"
    );
    assert_eq!(base.memo_hit_rate, 0.0);
    assert!(base.staged_rows > 0, "staged-row accounting is always on");

    for memo_rows in [TIGHT, GENEROUS] {
        for shards in [1usize, 4] {
            for partition in [PartitionStrategy::Off, PartitionStrategy::Degree] {
                for sequential in [false, true] {
                    let pipeline =
                        if sequential { PipelineConfig::off() } else { PipelineConfig::default() };
                    let tag = format!(
                        "memo={memo_rows} x {shards} shards x {partition:?} x seq={sequential}"
                    );
                    let (got, stats) =
                        serve_all_memo(&graph, memo_rows, shards, partition, pipeline, &reqs);
                    assert_eq!(got.len(), want.len(), "{tag}");
                    for (a, b) in want.iter().zip(got.iter()) {
                        assert_eq!(a.id, b.id);
                        assert_eq!(
                            a.embedding, b.embedding,
                            "id {}: {tag} changed numerics",
                            a.id
                        );
                        assert!(
                            b.accel_us <= a.accel_us,
                            "id {}: {tag} grew the simulated pass ({} > {})",
                            a.id,
                            b.accel_us,
                            a.accel_us
                        );
                        assert!(b.neighborhood <= a.neighborhood, "id {}: {tag}", a.id);
                        assert!(!b.timing_only);
                    }
                    assert_eq!(stats.memo_rows_total, memo_rows, "{tag}");
                    let caches =
                        if matches!(partition, PartitionStrategy::Off) { 1 } else { shards };
                    assert_eq!(stats.shard_memo_rows.len(), caches, "{tag}");
                    assert_eq!(
                        stats.shard_memo_rows.iter().sum::<usize>(),
                        memo_rows,
                        "{tag}: rows lost in the shard split"
                    );
                    assert!(
                        stats.memo_resident_rows <= memo_rows as u64,
                        "{tag}: resident rows {} exceed the budget",
                        stats.memo_resident_rows
                    );
                    if memo_rows == GENEROUS {
                        assert!(stats.memo_deposits > 0, "{tag}: pass 1 must harvest hub rows");
                        assert!(
                            stats.memo_hits > 0,
                            "{tag}: pass 2 must hit what pass 1 deposited"
                        );
                        assert!(stats.memo_hit_rate > 0.0, "{tag}");
                        assert!(stats.memo_pruned_vertices > 0, "{tag}: hits must prune");
                        assert!(stats.memo_pruned_edges > 0, "{tag}");
                        assert!(stats.memo_resident_bytes > 0, "{tag}");
                        assert_eq!(
                            stats.memo_evictions, 0,
                            "{tag}: a generous budget never evicts"
                        );
                        assert!(
                            stats.staged_rows < base.staged_rows,
                            "{tag}: pruning must gather fewer layer-0 rows ({} vs {})",
                            stats.staged_rows,
                            base.staged_rows
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_thrashing_memo_budget_still_replies_bit_identically() {
    // A two-row cache under dozens of distinct hub rows turns over
    // constantly; turnover may cost hits, never bits.
    let graph = serving_graph(31);
    let (lib, _) = ModelLibrary::with_customs(&small_mc(), &[depth3_spec()]).unwrap();
    let keys: Vec<ModelKey> = lib.keys().collect();
    let hubs = hub_targets(&graph, 8);
    let reqs = two_pass_hub_requests(&keys, &hubs);

    let (want, _) = serve_all_memo(
        &graph,
        0,
        1,
        PartitionStrategy::Off,
        PipelineConfig::default(),
        &reqs,
    );
    let (got, stats) = serve_all_memo(
        &graph,
        2,
        1,
        PartitionStrategy::Off,
        PipelineConfig::default(),
        &reqs,
    );
    for (a, b) in want.iter().zip(got.iter()) {
        assert_eq!(a.embedding, b.embedding, "id {}: thrashing changed numerics", a.id);
        assert!(b.accel_us <= a.accel_us, "id {}", a.id);
    }
    assert_eq!(stats.memo_rows_total, 2);
    assert!(stats.memo_resident_rows <= 2, "residency stays under the budget while thrashing");
    assert!(stats.memo_deposits > 0);
    assert!(
        stats.memo_evictions > 0,
        "a two-row cache under {} requests over {} hubs must turn over",
        reqs.len(),
        hubs.len()
    );
}

#[test]
fn prop_memo_budget_split_conserves_rows_and_the_pool_applies_it() {
    // `--memo-rows` shares `split_cache_rows` with the feature cache:
    // largest remainder, total conserved, shares within one row.
    let mut rng = SplitMix64::new(0x4D45_4D4F);
    for case in 0..200 {
        let rows = rng.gen_range(1 << 16) + 1;
        let shards = rng.gen_range(8) + 1;
        let split = split_cache_rows(rows, shards);
        assert_eq!(split.len(), shards, "case {case}");
        assert_eq!(split.iter().sum::<usize>(), rows, "case {case}: rows lost in the split");
        let min = *split.iter().min().unwrap();
        let max = *split.iter().max().unwrap();
        assert!(max - min <= 1, "case {case}: uneven split {split:?}");
    }
    assert_eq!(split_cache_rows(0, 4), vec![0; 4], "budget 0 splits to 0 everywhere");

    // The partitioned pool reports exactly that split back.
    let graph = serving_graph(33);
    let hubs = hub_targets(&graph, 2);
    let reqs: Vec<(ModelKey, u32)> =
        hubs.iter().map(|&h| (ModelKey::from_index(0), h)).collect();
    let (_, stats) = serve_all_memo(
        &graph,
        1_001,
        3,
        PartitionStrategy::Degree,
        PipelineConfig::default(),
        &reqs,
    );
    assert_eq!(stats.shard_memo_rows, split_cache_rows(1_001, 3));
    assert_eq!(stats.memo_rows_total, 1_001);
}

#[test]
fn prop_admission_is_hub_only_per_calibrated_classes() {
    // Synthetic skew: a heavy degree-2 tail under a 10-vertex hub band.
    let degrees: Vec<usize> = (0..100).map(|i| if i < 90 { 2 } else { 140 + i }).collect();
    let classes = DegreeClasses::from_degrees(degrees);
    let cache = MemoCache::with_classes(64, classes);
    assert!(!cache.admits(0));
    assert!(!cache.admits(classes.b2), "class 2 (at the p75 breakpoint) is refused");
    assert!(cache.admits(classes.b2 + 1), "just above p75 = class 3: admitted");
    assert!(cache.admits(1_000_000), "class 4: admitted");
    // The gate is exactly `class >= MEMO_MIN_CLASS`, nothing looser.
    for d in [0, 1, 2, classes.b1, classes.b2, classes.b2 + 1, classes.b3, classes.b3 + 1, 10_000]
    {
        assert_eq!(cache.admits(d), classes.class(d) >= MEMO_MIN_CLASS, "degree {d}");
    }

    // Over the real serving graph: the hubs the design is about are
    // admitted, the minimum-degree tail never is.
    let g = serving_graph(29);
    let gc = DegreeClasses::from_graph(&g);
    let gcache = MemoCache::with_classes(64, gc);
    for &h in &hub_targets(&g, 4) {
        assert!(gcache.admits(g.degree(h)), "top-degree hub {h} must be admitted");
    }
    let tail = (0..g.num_vertices() as u32).min_by_key(|&v| g.degree(v)).unwrap();
    assert!(!gcache.admits(g.degree(tail)), "the minimum-degree vertex is never a hub");
    // And a zero-row budget admits nothing at any degree.
    assert!(!MemoCache::with_classes(0, gc).admits(1_000_000));
}

#[test]
fn prop_weight_seed_is_part_of_the_key_and_memoized_serving_respects_it() {
    // Unit level: the same (model, layer, vertex) under two weight
    // seeds must never alias to one slot.
    let c = MemoCache::with_classes(8, DegreeClasses::default());
    let k1 = MemoKey { model: ModelKey::from_index(2), seed: 0xA11CE, layer: 1, vertex: 7 };
    let k2 = MemoKey { seed: 0xB0B, ..k1 };
    c.insert(k1, 1_000, vec![Fx16::from_raw(1_111); 5]);
    assert_eq!(c.lookup(k2), None, "a different weight seed must miss");
    assert_eq!(c.lookup(k1), Some(vec![Fx16::from_raw(1_111); 5]), "the original seed hits");
    assert_eq!(c.resident_rows(), 1);

    // End to end: under a non-default weight seed the memoized pool
    // still matches its own memo-off baseline bit for bit (the cached
    // rows are keyed by *that* seed), while serving visibly different
    // bits than the default-seed pool (the weights really changed).
    let graph = serving_graph(37);
    let (lib, _) = ModelLibrary::with_customs(&small_mc(), &[depth3_spec()]).unwrap();
    let keys: Vec<ModelKey> = lib.keys().collect();
    let hubs = hub_targets(&graph, 4);
    let reqs = two_pass_hub_requests(&keys, &hubs);
    let run = |memo_rows: usize, seed: u64| {
        let cfg = ServeConfig {
            backend: BackendChoice::Fixed,
            shards: 1,
            builders: 3,
            model_cfg: small_mc(),
            custom_specs: vec![depth3_spec()],
            weight_seed: seed,
            memo_rows,
            ..Default::default()
        };
        let coord = Coordinator::start(graph.clone(), 11, cfg).unwrap();
        let responses: Vec<InferenceResponse> = reqs
            .iter()
            .enumerate()
            .map(|(i, &(m, t))| {
                coord
                    .submit(InferenceRequest::single(i as u64, m, t))
                    .unwrap()
                    .recv()
                    .unwrap()
                    .unwrap()
            })
            .collect();
        let stats = coord.serve_stats();
        (responses, stats)
    };

    let (want, _) = run(0, 0xBEEF);
    let (got, stats) = run(GENEROUS, 0xBEEF);
    for (a, b) in want.iter().zip(got.iter()) {
        assert_eq!(a.embedding, b.embedding, "id {}: memo changed numerics under seed", a.id);
    }
    assert!(stats.memo_hits > 0, "repeated hub targets must hit under any seed");

    let (base, _) = run(0, ServeConfig::default().weight_seed);
    assert!(
        want.iter().zip(base.iter()).any(|(a, b)| a.embedding != b.embedding),
        "two weight seeds must not serve the same function"
    );
}
