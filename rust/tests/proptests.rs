//! Property-based tests over randomized inputs.
//!
//! The offline vendored crate set has no `proptest`, so this is a
//! lightweight hand-rolled harness: each property runs over a few
//! hundred seeded random cases from `SplitMix64` (deterministic; a
//! failing seed is printed for reproduction).

use grip::config::{GripConfig, ModelConfig};
use grip::fixed::{Fx16, LutConfig, TwoLevelLut};
use grip::graph::{generate, GeneratorParams};
use grip::greta::{compile, exec_test_args, execute_model, execute_model_ref, GnnModel, ALL_MODELS};
use grip::nodeflow::{Nodeflow, NodeflowLayer, PartitionedLayer, Sampler};
use grip::rng::SplitMix64;
use grip::sim::simulate;

/// Run `f` over `n` seeded cases.
fn for_cases(n: u64, mut f: impl FnMut(u64, &mut SplitMix64)) {
    for case in 0..n {
        let mut rng = SplitMix64::new(0xC0FFEE ^ case.wrapping_mul(0x9E3779B97F4A7C15));
        f(case, &mut rng);
    }
}

fn random_layer(rng: &mut SplitMix64) -> NodeflowLayer {
    let num_outputs = 1 + rng.gen_range(30);
    let extra_inputs = rng.gen_range(200);
    let num_inputs = num_outputs + extra_inputs;
    let num_edges = rng.gen_range(400);
    let edges = (0..num_edges)
        .map(|_| (rng.gen_range(num_inputs) as u32, rng.gen_range(num_outputs) as u32))
        .collect();
    NodeflowLayer::new((0..num_inputs as u32).collect(), num_outputs, edges)
}

// ------------------------------------------------------ CSR edge view
#[test]
fn prop_csr_view_matches_edge_list() {
    for_cases(300, |case, rng| {
        let layer = random_layer(rng);
        assert_eq!(layer.edge_offsets.len(), layer.num_outputs + 1, "case {case}");
        assert_eq!(layer.edge_srcs.len(), layer.edges.len(), "case {case}");
        for v in 0..layer.num_outputs {
            // Same sources, same relative order (stable counting sort).
            let want: Vec<u32> = layer
                .edges
                .iter()
                .filter(|&&(_, d)| d as usize == v)
                .map(|&(u, _)| u)
                .collect();
            assert_eq!(layer.edge_srcs_of(v), &want[..], "case {case} dst {v}");
        }
    });
}

// ---------------------------------------------------------- partitioning
#[test]
fn prop_partition_preserves_every_edge_exactly_once() {
    for_cases(300, |case, rng| {
        let layer = random_layer(rng);
        let n = 1 + rng.gen_range(64);
        let m = 1 + rng.gen_range(16);
        let part = PartitionedLayer::new(&layer, n, m);
        // total count preserved
        assert_eq!(part.total_edges(), layer.edges.len(), "case {case}");
        // every edge recoverable at its global coordinates
        let mut reconstructed = Vec::new();
        for j in 0..part.num_output_chunks {
            for i in 0..part.num_input_chunks {
                for &(ul, vl) in &part.block(i, j).edges {
                    reconstructed.push(((i * n) as u32 + ul, (j * m) as u32 + vl));
                }
            }
        }
        let mut want = layer.edges.clone();
        want.sort_unstable();
        reconstructed.sort_unstable();
        assert_eq!(reconstructed, want, "case {case} (n={n}, m={m})");
    });
}

#[test]
fn prop_partition_chunk_sizes_cover_exactly() {
    for_cases(200, |case, rng| {
        let layer = random_layer(rng);
        let n = 1 + rng.gen_range(64);
        let m = 1 + rng.gen_range(16);
        let part = PartitionedLayer::new(&layer, n, m);
        assert_eq!(
            part.chunk_input_sizes.iter().sum::<usize>(),
            layer.num_inputs(),
            "case {case}"
        );
        assert_eq!(
            part.chunk_output_sizes.iter().sum::<usize>(),
            layer.num_outputs,
            "case {case}"
        );
        assert!(part.chunk_input_sizes.iter().all(|&s| s <= n));
        assert!(part.chunk_output_sizes.iter().all(|&s| s <= m));
    });
}

// -------------------------------------------------------------- nodeflow
#[test]
fn prop_nodeflow_invariants() {
    let g = generate(&GeneratorParams { nodes: 3_000, mean_degree: 7.0, ..Default::default() });
    let mc = ModelConfig { sample1: 5, sample2: 4, f_in: 8, f_hid: 8, f_out: 4 };
    for_cases(200, |case, rng| {
        let s = Sampler::new(rng.next_u64());
        let t = rng.gen_range(3_000) as u32;
        let nf = Nodeflow::build(&g, &s, &[t], &mc);
        // V-prefix-of-U convention at every layer.
        let v1: Vec<u32> = nf.layers[0].inputs[..nf.layers[0].num_outputs].to_vec();
        assert_eq!(v1, nf.layers[1].inputs, "case {case}");
        assert_eq!(nf.layers[1].inputs[0], t, "case {case}");
        // Inputs unique.
        for l in &nf.layers {
            let mut u = l.inputs.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), l.inputs.len(), "case {case}");
            for &(us, vd) in &l.edges {
                assert!((us as usize) < l.num_inputs());
                assert!((vd as usize) < l.num_outputs);
            }
        }
        // Edge sources really are sampled neighbors.
        for &(us, vd) in &nf.layers[1].edges {
            let src = nf.layers[1].inputs[us as usize];
            let dst = nf.layers[1].inputs[vd as usize];
            assert!(g.neighbors(dst).contains(&src), "case {case}");
        }
    });
}

// ------------------------------------------------------------- executor
/// PR 1 acceptance: the destination-sorted CSR executor must be
/// bit-identical to the seed edge-list executor for all four models —
/// including GraphSAGE's `ReduceOp::Max` first-touch semantics and
/// order-sensitive saturating sums, which only survive because the CSR
/// sort is stable within each destination.
#[test]
fn prop_csr_executor_bit_identical_to_edge_list() {
    let g = generate(&GeneratorParams { nodes: 2_000, mean_degree: 9.0, ..Default::default() });
    for_cases(30, |case, rng| {
        let mc = ModelConfig {
            sample1: 2 + rng.gen_range(8),
            sample2: 1 + rng.gen_range(6),
            f_in: 4 + rng.gen_range(12),
            f_hid: 4 + rng.gen_range(10),
            f_out: 2 + rng.gen_range(8),
        };
        let s = Sampler::new(rng.next_u64());
        let mut targets: Vec<u32> =
            (0..1 + rng.gen_range(3)).map(|_| rng.gen_range(2_000) as u32).collect();
        targets.sort_unstable();
        targets.dedup();
        let nf = Nodeflow::build(&g, &s, &targets, &mc);
        let h: Vec<f32> = (0..nf.layers[0].num_inputs() * mc.f_in)
            .map(|_| (rng.gen_f64() - 0.5) as f32)
            .collect();
        for model in ALL_MODELS {
            let plan = compile(model, &mc);
            let mut args = exec_test_args(&plan, rng.next_u64());
            args.insert("eps1".into(), (vec![], vec![0.15]));
            args.insert("eps2".into(), (vec![], vec![0.25]));
            let fast = execute_model(&plan, &nf, &h, &args).unwrap();
            let slow = execute_model_ref(&plan, &nf, &h, &args).unwrap();
            assert_eq!(fast, slow, "case {case} model {model:?}");
            assert_eq!(fast.len(), targets.len() * mc.f_out, "case {case} {model:?}");
        }
    });
}

// ----------------------------------------------------------- fixed point
#[test]
fn prop_fx16_roundtrip_error_bounded() {
    for_cases(2_000, |case, rng| {
        let x = (rng.gen_f64() * 16.0 - 8.0) as f32;
        let q = Fx16::from_f32(x).to_f32();
        if (-8.0..7.999).contains(&x) {
            assert!((q - x).abs() <= 1.0 / 4096.0 + 1e-6, "case {case}: {x} -> {q}");
        }
    });
}

#[test]
fn prop_fx16_add_commutative_and_monotone() {
    for_cases(2_000, |case, rng| {
        let a = Fx16::from_raw((rng.next_u64() & 0xFFFF) as u16 as i16);
        let b = Fx16::from_raw((rng.next_u64() & 0xFFFF) as u16 as i16);
        assert_eq!(a.sat_add(b), b.sat_add(a), "case {case}");
        // saturating add never wraps sign against the operand direction
        if b.0 >= 0 {
            assert!(a.sat_add(b).0 >= a.0.saturating_add(0).min(a.0), "case {case}");
        }
    });
}

#[test]
fn prop_fx16_mul_sign_and_bounds() {
    for_cases(2_000, |case, rng| {
        let a = Fx16::from_f32((rng.gen_f64() * 4.0 - 2.0) as f32);
        let b = Fx16::from_f32((rng.gen_f64() * 4.0 - 2.0) as f32);
        let p = a.sat_mul(b);
        let want = a.to_f32() * b.to_f32();
        assert!((p.to_f32() - want).abs() < 0.002, "case {case}: {want} vs {}", p.to_f32());
    });
}

#[test]
fn prop_lut_sigmoid_bounded_and_monotone() {
    let lut = TwoLevelLut::new(LutConfig::sigmoid());
    for_cases(500, |case, rng| {
        let x = (rng.gen_f64() * 16.0 - 8.0) as f32;
        let y = lut.eval_f32(x);
        assert!((-0.01..=1.01).contains(&y), "case {case}: sigmoid({x}) = {y}");
        // monotone within quantization slack
        let y2 = lut.eval_f32(x + 0.5);
        assert!(y2 >= y - 0.02, "case {case}: non-monotone at {x}");
    });
}

// -------------------------------------------------------------- simulator
#[test]
fn prop_sim_latency_positive_and_monotone_in_work() {
    let g = generate(&GeneratorParams { nodes: 3_000, mean_degree: 10.0, ..Default::default() });
    let cfg = GripConfig::paper();
    for_cases(40, |case, rng| {
        let s1 = 2 + rng.gen_range(20);
        let mc_small = ModelConfig { sample1: s1, sample2: 4, ..ModelConfig::paper() };
        let mc_big = ModelConfig { sample1: s1 + 8, sample2: 4, ..ModelConfig::paper() };
        let s = Sampler::new(rng.next_u64());
        let t = rng.gen_range(3_000) as u32;
        let nf_s = Nodeflow::build(&g, &s, &[t], &mc_small);
        let nf_b = Nodeflow::build(&g, &s, &[t], &mc_big);
        let r_s = simulate(&cfg, &compile(GnnModel::Gcn, &mc_small), &nf_s);
        let r_b = simulate(&cfg, &compile(GnnModel::Gcn, &mc_big), &nf_b);
        assert!(r_s.cycles > 0.0, "case {case}");
        // more samples => at least as much work (within 2% model noise)
        assert!(r_b.cycles >= r_s.cycles * 0.98, "case {case}: {} vs {}", r_s.cycles, r_b.cycles);
    });
}

#[test]
fn prop_sim_counters_scale_with_edges() {
    let g = generate(&GeneratorParams { nodes: 3_000, mean_degree: 10.0, ..Default::default() });
    let cfg = GripConfig::paper();
    let mc = ModelConfig::paper();
    let plan = compile(GnnModel::Gcn, &mc);
    for_cases(40, |case, rng| {
        let s = Sampler::new(rng.next_u64());
        let t = rng.gen_range(3_000) as u32;
        let nf = Nodeflow::build(&g, &s, &[t], &mc);
        let r = simulate(&cfg, &plan, &nf);
        // edge ALU ops = edges x dims exactly (GCN single edge program)
        let want: u64 = nf
            .layers
            .iter()
            .zip([mc.f_in, mc.f_hid])
            .map(|(l, d)| (l.edges.len() * d) as u64)
            .sum();
        assert_eq!(r.counters.edge_alu_ops, want, "case {case}");
    });
}

#[test]
fn prop_disabled_optimizations_never_help() {
    // Turning an optimization OFF must never make the simulator faster.
    let g = generate(&GeneratorParams { nodes: 3_000, mean_degree: 10.0, ..Default::default() });
    let mc = ModelConfig::paper();
    let plan = compile(GnnModel::Gcn, &mc);
    for_cases(25, |case, rng| {
        let s = Sampler::new(rng.next_u64());
        let t = rng.gen_range(3_000) as u32;
        let nf = Nodeflow::build(&g, &s, &[t], &mc);
        let on = GripConfig::paper();
        let base = simulate(&on, &plan, &nf).cycles;
        for knob in 0..4 {
            let mut off = on.clone();
            match knob {
                0 => off.pipeline_partitions = false,
                1 => off.preload_weights = false,
                2 => off.pipeline_update = false,
                _ => off.cache_features = false,
            }
            let t_off = simulate(&off, &plan, &nf).cycles;
            assert!(
                t_off >= base * 0.999,
                "case {case} knob {knob}: off {t_off} < on {base}"
            );
        }
    });
}
