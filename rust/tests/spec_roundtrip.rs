//! ModelSpec redesign acceptance tests.
//!
//! The four paper presets used to be hardcoded `match` arms building
//! `Program` literals (the pre-redesign `compile_layer`). That exact
//! construction is preserved *here*, as `legacy_compile`, and every
//! preset's spec-compiled plan must execute bit-identically to it on a
//! fixed seed graph — the redesign is a pure refactor of where program
//! structure lives, never of what it computes.
//!
//! Also here: the JSON example file under `examples/` must parse,
//! compile, and execute (so the documented schema cannot drift from the
//! parser — the CI smoke step runs the same file through the `grip`
//! CLI), and spec validation must reject malformed models.

use grip::config::ModelConfig;
use grip::greta::{
    compile, exec_test_args, execute_model, Activate, Domain, ExecArgs, ExecError, GatherOp,
    GnnModel, LayerPlan, LayerSpec, MatMul, ModelPlan, ModelSpec, Program, ProgramSpec, ReduceOp,
    SelfScale, Src, ALL_MODELS,
};
use grip::graph::{generate, GeneratorParams};
use grip::nodeflow::{Nodeflow, Sampler};
use grip::rng::GoldenLcg;

// ---------------------------------------------------------------------------
// The pre-redesign hardcoded compiler, verbatim (names owned instead of
// &'static str — the only mechanical difference).
// ---------------------------------------------------------------------------

fn legacy_compile(model: GnnModel, mc: &ModelConfig) -> ModelPlan {
    let dims = mc.layers();
    let layers = dims
        .iter()
        .enumerate()
        .map(|(i, &(_, in_dim, out_dim))| legacy_layer(model, i, in_dim, mc.f_hid, out_dim))
        .collect();
    ModelPlan { name: model.name().to_string(), layers }
}

fn legacy_layer(
    model: GnnModel,
    layer: usize,
    in_dim: usize,
    mid: usize,
    out_dim: usize,
) -> LayerPlan {
    macro_rules! w {
        ($a:expr, $b:expr) => {
            if layer == 0 {
                $a.to_string()
            } else {
                $b.to_string()
            }
        };
    }
    let programs = match model {
        GnnModel::Gcn => vec![Program {
            name: "gcn".into(),
            domain: Domain::Edges,
            source: Src::LayerInput,
            gather: GatherOp::Identity,
            reduce: ReduceOp::Mean,
            self_scale: None,
            transform: Some(MatMul { weight: w!("w1", "w2"), in_dim, out_dim }),
            add_program: None,
            activate: Activate::Relu,
        }],
        GnnModel::Sage => vec![
            Program {
                name: "sage-pool".into(),
                domain: Domain::AllInputs,
                source: Src::LayerInput,
                gather: GatherOp::Identity,
                reduce: ReduceOp::Sum,
                self_scale: None,
                transform: Some(MatMul { weight: w!("wp1", "wp2"), in_dim, out_dim: mid }),
                add_program: None,
                activate: Activate::Relu,
            },
            Program {
                name: "sage-agg".into(),
                domain: Domain::Edges,
                source: Src::Program(0),
                gather: GatherOp::Identity,
                reduce: ReduceOp::Max,
                self_scale: None,
                transform: Some(MatMul { weight: w!("wn1", "wn2"), in_dim: mid, out_dim }),
                add_program: None,
                activate: Activate::None,
            },
            Program {
                name: "sage-update".into(),
                domain: Domain::Outputs,
                source: Src::LayerInput,
                gather: GatherOp::Identity,
                reduce: ReduceOp::Sum,
                self_scale: None,
                transform: Some(MatMul { weight: w!("ws1", "ws2"), in_dim, out_dim }),
                add_program: Some(1),
                activate: Activate::Relu,
            },
        ],
        GnnModel::Gin => vec![
            Program {
                name: "gin-agg".into(),
                domain: Domain::Edges,
                source: Src::LayerInput,
                gather: GatherOp::Identity,
                reduce: ReduceOp::Sum,
                self_scale: Some(SelfScale::OnePlusArg(w!("eps1", "eps2"))),
                transform: Some(MatMul { weight: w!("w1a", "w2a"), in_dim, out_dim: mid }),
                add_program: None,
                activate: Activate::Relu,
            },
            Program {
                name: "gin-mlp2".into(),
                domain: Domain::Outputs,
                source: Src::Program(0),
                gather: GatherOp::Identity,
                reduce: ReduceOp::Sum,
                self_scale: None,
                transform: Some(MatMul { weight: w!("w1b", "w2b"), in_dim: mid, out_dim }),
                add_program: None,
                activate: Activate::Relu,
            },
        ],
        GnnModel::Ggcn => vec![
            Program {
                name: "ggcn-gate".into(),
                domain: Domain::AllInputs,
                source: Src::LayerInput,
                gather: GatherOp::Identity,
                reduce: ReduceOp::Sum,
                self_scale: None,
                transform: Some(MatMul { weight: w!("wg1", "wg2"), in_dim, out_dim: 1 }),
                add_program: None,
                activate: Activate::Sigmoid,
            },
            Program {
                name: "ggcn-msg".into(),
                domain: Domain::AllInputs,
                source: Src::LayerInput,
                gather: GatherOp::Identity,
                reduce: ReduceOp::Sum,
                self_scale: None,
                transform: Some(MatMul { weight: w!("wm1", "wm2"), in_dim, out_dim }),
                add_program: None,
                activate: Activate::None,
            },
            Program {
                name: "ggcn-reduce".into(),
                domain: Domain::Edges,
                source: Src::Program(1),
                gather: GatherOp::ProductWith(0),
                reduce: ReduceOp::Sum,
                self_scale: None,
                transform: None,
                add_program: None,
                activate: Activate::None,
            },
            Program {
                name: "ggcn-update".into(),
                domain: Domain::Outputs,
                source: Src::LayerInput,
                gather: GatherOp::Identity,
                reduce: ReduceOp::Sum,
                self_scale: None,
                transform: Some(MatMul { weight: w!("ws1", "ws2"), in_dim, out_dim }),
                add_program: Some(2),
                activate: Activate::Relu,
            },
        ],
    };
    let output_program = programs.len() - 1;
    LayerPlan { programs, output_program, in_dim, out_dim }
}

// ---------------------------------------------------------------------------
// Harness
// ---------------------------------------------------------------------------

fn small_mc() -> ModelConfig {
    ModelConfig { sample1: 4, sample2: 3, f_in: 12, f_hid: 10, f_out: 6 }
}

fn setup(mc: &ModelConfig, targets: &[u32]) -> (Nodeflow, Vec<f32>) {
    let g = generate(&GeneratorParams { nodes: 900, mean_degree: 7.0, ..Default::default() });
    let nf = Nodeflow::build(&g, &Sampler::new(3), targets, mc);
    let mut lcg = GoldenLcg::new(7);
    let h: Vec<f32> =
        lcg.fill(nf.layers[0].num_inputs() * mc.f_in).iter().map(|x| x * 0.5).collect();
    (nf, h)
}

fn args_for(plan: &ModelPlan, seed: u64) -> ExecArgs {
    let mut args = exec_test_args(plan, seed);
    args.insert("eps1".into(), (vec![], vec![0.1]));
    args.insert("eps2".into(), (vec![], vec![0.2]));
    args
}

// ---------------------------------------------------------------------------
// Round trip: preset specs == legacy hardcoded plans
// ---------------------------------------------------------------------------

#[test]
fn preset_specs_bit_identical_to_legacy_hardcoded_plans() {
    let mc = small_mc();
    let (nf, h) = setup(&mc, &[17, 230]);
    for model in ALL_MODELS {
        let legacy = legacy_compile(model, &mc);
        let spec_plan = model.spec(&mc).compile().expect("preset spec compiles");
        // Same weight contract in the same order → one argument set
        // feeds both plans identically.
        assert_eq!(spec_plan.weight_names(), legacy.weight_names(), "{model:?}");
        assert_eq!(spec_plan.num_programs(), legacy.num_programs(), "{model:?}");
        let args = args_for(&legacy, 99);
        let a = execute_model(&legacy, &nf, &h, &args).unwrap();
        let b = execute_model(&spec_plan, &nf, &h, &args).unwrap();
        assert_eq!(a, b, "{model:?}: spec-compiled plan diverged from the legacy plan");
        // And `compile()` is exactly the spec path.
        let c = execute_model(&compile(model, &mc), &nf, &h, &args).unwrap();
        assert_eq!(a, c, "{model:?}");
    }
}

#[test]
fn preset_specs_match_legacy_structure_at_paper_dims() {
    // Executing 602-dim plans is too slow for a unit test; pin the
    // structural contract instead (dims, weight bytes, names).
    let mc = ModelConfig::paper();
    for model in ALL_MODELS {
        let legacy = legacy_compile(model, &mc);
        let spec_plan = compile(model, &mc);
        assert_eq!(spec_plan.weight_names(), legacy.weight_names(), "{model:?}");
        assert_eq!(spec_plan.weight_bytes(2), legacy.weight_bytes(2), "{model:?}");
        assert_eq!(spec_plan.layers.len(), legacy.layers.len());
        for (sl, ll) in spec_plan.layers.iter().zip(legacy.layers.iter()) {
            assert_eq!(sl.in_dim, ll.in_dim);
            assert_eq!(sl.out_dim, ll.out_dim);
            assert_eq!(sl.output_program, ll.output_program);
            assert_eq!(sl.programs.len(), ll.programs.len());
        }
    }
}

// ---------------------------------------------------------------------------
// JSON: the documented example file executes end-to-end
// ---------------------------------------------------------------------------

fn example_spec() -> ModelSpec {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/model_spec.json");
    let text = std::fs::read_to_string(path).expect("examples/model_spec.json in repo");
    ModelSpec::from_json_str(&text).expect("example spec parses")
}

#[test]
fn example_json_spec_compiles_and_executes_three_layers() {
    let spec = example_spec();
    assert_eq!(spec.depth(), 3, "the example documents a depth-3 model");
    let plan = spec.compile().expect("example spec validates");

    // Execute on a nodeflow built with the spec's own sampling.
    let g = generate(&GeneratorParams { nodes: 900, mean_degree: 7.0, ..Default::default() });
    let samples: Vec<usize> =
        spec.layers.iter().map(|l| l.sample.expect("example sets sampling")).collect();
    let nf = Nodeflow::build_layers(&g, &Sampler::new(3), &[42, 77], &samples);
    assert_eq!(nf.layers.len(), 3);

    let in_dim = plan.layers[0].in_dim;
    let mut lcg = GoldenLcg::new(5);
    let h: Vec<f32> =
        lcg.fill(nf.layers[0].num_inputs() * in_dim).iter().map(|x| x * 0.5).collect();
    let args = args_for(&plan, 31);
    let out = execute_model(&plan, &nf, &h, &args).unwrap();
    assert_eq!(out.len(), 2 * plan.layers.last().unwrap().out_dim);
    assert!(out.iter().all(|x| x.is_finite()));
    // Deterministic.
    assert_eq!(out, execute_model(&plan, &nf, &h, &args).unwrap());
}

// ---------------------------------------------------------------------------
// Negative: validation and argument resolution reject bad specs
// ---------------------------------------------------------------------------

#[test]
fn spec_validation_rejects_dim_mismatch() {
    let spec = ModelSpec::builder("bad-dims")
        .layer(
            LayerSpec::new(8, 4)
                .program(ProgramSpec::new("p").transform("w", 6, 4)), // in_dim 6 != source 8
        )
        .build();
    let err = spec.compile().unwrap_err();
    assert!(err.to_string().contains("transform in_dim"), "{err}");
}

#[test]
fn spec_validation_rejects_dangling_program_ref() {
    let spec = ModelSpec::builder("bad-ref")
        .layer(
            LayerSpec::new(4, 4)
                .program(ProgramSpec::new("a").transform("w0", 4, 4))
                .program(ProgramSpec::new("b").source_program(5).transform("w1", 4, 4)),
        )
        .build();
    let err = spec.compile().unwrap_err();
    assert!(err.to_string().contains("dangling"), "{err}");
}

#[test]
fn unknown_weight_name_surfaces_as_missing_arg() {
    // Validation can't know what weights the runtime will supply; a
    // spec naming a weight absent from the argument set must fail
    // resolution with the name attached, not panic mid-execution.
    let spec = ModelSpec::builder("missing-w")
        .layer(LayerSpec::new(12, 6).program(
            ProgramSpec::new("p").reduce(ReduceOp::Mean).transform("nobody_supplies_this", 12, 6),
        ))
        .build();
    let plan = spec.compile().unwrap();
    let nf = Nodeflow::build_layers(
        &generate(&GeneratorParams { nodes: 900, mean_degree: 7.0, ..Default::default() }),
        &Sampler::new(3),
        &[17],
        &[4],
    );
    let h: Vec<f32> = vec![0.1; nf.layers[0].num_inputs() * 12];
    let err = execute_model(&plan, &nf, &h, &ExecArgs::new()).unwrap_err();
    match err {
        ExecError::MissingArg(name) => assert_eq!(name, "nobody_supplies_this"),
        other => panic!("expected MissingArg, got {other:?}"),
    }
}
