//! Property tests for the PR-8 adaptive SLO control plane (hand-rolled
//! seeded cases, same style as `serve_props.rs`).
//!
//! THE control invariant: the controller may reshape *scheduling* —
//! batcher window, prefetch lanes, pipeline depth, active shards — but
//! never numerics. Replies under `--control static` and `--control
//! adaptive` must be bit-identical (embeddings AND simulated timing) to
//! `--control off` across every preset plus a depth-3 custom spec, at
//! {1, 4} shards, with the phase pipeline on and off, and with graph
//! partitioning off and on. The policy's per-rule trigger thresholds
//! are pinned separately by the unit tests in `src/control/policy.rs`;
//! this file pins the end-to-end property those rules must preserve.
//!
//! The unbatched matrix demands full bit-identity (embedding, simulated
//! accelerator timing, neighborhood). The batched case compares
//! embeddings per request id only: a coalesced batch's `accel_us` is
//! the shared multi-target nodeflow's, so it depends on real-time batch
//! composition — which varies run to run even with control off —
//! while embeddings are batch-invariant (pinned by the coordinator's
//! `batched_reply_matches_unbatched_bit_for_bit`).

use grip::backend::BackendChoice;
use grip::config::ModelConfig;
use grip::coordinator::{
    BatchConfig, ControlConfig, ControlMode, Coordinator, InferenceRequest, InferenceResponse,
    PipelineConfig, ServeConfig,
};
use grip::graph::{generate, CsrGraph, GeneratorParams, PartitionStrategy};
use grip::greta::{Activate, LayerSpec, ModelKey, ModelLibrary, ModelSpec, ProgramSpec, ReduceOp};
use grip::rng::SplitMix64;

fn serving_graph(seed: u64) -> CsrGraph {
    generate(&GeneratorParams { nodes: 1_500, mean_degree: 7.0, seed, ..Default::default() })
}

fn small_mc() -> ModelConfig {
    ModelConfig { sample1: 4, sample2: 3, f_in: 12, f_hid: 10, f_out: 6 }
}

/// A depth-3 mean-aggregate spec (8 → 6 → 5 → 3), as in
/// `serve_props.rs` — deeper-than-preset coverage for the controller.
fn depth3_spec() -> ModelSpec {
    ModelSpec::builder("tri3")
        .layer(LayerSpec::new(8, 6).sample(3).program(
            ProgramSpec::new("t0")
                .reduce(ReduceOp::Mean)
                .transform("t_w0", 8, 6)
                .activate(Activate::Relu),
        ))
        .layer(LayerSpec::new(6, 5).sample(2).program(
            ProgramSpec::new("t1")
                .reduce(ReduceOp::Mean)
                .transform("t_w1", 6, 5)
                .activate(Activate::Relu),
        ))
        .layer(LayerSpec::new(5, 3).sample(2).program(
            ProgramSpec::new("t2")
                .reduce(ReduceOp::Mean)
                .transform("t_w2", 5, 3)
                .activate(Activate::Relu),
        ))
        .build()
}

fn mixed_reqs(lib_seed: u64, n: usize) -> (Vec<ModelKey>, Vec<(ModelKey, u32)>) {
    let (lib, _) = ModelLibrary::with_customs(&small_mc(), &[depth3_spec()]).unwrap();
    let keys: Vec<ModelKey> = lib.keys().collect();
    assert_eq!(keys.len(), 5, "4 presets + tri3");
    let mut rng = SplitMix64::new(lib_seed);
    let reqs = (0..n).map(|i| (keys[i % keys.len()], rng.gen_range(1_500) as u32)).collect();
    (keys, reqs)
}

/// Serve `reqs` (mixed presets + the depth-3 spec) with the given
/// control mode over one scheduling shape. A 1 ms tick gives the
/// adaptive policy real opportunities to move knobs while the requests
/// are in flight; returns the replies in request order plus the run's
/// control summary.
fn serve_controlled(
    graph: &CsrGraph,
    mode: ControlMode,
    shards: usize,
    pipeline: PipelineConfig,
    partition: PartitionStrategy,
    batch: Option<BatchConfig>,
    reqs: &[(ModelKey, u32)],
) -> (Vec<InferenceResponse>, grip::control::ControlStats) {
    let cfg = ServeConfig {
        backend: BackendChoice::Fixed,
        shards,
        builders: 3,
        model_cfg: small_mc(),
        pipeline,
        partition,
        cache_rows: 300,
        batch,
        control: ControlConfig { mode, interval_ms: 1 },
        custom_specs: vec![depth3_spec()],
        ..Default::default()
    };
    let coord = Coordinator::start(graph.clone(), 11, cfg).unwrap();
    let pending: Vec<_> = reqs
        .iter()
        .enumerate()
        .map(|(i, &(m, t))| coord.submit(InferenceRequest::single(i as u64, m, t)).unwrap())
        .collect();
    let responses = pending.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
    let control = coord.serve_stats().control;
    (responses, control)
}

#[test]
fn prop_control_modes_bit_identical_across_scheduling_shapes() {
    let graph = serving_graph(29);
    let (_, reqs) = mixed_reqs(83, 25);

    for (pipeline, pname) in
        [(PipelineConfig::default(), "pipeline-on"), (PipelineConfig::off(), "pipeline-off")]
    {
        for partition in [PartitionStrategy::Off, PartitionStrategy::Degree] {
            for shards in [1usize, 4] {
                let (off, off_stats) = serve_controlled(
                    &graph,
                    ControlMode::Off,
                    shards,
                    pipeline,
                    partition,
                    None,
                    &reqs,
                );
                assert!(off.iter().all(|r| !r.timing_only));
                assert_eq!(off_stats.mode, "off");
                assert_eq!(off_stats.ticks, 0, "off spawns no controller");

                for mode in [ControlMode::Static, ControlMode::Adaptive] {
                    let (got, stats) = serve_controlled(
                        &graph, mode, shards, pipeline, partition, None, &reqs,
                    );
                    let shape = format!("{mode:?}/{pname}/{partition:?}/s{shards}");
                    assert_eq!(got.len(), off.len(), "{shape}");
                    for (a, b) in off.iter().zip(got.iter()) {
                        assert_eq!(a.id, b.id, "{shape}");
                        assert_eq!(
                            a.embedding, b.embedding,
                            "id {}: {shape} changed numerics",
                            a.id
                        );
                        assert_eq!(
                            a.accel_us, b.accel_us,
                            "id {}: {shape} changed simulated timing",
                            a.id
                        );
                        assert_eq!(a.neighborhood, b.neighborhood, "{shape}");
                    }
                    assert_eq!(stats.mode, mode.label(), "{shape}");
                    if mode == ControlMode::Static {
                        assert_eq!(stats.actions, 0, "{shape}: static holds every knob");
                    }
                    // Knob readouts always land in the final shape —
                    // even when no action fired, the controller reports
                    // where the knobs ended up.
                    assert!(stats.final_lanes >= 1 && stats.final_depth >= 1, "{shape}");
                    assert!(stats.final_active_shards >= 1, "{shape}");
                    assert_eq!(stats.log.len() as u64, stats.actions.min(256), "{shape}");
                }
            }
        }
    }
}

#[test]
fn prop_control_modes_preserve_embeddings_under_batching() {
    // With the SLO batcher in the loop the window knob is live too
    // (adaptive runs widen/narrow it against measured deadline margin);
    // embeddings per request id must still match control-off exactly.
    let graph = serving_graph(31);
    let (_, reqs) = mixed_reqs(59, 30);
    let batch = Some(BatchConfig { slo_us: 10_000.0, margin_us: 2_000.0, max_batch: 4 });

    let (off, _) = serve_controlled(
        &graph,
        ControlMode::Off,
        2,
        PipelineConfig::default(),
        PartitionStrategy::Off,
        batch,
        &reqs,
    );
    assert!(off.iter().all(|r| !r.timing_only));
    for mode in [ControlMode::Static, ControlMode::Adaptive] {
        let (got, stats) = serve_controlled(
            &graph,
            mode,
            2,
            PipelineConfig::default(),
            PartitionStrategy::Off,
            batch,
            &reqs,
        );
        assert_eq!(got.len(), off.len());
        for (a, b) in off.iter().zip(got.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.embedding, b.embedding,
                "id {}: {mode:?} batching changed numerics",
                a.id
            );
        }
        assert_eq!(stats.mode, mode.label());
        assert!(stats.ticks > 0, "{mode:?}: controller ticked while serving");
    }
}
