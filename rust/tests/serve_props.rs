//! Property tests for the PR-2 serving subsystem (hand-rolled seeded
//! cases, same style as `proptests.rs`; the offline crate set has no
//! `proptest`).
//!
//! * The SLO-aware batcher never dispatches a request after its
//!   deadline budget in virtual time, never mixes models, never
//!   overfills a batch, and loses nothing.
//! * The sharded executor pool is bit-identical to the single-executor
//!   path — and to a from-scratch single-threaded execution — for the
//!   same request set.
//! * The PR-5 phase-decoupled shard pipeline is bit-identical to the
//!   sequential `--pipeline off` loop (and to the same from-scratch
//!   reference) for every (lanes, depth), every preset, and a depth-3
//!   custom spec: scheduling may never change numerics.
//! * The PR-6 partitioned pool — home-shard routing, partition-local
//!   caches, cross-shard boundary fetches — is bit-identical to
//!   `--partition off` and to the from-scratch reference for
//!   {degree, hash} × {1, 4} shards over every preset plus the depth-3
//!   spec: locality may never change numerics either.

use grip::backend::BackendChoice;
use grip::config::ModelConfig;
use grip::coordinator::{
    Coordinator, InferenceRequest, InferenceResponse, PipelineConfig, ServeConfig,
};
use grip::graph::{generate, CsrGraph, GeneratorParams, PartitionStrategy};
use grip::greta::{
    compile, execute_model_into, Activate, ExecScratch, GnnModel, LayerSpec, ModelKey,
    ModelLibrary, ModelSpec, PlanArgs, ProgramSpec, ReduceOp,
};
use grip::nodeflow::{Nodeflow, Sampler};
use grip::rng::SplitMix64;
use grip::runtime::fill_feature_row;
use grip::serve::{
    fixed_serving_args, generate_arrivals, ArrivalProcess, BatchConfig, Batcher, ModelMix,
    TargetDist,
};

/// Run `f` over `n` seeded cases.
fn for_cases(n: u64, mut f: impl FnMut(u64, &mut SplitMix64)) {
    for case in 0..n {
        let mut rng = SplitMix64::new(0xBA7C4E5 ^ case.wrapping_mul(0x9E3779B97F4A7C15));
        f(case, &mut rng);
    }
}

fn min_opt(a: Option<f64>, b: Option<f64>) -> Option<f64> {
    match (a, b) {
        (Some(x), Some(y)) => Some(x.min(y)),
        (x, None) => x,
        (None, y) => y,
    }
}

// ------------------------------------------------ batcher deadline SLO
#[test]
fn prop_batcher_never_exceeds_deadline_budget() {
    for_cases(60, |case, rng| {
        let slo_us = 500.0 + rng.gen_f64() * 20_000.0;
        let margin_us = rng.gen_f64() * slo_us;
        let max_batch = 1 + rng.gen_range(15);
        let cfg = BatchConfig { slo_us, margin_us, max_batch };
        let budget_us = (slo_us - margin_us).max(0.0);

        let process = if rng.gen_f64() < 0.5 {
            ArrivalProcess::Poisson { rate_rps: 100.0 + rng.gen_f64() * 5_000.0 }
        } else {
            ArrivalProcess::Bursty {
                base_rps: 100.0 + rng.gen_f64() * 500.0,
                burst_rps: 1_000.0 + rng.gen_f64() * 5_000.0,
                base_dwell_ms: 5.0 + rng.gen_f64() * 50.0,
                burst_dwell_ms: 1.0 + rng.gen_f64() * 20.0,
            }
        };
        let n = 120;
        let arrivals =
            generate_arrivals(process, &ModelMix::default(), TargetDist::Uniform, n, 1_000, case);

        // Event-driven virtual-time driver: advance to the next arrival
        // or batcher deadline, offering/dispatching at exact times — the
        // discipline the real-time batcher thread approximates with
        // recv_timeout.
        let mut batcher: Batcher<usize> = Batcher::new(cfg);
        let mut dispatched = vec![false; n];
        let mut i = 0usize;
        let mut t = 0.0f64;
        loop {
            while let Some((model, batch)) = batcher.pop_due(t) {
                assert!(!batch.is_empty(), "case {case}: empty batch");
                assert!(batch.len() <= max_batch, "case {case}: oversized batch");
                // A partial batch must be due: its head's deadline expired.
                if batch.len() < max_batch {
                    assert!(
                        batch[0].dispatch_by_us <= t + 1e-6,
                        "case {case}: early partial dispatch at {t} (deadline {})",
                        batch[0].dispatch_by_us
                    );
                }
                for p in &batch {
                    let idx = p.item;
                    // THE property: dispatch never exceeds the deadline
                    // budget (arrival + slo - margin) in virtual time.
                    assert!(
                        t <= p.dispatch_by_us + 1e-6,
                        "case {case}: req {idx} dispatched at {t}, deadline {}",
                        p.dispatch_by_us
                    );
                    assert!(
                        p.dispatch_by_us - p.arrival_us <= budget_us + 1e-6,
                        "case {case}: deadline beyond the budget"
                    );
                    assert_eq!(arrivals[idx].model, model, "case {case}: mixed-model batch");
                    assert!(!dispatched[idx], "case {case}: req {idx} dispatched twice");
                    dispatched[idx] = true;
                }
            }
            let next_arrival = arrivals.get(i).map(|a| a.t_us);
            let Some(t_next) = min_opt(next_arrival, batcher.next_deadline()) else {
                break;
            };
            t = t.max(t_next);
            while i < arrivals.len() && arrivals[i].t_us <= t {
                batcher.offer(arrivals[i].model, i, arrivals[i].t_us);
                i += 1;
            }
        }
        assert!(batcher.is_empty(), "case {case}: requests stuck in the batcher");
        assert!(
            dispatched.iter().all(|&d| d),
            "case {case}: not every request dispatched"
        );
    });
}

// ------------------------------------- shard pool numeric bit-identity
fn serving_graph(seed: u64) -> CsrGraph {
    generate(&GeneratorParams { nodes: 1_500, mean_degree: 7.0, seed, ..Default::default() })
}

fn small_mc() -> ModelConfig {
    ModelConfig { sample1: 4, sample2: 3, f_in: 12, f_hid: 10, f_out: 6 }
}

fn fixed_cfg(shards: usize) -> ServeConfig {
    ServeConfig {
        backend: BackendChoice::Fixed,
        shards,
        builders: 3,
        model_cfg: small_mc(),
        ..Default::default()
    }
}

/// Serve `reqs` through a coordinator with the given shard count and
/// return responses in request order.
fn serve_all(
    graph: &CsrGraph,
    shards: usize,
    reqs: &[(GnnModel, u32)],
) -> Vec<InferenceResponse> {
    let coord = Coordinator::start(graph.clone(), 11, fixed_cfg(shards)).unwrap();
    let pending: Vec<_> = reqs
        .iter()
        .enumerate()
        .map(|(i, &(m, t))| coord.submit(InferenceRequest::single(i as u64, m, t)).unwrap())
        .collect();
    pending.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect()
}

#[test]
fn prop_shard_pool_bit_identical_to_single_executor() {
    let graph = serving_graph(5);
    let mut rng = SplitMix64::new(77);
    let models = [GnnModel::Gcn, GnnModel::Sage, GnnModel::Gin, GnnModel::Ggcn];
    let reqs: Vec<(GnnModel, u32)> = (0..48)
        .map(|_| (models[rng.gen_range(4)], rng.gen_range(1_500) as u32))
        .collect();

    let single = serve_all(&graph, 1, &reqs);
    let pooled = serve_all(&graph, 4, &reqs);
    assert_eq!(single.len(), pooled.len());
    for (a, b) in single.iter().zip(pooled.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.embedding, b.embedding, "id {}: shard count changed numerics", a.id);
        assert_eq!(a.accel_us, b.accel_us, "id {}: shard count changed timing", a.id);
        assert_eq!(a.neighborhood, b.neighborhood);
        assert!(!a.timing_only && !b.timing_only);
    }
}

// --------------------------- phase-pipeline numeric bit-identity (PR 5)

/// A depth-3 mean-aggregate spec with dims unrelated to `ModelConfig`
/// (8 → 6 → 5 → 3) — deeper-than-preset coverage for the pipeline.
fn depth3_spec() -> ModelSpec {
    ModelSpec::builder("tri3")
        .layer(LayerSpec::new(8, 6).sample(3).program(
            ProgramSpec::new("t0")
                .reduce(ReduceOp::Mean)
                .transform("t_w0", 8, 6)
                .activate(Activate::Relu),
        ))
        .layer(LayerSpec::new(6, 5).sample(2).program(
            ProgramSpec::new("t1")
                .reduce(ReduceOp::Mean)
                .transform("t_w1", 6, 5)
                .activate(Activate::Relu),
        ))
        .layer(LayerSpec::new(5, 3).sample(2).program(
            ProgramSpec::new("t2")
                .reduce(ReduceOp::Mean)
                .transform("t_w2", 5, 3)
                .activate(Activate::Relu),
        ))
        .build()
}

/// Serve `reqs` (mixed presets + the depth-3 spec) through a 3-shard
/// fixed-point coordinator with the given pipeline policy.
fn serve_all_pipelined(
    graph: &CsrGraph,
    pipeline: PipelineConfig,
    reqs: &[(ModelKey, u32)],
) -> Vec<InferenceResponse> {
    let cfg = ServeConfig {
        pipeline,
        custom_specs: vec![depth3_spec()],
        ..fixed_cfg(3)
    };
    let coord = Coordinator::start(graph.clone(), 11, cfg).unwrap();
    let pending: Vec<_> = reqs
        .iter()
        .enumerate()
        .map(|(i, &(m, t))| coord.submit(InferenceRequest::single(i as u64, m, t)).unwrap())
        .collect();
    pending.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect()
}

#[test]
fn prop_pipelined_pool_bit_identical_to_sequential_and_reference() {
    // THE PR-5 property: for a (lanes × depth) grid — including the
    // defaults and a depth-3 custom spec in the mix — pipelined replies
    // equal the sequential `--pipeline off` replies equal a
    // from-scratch single-threaded execution, bit for bit.
    let graph = serving_graph(13);
    let mc = small_mc();
    let weight_seed = ServeConfig::default().weight_seed;
    let (lib, _) = ModelLibrary::with_customs(&mc, &[depth3_spec()]).unwrap();
    let keys: Vec<ModelKey> = lib.keys().collect();
    assert_eq!(keys.len(), 5, "4 presets + tri3");
    let mut rng = SplitMix64::new(41);
    let reqs: Vec<(ModelKey, u32)> = (0..30)
        .map(|i| (keys[i % keys.len()], rng.gen_range(1_500) as u32))
        .collect();

    let sequential = serve_all_pipelined(&graph, PipelineConfig::off(), &reqs);
    assert!(sequential.iter().all(|r| !r.timing_only));

    // Every preset and the custom spec against the pipelined pool over
    // the full grid (the defaults 2x2 included).
    for (lanes, depth) in [(1, 1), (1, 3), (2, 2), (4, 1), (4, 3)] {
        let pipelined =
            serve_all_pipelined(&graph, PipelineConfig::lanes_depth(lanes, depth), &reqs);
        assert_eq!(pipelined.len(), sequential.len());
        for (a, b) in sequential.iter().zip(pipelined.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.embedding, b.embedding,
                "id {}: pipeline {lanes}x{depth} changed numerics",
                a.id
            );
            assert_eq!(a.accel_us, b.accel_us, "id {}: timing changed", a.id);
            assert_eq!(a.neighborhood, b.neighborhood);
        }
    }

    // From-scratch single-threaded reference: same sampler seed, same
    // serving weights, same synthesized features — no hidden state in
    // either pipeline mode.
    let sampler = Sampler::new(11);
    let mut scratch = ExecScratch::new();
    let mut out = Vec::new();
    for (i, &(key, t)) in reqs.iter().enumerate() {
        let plan = lib.plan(key);
        let pargs = PlanArgs::resolve(plan, &fixed_serving_args(plan, weight_seed)).unwrap();
        let nf = Nodeflow::build_layers(&graph, &sampler, &[t], lib.samples(key));
        let in_dim = plan.layers[0].in_dim;
        let l0 = &nf.layers[0];
        let mut h = vec![0f32; l0.num_inputs() * in_dim];
        for (r, &v) in l0.inputs.iter().enumerate() {
            fill_feature_row(v, &mut h[r * in_dim..(r + 1) * in_dim]);
        }
        execute_model_into(plan, &nf, &h, &pargs, &mut scratch, &mut out).unwrap();
        assert_eq!(
            sequential[i].embedding, out,
            "request {i} ({}@{t}) diverged from the reference",
            lib.name(key)
        );
    }
}

// ------------------------- partitioned-pool bit-identity (PR 6)

/// Serve mixed presets + the depth-3 spec through a partitioned pool.
fn serve_all_partitioned(
    graph: &CsrGraph,
    partition: PartitionStrategy,
    shards: usize,
    reqs: &[(ModelKey, u32)],
) -> Vec<InferenceResponse> {
    let cfg = ServeConfig {
        partition,
        cache_rows: 300,
        custom_specs: vec![depth3_spec()],
        ..fixed_cfg(shards)
    };
    let coord = Coordinator::start(graph.clone(), 11, cfg).unwrap();
    let pending: Vec<_> = reqs
        .iter()
        .enumerate()
        .map(|(i, &(m, t))| coord.submit(InferenceRequest::single(i as u64, m, t)).unwrap())
        .collect();
    pending.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect()
}

#[test]
fn prop_partitioned_pool_bit_identical_to_off_and_reference() {
    // THE PR-6 property: routing a job to its target's home shard,
    // serving layer-0 rows from a partition-local cache, and pulling
    // remote rows over the boundary-fetch path must be invisible in
    // every reply — embeddings AND simulated timing — for both
    // partitioning strategies, at 1 and 4 shards, across all four
    // presets and the depth-3 custom spec.
    let graph = serving_graph(21);
    let mc = small_mc();
    let weight_seed = ServeConfig::default().weight_seed;
    let (lib, _) = ModelLibrary::with_customs(&mc, &[depth3_spec()]).unwrap();
    let keys: Vec<ModelKey> = lib.keys().collect();
    assert_eq!(keys.len(), 5, "4 presets + tri3");
    let mut rng = SplitMix64::new(67);
    let reqs: Vec<(ModelKey, u32)> = (0..30)
        .map(|i| (keys[i % keys.len()], rng.gen_range(1_500) as u32))
        .collect();

    let off = serve_all_partitioned(&graph, PartitionStrategy::Off, 4, &reqs);
    assert!(off.iter().all(|r| !r.timing_only));

    for partition in [PartitionStrategy::Degree, PartitionStrategy::Hash] {
        for shards in [1usize, 4] {
            let got = serve_all_partitioned(&graph, partition, shards, &reqs);
            assert_eq!(got.len(), off.len());
            for (a, b) in off.iter().zip(got.iter()) {
                assert_eq!(a.id, b.id);
                assert_eq!(
                    a.embedding, b.embedding,
                    "id {}: {partition:?} x {shards} shards changed numerics",
                    a.id
                );
                assert_eq!(
                    a.accel_us, b.accel_us,
                    "id {}: {partition:?} x {shards} shards changed timing",
                    a.id
                );
                assert_eq!(a.neighborhood, b.neighborhood);
            }
        }
    }

    // From-scratch single-threaded reference: same sampler seed, same
    // serving weights, same synthesized features — the partitioned
    // cache/boundary path introduces no hidden numeric state.
    let sampler = Sampler::new(11);
    let mut scratch = ExecScratch::new();
    let mut out = Vec::new();
    for (i, &(key, t)) in reqs.iter().enumerate() {
        let plan = lib.plan(key);
        let pargs = PlanArgs::resolve(plan, &fixed_serving_args(plan, weight_seed)).unwrap();
        let nf = Nodeflow::build_layers(&graph, &sampler, &[t], lib.samples(key));
        let in_dim = plan.layers[0].in_dim;
        let l0 = &nf.layers[0];
        let mut h = vec![0f32; l0.num_inputs() * in_dim];
        for (r, &v) in l0.inputs.iter().enumerate() {
            fill_feature_row(v, &mut h[r * in_dim..(r + 1) * in_dim]);
        }
        execute_model_into(plan, &nf, &h, &pargs, &mut scratch, &mut out).unwrap();
        assert_eq!(
            off[i].embedding, out,
            "request {i} ({}@{t}) diverged from the reference",
            lib.name(key)
        );
    }
}

#[test]
fn prop_pool_matches_from_scratch_single_threaded_execution() {
    // The pool's replies must equal a from-scratch single-threaded
    // execution with the same sampler seed, serving weights, and
    // synthesized features — no hidden state in the pipeline.
    let graph = serving_graph(9);
    let mc = small_mc();
    let weight_seed = ServeConfig::default().weight_seed;
    let mut rng = SplitMix64::new(3);
    let reqs: Vec<(GnnModel, u32)> =
        (0..12).map(|_| (GnnModel::Gcn, rng.gen_range(1_500) as u32)).collect();
    let got = serve_all(&graph, 3, &reqs);

    let sampler = Sampler::new(11);
    let plan = compile(GnnModel::Gcn, &mc);
    let pargs = PlanArgs::resolve(&plan, &fixed_serving_args(&plan, weight_seed)).unwrap();
    let mut scratch = ExecScratch::new();
    let mut out = Vec::new();
    for (i, &(_, t)) in reqs.iter().enumerate() {
        let nf = Nodeflow::build(&graph, &sampler, &[t], &mc);
        let l0 = &nf.layers[0];
        let mut h = vec![0f32; l0.num_inputs() * mc.f_in];
        for (r, &v) in l0.inputs.iter().enumerate() {
            fill_feature_row(v, &mut h[r * mc.f_in..(r + 1) * mc.f_in]);
        }
        execute_model_into(&plan, &nf, &h, &pargs, &mut scratch, &mut out).unwrap();
        assert_eq!(got[i].embedding, out, "request {i} (target {t})");
    }
}
