//! End-to-end runtime tests: HLO artifacts on PJRT vs (a) the Python
//! golden vectors and (b) the Rust fixed-point functional executor —
//! the full numeric loop: Pallas kernel ≍ jnp ref ≍ HLO-on-PJRT ≍ Q4.12
//! datapath.
//!
//! These tests are skipped (pass vacuously) when `make artifacts` has
//! not been run, so `cargo test` works from a clean checkout.

use grip::config::ModelConfig;
use grip::graph::Dataset;
use grip::greta::{compile, execute_model, ExecArgs, GnnModel, ALL_MODELS};
use grip::nodeflow::{Nodeflow, Sampler};
use grip::runtime::{build_args, serving_weights, Executor, Manifest};

fn executor() -> Option<Executor> {
    Executor::load(&Manifest::default_dir()).ok()
}

#[test]
fn golden_vectors_verify_all_models() {
    let Some(exec) = executor() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    for name in exec.model_names() {
        let err = exec.verify_golden(name).unwrap();
        assert!(err < 1e-3, "{name}: golden max err {err}");
    }
}

#[test]
fn pjrt_output_shapes_match_manifest() {
    let Some(exec) = executor() else { return };
    for name in exec.model_names() {
        let artifact = exec.model(name).unwrap().artifact.clone();
        let args = grip::runtime::golden_args(&artifact);
        let out = exec.run(name, &args).unwrap();
        assert_eq!(out.len(), artifact.output_shape.iter().product::<usize>(), "{name}");
    }
}

#[test]
fn pjrt_execution_is_deterministic() {
    let Some(exec) = executor() else { return };
    let artifact = exec.model("gcn").unwrap().artifact.clone();
    let args = grip::runtime::golden_args(&artifact);
    let a = exec.run("gcn", &args).unwrap();
    let b = exec.run("gcn", &args).unwrap();
    assert_eq!(a, b);
}

/// The centerpiece: for a *real sampled nodeflow*, the float PJRT path
/// (JAX/Pallas AOT) and the Rust Q4.12 functional datapath must agree
/// within fixed-point error. This pins the Rust GReTA semantics to the
/// Python model definitions end-to-end.
#[test]
fn fixed_point_datapath_matches_pjrt_on_real_nodeflows() {
    let Some(exec) = executor() else { return };
    let mc = ModelConfig::paper();
    let g = Dataset::Youtube.generate(0.002, 5);
    let s = Sampler::new(3);
    let nf = Nodeflow::build(&g, &s, &[42], &mc);

    for model in ALL_MODELS {
        let artifact = exec.model(model.name()).unwrap().artifact.clone();
        let plan = compile(model, &mc);
        let args = build_args(&plan, &artifact, &nf).unwrap();
        let pjrt_out = exec.run(model.name(), &args).unwrap();
        let f_out = *artifact.output_shape.last().unwrap();
        let h = &args[2]; // padded features; executor wants exact rows
        let u1 = nf.layers[0].num_inputs();
        let h_exact: Vec<f32> = h[..u1 * mc.f_in].to_vec();
        let mut exec_args = ExecArgs::new();
        let weights = serving_weights(&artifact);
        for (spec, w) in artifact.args[3..].iter().zip(weights) {
            exec_args.insert(spec.name.clone(), (spec.shape.clone(), w));
        }
        let fx_out = execute_model(&plan, &nf, &h_exact, &exec_args).unwrap();

        // Compare the target row (first output vertex).
        let mut max_err = 0f32;
        let mut max_mag = 0f32;
        for (a, b) in pjrt_out[..f_out].iter().zip(fx_out[..f_out].iter()) {
            max_err = max_err.max((a - b).abs());
            max_mag = max_mag.max(a.abs());
        }
        // Q4.12 quantization + LUT sigmoid error accumulate over two
        // 512-deep layers; allow a small absolute + relative budget.
        let budget = 0.05 + 0.05 * max_mag;
        assert!(
            max_err < budget,
            "{model:?}: PJRT vs fixed-point max err {max_err} (mag {max_mag})"
        );
    }
}

/// The weight-resident hot path (`run_prepared` / `execute_b`) must be
/// numerically identical to the general literal path (`run`).
#[test]
fn run_prepared_matches_run() {
    let Some(exec) = executor() else { return };
    let mc = ModelConfig::paper();
    let g = Dataset::Youtube.generate(0.002, 5);
    let s = Sampler::new(3);
    let nf = Nodeflow::build(&g, &s, &[42], &mc);
    for model in ALL_MODELS {
        let artifact = exec.model(model.name()).unwrap().artifact.clone();
        let full = build_args(&compile(model, &mc), &artifact, &nf).unwrap();
        let via_run = exec.run(model.name(), &full).unwrap();
        let via_prepared = exec.run_prepared(model.name(), &full[..3]).unwrap();
        assert_eq!(via_run, via_prepared, "{model:?}");
    }
}

/// The Pallas-bodied HLO (the hardware-structural lowering of the L1
/// vertex-tiling kernel) must compute the same numbers as the fused
/// serving artifact — on-PJRT proof that the kernel is correct, not
/// just correct-under-jnp-interpretation.
#[test]
fn pallas_variant_matches_serving_artifact() {
    let Some(exec) = executor() else { return };
    // gcn exercises vertex_tiled_matmul twice; sage exercises masked_max.
    for name in ["gcn", "sage"] {
        let artifact = exec.model(name).unwrap().artifact.clone();
        if artifact.hlo_pallas_path.is_none() {
            eprintln!("skipping: no pallas artifact for {name}");
            continue;
        }
        let args = grip::runtime::golden_args(&artifact);
        let serving = exec.run(name, &args).unwrap();
        let pallas = exec.run_pallas_variant(name, &args).unwrap();
        let mut max_err = 0f32;
        for (a, b) in serving.iter().zip(pallas.iter()) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 2e-3, "{name}: serving vs pallas max err {max_err}");
    }
}

#[test]
fn serving_coordinator_with_numerics() {
    if executor().is_none() {
        return;
    }
    use grip::coordinator::{Coordinator, InferenceRequest, ServeConfig};
    let g = Dataset::Youtube.generate(0.002, 5);
    let coord = Coordinator::start(g, 7, ServeConfig::default()).unwrap();
    let resp = coord
        .infer(InferenceRequest::single(1, GnnModel::Gcn, 9))
        .unwrap();
    assert_eq!(resp.embedding.len(), 256);
    assert!(resp.embedding.iter().all(|x| x.is_finite()));
    assert!(resp.accel_us > 1.0);
    // GCN ends in ReLU: embeddings nonnegative.
    assert!(resp.embedding.iter().all(|&x| x >= 0.0));
}

#[test]
fn different_targets_different_embeddings() {
    if executor().is_none() {
        return;
    }
    use grip::coordinator::{Coordinator, InferenceRequest, ServeConfig};
    let g = Dataset::Youtube.generate(0.002, 5);
    let coord = Coordinator::start(g, 7, ServeConfig::default()).unwrap();
    let a = coord
        .infer(InferenceRequest::single(1, GnnModel::Gcn, 9))
        .unwrap();
    let b = coord
        .infer(InferenceRequest::single(2, GnnModel::Gcn, 1009))
        .unwrap();
    assert_ne!(a.embedding, b.embedding);
    // Determinism: same target twice gives the same embedding.
    let a2 = coord
        .infer(InferenceRequest::single(3, GnnModel::Gcn, 9))
        .unwrap();
    assert_eq!(a.embedding, a2.embedding);
}
