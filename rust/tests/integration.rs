//! Cross-module integration tests: dataset calibration against Table I,
//! GReTA plan ↔ AOT manifest contract, simulator ↔ baseline shape
//! checks, and end-to-end repro harness smoke.

use grip::config::{GripConfig, ModelConfig};
use grip::graph::{Dataset, TABLE1};
use grip::greta::{compile, execute_model, GnnModel, ALL_MODELS};
use grip::nodeflow::{Nodeflow, NormKind, Sampler};
use grip::repro::ReproCtx;
use grip::rng::GoldenLcg;

fn small_ctx() -> ReproCtx {
    ReproCtx { scale: 0.004, targets_per_dataset: 48, ..Default::default() }
}

#[test]
fn dataset_two_hop_calibration_matches_table1() {
    // The sampled-2-hop median of each synthetic dataset must land near
    // the paper's Table I value (the statistic every experiment rides on).
    let ctx = ReproCtx { scale: 0.005, targets_per_dataset: 128, ..Default::default() };
    for ds in TABLE1 {
        let wl = ctx.workload(ds);
        let got = ctx.median_two_hop(&wl) as f64;
        let want = ds.spec().two_hop_median as f64;
        let ratio = got / want;
        assert!(
            (0.6..=1.6).contains(&ratio),
            "{:?}: measured 2-hop median {got} vs paper {want}",
            ds
        );
    }
}

#[test]
fn plan_weights_match_manifest_param_names() {
    // The GReTA compiler's weight names must be exactly the manifest's
    // parameter names (python param_names) in order — the runtime feeds
    // literals positionally.
    let mc = ModelConfig::paper();
    let expect: &[(&str, &[&str])] = &[
        ("gcn", &["w1", "w2"]),
        ("sage", &["wp1", "wn1", "ws1", "wp2", "wn2", "ws2"]),
        ("gin", &["w1a", "w1b", "w2a", "w2b"]),
        ("ggcn", &["wg1", "wm1", "ws1", "wg2", "wm2", "ws2"]),
    ];
    for (name, weights) in expect {
        let model = GnnModel::from_name(name).unwrap();
        let plan = compile(model, &mc);
        assert_eq!(&plan.weight_names()[..], *weights, "{name}");
    }
}

#[test]
fn nodeflow_fits_aot_padding() {
    // Every nodeflow our sampler can build at paper sampling parameters
    // must fit the padded AOT shapes (u1=288, v1=16, u2=16, v2=8).
    let mc = ModelConfig::paper();
    let g = Dataset::Reddit.generate(0.004, 3);
    let s = Sampler::new(11);
    for v in (0..400u32).step_by(7) {
        let nf = Nodeflow::build(&g, &s, &[v], &mc);
        assert!(nf.layers[0].num_inputs() <= 288, "u1 = {}", nf.layers[0].num_inputs());
        assert!(nf.layers[0].num_outputs <= 16);
        assert!(nf.layers[1].num_inputs() <= 16);
        assert!(nf.layers[1].num_outputs <= 8);
    }
}

#[test]
fn fixed_point_executor_matches_all_models_reasonably() {
    // The Q4.12 functional executor must track a float reference within
    // quantization error for every model on a real nodeflow.
    let mc = ModelConfig { sample1: 6, sample2: 4, f_in: 24, f_hid: 20, f_out: 10 };
    let g = Dataset::Youtube.generate(0.002, 5);
    let s = Sampler::new(3);
    let nf = Nodeflow::build(&g, &s, &[42], &mc);
    let mut lcg = GoldenLcg::new(1);
    let h: Vec<f32> = lcg
        .fill(nf.layers[0].num_inputs() * mc.f_in)
        .iter()
        .map(|x| x * 0.5)
        .collect();
    for model in ALL_MODELS {
        let plan = compile(model, &mc);
        let mut args = grip::greta::exec_test_args(&plan, 9);
        args.insert("eps1".into(), (vec![], vec![0.1]));
        args.insert("eps2".into(), (vec![], vec![0.2]));
        let out = execute_model(&plan, &nf, &h, &args).unwrap();
        assert_eq!(out.len(), mc.f_out);
        assert!(out.iter().all(|x| x.is_finite() && *x >= 0.0), "{model:?}");
    }
}

#[test]
fn sim_speedup_over_cpu_baseline_in_paper_decade() {
    // GRIP vs the fitted CPU model: geomean speedup for GCN must land
    // in the paper's decade (Table III: 11-30x per dataset).
    let ctx = small_ctx();
    let plan = compile(GnnModel::Gcn, &ctx.mc);
    let mut speedups = Vec::new();
    for ds in TABLE1 {
        let wl = ctx.workload(ds);
        let (lat, nbhd, _) = ctx.sim_stats(&ctx.grip, &plan, &wl);
        let cpu = grip::baseline::cpu_latency_us(&plan, nbhd.p99() as usize);
        speedups.push(cpu / lat.p99());
    }
    let geo = (speedups.iter().map(|x: &f64| x.ln()).sum::<f64>() / speedups.len() as f64).exp();
    assert!(geo > 8.0 && geo < 45.0, "GCN CPU speedup geomean {geo}");
}

#[test]
fn dense_rendering_matches_edge_multiset() {
    // to_dense(Sum) must carry exactly the sampler's edge multiset so the
    // PJRT path and the functional executor agree on semantics.
    let mc = ModelConfig::paper();
    let g = Dataset::Youtube.generate(0.002, 5);
    let s = Sampler::new(3);
    let nf = Nodeflow::build(&g, &s, &[7], &mc);
    let d = nf.to_dense(0, 16, 288, NormKind::Sum);
    let total: f32 = d.iter().sum();
    assert_eq!(total as usize, nf.layers[0].edges.len());
    // Mean rows: each non-empty row sums to 1.
    let dm = nf.to_dense(0, 16, 288, NormKind::Mean);
    for v in 0..nf.layers[0].num_outputs {
        let s: f32 = dm[v * 288..(v + 1) * 288].iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }
}

#[test]
fn repro_harness_all_experiments_run() {
    // Every experiment generator must complete on a small context.
    let ctx = ReproCtx { scale: 0.003, targets_per_dataset: 16, ..Default::default() };
    let mut sink = Vec::new();
    grip::repro::run("all", &ctx, &mut sink).unwrap();
    let text = String::from_utf8(sink).unwrap();
    for marker in [
        "Table I", "Fig 2", "Table II", "Table III", "Fig 9a", "Fig 9b", "Fig 10a",
        "Fig 10b", "Fig 10c", "Fig 10d", "Fig 11a", "Fig 11b", "Fig 12", "Fig 13a",
        "Fig 13b", "Table IV",
    ] {
        assert!(text.contains(marker), "missing {marker}");
    }
}

#[test]
fn vertex_tiling_buffer_claim() {
    // Paper Sec. VIII-F: GRIP's edge-accumulate buffer is ~1.5 KiB vs
    // HyGCN's 16 MB (~10,000x). Verify our config reproduces the claim.
    let cfg = GripConfig::paper();
    let grip_buf = cfg.edge_acc_tile_bytes(512);
    assert_eq!(grip_buf, 1408); // 11 x 64 x 2 B ≈ 1.4 KiB
    let mut hygcn = cfg.clone();
    hygcn.vertex_tiling = false;
    // HyGCN materializes full feature vectors for a whole partition of
    // output vertices: 512 features x 2 B x many vertices; even per
    // vertex it is 16x GRIP's tile.
    let hygcn_per_vertex = hygcn.edge_acc_tile_bytes(512);
    assert!(hygcn_per_vertex >= 1024);
}

#[test]
fn serving_coordinator_timing_only_smoke() {
    // Coordinator end-to-end without numerics (timing-only backend):
    // queue, nodeflow, simulation, metrics.
    use grip::coordinator::{run_workload, BackendChoice, Coordinator, ServeConfig};
    let g = Dataset::Youtube.generate(0.002, 5);
    let n = g.num_vertices() as u32;
    let coord = Coordinator::start(
        g,
        7,
        ServeConfig { backend: BackendChoice::TimingOnly, ..Default::default() },
    )
    .unwrap();
    let targets: Vec<u32> = (0..16).map(|i| (i * 31) % n).collect();
    let (accel, host, responses) = run_workload(&coord, GnnModel::Gcn, &targets).unwrap();
    assert_eq!(responses.len(), 16);
    assert!(accel.p99() > 1.0 && accel.p99() < 1000.0, "{}", accel.p99());
    assert!(host.p99() > 0.0);
    assert!(responses.iter().all(|r| r.neighborhood >= 1));
}
