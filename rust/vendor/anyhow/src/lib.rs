//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access, so the subset of the
//! anyhow API this workspace uses is reimplemented here behind the same
//! crate name: [`Error`], [`Result`], the [`anyhow!`], [`bail!`] and
//! [`ensure!`] macros, and the [`Context`] extension trait. Swapping the
//! real crate back in is a one-line change in `Cargo.toml`.
//!
//! Semantics match anyhow where it matters for callers: `Error` wraps
//! any `std::error::Error + Send + Sync + 'static`, converts from such
//! errors via `?`, and deliberately does **not** implement
//! `std::error::Error` itself (so the blanket `From` impl does not
//! collide with the reflexive one).

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error, convertible from any standard error.
pub struct Error(Box<dyn StdError + Send + Sync + 'static>);

impl Error {
    /// Construct from a displayable message (what `anyhow!` produces).
    pub fn msg<M>(message: M) -> Error
    where
        M: fmt::Display + Send + Sync + 'static,
    {
        Error(Box::new(MessageError(message)))
    }

    /// Construct from a concrete error value.
    pub fn new<E>(error: E) -> Error
    where
        E: StdError + Send + Sync + 'static,
    {
        Error(Box::new(error))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // anyhow renders the message (plus a cause chain); keep the
        // message so `main() -> Result<()>` failures read well.
        write!(f, "{}", self.0)?;
        let mut source = self.0.source();
        while let Some(cause) = source {
            write!(f, "\n\nCaused by:\n    {cause}")?;
            source = cause.source();
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `Result` defaulted to [`Error`], as in anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Message-only error payload backing [`Error::msg`].
struct MessageError<M>(M);

impl<M: fmt::Display> fmt::Display for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<M: fmt::Display> fmt::Debug for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<M: fmt::Display> StdError for MessageError<M> {}

/// Attach context to errors, as in anyhow (message-flattening variant:
/// the context string and the underlying error are joined into one
/// message rather than kept as a cause chain).
pub trait Context<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or an error value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_build_messages() {
        let x = 3;
        let e = anyhow!("value {x} bad");
        assert_eq!(e.to_string(), "value 3 bad");
        let e2 = anyhow!("{}: {}", "ctx", 7);
        assert_eq!(e2.to_string(), "ctx: 7");
        let io = std::io::Error::other("boom");
        let e3 = anyhow!(io);
        assert_eq!(e3.to_string(), "boom");
    }

    #[test]
    fn ensure_and_bail() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "wanted {}", true);
            Ok(1)
        }
        fn g() -> Result<u32> {
            bail!("nope")
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(f(false).unwrap_err().to_string(), "wanted true");
        assert_eq!(g().unwrap_err().to_string(), "nope");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::other("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let o: Option<u32> = None;
        let e2 = o.with_context(|| format!("missing {}", 9)).unwrap_err();
        assert_eq!(e2.to_string(), "missing 9");
    }
}
