//! The float engine: AOT'd HLO artifacts on a PJRT client, one client
//! **per shard** (the client is not `Send`; the [`BackendFactory`]
//! constructs this backend inside each shard thread, which is what
//! deleted the old shard-0 pinning). `prepare` resolves the model's
//! artifact once — the serving weights were already transferred to the
//! device by [`Executor::load`] — so the request path only uploads the
//! per-request `(a1, a2, h)` dynamic args.
//!
//! Since PR 5 each preset may ship **two** artifacts: the batch-8 pads
//! (the SLO batcher's coalescing capacity) and a batch-1 variant
//! (`<model>_b1` in the manifest) with ~8× smaller dense `(a1, a2, h)`
//! shapes. `execute` picks by nodeflow target count, so online
//! single-target requests stop paying the batch-8 marshalling volume
//! and matmul rows (the ROADMAP open item). The variant serves the
//! **base artifact's** device weights (`Executor::load` sources them
//! from the primary entry — the serving-weight stream is
//! pad-dependent), so which artifact a request lands on can never
//! change its embedding.
//!
//! Compiles identically with and without the `pjrt` cargo feature: the
//! stub [`Executor`]'s `load` always fails, so default builds fall
//! back to timing-only serving at construction time (counted in
//! `ServeStats::backend_fallbacks`) rather than needing any cfg here.
//!
//! [`BackendFactory`]: super::BackendFactory

use super::{BackendOutput, Numerics, NumericsBackend, PreparedModel, StagedFeatures};
use crate::greta::{ExecArgs, ModelPlan, ALL_MODELS};
use crate::nodeflow::Nodeflow;
use crate::runtime::{
    build_dynamic_args_staged, fits_padding, Executor, Manifest, ModelArtifact,
};
use anyhow::{anyhow, Result};
use std::path::Path;

/// Per-model prepared state for the PJRT engine.
enum PjrtModel {
    /// An AOT artifact exists: serve float numerics through it.
    /// `b1` is the batch-1 variant, when the AOT bundle ships one —
    /// selected per job for single-target nodeflows that fit its
    /// smaller pads.
    Artifact { full: ModelArtifact, b1: Option<ModelArtifact> },
    /// No usable artifact: none exists (custom `ModelSpec`s are not
    /// AOT-compiled yet — the ROADMAP's spec→HLO bridge), or one
    /// exists but was compiled for different feature dims than this
    /// plan. An *expected* timing-only degrade, not an error.
    NoArtifact,
    /// A *preset* whose artifact is missing — a broken deployment.
    /// Kept per-model (rather than failing `prepare` and degrading the
    /// whole shard) so healthy presets keep serving float while every
    /// request for the broken one surfaces this error to its caller.
    Broken(String),
}

/// Float numerics on the CPU PJRT client, weights device-resident.
pub struct PjrtBackend {
    exec: Executor,
}

/// Do the artifact's feature dims match the plan's? An artifact is
/// only usable if it was AOT-compiled for this plan's feature dims
/// (h arg = `[pad_u, f_in]`). A name match with different dims — e.g.
/// serve-bench's shrunk default `ModelConfig` against the paper-dims
/// artifact — must NOT silently serve the artifact's numerics for a
/// different model.
fn dims_match(artifact: &ModelArtifact, plan: &ModelPlan) -> bool {
    let art_f_in = artifact.args.get(2).and_then(|a| a.shape.get(1)).copied();
    let art_f_out = artifact.output_shape.last().copied();
    art_f_in == plan.layers.first().map(|l| l.in_dim)
        && art_f_out == plan.layers.last().map(|l| l.out_dim)
}

impl PjrtBackend {
    /// Load the manifest, compile every model on this shard's own
    /// client, and transfer serving weights to the device. Fails when
    /// the runtime is stubbed out or artifacts are missing — callers
    /// degrade to [`super::TimingOnlyBackend`].
    pub fn load(artifact_dir: &Path) -> Result<PjrtBackend> {
        Ok(PjrtBackend { exec: Executor::load(artifact_dir)? })
    }

    /// The underlying per-shard executor (golden verification, tests).
    pub fn executor(&self) -> &Executor {
        &self.exec
    }
}

impl NumericsBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prepare(&mut self, plan: &ModelPlan, _args: &ExecArgs) -> Result<PreparedModel> {
        match self.exec.model(&plan.name) {
            Ok(lm) => {
                let artifact = lm.artifact.clone();
                if !dims_match(&artifact, plan) {
                    return Ok(PreparedModel::new(
                        plan.clone(),
                        Box::new(PjrtModel::NoArtifact),
                    ));
                }
                // The batch-1 variant is optional (older AOT bundles
                // predate it) and must agree on feature dims with the
                // full artifact it substitutes for.
                let b1 = self
                    .exec
                    .model(&Manifest::batch1_name(&plan.name))
                    .ok()
                    .map(|lm| lm.artifact.clone())
                    .filter(|a| dims_match(a, plan));
                let f_out = *artifact.output_shape.last().unwrap_or(&1);
                let mut prepared = PreparedModel::new(
                    plan.clone(),
                    Box::new(PjrtModel::Artifact { full: artifact, b1 }),
                );
                prepared.f_out = f_out;
                Ok(prepared)
            }
            Err(e) if ALL_MODELS.iter().any(|m| m.name() == plan.name) => {
                Ok(PreparedModel::new(
                    plan.clone(),
                    Box::new(PjrtModel::Broken(format!("preset {}: {e}", plan.name))),
                ))
            }
            Err(_) => Ok(PreparedModel::new(plan.clone(), Box::new(PjrtModel::NoArtifact))),
        }
    }

    fn execute<'s>(
        &mut self,
        prepared: &PreparedModel,
        nf: &Nodeflow,
        features: &StagedFeatures,
        scratch: &'s mut super::BackendScratch,
        // Float interiors are not Q4.12-exact; the serving layer never
        // passes a memo context to this engine.
        _memo: Option<super::MemoCtx<'_>>,
    ) -> Result<BackendOutput<'s>> {
        let state: &PjrtModel = prepared.state()?;
        let (full, b1) = match state {
            PjrtModel::Artifact { full, b1 } => (full, b1),
            // A broken preset deployment errors to *this* model's
            // callers; healthy models on the same shard keep serving.
            PjrtModel::Broken(msg) => return Err(anyhow!("{msg}")),
            PjrtModel::NoArtifact => {
                scratch.emb.clear();
                return Ok(BackendOutput {
                    embeddings: &scratch.emb,
                    f_out: 0,
                    numerics: Numerics::TimingOnly,
                });
            }
        };
        // Single-target requests take the batch-1 artifact when its
        // (much smaller) pads fit this nodeflow — same math over the
        // same device weights, ~8x less dense marshalling volume.
        let artifact = match b1 {
            Some(small) if nf.targets.len() == 1 && fits_padding(small, nf) => small,
            _ => full,
        };
        if !fits_padding(artifact, nf) {
            // The (batched) nodeflow exceeds the AOT padding: degrade
            // to an explicitly-tagged timing-only reply. The SLO
            // batcher's `max_coalesced_targets` clamp makes this
            // unreachable for coalesced batches; direct multi-target
            // submissions can still land here.
            scratch.emb.clear();
            return Ok(BackendOutput {
                embeddings: &scratch.emb,
                f_out: 0,
                numerics: Numerics::TimingOnly,
            });
        }
        let plan = prepared.plan();
        let h = features.rows_for(nf, plan.layers[0].in_dim)?;
        build_dynamic_args_staged(plan, artifact, nf, h, &mut scratch.marshal)?;
        let out = self.exec.run_prepared(&artifact.name, scratch.marshal.args())?;
        let f_out = prepared.f_out();
        scratch.emb.clear();
        scratch.emb.extend_from_slice(&out[..f_out * nf.targets.len()]);
        Ok(BackendOutput { embeddings: &scratch.emb, f_out, numerics: Numerics::Float })
    }
}
