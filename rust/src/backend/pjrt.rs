//! The float engine: AOT'd HLO artifacts on a PJRT client, one client
//! **per shard** (the client is not `Send`; the [`BackendFactory`]
//! constructs this backend inside each shard thread, which is what
//! deleted the old shard-0 pinning). `prepare` resolves the model's
//! artifact once — the serving weights were already transferred to the
//! device by [`Executor::load`] — so the request path only uploads the
//! per-request `(a1, a2, h)` dynamic args.
//!
//! Compiles identically with and without the `pjrt` cargo feature: the
//! stub [`Executor`]'s `load` always fails, so default builds fall
//! back to timing-only serving at construction time (counted in
//! `ServeStats::backend_fallbacks`) rather than needing any cfg here.
//!
//! [`BackendFactory`]: super::BackendFactory

use super::{BackendOutput, Numerics, NumericsBackend, PreparedModel};
use crate::greta::{ExecArgs, ModelPlan, ALL_MODELS};
use crate::nodeflow::Nodeflow;
use crate::runtime::{
    build_dynamic_args_into, fits_padding, Executor, FeatureSource, ModelArtifact,
};
use anyhow::{anyhow, Result};
use std::path::Path;

/// Per-model prepared state for the PJRT engine.
enum PjrtModel {
    /// An AOT artifact exists: serve float numerics through it.
    Artifact(ModelArtifact),
    /// No usable artifact: none exists (custom `ModelSpec`s are not
    /// AOT-compiled yet — the ROADMAP's spec→HLO bridge), or one
    /// exists but was compiled for different feature dims than this
    /// plan. An *expected* timing-only degrade, not an error.
    NoArtifact,
    /// A *preset* whose artifact is missing — a broken deployment.
    /// Kept per-model (rather than failing `prepare` and degrading the
    /// whole shard) so healthy presets keep serving float while every
    /// request for the broken one surfaces this error to its caller.
    Broken(String),
}

/// Float numerics on the CPU PJRT client, weights device-resident.
pub struct PjrtBackend {
    exec: Executor,
}

impl PjrtBackend {
    /// Load the manifest, compile every model on this shard's own
    /// client, and transfer serving weights to the device. Fails when
    /// the runtime is stubbed out or artifacts are missing — callers
    /// degrade to [`super::TimingOnlyBackend`].
    pub fn load(artifact_dir: &Path) -> Result<PjrtBackend> {
        Ok(PjrtBackend { exec: Executor::load(artifact_dir)? })
    }

    /// The underlying per-shard executor (golden verification, tests).
    pub fn executor(&self) -> &Executor {
        &self.exec
    }
}

impl NumericsBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prepare(&mut self, plan: &ModelPlan, _args: &ExecArgs) -> Result<PreparedModel> {
        match self.exec.model(&plan.name) {
            Ok(lm) => {
                let artifact = lm.artifact.clone();
                // An artifact is only usable if it was AOT-compiled for
                // this plan's feature dims (h arg = [pad_u1, f_in]). A
                // name match with different dims — e.g. serve-bench's
                // shrunk default ModelConfig against the paper-dims
                // artifact — must NOT silently serve the artifact's
                // numerics for a different model; degrade to the
                // explicit timing-only path instead.
                let art_f_in = artifact.args.get(2).and_then(|a| a.shape.get(1)).copied();
                let art_f_out = artifact.output_shape.last().copied();
                let plan_f_in = plan.layers.first().map(|l| l.in_dim);
                let plan_f_out = plan.layers.last().map(|l| l.out_dim);
                if art_f_in != plan_f_in || art_f_out != plan_f_out {
                    return Ok(PreparedModel::new(
                        plan.clone(),
                        Box::new(PjrtModel::NoArtifact),
                    ));
                }
                let f_out = *artifact.output_shape.last().unwrap_or(&1);
                let mut prepared =
                    PreparedModel::new(plan.clone(), Box::new(PjrtModel::Artifact(artifact)));
                prepared.f_out = f_out;
                Ok(prepared)
            }
            Err(e) if ALL_MODELS.iter().any(|m| m.name() == plan.name) => {
                Ok(PreparedModel::new(
                    plan.clone(),
                    Box::new(PjrtModel::Broken(format!("preset {}: {e}", plan.name))),
                ))
            }
            Err(_) => Ok(PreparedModel::new(plan.clone(), Box::new(PjrtModel::NoArtifact))),
        }
    }

    fn execute<'s>(
        &mut self,
        prepared: &PreparedModel,
        nf: &Nodeflow,
        features: &mut dyn FeatureSource,
        scratch: &'s mut super::BackendScratch,
    ) -> Result<BackendOutput<'s>> {
        let state: &PjrtModel = prepared.state()?;
        let artifact = match state {
            PjrtModel::Artifact(a) => a,
            // A broken preset deployment errors to *this* model's
            // callers; healthy models on the same shard keep serving.
            PjrtModel::Broken(msg) => return Err(anyhow!("{msg}")),
            PjrtModel::NoArtifact => {
                scratch.emb.clear();
                return Ok(BackendOutput {
                    embeddings: &scratch.emb,
                    f_out: 0,
                    numerics: Numerics::TimingOnly,
                });
            }
        };
        if !fits_padding(artifact, nf) {
            // The (batched) nodeflow exceeds the AOT padding: degrade
            // to an explicitly-tagged timing-only reply. The SLO
            // batcher's `max_coalesced_targets` clamp makes this
            // unreachable for coalesced batches; direct multi-target
            // submissions can still land here.
            scratch.emb.clear();
            return Ok(BackendOutput {
                embeddings: &scratch.emb,
                f_out: 0,
                numerics: Numerics::TimingOnly,
            });
        }
        let plan = prepared.plan();
        build_dynamic_args_into(plan, artifact, nf, features, &mut scratch.marshal)?;
        let out = self.exec.run_prepared(&plan.name, scratch.marshal.args())?;
        let f_out = prepared.f_out();
        scratch.emb.clear();
        scratch.emb.extend_from_slice(&out[..f_out * nf.targets.len()]);
        Ok(BackendOutput { embeddings: &scratch.emb, f_out, numerics: Numerics::Float })
    }
}
