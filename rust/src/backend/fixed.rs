//! The Q4.12 fixed-point engine: [`PlanArgs`] + [`ExecScratch`] behind
//! the [`NumericsBackend`] trait — bit-identical to the pre-trait
//! shard loop (pinned by `tests/backend_conformance.rs` and
//! `tests/serve_props.rs`).

use super::{BackendOutput, MemoCtx, Numerics, NumericsBackend, PreparedModel, StagedFeatures};
use crate::greta::{execute_model_into_memo, ExecArgs, ModelPlan, PlanArgs};
use crate::nodeflow::Nodeflow;
use anyhow::{anyhow, Result};

/// The scale-out serving engine: GRIP's bit-accurate 16-bit datapath
/// on the PR-1 hot path (weights quantized once at `prepare`, CSR edge
/// streaming, vertex-tiled matmul, zero steady-state allocations).
pub struct FixedPointBackend;

impl FixedPointBackend {
    pub fn new() -> Self {
        FixedPointBackend
    }
}

impl Default for FixedPointBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl NumericsBackend for FixedPointBackend {
    fn name(&self) -> &'static str {
        "fixed-q4.12"
    }

    /// Quantize and shape-check every transform weight / self-scale
    /// scalar once; the request path never touches the `Args` map.
    fn prepare(&mut self, plan: &ModelPlan, args: &ExecArgs) -> Result<PreparedModel> {
        let pargs = PlanArgs::resolve(plan, args)
            .map_err(|e| anyhow!("{}: resolving serving weights: {e}", plan.name))?;
        Ok(PreparedModel::new(plan.clone(), Box::new(pargs)))
    }

    fn execute<'s>(
        &mut self,
        prepared: &PreparedModel,
        nf: &Nodeflow,
        features: &StagedFeatures,
        scratch: &'s mut super::BackendScratch,
        memo: Option<MemoCtx<'_>>,
    ) -> Result<BackendOutput<'s>> {
        let pargs: &PlanArgs = prepared.state()?;
        let plan = prepared.plan();
        let h = features.rows_for(nf, plan.layers[0].in_dim)?;
        let splice = memo.map(|m| (m.plan, m.harvest));
        execute_model_into_memo(plan, nf, h, pargs, &mut scratch.exec, &mut scratch.emb, splice)
            .map_err(|e| anyhow!("{}: {e}", plan.name))?;
        Ok(BackendOutput {
            embeddings: &scratch.emb,
            f_out: prepared.f_out(),
            numerics: Numerics::FixedQ412,
        })
    }
}
