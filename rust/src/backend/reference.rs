//! The conformance engine: the seed edge-list executor
//! ([`execute_model_ref`]) behind the [`NumericsBackend`] trait. Slow
//! (per-call weight quantization, per-edge staging) but the canonical
//! Q4.12 semantics — `tests/backend_conformance.rs` pins the
//! fixed-point hot path bit-identical to this.

use super::{BackendOutput, MemoCtx, Numerics, NumericsBackend, PreparedModel, StagedFeatures};
use crate::greta::{execute_model_ref_memo, ExecArgs, ModelPlan};
use crate::nodeflow::Nodeflow;
use anyhow::{anyhow, Result};

/// Reference Q4.12 executor (seed implementation, unsorted edge-list
/// walk). Use for conformance runs, not serving throughput.
pub struct ReferenceBackend;

impl ReferenceBackend {
    pub fn new() -> Self {
        ReferenceBackend
    }
}

impl Default for ReferenceBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl NumericsBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    /// The reference executor re-resolves weights per call; `prepare`
    /// just snapshots the args map (and validates nothing up front —
    /// exactly the seed behavior the conformance suite compares
    /// against).
    fn prepare(&mut self, plan: &ModelPlan, args: &ExecArgs) -> Result<PreparedModel> {
        Ok(PreparedModel::new(plan.clone(), Box::new(args.clone())))
    }

    fn execute<'s>(
        &mut self,
        prepared: &PreparedModel,
        nf: &Nodeflow,
        features: &StagedFeatures,
        scratch: &'s mut super::BackendScratch,
        memo: Option<MemoCtx<'_>>,
    ) -> Result<BackendOutput<'s>> {
        let args: &ExecArgs = prepared.state()?;
        let plan = prepared.plan();
        let h = features.rows_for(nf, plan.layers[0].in_dim)?;
        let splice = memo.map(|m| (m.plan, m.harvest));
        let out = execute_model_ref_memo(plan, nf, h, args, splice)
            .map_err(|e| anyhow!("{}: {e}", plan.name))?;
        scratch.emb.clear();
        scratch.emb.extend_from_slice(&out);
        Ok(BackendOutput {
            embeddings: &scratch.emb,
            f_out: prepared.f_out(),
            numerics: Numerics::FixedQ412,
        })
    }
}
