//! Pluggable per-shard execution engines behind one trait (PR 4).
//!
//! GRIP's serving story is phase-specialized hardware behind a single
//! inference interface; before this module the runtime exposed three
//! incompatible execution APIs instead — the Q4.12 path
//! (`PlanArgs`/`ExecScratch`/`execute_model_into`), the PJRT float path
//! (`runtime::Executor`, hand-wired as an `Option<&Executor>` owned
//! only by shard 0), and a pair of bools (`pjrt`/`fixed_numerics`)
//! selecting between them. [`NumericsBackend`] unifies them:
//!
//! * [`prepare`](NumericsBackend::prepare) resolves one model's
//!   execution state **once per shard** — quantized weights for the
//!   fixed-point engine, device-resident weight buffers for PJRT — so
//!   the request path never compiles, quantizes, or uploads weights.
//! * [`execute`](NumericsBackend::execute) runs one (possibly
//!   coalesced) nodeflow and returns a [`BackendOutput`]: the target
//!   embeddings plus an explicit [`Numerics`] tag replacing the
//!   scattered `timing_only` bools.
//!
//! Backends are **not** required to be `Send`: the [`BackendFactory`]
//! is what crosses threads, and it constructs each shard's backend
//! *inside* that shard's thread. This is what un-pins PJRT from shard
//! 0 — every shard owns its own (non-`Send`) PJRT client and its own
//! device-resident weights, so float serving scales out exactly like
//! the fixed-point path.
//!
//! Engines shipped here:
//!
//! * [`FixedPointBackend`] — the Q4.12 hot path (bit-identical to the
//!   pre-trait shard loop).
//! * [`PjrtBackend`] — the AOT'd float path, one client per shard.
//! * [`ReferenceBackend`] — the seed edge-list executor, kept for
//!   conformance testing (`tests/backend_conformance.rs`).
//! * [`TimingOnlyBackend`] — no numerics; also the universal fallback
//!   when a configured backend fails to construct.
//!
//! Embedding-buffer convention: `execute` writes the job's embeddings
//! into [`BackendScratch::emb`] (reused across requests — the PR-1
//! zero-steady-state-allocation discipline) and returns them as the
//! borrowed [`BackendOutput::embeddings`] slice. See
//! `examples/BACKENDS.md` for the full contract.
//!
//! Since PR 5 the **edge-centric phase is decoupled from execution**:
//! layer-0 feature rows arrive pre-gathered in a [`StagedFeatures`]
//! buffer (filled by the serving pipeline's prefetch lanes, or inline
//! by the caller) instead of being pulled row-by-row through a
//! `FeatureSource` inside `execute`. This is what lets the shard
//! pipeline overlap feature gathering for job *i+1* with the matmul
//! for job *i* — GRIP's parallel prefetch engines feeding the vertex
//! engine.

mod fixed;
mod pjrt;
mod reference;

pub use fixed::FixedPointBackend;
pub use pjrt::PjrtBackend;
pub use reference::ReferenceBackend;

use crate::config::GripConfig;
use crate::greta::{ExecArgs, ExecScratch, ModelPlan};
use crate::nodeflow::{MemoHarvest, MemoPlan, Nodeflow};
use crate::runtime::{FeatureSource, Manifest, MarshalScratch};
use anyhow::{anyhow, Result};
use std::any::Any;
use std::path::PathBuf;

/// What kind of numbers a reply's embedding holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Numerics {
    /// f32 float embeddings (the AOT'd PJRT path).
    Float,
    /// Q4.12 fixed-point embeddings collapsed to f32 (the GRIP
    /// datapath — both the hot CSR executor and the reference
    /// edge-list executor produce this tag).
    FixedQ412,
    /// No numeric path ran: the reply carries timing only and its
    /// embedding is empty.
    TimingOnly,
}

impl Numerics {
    /// True when the reply carries an actual embedding.
    pub fn is_numeric(self) -> bool {
        !matches!(self, Numerics::TimingOnly)
    }
}

/// The result of one [`NumericsBackend::execute`] call.
pub struct BackendOutput<'a> {
    /// Row-major `[targets × f_out]` embeddings, borrowed from the
    /// scratch arena the call ran with. Empty iff `numerics` is
    /// [`Numerics::TimingOnly`].
    pub embeddings: &'a [f32],
    /// Output feature width per target (0 for timing-only replies).
    pub f_out: usize,
    /// Which numeric path produced `embeddings`.
    pub numerics: Numerics,
}

/// One model's per-shard execution state, produced by
/// [`NumericsBackend::prepare`]: the compiled plan plus an opaque
/// backend-specific payload (resolved Q4.12 weights, the PJRT
/// artifact record, ...). Handles are only valid with the backend
/// that prepared them.
pub struct PreparedModel {
    plan: ModelPlan,
    f_out: usize,
    state: Box<dyn Any>,
}

impl PreparedModel {
    /// Wrap a backend's per-model state. `f_out` defaults to the
    /// plan's final layer width (PJRT overrides it from the artifact).
    pub fn new(plan: ModelPlan, state: Box<dyn Any>) -> Self {
        let f_out = plan.layers.last().map(|l| l.out_dim).unwrap_or(0);
        Self { plan, f_out, state }
    }

    pub fn plan(&self) -> &ModelPlan {
        &self.plan
    }

    /// Output width the owning backend will produce per target.
    pub fn f_out(&self) -> usize {
        self.f_out
    }

    /// Downcast the backend-specific state.
    pub fn state<T: 'static>(&self) -> Result<&T> {
        self.state
            .downcast_ref::<T>()
            .ok_or_else(|| anyhow!("{}: prepared by a different backend", self.plan.name))
    }
}

/// Reusable working memory shared by every backend on one shard: the
/// output embedding buffer, the fixed-point executor arena, and the
/// PJRT marshalling arena. After warm-up no buffer reallocates — the
/// PR-1 hot-path discipline, now owned by the execution layer instead
/// of hand-threaded through the shard loop. (Layer-0 feature staging
/// moved out to [`StagedFeatures`] in PR 5 so it can cross the
/// prefetch-lane → vertex-engine queue.)
pub struct BackendScratch {
    /// Embedding output buffer ([`BackendOutput::embeddings`] borrows
    /// from here).
    pub emb: Vec<f32>,
    /// Fixed-point executor arena.
    pub exec: ExecScratch,
    /// PJRT dense-argument marshalling arena.
    pub marshal: MarshalScratch,
}

impl BackendScratch {
    pub fn new() -> Self {
        Self::for_config(&GripConfig::paper())
    }

    /// Vertex-tile width for the fixed-point matmul from an explicit
    /// architecture configuration.
    pub fn for_config(cfg: &GripConfig) -> Self {
        Self {
            emb: Vec::new(),
            exec: ExecScratch::for_config(cfg),
            marshal: MarshalScratch::new(),
        }
    }
}

impl Default for BackendScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// A job's staged layer-0 feature rows — the edge-centric phase's
/// output, decoupled from execution so it can cross the serving
/// pipeline's prefetch-lane → vertex-engine queue (this used to be the
/// `h` buffer inside `BackendScratch`, filled by a `stage_features`
/// call at the top of every `execute`).
///
/// Rows sit in `nf.layers[0].inputs` order at width `in_dim` — exactly
/// the layout `execute_model_into` consumes and the PJRT marshaller
/// pads from. Buffers are pooled and reused by the shard pipeline, so
/// staging is allocation-free in steady state.
#[derive(Debug, Default)]
pub struct StagedFeatures {
    rows: Vec<f32>,
    in_dim: usize,
    num_rows: usize,
}

impl StagedFeatures {
    pub fn new() -> Self {
        Self::default()
    }

    /// Gather `nf`'s layer-0 feature rows from `features` (the
    /// edge-centric phase). Deterministic in `(nf, features)`: the
    /// values depend only on vertex ids, never on which lane or thread
    /// staged them — the root of the pipeline's bit-identity guarantee.
    pub fn stage(&mut self, nf: &Nodeflow, in_dim: usize, features: &mut dyn FeatureSource) {
        let l0 = &nf.layers[0];
        self.in_dim = in_dim;
        self.num_rows = l0.num_inputs();
        // Resize without a clear: every element is overwritten by the
        // row loop below, so only growth pays a zero-fill (no
        // per-request memset of the whole staging buffer).
        self.rows.resize(self.num_rows * in_dim, 0f32);
        for (i, &v) in l0.inputs.iter().enumerate() {
            features.fill_row(v, &mut self.rows[i * in_dim..(i + 1) * in_dim]);
        }
    }

    /// Staged width per row.
    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    /// Staged row count.
    pub fn num_rows(&self) -> usize {
        self.num_rows
    }

    /// The flat `num_rows × in_dim` row block for `nf`, shape-checked
    /// against the consuming plan (catches a lane staging with a
    /// different width than the engine executes, or a buffer paired
    /// with the wrong job).
    pub fn rows_for(&self, nf: &Nodeflow, in_dim: usize) -> Result<&[f32]> {
        let want_rows = nf.layers[0].num_inputs();
        if self.in_dim != in_dim || self.num_rows != want_rows {
            return Err(anyhow!(
                "staged features are {}x{}, the job needs {}x{}",
                self.num_rows,
                self.in_dim,
                want_rows,
                in_dim
            ));
        }
        Ok(&self.rows[..self.num_rows * self.in_dim])
    }
}

/// Activation-memo context for one `execute` call (PR 10): the
/// build-time splice plan (cached rows to inject, rows to copy back
/// out) plus the harvest buffer the backend fills with freshly
/// computed interior-layer rows for deposit. Only engines with an
/// exact Q4.12 interior representation honor it (fixed, reference);
/// float/timing engines ignore it — the serving layer never constructs
/// one for them, so replies stay bit-identical either way.
pub struct MemoCtx<'a> {
    pub plan: &'a MemoPlan,
    pub harvest: &'a mut MemoHarvest,
}

/// A per-shard execution engine. One backend instance serves one shard
/// thread; it is constructed there by the [`BackendFactory`], prepares
/// every library model once, then executes jobs for the lifetime of
/// the shard.
///
/// Contract (pinned by `tests/backend_conformance.rs` and documented
/// in `examples/BACKENDS.md`):
///
/// * `prepare` is called once per (shard, model), before any
///   `execute`; all weight residency (quantization, device upload)
///   happens here.
/// * `execute` runs the nodeflow's target batch (`nf.targets`) and
///   leaves the embeddings in `scratch.emb`, returned as the borrowed
///   [`BackendOutput`]; it must be deterministic for a given
///   (prepared, nodeflow, staged-features) triple so replies never
///   depend on which shard served them.
/// * Backends need not be `Send`; they never leave the thread that
///   built them.
pub trait NumericsBackend {
    /// Stable engine name, also used as the per-shard status string in
    /// `ServeStats::shard_backends`.
    fn name(&self) -> &'static str;

    /// Resolve `plan`'s execution state for this shard. `args` holds
    /// the named serving weights/scalars; backends with their own
    /// weight source (PJRT's device-resident manifest weights) may
    /// ignore it.
    fn prepare(&mut self, plan: &ModelPlan, args: &ExecArgs) -> Result<PreparedModel>;

    /// Execute one job over `nf` (embeddings for every target, in
    /// member order). `features` carries the job's pre-gathered layer-0
    /// rows — the edge-centric phase already ran, possibly on another
    /// thread; `scratch` is this shard's reusable working memory.
    /// `memo`, when present, splices cached interior-layer rows in and
    /// harvests fresh ones out ([`MemoCtx`]); engines without exact
    /// fixed-point interiors ignore it.
    fn execute<'s>(
        &mut self,
        prepared: &PreparedModel,
        nf: &Nodeflow,
        features: &StagedFeatures,
        scratch: &'s mut BackendScratch,
        memo: Option<MemoCtx<'_>>,
    ) -> Result<BackendOutput<'s>>;
}

/// The no-numerics engine: replies carry cycle-sim timing only. Also
/// the universal fallback when a configured backend fails to construct
/// (surfaced via `ServeStats::backend_fallbacks`).
pub struct TimingOnlyBackend;

impl NumericsBackend for TimingOnlyBackend {
    fn name(&self) -> &'static str {
        "timing-only"
    }

    fn prepare(&mut self, plan: &ModelPlan, _args: &ExecArgs) -> Result<PreparedModel> {
        Ok(PreparedModel::new(plan.clone(), Box::new(())))
    }

    fn execute<'s>(
        &mut self,
        _prepared: &PreparedModel,
        _nf: &Nodeflow,
        _features: &StagedFeatures,
        scratch: &'s mut BackendScratch,
        _memo: Option<MemoCtx<'_>>,
    ) -> Result<BackendOutput<'s>> {
        scratch.emb.clear();
        Ok(BackendOutput { embeddings: &scratch.emb, f_out: 0, numerics: Numerics::TimingOnly })
    }
}

/// Which execution engine a serving stack runs — the plain-data
/// selector that replaced the `pjrt`/`fixed_numerics` bool pair in
/// `ShardSpec`/`ServeConfig` (`--backend` on the CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendChoice {
    /// No numeric path; timing-only replies.
    TimingOnly,
    /// Q4.12 fixed-point datapath (the scale-out serving default).
    Fixed,
    /// AOT'd float path on PJRT, one client per shard. Falls back to
    /// timing-only per shard when the runtime is unavailable.
    Pjrt,
    /// Seed edge-list executor (conformance; slow).
    Reference,
}

/// Accepted `--backend` spellings.
pub const BACKEND_NAME_HELP: &str =
    "fixed (q412) | pjrt (float) | reference (ref) | timing (none)";

impl BackendChoice {
    pub fn name(self) -> &'static str {
        match self {
            BackendChoice::TimingOnly => "timing",
            BackendChoice::Fixed => "fixed",
            BackendChoice::Pjrt => "pjrt",
            BackendChoice::Reference => "reference",
        }
    }

    /// Parse a CLI spelling (see [`BACKEND_NAME_HELP`]).
    pub fn from_name(s: &str) -> Option<BackendChoice> {
        match s.to_ascii_lowercase().as_str() {
            "fixed" | "fixed-point" | "q412" | "q4.12" => Some(BackendChoice::Fixed),
            "pjrt" | "float" => Some(BackendChoice::Pjrt),
            "reference" | "ref" => Some(BackendChoice::Reference),
            "timing" | "timing-only" | "none" => Some(BackendChoice::TimingOnly),
            _ => None,
        }
    }
}

impl std::fmt::Display for BackendChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Builds one backend per shard. The factory itself is plain `Send +
/// Sync` data and is cloned into every shard thread; [`build`] runs
/// *inside* the thread, so non-`Send` engines (the PJRT client) are
/// born where they live and never cross a thread boundary.
///
/// [`build`]: BackendFactory::build
#[derive(Debug, Clone)]
pub struct BackendFactory {
    choice: BackendChoice,
    artifact_dir: PathBuf,
}

impl BackendFactory {
    /// A factory for `choice` loading PJRT artifacts from the default
    /// directory.
    pub fn new(choice: BackendChoice) -> Self {
        Self { choice, artifact_dir: Manifest::default_dir() }
    }

    /// A factory with an explicit artifact directory (PJRT only).
    pub fn with_artifact_dir(choice: BackendChoice, artifact_dir: PathBuf) -> Self {
        Self { choice, artifact_dir }
    }

    pub fn choice(&self) -> BackendChoice {
        self.choice
    }

    /// Construct shard `shard`'s backend. Errors (e.g. PJRT runtime or
    /// artifacts unavailable) are the caller's to surface — the shard
    /// pool counts them in `ServeStats::backend_fallbacks` and serves
    /// the [`fallback`](BackendFactory::fallback) instead.
    pub fn build(&self, shard: usize) -> Result<Box<dyn NumericsBackend>> {
        match self.choice {
            BackendChoice::TimingOnly => Ok(Box::new(TimingOnlyBackend)),
            BackendChoice::Fixed => Ok(Box::new(FixedPointBackend::new())),
            BackendChoice::Reference => Ok(Box::new(ReferenceBackend::new())),
            BackendChoice::Pjrt => PjrtBackend::load(&self.artifact_dir)
                .map(|b| Box::new(b) as Box<dyn NumericsBackend>)
                .map_err(|e| anyhow!("shard {shard}: PJRT backend: {e}")),
        }
    }

    /// The engine a shard degrades to when [`build`] or `prepare`
    /// fails: timing-only serving, never a hard stop.
    ///
    /// [`build`]: BackendFactory::build
    pub fn fallback(&self) -> Box<dyn NumericsBackend> {
        Box::new(TimingOnlyBackend)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::graph::{generate, GeneratorParams};
    use crate::greta::{exec_test_args, GnnModel};
    use crate::nodeflow::Sampler;
    use crate::runtime::FeatureStore;

    fn small_mc() -> ModelConfig {
        ModelConfig { sample1: 4, sample2: 3, f_in: 12, f_hid: 10, f_out: 6 }
    }

    fn small_nf(mc: &ModelConfig) -> Nodeflow {
        let g = generate(&GeneratorParams { nodes: 400, mean_degree: 6.0, ..Default::default() });
        Nodeflow::build(&g, &Sampler::new(3), &[17], mc)
    }

    #[test]
    fn choice_names_round_trip() {
        for c in [
            BackendChoice::TimingOnly,
            BackendChoice::Fixed,
            BackendChoice::Pjrt,
            BackendChoice::Reference,
        ] {
            assert_eq!(BackendChoice::from_name(c.name()), Some(c), "{c}");
        }
        assert_eq!(BackendChoice::from_name("Q4.12"), Some(BackendChoice::Fixed));
        assert_eq!(BackendChoice::from_name("none"), Some(BackendChoice::TimingOnly));
        assert_eq!(BackendChoice::from_name("bogus"), None);
    }

    #[test]
    fn timing_only_backend_serves_empty_tagged_replies() {
        let mc = small_mc();
        let nf = small_nf(&mc);
        let plan = crate::greta::compile(GnnModel::Gcn, &mc);
        let mut be = TimingOnlyBackend;
        let prepared = be.prepare(&plan, &exec_test_args(&plan, 1)).unwrap();
        let mut store = FeatureStore::new();
        let mut staged = StagedFeatures::new();
        staged.stage(&nf, mc.f_in, &mut store);
        let mut scratch = BackendScratch::new();
        // Dirty the shared embedding buffer first: a timing-only reply
        // must never leak a previous job's numbers.
        scratch.emb.extend_from_slice(&[1.0, 2.0, 3.0]);
        let out = be.execute(&prepared, &nf, &staged, &mut scratch, None).unwrap();
        assert_eq!(out.numerics, Numerics::TimingOnly);
        assert!(!out.numerics.is_numeric());
        assert!(out.embeddings.is_empty());
        assert_eq!(out.f_out, 0);
    }

    #[test]
    fn staged_features_match_direct_gather_and_check_shape() {
        let mc = small_mc();
        let nf = small_nf(&mc);
        let mut store = FeatureStore::new();
        let mut staged = StagedFeatures::new();
        staged.stage(&nf, mc.f_in, &mut store);
        assert_eq!(staged.num_rows(), nf.layers[0].num_inputs());
        assert_eq!(staged.in_dim(), mc.f_in);
        // The staged block equals a hand-rolled row-by-row gather.
        let rows = staged.rows_for(&nf, mc.f_in).unwrap();
        let mut want = vec![0f32; nf.layers[0].num_inputs() * mc.f_in];
        for (i, &v) in nf.layers[0].inputs.iter().enumerate() {
            crate::runtime::fill_feature_row(v, &mut want[i * mc.f_in..(i + 1) * mc.f_in]);
        }
        assert_eq!(rows, &want[..]);
        // Re-staging at a different width over the dirty buffer is
        // exact (the pipeline pools and reuses these buffers).
        staged.stage(&nf, 7, &mut store);
        assert_eq!(staged.rows_for(&nf, 7).unwrap().len(), nf.layers[0].num_inputs() * 7);
        // Shape mismatches are errors, not silent garbage.
        assert!(staged.rows_for(&nf, mc.f_in).is_err(), "stale width must be rejected");
    }

    #[test]
    fn prepared_state_downcast_is_checked() {
        let mc = small_mc();
        let plan = crate::greta::compile(GnnModel::Gcn, &mc);
        let mut be = TimingOnlyBackend;
        let prepared = be.prepare(&plan, &ExecArgs::new()).unwrap();
        assert!(prepared.state::<()>().is_ok());
        assert!(prepared.state::<u32>().is_err(), "wrong-backend handles must not alias");
        assert_eq!(prepared.f_out(), mc.f_out);
        assert_eq!(prepared.plan().name, "gcn");
    }

    #[test]
    fn factory_builds_every_infallible_choice() {
        for c in [BackendChoice::TimingOnly, BackendChoice::Fixed, BackendChoice::Reference] {
            let be = BackendFactory::new(c).build(0).unwrap();
            assert!(!be.name().is_empty());
        }
        // PJRT may fail (stub executor / no artifacts); either way the
        // factory's fallback path must hold.
        let f = BackendFactory::new(BackendChoice::Pjrt);
        if let Err(e) = f.build(0) {
            let msg = e.to_string();
            assert!(msg.contains("PJRT"), "error names the backend: {msg}");
            assert_eq!(f.fallback().name(), "timing-only");
        }
    }
}
