//! Shared workload machinery for the repro experiments: dataset
//! generation, target sampling, nodeflow batches, and percentile
//! summaries over simulated latency.
//!
//! This driver is **closed-loop** (a fixed batch of sampled targets,
//! simulated back to back), which is what the paper's *tables* need.
//! Serving experiments — tail latency at a given offered load — use
//! the open-loop engine in [`crate::serve::loadgen`] instead (PR 2):
//! closed-loop replay saturates the pipeline and measures backlog, not
//! the latency a client at that arrival rate would see.

use crate::config::{GripConfig, ModelConfig};
use crate::coordinator::LatencyStats;
use crate::graph::{CsrGraph, Dataset};
use crate::greta::ModelPlan;
use crate::nodeflow::{Nodeflow, Sampler};
use crate::rng::SplitMix64;
use crate::sim::{simulate, SimResult};

/// Shared experiment context: graph scale, number of sampled targets,
/// and base configurations. Latency statistics depend only on *local*
/// graph structure, which the generator preserves at any scale, so
/// experiments default to a small scale for speed (`--scale` overrides).
#[derive(Debug, Clone)]
pub struct ReproCtx {
    pub scale: f64,
    pub targets_per_dataset: usize,
    pub seed: u64,
    pub grip: GripConfig,
    pub mc: ModelConfig,
}

impl Default for ReproCtx {
    fn default() -> Self {
        Self {
            scale: 0.01,
            targets_per_dataset: 128,
            seed: 17,
            grip: GripConfig::paper(),
            mc: ModelConfig::paper(),
        }
    }
}

/// A dataset's sampled workload: nodeflows for randomly chosen targets.
pub struct DatasetWorkload {
    pub dataset: Dataset,
    pub graph: CsrGraph,
    pub nodeflows: Vec<Nodeflow>,
}

impl ReproCtx {
    /// Build the workload for one dataset (deterministic).
    pub fn workload(&self, ds: Dataset) -> DatasetWorkload {
        let graph = ds.generate(self.scale, self.seed);
        let sampler = Sampler::new(self.seed ^ 0xA5);
        let mut rng = SplitMix64::new(self.seed ^ 0x7777);
        let nodeflows = (0..self.targets_per_dataset)
            .map(|_| {
                let t = rng.gen_range(graph.num_vertices()) as u32;
                Nodeflow::build(&graph, &sampler, &[t], &self.mc)
            })
            .collect();
        DatasetWorkload { dataset: ds, graph, nodeflows }
    }

    /// Simulate a compiled plan over a workload with a given config;
    /// returns (latency stats µs, neighborhood stats, a representative
    /// SimResult for counters — the one at the p99 neighborhood). Plans
    /// come from anywhere — presets via `compile(model, &ctx.mc)`, or a
    /// spec's [`ModelSpec::compile`](crate::greta::ModelSpec::compile).
    pub fn sim_stats(
        &self,
        cfg: &GripConfig,
        plan: &ModelPlan,
        wl: &DatasetWorkload,
    ) -> (LatencyStats, LatencyStats, SimResult) {
        let mut lat = LatencyStats::new();
        let mut nbhd = LatencyStats::new();
        let mut best: Option<(usize, SimResult)> = None;
        for nf in &wl.nodeflows {
            let r = simulate(cfg, plan, nf);
            lat.record(r.us(cfg));
            nbhd.record(nf.neighborhood_size() as f64);
            let n = nf.neighborhood_size();
            if best.as_ref().map(|(bn, _)| n > *bn).unwrap_or(true) {
                best = Some((n, r));
            }
        }
        (lat, nbhd, best.unwrap().1)
    }

    /// Median unique 2-hop neighborhood over the workload (Table I).
    pub fn median_two_hop(&self, wl: &DatasetWorkload) -> usize {
        let mut sizes: Vec<usize> =
            wl.nodeflows.iter().map(|nf| nf.neighborhood_size()).collect();
        sizes.sort_unstable();
        sizes[sizes.len() / 2]
    }
}

/// Geometric mean of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn workload_deterministic() {
        let ctx = ReproCtx { targets_per_dataset: 4, scale: 0.003, ..Default::default() };
        let a = ctx.workload(Dataset::Youtube);
        let b = ctx.workload(Dataset::Youtube);
        let sizes = |w: &DatasetWorkload| -> Vec<usize> {
            w.nodeflows.iter().map(|n| n.neighborhood_size()).collect()
        };
        assert_eq!(sizes(&a), sizes(&b));
    }

    #[test]
    fn sim_stats_populated() {
        use crate::greta::{compile, GnnModel};
        let ctx = ReproCtx { targets_per_dataset: 4, scale: 0.003, ..Default::default() };
        let wl = ctx.workload(Dataset::Youtube);
        let plan = compile(GnnModel::Gcn, &ctx.mc);
        let (lat, nbhd, rep) = ctx.sim_stats(&ctx.grip, &plan, &wl);
        assert_eq!(lat.count(), 4);
        assert!(nbhd.p50() >= 1.0);
        assert!(rep.counters.macs > 0);
    }
}
