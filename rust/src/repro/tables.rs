//! Table generators: Tables I–IV of the paper.

use super::workload::{geomean, ReproCtx};
use crate::baseline::{cpu_latency_us, gpu_latency_us};
use crate::energy::{power_breakdown, EnergyParams};
use crate::graph::{Dataset, TABLE1};
use crate::greta::{compile, GnnModel};
use std::io::Write;

/// Table III row order (paper order, not ALL_MODELS order).
const MODELS: [GnnModel; 4] = [GnnModel::Gcn, GnnModel::Ggcn, GnnModel::Sage, GnnModel::Gin];

/// Table I: dataset statistics (paper values vs our synthetic
/// equivalents, including the measured sampled-2-hop median).
pub fn table1(ctx: &ReproCtx, out: &mut dyn Write) -> anyhow::Result<()> {
    writeln!(out, "== Table I: datasets (paper vs synthetic @ scale {}) ==", ctx.scale)?;
    writeln!(
        out,
        "{:<14} {:>10} {:>10} {:>11} {:>11} {:>9} {:>9}",
        "dataset", "nodes", "edges", "paper-2hop", "ours-2hop", "mean-deg", "paper-deg"
    )?;
    for ds in TABLE1 {
        let spec = ds.spec();
        let wl = ctx.workload(ds);
        let two_hop = ctx.median_two_hop(&wl);
        writeln!(
            out,
            "{:<14} {:>10} {:>10} {:>11} {:>11} {:>9.2} {:>9.2}",
            spec.name,
            wl.graph.num_vertices(),
            wl.graph.num_edges(),
            spec.two_hop_median,
            two_hop,
            wl.graph.mean_degree(),
            spec.edges as f64 / spec.nodes as f64,
        )?;
    }
    Ok(())
}

/// Table II: architectural characteristics (static configuration dump).
pub fn table2(ctx: &ReproCtx, out: &mut dyn Write) -> anyhow::Result<()> {
    let c = &ctx.grip;
    writeln!(out, "== Table II: architectural characteristics ==")?;
    writeln!(out, "{:<22} {:>14} {:>14}", "", "paper", "ours")?;
    writeln!(out, "{:<22} {:>14} {:>14}", "compute (TOP/s)", "1.088", format!("{:.3}", c.peak_tops()))?;
    writeln!(out, "{:<22} {:>14} {:>14}", "clock (GHz)", "1.0", format!("{:.1}", c.freq_ghz))?;
    writeln!(out, "{:<22} {:>14} {:>14}", "nodeflow SRAM (KiB)", "4x20", format!("{}", c.nodeflow_buf_bytes / 1024))?;
    writeln!(out, "{:<22} {:>14} {:>14}", "tile SRAM (KiB)", "2x64", format!("{}", c.tile_buf_bytes / 1024))?;
    writeln!(out, "{:<22} {:>14} {:>14}", "weight SRAM (MiB)", "2", format!("{}", c.weight_buf_bytes >> 20))?;
    writeln!(out, "{:<22} {:>14} {:>14}", "off-chip (GiB/s)", "76.8", format!("{:.1}", c.dram_bytes_per_cycle() * c.freq_ghz))?;
    writeln!(out, "{:<22} {:>14} {:>14}", "DRAM channels", "4", format!("{}", c.dram_channels))?;
    writeln!(out, "{:<22} {:>14} {:>14}", "PE array", "16x32", format!("{}x{}", c.pe_rows, c.pe_cols))?;
    writeln!(out, "{:<22} {:>14} {:>14}", "area (mm^2)", "11.27", "n/a (sim)")?;
    writeln!(out, "{:<22} {:>14} {:>14}", "power (W)", "4.9", "see table4")?;
    Ok(())
}

/// Paper Table III reference values (µs): (model, dataset, grip, cpu, gpu).
pub const PAPER_TABLE3: [(&str, &str, f64, f64, f64); 16] = [
    ("gcn", "youtube", 15.4, 309.2, 1082.4),
    ("gcn", "livejournal", 15.8, 466.8, 1313.6),
    ("gcn", "pokec", 16.0, 477.1, 1085.6),
    ("gcn", "reddit", 16.3, 407.1, 813.2),
    ("ggcn", "youtube", 134.1, 2315.9, 1332.5),
    ("ggcn", "livejournal", 146.3, 2493.2, 1837.6),
    ("ggcn", "pokec", 146.7, 2637.9, 1409.2),
    ("ggcn", "reddit", 147.0, 2864.2, 1133.9),
    ("sage", "youtube", 113.7, 1545.1, 1309.0),
    ("sage", "livejournal", 124.4, 1947.4, 2193.8),
    ("sage", "pokec", 124.9, 2075.7, 1759.1),
    ("sage", "reddit", 125.3, 2099.0, 1252.8),
    ("gin", "youtube", 30.5, 344.7, 1387.6),
    ("gin", "livejournal", 30.9, 416.1, 1221.5),
    ("gin", "pokec", 31.1, 340.7, 855.5),
    ("gin", "reddit", 31.4, 354.8, 1009.4),
];

/// Table III: 99th-percentile inference latency, GRIP vs CPU vs GPU.
pub fn table3(ctx: &ReproCtx, out: &mut dyn Write) -> anyhow::Result<()> {
    writeln!(out, "== Table III: p99 inference latency (µs) ==")?;
    writeln!(
        out,
        "{:<6} {:<13} {:>8} {:>9} {:>8} {:>7} {:>8} {:>7}  {:>18}",
        "model", "dataset", "GRIP", "CPU", "(x)", "GPU", "(x)", "", "paper GRIP/CPUx/GPUx"
    )?;
    let mut cpu_speedups = Vec::new();
    let mut gpu_speedups = Vec::new();
    for model in MODELS {
        let plan = compile(model, &ctx.mc);
        for ds in TABLE1 {
            let wl = ctx.workload(ds);
            let (lat, nbhd, rep) = ctx.sim_stats(&ctx.grip, &plan, &wl);
            let grip_us = lat.p99();
            let p99_nbhd = nbhd.p99() as usize;
            let cpu_us = cpu_latency_us(&plan, p99_nbhd);
            let flops = 2.0 * rep.counters.macs as f64;
            let gpu_us = gpu_latency_us(&plan, p99_nbhd, flops);
            let (cx, gx) = (cpu_us / grip_us, gpu_us / grip_us);
            cpu_speedups.push(cx);
            gpu_speedups.push(gx);
            let paper = PAPER_TABLE3
                .iter()
                .find(|(m, d, ..)| *m == plan.name && *d == ds.spec().name)
                .unwrap();
            writeln!(
                out,
                "{:<6} {:<13} {:>8.1} {:>9.1} {:>7.1}x {:>7.0} {:>7.1}x {:>7}  {:>5.1}/{:>4.1}x/{:>4.1}x",
                plan.name,
                ds.spec().name,
                grip_us,
                cpu_us,
                cx,
                gpu_us,
                gx,
                "",
                paper.2,
                paper.3 / paper.2,
                paper.4 / paper.2,
            )?;
        }
    }
    writeln!(
        out,
        "geomean speedup: CPU {:.1}x (paper 17.0x), GPU {:.1}x (paper 23.4x)",
        geomean(&cpu_speedups),
        geomean(&gpu_speedups)
    )?;
    Ok(())
}

/// Paper Table IV reference (mW).
pub const PAPER_TABLE4: [(&str, f64, f64); 6] = [
    ("edge", 4.1, 0.1),
    ("vertex", 656.6, 12.6),
    ("update", 0.4, 0.1),
    ("weight-sram", 1476.7, 28.3),
    ("nodeflow-sram", 269.5, 5.1),
    ("dram", 2794.7, 53.7),
];

/// Table IV: power breakdown for GCN inference.
pub fn table4(ctx: &ReproCtx, out: &mut dyn Write) -> anyhow::Result<()> {
    let wl = ctx.workload(Dataset::Pokec);
    let (_, _, rep) = ctx.sim_stats(&ctx.grip, &compile(GnnModel::Gcn, &ctx.mc), &wl);
    let b = power_breakdown(&ctx.grip, &EnergyParams::paper(), &rep);
    writeln!(out, "== Table IV: power breakdown, GCN inference ==")?;
    writeln!(
        out,
        "{:<15} {:>9} {:>7} {:>12} {:>10}",
        "module", "ours mW", "ours %", "paper mW", "paper %"
    )?;
    for (module, paper_mw, paper_pct) in PAPER_TABLE4 {
        writeln!(
            out,
            "{:<15} {:>9.1} {:>6.1}% {:>12.1} {:>9.1}%",
            module,
            b.mw(module),
            b.pct(module),
            paper_mw,
            paper_pct
        )?;
    }
    writeln!(out, "{:<15} {:>9.1} {:>7} {:>12.1}", "total", b.total_mw, "", 4932.4)?;
    Ok(())
}
