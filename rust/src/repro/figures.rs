//! Figure generators: Figs. 2 and 9–13 of the paper, as text series.

use super::workload::{geomean, ReproCtx};
use crate::baseline::{
    baseline_ladder, cpu_latency_us, cpu_roofline_point, prior_work_configs, PriorWork,
};
use crate::config::{GripConfig, ModelConfig};
use crate::coordinator::LatencyStats;
use crate::graph::Dataset;
use crate::greta::{compile, GnnModel};
use crate::sim::simulate;
use std::io::Write;

/// Fig. 2: CPU performance vs arithmetic intensity for GCN on Pokec,
/// with the roofline bound and the LLC gap.
pub fn fig2(ctx: &ReproCtx, out: &mut dyn Write) -> anyhow::Result<()> {
    let wl = ctx.workload(Dataset::Pokec);
    writeln!(out, "== Fig 2: CPU roofline, GCN on Pokec ==")?;
    writeln!(out, "{:>6} {:>8} {:>12} {:>12} {:>7}", "nbhd", "AI", "GFLOP/s", "roofline", "gap")?;
    let mut sizes: Vec<usize> = wl.nodeflows.iter().map(|n| n.neighborhood_size()).collect();
    sizes.sort_unstable();
    sizes.dedup();
    for (i, &u) in sizes.iter().enumerate() {
        if i % (sizes.len() / 12 + 1) != 0 && i != sizes.len() - 1 {
            continue; // print ~12 representative points
        }
        let p = cpu_roofline_point(u, &ctx.mc);
        writeln!(
            out,
            "{:>6} {:>8.3} {:>12.1} {:>12.1} {:>6.1}x",
            u,
            p.ai,
            p.gflops,
            p.roofline,
            p.roofline / p.gflops
        )?;
    }
    writeln!(out, "(paper: measured points sit well below the roofline; the gap")?;
    writeln!(out, " grows with AI due to LLC bandwidth — same shape here)")?;
    Ok(())
}

fn gcn_largest_nbhd_cycles(ctx: &ReproCtx, cfg: &GripConfig) -> f64 {
    // Paper Sec. VIII-B: "geometric mean speedup of GCN for the largest
    // neighborhood in each dataset", in *time* (normalize cycles by clock).
    let mut times = Vec::new();
    for ds in crate::graph::TABLE1 {
        let wl = ctx.workload(ds);
        let nf = wl
            .nodeflows
            .iter()
            .max_by_key(|n| n.neighborhood_size())
            .unwrap();
        let plan = compile(GnnModel::Gcn, &ctx.mc);
        let r = simulate(cfg, &plan, nf);
        times.push(r.us(cfg));
    }
    geomean(&times)
}

/// Fig. 9a: speedup breakdown per architectural feature.
pub fn fig9a(ctx: &ReproCtx, out: &mut dyn Write) -> anyhow::Result<()> {
    writeln!(out, "== Fig 9a: speedup breakdown vs CPU-like baseline ==")?;
    let ladder = baseline_ladder();
    let base = gcn_largest_nbhd_cycles(ctx, &ladder[0].1);
    writeln!(out, "{:<16} {:>12} {:>10} {:>12}", "config", "geomean µs", "cum. x", "paper step")?;
    let paper_steps = ["1.0x", "2.8x", "x3.4", "x1.87", "x1.02"];
    let mut prev = base;
    for ((name, cfg), paper) in ladder.iter().zip(paper_steps) {
        let t = gcn_largest_nbhd_cycles(ctx, cfg);
        writeln!(
            out,
            "{:<16} {:>12.1} {:>9.1}x {:>12} (step {:.2}x)",
            name,
            t,
            base / t,
            paper,
            prev / t
        )?;
        prev = t;
    }
    Ok(())
}

/// Fig. 9b: prior-work comparison.
pub fn fig9b(ctx: &ReproCtx, out: &mut dyn Write) -> anyhow::Result<()> {
    writeln!(out, "== Fig 9b: estimated speedup of prior work vs baseline ==")?;
    let ladder = baseline_ladder();
    let base = gcn_largest_nbhd_cycles(ctx, &ladder[0].1);
    let grip = gcn_largest_nbhd_cycles(ctx, &ctx.grip);
    writeln!(out, "{:<16} {:>10} {:>12} {:>12}", "arch", "µs", "vs baseline", "paper")?;
    writeln!(out, "{:<16} {:>10.1} {:>11.1}x {:>12}", "baseline", base, 1.0, "1x")?;
    for (pw, paper) in [
        (PriorWork::Graphicionado, "2.4x"),
        (PriorWork::HyGcn, "4.4x"),
        (PriorWork::TpuPlus, "11.3x"),
    ] {
        let t = gcn_largest_nbhd_cycles(ctx, &prior_work_configs(pw));
        writeln!(out, "{:<16} {:>10.1} {:>11.1}x {:>12}", format!("{pw:?}"), t, base / t, paper)?;
    }
    writeln!(out, "{:<16} {:>10.1} {:>11.1}x {:>12}", "GRIP", grip, base / grip, "~20x")?;
    Ok(())
}

/// Fig. 10: architectural parameter sweeps (a: DRAM channels, b: weight
/// bandwidth, c: crossbar width, d: matmul TOP/s).
pub fn fig10(ctx: &ReproCtx, out: &mut dyn Write, which: char) -> anyhow::Result<()> {
    let wl = ctx.workload(Dataset::Pokec);
    let plan = compile(GnnModel::Gcn, &ctx.mc);
    let nf = &wl.nodeflows[wl.nodeflows.len() / 2];
    let run = |cfg: &GripConfig| simulate(cfg, &plan, nf).us(cfg);
    let base = run(&ctx.grip);

    match which {
        'a' => {
            writeln!(out, "== Fig 10a: DRAM channels (lanes = channels) ==")?;
            writeln!(out, "{:>9} {:>10} {:>9}", "channels", "µs", "speedup")?;
            for ch in [1usize, 2, 4, 8, 12, 16] {
                let mut c = ctx.grip.clone();
                c.dram_channels = ch;
                c.prefetch_lanes = ch;
                let t = run(&c);
                let marker = if ch == 4 { "  <- paper config" } else { "" };
                writeln!(out, "{:>9} {:>10.1} {:>8.2}x{}", ch, t, base / t, marker)?;
            }
            writeln!(out, "(paper: strong scaling until ~8 channels / 150 GiB/s)")?;
        }
        'b' => {
            writeln!(out, "== Fig 10b: weight bandwidth (GiB/s at 1 GHz) ==")?;
            writeln!(out, "{:>9} {:>10} {:>9}", "GiB/s", "µs", "speedup")?;
            for bw in [16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0] {
                let mut c = ctx.grip.clone();
                c.weight_bw_bytes_per_cycle = bw;
                let t = run(&c);
                let marker = if bw == 128.0 { "  <- paper knee" } else { "" };
                writeln!(out, "{:>9.0} {:>10.1} {:>8.2}x{}", bw, t, base / t, marker)?;
            }
            writeln!(out, "(paper: bottleneck below 128 GiB/s = 64 values/cycle)")?;
        }
        'c' => {
            writeln!(out, "== Fig 10c: crossbar port width (elements) ==")?;
            writeln!(out, "{:>9} {:>10} {:>9}", "width", "µs", "speedup")?;
            for w in [2usize, 4, 8, 16, 32, 64, 128, 256] {
                let mut c = ctx.grip.clone();
                c.xbar_width_elems = w;
                let t = run(&c);
                let marker = if w == 16 { "  <- paper config" } else { "" };
                writeln!(out, "{:>9} {:>10.1} {:>8.2}x{}", w, t, base / t, marker)?;
            }
            writeln!(out, "(paper: limited impact — edge-accumulate is not the bottleneck)")?;
        }
        'd' => {
            writeln!(out, "== Fig 10d: matmul size (TOP/s) ==")?;
            writeln!(out, "{:>9} {:>10} {:>10} {:>9}", "PE", "TOP/s", "µs", "speedup")?;
            for scale in [1usize, 2, 4, 8, 16] {
                let mut c = ctx.grip.clone();
                c.pe_cols = 8 * scale; // 16x8 .. 16x128
                let t = run(&c);
                let marker = if scale == 4 { "  <- paper config" } else { "" };
                writeln!(
                    out,
                    "{:>6}x{:<3} {:>9.2} {:>10.1} {:>8.2}x{}",
                    c.pe_rows,
                    c.pe_cols,
                    c.peak_tops(),
                    t,
                    base / t,
                    marker
                )?;
            }
            writeln!(out, "(paper: saturates ~2 TOP/s; 4x larger unit only 1.14x)")?;
        }
        _ => anyhow::bail!("fig10 variant must be a-d"),
    }
    Ok(())
}

/// Fig. 11a: % of time in vertex-accumulate vs feature dimensions.
pub fn fig11a(ctx: &ReproCtx, out: &mut dyn Write) -> anyhow::Result<()> {
    writeln!(out, "== Fig 11a: %% time in matmul vs feature dims (GCN) ==")?;
    writeln!(out, "{:>9} {:>12} | {:>9} {:>12}", "f_in", "% matmul", "f_out", "% matmul")?;
    let wl = ctx.workload(Dataset::Pokec);
    let nf = &wl.nodeflows[wl.nodeflows.len() / 2];
    for i in 0..8 {
        let dim = 8 << i; // 8..1024
        let mc_in = ModelConfig { f_in: dim, ..ctx.mc };
        let r_in = simulate(&ctx.grip, &compile(GnnModel::Gcn, &mc_in), nf);
        let mc_out = ModelConfig { f_out: dim, f_hid: dim.max(64), ..ctx.mc };
        let r_out = simulate(&ctx.grip, &compile(GnnModel::Gcn, &mc_out), nf);
        writeln!(
            out,
            "{:>9} {:>11.1}% | {:>9} {:>11.1}%",
            dim,
            100.0 * r_in.pct_vertex(),
            dim,
            100.0 * r_out.pct_vertex()
        )?;
    }
    writeln!(out, "(paper: rises until ~32-64 input features — DRAM burst underuse")?;
    writeln!(out, " below the 64-element interface — then flat; output dims always raise it)")?;
    Ok(())
}

/// Fig. 11b: % of time in edge-accumulate vs sampled edges per vertex.
pub fn fig11b(ctx: &ReproCtx, out: &mut dyn Write) -> anyhow::Result<()> {
    writeln!(out, "== Fig 11b: %% time in edge phase vs sampled edges (GCN) ==")?;
    writeln!(out, "{:>9} {:>12} {:>10}", "edges/v", "% edge", "µs")?;
    let wl = ctx.workload(Dataset::Pokec);
    for s in [2usize, 4, 8, 16, 25, 32, 48, 64] {
        let mc = ModelConfig { sample1: s, sample2: s.min(10), ..ctx.mc };
        // rebuild the nodeflow with this sampling
        let sampler = crate::nodeflow::Sampler::new(ctx.seed ^ 0xA5);
        let t = wl.nodeflows[0].targets[0];
        let nf = crate::nodeflow::Nodeflow::build(&wl.graph, &sampler, &[t], &mc);
        let r = simulate(&ctx.grip, &compile(GnnModel::Gcn, &mc), &nf);
        writeln!(out, "{:>9} {:>11.1}% {:>10.1}", s, 100.0 * r.pct_edge(), r.us(&ctx.grip))?;
    }
    writeln!(out, "(paper: compute-bound below ~8 edges/vertex, memory above)")?;
    Ok(())
}

/// Fig. 12: latency and speedup vs neighborhood size (GCN, LiveJournal).
pub fn fig12(ctx: &ReproCtx, out: &mut dyn Write) -> anyhow::Result<()> {
    writeln!(out, "== Fig 12: neighborhood size impact (GCN, LiveJournal) ==")?;
    let wl = ctx.workload(Dataset::Livejournal);
    let plan = compile(GnnModel::Gcn, &ctx.mc);
    // bin nodeflows by neighborhood size
    let mut by_bin: std::collections::BTreeMap<usize, LatencyStats> = Default::default();
    for nf in &wl.nodeflows {
        let bin = (nf.neighborhood_size() / 25) * 25;
        let r = simulate(&ctx.grip, &plan, nf);
        by_bin.entry(bin).or_insert_with(LatencyStats::new).record(r.us(&ctx.grip));
    }
    writeln!(
        out,
        "{:>9} {:>8} {:>8} {:>8} {:>8} {:>10}",
        "nbhd bin", "min µs", "med µs", "p99 µs", "CPU µs", "speedup"
    )?;
    for (bin, stats) in &by_bin {
        let cpu = cpu_latency_us(&plan, bin + 12);
        writeln!(
            out,
            "{:>9} {:>8.1} {:>8.1} {:>8.1} {:>8.0} {:>9.1}x",
            format!("{}-{}", bin, bin + 24),
            stats.min(),
            stats.p50(),
            stats.p99(),
            cpu,
            cpu / stats.p50()
        )?;
    }
    writeln!(out, "(paper: latency linear in neighborhood; speedup 12-18x below ~95,")?;
    writeln!(out, " rising past the CPU L2 cliff)")?;
    Ok(())
}

/// Fig. 13a: cumulative partitioning/pipelining optimization speedups.
pub fn fig13a(ctx: &ReproCtx, out: &mut dyn Write) -> anyhow::Result<()> {
    writeln!(out, "== Fig 13a: partition pipelining optimizations (GCN) ==")?;
    // Partitioning only matters when the nodeflow spans multiple
    // partition columns; use a batched (48-target) nodeflow, the
    // offline/batched regime the paper's partitioning targets.
    let wl = ctx.workload(Dataset::Reddit);
    let sampler = crate::nodeflow::Sampler::new(ctx.seed ^ 0xA5);
    let mut rng = crate::rng::SplitMix64::new(ctx.seed ^ 0x1313);
    let targets: Vec<u32> =
        (0..48).map(|_| rng.gen_range(wl.graph.num_vertices()) as u32).collect();
    let batched = crate::nodeflow::Nodeflow::build(&wl.graph, &sampler, &targets, &ctx.mc);
    let nf = &batched;
    let plan = compile(GnnModel::Gcn, &ctx.mc);
    let mut unopt = ctx.grip.clone();
    unopt.cache_features = false;
    unopt.pipeline_partitions = false;
    unopt.preload_weights = false;
    let steps: [(&str, Box<dyn Fn(&mut GripConfig)>, &str); 4] = [
        ("unoptimized", Box::new(|_c: &mut GripConfig| {}), "1.0x"),
        ("+caching", Box::new(|c: &mut GripConfig| c.cache_features = true), "1.3x"),
        ("+pipelining", Box::new(|c: &mut GripConfig| {
            c.cache_features = true;
            c.pipeline_partitions = true;
        }), "1.7x"),
        ("+weights", Box::new(|c: &mut GripConfig| {
            c.cache_features = true;
            c.pipeline_partitions = true;
            c.preload_weights = true;
        }), "2.5x"),
    ];
    let base = simulate(&unopt, &plan, nf).us(&unopt);
    writeln!(out, "{:<14} {:>10} {:>9} {:>9}", "config", "µs", "cum. x", "paper")?;
    for (name, apply, paper) in steps {
        let mut c = unopt.clone();
        apply(&mut c);
        let t = simulate(&c, &plan, nf).us(&c);
        writeln!(out, "{:<14} {:>10.1} {:>8.2}x {:>9}", name, t, base / t, paper)?;
    }
    Ok(())
}

/// Fig. 13b: vertex-tiling parameter sweep (M vertices × F features).
pub fn fig13b(ctx: &ReproCtx, out: &mut dyn Write) -> anyhow::Result<()> {
    writeln!(out, "== Fig 13b: vertex-tiling sweep (speedup vs no tiling, GCN) ==")?;
    let wl = ctx.workload(Dataset::Pokec);
    // The paper's sweep uses the canonical nodeflow with the maximum 11
    // output vertices (1 target + 10 sampled); pick one so the M axis
    // shows the paper's knee at M ≈ 11-12.
    let nf = wl
        .nodeflows
        .iter()
        .max_by_key(|n| (n.layers[0].num_outputs, n.neighborhood_size()))
        .unwrap();
    let plan = compile(GnnModel::Gcn, &ctx.mc);
    let mut no_tile = ctx.grip.clone();
    no_tile.vertex_tiling = false;
    let base = simulate(&no_tile, &plan, nf).us(&no_tile);
    write!(out, "{:>6}", "M\\F")?;
    let fs = [16usize, 32, 64, 128, 256];
    for f in fs {
        write!(out, " {:>7}", f)?;
    }
    writeln!(out)?;
    for m in [1usize, 2, 4, 8, 11, 12, 16] {
        write!(out, "{:>6}", m)?;
        for f in fs {
            let mut c = ctx.grip.clone();
            c.vertex_tiling = true;
            c.tile_m = m;
            c.tile_f = f;
            let t = simulate(&c, &plan, nf).us(&c);
            write!(out, " {:>6.2}x", base / t)?;
        }
        writeln!(out)?;
    }
    writeln!(out, "(paper: peak near F=64; M helps until ~12 — 11 is the max")?;
    writeln!(out, " output vertices, beyond which dummy vertices add latency)")?;
    Ok(())
}
