//! Experiment harness: one generator per table and figure of the
//! paper's evaluation (Sec. VIII). Each experiment prints the same
//! rows/series the paper reports, with the paper's published value
//! alongside ours where applicable. `grip repro --all` regenerates
//! everything (EXPERIMENTS.md records a run).

mod figures;
mod tables;
mod workload;

pub use workload::ReproCtx;

use std::io::Write;

/// All experiment ids in paper order.
pub const ALL_EXPERIMENTS: [&str; 14] = [
    "table1", "fig2", "table2", "table3", "fig9a", "fig9b", "fig10a", "fig10b", "fig10c",
    "fig10d", "fig11a", "fig11b", "fig12", "fig13a",
];
// fig13b and table4 are included in run() below; kept out of the const
// only to keep the array literal stable for CLI help text.

/// Run one experiment (or "all") and write its report.
pub fn run(exp: &str, ctx: &ReproCtx, out: &mut dyn Write) -> anyhow::Result<()> {
    match exp {
        "all" => {
            for e in [
                "table1", "fig2", "table2", "table3", "fig9a", "fig9b", "fig10a", "fig10b",
                "fig10c", "fig10d", "fig11a", "fig11b", "fig12", "fig13a", "fig13b", "table4",
            ] {
                run(e, ctx, out)?;
                writeln!(out)?;
            }
            Ok(())
        }
        "table1" => tables::table1(ctx, out),
        "table2" => tables::table2(ctx, out),
        "table3" => tables::table3(ctx, out),
        "table4" => tables::table4(ctx, out),
        "fig2" => figures::fig2(ctx, out),
        "fig9a" => figures::fig9a(ctx, out),
        "fig9b" => figures::fig9b(ctx, out),
        "fig10a" => figures::fig10(ctx, out, 'a'),
        "fig10b" => figures::fig10(ctx, out, 'b'),
        "fig10c" => figures::fig10(ctx, out, 'c'),
        "fig10d" => figures::fig10(ctx, out, 'd'),
        "fig11a" => figures::fig11a(ctx, out),
        "fig11b" => figures::fig11b(ctx, out),
        "fig12" => figures::fig12(ctx, out),
        "fig13a" => figures::fig13a(ctx, out),
        "fig13b" => figures::fig13b(ctx, out),
        other => anyhow::bail!("unknown experiment {other}; see `grip repro --list`"),
    }
}
