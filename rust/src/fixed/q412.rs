//! Q4.12 saturating fixed-point scalar (paper Sec. V-D: "the input is
//! first converted to a 16-bit fixed point representation with 4-bits of
//! integer precision").

/// A 16-bit fixed-point value: 1 sign + 3 integer + 12 fractional bits,
/// range [-8.0, 8.0), resolution 2^-12. All arithmetic saturates, as the
/// ASIC datapath does.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub struct Fx16(pub i16);

pub const FRAC_BITS: u32 = 12;
const ONE: i32 = 1 << FRAC_BITS;

impl Fx16 {
    pub const MAX: Fx16 = Fx16(i16::MAX);
    pub const MIN: Fx16 = Fx16(i16::MIN);
    pub const ZERO: Fx16 = Fx16(0);

    /// Convert from f32 with round-to-nearest and saturation.
    pub fn from_f32(x: f32) -> Self {
        if x.is_nan() {
            return Fx16::ZERO;
        }
        let scaled = (x as f64 * ONE as f64).round();
        Fx16(scaled.clamp(i16::MIN as f64, i16::MAX as f64) as i16)
    }

    pub fn to_f32(self) -> f32 {
        self.0 as f32 / ONE as f32
    }

    pub fn from_raw(raw: i16) -> Self {
        Fx16(raw)
    }

    /// Saturating addition (the reduce lanes' adder).
    pub fn sat_add(self, other: Fx16) -> Fx16 {
        Fx16(self.0.saturating_add(other.0))
    }

    /// Saturating subtraction.
    pub fn sat_sub(self, other: Fx16) -> Fx16 {
        Fx16(self.0.saturating_sub(other.0))
    }

    /// Saturating multiply: 16×16 → 32-bit product, rounded arithmetic
    /// shift back to Q4.12, saturate (the PE array's multiplier).
    pub fn sat_mul(self, other: Fx16) -> Fx16 {
        let prod = self.0 as i32 * other.0 as i32;
        // round-to-nearest on the truncated fraction
        let rounded = (prod + (1 << (FRAC_BITS - 1))) >> FRAC_BITS;
        Fx16(rounded.clamp(i16::MIN as i32, i16::MAX as i32) as i16)
    }

    /// Fused multiply into a 32-bit accumulator (the PE column reduction
    /// tree accumulates wider than the storage format).
    pub fn mac_into(self, other: Fx16, acc: i64) -> i64 {
        acc + (self.0 as i64 * other.0 as i64)
    }

    /// Collapse a 32/64-bit accumulator back to Q4.12 with saturation.
    pub fn from_acc(acc: i64) -> Fx16 {
        let rounded = (acc + (1 << (FRAC_BITS - 1))) >> FRAC_BITS;
        Fx16(rounded.clamp(i16::MIN as i64, i16::MAX as i64) as i16)
    }

    pub fn relu(self) -> Fx16 {
        if self.0 < 0 {
            Fx16::ZERO
        } else {
            self
        }
    }

    pub fn max(self, other: Fx16) -> Fx16 {
        if self.0 >= other.0 {
            self
        } else {
            other
        }
    }

    pub fn is_negative(self) -> bool {
        self.0 < 0
    }
}

/// Dot product through the PE array model: wide accumulate, one collapse.
pub fn dot(a: &[Fx16], b: &[Fx16]) -> Fx16 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc: i64 = 0;
    for (x, y) in a.iter().zip(b.iter()) {
        acc = x.mac_into(*y, acc);
    }
    Fx16::from_acc(acc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants() {
        assert_eq!(Fx16::from_f32(1.0).0, 4096);
        assert_eq!(Fx16::from_f32(-1.0).0, -4096);
        assert_eq!(Fx16::from_f32(0.0).0, 0);
    }

    #[test]
    fn saturation_bounds() {
        assert_eq!(Fx16::from_f32(100.0), Fx16::MAX);
        assert_eq!(Fx16::from_f32(-100.0), Fx16::MIN);
        assert_eq!(Fx16::MAX.sat_add(Fx16::from_f32(1.0)), Fx16::MAX);
        assert_eq!(Fx16::MIN.sat_sub(Fx16::from_f32(1.0)), Fx16::MIN);
    }

    #[test]
    fn mul_identity_and_sign() {
        let x = Fx16::from_f32(2.5);
        let one = Fx16::from_f32(1.0);
        assert_eq!(x.sat_mul(one), x);
        let y = Fx16::from_f32(-2.0);
        assert!((x.sat_mul(y).to_f32() + 5.0).abs() < 2e-3);
    }

    #[test]
    fn mul_saturates() {
        let big = Fx16::from_f32(7.9);
        assert_eq!(big.sat_mul(big), Fx16::MAX);
        let neg = Fx16::from_f32(-7.9);
        assert_eq!(big.sat_mul(neg), Fx16::MIN);
    }

    #[test]
    fn dot_matches_float_within_quantization() {
        let a: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) / 40.0).collect();
        let b: Vec<f32> = (0..64).map(|i| ((i * 7 % 13) as f32 - 6.0) / 10.0).collect();
        let fa: Vec<Fx16> = a.iter().map(|&x| Fx16::from_f32(x)).collect();
        let fb: Vec<Fx16> = b.iter().map(|&x| Fx16::from_f32(x)).collect();
        let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let got = dot(&fa, &fb).to_f32();
        // error bound: n * eps * max|b| + collapse rounding
        assert!((want - got).abs() < 0.02, "{want} vs {got}");
    }

    #[test]
    fn nan_maps_to_zero() {
        assert_eq!(Fx16::from_f32(f32::NAN), Fx16::ZERO);
    }

    #[test]
    fn relu_and_max() {
        assert_eq!(Fx16::from_f32(-3.0).relu(), Fx16::ZERO);
        let a = Fx16::from_f32(1.0);
        let b = Fx16::from_f32(2.0);
        assert_eq!(a.max(b), b);
    }
}
