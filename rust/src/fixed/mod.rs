//! GRIP's 16-bit fixed-point datapath (paper Sec. V-D, Sec. VII).
//!
//! The ASIC computes in 16-bit fixed point with 4 bits of integer
//! precision (Q4.12: 1 sign, 3 integer, 12 fractional bits). This module
//! is the *bit-exact functional* model of that datapath — saturating
//! arithmetic, the programmable activate PE (ReLU + two-level LUT), and
//! vector helpers used by the functional simulator. Validated against the
//! float path (PJRT execution of the JAX models) in integration tests.

mod lut;
mod q412;

pub use lut::{LutConfig, OverflowMode, TwoLevelLut};
pub use q412::{dot, Fx16};

/// Element-wise ReLU over a fixed-point vector (the activate PE's cheap
/// mode).
pub fn relu_vec(xs: &mut [Fx16]) {
    for x in xs.iter_mut() {
        *x = x.relu();
    }
}

/// Quantize an f32 slice into the datapath format.
pub fn quantize(xs: &[f32]) -> Vec<Fx16> {
    xs.iter().map(|&x| Fx16::from_f32(x)).collect()
}

/// Dequantize back to f32 (for comparisons against the PJRT path).
pub fn dequantize(xs: &[Fx16]) -> Vec<f32> {
    xs.iter().map(|x| x.to_f32()).collect()
}

/// Worst-case quantization error of the format (half a ULP for values in
/// range).
pub const QUANT_EPS: f32 = 1.0 / 4096.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_roundtrip_small_values() {
        let xs = [0.0f32, 0.5, -0.5, 1.25, -3.999, 7.9, -8.0];
        let q = quantize(&xs);
        let back = dequantize(&q);
        for (a, b) in xs.iter().zip(back.iter()) {
            assert!((a - b).abs() <= QUANT_EPS, "{a} -> {b}");
        }
    }

    #[test]
    fn relu_vec_zeroes_negatives() {
        let mut q = quantize(&[-1.0, 2.0, -0.25, 0.0]);
        relu_vec(&mut q);
        let back = dequantize(&q);
        assert_eq!(back[0], 0.0);
        assert!(back[1] > 1.99);
        assert_eq!(back[2], 0.0);
    }
}
