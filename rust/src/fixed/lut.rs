//! The activate PE's two-level configurable lookup table (paper Sec. V-D).
//!
//! Level 1 has 33 entries covering [-2^a, 2^a]; level 2 has 9 entries
//! covering the wider [-2^b, 2^b]. An input inside level 1's range is
//! linearly interpolated between its two nearest entries; otherwise level
//! 2 is checked; otherwise the configured overflow behaviour applies —
//! clamp to the closest level-2 value or evaluate a user linear function —
//! independently for positive and negative inputs (enabling asymmetric
//! activations).

use super::q412::Fx16;

pub const L1_ENTRIES: usize = 33;
pub const L2_ENTRIES: usize = 9;

/// Overflow behaviour beyond level 2's range, configured per sign.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OverflowMode {
    /// Clamp to the closest (outermost) level-2 entry.
    Clamp,
    /// Evaluate `y = slope * x + offset` in fixed point.
    Linear { slope: Fx16, offset: Fx16 },
}

/// Host-side LUT programming (what the control unit writes into the PE).
#[derive(Debug, Clone)]
pub struct LutConfig {
    /// Level-1 half-range exponent: covers [-2^a, 2^a].
    pub a: i32,
    /// Level-2 half-range exponent: covers [-2^b, 2^b]; b >= a.
    pub b: i32,
    pub level1: [Fx16; L1_ENTRIES],
    pub level2: [Fx16; L2_ENTRIES],
    pub pos_overflow: OverflowMode,
    pub neg_overflow: OverflowMode,
}

impl LutConfig {
    /// Program the LUT by sampling `f` on both levels' grids — how the
    /// host driver fills the tables for an arbitrary activation.
    pub fn from_fn(a: i32, b: i32, f: impl Fn(f32) -> f32, pos: OverflowMode, neg: OverflowMode) -> Self {
        assert!(b >= a, "level 2 must cover level 1");
        let mut level1 = [Fx16::ZERO; L1_ENTRIES];
        let mut level2 = [Fx16::ZERO; L2_ENTRIES];
        let r1 = 2f32.powi(a);
        let r2 = 2f32.powi(b);
        for (i, e) in level1.iter_mut().enumerate() {
            let x = -r1 + 2.0 * r1 * i as f32 / (L1_ENTRIES - 1) as f32;
            *e = Fx16::from_f32(f(x));
        }
        for (i, e) in level2.iter_mut().enumerate() {
            let x = -r2 + 2.0 * r2 * i as f32 / (L2_ENTRIES - 1) as f32;
            *e = Fx16::from_f32(f(x));
        }
        Self { a, b, level1, level2, pos_overflow: pos, neg_overflow: neg }
    }

    /// Sigmoid programming used by G-GCN (paper: "including sigmoid,
    /// which is required for models such as G-GCN"). Saturates to 1/0
    /// outside ±8.
    pub fn sigmoid() -> Self {
        Self::from_fn(
            1,
            3,
            |x| 1.0 / (1.0 + (-x).exp()),
            OverflowMode::Clamp,
            OverflowMode::Clamp,
        )
    }

    /// Tanh programming (symmetric clamp).
    pub fn tanh() -> Self {
        Self::from_fn(0, 2, |x| x.tanh(), OverflowMode::Clamp, OverflowMode::Clamp)
    }

    /// Leaky-ReLU programming — exercises the asymmetric linear overflow
    /// path (positive side is identity-like, negative side a small slope).
    pub fn leaky_relu(alpha: f32) -> Self {
        Self::from_fn(
            1,
            2,
            move |x| if x >= 0.0 { x } else { alpha * x },
            OverflowMode::Linear { slope: Fx16::from_f32(1.0), offset: Fx16::ZERO },
            OverflowMode::Linear { slope: Fx16::from_f32(alpha), offset: Fx16::ZERO },
        )
    }
}

/// The hardware unit: evaluates a programmed `LutConfig` on Q4.12 inputs.
#[derive(Debug, Clone)]
pub struct TwoLevelLut {
    cfg: LutConfig,
}

impl TwoLevelLut {
    pub fn new(cfg: LutConfig) -> Self {
        Self { cfg }
    }

    /// Interpolate within one level's table. `half_range` is 2^exp.
    fn interp(table: &[Fx16], half_range: f32, x: f32) -> Fx16 {
        let n = table.len() - 1;
        // map x in [-r, r] to [0, n]
        let t = (x + half_range) / (2.0 * half_range) * n as f32;
        let i = (t.floor() as usize).min(n - 1);
        let frac = Fx16::from_f32(t - i as f32);
        let lo = table[i];
        let hi = table[i + 1];
        // lo + frac * (hi - lo), all in the datapath format
        lo.sat_add(frac.sat_mul(hi.sat_sub(lo)))
    }

    /// Evaluate one input (already quantized, as the datapath receives it).
    pub fn eval(&self, x: Fx16) -> Fx16 {
        let xf = x.to_f32();
        let r1 = 2f32.powi(self.cfg.a);
        let r2 = 2f32.powi(self.cfg.b);
        if xf.abs() <= r1 {
            Self::interp(&self.cfg.level1, r1, xf)
        } else if xf.abs() <= r2 {
            Self::interp(&self.cfg.level2, r2, xf)
        } else {
            let mode = if xf > 0.0 { self.cfg.pos_overflow } else { self.cfg.neg_overflow };
            match mode {
                OverflowMode::Clamp => {
                    if xf > 0.0 {
                        self.cfg.level2[L2_ENTRIES - 1]
                    } else {
                        self.cfg.level2[0]
                    }
                }
                OverflowMode::Linear { slope, offset } => slope.sat_mul(x).sat_add(offset),
            }
        }
    }

    pub fn eval_f32(&self, x: f32) -> f32 {
        self.eval(Fx16::from_f32(x)).to_f32()
    }

    pub fn eval_vec(&self, xs: &mut [Fx16]) {
        for x in xs.iter_mut() {
            *x = self.eval(*x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn max_err(lut: &TwoLevelLut, f: impl Fn(f32) -> f32, lo: f32, hi: f32) -> f32 {
        let mut worst = 0f32;
        let n = 400;
        for i in 0..=n {
            let x = lo + (hi - lo) * i as f32 / n as f32;
            let err = (lut.eval_f32(x) - f(x)).abs();
            worst = worst.max(err);
        }
        worst
    }

    #[test]
    fn sigmoid_accuracy_level1() {
        let lut = TwoLevelLut::new(LutConfig::sigmoid());
        let e = max_err(&lut, |x| 1.0 / (1.0 + (-x).exp()), -2.0, 2.0);
        assert!(e < 0.01, "level-1 sigmoid err {e}");
    }

    #[test]
    fn sigmoid_accuracy_level2_coarser() {
        let lut = TwoLevelLut::new(LutConfig::sigmoid());
        let e = max_err(&lut, |x| 1.0 / (1.0 + (-x).exp()), -8.0, 8.0);
        assert!(e < 0.05, "level-2 sigmoid err {e}");
    }

    #[test]
    fn sigmoid_saturates_beyond_level2() {
        let lut = TwoLevelLut::new(LutConfig::sigmoid());
        assert!((lut.eval_f32(7.99) - 1.0).abs() < 0.01);
        assert!(lut.eval_f32(-7.99).abs() < 0.01);
    }

    #[test]
    fn tanh_accuracy() {
        let lut = TwoLevelLut::new(LutConfig::tanh());
        let e = max_err(&lut, |x| x.tanh(), -1.0, 1.0);
        assert!(e < 0.01, "tanh err {e}");
    }

    #[test]
    fn leaky_relu_asymmetric_overflow() {
        let lut = TwoLevelLut::new(LutConfig::leaky_relu(0.1));
        // Beyond level-2 range (±4): linear overflow, different per sign.
        assert!((lut.eval_f32(6.0) - 6.0).abs() < 0.02);
        assert!((lut.eval_f32(-6.0) + 0.6).abs() < 0.02);
    }

    #[test]
    fn interpolation_hits_table_points() {
        // At exact grid points the output equals the sampled function.
        let lut = TwoLevelLut::new(LutConfig::sigmoid());
        let r1 = 2.0f32;
        for i in 0..L1_ENTRIES {
            let x = -r1 + 2.0 * r1 * i as f32 / (L1_ENTRIES - 1) as f32;
            let want = 1.0 / (1.0 + (-x).exp());
            assert!((lut.eval_f32(x) - want).abs() < 3e-3, "i={i}");
        }
    }
}
