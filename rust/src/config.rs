//! Architectural and model configuration (paper Table II + Sec. VII).
//!
//! `GripConfig::paper()` is the 28 nm implementation evaluated in the paper;
//! every repro experiment perturbs one or more of these fields. All
//! bandwidth/latency fields are expressed in hardware-native units (bytes
//! per cycle, cycles) at `freq_ghz` so sweeps stay self-consistent.


/// Architectural parameters of the GRIP accelerator (Table II).
#[derive(Debug, Clone)]
pub struct GripConfig {
    /// Core clock, GHz (paper: 1.0).
    pub freq_ghz: f64,

    // ------------------------------------------------------------- DRAM
    /// Number of DDR4-2400 channels (paper: 4, 76.8 GiB/s total).
    pub dram_channels: usize,
    /// Per-channel bandwidth in bytes/cycle at `freq_ghz`
    /// (DDR4-2400 = 19.2 GB/s = 19.2 B/cycle at 1 GHz).
    pub dram_ch_bytes_per_cycle: f64,
    /// Fixed cycles of latency for a random row activation — charged per
    /// non-contiguous feature-vector fetch (Sec. VIII-D: small features
    /// underutilize DRAM).
    pub dram_random_penalty_cycles: f64,
    /// Burst granularity of one channel-pair interface in bytes (paper
    /// Sec. VIII-D: two dual-channel controllers, 64 × 2-byte elements).
    pub dram_interface_bytes: usize,

    // ---------------------------------------------------------- datapath
    /// Element width (16-bit fixed point).
    pub elem_bytes: usize,
    /// Prefetch lanes in the edge unit (paper sets = DRAM channels).
    pub prefetch_lanes: usize,
    /// Reduce lanes in the edge unit.
    pub reduce_lanes: usize,
    /// Crossbar port width, in elements per cycle per gather unit.
    pub xbar_width_elems: usize,
    /// PE array rows (feature/contraction dimension; paper: 16).
    pub pe_rows: usize,
    /// PE array columns (output dimension; paper: 32).
    pub pe_cols: usize,
    /// Pipeline fill latency of one matrix-vector op through the
    /// broadcast/reduce-tree array (paper Sec. V-C: 6 cycles, vs 48 for a
    /// systolic array of the same shape).
    pub pe_fill_cycles: u64,
    /// Update unit throughput, elements per cycle.
    pub update_elems_per_cycle: usize,

    // ------------------------------------------------------------- SRAM
    /// Global weight buffer bytes (paper: 2 MiB).
    pub weight_buf_bytes: usize,
    /// Bandwidth from the global weight buffer into the tile buffer,
    /// bytes/cycle (paper Fig. 10b knee: 128 GiB/s = 128 B/cycle).
    pub weight_bw_bytes_per_cycle: f64,
    /// Tile buffer bytes (paper: 2 × 64 KiB, double buffered).
    pub tile_buf_bytes: usize,
    /// Nodeflow buffer bytes (paper: 4 × 20 KiB).
    pub nodeflow_buf_bytes: usize,

    // ---------------------------------------------------- vertex tiling
    /// Vertex-tiling enabled (paper Sec. VI-B).
    pub vertex_tiling: bool,
    /// Vertices per tile (paper M; best ≈ max output vertices = 11).
    pub tile_m: usize,
    /// Edge-accumulator features per tile (paper F; best ≈ 64).
    pub tile_f: usize,

    // ----------------------------------------------------- partitioning
    /// Input vertices per partition chunk (paper N).
    pub part_inputs: usize,
    /// Output vertices per partition chunk (paper M).
    pub part_outputs: usize,

    // ------------------------------------------------- pipelining knobs
    /// Cache partition feature data in the nodeflow buffer across columns
    /// (Fig. 13a "caching": 1.3×).
    pub cache_features: bool,
    /// Overlap off-chip loads with edge-accumulate across partitions
    /// (Fig. 13a "pipelining": additional 1.3×).
    pub pipeline_partitions: bool,
    /// Preload next layer's weights / tile buffer while processing the
    /// last column (Fig. 13a "weights": total 2.5×).
    pub preload_weights: bool,
    /// Pipeline the update unit with the vertex unit (Fig. 9a: 1.02×).
    pub pipeline_update: bool,
    /// Separate weight and nodeflow SRAMs (Fig. 9a: merged SRAM is the
    /// CPU-like baseline; splitting gives 2.8×).
    pub split_srams: bool,
    /// Dedicated units allow load/edge/vertex phase overlap (Fig. 9a
    /// edge-unit step, 2.97× component). Disabled in the CPU-like
    /// baseline where one core does everything.
    pub overlap_phases: bool,
}

impl GripConfig {
    /// The paper's 28 nm implementation (Table II).
    pub fn paper() -> Self {
        Self {
            freq_ghz: 1.0,
            dram_channels: 4,
            dram_ch_bytes_per_cycle: 19.2,
            dram_random_penalty_cycles: 30.0,
            dram_interface_bytes: 128,
            elem_bytes: 2,
            prefetch_lanes: 4,
            reduce_lanes: 8,
            xbar_width_elems: 16,
            pe_rows: 16,
            pe_cols: 32,
            pe_fill_cycles: 6,
            update_elems_per_cycle: 32,
            weight_buf_bytes: 2 << 20,
            weight_bw_bytes_per_cycle: 128.0,
            tile_buf_bytes: 2 * 64 << 10,
            nodeflow_buf_bytes: 4 * 20 << 10,
            vertex_tiling: true,
            tile_m: 11,
            tile_f: 64,
            part_inputs: 256,
            part_outputs: 11,
            cache_features: true,
            pipeline_partitions: true,
            preload_weights: true,
            pipeline_update: true,
            split_srams: true,
            overlap_phases: true,
        }
    }

    /// Total off-chip bandwidth in bytes/cycle.
    pub fn dram_bytes_per_cycle(&self) -> f64 {
        self.dram_channels as f64 * self.dram_ch_bytes_per_cycle
    }

    /// Total off-chip bandwidth in GiB/s.
    pub fn dram_gib_s(&self) -> f64 {
        self.dram_bytes_per_cycle() * self.freq_ghz * 1e9 / (1u64 << 30) as f64
    }

    /// Peak MACs per cycle of the PE array.
    pub fn macs_per_cycle(&self) -> u64 {
        (self.pe_rows * self.pe_cols) as u64
    }

    /// Peak arithmetic throughput in TOP/s (1 MAC = 2 ops; paper reports
    /// 1.088 TOP/s for the 16×32 array plus edge/update ALUs).
    pub fn peak_tops(&self) -> f64 {
        2.0 * self.macs_per_cycle() as f64 * self.freq_ghz / 1e3
    }

    /// Convert a cycle count to microseconds at this clock.
    pub fn cycles_to_us(&self, cycles: f64) -> f64 {
        cycles / (self.freq_ghz * 1e3)
    }

    /// Effective vertex-tiling parameters: with tiling disabled the edge
    /// accumulator must hold full feature vectors for every output vertex
    /// of a chunk (HyGCN-style), i.e. m = 1 weight-reuse and f = full.
    pub fn effective_tile(&self, full_f: usize) -> (usize, usize) {
        if self.vertex_tiling {
            (self.tile_m.max(1), self.tile_f.min(full_f).max(1))
        } else {
            (1, full_f.max(1))
        }
    }

    /// Edge-accumulator tile bytes (paper: 1.5 KiB at m=11, f=64 16-bit).
    pub fn edge_acc_tile_bytes(&self, full_f: usize) -> usize {
        let (m, f) = self.effective_tile(full_f);
        m * f * self.elem_bytes
    }
}

impl Default for GripConfig {
    fn default() -> Self {
        Self::paper()
    }
}

/// GNN model hyper-parameters shared by the whole evaluation
/// (paper Sec. VII: 2 layers, samples 25/10, dims 602 → 512 → 256).
#[derive(Debug, Clone, Copy)]
pub struct ModelConfig {
    pub sample1: usize,
    pub sample2: usize,
    pub f_in: usize,
    pub f_hid: usize,
    pub f_out: usize,
}

impl ModelConfig {
    pub fn paper() -> Self {
        Self { sample1: 25, sample2: 10, f_in: 602, f_hid: 512, f_out: 256 }
    }

    /// Per-layer (fan-in sample, input dim, output dim), outermost first.
    pub fn layers(&self) -> [(usize, usize, usize); 2] {
        [
            (self.sample1, self.f_in, self.f_hid),
            (self.sample2, self.f_hid, self.f_out),
        ]
    }
}

impl Default for ModelConfig {
    fn default() -> Self {
        Self::paper()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_matches_table2() {
        let c = GripConfig::paper();
        // 4× DDR4-2400 = 76.8 GB/s ≈ 71.5 GiB/s
        assert!((c.dram_bytes_per_cycle() - 76.8).abs() < 1e-9);
        // 16×32 MACs at 1 GHz ≈ 1.02 TMAC/s → ~1.05 TOP/s (paper: 1.088
        // including edge/update ALUs).
        assert!((c.peak_tops() - 1.024).abs() < 1e-9);
        assert_eq!(c.weight_buf_bytes, 2 * 1024 * 1024);
        assert_eq!(c.nodeflow_buf_bytes, 80 * 1024);
        assert_eq!(c.tile_buf_bytes, 128 * 1024);
    }

    #[test]
    fn edge_acc_tile_is_small_with_tiling() {
        let c = GripConfig::paper();
        // Paper Sec. VIII-F: ~1.5 KiB vs HyGCN's 16 MB buffer.
        assert_eq!(c.edge_acc_tile_bytes(512), 11 * 64 * 2);
        let mut no_tile = c.clone();
        no_tile.vertex_tiling = false;
        assert!(no_tile.edge_acc_tile_bytes(512) > c.edge_acc_tile_bytes(512) / 11);
    }

    #[test]
    fn cycles_to_us_roundtrip() {
        let c = GripConfig::paper();
        assert!((c.cycles_to_us(1000.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn model_config_layers() {
        let m = ModelConfig::paper();
        assert_eq!(m.layers()[0], (25, 602, 512));
        assert_eq!(m.layers()[1], (10, 512, 256));
    }
}
