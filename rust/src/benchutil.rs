//! Minimal benchmarking harness (criterion is unavailable in the
//! offline vendored crate set). Measures wall time over warmup +
//! measured iterations and prints mean / min / p99-style max, which is
//! what the perf pass (EXPERIMENTS.md §Perf) records.

use std::time::Instant;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub min_us: f64,
    pub max_us: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>10.2} µs/iter (min {:>9.2}, max {:>9.2}, n={})",
            self.name, self.mean_us, self.min_us, self.max_us, self.iters
        );
    }
}

/// Run `f` for `warmup` + `iters` iterations and report per-iteration
/// wall time. `f` should return something observable to prevent the
/// optimizer from deleting the work (its result is black-boxed).
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0f64, f64::max);
    let r = BenchResult { name: name.to_string(), iters, mean_us: mean, min_us: min, max_us: max };
    r.print();
    r
}

/// Optimizer barrier (std::hint::black_box re-export for stable use).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 2, 10, || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.mean_us >= 0.0);
        assert!(r.min_us <= r.mean_us && r.mean_us <= r.max_us + 1e-9);
        assert_eq!(r.iters, 10);
    }
}
