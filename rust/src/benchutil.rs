//! Minimal benchmarking harness (criterion is unavailable in the
//! offline vendored crate set). Measures wall time over warmup +
//! measured iterations and prints mean / min / p99-style max, which is
//! what the perf pass (EXPERIMENTS.md §Perf) records.

use std::time::Instant;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub min_us: f64,
    pub max_us: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>10.2} µs/iter (min {:>9.2}, max {:>9.2}, n={})",
            self.name, self.mean_us, self.min_us, self.max_us, self.iters
        );
    }
}

/// Run `f` for `warmup` + `iters` iterations and report per-iteration
/// wall time. `f` should return something observable to prevent the
/// optimizer from deleting the work (its result is black-boxed).
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0f64, f64::max);
    let r = BenchResult { name: name.to_string(), iters, mean_us: mean, min_us: min, max_us: max };
    r.print();
    r
}

/// Optimizer barrier (std::hint::black_box re-export for stable use).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Serialize benchmark sections to a JSON file so perf trajectories are
/// tracked in-repo (`BENCH_serve.json` at the repo root; no serde in
/// the offline vendored crate set, so the emitter is hand-rolled).
///
/// Output shape: `{"section": {"metric": 1.23, ...}, ...}` with keys in
/// the given order. Non-finite values are written as `null`. Generic
/// over the key types so callers can mix static labels with the
/// per-partition keys (`part{i}_hit_rate`, ...) a partitioned serve
/// report generates at runtime.
pub fn write_bench_json<S: AsRef<str>, K: AsRef<str>>(
    path: &std::path::Path,
    sections: &[(S, Vec<(K, f64)>)],
) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{{")?;
    for (si, (section, metrics)) in sections.iter().enumerate() {
        writeln!(f, "  {:?}: {{", section.as_ref())?;
        for (mi, (name, value)) in metrics.iter().enumerate() {
            let comma = if mi + 1 < metrics.len() { "," } else { "" };
            if value.is_finite() {
                writeln!(f, "    {:?}: {:.3}{}", name.as_ref(), value, comma)?;
            } else {
                writeln!(f, "    {:?}: null{}", name.as_ref(), comma)?;
            }
        }
        let comma = if si + 1 < sections.len() { "," } else { "" };
        writeln!(f, "  }}{}", comma)?;
    }
    writeln!(f, "}}")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_bench_json_parses_back() {
        let dir = std::env::temp_dir().join("grip_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bench.json");
        write_bench_json(
            &path,
            &[
                ("serve", vec![("throughput_rps", 123.456), ("p99_us", 7.0)]),
                ("exec", vec![("speedup", f64::NAN)]),
            ],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let json = crate::runtime::json::parse(&text).unwrap();
        let serve = json.get("serve").unwrap();
        let tput = serve.get("throughput_rps").unwrap().as_f64().unwrap();
        assert!((tput - 123.456).abs() < 1e-9);
        assert_eq!(serve.get("p99_us").unwrap().as_f64(), Some(7.0));
        assert_eq!(
            json.get("exec").unwrap().get("speedup"),
            Some(&crate::runtime::json::Json::Null)
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 2, 10, || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.mean_us >= 0.0);
        assert!(r.min_us <= r.mean_us && r.mean_us <= r.max_us + 1e-9);
        assert_eq!(r.iters, 10);
    }
}
