//! GPU (Nvidia P100) latency model.
//!
//! Paper Sec. VIII-A attributes GPU inference latency to exactly three
//! terms, which we model directly:
//! 1. host→device embedding transfer: "roughly 200–500 µs, depending on
//!    the neighborhood size" (25–50% of total for GCN);
//! 2. kernel-launch / framework-dispatch overhead, dominating at batch
//!    size 1 ("the overhead of launching each kernel tends to
//!    dominate");
//! 3. low-utilization compute.
//!
//! Kernel counts are derived from plan structure (launches ≈ framework
//! ops ≈ 8 + one per GReTA program), which reproduces the per-model
//! counts previously hardcoded for the four presets (GCN 10, GIN 12,
//! SAGE 14, G-GCN 16) and extends to arbitrary specs.

use crate::greta::ModelPlan;

#[derive(Debug, Clone, Copy)]
pub struct GpuModel {
    /// PCIe transfer base cost (µs).
    pub transfer_base_us: f64,
    /// Transfer cost per unique neighbor row (µs).
    pub transfer_per_vertex_us: f64,
    /// Kernel launches per inference (ops per layer × layers).
    pub kernels: usize,
    /// Per-kernel launch + dispatch overhead (µs).
    pub launch_us: f64,
    /// Effective compute throughput at batch-1 occupancy (GFLOP/s).
    pub eff_gflops: f64,
}

impl GpuModel {
    /// Launch counts follow the plan's program structure: a fixed
    /// framework floor (gathers, concats, activations) plus one
    /// launch per GReTA program (the TF op it lowers to).
    pub fn for_plan(plan: &ModelPlan) -> Self {
        Self {
            transfer_base_us: 200.0,
            transfer_per_vertex_us: 1.0,
            kernels: 8 + plan.num_programs(),
            launch_us: 70.0,
            eff_gflops: 500.0,
        }
    }

    pub fn latency_us(&self, unique_neighbors: usize, flops: f64) -> f64 {
        let transfer = self.transfer_base_us + self.transfer_per_vertex_us * unique_neighbors as f64;
        let launch = self.kernels as f64 * self.launch_us;
        let compute = flops / (self.eff_gflops * 1e3); // µs
        transfer + launch + compute
    }
}

/// GPU latency for a plan with `u` unique neighbors and `flops` total
/// floating-point work (2 × MACs from the simulator counters).
pub fn gpu_latency_us(plan: &ModelPlan, u: usize, flops: f64) -> f64 {
    GpuModel::for_plan(plan).latency_us(u, flops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::greta::{compile, GnnModel};

    fn plan(m: GnnModel) -> ModelPlan {
        compile(m, &ModelConfig::paper())
    }

    #[test]
    fn kernel_counts_match_pre_redesign_constants() {
        // The hardcoded per-model counts, now derived structurally.
        assert_eq!(GpuModel::for_plan(&plan(GnnModel::Gcn)).kernels, 10);
        assert_eq!(GpuModel::for_plan(&plan(GnnModel::Gin)).kernels, 12);
        assert_eq!(GpuModel::for_plan(&plan(GnnModel::Sage)).kernels, 14);
        assert_eq!(GpuModel::for_plan(&plan(GnnModel::Ggcn)).kernels, 16);
    }

    #[test]
    fn gcn_in_table3_band() {
        // Paper: GCN GPU 813–1388 µs.
        let t = gpu_latency_us(&plan(GnnModel::Gcn), 167, 20e6);
        assert!(t > 700.0 && t < 1600.0, "{t}");
    }

    #[test]
    fn transfer_share_matches_paper() {
        // Sec. VIII-A: transfer is 25–50% of GCN total.
        let m = GpuModel::for_plan(&plan(GnnModel::Gcn));
        let u = 167;
        let total = m.latency_us(u, 20e6);
        let transfer = m.transfer_base_us + m.transfer_per_vertex_us * u as f64;
        let share = transfer / total;
        assert!(share > 0.2 && share < 0.55, "share {share}");
    }

    #[test]
    fn more_kernels_more_latency() {
        let t_gcn = gpu_latency_us(&plan(GnnModel::Gcn), 100, 20e6);
        let t_ggcn = gpu_latency_us(&plan(GnnModel::Ggcn), 100, 200e6);
        assert!(t_ggcn > t_gcn);
    }
}
