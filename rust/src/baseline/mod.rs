//! Baseline performance models (paper Sec. VII "Baseline", VIII-B,
//! VIII-F).
//!
//! * [`cpu`] — the Xeon E5-2690v4 + TF/MKL baseline. The paper measured
//!   real hardware; we fit a documented analytic model to the paper's own
//!   published measurements (Table III + Fig. 12's cache cliff), so our
//!   speedup tables inherit the authors' hardware truth.
//! * [`gpu`] — the P100 baseline: host→device transfer + per-op launch
//!   overhead + low-utilization compute, the three terms the paper's own
//!   analysis attributes GPU latency to (Sec. VIII-A).
//! * [`prior`] — GRIP-simulator reconfigurations for the Sec. VIII-B
//!   breakdown ladder and the Sec. VIII-F prior-work comparisons
//!   (HyGCN-like, TPU+, Graphicionado-like), exactly the paper's method.
//! * [`roofline`] — the Fig. 2 CPU roofline/measured-performance model.

mod cpu;
mod gpu;
mod prior;
mod roofline;

pub use cpu::{cpu_latency_us, CpuModel};
pub use gpu::{gpu_latency_us, GpuModel};
pub use prior::{baseline_ladder, breakdown_step, prior_work_configs, PriorWork};
pub use roofline::{cpu_roofline_point, RooflinePoint};
