//! Prior-work and breakdown configurations (paper Sec. VIII-B, VIII-F).
//!
//! The paper evaluates every alternative architecture by reconfiguring
//! its own cycle simulator; each function here returns the corresponding
//! [`GripConfig`] perturbation.

use crate::config::GripConfig;

/// The Sec. VIII-B "baseline configuration": GRIP degraded until it
/// emulates the CPU's structure — 14 cores as small matmul units, merged
//  SRAM, no inter-unit pipelining.
pub fn cpu_like_baseline() -> GripConfig {
    let mut c = GripConfig::paper();
    c.freq_ghz = 2.6; // CPU clock
    // 14 × (8-wide × 2 SIMD) ≈ one 8×28 MAC array in aggregate.
    c.pe_rows = 8;
    c.pe_cols = 28;
    c.pe_fill_cycles = 12;
    // 14 fetch/gather units, 32-byte crossbar (L2 bandwidth).
    c.prefetch_lanes = 14;
    c.reduce_lanes = 14;
    c.xbar_width_elems = 16; // 32 B / 2 B elements
    // Merged weight + nodeflow SRAM behind a single L3-like stream port
    // (16 B/cycle at 2.6 GHz ≈ 41.6 GB/s). Weights are re-streamed per
    // vertex (no tiling), which is what makes this configuration ~230 µs
    // for GCN — matching the paper's statement that its baseline sim is
    // 2.07× faster than the measured 477 µs CPU.
    c.split_srams = false;
    c.weight_bw_bytes_per_cycle = 16.0;
    // No dedicated units: no phase overlap, no partition pipelining.
    c.overlap_phases = false;
    c.pipeline_partitions = false;
    c.pipeline_update = false;
    c.preload_weights = false;
    c.cache_features = true;
    // CPU-style full-vector accumulation (no vertex-tiling).
    c.vertex_tiling = false;
    c
}

/// One step of the Fig. 9a ladder, cumulative from the baseline:
/// 0 = baseline, 1 = +split SRAMs, 2 = +edge unit, 3 = +vertex unit,
/// 4 = +pipelined update unit (= full GRIP).
pub fn breakdown_step(step: usize) -> GripConfig {
    let paper = GripConfig::paper();
    let mut c = cpu_like_baseline();
    if step >= 1 {
        // Split weight/nodeflow SRAMs: removes contention (the /2 in the
        // vertex-unit model) and doubles the dedicated weight bandwidth
        // (paper: 2.0× and 1.4× components of the 2.8× step).
        c.split_srams = true;
        c.weight_bw_bytes_per_cycle = 32.0;
    }
    if step >= 2 {
        // Dedicated edge unit: restore lanes/crossbar and let load,
        // edge-accumulate and vertex-accumulate overlap.
        c.prefetch_lanes = paper.prefetch_lanes;
        c.reduce_lanes = paper.reduce_lanes;
        c.xbar_width_elems = paper.xbar_width_elems;
        c.overlap_phases = true;
        c.pipeline_partitions = true;
        c.cache_features = true;
        c.preload_weights = true;
    }
    if step >= 3 {
        // Single 16×32 vertex unit at 1 GHz with vertex-tiling and the
        // full on-chip weight path.
        c.weight_bw_bytes_per_cycle = paper.weight_bw_bytes_per_cycle;
        c.freq_ghz = paper.freq_ghz;
        c.pe_rows = paper.pe_rows;
        c.pe_cols = paper.pe_cols;
        c.pe_fill_cycles = paper.pe_fill_cycles;
        c.vertex_tiling = true;
        c.tile_m = paper.tile_m;
        c.tile_f = paper.tile_f;
    }
    if step >= 4 {
        // Separate, pipelined update unit.
        c.pipeline_update = true;
    }
    c
}

/// Number of steps in the Fig. 9a ladder (including the baseline).
pub fn baseline_ladder() -> Vec<(&'static str, GripConfig)> {
    vec![
        ("baseline", breakdown_step(0)),
        ("+split srams", breakdown_step(1)),
        ("+edge unit", breakdown_step(2)),
        ("+vertex unit", breakdown_step(3)),
        ("+update unit", breakdown_step(4)),
    ]
}

/// Prior-work architectures as simulator configurations (Sec. VIII-F).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PriorWork {
    /// HyGCN-like: single-issue edge engine (1 fetch/gather unit, 256-
    /// lane SIMD crossbar), full feature vectors accumulated before
    /// vertex ops (no vertex-tiling).
    HyGcn,
    /// TPU-like + GRIP edge unit: 16×32 systolic array (48-cycle fill),
    /// weights streamed from off-chip at a dedicated 30 GiB/s.
    TpuPlus,
    /// Graphicionado-like: per-lane vertex units sharing one tile-buffer
    /// port, no tiling.
    Graphicionado,
}

pub fn prior_work_configs(which: PriorWork) -> GripConfig {
    let mut c = GripConfig::paper();
    match which {
        PriorWork::HyGcn => {
            c.prefetch_lanes = 1;
            c.reduce_lanes = 1;
            c.xbar_width_elems = 256;
            c.vertex_tiling = false;
        }
        PriorWork::TpuPlus => {
            c.prefetch_lanes = 1;
            c.reduce_lanes = 1;
            // Systolic data setup: input skew + drain (paper Sec. V-C:
            // 16 + 32 = 48 cycles vs GRIP's 6).
            c.pe_fill_cycles = 48;
            // Weights off-chip at 30 GiB/s dedicated (original TPU).
            c.weight_bw_bytes_per_cycle = 30.0;
        }
        PriorWork::Graphicionado => {
            c.vertex_tiling = false;
            // Two half-size vertex lanes sharing a single tile-buffer
            // port: same MACs, half the effective weight bandwidth.
            c.weight_bw_bytes_per_cycle /= 2.0;
            c.reduce_lanes = 2;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::graph::Dataset;
    use crate::greta::{compile, GnnModel};
    use crate::nodeflow::{Nodeflow, Sampler};
    use crate::sim::simulate;

    fn cycles(cfg: &GripConfig) -> f64 {
        let mc = ModelConfig::paper();
        let g = Dataset::Pokec.generate(0.002, 3);
        let nf = Nodeflow::build(&g, &Sampler::new(5), &[42], &mc);
        let plan = compile(GnnModel::Gcn, &mc);
        simulate(cfg, &plan, &nf).cycles / cfg.freq_ghz // normalize to ns
    }

    #[test]
    fn ladder_monotonically_improves() {
        let ladder = baseline_ladder();
        let times: Vec<f64> = ladder.iter().map(|(_, c)| cycles(c)).collect();
        for w in times.windows(2) {
            assert!(w[1] <= w[0] * 1.02, "ladder regressed: {times:?}");
        }
        // Full ladder speedup should be large (paper: 2.8×3.4×1.87×1.02
        // ≈ 18×).
        let speedup = times[0] / times[times.len() - 1];
        assert!(speedup > 4.0, "total ladder speedup {speedup}");
    }

    #[test]
    fn grip_beats_all_prior_work() {
        let grip = cycles(&GripConfig::paper());
        for pw in [PriorWork::HyGcn, PriorWork::TpuPlus, PriorWork::Graphicionado] {
            let t = cycles(&prior_work_configs(pw));
            assert!(t > grip, "{pw:?}: {t} vs grip {grip}");
        }
    }

    #[test]
    fn prior_work_still_beats_cpu_baseline() {
        // Fig. 9b: HyGCN-like 4.4×, TPU+ 11.3×, Graphicionado-like 2.4×
        // over the baseline — all should improve on the baseline config.
        let base = cycles(&cpu_like_baseline());
        for pw in [PriorWork::HyGcn, PriorWork::TpuPlus, PriorWork::Graphicionado] {
            let t = cycles(&prior_work_configs(pw));
            assert!(t < base, "{pw:?}: {t} vs baseline {base}");
        }
    }

    #[test]
    fn step4_is_paper_config_shape() {
        let c = breakdown_step(4);
        let p = GripConfig::paper();
        assert_eq!(c.pe_rows, p.pe_rows);
        assert_eq!(c.pe_cols, p.pe_cols);
        assert!(c.vertex_tiling && c.pipeline_update && c.split_srams);
    }
}
