//! CPU (Xeon E5-2690v4, single socket, TF 2.0 + MKL) latency model.
//!
//! The paper measured this baseline on real hardware (Table III) and
//! showed (Sec. II-B, Fig. 12) that latency is dominated by
//! non-computational factors: per-inference framework overhead, random
//! feature gathers, and a cache cliff once the working set spills the
//! per-core L2 (~95 unique neighbors: 95 × 602 floats × 4 B ≈ 229 KB >
//! 256 KiB L2). We therefore model
//!
//!   t = a_model + b_model · U + c_model · max(0, U − U_cliff)
//!
//! with per-model constants fitted to the paper's published
//! measurements. This is the honest substitution (DESIGN.md): GRIP-side
//! numbers come from our simulator; CPU-side numbers come from the
//! authors' hardware, interpolated.
//!
//! Since the `ModelSpec` redesign the entry point is
//! [`CpuModel::for_plan`]: the four paper models select their fitted
//! constants by *plan name* (a calibration lookup, not program
//! structure), and any other plan falls back to a structural estimate
//! extrapolated from the GCN anchor — uncalibrated, but monotone in
//! model size, so custom specs get plausible comparisons instead of a
//! panic.

use crate::greta::ModelPlan;

/// Fitted per-model constants (µs).
#[derive(Debug, Clone, Copy)]
pub struct CpuModel {
    /// Fixed per-inference cost: framework dispatch, weight streaming.
    pub base_us: f64,
    /// Per-unique-neighbor cost below the cache cliff (gathers).
    pub per_vertex_us: f64,
    /// Additional per-neighbor cost past the L2 cliff (Fig. 12b).
    pub cliff_us: f64,
    /// Cliff position in unique 2-hop neighbors (Sec. VIII-D: ~95).
    pub cliff_at: f64,
}

/// (plan name, fitted constants) for the paper's measured models.
const CALIBRATED: [(&str, CpuModel); 4] = [
    ("gcn", CpuModel { base_us: 280.0, per_vertex_us: 0.8, cliff_us: 1.3, cliff_at: 95.0 }),
    ("gin", CpuModel { base_us: 330.0, per_vertex_us: 0.5, cliff_us: 0.9, cliff_at: 95.0 }),
    ("sage", CpuModel { base_us: 1450.0, per_vertex_us: 2.6, cliff_us: 0.8, cliff_at: 95.0 }),
    ("ggcn", CpuModel { base_us: 2250.0, per_vertex_us: 2.4, cliff_us: 0.8, cliff_at: 95.0 }),
];

impl CpuModel {
    /// Constants for a compiled plan: the fitted Table III + Fig. 12
    /// values for the four paper models (by name), or a structural
    /// estimate for custom specs — framework dispatch scales with
    /// program count, the gather term with the number of edge-domain
    /// programs (each re-walks the neighborhood).
    pub fn for_plan(plan: &ModelPlan) -> Self {
        if let Some((_, m)) = CALIBRATED.iter().find(|(name, _)| *name == plan.name) {
            return *m;
        }
        let progs = plan.num_programs() as f64;
        let edge_progs = plan.num_edge_programs().max(1) as f64;
        Self {
            base_us: 140.0 * progs,
            per_vertex_us: 0.8 * edge_progs,
            cliff_us: 1.0,
            cliff_at: 95.0,
        }
    }

    pub fn latency_us(&self, unique_neighbors: usize) -> f64 {
        let u = unique_neighbors as f64;
        self.base_us + self.per_vertex_us * u + self.cliff_us * (u - self.cliff_at).max(0.0)
    }
}

/// Convenience: CPU latency for a plan on a neighborhood of `u` unique
/// vertices.
pub fn cpu_latency_us(plan: &ModelPlan, u: usize) -> f64 {
    CpuModel::for_plan(plan).latency_us(u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::greta::{compile, GnnModel};

    fn plan(m: GnnModel) -> ModelPlan {
        compile(m, &ModelConfig::paper())
    }

    #[test]
    fn table3_ballpark() {
        // Paper Table III CPU runs 309–477 µs for GCN across datasets
        // whose p99 neighborhoods range ~25–300.
        for u in [25, 65, 167, 239] {
            let t = cpu_latency_us(&plan(GnnModel::Gcn), u);
            assert!(t > 250.0 && t < 800.0, "u={u} t={t}");
        }
        // SAGE/GGCN land in the paper's 1.5–2.9 ms band.
        assert!(cpu_latency_us(&plan(GnnModel::Sage), 100) > 1400.0);
        assert!(cpu_latency_us(&plan(GnnModel::Ggcn), 240) < 3500.0);
    }

    #[test]
    fn monotone_in_neighborhood() {
        let m = CpuModel::for_plan(&plan(GnnModel::Gcn));
        assert!(m.latency_us(200) > m.latency_us(100));
        assert!(m.latency_us(100) > m.latency_us(10));
    }

    #[test]
    fn cliff_changes_slope() {
        let m = CpuModel::for_plan(&plan(GnnModel::Gcn));
        let below = m.latency_us(90) - m.latency_us(80);
        let above = m.latency_us(210) - m.latency_us(200);
        assert!(above > 1.5 * below, "slope below {below}, above {above}");
    }

    #[test]
    fn model_ordering() {
        // Table III CPU: GCN ≈ GIN (within ~1.6× either way, the paper
        // has them crossing over by dataset), both far below SAGE, and
        // SAGE < GGCN.
        let u = 167;
        let t = |m| cpu_latency_us(&plan(m), u);
        let ratio = t(GnnModel::Gcn) / t(GnnModel::Gin);
        assert!(ratio > 0.6 && ratio < 1.7, "gcn/gin {ratio}");
        assert!(t(GnnModel::Gin) < t(GnnModel::Sage) / 2.0);
        assert!(t(GnnModel::Sage) < t(GnnModel::Ggcn));
    }

    #[test]
    fn custom_plan_gets_structural_estimate() {
        // A renamed GCN-shaped plan is no longer name-calibrated but
        // still yields a finite, monotone estimate.
        let mut p = plan(GnnModel::Gcn);
        p.name = "my-custom".into();
        let m = CpuModel::for_plan(&p);
        assert!(m.base_us > 0.0 && m.per_vertex_us > 0.0);
        assert!(m.latency_us(200) > m.latency_us(20));
        // More programs → larger dispatch estimate.
        let mut big = plan(GnnModel::Ggcn);
        big.name = "my-custom-2".into();
        assert!(CpuModel::for_plan(&big).base_us > m.base_us);
    }
}
