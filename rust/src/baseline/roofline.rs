//! CPU roofline model for Fig. 2: measured performance vs arithmetic
//! intensity for per-vertex GCN inference, with the LLC-bandwidth gap.

use super::cpu::cpu_latency_us;
use crate::config::ModelConfig;
use crate::greta::{compile, GnnModel};

/// One scatter point of Fig. 2.
#[derive(Debug, Clone, Copy)]
pub struct RooflinePoint {
    /// Unique 2-hop neighbors of the vertex.
    pub neighborhood: usize,
    /// Arithmetic intensity, flop / byte.
    pub ai: f64,
    /// Modeled achieved performance, GFLOP/s.
    pub gflops: f64,
    /// Roofline bound at this AI, GFLOP/s.
    pub roofline: f64,
}

/// Sustained CPU peaks measured by the paper (Sec. VII): 1.084 TFLOP/s
/// matmul, 64.5 GiB/s memory.
pub const CPU_PEAK_GFLOPS: f64 = 1084.0;
pub const CPU_MEM_GIB_S: f64 = 64.5;

/// Flops and bytes of one 2-layer GCN inference over `u` unique
/// neighbors (SpMM form, f32 on CPU).
pub fn gcn_work(u: usize, mc: &ModelConfig) -> (f64, f64) {
    let v1 = 1 + mc.sample2;
    let flops = 2.0
        * ((v1 * u * mc.f_in) as f64            // Â·H layer 1
            + (v1 * mc.f_in * mc.f_hid) as f64  // (Â H)·W1
            + (v1 * mc.f_hid) as f64            // layer-2 Â·H
            + (mc.f_hid * mc.f_out) as f64);    // ·W2
    let bytes = (u * mc.f_in * 4                      // features
        + (mc.f_in * mc.f_hid + mc.f_hid * mc.f_out) * 4 // weights
        + v1 * (mc.f_hid + mc.f_out) * 4) as f64; // intermediates
    (flops, bytes)
}

/// Fig. 2 point for a vertex with `u` unique 2-hop neighbors.
pub fn cpu_roofline_point(u: usize, mc: &ModelConfig) -> RooflinePoint {
    let (flops, bytes) = gcn_work(u, mc);
    let ai = flops / bytes;
    let t_us = cpu_latency_us(&compile(GnnModel::Gcn, mc), u);
    let gflops = flops / (t_us * 1e3);
    let roofline = CPU_PEAK_GFLOPS.min(ai * CPU_MEM_GIB_S * 1.073_741_824);
    RooflinePoint { neighborhood: u, ai, gflops, roofline }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_below_roofline() {
        let mc = ModelConfig::paper();
        for u in [10, 50, 150, 300] {
            let p = cpu_roofline_point(u, &mc);
            assert!(p.gflops < p.roofline, "u={u}: {} !< {}", p.gflops, p.roofline);
        }
    }

    #[test]
    fn gap_grows_with_ai() {
        // Fig. 2: the measured-vs-roofline gap widens at higher AI.
        let mc = ModelConfig::paper();
        let lo = cpu_roofline_point(20, &mc);
        let hi = cpu_roofline_point(300, &mc);
        let gap = |p: &RooflinePoint| p.roofline / p.gflops;
        assert!(gap(&hi) > gap(&lo), "lo {} hi {}", gap(&lo), gap(&hi));
    }

    #[test]
    fn ai_increases_with_reuse() {
        // Larger neighborhoods amortize weights -> higher AI... actually
        // in SpMM form AI *decreases* with u (feature bytes grow faster
        // than flops once weights amortize); just pin monotone behavior.
        let mc = ModelConfig::paper();
        let a = cpu_roofline_point(10, &mc).ai;
        let b = cpu_roofline_point(300, &mc).ai;
        assert!(a != b);
    }

    #[test]
    fn memory_bound_region_exists() {
        let mc = ModelConfig::paper();
        let p = cpu_roofline_point(250, &mc);
        assert!(p.roofline < CPU_PEAK_GFLOPS, "should be bandwidth-bound");
    }
}
