//! Per-event energy constants (28 nm class).
//!
//! Sources / calibration:
//! * DRAM: DDR4 access energy is commonly quoted at 15–40 pJ/bit
//!   device+IO; we use 34.4 pJ/byte (≈4.3 pJ/bit) matching DRAMPower-
//!   style estimates for DDR4-2400 under the paper's access mix, which
//!   reproduces Table IV's 2794.7 mW during a 16 µs GCN inference.
//! * Weight SRAM: Cacti-class 2 MiB SRAM reads cost ~10–15 pJ per
//!   16-bit access at 28 nm including H-tree; 25.7 pJ/byte.
//! * Nodeflow SRAM: small 20 KiB banks, ~2 pJ per access; 4.3 pJ/byte.
//! * 16-bit MAC at 28 nm: ~1–3 pJ including pipeline registers; 2.9 pJ.
//! * Edge/update ALU ops: sub-pJ element operations.

/// Per-event energies in picojoules.
#[derive(Debug, Clone)]
pub struct EnergyParams {
    pub dram_pj_per_byte: f64,
    pub weight_sram_pj_per_byte: f64,
    pub nodeflow_sram_pj_per_byte: f64,
    pub mac_pj: f64,
    pub edge_alu_pj: f64,
    pub update_pj: f64,
}

impl EnergyParams {
    /// Constants calibrated to the paper's Table IV (see module docs).
    pub fn paper() -> Self {
        Self {
            dram_pj_per_byte: 34.4,
            weight_sram_pj_per_byte: 25.7,
            nodeflow_sram_pj_per_byte: 4.3,
            mac_pj: 2.9,
            edge_alu_pj: 0.4,
            update_pj: 1.1,
        }
    }
}

impl Default for EnergyParams {
    fn default() -> Self {
        Self::paper()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_physically_plausible() {
        let p = EnergyParams::paper();
        // DRAM must cost more per byte than any SRAM.
        assert!(p.dram_pj_per_byte > p.weight_sram_pj_per_byte);
        assert!(p.weight_sram_pj_per_byte > p.nodeflow_sram_pj_per_byte);
        // A MAC is more expensive than an ALU element op.
        assert!(p.mac_pj > p.edge_alu_pj);
    }
}
