//! Activity-based energy model (paper Sec. VII + Table IV).
//!
//! The paper estimates power by applying activity factors from the cycle
//! simulator to per-event energies from synthesis (logic), Cacti 6.5
//! (SRAMs), and Ramulator + DRAMPower (DRAM). We reproduce the
//! methodology with per-event energy constants ([`EnergyParams`]) in the
//! range published for 28 nm-class implementations, calibrated so the
//! paper configuration lands near Table IV's breakdown (the calibration
//! is asserted by the `table4` repro experiment, shape-wise).

mod params;

pub use params::EnergyParams;

use crate::config::GripConfig;
use crate::sim::{ActivityCounters, SimResult};

/// Energy and average power per module for one inference.
#[derive(Debug, Clone, Default)]
pub struct PowerBreakdown {
    /// (module, milliwatts) rows in Table IV order.
    pub rows: Vec<(&'static str, f64)>,
    pub total_mw: f64,
    pub total_uj: f64,
}

impl PowerBreakdown {
    pub fn mw(&self, module: &str) -> f64 {
        self.rows.iter().find(|(m, _)| *m == module).map(|(_, v)| *v).unwrap_or(0.0)
    }

    pub fn pct(&self, module: &str) -> f64 {
        if self.total_mw > 0.0 {
            100.0 * self.mw(module) / self.total_mw
        } else {
            0.0
        }
    }
}

/// Per-module energies (µJ) from activity counters.
pub fn energy_uj(p: &EnergyParams, c: &ActivityCounters) -> Vec<(&'static str, f64)> {
    vec![
        ("edge", c.edge_alu_ops as f64 * p.edge_alu_pj * 1e-6),
        ("vertex", c.macs as f64 * p.mac_pj * 1e-6),
        ("update", c.update_elems as f64 * p.update_pj * 1e-6),
        ("weight-sram", c.weight_sram_bytes as f64 * p.weight_sram_pj_per_byte * 1e-6),
        ("nodeflow-sram", c.nodeflow_sram_bytes as f64 * p.nodeflow_sram_pj_per_byte * 1e-6),
        ("dram", c.dram_bytes as f64 * p.dram_pj_per_byte * 1e-6),
    ]
}

/// Table IV: average power per module over one inference.
pub fn power_breakdown(cfg: &GripConfig, p: &EnergyParams, sim: &SimResult) -> PowerBreakdown {
    let us = sim.us(cfg).max(1e-9);
    let energies = energy_uj(p, &sim.counters);
    let rows: Vec<(&'static str, f64)> =
        energies.iter().map(|&(m, uj)| (m, uj / us * 1e3)).collect();
    let total_mw: f64 = rows.iter().map(|(_, v)| v).sum();
    let total_uj: f64 = energies.iter().map(|(_, v)| v).sum();
    PowerBreakdown { rows, total_mw, total_uj }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::graph::Dataset;
    use crate::greta::{compile, GnnModel};
    use crate::nodeflow::{Nodeflow, Sampler};
    use crate::sim::simulate;

    fn gcn_breakdown() -> PowerBreakdown {
        let cfg = GripConfig::paper();
        let mc = ModelConfig::paper();
        let g = Dataset::Pokec.generate(0.002, 3);
        let nf = Nodeflow::build(&g, &Sampler::new(5), &[42], &mc);
        let plan = compile(GnnModel::Gcn, &mc);
        let sim = simulate(&cfg, &plan, &nf);
        power_breakdown(&cfg, &EnergyParams::paper(), &sim)
    }

    #[test]
    fn dram_dominates_gcn() {
        // Table IV: DRAM is 53.7% — "more than the rest of the
        // accelerator combined".
        let b = gcn_breakdown();
        let dram = b.pct("dram");
        assert!(dram > 35.0 && dram < 75.0, "dram {dram}%");
        assert!(b.mw("dram") > b.mw("vertex") + b.mw("edge") + b.mw("update"));
    }

    #[test]
    fn weight_sram_second_largest() {
        let b = gcn_breakdown();
        assert!(b.mw("weight-sram") > b.mw("nodeflow-sram"));
        assert!(b.mw("weight-sram") > b.mw("vertex"));
    }

    #[test]
    fn edge_and_update_negligible() {
        // Table IV: edge 0.1%, update < 0.1%.
        let b = gcn_breakdown();
        assert!(b.pct("edge") < 2.0, "{}", b.pct("edge"));
        assert!(b.pct("update") < 1.0, "{}", b.pct("update"));
    }

    #[test]
    fn total_power_near_5w() {
        // Paper: 4.9 W total for GCN inference.
        let b = gcn_breakdown();
        assert!(b.total_mw > 1_000.0 && b.total_mw < 15_000.0, "{} mW", b.total_mw);
    }

    #[test]
    fn percentages_sum_to_100() {
        let b = gcn_breakdown();
        let s: f64 = ["edge", "vertex", "update", "weight-sram", "nodeflow-sram", "dram"]
            .iter()
            .map(|m| b.pct(m))
            .sum();
        assert!((s - 100.0).abs() < 1e-6);
    }
}
