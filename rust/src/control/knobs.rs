//! Runtime-adjustable scheduling knobs.
//!
//! Every knob the control plane can turn lives in one shared
//! [`Knobs`] cell: the SLO batcher window, the per-shard prefetch
//! lane count and pipeline depth, and the number of active shards.
//! Values are plain atomics read per-dispatch / per-job by the
//! serving threads; each knob carries a construction-time cap that
//! bounds what the controller may ever set. With control off the caps
//! equal the configured values, so every gate degenerates to the
//! pre-control constant and behavior is byte-identical to the
//! knob-free code.
//!
//! Knobs shape *scheduling only* — which thread stages or executes a
//! job, and when a batch dispatches — never the numerics of a reply.

use std::sync::atomic::{AtomicU64, Ordering};

/// The four knob identities, used for policy decisions and log lines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Knob {
    /// SLO batcher window (µs between arrival and forced dispatch).
    BatchWindowUs,
    /// Prefetch lanes active per shard.
    PrefetchLanes,
    /// Ready-queue depth between the lanes and the vertex engine.
    PipelineDepth,
    /// Shards actively pulling from the shared queue.
    ActiveShards,
}

impl Knob {
    pub fn name(&self) -> &'static str {
        match self {
            Knob::BatchWindowUs => "batch_window_us",
            Knob::PrefetchLanes => "prefetch_lanes",
            Knob::PipelineDepth => "pipeline_depth",
            Knob::ActiveShards => "active_shards",
        }
    }
}

/// Shared atomic knob cells plus their immutable caps. One `Arc<Knobs>`
/// is threaded into the batcher loop, every shard lane/engine, and the
/// controller; reads are single `Relaxed` loads.
#[derive(Debug)]
pub struct Knobs {
    window_us: AtomicU64,
    lanes: AtomicU64,
    depth: AtomicU64,
    shards: AtomicU64,
    /// Widest batcher window the controller may set (µs).
    pub max_window_us: u64,
    /// Lane threads spawned per shard (knob gates which are active).
    pub max_lanes: usize,
    /// Ready-queue channel capacity (knob narrows the usable depth).
    pub max_depth: usize,
    /// Total shards in the pool (knob quiesces the tail).
    pub max_shards: usize,
}

impl Default for Knobs {
    fn default() -> Self {
        Self::fixed(0.0, 1, 1, 1)
    }
}

impl Knobs {
    /// Caps pinned to the configured values: the control-off (and
    /// static-policy) shape, where no knob can move.
    pub fn fixed(window_us: f64, lanes: usize, depth: usize, shards: usize) -> Self {
        Self::with_caps(window_us, window_us, lanes, lanes, depth, depth, shards, shards)
    }

    /// Caps widened around the configured starting point so the
    /// adaptive policy has room to move: lanes up to
    /// `max(lanes, 4)` (≤ 8), depth up to `4 × depth` (≤ 32), the
    /// window up to `max_window_us` (the full SLO budget), shards
    /// down to 1.
    #[allow(clippy::manual_clamp)]
    pub fn adaptive(
        window_us: f64,
        max_window_us: f64,
        lanes: usize,
        depth: usize,
        shards: usize,
    ) -> Self {
        let max_lanes = lanes.max(4).min(8).max(lanes);
        let max_depth = (depth * 4).min(32).max(depth);
        Self::with_caps(
            window_us,
            max_window_us.max(window_us),
            lanes,
            max_lanes,
            depth,
            max_depth,
            shards,
            shards,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn with_caps(
        window_us: f64,
        max_window_us: f64,
        lanes: usize,
        max_lanes: usize,
        depth: usize,
        max_depth: usize,
        shards: usize,
        max_shards: usize,
    ) -> Self {
        let to_u64 = |v: f64| if v.is_finite() && v > 0.0 { v.round() as u64 } else { 0 };
        Self {
            window_us: AtomicU64::new(to_u64(window_us)),
            lanes: AtomicU64::new(lanes.max(1) as u64),
            depth: AtomicU64::new(depth.max(1) as u64),
            shards: AtomicU64::new(shards.max(1) as u64),
            max_window_us: to_u64(max_window_us),
            max_lanes: max_lanes.max(1),
            max_depth: max_depth.max(1),
            max_shards: max_shards.max(1),
        }
    }

    pub fn window_us(&self) -> f64 {
        self.window_us.load(Ordering::Relaxed) as f64
    }

    pub fn lanes(&self) -> usize {
        self.lanes.load(Ordering::Relaxed) as usize
    }

    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed) as usize
    }

    pub fn active_shards(&self) -> usize {
        self.shards.load(Ordering::Relaxed) as usize
    }

    pub fn get(&self, k: Knob) -> u64 {
        match k {
            Knob::BatchWindowUs => self.window_us.load(Ordering::Relaxed),
            Knob::PrefetchLanes => self.lanes.load(Ordering::Relaxed),
            Knob::PipelineDepth => self.depth.load(Ordering::Relaxed),
            Knob::ActiveShards => self.shards.load(Ordering::Relaxed),
        }
    }

    /// Set a knob, clamped into `[min, cap]` (window: `[0, cap]`,
    /// the rest `[1, cap]`). Returns the value actually stored.
    pub fn set(&self, k: Knob, v: u64) -> u64 {
        let (cell, lo, hi) = match k {
            Knob::BatchWindowUs => (&self.window_us, 0, self.max_window_us),
            Knob::PrefetchLanes => (&self.lanes, 1, self.max_lanes as u64),
            Knob::PipelineDepth => (&self.depth, 1, self.max_depth as u64),
            Knob::ActiveShards => (&self.shards, 1, self.max_shards as u64),
        };
        let v = v.clamp(lo, hi.max(lo));
        cell.store(v, Ordering::Relaxed);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_knobs_cannot_move() {
        let k = Knobs::fixed(3_500.0, 2, 2, 4);
        assert_eq!(k.window_us(), 3_500.0);
        assert_eq!((k.lanes(), k.depth(), k.active_shards()), (2, 2, 4));
        // Caps equal values: every set clamps back.
        k.set(Knob::PrefetchLanes, 8);
        k.set(Knob::PipelineDepth, 8);
        k.set(Knob::BatchWindowUs, 9_999);
        assert_eq!((k.lanes(), k.depth()), (2, 2));
        assert_eq!(k.window_us(), 3_500.0);
        // Shards may only quiesce down to 1 and back up to the cap.
        assert_eq!(k.set(Knob::ActiveShards, 0), 1);
        assert_eq!(k.set(Knob::ActiveShards, 100), 4);
    }

    #[test]
    fn adaptive_caps_widen_around_the_configured_point() {
        let k = Knobs::adaptive(3_500.0, 5_000.0, 2, 2, 4);
        assert_eq!((k.lanes(), k.depth()), (2, 2), "starts at the configured values");
        assert_eq!(k.max_lanes, 4);
        assert_eq!(k.max_depth, 8);
        assert_eq!(k.max_window_us, 5_000);
        assert_eq!(k.set(Knob::PrefetchLanes, 9), 4);
        assert_eq!(k.set(Knob::PipelineDepth, 3), 3);
        // A configured value above the widening heuristic is its own cap.
        let wide = Knobs::adaptive(0.0, 0.0, 16, 2, 1);
        assert_eq!(wide.max_lanes, 16);
    }
}
