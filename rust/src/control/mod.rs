//! Adaptive SLO control plane: a closed loop from stage telemetry to
//! the scheduling knobs.
//!
//! A [`Controller`] thread wakes every `interval_ms`, snapshots the
//! live signals (pool counters via a [`SignalSource`], stage-histogram
//! percentiles via [`Telemetry`], the in-flight gauge) into a
//! [`ControlSnapshot`], runs the configured [`ControlMode`]'s policy,
//! and applies the resulting knob changes through the shared
//! [`Knobs`] cells the batcher loop and shard lanes read per dispatch.
//! Every applied action lands in a bounded [`ControlLog`] exported
//! through `ServeStats`, the Prometheus text, and `BENCH_serve.json`.
//!
//! The hard invariant: control reshapes *scheduling only* — lane
//! activation, queue admission depth, batch dispatch timing, shard
//! quiescing — never the numerics of a reply. `--control adaptive`
//! replies are bit-identical to `--control off` (pinned by
//! `tests/control_props.rs`).

pub mod knobs;
pub mod policy;

pub use knobs::{Knob, Knobs};
pub use policy::{AdaptivePolicy, ControlAction, ControlSnapshot, Decision};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Duration;

use crate::telemetry::Telemetry;

/// Retained control actions; beyond this the oldest entries stay and
/// later ones are only counted, so a runaway policy can't grow memory.
pub const CONTROL_LOG_CAP: usize = 256;

/// Which policy the controller runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ControlMode {
    /// No controller thread at all — the pre-control serving stack.
    #[default]
    Off,
    /// Controller ticks and snapshots but never moves a knob: the
    /// observation loop without actuation (a deployment canary).
    Static,
    /// The hysteresis/AIMD rule set in [`AdaptivePolicy`].
    Adaptive,
}

impl ControlMode {
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "off" | "none" => Some(Self::Off),
            "static" => Some(Self::Static),
            "adaptive" => Some(Self::Adaptive),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Off => "off",
            Self::Static => "static",
            Self::Adaptive => "adaptive",
        }
    }
}

/// Controller configuration carried through `ServeConfig`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ControlConfig {
    pub mode: ControlMode,
    /// Snapshot/decision interval.
    pub interval_ms: u64,
}

impl Default for ControlConfig {
    fn default() -> Self {
        Self { mode: ControlMode::Off, interval_ms: 50 }
    }
}

/// Cumulative pool counters the controller diffs tick over tick.
/// Implemented by the shard pool's cloneable signal handle.
#[derive(Debug, Clone, Copy, Default)]
pub struct RawSignals {
    pub jobs: u64,
    pub staged_jobs: u64,
    pub prefetch_stalls: u64,
    pub engine_stalls: u64,
    /// Mean ready-queue occupancy so far, 0..1 of the depth knob.
    pub occupancy: f64,
}

/// Source of [`RawSignals`] — a trait so `control` never depends on
/// the serving layer that feeds it.
pub trait SignalSource: Send + 'static {
    fn sample(&self) -> RawSignals;
}

/// Bounded, thread-safe action log.
#[derive(Debug, Default)]
pub struct ControlLog {
    entries: Mutex<Vec<ControlAction>>,
    total: AtomicU64,
}

impl ControlLog {
    pub fn push(&self, action: ControlAction) {
        self.total.fetch_add(1, Ordering::Relaxed);
        let mut entries = self.entries.lock().unwrap();
        if entries.len() < CONTROL_LOG_CAP {
            entries.push(action);
        }
    }

    /// Every retained action, in application order.
    pub fn entries(&self) -> Vec<ControlAction> {
        self.entries.lock().unwrap().clone()
    }

    /// Total actions applied, including any beyond the retention cap.
    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }
}

/// Control-plane summary exported through `ServeStats` (composed by
/// the coordinator; defaults to the `"off"` shape so pool-only stats
/// stay unchanged).
#[derive(Debug, Clone)]
pub struct ControlStats {
    pub mode: String,
    pub ticks: u64,
    pub actions: u64,
    pub lane_actions: u64,
    pub depth_actions: u64,
    pub window_actions: u64,
    pub shard_actions: u64,
    pub final_lanes: u64,
    pub final_depth: u64,
    pub final_window_us: f64,
    pub final_active_shards: u64,
    /// Rendered `ControlLog` lines (bounded by [`CONTROL_LOG_CAP`]).
    pub log: Vec<String>,
}

impl Default for ControlStats {
    fn default() -> Self {
        Self {
            mode: "off".to_string(),
            ticks: 0,
            actions: 0,
            lane_actions: 0,
            depth_actions: 0,
            window_actions: 0,
            shard_actions: 0,
            final_lanes: 0,
            final_depth: 0,
            final_window_us: 0.0,
            final_active_shards: 0,
            log: Vec::new(),
        }
    }
}

/// Everything the controller reads besides the pool counters.
pub struct ControlInputs {
    pub telemetry: Telemetry,
    /// Requests admitted but not yet replied (the coordinator gauge).
    pub inflight: Arc<AtomicU64>,
    /// SLO budget (µs) the window/margin rules measure against.
    pub slo_us: f64,
    /// Pins the shard-quiesce rule off (routed jobs have one home).
    pub partitioned: bool,
}

struct Shared {
    mode: ControlMode,
    ticks: AtomicU64,
    log: ControlLog,
    knobs: Arc<Knobs>,
}

/// The controller thread handle. Dropping (or [`Controller::stop`])
/// closes the shutdown channel and joins the thread.
pub struct Controller {
    shared: Arc<Shared>,
    shutdown: Option<mpsc::Sender<()>>,
    handle: Option<thread::JoinHandle<()>>,
}

impl Controller {
    /// Spawn the control loop. `Off` mode is the caller's business —
    /// don't spawn at all.
    pub fn spawn(
        cfg: ControlConfig,
        knobs: Arc<Knobs>,
        source: Box<dyn SignalSource>,
        inputs: ControlInputs,
    ) -> Self {
        let shared = Arc::new(Shared {
            mode: cfg.mode,
            ticks: AtomicU64::new(0),
            log: ControlLog::default(),
            knobs: Arc::clone(&knobs),
        });
        let (shutdown_tx, shutdown_rx) = mpsc::channel::<()>();
        let interval = Duration::from_millis(cfg.interval_ms.max(1));
        let loop_shared = Arc::clone(&shared);
        let handle = thread::Builder::new()
            .name("grip-control".to_string())
            .spawn(move || {
                control_loop(cfg.mode, interval, knobs, source, inputs, &loop_shared, shutdown_rx)
            })
            .expect("spawning grip-control");
        Self { shared, shutdown: Some(shutdown_tx), handle: Some(handle) }
    }

    /// Snapshot the control summary for `ServeStats`.
    pub fn stats(&self) -> ControlStats {
        let entries = self.shared.log.entries();
        let count = |k: Knob| entries.iter().filter(|a| a.knob == k).count() as u64;
        let knobs = &self.shared.knobs;
        ControlStats {
            mode: self.shared.mode.label().to_string(),
            ticks: self.shared.ticks.load(Ordering::Relaxed),
            actions: self.shared.log.total(),
            lane_actions: count(Knob::PrefetchLanes),
            depth_actions: count(Knob::PipelineDepth),
            window_actions: count(Knob::BatchWindowUs),
            shard_actions: count(Knob::ActiveShards),
            final_lanes: knobs.lanes() as u64,
            final_depth: knobs.depth() as u64,
            final_window_us: knobs.window_us(),
            final_active_shards: knobs.active_shards() as u64,
            log: entries.iter().map(ControlAction::render).collect(),
        }
    }

    /// Stop the loop and join the thread (idempotent).
    pub fn stop(&mut self) {
        self.shutdown.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Controller {
    fn drop(&mut self) {
        self.stop();
    }
}

fn control_loop(
    mode: ControlMode,
    interval: Duration,
    knobs: Arc<Knobs>,
    source: Box<dyn SignalSource>,
    inputs: ControlInputs,
    shared: &Shared,
    shutdown_rx: mpsc::Receiver<()>,
) {
    let mut policy = AdaptivePolicy::new();
    let mut prev = RawSignals::default();
    let mut tick = 0u64;
    loop {
        match shutdown_rx.recv_timeout(interval) {
            Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => break,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
        }
        tick += 1;
        let raw = source.sample();
        let stages = inputs.telemetry.stages();
        let snap = ControlSnapshot {
            tick,
            t_ms: inputs.telemetry.now_us() / 1_000.0,
            d_jobs: raw.jobs.saturating_sub(prev.jobs),
            d_staged_jobs: raw.staged_jobs.saturating_sub(prev.staged_jobs),
            d_prefetch_stalls: raw.prefetch_stalls.saturating_sub(prev.prefetch_stalls),
            d_engine_stalls: raw.engine_stalls.saturating_sub(prev.engine_stalls),
            prefetch_occupancy: raw.occupancy,
            queue_wait_p99_us: stages.queue_wait.percentile_us(99.0),
            ready_wait_p99_us: stages.ready_wait.percentile_us(99.0),
            e2e_p99_us: stages.e2e.percentile_us(99.0),
            inflight: inputs.inflight.load(Ordering::Relaxed),
            slo_us: inputs.slo_us,
            partitioned: inputs.partitioned,
            lanes: knobs.lanes() as u64,
            depth: knobs.depth() as u64,
            window_us: knobs.get(Knob::BatchWindowUs),
            active_shards: knobs.active_shards() as u64,
            max_lanes: knobs.max_lanes as u64,
            max_depth: knobs.max_depth as u64,
            max_window_us: knobs.max_window_us,
            max_shards: knobs.max_shards as u64,
        };
        prev = raw;
        shared.ticks.fetch_add(1, Ordering::Relaxed);
        if mode != ControlMode::Adaptive {
            continue;
        }
        for d in policy.step(&snap) {
            let from = knobs.get(d.knob);
            let to = knobs.set(d.knob, d.to);
            if to == from {
                continue; // clamped into a no-op: nothing applied
            }
            shared.log.push(ControlAction {
                tick,
                t_ms: snap.t_ms.round() as u64,
                knob: d.knob,
                from,
                to,
                why: d.why,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct FixedSignals(RawSignals);
    impl SignalSource for FixedSignals {
        fn sample(&self) -> RawSignals {
            self.0
        }
    }

    fn spawn_mode(mode: ControlMode, signals: RawSignals) -> (Controller, Arc<Knobs>) {
        let knobs = Arc::new(Knobs::adaptive(3_500.0, 5_000.0, 2, 2, 4));
        let telemetry = Telemetry::disabled();
        // A huge e2e so far below the SLO that the widen rule fires on
        // every busy tick.
        telemetry.stages().e2e.record_us(100.0);
        let ctl = Controller::spawn(
            ControlConfig { mode, interval_ms: 1 },
            Arc::clone(&knobs),
            Box::new(FixedSignals(signals)),
            ControlInputs {
                telemetry,
                inflight: Arc::new(AtomicU64::new(0)),
                slo_us: 5_000.0,
                partitioned: false,
            },
        );
        (ctl, knobs)
    }

    fn busy() -> RawSignals {
        RawSignals { jobs: 100, staged_jobs: 100, occupancy: 0.4, ..Default::default() }
    }

    #[test]
    fn adaptive_controller_ticks_acts_and_logs() {
        let (mut ctl, knobs) = spawn_mode(ControlMode::Adaptive, busy());
        // First busy tick: margin 4900 > 50% of SLO → widen. Counters
        // are constant after that, so d_jobs = 0 and later ticks idle.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while ctl.stats().actions == 0 && std::time::Instant::now() < deadline {
            thread::sleep(Duration::from_millis(2));
        }
        ctl.stop();
        let stats = ctl.stats();
        assert!(stats.ticks >= 1);
        assert_eq!(stats.mode, "adaptive");
        assert_eq!(stats.actions, 1, "one busy tick, one widen action");
        assert_eq!(stats.window_actions, 1);
        assert_eq!(knobs.get(Knob::BatchWindowUs), 4_000);
        assert!(stats.log[0].contains("batch_window_us 3500 -> 4000"), "{}", stats.log[0]);
        assert_eq!(stats.final_window_us, 4_000.0);
    }

    #[test]
    fn static_controller_ticks_but_never_moves_a_knob() {
        let (mut ctl, knobs) = spawn_mode(ControlMode::Static, busy());
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while ctl.stats().ticks < 3 && std::time::Instant::now() < deadline {
            thread::sleep(Duration::from_millis(2));
        }
        ctl.stop();
        let stats = ctl.stats();
        assert!(stats.ticks >= 3);
        assert_eq!(stats.actions, 0);
        assert_eq!(knobs.get(Knob::BatchWindowUs), 3_500);
        assert_eq!((knobs.lanes(), knobs.depth(), knobs.active_shards()), (2, 2, 4));
    }

    #[test]
    fn control_log_is_bounded() {
        let log = ControlLog::default();
        for i in 0..(CONTROL_LOG_CAP as u64 + 50) {
            log.push(ControlAction {
                tick: i,
                t_ms: i,
                knob: Knob::BatchWindowUs,
                from: i,
                to: i + 1,
                why: "test".into(),
            });
        }
        assert_eq!(log.entries().len(), CONTROL_LOG_CAP);
        assert_eq!(log.total(), CONTROL_LOG_CAP as u64 + 50);
    }
}
