//! Control policy: fixed-interval telemetry snapshots in, knob
//! decisions out.
//!
//! [`ControlSnapshot`] is a plain struct of the signals one controller
//! tick sees — stall/job *deltas* since the previous tick (the pool
//! counters are cumulative), stage-histogram percentiles, occupancy,
//! in-flight depth, and the current knob values with their caps.
//! [`AdaptivePolicy::step`] is a pure-ish function over it (the only
//! state is hysteresis streaks), so every rule is unit-testable with a
//! hand-built snapshot.
//!
//! Counter semantics (they read inverted at first glance):
//! `prefetch_stalls` counts a *lane* blocked on a full ready queue —
//! the engine is the bottleneck; `engine_stalls` counts the *engine*
//! starved while jobs are in flight upstream — prefetch is the
//! bottleneck. The lane rule therefore grows lanes on `engine_stalls`
//! and sheds them on `prefetch_stalls`.

use super::knobs::Knob;

/// One controller tick's view of the serving pipeline.
#[derive(Debug, Clone, Default)]
pub struct ControlSnapshot {
    pub tick: u64,
    /// Milliseconds since the telemetry origin.
    pub t_ms: f64,
    /// Jobs executed since the previous tick.
    pub d_jobs: u64,
    /// Jobs staged by prefetch lanes since the previous tick.
    pub d_staged_jobs: u64,
    /// Lane-blocked-on-full-ready-queue events since the previous tick.
    pub d_prefetch_stalls: u64,
    /// Engine-starved-with-work-upstream events since the previous tick.
    pub d_engine_stalls: u64,
    /// Mean ready-queue occupancy, 0..1 of the current depth knob.
    pub prefetch_occupancy: f64,
    /// Stage-histogram p99s (cumulative over the run so far).
    pub queue_wait_p99_us: f64,
    pub ready_wait_p99_us: f64,
    pub e2e_p99_us: f64,
    /// Requests admitted but not yet replied.
    pub inflight: u64,
    /// The SLO budget the batcher window burns against.
    pub slo_us: f64,
    /// Partitioned pools pin `active_shards`: routed jobs have exactly
    /// one home shard, so the quiesce rule must not fire.
    pub partitioned: bool,
    /// Current knob values.
    pub lanes: u64,
    pub depth: u64,
    pub window_us: u64,
    pub active_shards: u64,
    /// Knob caps.
    pub max_lanes: u64,
    pub max_depth: u64,
    pub max_window_us: u64,
    pub max_shards: u64,
}

/// One knob change the policy wants applied.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Decision {
    pub knob: Knob,
    pub to: u64,
    pub why: String,
}

/// A `Decision` the controller actually applied, with the before/after
/// values as clamped by the knob caps.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ControlAction {
    pub tick: u64,
    pub t_ms: u64,
    pub knob: Knob,
    pub from: u64,
    pub to: u64,
    pub why: String,
}

impl ControlAction {
    /// Human-readable log line, the shape exported via `ServeStats`.
    pub fn render(&self) -> String {
        format!(
            "tick {} @ {} ms: {} {} -> {} ({})",
            self.tick,
            self.t_ms,
            self.knob.name(),
            self.from,
            self.to,
            self.why
        )
    }
}

/// Hysteresis/AIMD rule set closing the loop from stage telemetry to
/// the scheduling knobs. Thresholds are associated consts so the unit
/// tests pin exactly where each rule triggers.
#[derive(Debug, Default)]
pub struct AdaptivePolicy {
    /// Consecutive low-pressure ticks seen (shard-quiesce hysteresis).
    low_load_streak: u32,
}

impl AdaptivePolicy {
    /// One stall kind must beat the other by this factor before the
    /// lane rule moves (strictly greater — a 2:1 tie holds still).
    pub const STALL_DOMINANCE: f64 = 2.0;
    /// Ready-wait p99 above this fraction of the SLO halves the depth.
    pub const READY_WAIT_SLO_FRAC: f64 = 0.25;
    /// Ready-wait p99 below this fraction counts as "small" for growth.
    pub const READY_WAIT_SMALL_FRAC: f64 = 0.10;
    /// Occupancy above this grows the depth (when ready-wait is small).
    pub const OCC_HIGH: f64 = 0.75;
    /// SLO margin below this fraction halves the batcher window.
    pub const MARGIN_NARROW_FRAC: f64 = 0.20;
    /// SLO margin above this fraction widens the window additively.
    pub const MARGIN_WIDE_FRAC: f64 = 0.50;
    /// Additive window step, as a fraction of the SLO.
    pub const WINDOW_STEP_FRAC: f64 = 0.10;
    /// Occupancy below this counts toward the quiesce streak.
    pub const QUIESCE_OCC: f64 = 0.10;
    /// Consecutive low-pressure ticks before one shard quiesces.
    pub const QUIESCE_STREAK: u32 = 3;
    /// Queue-wait p99 above this fraction of the SLO is "pressure":
    /// every quiesced shard reactivates in one tick (fast up, slow
    /// down).
    pub const PRESSURE_QUEUE_FRAC: f64 = 0.25;

    pub fn new() -> Self {
        Self::default()
    }

    pub fn step(&mut self, s: &ControlSnapshot) -> Vec<Decision> {
        let mut out = Vec::new();
        if s.d_jobs == 0 {
            // Idle tick: no fresh signal — hold every knob and the
            // streak where they are.
            return out;
        }

        // Rule 1 — prefetch lanes, from stall dominance. See the
        // module doc for why the counter names point the directions
        // they do.
        let (ps, es) = (s.d_prefetch_stalls as f64, s.d_engine_stalls as f64);
        if es > Self::STALL_DOMINANCE * ps && es > 0.0 && s.lanes < s.max_lanes {
            out.push(Decision {
                knob: Knob::PrefetchLanes,
                to: s.lanes + 1,
                why: format!("prefetch-bound: Δengine_stalls {es} > {}×Δprefetch_stalls {ps}",
                    Self::STALL_DOMINANCE),
            });
        } else if ps > Self::STALL_DOMINANCE * es && ps > 0.0 && s.lanes > 1 {
            out.push(Decision {
                knob: Knob::PrefetchLanes,
                to: s.lanes - 1,
                why: format!("engine-bound: Δprefetch_stalls {ps} > {}×Δengine_stalls {es}",
                    Self::STALL_DOMINANCE),
            });
        }

        // Rule 2 — pipeline depth: multiplicative decrease when
        // ready-wait (staged → engine pickup) eats the SLO, additive
        // increase when the ready queue runs hot but drains fast.
        if s.ready_wait_p99_us > Self::READY_WAIT_SLO_FRAC * s.slo_us && s.depth > 1 {
            out.push(Decision {
                knob: Knob::PipelineDepth,
                to: (s.depth / 2).max(1),
                why: format!(
                    "ready-wait p99 {:.0} µs > {:.0}% of SLO",
                    s.ready_wait_p99_us,
                    Self::READY_WAIT_SLO_FRAC * 100.0
                ),
            });
        } else if s.prefetch_occupancy > Self::OCC_HIGH
            && s.ready_wait_p99_us < Self::READY_WAIT_SMALL_FRAC * s.slo_us
            && s.depth < s.max_depth
        {
            out.push(Decision {
                knob: Knob::PipelineDepth,
                to: s.depth + 1,
                why: format!(
                    "occupancy {:.2} > {:.2} with small ready-wait",
                    s.prefetch_occupancy,
                    Self::OCC_HIGH
                ),
            });
        }

        // Rule 3 — batcher window AIMD against the measured SLO
        // margin. `max_window_us == 0` means batching is off.
        if s.max_window_us > 0 {
            let margin = s.slo_us - s.e2e_p99_us;
            if margin < Self::MARGIN_NARROW_FRAC * s.slo_us && s.window_us > 0 {
                out.push(Decision {
                    knob: Knob::BatchWindowUs,
                    to: s.window_us / 2,
                    why: format!(
                        "SLO margin {margin:.0} µs < {:.0}% of budget: dispatch sooner",
                        Self::MARGIN_NARROW_FRAC * 100.0
                    ),
                });
            } else if margin > Self::MARGIN_WIDE_FRAC * s.slo_us && s.window_us < s.max_window_us {
                let step = ((Self::WINDOW_STEP_FRAC * s.slo_us) as u64).max(1);
                out.push(Decision {
                    knob: Knob::BatchWindowUs,
                    to: (s.window_us + step).min(s.max_window_us),
                    why: format!(
                        "SLO margin {margin:.0} µs > {:.0}% of budget: widen for batching",
                        Self::MARGIN_WIDE_FRAC * 100.0
                    ),
                });
            }
        }

        // Rule 4 — shard quiesce/reactivate (shared-queue pools only):
        // K consecutive low-pressure ticks park one shard's lanes; any
        // pressure signal reactivates everything at once.
        if !s.partitioned && s.max_shards > 1 {
            let pressure = s.queue_wait_p99_us > Self::PRESSURE_QUEUE_FRAC * s.slo_us
                || s.prefetch_occupancy > Self::OCC_HIGH;
            let calm = s.prefetch_occupancy < Self::QUIESCE_OCC
                && s.slo_us - s.e2e_p99_us > Self::MARGIN_WIDE_FRAC * s.slo_us;
            if pressure {
                self.low_load_streak = 0;
                if s.active_shards < s.max_shards {
                    out.push(Decision {
                        knob: Knob::ActiveShards,
                        to: s.max_shards,
                        why: format!(
                            "pressure (queue p99 {:.0} µs, occ {:.2}): reactivate all shards",
                            s.queue_wait_p99_us, s.prefetch_occupancy
                        ),
                    });
                }
            } else if calm {
                self.low_load_streak += 1;
                if self.low_load_streak >= Self::QUIESCE_STREAK && s.active_shards > 1 {
                    self.low_load_streak = 0;
                    out.push(Decision {
                        knob: Knob::ActiveShards,
                        to: s.active_shards - 1,
                        why: format!(
                            "{} calm ticks (occ {:.2} < {:.2}): quiesce one shard",
                            Self::QUIESCE_STREAK,
                            s.prefetch_occupancy,
                            Self::QUIESCE_OCC
                        ),
                    });
                }
            } else {
                self.low_load_streak = 0;
            }
        }

        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A quiet, healthy snapshot no rule fires on (margin sits between
    /// the narrow and widen thresholds).
    fn base() -> ControlSnapshot {
        ControlSnapshot {
            tick: 1,
            d_jobs: 50,
            d_staged_jobs: 50,
            prefetch_occupancy: 0.4,
            queue_wait_p99_us: 100.0,
            ready_wait_p99_us: 100.0,
            e2e_p99_us: 3_500.0, // margin 1500 = 30% of SLO: dead zone
            slo_us: 5_000.0,
            lanes: 2,
            depth: 2,
            window_us: 3_500,
            active_shards: 4,
            max_lanes: 4,
            max_depth: 8,
            max_window_us: 5_000,
            max_shards: 4,
            ..Default::default()
        }
    }

    fn decided(p: &mut AdaptivePolicy, s: &ControlSnapshot, knob: Knob) -> Option<u64> {
        p.step(s).into_iter().find(|d| d.knob == knob).map(|d| d.to)
    }

    #[test]
    fn quiet_snapshot_holds_every_knob() {
        let mut p = AdaptivePolicy::new();
        assert!(p.step(&base()).is_empty());
    }

    #[test]
    fn idle_tick_never_acts() {
        let mut p = AdaptivePolicy::new();
        let mut s = base();
        s.d_jobs = 0;
        s.d_engine_stalls = 100; // stale signal: must be ignored
        s.e2e_p99_us = 4_900.0;
        assert!(p.step(&s).is_empty());
    }

    #[test]
    fn engine_stalls_grow_lanes_prefetch_stalls_shrink_them() {
        let mut p = AdaptivePolicy::new();
        let mut s = base();
        // Engine starved (prefetch-bound): grow.
        s.d_engine_stalls = 9;
        s.d_prefetch_stalls = 4;
        assert_eq!(decided(&mut p, &s, Knob::PrefetchLanes), Some(3));
        // Exactly at the dominance ratio: hysteresis holds still.
        s.d_engine_stalls = 8;
        assert_eq!(decided(&mut p, &s, Knob::PrefetchLanes), None);
        // Lane blocked on the ready queue (engine-bound): shed one.
        s.d_engine_stalls = 1;
        s.d_prefetch_stalls = 9;
        assert_eq!(decided(&mut p, &s, Knob::PrefetchLanes), Some(1));
        // At the cap the grow side holds.
        s.d_engine_stalls = 9;
        s.d_prefetch_stalls = 0;
        s.lanes = 4;
        assert_eq!(decided(&mut p, &s, Knob::PrefetchLanes), None);
    }

    #[test]
    fn ready_wait_halves_depth_hot_queue_grows_it() {
        let mut p = AdaptivePolicy::new();
        let mut s = base();
        s.depth = 8;
        s.ready_wait_p99_us = 1_251.0; // > 25% of 5000
        assert_eq!(decided(&mut p, &s, Knob::PipelineDepth), Some(4), "multiplicative decrease");
        s.ready_wait_p99_us = 1_250.0; // exactly at the threshold: hold
        s.prefetch_occupancy = 0.5;
        assert_eq!(decided(&mut p, &s, Knob::PipelineDepth), None);
        // Hot but draining fast: additive increase.
        s.prefetch_occupancy = 0.8;
        s.ready_wait_p99_us = 400.0; // < 10% of SLO
        s.depth = 2;
        assert_eq!(decided(&mut p, &s, Knob::PipelineDepth), Some(3));
        // Hot but ready-wait not small: hold (the two halves of the
        // rule must not fight).
        s.ready_wait_p99_us = 600.0;
        assert_eq!(decided(&mut p, &s, Knob::PipelineDepth), None);
    }

    #[test]
    fn window_aimd_tracks_the_slo_margin() {
        let mut p = AdaptivePolicy::new();
        let mut s = base();
        // Margin burning (< 20% of SLO): multiplicative narrow.
        s.e2e_p99_us = 4_200.0; // margin 800
        assert_eq!(decided(&mut p, &s, Knob::BatchWindowUs), Some(1_750));
        // Comfortable margin (> 50%): additive widen by 10% of SLO.
        s.e2e_p99_us = 2_000.0; // margin 3000
        assert_eq!(decided(&mut p, &s, Knob::BatchWindowUs), Some(4_000));
        // Widen clamps at the cap...
        s.window_us = 4_800;
        assert_eq!(decided(&mut p, &s, Knob::BatchWindowUs), Some(5_000));
        // ...and holds once there.
        s.window_us = 5_000;
        assert_eq!(decided(&mut p, &s, Knob::BatchWindowUs), None);
        // Batching off (cap 0): the rule never fires.
        s.max_window_us = 0;
        s.window_us = 0;
        s.e2e_p99_us = 4_900.0;
        assert_eq!(decided(&mut p, &s, Knob::BatchWindowUs), None);
    }

    #[test]
    fn quiesce_needs_a_streak_reactivate_is_immediate() {
        let mut p = AdaptivePolicy::new();
        let mut s = base();
        s.prefetch_occupancy = 0.05;
        s.e2e_p99_us = 1_000.0; // margin 4000 > 50%
        // Two calm ticks: not yet.
        assert_eq!(decided(&mut p, &s, Knob::ActiveShards), None);
        assert_eq!(decided(&mut p, &s, Knob::ActiveShards), None);
        // Third consecutive calm tick quiesces exactly one shard.
        assert_eq!(decided(&mut p, &s, Knob::ActiveShards), Some(3));
        // A busy tick in between resets the streak.
        let mut busy = s.clone();
        busy.prefetch_occupancy = 0.4;
        assert_eq!(decided(&mut p, &s, Knob::ActiveShards), None);
        assert_eq!(decided(&mut p, &s, Knob::ActiveShards), None);
        assert_eq!(decided(&mut p, &busy, Knob::ActiveShards), None);
        assert_eq!(decided(&mut p, &s, Knob::ActiveShards), None, "streak was reset");
        // Pressure reactivates everything in one tick.
        let mut hot = s.clone();
        hot.active_shards = 2;
        hot.queue_wait_p99_us = 1_300.0; // > 25% of SLO
        assert_eq!(decided(&mut p, &hot, Knob::ActiveShards), Some(4));
    }

    #[test]
    fn partitioned_pools_never_quiesce() {
        let mut p = AdaptivePolicy::new();
        let mut s = base();
        s.partitioned = true;
        s.prefetch_occupancy = 0.0;
        s.e2e_p99_us = 100.0;
        for _ in 0..10 {
            assert_eq!(decided(&mut p, &s, Knob::ActiveShards), None);
        }
    }
}
