//! # GRIP — Graph Neural Network Accelerator Architecture (reproduction)
//!
//! A full-system reproduction of *GRIP: A Graph Neural Network Accelerator
//! Architecture* (Kiningham, Ré, Levis; 2020). The paper evaluates a 28 nm
//! ASIC through a cycle-accurate simulator; this crate rebuilds that entire
//! evaluation substrate plus a production serving stack around it:
//!
//! * [`graph`] — CSR graphs and synthetic dataset generators calibrated to
//!   the paper's Table I (Youtube / LiveJournal / Pokec / Reddit).
//! * [`nodeflow`] — GraphSAGE-style sampling, per-layer bipartite nodeflows,
//!   and execution partitioning (paper Sec. VI-A).
//! * [`greta`] — the GReTA programming model: UDFs, the data-driven
//!   `ModelSpec` IR (typed builder + JSON loader + validation/lowering
//!   pass), the serving `ModelLibrary`/`ModelKey` registry, and the
//!   preset factory yielding the paper's four models (GCN,
//!   GraphSAGE-max, GIN, G-GCN) as specs (paper Sec. IV, Fig. 3/4).
//! * [`sim`] — the cycle-level GRIP microarchitecture simulator: edge unit
//!   (prefetch lanes, crossbar, reduce lanes), vertex unit (16×32 PE array,
//!   tile buffer, weight sequencer), update unit (ReLU + two-level LUT),
//!   DDR4 memory controller, double buffering, partition pipelining, and
//!   vertex-tiling (paper Sec. V/VI).
//! * [`fixed`] — GRIP's bit-exact 16-bit fixed-point datapath including the
//!   configurable two-level LUT activation unit (paper Sec. V-D).
//! * [`energy`] — activity-counter energy model reproducing Table IV.
//! * [`baseline`] — CPU (Sec. VIII-B), GPU, and prior-work (HyGCN-like,
//!   TPU+, Graphicionado-like; Sec. VIII-F) performance models.
//! * [`runtime`] — PJRT executor loading the AOT-compiled JAX/Pallas HLO
//!   artifacts; Python never runs on the request path.
//! * [`backend`] — the pluggable execution layer: the `NumericsBackend`
//!   trait (prepare = per-shard weight residency, execute = one
//!   nodeflow → tagged embeddings) with fixed-point, PJRT (one client
//!   per shard), reference, and timing-only engines behind a
//!   thread-crossing `BackendFactory`.
//! * [`coordinator`] — the low-latency serving pipeline: bounded request
//!   queue, parallel nodeflow-builder pool, sharded executor pool, batched
//!   multi-target requests, and latency metrics (p50/p99).
//! * [`serve`] — the scale-out serving subsystem: open-loop load engine
//!   (Poisson / bursty MMPP) with per-worker submission lanes, SLO-aware
//!   dynamic batcher, phase-decoupled executor shard pool (per shard:
//!   prefetch lanes feeding the vertex engine through a bounded ready
//!   queue, mirroring GRIP's edge/vertex phase split) with a shared
//!   degree-aware feature cache, and the open-loop rate × shard sweep
//!   behind `grip serve-bench`.
//! * [`control`] — the adaptive SLO control plane: a controller thread
//!   closing the loop from stage telemetry (stall deltas, occupancy,
//!   p99s) to runtime scheduling knobs (batcher window, prefetch
//!   lanes, pipeline depth, active shards) via a hysteresis/AIMD
//!   policy — reshaping scheduling only, never numerics
//!   (`--control off|static|adaptive`).
//! * [`telemetry`] — serving-wide observability: a lock-light registry
//!   of counters/gauges and fixed-bucket log₂ streaming histograms
//!   (O(1) record, bounded memory, mergeable across shards), sampled
//!   per-request `SpanTrace` lifecycle tracing, and exporters for
//!   Chrome `trace_event` JSON (Perfetto) and Prometheus text.
//! * [`residency`] — the per-shard weight-residency manager: a
//!   byte-budgeted store of prepared models (GRIP's dedicated
//!   weight-memory subsystem, host side) paging tenants in and out
//!   under a multi-tenant mix with pluggable eviction
//!   (`--weight-budget-bytes`, `--evict lru|cost|size-aware`).
//! * [`repro`] — one generator per paper table and figure.

pub mod backend;
pub mod baseline;
pub mod benchutil;
pub mod config;
pub mod control;
pub mod coordinator;
pub mod energy;
pub mod fixed;
pub mod graph;
pub mod greta;
pub mod nodeflow;
pub mod repro;
pub mod residency;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod telemetry;

pub use config::{GripConfig, ModelConfig};
