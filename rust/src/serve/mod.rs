//! Scale-out serving subsystem (PR 2): open-loop load generation,
//! SLO-aware dynamic batching, a sharded fixed-point executor pool, and
//! degree-aware feature caches — one shared, or (PR 6,
//! `--partition degree|hash`) one partition-local cache per shard with
//! degree-balanced routing and a cross-shard boundary-fetch path.
//!
//! The paper's headline claim is 99th-percentile latency under *online
//! inference load*; this module provides the system layer that claim
//! is actually measured with. It composes with the [`crate::coordinator`]
//! pipeline like this:
//!
//! ```text
//!  loadgen (open-loop Poisson / bursty MMPP schedule over the
//!  Table-I dataset + model mix; deterministic from a seed)
//!      │  submit at scheduled arrival times, never blocking
//!      ▼
//!  Coordinator::submit
//!      │
//!      ▼
//!  batcher — SLO-aware dynamic batching: coalesce compatible
//!  single-target requests into multi-target batches, dispatching
//!  by *deadline* (arrival + SLO − margin), on a full batch, or
//!  immediately while the pipeline is idle — never by a fixed
//!  timer or count alone
//!      │  coalesced jobs
//!      ▼
//!  nodeflow-builder pool (PR 1): parallel sampling + CSR build
//!      │  built nodeflows
//!      ▼
//!  router (with `--partition degree|hash`) — maps each job's
//!  target vertex to its home shard's bounded queue via the
//!  graph partitioning (crate::graph::Partitioning); with
//!  `--partition off` every shard drains one shared queue
//!      │  routed jobs
//!      ▼
//!  shards — executor pool: K phase-decoupled shards. Per shard,
//!  N prefetch lanes (edge-centric: cycle sim + feature gather
//!  through the shard's cache into pooled StagedFeatures buffers)
//!  feed a bounded ready queue consumed by the vertex engine —
//!  the shard's NumericsBackend (crate::backend), built inside
//!  its own thread: fixed-point, per-shard PJRT clients,
//!  reference, or timing-only — so the gather for job i+1
//!  overlaps the matmul for job i (GRIP's parallel prefetch
//!  engines; `--pipeline off` restores the sequential loop)
//!      │         │
//!      │         ▼
//!      │  feature_cache — degree-aware clock cache(s) of
//!      │  synthesized feature rows (GNNIE-style: high-degree rows
//!      │  get more second chances). Unpartitioned: one shared
//!      │  cache. Partitioned: one per shard, holding only that
//!      │  partition's rows (the --cache-rows budget split by
//!      │  largest remainder, DegreeClasses recalibrated per
//!      │  partition); remote layer-0 inputs arrive as batched
//!      │  boundary pulls answered by the owning shard's boundary
//!      │  service. Hit rates are mirrored by the cycle sim's
//!      │  `cache_features` accounting so host and simulated
//!      │  locality are directly comparable
//!      ▼
//!  per-request replies → harness percentiles (p50/p99 vs offered
//!  load, per shard count × partition strategy) → BENCH_serve.json
//! ```
//!
//! * [`loadgen`] — deterministic Poisson and Markov-modulated (bursty)
//!   arrival processes, weighted model mixes.
//! * [`batcher`] — the batch-by-deadline state machine (pure virtual
//!   time; property-tested in `tests/serve_props.rs`).
//! * [`shards`] — the executor pool (one [`crate::backend::NumericsBackend`]
//!   per shard, backend fallbacks surfaced in [`ServeStats`]) and its
//!   serving statistics.
//! * [`feature_cache`] — the degree-aware clock cache (shared or
//!   partition-local).
//! * [`harness`] — open-loop measurement and the rate × shard ×
//!   partition sweep behind `grip serve-bench` and
//!   `cargo bench --bench bench_exec`.
//!
//! Every stage of the diagram above is instrumented through
//! [`crate::telemetry`]: always-on stage histograms (the per-stage
//! p50/p99 breakdown in [`ServeStats`] / `BENCH_serve.json`) plus
//! sampled per-request [`crate::telemetry::SpanTrace`] lifecycle
//! traces exportable as Chrome `trace_event` JSON and Prometheus text
//! (`--trace-sample`, `--trace-out`, `--metrics-out`).

pub mod batcher;
pub mod feature_cache;
pub mod harness;
pub mod loadgen;
pub mod memo_cache;
pub mod shards;

pub use batcher::{BatchConfig, Batcher, Pending};
pub use feature_cache::{DegreeClasses, FeatureCache};
pub use harness::{poisson, run_open_loop, run_sweep, OpenLoopConfig, OpenLoopReport};
pub use loadgen::{
    generate_arrivals, generate_arrivals_mixed, Arrival, ArrivalProcess, ModelMix, TargetDist,
    TenantMix,
};
pub use memo_cache::{MemoCache, MemoKey, MemoScope, MEMO_MIN_CLASS, MEMO_VALUE_BYTES};
pub use shards::{
    fixed_serving_args, split_cache_rows, CachedFeatures, ExecJob, MemoRouter, PipelineConfig,
    PoolSignals, ReplySlot, ServeStats, ShardPool, ShardSpec,
};
