//! Open-loop serving harness: drive a coordinator with a generated
//! arrival schedule and measure tail latency at a fixed offered load.
//!
//! Unlike `run_workload` (closed-loop: submit everything, measure a
//! saturated pipeline), requests here are submitted at their scheduled
//! arrival times regardless of completions — so queueing delay shows up
//! in the end-to-end percentiles exactly as a client would see it, and
//! sweeping the arrival rate traces the p50/p99-vs-load curve
//! (`BENCH_serve.json`, `grip serve-bench`).

use super::batcher::BatchConfig;
use super::loadgen::{generate_arrivals_mixed, ArrivalProcess, ModelMix, TargetDist, TenantMix};
use super::shards::{PipelineConfig, ServeStats};
use crate::backend::BackendChoice;
use crate::config::{GripConfig, ModelConfig};
use crate::control::{ControlConfig, ControlMode};
use crate::coordinator::{
    Coordinator, InferenceRequest, InferenceResponse, LatencyStats, ServeConfig,
};
use crate::graph::{CsrGraph, PartitionStrategy};
use crate::greta::{ModelKey, ModelSpec};
use crate::residency::{tenant_zoo, EvictPolicy};
use crate::telemetry::SpanTrace;
use anyhow::{anyhow, Result};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// The reply receiver a submission lane collects per arrival.
type ReplyRx = mpsc::Receiver<Result<InferenceResponse, String>>;

/// One open-loop measurement's configuration.
#[derive(Debug, Clone)]
pub struct OpenLoopConfig {
    pub process: ArrivalProcess,
    pub requests: usize,
    pub mix: ModelMix,
    /// Executor shards.
    pub shards: usize,
    /// Execution engine per shard. Defaults to the Q4.12 fixed-point
    /// path so rate × shard sweeps measure real numerics; `--backend
    /// pjrt` runs one PJRT client per shard instead (shards that fail
    /// to construct it serve timing-only and are counted in
    /// `backend_fallbacks`).
    pub backend: BackendChoice,
    /// Per-shard phase pipeline (prefetch lanes → vertex engine).
    pub pipeline: PipelineConfig,
    /// Optional SLO-aware dynamic batching policy.
    pub batch: Option<BatchConfig>,
    /// Control plane over the scheduling knobs (`--control
    /// off|static|adaptive`). `Off` (the default) spawns no controller
    /// and leaves every historical invocation byte-for-byte unchanged.
    pub control: ControlConfig,
    pub grip: GripConfig,
    pub model_cfg: ModelConfig,
    /// Custom model specs to register with the coordinator (keys follow
    /// the four presets in list order; address them in `mix`).
    pub custom_specs: Vec<ModelSpec>,
    pub cache_rows: usize,
    /// Graph partitioning across shards (`Off` = shared queue + shared
    /// cache; `Degree`/`Hash` = routed home shards with partition-local
    /// caches and boundary fetches).
    pub partition: PartitionStrategy,
    /// Target-vertex skew: 0 = uniform targets, otherwise the Zipf
    /// exponent for [`TargetDist::from_skew`].
    pub target_skew: f64,
    /// Multi-tenant model zoo: 0 (the default) serves `mix` unchanged;
    /// N > 0 registers N generated tenant specs
    /// ([`crate::residency::tenant_zoo`]) alongside the four presets
    /// and replaces `mix` with a tenant sampler spanning every
    /// registered model (`--tenants` on the CLI).
    pub tenants: usize,
    /// Tenant popularity skew: 0 = equal-weight tenants, otherwise the
    /// Zipf exponent over model keys, hottest first
    /// ([`TenantMix::from_skew`]; `--tenant-skew`). Arrival times and
    /// targets are invariant across skews — only the model column
    /// changes.
    pub tenant_skew: f64,
    /// Per-pool weight-residency budget in bytes, split across shards
    /// (0 = unlimited, the historical eager store;
    /// `--weight-budget-bytes`).
    pub weight_budget_bytes: usize,
    /// Eviction policy of the budgeted weight store (`--evict`).
    pub evict: EvictPolicy,
    /// Cross-request hub-embedding memo budget in cached interior-layer
    /// rows across the pool (`--memo-rows`, 0 = off). Exact activation
    /// reuse: replies are bit-identical for any budget; only the
    /// fixed-point and reference backends memoize.
    pub memo_rows: usize,
    pub builders: usize,
    /// Pacing lanes submitting the arrival schedule (0 = auto-scale
    /// with the offered rate). One sleep+spin thread saturates around
    /// ~50k submissions/s; beyond that the *submitter* throttled the
    /// measured load — per-worker lanes (each a cloned
    /// [`crate::coordinator::Submitter`]) keep the schedule honest.
    pub submit_lanes: usize,
    /// Span-trace sampling: 1-in-N requests carry a lifecycle
    /// [`SpanTrace`] (0 disables spans; stage histograms always
    /// record). `--trace-sample` on the CLI.
    pub trace_sample: u64,
    pub seed: u64,
}

impl Default for OpenLoopConfig {
    fn default() -> Self {
        Self {
            process: ArrivalProcess::Poisson { rate_rps: 100.0 },
            requests: 200,
            mix: ModelMix::default(),
            shards: 1,
            backend: BackendChoice::Fixed,
            pipeline: PipelineConfig::default(),
            batch: None,
            control: ControlConfig::default(),
            grip: GripConfig::paper(),
            model_cfg: ModelConfig::paper(),
            custom_specs: Vec::new(),
            cache_rows: 4096,
            partition: PartitionStrategy::Off,
            target_skew: 0.0,
            tenants: 0,
            tenant_skew: 0.0,
            weight_budget_bytes: 0,
            evict: EvictPolicy::default(),
            memo_rows: 0,
            builders: 4,
            submit_lanes: 0,
            trace_sample: 64,
            seed: 17,
        }
    }
}

impl OpenLoopConfig {
    /// Resolved submitter-lane count: explicit, or one lane per ~25k
    /// offered rps (capped at 8 — lanes pace disjoint slices of one
    /// schedule, so more lanes than cores just fight over sleep
    /// wakeups).
    pub fn resolved_submit_lanes(&self) -> usize {
        if self.submit_lanes > 0 {
            return self.submit_lanes;
        }
        ((self.process.mean_rps() / 25_000.0).ceil() as usize).clamp(1, 8)
    }
}

/// Results of one open-loop run.
#[derive(Debug)]
pub struct OpenLoopReport {
    pub offered_rps: f64,
    pub achieved_rps: f64,
    pub requests: usize,
    pub shards: usize,
    /// Submit-to-response latency (includes batching + queueing).
    pub e2e: LatencyStats,
    /// Build + execute time, excluding queue wait.
    pub service: LatencyStats,
    /// Simulated accelerator latency.
    pub accel: LatencyStats,
    pub stats: ServeStats,
    pub responses: Vec<InferenceResponse>,
    /// Sampled lifecycle spans drained from the run's telemetry
    /// (feed [`crate::telemetry::chrome_trace_json`]).
    pub spans: Vec<SpanTrace>,
    /// End-of-run Prometheus text snapshot (registry + pool counters).
    pub prom: String,
}

impl OpenLoopReport {
    /// Flatten to `(metric, value)` pairs for
    /// [`crate::benchutil::write_bench_json`]. Keys are owned strings
    /// because the partitioned pool contributes per-partition entries
    /// (`part{i}_hit_rate`, ...) whose names depend on the shard count.
    pub fn metrics(&self) -> Vec<(String, f64)> {
        let mut out: Vec<(String, f64)> = [
            ("offered_rps", self.offered_rps),
            ("achieved_rps", self.achieved_rps),
            ("requests", self.requests as f64),
            ("shards", self.shards as f64),
            ("e2e_p50_us", self.e2e.p50()),
            ("e2e_p99_us", self.e2e.p99()),
            ("e2e_mean_us", self.e2e.mean()),
            ("service_p50_us", self.service.p50()),
            ("service_p99_us", self.service.p99()),
            ("accel_p50_us", self.accel.p50()),
            ("accel_p99_us", self.accel.p99()),
            ("cache_hit_rate", self.stats.cache_hit_rate),
            ("sim_feature_hit_rate", self.stats.sim_feature_hit_rate),
            ("jobs", self.stats.jobs as f64),
            ("timing_only_jobs", self.stats.timing_only_jobs as f64),
            ("backend_fallbacks", self.stats.backend_fallbacks as f64),
            // Phase-pipeline health: how often each side of the
            // lane → engine queue waited, and how full it ran —
            // alongside the cycle sim's overlap fraction for the same
            // jobs (host vs on-chip phase overlap, side by side).
            ("staged_jobs", self.stats.staged_jobs as f64),
            // Layer-0 feature rows actually staged for execution —
            // memoized subtree pruning shows up here as a drop at
            // equal load (always reported, so the delta is visible
            // against memo-off runs).
            ("staged_rows", self.stats.staged_rows as f64),
            ("prefetch_stalls", self.stats.prefetch_stalls as f64),
            ("engine_stalls", self.stats.engine_stalls as f64),
            ("prefetch_occupancy", self.stats.prefetch_occupancy),
            ("sim_phase_overlap", self.stats.sim_phase_overlap),
            // Partitioned serving: cut/balance of the partitioning the
            // pool ran, the cache budget actually resident, and the
            // cross-shard boundary-fetch traffic (all zero-ish with
            // --partition off).
            ("edge_cut_fraction", self.stats.edge_cut_fraction),
            ("partition_balance", self.stats.partition_balance),
            ("cache_rows_total", self.stats.cache_rows_total as f64),
            ("boundary_fetches", self.stats.boundary_fetches as f64),
            ("boundary_rows", self.stats.boundary_rows as f64),
            ("boundary_fetch_p99_us", self.stats.boundary_fetch_p99_us),
            // Per-stage latency breakdown from the always-on stage
            // histograms: where a request's time actually went (queue,
            // local gather, boundary wait, compute, reply fan-out).
            ("stage_queue_wait_p50_us", self.stats.queue_wait_p50_us),
            ("stage_queue_wait_p99_us", self.stats.queue_wait_p99_us),
            ("stage_prefetch_local_p50_us", self.stats.prefetch_local_p50_us),
            ("stage_prefetch_local_p99_us", self.stats.prefetch_local_p99_us),
            ("stage_boundary_wait_p50_us", self.stats.boundary_wait_p50_us),
            ("stage_boundary_wait_p99_us", self.stats.boundary_wait_p99_us),
            ("stage_compute_p50_us", self.stats.compute_p50_us),
            ("stage_compute_p99_us", self.stats.compute_p99_us),
            ("stage_reply_p50_us", self.stats.reply_p50_us),
            ("stage_reply_p99_us", self.stats.reply_p99_us),
        ]
        .into_iter()
        .map(|(k, v)| (k.to_string(), v))
        .collect();
        // Per-partition rows only when a partitioning actually ran —
        // the unpartitioned report keeps its PR-5 key set.
        if self.stats.partition != "off" {
            for (i, (&rows, &hit)) in self
                .stats
                .shard_cache_rows
                .iter()
                .zip(self.stats.shard_cache_hit_rate.iter())
                .enumerate()
            {
                out.push((format!("part{i}_cache_rows"), rows as f64));
                out.push((format!("part{i}_hit_rate"), hit));
            }
            for (i, &jobs) in self.stats.routed_jobs.iter().enumerate() {
                out.push((format!("part{i}_routed_jobs"), jobs as f64));
            }
        }
        // Weight-residency summary only when a byte budget actually
        // constrained the store — unlimited (eager) reports keep their
        // historical key set.
        if self.stats.residency_budget_bytes > 0 {
            out.push(("residency_budget_bytes".to_string(), self.stats.residency_budget_bytes as f64));
            out.push(("residency_hits".to_string(), self.stats.residency_hits as f64));
            out.push(("residency_misses".to_string(), self.stats.residency_misses as f64));
            out.push(("residency_hit_rate".to_string(), self.stats.residency_hit_rate));
            out.push(("residency_evictions".to_string(), self.stats.residency_evictions as f64));
            out.push(("residency_resident_bytes".to_string(), self.stats.residency_resident_bytes as f64));
            out.push(("residency_resident_models".to_string(), self.stats.residency_resident_models as f64));
            out.push(("residency_prepare_failures".to_string(), self.stats.residency_prepare_failures as f64));
            out.push(("residency_prepare_p50_us".to_string(), self.stats.residency_prepare_p50_us));
            out.push(("residency_prepare_p99_us".to_string(), self.stats.residency_prepare_p99_us));
        }
        // Memoization summary only when a memo budget is configured —
        // `--memo-rows 0` reports keep their historical key set.
        if self.stats.memo_rows_total > 0 {
            out.push(("memo_rows_total".to_string(), self.stats.memo_rows_total as f64));
            out.push(("memo_hits".to_string(), self.stats.memo_hits as f64));
            out.push(("memo_misses".to_string(), self.stats.memo_misses as f64));
            out.push(("memo_hit_rate".to_string(), self.stats.memo_hit_rate));
            out.push(("memo_deposits".to_string(), self.stats.memo_deposits as f64));
            out.push(("memo_evictions".to_string(), self.stats.memo_evictions as f64));
            out.push(("memo_resident_rows".to_string(), self.stats.memo_resident_rows as f64));
            out.push(("memo_resident_bytes".to_string(), self.stats.memo_resident_bytes as f64));
            out.push(("memo_pruned_vertices".to_string(), self.stats.memo_pruned_vertices as f64));
            out.push(("memo_pruned_edges".to_string(), self.stats.memo_pruned_edges as f64));
            out.push(("memo_dedup_hits".to_string(), self.stats.memo_dedup_hits as f64));
        }
        // Control-plane summary only when a controller actually ran —
        // `--control off` reports keep their historical key set.
        if self.stats.control.mode != "off" {
            let c = &self.stats.control;
            out.push(("control_ticks".to_string(), c.ticks as f64));
            out.push(("control_actions".to_string(), c.actions as f64));
            out.push(("control_lane_actions".to_string(), c.lane_actions as f64));
            out.push(("control_depth_actions".to_string(), c.depth_actions as f64));
            out.push(("control_window_actions".to_string(), c.window_actions as f64));
            out.push(("control_shard_actions".to_string(), c.shard_actions as f64));
            out.push(("control_final_lanes".to_string(), c.final_lanes as f64));
            out.push(("control_final_depth".to_string(), c.final_depth as f64));
            out.push(("control_final_window_us".to_string(), c.final_window_us));
            out.push(("control_final_active_shards".to_string(), c.final_active_shards as f64));
        }
        out
    }
}

/// Sleep-then-spin until `due` past `origin` (plain `sleep` is too
/// coarse for sub-millisecond interarrival gaps).
fn pace_until(origin: &Instant, due: Duration) {
    loop {
        let elapsed = origin.elapsed();
        if elapsed >= due {
            return;
        }
        let remaining = due - elapsed;
        if remaining > Duration::from_millis(1) {
            std::thread::sleep(remaining - Duration::from_millis(1));
        } else {
            std::hint::spin_loop();
        }
    }
}

/// Run one open-loop measurement over (a clone of) `graph` with
/// `cfg.backend` numerics on every shard (fixed-point by default; the
/// per-shard PJRT engine sweeps too, now that nothing pins it to one
/// shard). Submissions are paced by `cfg.resolved_submit_lanes()`
/// worker lanes — each owns a cloned [`crate::coordinator::Submitter`]
/// and paces a disjoint round-robin slice of the schedule against the
/// shared origin, so the offered load is achieved even past the
/// ~50k rps where one sleep+spin thread used to become the bottleneck.
/// Request ids, targets, and replies are identical for any lane count.
pub fn run_open_loop(graph: &CsrGraph, cfg: &OpenLoopConfig) -> Result<OpenLoopReport> {
    // Multi-tenant zoo: generated tenant specs register after the four
    // presets (and any caller customs), and the arrival sampler spans
    // every key — hottest tenant first. With `tenants` 0 the wrapped
    // equal path is draw-for-draw the classic `generate_arrivals`.
    let mut custom_specs = cfg.custom_specs.clone();
    let mix = if cfg.tenants > 0 {
        custom_specs.extend(tenant_zoo(cfg.tenants, &cfg.model_cfg));
        let keys = (0..4 + custom_specs.len()).map(ModelKey::from_index).collect();
        TenantMix::from_skew(keys, cfg.tenant_skew)
    } else {
        TenantMix::Weighted(cfg.mix.clone())
    };
    let arrivals = generate_arrivals_mixed(
        cfg.process,
        &mix,
        TargetDist::from_skew(cfg.target_skew),
        cfg.requests,
        graph.num_vertices(),
        cfg.seed,
    );
    let serve = ServeConfig {
        backend: cfg.backend,
        shards: cfg.shards,
        partition: cfg.partition,
        pipeline: cfg.pipeline,
        batch: cfg.batch,
        control: cfg.control,
        grip: cfg.grip.clone(),
        model_cfg: cfg.model_cfg,
        custom_specs,
        cache_rows: cfg.cache_rows,
        weight_budget_bytes: cfg.weight_budget_bytes,
        evict: cfg.evict,
        memo_rows: cfg.memo_rows,
        builders: cfg.builders,
        trace_sample: cfg.trace_sample,
        // Open loop: the submission path must never block, or the
        // schedule silently degrades to closed-loop under overload.
        queue_depth: cfg.requests.max(256),
        ..Default::default()
    };
    let coord = Coordinator::start(graph.clone(), cfg.seed, serve)?;
    let shards = coord.shards();
    let lanes = cfg.resolved_submit_lanes().max(1);

    let origin = Instant::now();
    let mut pending: Vec<Option<ReplyRx>> = (0..arrivals.len()).map(|_| None).collect();
    std::thread::scope(|scope| -> Result<()> {
        // Scoped lanes: every Submitter clone dies here, before the
        // coordinator, so pipeline shutdown can drain.
        let handles: Vec<_> = (0..lanes)
            .map(|w| {
                let sub = coord.submitter();
                let arrivals = &arrivals;
                let origin = &origin;
                scope.spawn(move || -> Result<Vec<(usize, ReplyRx)>> {
                    let mut got = Vec::with_capacity(arrivals.len() / lanes + 1);
                    for i in (w..arrivals.len()).step_by(lanes) {
                        let a = &arrivals[i];
                        pace_until(origin, Duration::from_secs_f64(a.t_us / 1e6));
                        got.push((
                            i,
                            sub.submit(InferenceRequest::single(i as u64, a.model, a.target))?,
                        ));
                    }
                    Ok(got)
                })
            })
            .collect();
        for h in handles {
            let got = h.join().map_err(|_| anyhow!("submitter lane panicked"))??;
            for (i, rx) in got {
                pending[i] = Some(rx);
            }
        }
        Ok(())
    })?;
    let mut e2e = LatencyStats::new();
    let mut service = LatencyStats::new();
    let mut accel = LatencyStats::new();
    let mut responses = Vec::with_capacity(pending.len());
    for rx in pending {
        let rx = rx.ok_or_else(|| anyhow!("arrival never submitted"))?;
        let r = rx.recv().map_err(|_| anyhow!("pipeline dropped"))?.map_err(|e| anyhow!(e))?;
        e2e.record(r.host_us);
        service.record(r.service_us);
        accel.record(r.accel_us);
        responses.push(r);
    }
    let wall_s = origin.elapsed().as_secs_f64();
    let stats = coord.serve_stats();
    let spans = coord.telemetry().take_spans();
    let prom = stats.render_prometheus(coord.telemetry());
    drop(coord);

    let span_s = arrivals.last().map(|a| a.t_us / 1e6).unwrap_or(0.0);
    Ok(OpenLoopReport {
        offered_rps: if span_s > 0.0 { cfg.requests as f64 / span_s } else { 0.0 },
        achieved_rps: if wall_s > 0.0 { cfg.requests as f64 / wall_s } else { 0.0 },
        requests: cfg.requests,
        shards,
        e2e,
        service,
        accel,
        stats,
        responses,
        spans,
        prom,
    })
}

/// Sweep arrival rate × shard count over one graph; returns
/// `(section_label, report)` per point, ready for
/// [`crate::benchutil::write_bench_json`]. `process_for` maps each
/// swept rate to its arrival process (Poisson, bursty MMPP, ...), so
/// `bench_exec` and `grip serve-bench` share one loop and one label
/// format — labels look like `serve_load/poisson_r100_s4`, gaining a
/// `_pdegree` / `_phash` suffix only when `base.partition` is on, a
/// `_cstatic` / `_cadaptive` suffix only when `base.control` is on, a
/// `_t{n}z{skew}` suffix only when a tenant zoo is registered, a
/// `_w{bytes}b_e{policy}` suffix only when a weight budget constrains
/// the store, a `_z{skew}` suffix only when targets are Zipf-skewed,
/// and a `_m{rows}` suffix only when a memo budget is configured (so
/// historical unpartitioned, uncontrolled, untenanted labels stay
/// byte-stable in `BENCH_serve.json`).
pub fn run_sweep(
    graph: &CsrGraph,
    rates_rps: &[f64],
    shard_counts: &[usize],
    base: &OpenLoopConfig,
    process_for: impl Fn(f64) -> ArrivalProcess,
) -> Result<Vec<(String, OpenLoopReport)>> {
    let mut out = Vec::with_capacity(rates_rps.len() * shard_counts.len());
    for &shards in shard_counts {
        for &rate in rates_rps {
            let process = process_for(rate);
            let cfg = OpenLoopConfig { process, shards, ..base.clone() };
            let part = match base.partition {
                PartitionStrategy::Off => String::new(),
                p => format!("_p{}", p.name()),
            };
            let ctl = match base.control.mode {
                ControlMode::Off => String::new(),
                m => format!("_c{}", m.label()),
            };
            let ten = if base.tenants > 0 {
                format!("_t{}z{:.1}", base.tenants, base.tenant_skew)
            } else {
                String::new()
            };
            let res = if base.weight_budget_bytes > 0 {
                format!("_w{}b_e{}", base.weight_budget_bytes, base.evict.name())
            } else {
                String::new()
            };
            let skew = if base.target_skew > 0.0 {
                format!("_z{:.1}", base.target_skew)
            } else {
                String::new()
            };
            let memo = if base.memo_rows > 0 {
                format!("_m{}", base.memo_rows)
            } else {
                String::new()
            };
            let label = format!(
                "serve_load/{}_r{}_s{}{}{}{}{}{}{}",
                process.label(),
                rate.round(),
                shards,
                part,
                ctl,
                ten,
                res,
                skew,
                memo
            );
            let report = run_open_loop(graph, &cfg)?;
            out.push((label, report));
        }
    }
    Ok(out)
}

/// The default sweep shape: plain Poisson arrivals at each rate.
pub fn poisson(rate_rps: f64) -> ArrivalProcess {
    ArrivalProcess::Poisson { rate_rps }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, GeneratorParams};
    use crate::greta::GnnModel;

    fn tiny_cfg(rate: f64, requests: usize) -> OpenLoopConfig {
        OpenLoopConfig {
            process: ArrivalProcess::Poisson { rate_rps: rate },
            requests,
            // Small dims keep the fixed-point matmuls test-sized.
            model_cfg: ModelConfig { sample1: 4, sample2: 3, f_in: 12, f_hid: 10, f_out: 6 },
            mix: ModelMix::only(GnnModel::Gcn),
            builders: 2,
            ..Default::default()
        }
    }

    #[test]
    fn open_loop_serves_all_requests() {
        let g = generate(&GeneratorParams { nodes: 1_000, mean_degree: 6.0, ..Default::default() });
        let report = run_open_loop(&g, &tiny_cfg(2_000.0, 40)).unwrap();
        assert_eq!(report.responses.len(), 40);
        assert_eq!(report.e2e.count(), 40);
        assert!(report.e2e.p99() >= report.e2e.p50());
        assert!(report.offered_rps > 0.0);
        assert!(report.achieved_rps > 0.0);
        assert_eq!(report.stats.jobs, 40, "no batching configured");
        assert!(report.responses.iter().all(|r| !r.timing_only));
    }

    #[test]
    fn open_loop_serves_json_spec_timing_and_numerics() {
        // The acceptance path: the depth-3 spec from examples/ (the same
        // file `grip serve-bench --model-spec` loads) served open-loop —
        // cycle-sim timing plus fixed-point numerics on every reply.
        use crate::greta::{ModelLibrary, ModelSpec};
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/model_spec.json");
        let text = std::fs::read_to_string(path).expect("examples/model_spec.json in repo");
        let spec = ModelSpec::from_json_str(&text).expect("example spec parses");
        assert_eq!(spec.depth(), 3);
        let out_dim = spec.layers.last().unwrap().out_dim;

        let base = tiny_cfg(2_000.0, 24);
        // Resolve the spec's key exactly as the coordinator will.
        let (_, keys) =
            ModelLibrary::with_customs(&base.model_cfg, std::slice::from_ref(&spec)).unwrap();
        let cfg = OpenLoopConfig {
            custom_specs: vec![spec],
            mix: ModelMix::only(keys[0]),
            ..base
        };
        let g = generate(&GeneratorParams { nodes: 1_000, mean_degree: 6.0, ..Default::default() });
        let report = run_open_loop(&g, &cfg).unwrap();
        assert_eq!(report.responses.len(), 24);
        for r in &report.responses {
            assert!(!r.timing_only, "fixed-point numerics serve the spec");
            assert_eq!(r.embedding.len(), out_dim, "3-layer spec's final out_dim");
            assert!(r.accel_us > 0.0, "cycle sim timed the 3-layer nodeflow");
        }
    }

    #[test]
    fn submit_lanes_resolve_and_serve_identically() {
        // Auto-scaling: low rates pace on one lane, huge rates fan out.
        assert_eq!(tiny_cfg(100.0, 4).resolved_submit_lanes(), 1);
        assert_eq!(tiny_cfg(60_000.0, 4).resolved_submit_lanes(), 3);
        assert_eq!(tiny_cfg(1e9, 4).resolved_submit_lanes(), 8, "capped");
        assert_eq!(
            OpenLoopConfig { submit_lanes: 5, ..tiny_cfg(100.0, 4) }.resolved_submit_lanes(),
            5,
            "explicit overrides auto"
        );
        // Same schedule through 1 and 4 lanes: same replies per id.
        let g = generate(&GeneratorParams { nodes: 1_000, mean_degree: 6.0, ..Default::default() });
        let one = run_open_loop(
            &g,
            &OpenLoopConfig { submit_lanes: 1, ..tiny_cfg(3_000.0, 32) },
        )
        .unwrap();
        let four = run_open_loop(
            &g,
            &OpenLoopConfig { submit_lanes: 4, ..tiny_cfg(3_000.0, 32) },
        )
        .unwrap();
        assert_eq!(one.responses.len(), four.responses.len());
        for (a, b) in one.responses.iter().zip(four.responses.iter()) {
            assert_eq!(a.id, b.id, "responses collected in arrival order");
            assert_eq!(a.embedding, b.embedding, "id {}: lane count changed numerics", a.id);
        }
    }

    #[test]
    fn report_carries_pipeline_metrics() {
        let g = generate(&GeneratorParams { nodes: 1_000, mean_degree: 6.0, ..Default::default() });
        let report = run_open_loop(&g, &tiny_cfg(2_000.0, 24)).unwrap();
        let metrics = report.metrics();
        for key in
            ["staged_jobs", "prefetch_stalls", "engine_stalls", "prefetch_occupancy", "sim_phase_overlap"]
        {
            assert!(metrics.iter().any(|(k, _)| *k == key), "missing {key}");
        }
        // The per-stage breakdown is always present, pipelined or not.
        for key in [
            "stage_queue_wait_p50_us",
            "stage_queue_wait_p99_us",
            "stage_prefetch_local_p50_us",
            "stage_prefetch_local_p99_us",
            "stage_boundary_wait_p50_us",
            "stage_boundary_wait_p99_us",
            "stage_compute_p50_us",
            "stage_compute_p99_us",
            "stage_reply_p50_us",
            "stage_reply_p99_us",
        ] {
            assert!(metrics.iter().any(|(k, _)| *k == key), "missing {key}");
        }
        assert!(report.stats.compute_p99_us > 0.0, "compute histogram recorded");
        // Default 1-in-64 sampling traces at least request id 0.
        assert!(!report.spans.is_empty(), "sampled spans collected");
        assert!(report.prom.contains("grip_stage_compute_us_count"));
        assert!(report.prom.contains("grip_jobs_total 24"));
        // The default pipeline staged every job.
        assert_eq!(report.stats.staged_jobs, 24);
        // And the sequential path reports zero staged jobs.
        let off = run_open_loop(
            &g,
            &OpenLoopConfig { pipeline: crate::serve::PipelineConfig::off(), ..tiny_cfg(2_000.0, 8) },
        )
        .unwrap();
        assert_eq!(off.stats.staged_jobs, 0);
        for (a, b) in off.responses.iter().zip(report.responses[..8].iter()) {
            // Same seed → same schedule prefix → same targets; replies
            // must agree across pipeline modes bit for bit.
            assert_eq!(a.id, b.id);
            assert_eq!(a.embedding, b.embedding, "id {}: pipeline mode changed numerics", a.id);
        }
    }

    #[test]
    fn partitioned_report_carries_per_partition_metrics() {
        let g = generate(&GeneratorParams { nodes: 1_000, mean_degree: 6.0, ..Default::default() });
        let cfg = OpenLoopConfig {
            partition: PartitionStrategy::Degree,
            shards: 2,
            cache_rows: 64,
            ..tiny_cfg(2_000.0, 24)
        };
        let report = run_open_loop(&g, &cfg).unwrap();
        let metrics = report.metrics();
        for key in [
            "edge_cut_fraction",
            "partition_balance",
            "cache_rows_total",
            "boundary_fetches",
            "boundary_fetch_p99_us",
            "part0_cache_rows",
            "part1_cache_rows",
            "part0_hit_rate",
            "part1_hit_rate",
            "part0_routed_jobs",
            "part1_routed_jobs",
        ] {
            assert!(metrics.iter().any(|(k, _)| *k == key), "missing {key}");
        }
        let total = metrics.iter().find(|(k, _)| *k == "cache_rows_total").unwrap().1;
        assert_eq!(total, 64.0, "split caches preserve the total row budget");
        // The unpartitioned report keeps its key set per-partition-free.
        let off = run_open_loop(&g, &tiny_cfg(2_000.0, 8)).unwrap();
        assert!(off.metrics().iter().all(|(k, _)| !k.starts_with("part0_")));
        // Zipfian targets flow through the same harness deterministically.
        let zcfg = OpenLoopConfig { target_skew: 1.1, ..tiny_cfg(2_000.0, 8) };
        let zipf = run_open_loop(&g, &zcfg).unwrap();
        assert_eq!(zipf.responses.len(), 8);
        // Partition suffix appears in sweep labels only when enabled.
        let pts = run_sweep(&g, &[2_000.0], &[2], &cfg, poisson).unwrap();
        assert!(pts.iter().any(|(l, _)| l == "serve_load/poisson_r2000_s2_pdegree"));
    }

    #[test]
    fn control_report_gates_keys_and_labels() {
        let g = generate(&GeneratorParams { nodes: 1_000, mean_degree: 6.0, ..Default::default() });
        // Off (default): no control_* keys, historical label.
        let off = run_open_loop(&g, &tiny_cfg(2_000.0, 12)).unwrap();
        assert!(off.metrics().iter().all(|(k, _)| !k.starts_with("control_")));
        // Adaptive: summary keys present, label gains the _c suffix.
        let cfg = OpenLoopConfig {
            control: ControlConfig { mode: ControlMode::Adaptive, interval_ms: 5 },
            batch: Some(BatchConfig { slo_us: 20_000.0, margin_us: 5_000.0, max_batch: 4 }),
            ..tiny_cfg(2_000.0, 24)
        };
        let report = run_open_loop(&g, &cfg).unwrap();
        assert_eq!(report.responses.len(), 24);
        let metrics = report.metrics();
        for key in [
            "control_ticks",
            "control_actions",
            "control_lane_actions",
            "control_depth_actions",
            "control_window_actions",
            "control_shard_actions",
            "control_final_lanes",
            "control_final_depth",
            "control_final_window_us",
            "control_final_active_shards",
        ] {
            assert!(metrics.iter().any(|(k, _)| *k == key), "missing {key}");
        }
        assert!(
            metrics.iter().any(|(k, &v)| *k == "control_final_lanes" && v >= 1.0),
            "final lane knob reported"
        );
        let pts = run_sweep(&g, &[2_000.0], &[1], &cfg, poisson).unwrap();
        assert!(pts.iter().any(|(l, _)| l == "serve_load/poisson_r2000_s1_cadaptive"));
    }

    #[test]
    fn residency_report_gates_keys_and_labels() {
        use crate::greta::ModelLibrary;
        use crate::residency::plan_weight_bytes;
        let g = generate(&GeneratorParams { nodes: 1_000, mean_degree: 6.0, ..Default::default() });
        // Unlimited (default): no residency_* keys, no residency series.
        let off = run_open_loop(&g, &tiny_cfg(2_000.0, 8)).unwrap();
        assert!(off.metrics().iter().all(|(k, _)| !k.starts_with("residency_")));
        assert!(!off.prom.contains("grip_residency_"));

        // A budget that fits barely one model at a time over a 3-tenant
        // zoo with a skewed mix: models page constantly.
        let base = tiny_cfg(2_000.0, 32);
        let zoo = tenant_zoo(3, &base.model_cfg);
        let (lib, _) = ModelLibrary::with_customs(&base.model_cfg, &zoo).unwrap();
        let seed = ServeConfig::default().weight_seed;
        let max = lib.keys().map(|k| plan_weight_bytes(&lib, k, seed)).max().unwrap();
        let cfg = OpenLoopConfig {
            tenants: 3,
            tenant_skew: 1.1,
            weight_budget_bytes: max + 1,
            ..base
        };
        let report = run_open_loop(&g, &cfg).unwrap();
        assert_eq!(report.responses.len(), 32);
        assert!(report.responses.iter().all(|r| !r.timing_only), "every tenant serves numerics");
        let metrics = report.metrics();
        for key in [
            "residency_budget_bytes",
            "residency_hits",
            "residency_misses",
            "residency_hit_rate",
            "residency_evictions",
            "residency_resident_bytes",
            "residency_resident_models",
            "residency_prepare_failures",
            "residency_prepare_p50_us",
            "residency_prepare_p99_us",
        ] {
            assert!(metrics.iter().any(|(k, _)| *k == key), "missing {key}");
        }
        assert!(report.stats.residency_evictions >= 1, "tight budget must evict");
        assert!(report.stats.residency_misses >= 2, "distinct tenants page in");
        assert_eq!(report.stats.residency_prepare_failures, 0);
        assert!(report.prom.contains("grip_residency_hits_total"));
        assert!(report.prom.contains("grip_residency_evictions_total"));
        // Sweep labels gain the tenant and budget suffixes only here.
        let pts = run_sweep(&g, &[2_000.0], &[1], &cfg, poisson).unwrap();
        let want = format!("serve_load/poisson_r2000_s1_t3z1.1_w{}b_elru", max + 1);
        assert!(
            pts.iter().any(|(l, _)| *l == want),
            "missing label {want}; got {:?}",
            pts.iter().map(|(l, _)| l.clone()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn memo_report_gates_keys_and_labels() {
        let g = generate(&GeneratorParams { nodes: 1_000, mean_degree: 6.0, ..Default::default() });
        // Off (default): no memo_* keys, no memo series — but the
        // always-on staged_rows metric reports regardless.
        let off = run_open_loop(&g, &tiny_cfg(2_000.0, 12)).unwrap();
        assert!(off.metrics().iter().all(|(k, _)| !k.starts_with("memo_")));
        assert!(!off.prom.contains("grip_memo_"));
        assert!(
            off.metrics().iter().any(|(k, &v)| *k == "staged_rows" && v > 0.0),
            "staged_rows reports even with memo off"
        );

        // Memoized Zipf-skewed run vs the identical memo-off schedule:
        // the memo budget may only reshape nodeflows, never replies.
        let base = OpenLoopConfig { target_skew: 1.1, ..tiny_cfg(2_000.0, 32) };
        let plain = run_open_loop(&g, &base).unwrap();
        let cfg = OpenLoopConfig { memo_rows: 4096, ..base.clone() };
        let report = run_open_loop(&g, &cfg).unwrap();
        assert_eq!(report.responses.len(), 32);
        for (a, b) in plain.responses.iter().zip(report.responses.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.embedding, b.embedding, "id {}: memoization changed numerics", a.id);
            assert!(
                b.accel_us <= a.accel_us,
                "id {}: a pruned nodeflow cannot cost more sim time",
                a.id
            );
        }
        assert!(
            report.stats.staged_rows <= plain.stats.staged_rows,
            "pruning can only reduce staged feature rows"
        );
        let metrics = report.metrics();
        for key in [
            "memo_rows_total",
            "memo_hits",
            "memo_misses",
            "memo_hit_rate",
            "memo_deposits",
            "memo_evictions",
            "memo_resident_rows",
            "memo_resident_bytes",
            "memo_pruned_vertices",
            "memo_pruned_edges",
            "memo_dedup_hits",
        ] {
            assert!(metrics.iter().any(|(k, _)| *k == key), "missing {key}");
        }
        assert!(report.prom.contains("grip_memo_rows_total"));
        assert!(report.prom.contains("grip_memo_hit_rate"));
        assert!(report.prom.contains("grip_staged_rows_total"));
        // Sweep labels gain the skew and memo suffixes only here.
        let pts = run_sweep(&g, &[2_000.0], &[1], &cfg, poisson).unwrap();
        assert!(
            pts.iter().any(|(l, _)| l == "serve_load/poisson_r2000_s1_z1.1_m4096"),
            "got {:?}",
            pts.iter().map(|(l, _)| l.clone()).collect::<Vec<_>>()
        );
        let zonly = run_sweep(&g, &[2_000.0], &[1], &base, poisson).unwrap();
        assert!(zonly.iter().any(|(l, _)| l == "serve_load/poisson_r2000_s1_z1.1"));
    }

    #[test]
    fn tenant_mix_keeps_schedule_invariant_and_pages_bit_identically() {
        let g = generate(&GeneratorParams { nodes: 1_000, mean_degree: 6.0, ..Default::default() });
        // Same seed, tenants on vs off: only the model column moves, so
        // per-id targets (and thus reply shapes) stay aligned across
        // budgets — pin replies across all three eviction policies.
        let base = OpenLoopConfig { tenants: 4, tenant_skew: 1.1, ..tiny_cfg(2_000.0, 24) };
        let unlimited = run_open_loop(&g, &base).unwrap();
        assert_eq!(unlimited.stats.residency_budget_bytes, 0);
        for policy in [EvictPolicy::Lru, EvictPolicy::Cost, EvictPolicy::SizeAware] {
            let cfg = OpenLoopConfig {
                weight_budget_bytes: 16 << 10,
                evict: policy,
                ..base.clone()
            };
            let paged = run_open_loop(&g, &cfg).unwrap();
            assert_eq!(paged.responses.len(), unlimited.responses.len());
            for (a, b) in unlimited.responses.iter().zip(paged.responses.iter()) {
                assert_eq!(a.id, b.id);
                assert_eq!(
                    a.embedding, b.embedding,
                    "id {}: {} paging changed numerics",
                    a.id,
                    policy.name()
                );
                assert_eq!(a.accel_us, b.accel_us, "id {}: paging changed sim timing", a.id);
            }
        }
    }

    #[test]
    fn sweep_labels_and_coverage() {
        let g = generate(&GeneratorParams { nodes: 800, mean_degree: 6.0, ..Default::default() });
        let base = tiny_cfg(1.0, 12);
        let points = run_sweep(&g, &[1_000.0, 4_000.0], &[1, 2], &base, poisson).unwrap();
        assert_eq!(points.len(), 4);
        assert!(points.iter().any(|(l, _)| l == "serve_load/poisson_r1000_s1"));
        assert!(points.iter().any(|(l, _)| l == "serve_load/poisson_r4000_s2"));
        for (label, r) in &points {
            assert_eq!(r.requests, 12, "{label}");
            let metrics = r.metrics();
            assert!(metrics.iter().any(|(k, _)| *k == "e2e_p99_us"));
            assert!(metrics.iter().any(|(k, _)| *k == "cache_hit_rate"));
        }
    }
}
