//! Open-loop load engine: deterministic arrival-process generation for
//! serving experiments.
//!
//! The PR-1 workload driver (`run_workload`) is **closed-loop**: it
//! submits every request up front and measures a saturated pipeline,
//! which is the right harness for throughput but says nothing about
//! tail latency at a given offered load. This module generates
//! **open-loop** schedules — requests arrive at times drawn from an
//! arrival process, independent of completions — which is how the
//! paper's 99th-percentile online-inference claim (and MLPerf server
//! mode) is actually measured.
//!
//! Two processes are provided, both bit-deterministic from a seed:
//!
//! * [`ArrivalProcess::Poisson`] — memoryless arrivals at a fixed rate
//!   (exponential interarrivals by inverse-CDF).
//! * [`ArrivalProcess::Bursty`] — a two-state Markov-modulated Poisson
//!   process (MMPP-2): the generator dwells in a *base* state and a
//!   *burst* state with exponentially distributed dwell times, emitting
//!   Poisson arrivals at the state's rate. This reproduces the
//!   bursty/self-similar traffic that makes p99 diverge from p50 long
//!   before mean utilization saturates.
//!
//! Each arrival carries a model drawn from a weighted [`ModelMix`]
//! (defaults to the paper's four Table-III models, equally weighted)
//! and a target vertex drawn from a [`TargetDist`] — uniform, or
//! Zipfian (`--target-skew`) so sweeps exercise hot-vertex partitions
//! instead of a flat target distribution (the honest setting for
//! partition-balance numbers: a degree-balanced partitioning only
//! earns its keep when some vertices are much hotter than others).

use crate::greta::{GnnModel, ModelKey, ALL_MODELS};
use crate::rng::SplitMix64;

/// One scheduled request of the open-loop workload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    /// Scheduled submission time, µs from workload start.
    pub t_us: f64,
    /// Model to serve (preset or registered custom spec).
    pub model: ModelKey,
    /// Target vertex id (uniform over the serving graph).
    pub target: u32,
}

/// Arrival process shapes. Rates are requests/second of *virtual* time.
#[derive(Debug, Clone, Copy)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at `rate_rps`.
    Poisson { rate_rps: f64 },
    /// Two-state MMPP: Poisson at `base_rps`, with bursts at
    /// `burst_rps`; dwell times in each state are exponential with the
    /// given means.
    Bursty {
        base_rps: f64,
        burst_rps: f64,
        base_dwell_ms: f64,
        burst_dwell_ms: f64,
    },
}

impl ArrivalProcess {
    /// Long-run offered rate (requests/second).
    pub fn mean_rps(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate_rps } => rate_rps,
            ArrivalProcess::Bursty { base_rps, burst_rps, base_dwell_ms, burst_dwell_ms } => {
                let total = base_dwell_ms + burst_dwell_ms;
                (base_rps * base_dwell_ms + burst_rps * burst_dwell_ms) / total.max(1e-12)
            }
        }
    }

    /// Short label for report keys, e.g. `poisson` / `bursty`.
    pub fn label(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::Bursty { .. } => "bursty",
        }
    }
}

/// Target-vertex distribution for generated requests.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum TargetDist {
    /// Every vertex equally likely (the pre-PR-6 behavior).
    #[default]
    Uniform,
    /// Zipf-like skew with exponent `s`: vertex ids are ranked, so low
    /// ids are the hot head. `s` around 0.8–1.2 matches the access
    /// skew real serving traces show.
    Zipf { s: f64 },
}

impl TargetDist {
    /// Map a CLI `--target-skew` value: `s <= 0` is uniform; the
    /// inverse-CDF sampler is singular at `s == 1` (its exponent is
    /// `1/(1-s)`), so values within 1e-3 of 1.0 are nudged to 1.001.
    pub fn from_skew(s: f64) -> Self {
        if s <= 0.0 {
            TargetDist::Uniform
        } else if (s - 1.0).abs() < 1e-3 {
            TargetDist::Zipf { s: 1.001 }
        } else {
            TargetDist::Zipf { s }
        }
    }

    fn sample(&self, rng: &mut SplitMix64, num_vertices: usize) -> u32 {
        let n = num_vertices.max(1);
        match *self {
            TargetDist::Uniform => rng.gen_range(n) as u32,
            // gen_zipf returns a rank in [1, n]; rank 1 = vertex 0.
            TargetDist::Zipf { s } => (rng.gen_zipf(n, s) - 1) as u32,
        }
    }
}

/// Weighted model mix for generated requests. Entries are
/// [`ModelKey`]s, so a mix can combine presets and registered custom
/// specs freely.
#[derive(Debug, Clone)]
pub struct ModelMix {
    /// (model, weight) — weights need not be normalized.
    pub weights: Vec<(ModelKey, f64)>,
}

impl Default for ModelMix {
    /// All four Table-III models, equally weighted.
    fn default() -> Self {
        Self { weights: ALL_MODELS.into_iter().map(|m| (m.key(), 1.0)).collect() }
    }
}

impl ModelMix {
    /// A single-model mix.
    pub fn only(model: impl Into<ModelKey>) -> Self {
        Self { weights: vec![(model.into(), 1.0)] }
    }

    fn pick(&self, rng: &mut SplitMix64) -> ModelKey {
        let total: f64 = self.weights.iter().map(|(_, w)| w).sum();
        let mut x = rng.gen_f64() * total;
        for &(m, w) in &self.weights {
            if x < w {
                return m;
            }
            x -= w;
        }
        self.weights.last().map(|&(m, _)| m).unwrap_or(GnnModel::Gcn.key())
    }
}

/// Tenant-skewed model sampler (`--tenants` + `--tenant-skew`): which
/// model each arrival requests, with the per-tenant popularity skew a
/// multi-tenant zoo actually sees. Both variants consume **exactly one
/// rng draw per request** — a weighted pick is one `gen_f64`, a Zipf
/// pick is one `gen_zipf` (itself one `gen_f64`, the PR-6 inverse-CDF
/// sampler) — so for a given seed the arrival *times* and *targets*
/// are bit-identical across every skew setting; only the model column
/// changes. That is what lets residency sweeps attribute hit-rate
/// movement to the skew alone.
#[derive(Debug, Clone)]
pub enum TenantMix {
    /// Weighted pick (the classic [`ModelMix`] path; equal weights =
    /// skew 0).
    Weighted(ModelMix),
    /// Zipf-ranked pick over an ordered key list: rank 1 = `keys[0]`,
    /// the hottest tenant. `s` around 1 matches real multi-tenant
    /// traffic, where a few models dominate and a long tail churns.
    Zipf { keys: Vec<ModelKey>, s: f64 },
}

impl TenantMix {
    /// Map a CLI `--tenant-skew` over an ordered key list: `s <= 0` is
    /// the equal-weight mix; values within 1e-3 of the inverse-CDF
    /// singularity at `s == 1` are nudged to 1.001 (the same rule as
    /// [`TargetDist::from_skew`]).
    pub fn from_skew(keys: Vec<ModelKey>, s: f64) -> TenantMix {
        if s <= 0.0 {
            TenantMix::Weighted(ModelMix {
                weights: keys.into_iter().map(|k| (k, 1.0)).collect(),
            })
        } else if (s - 1.0).abs() < 1e-3 {
            TenantMix::Zipf { keys, s: 1.001 }
        } else {
            TenantMix::Zipf { keys, s }
        }
    }

    fn pick(&self, rng: &mut SplitMix64) -> ModelKey {
        match self {
            TenantMix::Weighted(mix) => mix.pick(rng),
            TenantMix::Zipf { keys, s } => {
                // gen_zipf returns a rank in [1, n]; rank 1 = keys[0].
                let rank = rng.gen_zipf(keys.len().max(1), *s);
                keys.get(rank - 1).copied().unwrap_or(GnnModel::Gcn.key())
            }
        }
    }
}

/// Exponential variate with the given mean (inverse-CDF; deterministic
/// from the rng stream).
fn exp_sample(rng: &mut SplitMix64, mean: f64) -> f64 {
    // gen_f64 ∈ [0, 1); clamp away from 0 so ln() stays finite.
    -(1.0 - rng.gen_f64()).max(1e-15).ln() * mean
}

/// Generate the first `n` arrivals of `process` over a graph with
/// `num_vertices` vertices, targets drawn from `targets`.
/// Deterministic in `seed`; arrival times are strictly increasing.
pub fn generate_arrivals(
    process: ArrivalProcess,
    mix: &ModelMix,
    targets: TargetDist,
    n: usize,
    num_vertices: usize,
    seed: u64,
) -> Vec<Arrival> {
    generate_arrivals_mixed(
        process,
        &TenantMix::Weighted(mix.clone()),
        targets,
        n,
        num_vertices,
        seed,
    )
}

/// [`generate_arrivals`] with a [`TenantMix`] model sampler — the
/// multi-tenant entry point. Both mix variants cost one rng draw per
/// arrival (see [`TenantMix`]), so the schedule's times and targets
/// are invariant under the tenant-skew setting. Per arrival the draw
/// order is gap → target → model (targets before any model draw): the
/// target column — the input the memo cache keys on — can never move
/// because a downstream mix option toggled.
pub fn generate_arrivals_mixed(
    process: ArrivalProcess,
    mix: &TenantMix,
    targets: TargetDist,
    n: usize,
    num_vertices: usize,
    seed: u64,
) -> Vec<Arrival> {
    let mut rng = SplitMix64::new(seed ^ 0x09E4_10AD_0F_F3);
    let mut out = Vec::with_capacity(n);
    let mut t_us = 0.0f64;
    match process {
        ArrivalProcess::Poisson { rate_rps } => {
            let mean_gap_us = 1e6 / rate_rps.max(1e-9);
            while out.len() < n {
                t_us += exp_sample(&mut rng, mean_gap_us);
                // Draw order is gap → target → model, each costing
                // exactly one rng advance: the memo-relevant target
                // column comes before any per-request model draw, so
                // schedules stay draw-for-draw aligned across every
                // mix/skew/memo knob combination.
                let target = targets.sample(&mut rng, num_vertices);
                out.push(Arrival { t_us, model: mix.pick(&mut rng), target });
            }
        }
        ArrivalProcess::Bursty { base_rps, burst_rps, base_dwell_ms, burst_dwell_ms } => {
            let mut bursting = false;
            // End of the current dwell period (µs).
            let mut dwell_end_us = exp_sample(&mut rng, base_dwell_ms * 1e3);
            while out.len() < n {
                let rate = if bursting { burst_rps } else { base_rps };
                let mean_gap_us = 1e6 / rate.max(1e-9);
                let gap = exp_sample(&mut rng, mean_gap_us);
                if t_us + gap > dwell_end_us {
                    // State switch before the next arrival: restart the
                    // (memoryless) interarrival draw in the new state.
                    t_us = dwell_end_us;
                    bursting = !bursting;
                    let mean_dwell_us =
                        1e3 * if bursting { burst_dwell_ms } else { base_dwell_ms };
                    dwell_end_us = t_us + exp_sample(&mut rng, mean_dwell_us);
                    continue;
                }
                t_us += gap;
                // Same draw discipline as the Poisson arm: gap →
                // target → model, one rng advance each.
                let target = targets.sample(&mut rng, num_vertices);
                out.push(Arrival { t_us, model: mix.pick(&mut rng), target });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn poisson(rate: f64) -> ArrivalProcess {
        ArrivalProcess::Poisson { rate_rps: rate }
    }

    fn bursty() -> ArrivalProcess {
        ArrivalProcess::Bursty {
            base_rps: 100.0,
            burst_rps: 1000.0,
            base_dwell_ms: 50.0,
            burst_dwell_ms: 10.0,
        }
    }

    /// Uniform-target shorthand for the pre-PR-6 call shape.
    fn gen(
        process: ArrivalProcess,
        mix: &ModelMix,
        n: usize,
        num_vertices: usize,
        seed: u64,
    ) -> Vec<Arrival> {
        generate_arrivals(process, mix, TargetDist::Uniform, n, num_vertices, seed)
    }

    #[test]
    fn deterministic_in_seed() {
        let mix = ModelMix::default();
        let a = gen(poisson(500.0), &mix, 200, 1000, 7);
        let b = gen(poisson(500.0), &mix, 200, 1000, 7);
        assert_eq!(a, b);
        let c = gen(poisson(500.0), &mix, 200, 1000, 8);
        assert_ne!(a, c, "different seed, different schedule");
    }

    #[test]
    fn times_strictly_increasing_and_targets_in_range() {
        for proc in [poisson(800.0), bursty()] {
            let a = gen(proc, &ModelMix::default(), 500, 123, 3);
            assert_eq!(a.len(), 500);
            for w in a.windows(2) {
                assert!(w[1].t_us > w[0].t_us);
            }
            assert!(a.iter().all(|x| (x.target as usize) < 123));
        }
    }

    #[test]
    fn poisson_rate_close_to_nominal() {
        let n = 4000;
        let a = gen(poisson(1000.0), &ModelMix::default(), n, 10, 11);
        let measured_rps = (n - 1) as f64 / (a.last().unwrap().t_us - a[0].t_us) * 1e6;
        assert!(
            (measured_rps - 1000.0).abs() < 100.0,
            "measured {measured_rps} rps vs nominal 1000"
        );
    }

    #[test]
    fn bursty_is_burstier_than_poisson() {
        // Coefficient of variation of interarrival gaps: ~1 for Poisson,
        // strictly larger for the 10x MMPP.
        let cov = |a: &[Arrival]| {
            let gaps: Vec<f64> = a.windows(2).map(|w| w[1].t_us - w[0].t_us).collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var =
                gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
            var.sqrt() / mean
        };
        let mix = ModelMix::default();
        let mean_rps = bursty().mean_rps();
        let p = gen(poisson(mean_rps), &mix, 3000, 10, 5);
        let b = gen(bursty(), &mix, 3000, 10, 5);
        assert!(
            cov(&b) > cov(&p) * 1.15,
            "bursty CoV {} should exceed poisson CoV {}",
            cov(&b),
            cov(&p)
        );
    }

    #[test]
    fn mmpp_mean_rate_formula() {
        let m = bursty().mean_rps();
        // (100*50 + 1000*10) / 60 = 250
        assert!((m - 250.0).abs() < 1e-9, "{m}");
    }

    #[test]
    fn model_mix_respects_weights() {
        let mix =
            ModelMix { weights: vec![(GnnModel::Gcn.key(), 3.0), (GnnModel::Gin.key(), 1.0)] };
        let a = gen(poisson(100.0), &mix, 2000, 10, 9);
        let gcn = a.iter().filter(|x| x.model == GnnModel::Gcn.key()).count();
        let frac = gcn as f64 / a.len() as f64;
        assert!((frac - 0.75).abs() < 0.05, "gcn fraction {frac}");
        assert!(a.iter().all(|x| x.model != GnnModel::Sage.key()));
    }

    #[test]
    fn single_model_mix() {
        let mix = ModelMix::only(GnnModel::Ggcn);
        let a = gen(poisson(100.0), &mix, 50, 10, 1);
        assert!(a.iter().all(|x| x.model == GnnModel::Ggcn.key()));
    }

    #[test]
    fn skew_mapping_handles_the_zipf_singularity() {
        assert_eq!(TargetDist::from_skew(0.0), TargetDist::Uniform);
        assert_eq!(TargetDist::from_skew(-1.0), TargetDist::Uniform);
        assert_eq!(TargetDist::from_skew(1.0), TargetDist::Zipf { s: 1.001 });
        assert_eq!(TargetDist::from_skew(0.9995), TargetDist::Zipf { s: 1.001 });
        assert_eq!(TargetDist::from_skew(1.2), TargetDist::Zipf { s: 1.2 });
        assert_eq!(TargetDist::default(), TargetDist::Uniform);
    }

    #[test]
    fn tenant_skew_changes_only_the_model_column() {
        // The satellite-1 guarantee: one rng draw per request whatever
        // the tenant mix, so arrival times AND targets are identical
        // across skews — only which tenant each request asks for moves.
        let keys: Vec<ModelKey> = (0..6).map(ModelKey::from_index).collect();
        let n = 10_000usize;
        let flat = generate_arrivals_mixed(
            poisson(500.0),
            &TenantMix::from_skew(keys.clone(), 0.0),
            TargetDist::from_skew(1.1),
            4000,
            n,
            21,
        );
        let skewed = generate_arrivals_mixed(
            poisson(500.0),
            &TenantMix::from_skew(keys.clone(), 1.1),
            TargetDist::from_skew(1.1),
            4000,
            n,
            21,
        );
        for (f, s) in flat.iter().zip(skewed.iter()) {
            assert_eq!(f.t_us, s.t_us, "tenant skew changed an arrival time");
            assert_eq!(f.target, s.target, "tenant skew changed a target draw");
        }
        // The classic weighted path and the TenantMix wrapper are the
        // same stream: ModelMix::default() == equal-weight TenantMix
        // over the same keys.
        let preset_keys: Vec<ModelKey> = ALL_MODELS.iter().map(|m| m.key()).collect();
        let classic = generate_arrivals(
            poisson(500.0),
            &ModelMix::default(),
            TargetDist::Uniform,
            500,
            n,
            9,
        );
        let wrapped = generate_arrivals_mixed(
            poisson(500.0),
            &TenantMix::from_skew(preset_keys, 0.0),
            TargetDist::Uniform,
            500,
            n,
            9,
        );
        assert_eq!(classic, wrapped);
        // Zipf(1.1) concentrates picks on the rank-1 tenant well above
        // its 1/6 flat share.
        let head = |a: &[Arrival]| {
            a.iter().filter(|x| x.model == keys[0]).count() as f64 / a.len() as f64
        };
        assert!(head(&flat) < 0.25, "flat head share {}", head(&flat));
        assert!(
            head(&skewed) > head(&flat) * 2.0,
            "zipf head share {} vs flat {}",
            head(&skewed),
            head(&flat)
        );
        // And the singularity nudge applies to tenant skews too.
        match TenantMix::from_skew(keys.clone(), 1.0) {
            TenantMix::Zipf { s, .. } => assert!((s - 1.001).abs() < 1e-12),
            TenantMix::Weighted(_) => panic!("skew 1.0 must be Zipf"),
        }
        match TenantMix::from_skew(keys, -0.5) {
            TenantMix::Weighted(m) => assert_eq!(m.weights.len(), 6),
            TenantMix::Zipf { .. } => panic!("non-positive skew must be weighted"),
        }
    }

    #[test]
    fn zipf_targets_concentrate_on_the_head() {
        let n = 10_000usize;
        let mix = ModelMix::default();
        let uni =
            generate_arrivals(poisson(500.0), &mix, TargetDist::Uniform, 4000, n, 21);
        let zipf = generate_arrivals(
            poisson(500.0),
            &mix,
            TargetDist::from_skew(1.1),
            4000,
            n,
            21,
        );
        assert!(zipf.iter().all(|a| (a.target as usize) < n));
        let head = |a: &[Arrival]| {
            a.iter().filter(|x| (x.target as usize) < n / 100).count() as f64 / a.len() as f64
        };
        // Uniform puts ~1% of traffic on the hottest 1% of vertices;
        // zipf(1.1) concentrates a large multiple of that.
        assert!(head(&uni) < 0.05, "uniform head share {}", head(&uni));
        assert!(
            head(&zipf) > head(&uni) * 5.0,
            "zipf head share {} vs uniform {}",
            head(&zipf),
            head(&uni)
        );
        // Still deterministic in the seed and schedule-compatible: the
        // arrival times are identical, only targets changed.
        for (u, z) in uni.iter().zip(zipf.iter()) {
            assert_eq!(u.t_us, z.t_us);
            assert_eq!(u.model, z.model);
        }
        let zipf2 = generate_arrivals(
            poisson(500.0),
            &mix,
            TargetDist::from_skew(1.1),
            4000,
            n,
            21,
        );
        assert_eq!(zipf, zipf2);
    }
}
