//! Shared degree-aware feature cache fronting the shard pool.
//!
//! Real GNN serving is dominated by irregular feature reads: a few
//! high-degree vertices appear in a large fraction of sampled
//! neighborhoods while the long tail is touched once and never again
//! (GNNIE's "degree-aware caching" observation). This cache exploits
//! that skew with a **clock / second-chance** replacement policy whose
//! protection level is **degree-weighted**: a row's initial (and
//! hit-refreshed) life count grows with its vertex's out-degree, so hub
//! rows survive scans of cold tail rows instead of being evicted by
//! them.
//!
//! The cache is shared across executor shards behind one mutex; rows
//! are small (`f_in` f32s) and the critical section is a hash probe
//! plus a memcpy, so contention stays far below the execute cost.
//! Synthesis of a missing row is deterministic per vertex id
//! ([`crate::runtime::fill_feature_row`]), which keeps every consumer
//! of the cache bit-identical regardless of hit/miss interleaving —
//! the property the shard-pool identity tests rely on.
//!
//! Hit/miss counters are kept outside the mutex (relaxed atomics) and
//! are mirrored by the cycle simulator's `cache_features` accounting
//! ([`crate::sim::ActivityCounters::feature_hit_rate`]), so host-side
//! and simulated on-chip hit rates can be compared side by side in
//! `BENCH_serve.json`.

use crate::graph::CsrGraph;
use crate::runtime::fill_feature_row;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Degree-class breakpoints: a vertex of out-degree `d` gets protection
/// class 1 (`d <= b1`), 2 (`d <= b2`), 3 (`d <= b3`), or 4 (hubs).
///
/// The defaults (2/8/32) were hand-picked for the synthetic Table-I
/// zipf graphs; [`DegreeClasses::from_graph`] calibrates them to the
/// *served* dataset's actual degree quantiles (p50/p75/p90) instead, so
/// "hub" means hub relative to this graph, not to a constant. The
/// static values remain the fallback when no graph statistics are
/// available (empty graph, or callers without one).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DegreeClasses {
    pub b1: usize,
    pub b2: usize,
    pub b3: usize,
}

impl Default for DegreeClasses {
    fn default() -> Self {
        Self { b1: 2, b2: 8, b3: 32 }
    }
}

impl DegreeClasses {
    /// Calibrate breakpoints from the graph's out-degree distribution:
    /// b1/b2/b3 = p50/p75/p90. Quantile ties are forced strictly
    /// increasing so all four classes stay reachable; an empty graph
    /// falls back to the static defaults.
    pub fn from_graph(g: &CsrGraph) -> Self {
        let n = g.num_vertices();
        Self::from_degrees((0..n as u32).map(|v| g.degree(v)).collect())
    }

    /// Calibrate breakpoints from an explicit degree sample — the
    /// partition-local path hands in only the degrees a shard actually
    /// owns, so "hub" means hub *within that partition* (a degree-
    /// balanced split concentrates hubs, shifting these quantiles well
    /// above the whole-graph ones).
    pub fn from_degrees(mut degrees: Vec<usize>) -> Self {
        let n = degrees.len();
        if n == 0 {
            return Self::default();
        }
        degrees.sort_unstable();
        let q = |p: f64| degrees[((n - 1) as f64 * p) as usize];
        let b1 = q(0.50).max(1);
        let b2 = q(0.75).max(b1 + 1);
        let b3 = q(0.90).max(b2 + 1);
        Self { b1, b2, b3 }
    }

    /// Protection level for an out-degree: hubs get more second chances.
    /// Public so other degree-aware caches (the activation memo cache)
    /// share one notion of "hub" per graph/partition.
    pub fn class(&self, degree: usize) -> u8 {
        if degree <= self.b1 {
            1
        } else if degree <= self.b2 {
            2
        } else if degree <= self.b3 {
            3
        } else {
            4
        }
    }
}

/// One cached feature row.
struct Slot {
    v: u32,
    /// Second-chance lives left; refreshed to the degree class on hit,
    /// decremented by the clock hand, evicted at 0.
    lives: u8,
    row: Vec<f32>,
}

struct Inner {
    /// vertex id -> slot index.
    index: HashMap<u32, usize>,
    slots: Vec<Slot>,
    /// Clock hand over `slots`.
    hand: usize,
}

/// Degree-aware clock cache of synthesized feature rows. See the
/// module docs for the policy.
pub struct FeatureCache {
    inner: Mutex<Inner>,
    capacity: usize,
    f_in: usize,
    classes: DegreeClasses,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl FeatureCache {
    /// A cache holding at most `capacity` rows of `f_in` features, with
    /// the static default degree classes. `capacity == 0` disables
    /// caching (every access is a miss that synthesizes in place —
    /// useful as an ablation baseline).
    pub fn new(capacity: usize, f_in: usize) -> Self {
        Self::with_classes(capacity, f_in, DegreeClasses::default())
    }

    /// A cache with explicit degree-class breakpoints (usually
    /// [`DegreeClasses::from_graph`] over the serving graph).
    pub fn with_classes(capacity: usize, f_in: usize, classes: DegreeClasses) -> Self {
        Self {
            inner: Mutex::new(Inner {
                index: HashMap::with_capacity(capacity),
                slots: Vec::with_capacity(capacity),
                hand: 0,
            }),
            capacity,
            f_in,
            classes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn f_in(&self) -> usize {
        self.f_in
    }

    /// Maximum resident rows (0 = caching disabled).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The degree-class breakpoints this cache protects with.
    pub fn classes(&self) -> DegreeClasses {
        self.classes
    }

    /// Append vertex `v`'s `f_in` feature values to `out`. `degree` is
    /// the vertex's out-degree in the serving graph (drives admission
    /// protection). The returned values are identical whether the call
    /// hits or misses.
    pub fn append_row(&self, v: u32, degree: usize, out: &mut Vec<f32>) {
        if self.capacity == 0 {
            let start = out.len();
            out.resize(start + self.f_in, 0.0);
            fill_feature_row(v, &mut out[start..]);
            self.misses.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let mut inner = self.inner.lock().expect("feature cache poisoned");
        if let Some(&si) = inner.index.get(&v) {
            let class = self.classes.class(degree);
            let slot = &mut inner.slots[si];
            slot.lives = slot.lives.max(class);
            out.extend_from_slice(&slot.row);
            drop(inner);
            self.hits.fetch_add(1, Ordering::Relaxed);
            return;
        }
        // Miss: synthesize straight into the caller's buffer, then admit
        // a copy under the degree-weighted clock policy.
        let start = out.len();
        out.resize(start + self.f_in, 0.0);
        fill_feature_row(v, &mut out[start..]);
        self.admit(&mut inner, v, degree, &out[start..]);
        drop(inner);
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Copy vertex `v`'s row into `dst` (exactly `f_in` long).
    pub fn copy_row(&self, v: u32, degree: usize, dst: &mut [f32]) {
        debug_assert_eq!(dst.len(), self.f_in);
        if self.capacity == 0 {
            fill_feature_row(v, dst);
            self.misses.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let mut inner = self.inner.lock().expect("feature cache poisoned");
        if let Some(&si) = inner.index.get(&v) {
            let class = self.classes.class(degree);
            let slot = &mut inner.slots[si];
            slot.lives = slot.lives.max(class);
            dst.copy_from_slice(&slot.row);
            drop(inner);
            self.hits.fetch_add(1, Ordering::Relaxed);
            return;
        }
        fill_feature_row(v, dst);
        self.admit(&mut inner, v, degree, dst);
        drop(inner);
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Degree-weighted admission: when the cache is full, each miss
    /// advances the clock hand one step. The resident under the hand is
    /// evicted only if its remaining lives do not exceed the
    /// candidate's degree class; otherwise it loses one life and the
    /// candidate is *bypassed* (served but not cached). One probe per
    /// miss keeps a burst of cold tail rows from stripping more than
    /// one life per miss off the hub rows — a cold scan must pay
    /// `capacity × (class − 1)` misses before the first hub falls out,
    /// while an equal-or-hotter candidate still replaces in O(1). The
    /// evicted slot's buffer is reused (no steady-state allocation).
    fn admit(&self, inner: &mut Inner, v: u32, degree: usize, row: &[f32]) {
        let lives = self.classes.class(degree);
        if inner.slots.len() < self.capacity {
            let si = inner.slots.len();
            inner.slots.push(Slot { v, lives, row: row.to_vec() });
            inner.index.insert(v, si);
            return;
        }
        let hand = inner.hand;
        inner.hand = (inner.hand + 1) % inner.slots.len();
        if inner.slots[hand].lives <= lives {
            let old_v = inner.slots[hand].v;
            inner.index.remove(&old_v);
            let slot = &mut inner.slots[hand];
            slot.v = v;
            slot.lives = lives;
            slot.row.clear();
            slot.row.extend_from_slice(row);
            inner.index.insert(v, hand);
        } else {
            inner.slots[hand].lives -= 1;
        }
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Hit fraction over the cache's lifetime (0.0 when untouched).
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits() as f64;
        let m = self.misses() as f64;
        if h + m > 0.0 {
            h / (h + m)
        } else {
            0.0
        }
    }

    /// Rows currently resident.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("feature cache poisoned").slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Reset the hit/miss counters (the resident rows stay — useful for
    /// excluding warmup from a measurement window).
    pub fn reset_stats(&self) {
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::feature_rows;

    #[test]
    fn rows_match_feature_store_synthesis() {
        let cache = FeatureCache::new(8, 6);
        let mut out = Vec::new();
        cache.append_row(42, 1, &mut out); // miss
        cache.append_row(42, 1, &mut out); // hit
        let want = feature_rows(&[42], 6, 1);
        assert_eq!(&out[..6], &want[..]);
        assert_eq!(&out[6..], &want[..], "hit must replay the same row");
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert!((cache.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let cache = FeatureCache::new(0, 4);
        let mut out = Vec::new();
        cache.append_row(7, 100, &mut out);
        cache.append_row(7, 100, &mut out);
        assert_eq!(cache.hits(), 0);
        assert_eq!(cache.misses(), 2);
        assert_eq!(&out[..4], &out[4..], "synthesis is deterministic");
        assert!(cache.is_empty());
    }

    #[test]
    fn high_degree_rows_survive_cold_scans() {
        // A 4-row cache holding four hub rows (degree 100 => 4 lives); a
        // scan of 12 distinct degree-1 rows costs each hub at most 3
        // lives (one probe per miss), so every hub stays resident —
        // where a plain FIFO/clock of 1-life entries would have flushed
        // all of them.
        let cache = FeatureCache::new(4, 2);
        let mut out = Vec::new();
        for v in 0..4u32 {
            cache.append_row(v, 100, &mut out);
        }
        for v in 1000..1012u32 {
            cache.append_row(v, 1, &mut out);
        }
        cache.reset_stats();
        for v in 0..4u32 {
            cache.append_row(v, 100, &mut out);
        }
        assert_eq!(
            cache.hits(),
            4,
            "degree-weighted admission must keep every hub resident through the scan"
        );
        // A longer scan does eventually turn the cache over (no pinning).
        for v in 2000..2200u32 {
            cache.append_row(v, 1, &mut out);
        }
        cache.reset_stats();
        let mut probe = Vec::new();
        cache.append_row(2199, 1, &mut probe);
        // The last cold row was either admitted or bypassed; either way
        // the cache still functions and holds exactly `capacity` rows.
        assert_eq!(cache.len(), 4);
    }

    #[test]
    fn fifo_clock_evicts_equal_degree_rows() {
        // Equal degrees degrade to plain second-chance: filling past
        // capacity evicts, and the cache never exceeds capacity.
        let cache = FeatureCache::new(3, 2);
        let mut out = Vec::new();
        for v in 0..10u32 {
            cache.append_row(v, 1, &mut out);
        }
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.misses(), 10);
    }

    #[test]
    fn degree_classes_calibrate_from_graph_quantiles() {
        use crate::graph::{generate, GeneratorParams};
        let g = generate(&GeneratorParams {
            nodes: 3_000,
            mean_degree: 8.0,
            ..Default::default()
        });
        let c = DegreeClasses::from_graph(&g);
        // Quantiles are strictly increasing and ordered like the degree
        // distribution (zipf: p50 < p75 < p90 << max).
        assert!(c.b1 >= 1 && c.b1 < c.b2 && c.b2 < c.b3, "{c:?}");
        // The calibrated breakpoints classify ~half the vertices as
        // class 1 and only a small head above class 3.
        let n = g.num_vertices();
        let class_le_1 =
            (0..n as u32).filter(|&v| g.degree(v) <= c.b1).count() as f64 / n as f64;
        let hubs = (0..n as u32).filter(|&v| g.degree(v) > c.b3).count() as f64 / n as f64;
        assert!(class_le_1 >= 0.5, "p50 breakpoint covers {class_le_1}");
        assert!(hubs <= 0.12, "hub fraction {hubs}");
        // Deterministic, and wired through the constructor.
        assert_eq!(c, DegreeClasses::from_graph(&g));
        let cache = FeatureCache::with_classes(8, 4, c);
        assert_eq!(cache.classes(), c);
    }

    #[test]
    fn empty_graph_falls_back_to_static_classes() {
        let g = crate::graph::CsrGraph::from_adjacency(Vec::new());
        assert_eq!(DegreeClasses::from_graph(&g), DegreeClasses::default());
        assert_eq!(DegreeClasses::default(), DegreeClasses { b1: 2, b2: 8, b3: 32 });
    }

    #[test]
    fn from_degrees_matches_from_graph_and_recalibrates_per_partition() {
        use crate::graph::{generate, GeneratorParams};
        let g = generate(&GeneratorParams {
            nodes: 2_000,
            mean_degree: 8.0,
            ..Default::default()
        });
        let all: Vec<usize> = (0..g.num_vertices() as u32).map(|v| g.degree(v)).collect();
        assert_eq!(DegreeClasses::from_degrees(all.clone()), DegreeClasses::from_graph(&g));
        assert_eq!(DegreeClasses::from_degrees(Vec::new()), DegreeClasses::default());
        // A hub-only sample must calibrate strictly above the tail-only
        // sample: "hub" is relative to the partition, not the graph.
        let mut sorted = all;
        sorted.sort_unstable();
        let half = sorted.len() / 2;
        let tail = DegreeClasses::from_degrees(sorted[..half].to_vec());
        let head = DegreeClasses::from_degrees(sorted[half..].to_vec());
        assert!(head.b1 >= tail.b1 && head.b3 > tail.b3, "head {head:?} vs tail {tail:?}");
    }

    #[test]
    fn capacity_accessor_reports_the_construction_budget() {
        assert_eq!(FeatureCache::new(12, 4).capacity(), 12);
        assert_eq!(FeatureCache::new(0, 4).capacity(), 0);
    }

    #[test]
    fn copy_row_matches_append_row() {
        let cache = FeatureCache::new(4, 5);
        let mut a = Vec::new();
        cache.append_row(9, 2, &mut a);
        let mut b = vec![0.0f32; 5];
        cache.copy_row(9, 2, &mut b);
        assert_eq!(&a[..], &b[..]);
        assert_eq!(cache.hits(), 1);
    }
}
