//! Cross-request activation memoization (PR 10): a degree-aware clock
//! cache of *interior-layer embeddings*, the dual of GRIP's
//! vertex-tiling. Vertex tiling increases **weight** reuse within one
//! execution; this cache adds **activation** reuse across executions —
//! on a static graph with seed-derived serving weights, the post-layer
//! Q4.12 row of any interior vertex is a pure function of
//! `(ModelKey, weight_seed, layer, vertex)` (the sampler draws
//! deterministically per vertex/layer), so high-degree hubs that land
//! in almost every sampled nodeflow need only be computed once.
//!
//! Exactness is structural, not approximate: the cache stores the
//! post-program Q4.12 rows the fixed-point executor produced, and a
//! hit is spliced back in bit-for-bit ([`crate::nodeflow::MemoPlan`]),
//! so replies are identical with the cache on, off, tight, or
//! thrashing. What a hit *changes* is work: the nodeflow builder
//! prunes the hit vertex's whole sampling subtree — fewer edges
//! gathered, fewer layer-0 rows staged, smaller matmuls.
//!
//! Policy mirrors the feature cache ([`super::feature_cache`]):
//! clock/second-chance eviction with degree-weighted lives. Admission
//! is stricter — only the top two [`DegreeClasses`] (degree above the
//! calibrated p75) may enter, because a tail vertex's embedding is
//! nearly never re-requested while it costs the same bytes as a hub's.
//! One instance per partition when serving partitioned (budget split
//! like `--cache-rows`), one shared instance otherwise.

use super::feature_cache::DegreeClasses;
use crate::fixed::Fx16;
use crate::greta::ModelKey;
use crate::nodeflow::{MemoHarvest, MemoProbe};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Bytes per cached value (one Q4.12 `Fx16`).
pub const MEMO_VALUE_BYTES: u64 = 2;

/// Minimum [`DegreeClasses::class`] admitted: hubs only (class 3 and 4,
/// i.e. degree above the calibrated p75).
pub const MEMO_MIN_CLASS: u8 = 3;

/// Full cache key: embeddings are pure in all four components, and all
/// four are necessary — two weight seeds (or two models) must never
/// share an entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MemoKey {
    pub model: ModelKey,
    pub seed: u64,
    pub layer: u32,
    pub vertex: u32,
}

struct Slot {
    key: MemoKey,
    /// Second-chance lives; refreshed to the degree class on hit,
    /// decremented by the clock hand.
    lives: u8,
    class: u8,
    row: Vec<Fx16>,
}

struct Inner {
    index: HashMap<MemoKey, usize>,
    slots: Vec<Slot>,
    hand: usize,
    /// Σ row lengths over resident slots (rows vary in width per
    /// model/layer), for byte accounting.
    resident_values: u64,
}

/// Degree-aware clock cache of interior-layer Q4.12 embedding rows.
/// See the module docs for the policy and exactness argument.
pub struct MemoCache {
    inner: Mutex<Inner>,
    capacity: usize,
    classes: DegreeClasses,
    hits: AtomicU64,
    misses: AtomicU64,
    deposits: AtomicU64,
    evictions: AtomicU64,
}

impl MemoCache {
    /// A cache holding at most `capacity` rows, admitting only vertices
    /// whose degree class under `classes` is ≥ [`MEMO_MIN_CLASS`].
    /// `capacity == 0` disables memoization entirely (no admission, no
    /// counters — the `--memo-rows 0` baseline).
    pub fn with_classes(capacity: usize, classes: DegreeClasses) -> Self {
        Self {
            inner: Mutex::new(Inner {
                index: HashMap::with_capacity(capacity),
                slots: Vec::with_capacity(capacity),
                hand: 0,
                resident_values: 0,
            }),
            capacity,
            classes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            deposits: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Maximum resident rows (0 = memoization disabled).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn classes(&self) -> DegreeClasses {
        self.classes
    }

    /// Hub-only admission gate: would a row for a vertex of this
    /// out-degree be stored at all?
    pub fn admits(&self, degree: usize) -> bool {
        self.capacity > 0 && self.classes.class(degree) >= MEMO_MIN_CLASS
    }

    /// The exact cached row, if resident. A hit refreshes the slot's
    /// second-chance lives; a miss only counts (the deposit comes later
    /// from the executor's harvest).
    pub fn lookup(&self, key: MemoKey) -> Option<Vec<Fx16>> {
        if self.capacity == 0 {
            return None;
        }
        let mut inner = self.inner.lock().expect("memo cache poisoned");
        if let Some(&si) = inner.index.get(&key) {
            let slot = &mut inner.slots[si];
            slot.lives = slot.lives.max(slot.class);
            let row = slot.row.clone();
            drop(inner);
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Some(row);
        }
        drop(inner);
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Offer a freshly computed row under the degree-weighted clock
    /// policy (same single-probe second-chance as the feature cache:
    /// the resident under the hand is evicted only if its lives do not
    /// exceed the candidate's class, else it loses one life and the
    /// candidate is bypassed). Duplicate keys are dropped — the first
    /// deposit already holds the (identical, pure) value.
    pub fn insert(&self, key: MemoKey, degree: usize, row: Vec<Fx16>) {
        if !self.admits(degree) {
            return;
        }
        let class = self.classes.class(degree);
        let mut inner = self.inner.lock().expect("memo cache poisoned");
        if inner.index.contains_key(&key) {
            return;
        }
        if inner.slots.len() < self.capacity {
            let si = inner.slots.len();
            inner.resident_values += row.len() as u64;
            inner.slots.push(Slot { key, lives: class, class, row });
            inner.index.insert(key, si);
            drop(inner);
            self.deposits.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let hand = inner.hand;
        inner.hand = (inner.hand + 1) % inner.slots.len();
        if inner.slots[hand].lives <= class {
            let old_key = inner.slots[hand].key;
            let old_len = inner.slots[hand].row.len() as u64;
            inner.index.remove(&old_key);
            inner.resident_values = inner.resident_values - old_len + row.len() as u64;
            let slot = &mut inner.slots[hand];
            slot.key = key;
            slot.lives = class;
            slot.class = class;
            slot.row = row;
            inner.index.insert(key, hand);
            drop(inner);
            self.deposits.fetch_add(1, Ordering::Relaxed);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        } else {
            inner.slots[hand].lives -= 1;
        }
    }

    /// Move an executor harvest into the cache (one insert per row).
    pub fn deposit(&self, model: ModelKey, seed: u64, harvest: MemoHarvest) {
        for r in harvest.rows {
            let key = MemoKey { model, seed, layer: r.layer, vertex: r.vertex };
            self.insert(key, r.degree as usize, r.values);
        }
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn deposits(&self) -> u64 {
        self.deposits.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Hit fraction over the cache's lifetime (0.0 when untouched).
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits() as f64;
        let m = self.misses() as f64;
        if h + m > 0.0 {
            h / (h + m)
        } else {
            0.0
        }
    }

    /// Rows currently resident.
    pub fn resident_rows(&self) -> usize {
        self.inner.lock().expect("memo cache poisoned").slots.len()
    }

    /// Bytes currently resident (2 bytes per Q4.12 value; row widths
    /// vary per model/layer).
    pub fn resident_bytes(&self) -> u64 {
        self.inner.lock().expect("memo cache poisoned").resident_values * MEMO_VALUE_BYTES
    }
}

/// One request's view of a [`MemoCache`]: the cache handle plus the
/// `(model, weight_seed)` key context, presented to the nodeflow
/// builder as a [`MemoProbe`]. Keeps the nodeflow crate ignorant of
/// cache policy and key layout.
pub struct MemoScope<'a> {
    cache: &'a MemoCache,
    model: ModelKey,
    seed: u64,
}

impl<'a> MemoScope<'a> {
    pub fn new(cache: &'a MemoCache, model: ModelKey, seed: u64) -> Self {
        Self { cache, model, seed }
    }
}

impl MemoProbe for MemoScope<'_> {
    fn admits(&self, _layer: usize, _vertex: u32, degree: usize) -> bool {
        self.cache.admits(degree)
    }

    fn lookup(&self, layer: usize, vertex: u32) -> Option<Vec<Fx16>> {
        self.cache.lookup(MemoKey {
            model: self.model,
            seed: self.seed,
            layer: layer as u32,
            vertex,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn classes() -> DegreeClasses {
        // b1/b2/b3 = 2/8/32: class 3 starts above degree 8.
        DegreeClasses::default()
    }

    fn key(seed: u64, layer: u32, vertex: u32) -> MemoKey {
        MemoKey { model: ModelKey::from_index(0), seed, layer, vertex }
    }

    fn row(tag: i16) -> Vec<Fx16> {
        vec![Fx16(tag); 4]
    }

    #[test]
    fn hub_only_admission() {
        let c = MemoCache::with_classes(8, classes());
        assert!(!c.admits(1), "tail (class 1) never admitted");
        assert!(!c.admits(8), "class 2 never admitted");
        assert!(c.admits(9), "class 3 admitted");
        assert!(c.admits(1000), "class 4 admitted");
        c.insert(key(0, 0, 1), 1, row(1));
        assert_eq!(c.resident_rows(), 0, "tail insert is dropped");
        c.insert(key(0, 0, 2), 100, row(2));
        assert_eq!(c.resident_rows(), 1);
        assert_eq!(c.deposits(), 1);
        assert_eq!(c.resident_bytes(), 4 * MEMO_VALUE_BYTES);
    }

    #[test]
    fn lookup_returns_exact_bytes_and_counts() {
        let c = MemoCache::with_classes(8, classes());
        c.insert(key(7, 1, 42), 50, row(1234));
        assert_eq!(c.lookup(key(7, 1, 42)), Some(row(1234)));
        assert_eq!(c.lookup(key(7, 1, 43)), None);
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn weight_seeds_never_share_an_entry() {
        let c = MemoCache::with_classes(8, classes());
        c.insert(key(1, 0, 9), 100, row(11));
        c.insert(key(2, 0, 9), 100, row(22));
        assert_eq!(c.lookup(key(1, 0, 9)), Some(row(11)));
        assert_eq!(c.lookup(key(2, 0, 9)), Some(row(22)));
        assert_eq!(c.resident_rows(), 2, "distinct seeds occupy distinct slots");
        // Same isolation across layers and models.
        assert_eq!(c.lookup(key(1, 1, 9)), None);
        let other_model = MemoKey { model: ModelKey::from_index(1), ..key(1, 0, 9) };
        assert_eq!(c.lookup(other_model), None);
    }

    #[test]
    fn duplicate_deposit_is_dropped() {
        let c = MemoCache::with_classes(8, classes());
        c.insert(key(0, 0, 5), 100, row(1));
        c.insert(key(0, 0, 5), 100, row(2));
        assert_eq!(c.deposits(), 1);
        assert_eq!(c.lookup(key(0, 0, 5)), Some(row(1)), "first (pure) value wins");
    }

    #[test]
    fn zero_capacity_disables_everything() {
        let c = MemoCache::with_classes(0, classes());
        assert!(!c.admits(10_000));
        c.insert(key(0, 0, 1), 10_000, row(1));
        assert_eq!(c.lookup(key(0, 0, 1)), None);
        assert_eq!(c.hits() + c.misses() + c.deposits(), 0, "off = no counters");
        assert_eq!(c.resident_rows(), 0);
        assert_eq!(c.resident_bytes(), 0);
    }

    #[test]
    fn clock_eviction_bounds_residency_and_tracks_bytes() {
        let c = MemoCache::with_classes(2, classes());
        for v in 0..10u32 {
            c.insert(key(0, 0, v), 9, row(v as i16));
        }
        assert_eq!(c.resident_rows(), 2, "never exceeds capacity");
        assert!(c.evictions() > 0, "equal-class inserts must turn the cache over");
        assert_eq!(c.resident_bytes(), 2 * 4 * MEMO_VALUE_BYTES);
        // Higher-class (hub) rows resist eviction by equal-or-lower
        // candidates for `class` hand passes.
        let c2 = MemoCache::with_classes(1, classes());
        c2.insert(key(0, 0, 1), 1000, row(1)); // class 4
        c2.insert(key(0, 0, 2), 9, row(2)); // class 3: bypassed 1st try
        assert_eq!(c2.lookup(key(0, 0, 1)), Some(row(1)));
        assert_eq!(c2.lookup(key(0, 0, 2)), None);
    }

    #[test]
    fn scope_probe_translates_layer_and_vertex() {
        let c = MemoCache::with_classes(4, classes());
        let m = ModelKey::from_index(3);
        c.insert(MemoKey { model: m, seed: 99, layer: 1, vertex: 7 }, 100, row(5));
        let scope = MemoScope::new(&c, m, 99);
        assert!(MemoProbe::admits(&scope, 1, 7, 100));
        assert!(!MemoProbe::admits(&scope, 1, 7, 2));
        assert_eq!(MemoProbe::lookup(&scope, 1, 7), Some(row(5)));
        assert_eq!(MemoProbe::lookup(&scope, 0, 7), None, "layer is part of the key");
        let wrong_seed = MemoScope::new(&c, m, 98);
        assert_eq!(MemoProbe::lookup(&wrong_seed, 1, 7), None);
    }

    #[test]
    fn deposit_moves_harvest_rows_under_admission() {
        use crate::nodeflow::HarvestRow;
        let c = MemoCache::with_classes(8, classes());
        let mut h = MemoHarvest::default();
        h.rows.push(HarvestRow { layer: 0, vertex: 1, degree: 100, values: row(1) });
        h.rows.push(HarvestRow { layer: 0, vertex: 2, degree: 1, values: row(2) });
        let m = ModelKey::from_index(0);
        c.deposit(m, 5, h);
        assert_eq!(c.resident_rows(), 1, "tail harvest row filtered at deposit");
        assert_eq!(c.lookup(MemoKey { model: m, seed: 5, layer: 0, vertex: 1 }), Some(row(1)));
    }
}
