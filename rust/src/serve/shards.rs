//! Sharded executor pool: N executor shards behind one work queue,
//! fronted by the shared degree-aware [`FeatureCache`].
//!
//! PR 1 parallelized nodeflow *builds* but left execution on a single
//! thread; PR 2 sharded the fixed-point datapath; PR 4 made the
//! engine itself pluggable. Each shard owns a boxed
//! [`NumericsBackend`] built **inside its own thread** by the
//! [`BackendFactory`], plus that backend's prepared per-model state
//! ([`PreparedModel`]: quantized weights, device-resident PJRT
//! buffers) and a [`BackendScratch`] arena — so shards share **no
//! mutable state** except the feature cache, and execution scales
//! across cores for *every* engine. In particular the PJRT float path
//! is no longer pinned to shard 0: every shard constructs its own
//! (non-`Send`) client with its own device weights.
//!
//! A shard whose configured backend fails to construct or prepare
//! (PJRT runtime stubbed out, artifact manifest missing) falls back to
//! timing-only serving; the failure is counted in
//! [`ServeStats::backend_fallbacks`] and the per-shard status string
//! in [`ServeStats::shard_backends`] carries the error — it no longer
//! vanishes into stderr. (A single broken *model* inside an otherwise
//! healthy backend stays per-model: its requests get error replies
//! while sibling models keep serving.)
//!
//! Replies must not depend on which shard served them: every backend's
//! `execute` is deterministic in (prepared state, nodeflow, features),
//! per-request results depend only on vertex ids — sampled nodeflow,
//! synthesized features, and the deterministic serving weights — never
//! on scheduling. `tests/serve_props.rs` and
//! `tests/backend_conformance.rs` pin this for any shard count.

use crate::backend::{
    BackendChoice, BackendFactory, BackendScratch, NumericsBackend, PreparedModel,
};
use crate::config::{GripConfig, ModelConfig};
use crate::coordinator::InferenceResponse;
use crate::graph::CsrGraph;
use crate::greta::{exec_test_args, ExecArgs, ModelKey, ModelLibrary, ModelPlan, SelfScale};
use crate::nodeflow::Nodeflow;
use crate::runtime::{fill_feature_row, FeatureSource};
use crate::serve::{DegreeClasses, FeatureCache};
use crate::sim::simulate;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// One original caller's stake in a (possibly coalesced) job: its id,
/// how many of the job's targets are its, and where to send the reply.
pub struct ReplySlot {
    pub id: u64,
    pub n_targets: usize,
    pub t_submit: Instant,
    pub reply: mpsc::Sender<Result<InferenceResponse, String>>,
}

/// A unit of executor work: a built nodeflow plus the reply slots of
/// every request coalesced into it (one slot for direct submissions).
pub struct ExecJob {
    /// Model to execute, resolved against the pool's [`ModelLibrary`].
    pub model: ModelKey,
    pub nf: Nodeflow,
    pub members: Vec<ReplySlot>,
    /// When a builder dequeued the job (start of service time).
    pub t_dequeue: Instant,
}

/// Pool configuration (a plain-data subset of the coordinator's
/// `ServeConfig`, cloneable into each shard thread).
#[derive(Debug, Clone)]
pub struct ShardSpec {
    pub shards: usize,
    pub grip: GripConfig,
    pub model_cfg: ModelConfig,
    /// Execution engine every shard runs (the [`BackendFactory`] is
    /// invoked once per shard, inside the shard thread). Replaces the
    /// old `pjrt`/`fixed_numerics` bool pair.
    pub backend: BackendChoice,
    /// Shared feature-cache capacity in rows (0 disables caching).
    pub cache_rows: usize,
    /// Seed of the deterministic fixed-point serving weights.
    pub weight_seed: u64,
}

impl Default for ShardSpec {
    fn default() -> Self {
        Self {
            shards: 1,
            grip: GripConfig::paper(),
            model_cfg: ModelConfig::paper(),
            backend: BackendChoice::TimingOnly,
            cache_rows: 4096,
            weight_seed: 0x5EED_5E4E,
        }
    }
}

/// Monotonic pool counters (relaxed atomics; snapshot via
/// [`ShardPool::stats`]).
#[derive(Debug, Default)]
struct PoolCounters {
    jobs: AtomicU64,
    timing_only: AtomicU64,
    backend_fallbacks: AtomicU64,
    sim_rows_touched: AtomicU64,
    sim_rows_loaded: AtomicU64,
}

/// A point-in-time view of the pool's serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Executor shards actually running.
    pub shards: usize,
    /// Jobs executed (batches count once).
    pub jobs: u64,
    /// Jobs that produced no numeric embedding (see
    /// `InferenceResponse::timing_only`).
    pub timing_only_jobs: u64,
    /// Shards whose configured backend failed to construct/prepare and
    /// fell back to timing-only serving (the old stderr-only "PJRT
    /// unavailable" signal, now first-class).
    pub backend_fallbacks: u64,
    /// Per-shard backend status: the engine name, or
    /// `timing-only (fallback: <error>)` after a fallback.
    pub shard_backends: Vec<String>,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Host-side feature-cache hit fraction.
    pub cache_hit_rate: f64,
    /// The cycle simulator's on-chip feature hit fraction over the same
    /// jobs (`cache_features` accounting) — comparable to
    /// `cache_hit_rate` in `BENCH_serve.json`.
    pub sim_feature_hit_rate: f64,
}

/// The executor pool. Threads drain the `ExecJob` receiver until its
/// sender side closes; dropping the pool joins them.
pub struct ShardPool {
    threads: Vec<std::thread::JoinHandle<()>>,
    cache: Arc<FeatureCache>,
    counters: Arc<PoolCounters>,
    status: Arc<Mutex<Vec<String>>>,
    shards: usize,
}

/// Deterministic fixed-point serving weights for `plan` (the Q4.12
/// analogue of `runtime::serving_weights`): every transform weight from
/// the shared test-weight generator, plus a scalar for every
/// `one_plus_arg` self-scale the plan declares (layer `i` gets
/// `0.1 * (i + 1)` — exactly the eps1 = 0.1 / eps2 = 0.2 the GIN preset
/// served before the spec redesign, now derived from plan structure
/// instead of hardcoded names). Identical on every shard for a given
/// seed — the root of the pool's bit-identity guarantee.
pub fn fixed_serving_args(plan: &ModelPlan, seed: u64) -> ExecArgs {
    let mut args = exec_test_args(plan, seed);
    for (li, layer) in plan.layers.iter().enumerate() {
        for p in &layer.programs {
            if let Some(SelfScale::OnePlusArg(name)) = &p.self_scale {
                args.entry(name.clone())
                    .or_insert_with(|| (Vec::new(), vec![0.1 * (li as f32 + 1.0)]));
            }
        }
    }
    args
}

/// [`FeatureSource`] adapter: serve rows from the shared cache, using
/// the serving graph's out-degree as the admission weight. Rows whose
/// width differs from the cache's configured `f_in` (a custom spec
/// with non-default dims) bypass the cache and synthesize directly —
/// the cache stores a single fixed row width.
pub struct CachedFeatures<'a> {
    pub cache: &'a FeatureCache,
    pub graph: &'a CsrGraph,
}

impl FeatureSource for CachedFeatures<'_> {
    fn fill_row(&mut self, v: u32, dst: &mut [f32]) {
        if dst.len() == self.cache.f_in() {
            self.cache.copy_row(v, self.graph.degree(v), dst);
        } else {
            fill_feature_row(v, dst);
        }
    }
}

impl ShardPool {
    /// Spawn the pool over `rx`, serving the models in `library`.
    /// `spec.shards` shards share the queue regardless of backend —
    /// each shard builds its own engine (and, for PJRT, its own
    /// non-`Send` client + device-resident weights) inside its thread,
    /// so no engine pins the pool to one shard anymore. The shared
    /// feature cache's degree classes are calibrated from the serving
    /// graph's degree quantiles ([`DegreeClasses::from_graph`]).
    /// `inflight` is decremented once per completed job — the gauge the
    /// coordinator's batcher uses for idle-aware early dispatch (the
    /// sender increments it on enqueue).
    pub fn start(
        spec: &ShardSpec,
        library: Arc<ModelLibrary>,
        graph: Arc<CsrGraph>,
        rx: mpsc::Receiver<ExecJob>,
        inflight: Arc<AtomicU64>,
    ) -> Result<ShardPool> {
        let shards = spec.shards.max(1);
        // Quantile calibration walks + sorts every vertex degree — skip
        // it when caching is disabled (cache_rows 0 never admits).
        let classes = if spec.cache_rows > 0 {
            DegreeClasses::from_graph(&graph)
        } else {
            DegreeClasses::default()
        };
        let cache =
            Arc::new(FeatureCache::with_classes(spec.cache_rows, spec.model_cfg.f_in, classes));
        let counters = Arc::new(PoolCounters::default());
        let status = Arc::new(Mutex::new(vec![String::from("starting"); shards]));
        let rx = Arc::new(Mutex::new(rx));
        // Shards signal here once their backend is built and every
        // model prepared; `start` blocks on all of them so the request
        // path never races engine construction and `stats()` always
        // reflects the shards' real backends.
        let (init_tx, init_rx) = mpsc::channel::<()>();
        let mut threads = Vec::with_capacity(shards);
        for i in 0..shards {
            let spec = spec.clone();
            let library = library.clone();
            let graph = graph.clone();
            let cache = cache.clone();
            let counters = counters.clone();
            let status = status.clone();
            let rx = rx.clone();
            let inflight = inflight.clone();
            let init_tx = init_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("grip-shard-{i}"))
                .spawn(move || {
                    shard_loop(
                        i, &spec, &library, &graph, &cache, &counters, &status, init_tx, &rx,
                        &inflight,
                    )
                })
                .map_err(|e| anyhow!("spawning shard {i}: {e}"))?;
            threads.push(handle);
        }
        drop(init_tx);
        for _ in 0..shards {
            // Err only if a shard panicked during init; the join in
            // Drop will surface that — don't hang here.
            let _ = init_rx.recv();
        }
        Ok(ShardPool { threads, cache, counters, status, shards })
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn stats(&self) -> ServeStats {
        let touched = self.counters.sim_rows_touched.load(Ordering::Relaxed);
        let loaded = self.counters.sim_rows_loaded.load(Ordering::Relaxed);
        let shard_backends =
            self.status.lock().map(|s| s.clone()).unwrap_or_default();
        ServeStats {
            shards: self.shards,
            jobs: self.counters.jobs.load(Ordering::Relaxed),
            timing_only_jobs: self.counters.timing_only.load(Ordering::Relaxed),
            backend_fallbacks: self.counters.backend_fallbacks.load(Ordering::Relaxed),
            shard_backends,
            cache_hits: self.cache.hits(),
            cache_misses: self.cache.misses(),
            cache_hit_rate: self.cache.hit_rate(),
            sim_feature_hit_rate: if touched > 0 {
                1.0 - loaded as f64 / touched as f64
            } else {
                0.0
            },
        }
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // The job sender must already be gone (the coordinator drops the
        // pipeline front-to-back); joining here never deadlocks because
        // each shard exits on the closed channel.
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Prepare every library model on `backend` (per-shard weight
/// residency). The serving weights are derived deterministically from
/// each plan + the pool seed, so prepared state is identical across
/// shards.
fn prepare_all(
    backend: &mut dyn NumericsBackend,
    library: &ModelLibrary,
    weight_seed: u64,
) -> Result<Vec<PreparedModel>> {
    library
        .keys()
        .map(|k| {
            let plan = library.plan(k);
            let args = fixed_serving_args(plan, weight_seed);
            backend.prepare(plan, &args)
        })
        .collect()
}

/// Build + prepare this shard's backend, degrading to the factory's
/// timing-only fallback on failure. Returns the engine, its prepared
/// models, and the status string for [`ServeStats::shard_backends`];
/// `fell_back` drives the `backend_fallbacks` counter.
struct ShardEngine {
    backend: Box<dyn NumericsBackend>,
    prepared: Vec<PreparedModel>,
    status: String,
    fell_back: bool,
}

fn init_engine(shard: usize, spec: &ShardSpec, library: &ModelLibrary) -> ShardEngine {
    let factory = BackendFactory::new(spec.backend);
    let attempt = factory.build(shard).and_then(|mut backend| {
        let prepared = prepare_all(backend.as_mut(), library, spec.weight_seed)?;
        Ok((backend, prepared))
    });
    match attempt {
        Ok((backend, prepared)) => {
            let status = backend.name().to_string();
            ShardEngine { backend, prepared, status, fell_back: false }
        }
        Err(e) => {
            let mut backend = factory.fallback();
            let prepared = prepare_all(backend.as_mut(), library, spec.weight_seed)
                .expect("timing-only prepare is infallible");
            ShardEngine {
                backend,
                prepared,
                status: format!("timing-only (fallback: {e})"),
                fell_back: true,
            }
        }
    }
}

/// One shard: build its backend *in this thread* (non-`Send` engines
/// never cross threads), prepare every library model once, signal
/// readiness on `init_tx`, then drain the shared queue.
#[allow(clippy::too_many_arguments)]
fn shard_loop(
    shard: usize,
    spec: &ShardSpec,
    library: &ModelLibrary,
    graph: &CsrGraph,
    cache: &FeatureCache,
    counters: &PoolCounters,
    status: &Mutex<Vec<String>>,
    init_tx: mpsc::Sender<()>,
    rx: &Mutex<mpsc::Receiver<ExecJob>>,
    inflight: &AtomicU64,
) {
    let mut engine = init_engine(shard, spec, library);
    if engine.fell_back {
        counters.backend_fallbacks.fetch_add(1, Ordering::Relaxed);
    }
    if let Ok(mut s) = status.lock() {
        s[shard] = engine.status.clone();
    }
    let mut scratch = BackendScratch::for_config(&spec.grip);
    // Init complete: unblock `ShardPool::start` (dropping the sender
    // right away so a sibling shard's panic can never wedge it).
    let _ = init_tx.send(());
    drop(init_tx);

    loop {
        // Hold the queue lock only while waiting; execution runs
        // unlocked so shards overlap.
        let job = {
            let guard = match rx.lock() {
                Ok(g) => g,
                Err(_) => break,
            };
            match guard.recv() {
                Ok(j) => j,
                Err(_) => break,
            }
        };
        execute_job(
            spec,
            library,
            graph,
            cache,
            counters,
            engine.backend.as_mut(),
            &engine.prepared,
            &mut scratch,
            job,
        );
        // Replies are out: this job no longer occupies the pipeline.
        inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Execute one job on `backend` and fan replies out to its members.
#[allow(clippy::too_many_arguments)]
fn execute_job(
    spec: &ShardSpec,
    library: &ModelLibrary,
    graph: &CsrGraph,
    cache: &FeatureCache,
    counters: &PoolCounters,
    backend: &mut dyn NumericsBackend,
    prepared: &[PreparedModel],
    scratch: &mut BackendScratch,
    job: ExecJob,
) {
    let ExecJob { model, nf, members, t_dequeue } = job;
    let plan = library.plan(model);

    // 1. Cycle-level accelerator timing (and the sim-side feature-cache
    //    accounting mirrored into the pool stats).
    let sim = simulate(&spec.grip, plan, &nf);
    let accel_us = sim.us(&spec.grip);
    counters.jobs.fetch_add(1, Ordering::Relaxed);
    counters
        .sim_rows_touched
        .fetch_add(sim.counters.feature_rows_touched, Ordering::Relaxed);
    counters
        .sim_rows_loaded
        .fetch_add(sim.counters.feature_rows_loaded, Ordering::Relaxed);

    // 2. Numerics: one backend call, whatever the engine. The shared
    //    cache fronts feature rows for every backend via the
    //    width-checking adapter.
    let mut features = CachedFeatures { cache, graph };
    let outcome = backend.execute(&prepared[model.index()], &nf, &mut features, scratch);

    // 3. Fan out per-member replies (a coalesced batch shares one
    //    nodeflow, one simulated pass, and one embedding buffer).
    match outcome {
        Err(e) => {
            let e = e.to_string();
            for m in members {
                let _ = m.reply.send(Err(e.clone()));
            }
        }
        Ok(out) => {
            let timing_only = !out.numerics.is_numeric();
            if timing_only {
                counters.timing_only.fetch_add(1, Ordering::Relaxed);
            }
            let service_us = t_dequeue.elapsed().as_secs_f64() * 1e6;
            let neighborhood = nf.neighborhood_size();
            let mut row = 0usize;
            for m in members {
                let embedding = if timing_only {
                    Vec::new()
                } else {
                    out.embeddings[row * out.f_out..(row + m.n_targets) * out.f_out].to_vec()
                };
                row += m.n_targets;
                let resp = InferenceResponse {
                    id: m.id,
                    embedding,
                    accel_us,
                    host_us: m.t_submit.elapsed().as_secs_f64() * 1e6,
                    service_us,
                    neighborhood,
                    timing_only,
                };
                let _ = m.reply.send(Ok(resp));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{FixedPointBackend, TimingOnlyBackend};
    use crate::graph::{generate, GeneratorParams};
    use crate::greta::GnnModel;
    use crate::nodeflow::Sampler;

    fn graph() -> Arc<CsrGraph> {
        Arc::new(generate(&GeneratorParams {
            nodes: 2_000,
            mean_degree: 8.0,
            ..Default::default()
        }))
    }

    /// An in-flight gauge pre-charged for `jobs` sends (the test
    /// harness enqueues directly, without the coordinator's increments).
    fn gauge(jobs: usize) -> Arc<AtomicU64> {
        Arc::new(AtomicU64::new(jobs as u64))
    }

    fn small_mc() -> ModelConfig {
        ModelConfig { sample1: 4, sample2: 3, f_in: 12, f_hid: 10, f_out: 6 }
    }

    fn submit(
        tx: &mpsc::Sender<ExecJob>,
        g: &CsrGraph,
        mc: &ModelConfig,
        model: GnnModel,
        id: u64,
        targets: &[u32],
    ) -> mpsc::Receiver<Result<InferenceResponse, String>> {
        let nf = Nodeflow::build(g, &Sampler::new(9), targets, mc);
        let (rtx, rrx) = mpsc::channel();
        tx.send(ExecJob {
            model: model.key(),
            nf,
            members: vec![ReplySlot {
                id,
                n_targets: targets.len(),
                t_submit: Instant::now(),
                reply: rtx,
            }],
            t_dequeue: Instant::now(),
        })
        .unwrap();
        rrx
    }

    fn run_pool_stats(
        shards: usize,
        backend: BackendChoice,
        ids: &[u32],
    ) -> (Vec<InferenceResponse>, ServeStats) {
        let g = graph();
        let mc = small_mc();
        let spec =
            ShardSpec { shards, model_cfg: mc, backend, cache_rows: 256, ..Default::default() };
        let (tx, rx) = mpsc::channel();
        let library = Arc::new(ModelLibrary::presets(&mc));
        let pool = ShardPool::start(&spec, library, g.clone(), rx, gauge(ids.len())).unwrap();
        let replies: Vec<_> = ids
            .iter()
            .enumerate()
            .map(|(i, &t)| submit(&tx, &g, &mc, GnnModel::Gcn, i as u64, &[t]))
            .collect();
        drop(tx);
        let out: Vec<InferenceResponse> =
            replies.into_iter().map(|r| r.recv().unwrap().unwrap()).collect();
        let stats = pool.stats();
        drop(pool);
        (out, stats)
    }

    fn run_pool(shards: usize, backend: BackendChoice, ids: &[u32]) -> Vec<InferenceResponse> {
        run_pool_stats(shards, backend, ids).0
    }

    #[test]
    fn fixed_point_pool_serves_embeddings() {
        let out = run_pool(2, BackendChoice::Fixed, &[1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(out.len(), 8);
        for r in &out {
            assert!(!r.timing_only);
            assert_eq!(r.embedding.len(), 6);
            assert!(r.accel_us > 0.0);
        }
    }

    #[test]
    fn pool_output_independent_of_shard_count() {
        let ids: Vec<u32> = (0..24).map(|i| i * 13 % 2000).collect();
        let one = run_pool(1, BackendChoice::Fixed, &ids);
        let four = run_pool(4, BackendChoice::Fixed, &ids);
        for (a, b) in one.iter().zip(four.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.embedding, b.embedding, "id {}", a.id);
            assert_eq!(a.accel_us, b.accel_us);
            assert_eq!(a.neighborhood, b.neighborhood);
        }
    }

    #[test]
    fn without_numerics_replies_are_flagged_timing_only() {
        let (out, stats) = run_pool_stats(2, BackendChoice::TimingOnly, &[10, 20]);
        for r in &out {
            assert!(r.timing_only);
            assert!(r.embedding.is_empty());
            assert!(r.accel_us > 0.0, "timing still served");
        }
        // An explicitly-requested timing-only engine is not a fallback.
        assert_eq!(stats.backend_fallbacks, 0);
        assert_eq!(stats.shard_backends, vec!["timing-only", "timing-only"]);
    }

    #[test]
    fn pjrt_pool_runs_every_shard_and_reports_status() {
        // The acceptance path: `--backend pjrt --shards 4` must run all
        // 4 shards (no more shard-0 pinning) whatever happens to the
        // runtime. In default builds the stub executor fails to load,
        // so every shard reports a counted timing-only fallback instead
        // of an stderr-only message.
        let ids: Vec<u32> = (0..12).map(|i| i * 7 % 2000).collect();
        let (four, stats) = run_pool_stats(4, BackendChoice::Pjrt, &ids);
        assert_eq!(stats.shards, 4, "PJRT no longer pins the pool to one shard");
        assert_eq!(stats.shard_backends.len(), 4);
        if stats.backend_fallbacks > 0 {
            // Stub executor / no artifacts: all shards fall back, all
            // replies are tagged, and the status strings say why.
            assert_eq!(stats.backend_fallbacks, 4);
            assert!(stats
                .shard_backends
                .iter()
                .all(|s| s.starts_with("timing-only (fallback:")), "{:?}", stats.shard_backends);
            assert!(four.iter().all(|r| r.timing_only && r.embedding.is_empty()));
        } else {
            // Real PJRT runtime + artifacts: every shard serves float.
            assert!(stats.shard_backends.iter().all(|s| s == "pjrt"));
        }
        // Replies are shard-count-independent either way.
        let (one, _) = run_pool_stats(1, BackendChoice::Pjrt, &ids);
        for (a, b) in one.iter().zip(four.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.embedding, b.embedding, "id {}", a.id);
            assert_eq!(a.timing_only, b.timing_only);
        }
    }

    #[test]
    fn reference_pool_matches_fixed_pool() {
        let ids: Vec<u32> = (0..10).map(|i| i * 191 % 2000).collect();
        let fixed = run_pool(2, BackendChoice::Fixed, &ids);
        let reference = run_pool(2, BackendChoice::Reference, &ids);
        for (a, b) in fixed.iter().zip(reference.iter()) {
            assert_eq!(a.embedding, b.embedding, "id {}: hot path diverged from reference", a.id);
        }
    }

    #[test]
    fn timing_only_reply_never_leaks_a_previous_jobs_embedding() {
        // Timing-only executions share one scratch arena with numeric
        // jobs on the same shard; a stale embedding buffer must never
        // fan out to members.
        let g = graph();
        let mc = small_mc();
        let spec = ShardSpec { model_cfg: mc, ..Default::default() };
        let library = ModelLibrary::presets(&mc);
        let mut fixed: Box<dyn NumericsBackend> = Box::new(FixedPointBackend::new());
        let prepared_fx =
            prepare_all(fixed.as_mut(), &library, spec.weight_seed).unwrap();
        let mut timing: Box<dyn NumericsBackend> = Box::new(TimingOnlyBackend);
        let prepared_t =
            prepare_all(timing.as_mut(), &library, spec.weight_seed).unwrap();
        let cache = FeatureCache::new(64, mc.f_in);
        let counters = PoolCounters::default();
        let mut scratch = BackendScratch::new();

        let mk_job = |id: u64| {
            let nf = Nodeflow::build(&g, &Sampler::new(9), &[7], &mc);
            let (rtx, rrx) = mpsc::channel();
            let job = ExecJob {
                model: GnnModel::Gcn.key(),
                nf,
                members: vec![ReplySlot {
                    id,
                    n_targets: 1,
                    t_submit: Instant::now(),
                    reply: rtx,
                }],
                t_dequeue: Instant::now(),
            };
            (job, rrx)
        };

        // 1. A numeric job fills the shared embedding buffer.
        let (job, rx1) = mk_job(0);
        execute_job(
            &spec, &library, &g, &cache, &counters, fixed.as_mut(), &prepared_fx,
            &mut scratch, job,
        );
        let r1 = rx1.recv().unwrap().unwrap();
        assert!(!r1.timing_only && !r1.embedding.is_empty());

        // 2. A timing-only job reusing the same scratch must reply empty.
        let (job, rx2) = mk_job(1);
        execute_job(
            &spec, &library, &g, &cache, &counters, timing.as_mut(), &prepared_t,
            &mut scratch, job,
        );
        let r2 = rx2.recv().unwrap().unwrap();
        assert!(r2.timing_only, "no numeric path ran");
        assert!(r2.embedding.is_empty(), "stale embedding leaked from the previous job");
    }

    #[test]
    fn stats_track_cache_and_jobs() {
        let g = graph();
        let mc = small_mc();
        let spec = ShardSpec {
            shards: 2,
            model_cfg: mc,
            backend: BackendChoice::Fixed,
            cache_rows: 1024,
            ..Default::default()
        };
        let (tx, rx) = mpsc::channel();
        let library = Arc::new(ModelLibrary::presets(&mc));
        let pool = ShardPool::start(&spec, library, g.clone(), rx, gauge(2)).unwrap();
        // Same target twice: the second job's rows should mostly hit.
        let a = submit(&tx, &g, &mc, GnnModel::Gcn, 0, &[42]);
        a.recv().unwrap().unwrap();
        let b = submit(&tx, &g, &mc, GnnModel::Gcn, 1, &[42]);
        b.recv().unwrap().unwrap();
        drop(tx);
        let s = pool.stats();
        assert_eq!(s.jobs, 2);
        assert_eq!(s.timing_only_jobs, 0);
        assert_eq!(s.backend_fallbacks, 0);
        assert!(s.shard_backends.iter().all(|b| b == "fixed-q4.12"), "{:?}", s.shard_backends);
        assert!(s.cache_hits > 0, "repeat neighborhood must hit");
        assert!(s.cache_hit_rate > 0.0 && s.cache_hit_rate < 1.0);
        assert!(s.sim_feature_hit_rate >= 0.0);
    }
}
