//! Sharded executor pool: N fixed-point executors behind one work
//! queue, fronted by the shared degree-aware [`FeatureCache`].
//!
//! PR 1 parallelized nodeflow *builds* but left execution on a single
//! thread (ROADMAP open item). This pool closes that gap for the
//! fixed-point datapath: each shard owns its own compiled
//! [`ModelPlan`]s, resolved [`PlanArgs`] (weights pre-quantized once)
//! and [`ExecScratch`] arena, so shards share **no mutable state**
//! except the feature cache — execution scales across cores with one
//! mutex probe per feature row.
//!
//! The PJRT float path stays **pinned to shard 0**: the PJRT client is
//! not `Send`, and replies must not depend on which shard served them,
//! so when PJRT numerics are requested the pool runs single-shard
//! (exactly the PR-1 pipeline, plus the marshalling arena and the
//! explicit `timing_only` fallback). Scale-out applies to the Q4.12
//! fixed-point serving mode, whose output is bit-identical for any
//! shard count (`tests/serve_props.rs` pins this): per-request results
//! depend only on vertex ids — sampled nodeflow, synthesized features,
//! and the deterministic serving weights — never on scheduling.

use crate::config::{GripConfig, ModelConfig};
use crate::coordinator::InferenceResponse;
use crate::graph::CsrGraph;
use crate::greta::{
    exec_test_args, execute_model_into, ExecArgs, ExecScratch, ModelKey, ModelLibrary, ModelPlan,
    PlanArgs, SelfScale, ALL_MODELS,
};
use crate::nodeflow::Nodeflow;
use crate::runtime::{
    build_dynamic_args_into, fill_feature_row, fits_padding, Executor, FeatureSource, Manifest,
    MarshalScratch,
};
use crate::serve::{DegreeClasses, FeatureCache};
use crate::sim::simulate;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// One original caller's stake in a (possibly coalesced) job: its id,
/// how many of the job's targets are its, and where to send the reply.
pub struct ReplySlot {
    pub id: u64,
    pub n_targets: usize,
    pub t_submit: Instant,
    pub reply: mpsc::Sender<Result<InferenceResponse, String>>,
}

/// A unit of executor work: a built nodeflow plus the reply slots of
/// every request coalesced into it (one slot for direct submissions).
pub struct ExecJob {
    /// Model to execute, resolved against the pool's [`ModelLibrary`].
    pub model: ModelKey,
    pub nf: Nodeflow,
    pub members: Vec<ReplySlot>,
    /// When a builder dequeued the job (start of service time).
    pub t_dequeue: Instant,
}

/// Pool configuration (a plain-data subset of the coordinator's
/// `ServeConfig`, cloneable into each shard thread).
#[derive(Debug, Clone)]
pub struct ShardSpec {
    pub shards: usize,
    pub grip: GripConfig,
    pub model_cfg: ModelConfig,
    /// Attempt to load the PJRT executor (pins the pool to one shard).
    pub pjrt: bool,
    /// Serve Q4.12 fixed-point embeddings from every shard when PJRT
    /// numerics are off/unavailable (otherwise replies are timing-only).
    pub fixed_numerics: bool,
    /// Shared feature-cache capacity in rows (0 disables caching).
    pub cache_rows: usize,
    /// Seed of the deterministic fixed-point serving weights.
    pub weight_seed: u64,
}

impl Default for ShardSpec {
    fn default() -> Self {
        Self {
            shards: 1,
            grip: GripConfig::paper(),
            model_cfg: ModelConfig::paper(),
            pjrt: false,
            fixed_numerics: false,
            cache_rows: 4096,
            weight_seed: 0x5EED_5E4E,
        }
    }
}

/// Monotonic pool counters (relaxed atomics; snapshot via
/// [`ShardPool::stats`]).
#[derive(Debug, Default)]
struct PoolCounters {
    jobs: AtomicU64,
    timing_only: AtomicU64,
    sim_rows_touched: AtomicU64,
    sim_rows_loaded: AtomicU64,
}

/// A point-in-time view of the pool's serving statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStats {
    /// Executor shards actually running.
    pub shards: usize,
    /// Jobs executed (batches count once).
    pub jobs: u64,
    /// Jobs that produced no numeric embedding (see
    /// `InferenceResponse::timing_only`).
    pub timing_only_jobs: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Host-side feature-cache hit fraction.
    pub cache_hit_rate: f64,
    /// The cycle simulator's on-chip feature hit fraction over the same
    /// jobs (`cache_features` accounting) — comparable to
    /// `cache_hit_rate` in `BENCH_serve.json`.
    pub sim_feature_hit_rate: f64,
}

/// The executor pool. Threads drain the `ExecJob` receiver until its
/// sender side closes; dropping the pool joins them.
pub struct ShardPool {
    threads: Vec<std::thread::JoinHandle<()>>,
    cache: Arc<FeatureCache>,
    counters: Arc<PoolCounters>,
    shards: usize,
}

/// Deterministic fixed-point serving weights for `plan` (the Q4.12
/// analogue of `runtime::serving_weights`): every transform weight from
/// the shared test-weight generator, plus a scalar for every
/// `one_plus_arg` self-scale the plan declares (layer `i` gets
/// `0.1 * (i + 1)` — exactly the eps1 = 0.1 / eps2 = 0.2 the GIN preset
/// served before the spec redesign, now derived from plan structure
/// instead of hardcoded names). Identical on every shard for a given
/// seed — the root of the pool's bit-identity guarantee.
pub fn fixed_serving_args(plan: &ModelPlan, seed: u64) -> ExecArgs {
    let mut args = exec_test_args(plan, seed);
    for (li, layer) in plan.layers.iter().enumerate() {
        for p in &layer.programs {
            if let Some(SelfScale::OnePlusArg(name)) = &p.self_scale {
                args.entry(name.clone())
                    .or_insert_with(|| (Vec::new(), vec![0.1 * (li as f32 + 1.0)]));
            }
        }
    }
    args
}

/// [`FeatureSource`] adapter: serve rows from the shared cache, using
/// the serving graph's out-degree as the admission weight.
pub struct CachedFeatures<'a> {
    pub cache: &'a FeatureCache,
    pub graph: &'a CsrGraph,
}

impl FeatureSource for CachedFeatures<'_> {
    fn fill_row(&mut self, v: u32, dst: &mut [f32]) {
        self.cache.copy_row(v, self.graph.degree(v), dst);
    }
}

impl ShardPool {
    /// Spawn the pool over `rx`, serving the models in `library`. When
    /// `spec.pjrt` is set the pool is forced to a single shard (shard 0
    /// owns the non-Send PJRT client); otherwise `spec.shards`
    /// fixed-point shards share the queue. The shared feature cache's
    /// degree classes are calibrated from the serving graph's degree
    /// quantiles ([`DegreeClasses::from_graph`]). `inflight` is
    /// decremented once per completed job — the gauge the coordinator's
    /// batcher uses for idle-aware early dispatch (the sender
    /// increments it on enqueue).
    pub fn start(
        spec: &ShardSpec,
        library: Arc<ModelLibrary>,
        graph: Arc<CsrGraph>,
        rx: mpsc::Receiver<ExecJob>,
        inflight: Arc<AtomicU64>,
    ) -> Result<ShardPool> {
        let shards = if spec.pjrt { 1 } else { spec.shards.max(1) };
        // Quantile calibration walks + sorts every vertex degree — skip
        // it when caching is disabled (cache_rows 0 never admits).
        let classes = if spec.cache_rows > 0 {
            DegreeClasses::from_graph(&graph)
        } else {
            DegreeClasses::default()
        };
        let cache =
            Arc::new(FeatureCache::with_classes(spec.cache_rows, spec.model_cfg.f_in, classes));
        let counters = Arc::new(PoolCounters::default());
        let rx = Arc::new(Mutex::new(rx));
        let mut threads = Vec::with_capacity(shards);
        for i in 0..shards {
            let spec = spec.clone();
            let library = library.clone();
            let graph = graph.clone();
            let cache = cache.clone();
            let counters = counters.clone();
            let rx = rx.clone();
            let inflight = inflight.clone();
            let handle = std::thread::Builder::new()
                .name(format!("grip-shard-{i}"))
                .spawn(move || {
                    shard_loop(i, &spec, &library, &graph, &cache, &counters, &rx, &inflight)
                })
                .map_err(|e| anyhow!("spawning shard {i}: {e}"))?;
            threads.push(handle);
        }
        Ok(ShardPool { threads, cache, counters, shards })
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn stats(&self) -> ServeStats {
        let touched = self.counters.sim_rows_touched.load(Ordering::Relaxed);
        let loaded = self.counters.sim_rows_loaded.load(Ordering::Relaxed);
        ServeStats {
            shards: self.shards,
            jobs: self.counters.jobs.load(Ordering::Relaxed),
            timing_only_jobs: self.counters.timing_only.load(Ordering::Relaxed),
            cache_hits: self.cache.hits(),
            cache_misses: self.cache.misses(),
            cache_hit_rate: self.cache.hit_rate(),
            sim_feature_hit_rate: if touched > 0 {
                1.0 - loaded as f64 / touched as f64
            } else {
                0.0
            },
        }
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // The job sender must already be gone (the coordinator drops the
        // pipeline front-to-back); joining here never deadlocks because
        // each shard exits on the closed channel.
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// One shard: resolve fixed-point weights for every library model once,
/// then drain the shared queue. Shard 0 additionally owns the PJRT
/// executor when requested.
#[allow(clippy::too_many_arguments)]
fn shard_loop(
    shard: usize,
    spec: &ShardSpec,
    library: &ModelLibrary,
    graph: &CsrGraph,
    cache: &FeatureCache,
    counters: &PoolCounters,
    rx: &Mutex<mpsc::Receiver<ExecJob>>,
    inflight: &AtomicU64,
) {
    let pjrt = if spec.pjrt && shard == 0 {
        match Executor::load(&Manifest::default_dir()) {
            Ok(e) => Some(e),
            Err(e) => {
                eprintln!("shard 0: PJRT unavailable ({e}); serving without float numerics");
                None
            }
        }
    } else {
        None
    };
    // One resolved PlanArgs per library model, indexed by ModelKey.
    let pargs: Vec<PlanArgs> = library
        .keys()
        .map(|k| {
            let plan = library.plan(k);
            let args = fixed_serving_args(plan, spec.weight_seed);
            PlanArgs::resolve(plan, &args).expect("serving weights match their own plan")
        })
        .collect();
    let mut scratch = ExecScratch::for_config(&spec.grip);
    let mut marshal = MarshalScratch::new();
    let mut h: Vec<f32> = Vec::new();
    let mut emb: Vec<f32> = Vec::new();

    loop {
        // Hold the queue lock only while waiting; execution runs
        // unlocked so shards overlap.
        let job = {
            let guard = match rx.lock() {
                Ok(g) => g,
                Err(_) => break,
            };
            match guard.recv() {
                Ok(j) => j,
                Err(_) => break,
            }
        };
        execute_job(
            spec,
            library,
            graph,
            cache,
            counters,
            pjrt.as_ref(),
            &pargs,
            &mut scratch,
            &mut marshal,
            &mut h,
            &mut emb,
            job,
        );
        // Replies are out: this job no longer occupies the pipeline.
        inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Execute one job and fan replies out to its members. `emb` holds the
/// job's full embedding (`f_out` values per target, member order).
#[allow(clippy::too_many_arguments)]
fn execute_job(
    spec: &ShardSpec,
    library: &ModelLibrary,
    graph: &CsrGraph,
    cache: &FeatureCache,
    counters: &PoolCounters,
    pjrt: Option<&Executor>,
    pargs: &[PlanArgs],
    scratch: &mut ExecScratch,
    marshal: &mut MarshalScratch,
    h: &mut Vec<f32>,
    emb: &mut Vec<f32>,
    job: ExecJob,
) {
    let ExecJob { model, nf, members, t_dequeue } = job;
    let plan = library.plan(model);

    // 1. Cycle-level accelerator timing (and the sim-side feature-cache
    //    accounting mirrored into the pool stats).
    let sim = simulate(&spec.grip, plan, &nf);
    let accel_us = sim.us(&spec.grip);
    counters.jobs.fetch_add(1, Ordering::Relaxed);
    counters
        .sim_rows_touched
        .fetch_add(sim.counters.feature_rows_touched, Ordering::Relaxed);
    counters
        .sim_rows_loaded
        .fetch_add(sim.counters.feature_rows_loaded, Ordering::Relaxed);

    // 2. Numerics: PJRT float path (shard 0), else the fixed-point
    //    datapath, else timing-only. On success `emb` holds
    //    f_out * nf.targets.len() values.
    let outcome: Result<(usize, bool), String> = if let Some(exec) = pjrt {
        match exec.model(&plan.name) {
            Ok(lm) if fits_padding(&lm.artifact, &nf) => {
                let mut src = CachedFeatures { cache, graph };
                build_dynamic_args_into(plan, &lm.artifact, &nf, &mut src, marshal)
                    .map_err(|e| e.to_string())
                    .and_then(|_| {
                        exec.run_prepared(&plan.name, marshal.args()).map_err(|e| e.to_string())
                    })
                    .map(|out| {
                        let f_out = *lm.artifact.output_shape.last().unwrap_or(&1);
                        emb.clear();
                        emb.extend_from_slice(&out[..f_out * nf.targets.len()]);
                        (f_out, false)
                    })
            }
            Ok(_) => {
                // The (batched) nodeflow exceeds the AOT padding:
                // degrade to an explicitly-flagged timing-only reply.
                emb.clear();
                Ok((0, true))
            }
            Err(_) if model.index() >= ALL_MODELS.len() => {
                // Custom specs have no AOT artifact — an expected
                // timing-only degrade, not an error.
                emb.clear();
                Ok((0, true))
            }
            // A *preset* artifact that fails to load is a broken
            // deployment: surface it to the caller instead of quietly
            // answering timing-only.
            Err(e) => Err(e.to_string()),
        }
    } else if spec.fixed_numerics {
        // The plan's own input width governs the feature rows; the
        // shared cache only serves rows of its configured width, so
        // specs with non-default dims synthesize rows directly.
        let in_dim = plan.layers[0].in_dim;
        let l0 = &nf.layers[0];
        h.clear();
        if in_dim == cache.f_in() {
            h.reserve(l0.num_inputs() * in_dim);
            for &v in &l0.inputs {
                cache.append_row(v, graph.degree(v), h);
            }
        } else {
            h.resize(l0.num_inputs() * in_dim, 0f32);
            for (i, &v) in l0.inputs.iter().enumerate() {
                fill_feature_row(v, &mut h[i * in_dim..(i + 1) * in_dim]);
            }
        }
        let f_out = plan.layers.last().expect("validated plans have layers").out_dim;
        match execute_model_into(plan, &nf, h, &pargs[model.index()], scratch, emb) {
            Ok(()) => Ok((f_out, false)),
            Err(e) => Err(e.to_string()),
        }
    } else {
        emb.clear();
        Ok((0, true))
    };

    // 3. Fan out per-member replies (a coalesced batch shares one
    //    nodeflow, one simulated pass, and one embedding buffer).
    match outcome {
        Err(e) => {
            for m in members {
                let _ = m.reply.send(Err(e.clone()));
            }
        }
        Ok((f_out, timing_only)) => {
            if timing_only {
                counters.timing_only.fetch_add(1, Ordering::Relaxed);
            }
            let service_us = t_dequeue.elapsed().as_secs_f64() * 1e6;
            let neighborhood = nf.neighborhood_size();
            let mut row = 0usize;
            for m in members {
                let embedding = if timing_only {
                    Vec::new()
                } else {
                    emb[row * f_out..(row + m.n_targets) * f_out].to_vec()
                };
                row += m.n_targets;
                let resp = InferenceResponse {
                    id: m.id,
                    embedding,
                    accel_us,
                    host_us: m.t_submit.elapsed().as_secs_f64() * 1e6,
                    service_us,
                    neighborhood,
                    timing_only,
                };
                let _ = m.reply.send(Ok(resp));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, GeneratorParams};
    use crate::greta::GnnModel;
    use crate::nodeflow::Sampler;

    fn graph() -> Arc<CsrGraph> {
        Arc::new(generate(&GeneratorParams {
            nodes: 2_000,
            mean_degree: 8.0,
            ..Default::default()
        }))
    }

    /// An in-flight gauge pre-charged for `jobs` sends (the test
    /// harness enqueues directly, without the coordinator's increments).
    fn gauge(jobs: usize) -> Arc<AtomicU64> {
        Arc::new(AtomicU64::new(jobs as u64))
    }

    fn small_mc() -> ModelConfig {
        ModelConfig { sample1: 4, sample2: 3, f_in: 12, f_hid: 10, f_out: 6 }
    }

    fn submit(
        tx: &mpsc::Sender<ExecJob>,
        g: &CsrGraph,
        mc: &ModelConfig,
        model: GnnModel,
        id: u64,
        targets: &[u32],
    ) -> mpsc::Receiver<Result<InferenceResponse, String>> {
        let nf = Nodeflow::build(g, &Sampler::new(9), targets, mc);
        let (rtx, rrx) = mpsc::channel();
        tx.send(ExecJob {
            model: model.key(),
            nf,
            members: vec![ReplySlot {
                id,
                n_targets: targets.len(),
                t_submit: Instant::now(),
                reply: rtx,
            }],
            t_dequeue: Instant::now(),
        })
        .unwrap();
        rrx
    }

    fn run_pool(shards: usize, fixed: bool, ids: &[u32]) -> Vec<InferenceResponse> {
        let g = graph();
        let mc = small_mc();
        let spec = ShardSpec {
            shards,
            model_cfg: mc,
            fixed_numerics: fixed,
            cache_rows: 256,
            ..Default::default()
        };
        let (tx, rx) = mpsc::channel();
        let library = Arc::new(ModelLibrary::presets(&mc));
        let pool = ShardPool::start(&spec, library, g.clone(), rx, gauge(ids.len())).unwrap();
        let replies: Vec<_> = ids
            .iter()
            .enumerate()
            .map(|(i, &t)| submit(&tx, &g, &mc, GnnModel::Gcn, i as u64, &[t]))
            .collect();
        drop(tx);
        let out: Vec<InferenceResponse> =
            replies.into_iter().map(|r| r.recv().unwrap().unwrap()).collect();
        drop(pool);
        out
    }

    #[test]
    fn fixed_point_pool_serves_embeddings() {
        let out = run_pool(2, true, &[1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(out.len(), 8);
        for r in &out {
            assert!(!r.timing_only);
            assert_eq!(r.embedding.len(), 6);
            assert!(r.accel_us > 0.0);
        }
    }

    #[test]
    fn pool_output_independent_of_shard_count() {
        let ids: Vec<u32> = (0..24).map(|i| i * 13 % 2000).collect();
        let one = run_pool(1, true, &ids);
        let four = run_pool(4, true, &ids);
        for (a, b) in one.iter().zip(four.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.embedding, b.embedding, "id {}", a.id);
            assert_eq!(a.accel_us, b.accel_us);
            assert_eq!(a.neighborhood, b.neighborhood);
        }
    }

    #[test]
    fn without_numerics_replies_are_flagged_timing_only() {
        let out = run_pool(2, false, &[10, 20]);
        for r in &out {
            assert!(r.timing_only);
            assert!(r.embedding.is_empty());
            assert!(r.accel_us > 0.0, "timing still served");
        }
    }

    #[test]
    fn timing_only_reply_never_leaks_a_previous_jobs_embedding() {
        // The timing-only fallbacks (numerics disabled, or the PJRT
        // padding-exceeded degrade — both run `emb.clear(); (0, true)`)
        // share one embedding buffer with numeric jobs on the same
        // shard; a stale buffer must never fan out to members.
        let g = graph();
        let mc = small_mc();
        let spec_fx = ShardSpec { model_cfg: mc, fixed_numerics: true, ..Default::default() };
        let spec_timing = ShardSpec { model_cfg: mc, fixed_numerics: false, ..Default::default() };
        let library = ModelLibrary::presets(&mc);
        let pargs: Vec<PlanArgs> = library
            .keys()
            .map(|k| {
                let p = library.plan(k);
                PlanArgs::resolve(p, &fixed_serving_args(p, spec_fx.weight_seed)).unwrap()
            })
            .collect();
        let cache = FeatureCache::new(64, mc.f_in);
        let counters = PoolCounters::default();
        let mut scratch = ExecScratch::new();
        let mut marshal = MarshalScratch::new();
        let mut h = Vec::new();
        let mut emb = Vec::new();

        let mk_job = |id: u64| {
            let nf = Nodeflow::build(&g, &Sampler::new(9), &[7], &mc);
            let (rtx, rrx) = mpsc::channel();
            let job = ExecJob {
                model: GnnModel::Gcn.key(),
                nf,
                members: vec![ReplySlot {
                    id,
                    n_targets: 1,
                    t_submit: Instant::now(),
                    reply: rtx,
                }],
                t_dequeue: Instant::now(),
            };
            (job, rrx)
        };

        // 1. A numeric job fills the shared embedding buffer.
        let (job, rx1) = mk_job(0);
        execute_job(
            &spec_fx, &library, &g, &cache, &counters, None, &pargs, &mut scratch,
            &mut marshal, &mut h, &mut emb, job,
        );
        let r1 = rx1.recv().unwrap().unwrap();
        assert!(!r1.timing_only && !r1.embedding.is_empty());

        // 2. A timing-only job reusing the same buffers must reply empty.
        let (job, rx2) = mk_job(1);
        execute_job(
            &spec_timing, &library, &g, &cache, &counters, None, &pargs, &mut scratch,
            &mut marshal, &mut h, &mut emb, job,
        );
        let r2 = rx2.recv().unwrap().unwrap();
        assert!(r2.timing_only, "no numeric path ran");
        assert!(r2.embedding.is_empty(), "stale embedding leaked from the previous job");
    }

    #[test]
    fn stats_track_cache_and_jobs() {
        let g = graph();
        let mc = small_mc();
        let spec = ShardSpec {
            shards: 2,
            model_cfg: mc,
            fixed_numerics: true,
            cache_rows: 1024,
            ..Default::default()
        };
        let (tx, rx) = mpsc::channel();
        let library = Arc::new(ModelLibrary::presets(&mc));
        let pool = ShardPool::start(&spec, library, g.clone(), rx, gauge(2)).unwrap();
        // Same target twice: the second job's rows should mostly hit.
        let a = submit(&tx, &g, &mc, GnnModel::Gcn, 0, &[42]);
        a.recv().unwrap().unwrap();
        let b = submit(&tx, &g, &mc, GnnModel::Gcn, 1, &[42]);
        b.recv().unwrap().unwrap();
        drop(tx);
        let s = pool.stats();
        assert_eq!(s.jobs, 2);
        assert_eq!(s.timing_only_jobs, 0);
        assert!(s.cache_hits > 0, "repeat neighborhood must hit");
        assert!(s.cache_hit_rate > 0.0 && s.cache_hit_rate < 1.0);
        assert!(s.sim_feature_hit_rate >= 0.0);
    }
}
