//! Sharded executor pool: N executor shards, fronted by degree-aware
//! [`FeatureCache`]s — one shared cache, or (PR 6) one
//! **partition-local** cache per shard.
//!
//! PR 1 parallelized nodeflow *builds* but left execution on a single
//! thread; PR 2 sharded the fixed-point datapath; PR 4 made the
//! engine itself pluggable; PR 5 **phase-decoupled each shard**. GRIP's
//! central claim is that GNN inference splits into a memory-bound
//! edge-centric phase and a compute-bound vertex-centric phase, and
//! that the hardware wins by specializing each and running them
//! concurrently ("multiple parallel prefetch and reduction engines"
//! feeding the matmul unit). A shard now mirrors that structure:
//!
//! ```text
//!            shared job queue (built nodeflows)
//!                │        │
//!        prefetch lane 0  prefetch lane N-1     — edge-centric: cycle
//!          (sim + feature gather through the      sim + gather layer-0
//!           shared FeatureCache into a pooled     rows into a pooled
//!           StagedFeatures buffer)                StagedFeatures
//!                │        │
//!                ▼        ▼
//!          bounded ready queue (depth K, backpressure)
//!                      │
//!                      ▼
//!                vertex engine                   — compute-bound: the
//!          (the shard's NumericsBackend,           shard's one backend
//!           !Send-safe: never leaves this          thread; matmul for
//!           thread; executes + fans out)           job i overlaps the
//!                                                  lanes' gather for
//!                                                  job i+1
//! ```
//!
//! [`PipelineConfig`] (`--prefetch-lanes`, `--pipeline-depth`,
//! `--pipeline off`) selects lanes/depth or the legacy single-loop
//! shard.
//!
//! **Graph-partitioned serving** (`--partition degree|hash|off`): with
//! a [`PartitionStrategy`] other than `Off`, the pool builds a
//! [`Partitioning`] over the serving graph and becomes
//! partition-local end to end. A **router** thread maps each job's
//! target vertex to its home shard's own bounded queue (no more
//! contending on one shared queue); each shard owns a private
//! [`FeatureCache`] holding only its partition's rows, with the row
//! budget split across shards by largest remainder (shard `i` gets
//! `rows/shards + 1` if `i < rows % shards`, else `rows/shards` — so
//! total resident rows are invariant under the shard sweep) and
//! [`DegreeClasses`] recalibrated from the partition's own degree
//! quantiles. Layer-0 inputs owned by *other* partitions are pulled
//! through the **boundary-fetch** path: one batched pull per peer per
//! job over a bounded channel, answered by the peer's boundary service
//! from its local cache ([`ServeStats::boundary_fetches`],
//! [`ServeStats::boundary_fetch_p99_us`]). This mirrors GRIP's split
//! between partition-resident prefetch engines and the explicit
//! vertex-tile exchange a multi-chip deployment would need.
//!
//! Scheduling can never change numerics: staging is
//! deterministic in the nodeflow (values depend only on vertex ids),
//! and a boundary pull returns exactly the bytes local synthesis
//! would, so partitioned and pipelined replies are **bit-identical**
//! to the sequential unpartitioned path for every backend, any
//! (lanes, depth), and both partitioning strategies — pinned by
//! `tests/serve_props.rs`. Occupancy and stall counters
//! ([`ServeStats::prefetch_occupancy`], [`ServeStats::engine_stalls`],
//! [`ServeStats::prefetch_stalls`]) expose how well the two phases
//! overlap, next to the cycle sim's mirrored
//! [`ServeStats::sim_phase_overlap`].
//!
//! Each shard owns a boxed [`NumericsBackend`] built **inside its own
//! engine thread** by the [`BackendFactory`], plus that backend's
//! prepared per-model state ([`PreparedModel`]: quantized weights,
//! device-resident PJRT buffers) and a [`BackendScratch`] arena — so
//! shards share **no mutable state** except the feature cache, and
//! execution scales across cores for *every* engine.
//!
//! A shard whose configured backend fails to construct or prepare
//! (PJRT runtime stubbed out, artifact manifest missing) falls back to
//! timing-only serving; the failure is counted in
//! [`ServeStats::backend_fallbacks`] and the per-shard status string
//! in [`ServeStats::shard_backends`] carries the error — it no longer
//! vanishes into stderr. (A single broken *model* inside an otherwise
//! healthy backend stays per-model: its requests get error replies
//! while sibling models keep serving.)

use crate::backend::{
    BackendChoice, BackendFactory, BackendScratch, MemoCtx, NumericsBackend, PreparedModel,
    StagedFeatures,
};
use crate::config::{GripConfig, ModelConfig};
use crate::control::{ControlStats, Knobs, RawSignals, SignalSource};
use crate::coordinator::{InferenceResponse, LatencyStats};
use crate::graph::{CsrGraph, PartitionStrategy, Partitioning};
use crate::greta::{exec_test_args, ExecArgs, ModelKey, ModelLibrary, ModelPlan, SelfScale};
use crate::nodeflow::{MemoHarvest, MemoPlan, Nodeflow};
use crate::residency::{split_weight_budget, ResidencyConfig, ResidencyCounters, ResidencyManager};
use crate::runtime::{fill_feature_row, FeatureSource};
use crate::serve::{DegreeClasses, FeatureCache, MemoCache, MemoScope};
use crate::sim::{simulate, SimResult};
use crate::telemetry::{SpanTrace, Stage, Telemetry};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Depth of each home shard's routed job queue (partitioned mode): the
/// router parks at most this many built jobs at a hot shard before
/// backpressuring the builders, keeping one skewed partition from
/// absorbing the whole built-queue budget.
const ROUTE_QUEUE_DEPTH: usize = 64;

/// Depth of each shard's boundary-pull service queue. A pull is a
/// batched request (one per peer per job), so this bounds outstanding
/// cross-shard chatter, not rows.
const BOUNDARY_QUEUE_DEPTH: usize = 64;

/// How long a knob-parked lane/shard thread sleeps between `try_recv`
/// polls of its job queue. Short enough that a reactivated thread is
/// back inside one controller tick.
const PARK_POLL: Duration = Duration::from_micros(200);

/// Poll interval of the pipeline-depth admission gate (engaged only
/// when the depth knob sits below the channel's capacity cap).
const GATE_POLL: Duration = Duration::from_micros(50);

/// Bounded iterations of the depth gate before the lane falls through
/// to the channel's own backpressure — a wedged engine must never spin
/// a lane forever, and the channel (sized at the cap) still bounds it.
const GATE_SPIN_LIMIT: usize = 20_000;

/// One original caller's stake in a (possibly coalesced) job: its id,
/// how many of the job's targets are its, and where to send the reply.
pub struct ReplySlot {
    pub id: u64,
    pub n_targets: usize,
    pub t_submit: Instant,
    pub reply: mpsc::Sender<Result<InferenceResponse, String>>,
    /// Lifecycle span for sampled requests (`None` on the unsampled
    /// fast path); stamped as the job moves through the pipeline and
    /// deposited into the pool's [`Telemetry`] with the reply.
    pub trace: Option<Box<SpanTrace>>,
}

/// A unit of executor work: a built nodeflow plus the reply slots of
/// every request coalesced into it (one slot for direct submissions).
pub struct ExecJob {
    /// Model to execute, resolved against the pool's [`ModelLibrary`].
    pub model: ModelKey,
    pub nf: Nodeflow,
    pub members: Vec<ReplySlot>,
    /// When a builder dequeued the job (start of service time).
    pub t_dequeue: Instant,
    /// When the builder finished the nodeflow and enqueued the job
    /// toward its shard (start of the shard-wait window).
    pub t_built: Instant,
    /// Activation-memo splice plan recorded while `nf` was built
    /// (`None` when memoization is off or nothing hit/harvested): rows
    /// to inject in place of pruned subtrees, plus slots to harvest
    /// back into the cache after execution.
    pub memo: Option<MemoPlan>,
}

/// Per-shard phase-decoupling policy: how many edge-centric prefetch
/// lanes feed the vertex engine, through how deep a ready queue.
/// `--prefetch-lanes` / `--pipeline-depth` / `--pipeline off` on the
/// CLI; carried by `ShardSpec`/`ServeConfig`/`OpenLoopConfig`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PipelineConfig {
    /// `false` = the legacy single-loop shard (`--pipeline off`):
    /// gather and execute back-to-back on one thread.
    pub enabled: bool,
    /// Prefetch lanes per shard (edge-centric feature staging).
    pub prefetch_lanes: usize,
    /// Ready-queue depth between the lanes and the vertex engine —
    /// how many staged jobs may wait, i.e. how far the edge phase may
    /// run ahead of the matmul.
    pub depth: usize,
}

impl Default for PipelineConfig {
    /// Two lanes, depth two: enough to hide a job's gather behind the
    /// previous job's matmul without hoarding memory.
    fn default() -> Self {
        Self { enabled: true, prefetch_lanes: 2, depth: 2 }
    }
}

impl PipelineConfig {
    /// The legacy sequential shard loop (`--pipeline off`).
    pub fn off() -> Self {
        Self { enabled: false, ..Self::default() }
    }

    /// An enabled pipeline with explicit lanes × depth (both clamped
    /// to ≥ 1).
    pub fn lanes_depth(lanes: usize, depth: usize) -> Self {
        Self { enabled: true, prefetch_lanes: lanes.max(1), depth: depth.max(1) }
    }

    /// Human-readable summary for logs (`off` or `2x4`).
    pub fn label(&self) -> String {
        if self.enabled {
            format!("{}x{}", self.prefetch_lanes.max(1), self.depth.max(1))
        } else {
            "off".into()
        }
    }
}

/// Pool configuration (a plain-data subset of the coordinator's
/// `ServeConfig`, cloneable into each shard thread).
#[derive(Debug, Clone)]
pub struct ShardSpec {
    pub shards: usize,
    pub grip: GripConfig,
    pub model_cfg: ModelConfig,
    /// Execution engine every shard runs (the [`BackendFactory`] is
    /// invoked once per shard, inside the shard's engine thread).
    /// Replaces the old `pjrt`/`fixed_numerics` bool pair.
    pub backend: BackendChoice,
    /// Per-shard phase pipeline (prefetch lanes → vertex engine).
    pub pipeline: PipelineConfig,
    /// **Total** feature-cache capacity in rows (0 disables caching).
    /// Unpartitioned, it is one shared cache; partitioned, it is split
    /// across the shards' partition-local caches by largest remainder,
    /// so total resident feature memory is invariant under the shard
    /// sweep.
    pub cache_rows: usize,
    /// **Total** activation-memo capacity in rows (0 disables
    /// cross-request memoization — the default, byte-identical to
    /// earlier PRs). Split across shards like `cache_rows` when
    /// partitioned; one shared cache otherwise. Only exact-Q4.12
    /// engines (`fixed`, `reference`) memoize — float and timing-only
    /// backends ignore the budget entirely.
    pub memo_rows: usize,
    /// Vertex partitioning across shards (`Off` = the legacy shared
    /// queue + shared cache pool).
    pub partition: PartitionStrategy,
    /// Seed of the deterministic fixed-point serving weights.
    pub weight_seed: u64,
    /// Weight-residency policy (`--weight-budget-bytes` + `--evict`).
    /// A 0 budget keeps the pre-zoo behavior: every model prepared
    /// eagerly at startup and resident forever. Budgeted, the **total**
    /// budget is split across shards by largest remainder (like
    /// `cache_rows`) and each vertex engine pages prepared models
    /// in/out through its own [`ResidencyManager`].
    pub residency: ResidencyConfig,
    /// Shared telemetry handle: stage histograms always record; span
    /// stamping happens only on requests the coordinator sampled.
    pub telemetry: Telemetry,
    /// Runtime scheduling knobs shared with the control plane. `None`
    /// (every pre-control caller) derives fixed knobs from the
    /// pipeline/shard fields, whose caps pin every value — behavior is
    /// then byte-identical to the knob-free pool.
    pub knobs: Option<Arc<Knobs>>,
}

/// Largest-remainder split of the total cache-row budget: shard `i`
/// gets `rows/shards`, plus one of the `rows % shards` remainder rows
/// if `i < rows % shards`. Sums to exactly `rows` for every shard
/// count — the documented rounding rule behind the memory-invariance
/// guarantee.
pub fn split_cache_rows(rows: usize, shards: usize) -> Vec<usize> {
    let shards = shards.max(1);
    (0..shards).map(|i| rows / shards + usize::from(i < rows % shards)).collect()
}

/// The builders' handle to the pool's activation-memo caches
/// (`--memo-rows > 0` with an exact-Q4.12 backend): maps a job's target
/// vertex to the [`MemoCache`] of its home shard — the same
/// `Partitioning::owner` routing the job itself will take, so a
/// builder only ever consults the cache its executor deposits into.
/// Unpartitioned pools hold one shared cache.
#[derive(Clone)]
pub struct MemoRouter {
    caches: Vec<Arc<MemoCache>>,
    partition: Option<Arc<Partitioning>>,
    weight_seed: u64,
}

impl MemoRouter {
    fn cache_for(&self, target: u32) -> &Arc<MemoCache> {
        match &self.partition {
            Some(p) => &self.caches[p.owner(target)],
            None => &self.caches[0],
        }
    }

    /// A [`crate::nodeflow::MemoProbe`] over the home-shard cache of
    /// `target`, keyed by `(model, weight_seed)`.
    pub fn scope(&self, model: ModelKey, target: u32) -> MemoScope<'_> {
        MemoScope::new(self.cache_for(target), model, self.weight_seed)
    }
}

impl Default for ShardSpec {
    fn default() -> Self {
        Self {
            shards: 1,
            grip: GripConfig::paper(),
            model_cfg: ModelConfig::paper(),
            backend: BackendChoice::TimingOnly,
            pipeline: PipelineConfig::default(),
            cache_rows: 4096,
            memo_rows: 0,
            partition: PartitionStrategy::Off,
            weight_seed: 0x5EED_5E4E,
            residency: ResidencyConfig::default(),
            telemetry: Telemetry::default(),
            knobs: None,
        }
    }
}

/// Monotonic pool counters (relaxed atomics; snapshot via
/// [`ShardPool::stats`]).
#[derive(Debug, Default)]
struct PoolCounters {
    jobs: AtomicU64,
    timing_only: AtomicU64,
    backend_fallbacks: AtomicU64,
    sim_rows_touched: AtomicU64,
    sim_rows_loaded: AtomicU64,
    /// Jobs that crossed a lane → engine ready queue (0 with
    /// `--pipeline off`).
    staged_jobs: AtomicU64,
    /// Times a prefetch lane blocked on a full ready queue (the vertex
    /// engine is the bottleneck — the overlap is working).
    prefetch_stalls: AtomicU64,
    /// Times the vertex engine blocked on an empty ready queue while
    /// work was in flight (the lanes can't stage fast enough — add
    /// lanes or cache rows; idle-pool waits are not counted).
    engine_stalls: AtomicU64,
    /// Jobs currently inside a backend's `execute` anywhere in the
    /// pool (a gauge, not monotonic). Lets the stall accounting
    /// distinguish "work exists upstream of the engines" from "the
    /// only in-flight jobs are already executing on sibling shards" —
    /// without it, a 4-shard pool would count a 'prefetch-bound' stall
    /// every time one shard idled while another merely ran a matmul.
    executing: AtomicU64,
    /// Σ of the ready-queue depth observed at each engine dequeue, and
    /// the number of observations — together the mean prefetch
    /// occupancy.
    occupancy_sum: AtomicU64,
    occupancy_samples: AtomicU64,
    /// Cycle-sim mirror of the same phase split: hidden (overlapped)
    /// cycles and total phase-busy cycles across simulated jobs.
    sim_overlap_cycles: AtomicU64,
    sim_busy_cycles: AtomicU64,
    /// Feature rows actually gathered at layer 0 across jobs (the
    /// denominator memoization shrinks: a pruned subtree's sources
    /// never reach the staging gather).
    staged_rows: AtomicU64,
    /// Interior output vertices whose sampling was skipped on a memo
    /// hit, the directly skipped sampled edges, and the within-request
    /// repeat expansions answered by the builder's epoch-dedup buffer
    /// (all folded from per-job [`MemoPlan`]s; zero with memo off).
    memo_pruned_vertices: AtomicU64,
    memo_pruned_edges: AtomicU64,
    memo_dedup_hits: AtomicU64,
    /// Batched cross-partition pulls issued (one per remote peer per
    /// job) and the feature rows they carried.
    boundary_fetches: AtomicU64,
    boundary_rows: AtomicU64,
    /// Per-pull round-trip latencies (send → rows received), for the
    /// boundary p99. Pulls are rare relative to jobs (edge-cut bound),
    /// so one mutex-guarded recorder is cheap.
    boundary_lat: Mutex<LatencyStats>,
}

/// A point-in-time view of the pool's serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Executor shards actually running.
    pub shards: usize,
    /// Jobs executed (batches count once).
    pub jobs: u64,
    /// Jobs that produced no numeric embedding (see
    /// `InferenceResponse::timing_only`).
    pub timing_only_jobs: u64,
    /// Shards whose configured backend failed to construct/prepare and
    /// fell back to timing-only serving (the old stderr-only "PJRT
    /// unavailable" signal, now first-class).
    pub backend_fallbacks: u64,
    /// Per-shard backend status: the engine name, or
    /// `timing-only (fallback: <error>)` after a fallback.
    pub shard_backends: Vec<String>,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Host-side feature-cache hit fraction.
    pub cache_hit_rate: f64,
    /// The cycle simulator's on-chip feature hit fraction over the same
    /// jobs (`cache_features` accounting) — comparable to
    /// `cache_hit_rate` in `BENCH_serve.json`.
    pub sim_feature_hit_rate: f64,
    /// Jobs served through the phase-decoupled pipeline (0 with
    /// `--pipeline off`).
    pub staged_jobs: u64,
    /// Prefetch lanes blocked on a full ready queue (engine-bound).
    pub prefetch_stalls: u64,
    /// Vertex engines blocked on an empty ready queue *while work was
    /// in flight* (prefetch-bound; an idle pool's waits don't count).
    pub engine_stalls: u64,
    /// Mean ready-queue fill fraction observed at engine dequeue
    /// (0 = the engine always drains the queue dry, 1 = the lanes keep
    /// it full — the host-side phase-overlap gauge).
    pub prefetch_occupancy: f64,
    /// The cycle sim's phase-overlap fraction over the same jobs
    /// (`ActivityCounters::phase_overlap_rate` aggregated) — the
    /// on-chip mirror of `prefetch_occupancy`, side by side in
    /// `BENCH_serve.json`.
    pub sim_phase_overlap: f64,
    /// Partitioning strategy the pool is running (`"off"`, `"degree"`,
    /// `"hash"`).
    pub partition: String,
    /// Fraction of graph edges crossing partitions (0 unpartitioned).
    pub edge_cut_fraction: f64,
    /// `max / mean` of per-partition edge load (1.0 = perfect degree
    /// balance; 1.0 when unpartitioned).
    pub partition_balance: f64,
    /// Per-cache row capacity: one entry per shard when partitioned,
    /// a single entry (the shared cache) otherwise. Always sums to
    /// `ShardSpec::cache_rows`.
    pub shard_cache_rows: Vec<usize>,
    /// Σ `shard_cache_rows` — the invariant the shard sweep checks.
    pub cache_rows_total: usize,
    /// Per-cache hit rate, aligned with `shard_cache_rows`.
    pub shard_cache_hit_rate: Vec<f64>,
    /// Jobs the router steered to each home shard (all zero with
    /// `--partition off`, where shards self-schedule off one queue).
    pub routed_jobs: Vec<u64>,
    /// Batched cross-partition pulls (one per remote peer per job).
    pub boundary_fetches: u64,
    /// Feature rows those pulls carried.
    pub boundary_rows: u64,
    /// p99 of the pull round-trip (µs), 0 when no pull happened.
    pub boundary_fetch_p99_us: f64,
    /// Per-stage latency breakdown from the pool's always-on stage
    /// histograms (µs, 0 when the stage never ran): submit → builder
    /// dequeue…
    pub queue_wait_p50_us: f64,
    pub queue_wait_p99_us: f64,
    /// …feature staging minus boundary wait…
    pub prefetch_local_p50_us: f64,
    pub prefetch_local_p99_us: f64,
    /// …wait on remote boundary rows (0 unpartitioned; previously
    /// folded into the prefetch window and double-counted there)…
    pub boundary_wait_p50_us: f64,
    pub boundary_wait_p99_us: f64,
    /// …backend execute…
    pub compute_p50_us: f64,
    pub compute_p99_us: f64,
    /// …and reply fan-out.
    pub reply_p50_us: f64,
    pub reply_p99_us: f64,
    /// Weight-residency summary (all zero with an unlimited budget —
    /// `residency_budget_bytes == 0` is the gate every exporter keys
    /// on, so unbudgeted output stays byte-identical to earlier PRs).
    /// Total prepared-weight budget across shards (0 = paging off).
    pub residency_budget_bytes: u64,
    /// Eviction policy name (`""` when paging is off).
    pub residency_policy: String,
    /// Lookups served from a shard's resident set.
    pub residency_hits: u64,
    /// Lookups that ran an on-demand prepare.
    pub residency_misses: u64,
    /// `hits / (hits + misses)` (0 before any lookup).
    pub residency_hit_rate: f64,
    /// Residents evicted to make room.
    pub residency_evictions: u64,
    /// Current resident bytes, summed across shards (≤ budget always).
    pub residency_resident_bytes: u64,
    /// Currently resident models, summed across shards.
    pub residency_resident_models: u64,
    /// On-demand prepares that failed (also folded into
    /// `backend_fallbacks` — the per-tenant path).
    pub residency_prepare_failures: u64,
    /// On-demand prepare latency percentiles (µs) — the paging cost a
    /// miss charges to its request.
    pub residency_prepare_p50_us: f64,
    pub residency_prepare_p99_us: f64,
    /// Layer-0 feature rows gathered across all jobs (always reported;
    /// the staged-row delta is how memoization's transitive subtree
    /// pruning shows up side by side with the cycle sim).
    pub staged_rows: u64,
    /// Activation-memo summary (all zero with `--memo-rows 0`, the gate
    /// every exporter keys on — memo-off output stays byte-identical to
    /// earlier PRs). Total memo capacity in rows across shards.
    pub memo_rows_total: usize,
    /// Per-cache memo capacity: one entry per shard when partitioned, a
    /// single entry otherwise. Sums to `memo_rows_total`.
    pub shard_memo_rows: Vec<usize>,
    /// Builder-side lookups that returned a cached interior row
    /// (pruning its subtree), and those that missed.
    pub memo_hits: u64,
    pub memo_misses: u64,
    /// `hits / (hits + misses)` (0 before any lookup).
    pub memo_hit_rate: f64,
    /// Freshly computed interior rows deposited by the executors.
    pub memo_deposits: u64,
    /// Resident rows evicted by the clock hand to make room.
    pub memo_evictions: u64,
    /// Rows / bytes currently resident across the memo caches.
    pub memo_resident_rows: u64,
    pub memo_resident_bytes: u64,
    /// Interior vertices whose sampling was skipped on a hit, and the
    /// sampled edges directly skipped there (the transitive saving is
    /// the `staged_rows` delta).
    pub memo_pruned_vertices: u64,
    pub memo_pruned_edges: u64,
    /// Within-request repeat neighbor expansions answered by the
    /// builder's epoch-stamped dedup buffer.
    pub memo_dedup_hits: u64,
    /// Control-plane summary, composed by the coordinator (the pool
    /// itself reports the default `"off"` shape).
    pub control: ControlStats,
}

/// The executor pool. Threads drain the `ExecJob` receiver until its
/// sender side closes; dropping the pool joins them.
pub struct ShardPool {
    threads: Vec<std::thread::JoinHandle<()>>,
    /// One shared cache (unpartitioned) or one partition-local cache
    /// per shard; capacities always sum to `ShardSpec::cache_rows`.
    caches: Vec<Arc<FeatureCache>>,
    /// Activation-memo caches, laid out like `caches` (empty when
    /// memoization is off); capacities sum to `ShardSpec::memo_rows`.
    memo_caches: Vec<Arc<MemoCache>>,
    /// The builders' routing handle over `memo_caches` (`None` = off).
    memo_router: Option<MemoRouter>,
    counters: Arc<PoolCounters>,
    /// Shared weight-residency telemetry (all zero when unbudgeted).
    res_counters: Arc<ResidencyCounters>,
    residency: ResidencyConfig,
    status: Arc<Mutex<Vec<String>>>,
    /// Jobs routed to each home shard (zeros when unpartitioned).
    routed: Arc<Vec<AtomicU64>>,
    partition: PartitionStrategy,
    edge_cut_fraction: f64,
    partition_balance: f64,
    shards: usize,
    telemetry: Telemetry,
    knobs: Arc<Knobs>,
}

/// A cloneable handle over the pool's raw control signals: the
/// controller samples it once per tick without `PoolCounters` (private
/// to this module) ever leaving it.
#[derive(Clone)]
pub struct PoolSignals {
    counters: Arc<PoolCounters>,
    knobs: Arc<Knobs>,
}

impl SignalSource for PoolSignals {
    fn sample(&self) -> RawSignals {
        let c = &self.counters;
        let samples = c.occupancy_samples.load(Ordering::Relaxed);
        RawSignals {
            jobs: c.jobs.load(Ordering::Relaxed),
            staged_jobs: c.staged_jobs.load(Ordering::Relaxed),
            prefetch_stalls: c.prefetch_stalls.load(Ordering::Relaxed),
            engine_stalls: c.engine_stalls.load(Ordering::Relaxed),
            occupancy: if samples > 0 {
                c.occupancy_sum.load(Ordering::Relaxed) as f64
                    / (samples as f64 * self.knobs.depth().max(1) as f64)
            } else {
                0.0
            },
        }
    }
}

/// Deterministic fixed-point serving weights for `plan` (the Q4.12
/// analogue of `runtime::serving_weights`): every transform weight from
/// the shared test-weight generator, plus a scalar for every
/// `one_plus_arg` self-scale the plan declares (layer `i` gets
/// `0.1 * (i + 1)` — exactly the eps1 = 0.1 / eps2 = 0.2 the GIN preset
/// served before the spec redesign, now derived from plan structure
/// instead of hardcoded names). Identical on every shard for a given
/// seed — the root of the pool's bit-identity guarantee.
pub fn fixed_serving_args(plan: &ModelPlan, seed: u64) -> ExecArgs {
    let mut args = exec_test_args(plan, seed);
    for (li, layer) in plan.layers.iter().enumerate() {
        for p in &layer.programs {
            if let Some(SelfScale::OnePlusArg(name)) = &p.self_scale {
                args.entry(name.clone())
                    .or_insert_with(|| (Vec::new(), vec![0.1 * (li as f32 + 1.0)]));
            }
        }
    }
    args
}

/// [`FeatureSource`] adapter: serve rows from the shared cache, using
/// the serving graph's out-degree as the admission weight. Rows whose
/// width differs from the cache's configured `f_in` (a custom spec
/// with non-default dims) bypass the cache and synthesize directly —
/// the cache stores a single fixed row width.
pub struct CachedFeatures<'a> {
    pub cache: &'a FeatureCache,
    pub graph: &'a CsrGraph,
}

impl FeatureSource for CachedFeatures<'_> {
    fn fill_row(&mut self, v: u32, dst: &mut [f32]) {
        if dst.len() == self.cache.f_in() {
            self.cache.copy_row(v, self.graph.degree(v), dst);
        } else {
            fill_feature_row(v, dst);
        }
    }
}

/// One batched cross-partition pull: the remote vertices a job's
/// layer-0 gather needs from one peer, and where to send their rows.
struct BoundaryPull {
    vertices: Vec<u32>,
    reply: mpsc::Sender<Vec<f32>>,
}

/// Boundary rows pulled for one job, indexed by vertex id. Empty when
/// nothing crossed a partition (or the pool is unpartitioned).
#[derive(Default)]
struct BoundaryRows {
    f_in: usize,
    index: HashMap<u32, usize>,
    rows: Vec<f32>,
}

/// A shard's view of the partitioned pool: its own partition id, the
/// vertex → owner map, and the peers' boundary-service queues.
#[derive(Clone)]
struct RouteCtx {
    shard: usize,
    part: Arc<Partitioning>,
    peers: Vec<mpsc::SyncSender<BoundaryPull>>,
}

/// Pull every remote layer-0 input of `nf` from its home shard: one
/// batched pull per peer, all sends first, then all receives (the
/// pulls overlap across peers). Rows whose width differs from the
/// cache row width never pull — they bypass the caches entirely (the
/// same custom-dims rule as [`CachedFeatures`]). On a shutdown race a
/// missing reply just leaves the vertex out of the map and the gather
/// synthesizes it locally — the bytes are identical either way.
///
/// Returns the rows plus the total µs this job spent waiting on its
/// peers (0 when nothing crossed a partition) — the component the
/// stage breakdown reports as `boundary_wait`, separate from the local
/// gather it used to be folded into.
fn fetch_boundary_rows(
    route: &RouteCtx,
    nf: &Nodeflow,
    in_dim: usize,
    cache_f_in: usize,
    counters: &PoolCounters,
) -> (BoundaryRows, f64) {
    let mut out = BoundaryRows { f_in: cache_f_in, ..Default::default() };
    if in_dim != cache_f_in {
        return (out, 0.0);
    }
    let mut per_peer: Vec<Vec<u32>> = vec![Vec::new(); route.peers.len()];
    for &v in &nf.layers[0].inputs {
        let owner = route.part.owner(v);
        if owner != route.shard {
            per_peer[owner].push(v);
        }
    }
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for (owner, vertices) in per_peer.into_iter().enumerate() {
        if vertices.is_empty() {
            continue;
        }
        counters.boundary_fetches.fetch_add(1, Ordering::Relaxed);
        counters.boundary_rows.fetch_add(vertices.len() as u64, Ordering::Relaxed);
        let (rtx, rrx) = mpsc::channel();
        if route.peers[owner]
            .send(BoundaryPull { vertices: vertices.clone(), reply: rtx })
            .is_ok()
        {
            pending.push((vertices, rrx));
        }
    }
    let had_pulls = !pending.is_empty();
    for (vertices, rrx) in pending {
        if let Ok(rows) = rrx.recv() {
            let base = out.rows.len() / cache_f_in;
            out.rows.extend_from_slice(&rows);
            for (i, &v) in vertices.iter().enumerate() {
                out.index.insert(v, base + i);
            }
            if let Ok(mut lat) = counters.boundary_lat.lock() {
                lat.record(t0.elapsed().as_secs_f64() * 1e6);
            }
        }
    }
    let wait_us = if had_pulls {
        t0.elapsed().as_secs_f64() * 1e6
    } else {
        0.0
    };
    (out, wait_us)
}

/// [`FeatureSource`] for a partitioned shard: remote rows come from
/// the job's pulled [`BoundaryRows`], everything else from the shard's
/// partition-local cache — same bytes as the shared-cache path, only
/// the locality differs.
struct RoutedFeatures<'a> {
    cache: &'a FeatureCache,
    graph: &'a CsrGraph,
    boundary: &'a BoundaryRows,
}

impl FeatureSource for RoutedFeatures<'_> {
    fn fill_row(&mut self, v: u32, dst: &mut [f32]) {
        if dst.len() != self.cache.f_in() {
            fill_feature_row(v, dst);
            return;
        }
        if let Some(&i) = self.boundary.index.get(&v) {
            let f = self.boundary.f_in;
            dst.copy_from_slice(&self.boundary.rows[i * f..(i + 1) * f]);
        } else {
            self.cache.copy_row(v, self.graph.degree(v), dst);
        }
    }
}

/// Stage `nf`'s layer-0 rows: through the boundary-fetch path when the
/// pool is partitioned, straight through the (shared) cache otherwise.
/// Returns the µs spent waiting on peers' boundary rows (0 when
/// unpartitioned) so callers can split the prefetch window into its
/// local-gather and boundary-wait components.
fn stage_features(
    staged: &mut StagedFeatures,
    nf: &Nodeflow,
    in_dim: usize,
    cache: &FeatureCache,
    graph: &CsrGraph,
    route: Option<&RouteCtx>,
    counters: &PoolCounters,
) -> f64 {
    // Every layer-0 input becomes one gathered feature row; the memo
    // path's transitive subtree pruning shows up as this counter
    // growing slower for the same request stream.
    counters
        .staged_rows
        .fetch_add(nf.layers[0].num_inputs() as u64, Ordering::Relaxed);
    match route {
        Some(r) => {
            let (boundary, wait_us) =
                fetch_boundary_rows(r, nf, in_dim, cache.f_in(), counters);
            let mut features = RoutedFeatures { cache, graph, boundary: &boundary };
            staged.stage(nf, in_dim, &mut features);
            wait_us
        }
        None => {
            let mut features = CachedFeatures { cache, graph };
            staged.stage(nf, in_dim, &mut features);
            0.0
        }
    }
}

/// One shard's boundary service: answer peers' batched pulls from this
/// shard's partition-local cache. Pure cache fills — the service never
/// waits on any other pool thread, so pulls can't deadlock. Exits when
/// every peer lane drops its sender.
fn boundary_service_loop(cache: &FeatureCache, graph: &CsrGraph, rx: mpsc::Receiver<BoundaryPull>) {
    let f_in = cache.f_in();
    while let Ok(pull) = rx.recv() {
        let mut rows = vec![0.0f32; pull.vertices.len() * f_in];
        for (i, &v) in pull.vertices.iter().enumerate() {
            cache.copy_row(v, graph.degree(v), &mut rows[i * f_in..(i + 1) * f_in]);
        }
        let _ = pull.reply.send(rows);
    }
}

/// A job whose edge-centric phase has completed: the built nodeflow
/// plus its staged feature rows (from a pooled buffer) and its
/// cycle-sim pass, queued for the vertex engine.
struct StagedJob {
    job: ExecJob,
    staged: StagedFeatures,
    sim: SimResult,
    /// When the prefetch lane finished staging (start of the
    /// ready-queue wait the engine measures at dequeue).
    t_staged: Instant,
}

impl ShardPool {
    /// Spawn the pool over `rx`, serving the models in `library`.
    /// `spec.shards` shards share the queue regardless of backend —
    /// each shard builds its own engine (and, for PJRT, its own
    /// non-`Send` client + device-resident weights) inside its engine
    /// thread, so no engine pins the pool to one shard anymore. With
    /// the pipeline enabled each shard additionally runs
    /// `spec.pipeline.prefetch_lanes` staging lanes feeding a bounded
    /// depth-`spec.pipeline.depth` ready queue. The shared feature
    /// cache's degree classes are calibrated from the serving graph's
    /// degree quantiles ([`DegreeClasses::from_graph`]); partitioned,
    /// each shard's cache calibrates from its own partition's degrees
    /// ([`DegreeClasses::from_degrees`]). `inflight` is
    /// decremented once per completed job — the gauge the
    /// coordinator's batcher uses for idle-aware early dispatch (the
    /// sender increments it on enqueue).
    pub fn start(
        spec: &ShardSpec,
        library: Arc<ModelLibrary>,
        graph: Arc<CsrGraph>,
        rx: mpsc::Receiver<ExecJob>,
        inflight: Arc<AtomicU64>,
    ) -> Result<ShardPool> {
        let shards = spec.shards.max(1);
        // Control-off callers get fixed knobs pinned to the configured
        // point: every knob read degenerates to the old constant.
        let knobs = spec.knobs.clone().unwrap_or_else(|| {
            Arc::new(Knobs::fixed(
                0.0,
                spec.pipeline.prefetch_lanes.max(1),
                spec.pipeline.depth.max(1),
                shards,
            ))
        });
        let partitioning = match spec.partition {
            PartitionStrategy::Off => None,
            s => Some(Arc::new(Partitioning::build(s, &graph, shards))),
        };
        // Activation-memo caches: laid out exactly like the feature
        // caches (largest-remainder split per partition, or one shared
        // instance), but only for exact-Q4.12 engines — a float or
        // timing-only backend never produces rows a splice could reuse
        // bit-for-bit, so its pool carries no memo state at all.
        let memo_active = spec.memo_rows > 0
            && matches!(spec.backend, BackendChoice::Fixed | BackendChoice::Reference);
        let memo_caches: Vec<Arc<MemoCache>> = if !memo_active {
            Vec::new()
        } else if let Some(part) = &partitioning {
            split_cache_rows(spec.memo_rows, shards)
                .into_iter()
                .enumerate()
                .map(|(i, cap)| {
                    let classes = if cap > 0 {
                        DegreeClasses::from_degrees(part.owned_degrees(&graph, i))
                    } else {
                        DegreeClasses::default()
                    };
                    Arc::new(MemoCache::with_classes(cap, classes))
                })
                .collect()
        } else {
            vec![Arc::new(MemoCache::with_classes(
                spec.memo_rows,
                DegreeClasses::from_graph(&graph),
            ))]
        };
        let memo_router = if memo_caches.is_empty() {
            None
        } else {
            Some(MemoRouter {
                caches: memo_caches.clone(),
                partition: partitioning.clone(),
                weight_seed: spec.weight_seed,
            })
        };
        // Shard i's engine deposits into (and its builder-side scope
        // reads from) the same cache the router picks for its targets.
        let shard_memo: Vec<Option<Arc<MemoCache>>> = (0..shards)
            .map(|i| {
                if memo_caches.is_empty() {
                    None
                } else if partitioning.is_some() {
                    Some(memo_caches[i].clone())
                } else {
                    Some(memo_caches[0].clone())
                }
            })
            .collect();
        let counters = Arc::new(PoolCounters::default());
        let res_counters = Arc::new(ResidencyCounters::default());
        let status = Arc::new(Mutex::new(vec![String::from("starting"); shards]));
        let routed: Arc<Vec<AtomicU64>> =
            Arc::new((0..shards).map(|_| AtomicU64::new(0)).collect());
        let mut threads = Vec::new();

        // The caches, the per-shard job queue each shard drains, and
        // (partitioned) its boundary-fetch context. Unpartitioned:
        // every shard shares one cache and one locked queue, exactly
        // the PR-5 pool. Partitioned: a router thread steers each job
        // to its target's home shard, each shard owns a slice of the
        // cache budget calibrated to its partition, and a boundary
        // service answers peers' pulls from that local cache.
        let caches: Vec<Arc<FeatureCache>>;
        let shard_caches: Vec<Arc<FeatureCache>>;
        let shard_rxs: Vec<Arc<Mutex<mpsc::Receiver<ExecJob>>>>;
        let mut routes: Vec<Option<RouteCtx>> = vec![None; shards];
        if let Some(part) = &partitioning {
            caches = split_cache_rows(spec.cache_rows, shards)
                .into_iter()
                .enumerate()
                .map(|(i, cap)| {
                    // Quantile calibration sorts the partition's degree
                    // list — skip it when this slice never admits.
                    let classes = if cap > 0 {
                        DegreeClasses::from_degrees(part.owned_degrees(&graph, i))
                    } else {
                        DegreeClasses::default()
                    };
                    Arc::new(FeatureCache::with_classes(cap, spec.model_cfg.f_in, classes))
                })
                .collect();
            shard_caches = caches.clone();

            // Home-shard queues + the router that fills them.
            let mut txs = Vec::with_capacity(shards);
            let mut rxs = Vec::with_capacity(shards);
            for _ in 0..shards {
                let (tx, srx) = mpsc::sync_channel::<ExecJob>(ROUTE_QUEUE_DEPTH);
                txs.push(tx);
                rxs.push(Arc::new(Mutex::new(srx)));
            }
            shard_rxs = rxs;
            {
                let part = part.clone();
                let routed = routed.clone();
                let handle = std::thread::Builder::new()
                    .name("grip-router".into())
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            let home =
                                job.nf.targets.first().map_or(0, |&t| part.owner(t));
                            routed[home].fetch_add(1, Ordering::Relaxed);
                            if txs[home].send(job).is_err() {
                                // Home shard died; dropping the job
                                // drops its reply senders, so callers
                                // see a closed channel, not a hang.
                                break;
                            }
                        }
                        // txs drop here → every home queue closes.
                    })
                    .map_err(|e| anyhow!("spawning router: {e}"))?;
                threads.push(handle);
            }

            // Boundary services: create every channel first so each
            // shard's RouteCtx can hold the full peer list.
            let mut peer_txs = Vec::with_capacity(shards);
            let mut peer_rxs = Vec::with_capacity(shards);
            for _ in 0..shards {
                let (btx, brx) = mpsc::sync_channel::<BoundaryPull>(BOUNDARY_QUEUE_DEPTH);
                peer_txs.push(btx);
                peer_rxs.push(brx);
            }
            for (i, brx) in peer_rxs.into_iter().enumerate() {
                let cache = caches[i].clone();
                let graph = graph.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("grip-shard-{i}-boundary"))
                    .spawn(move || boundary_service_loop(&cache, &graph, brx))
                    .map_err(|e| anyhow!("spawning shard {i} boundary service: {e}"))?;
                threads.push(handle);
            }
            for (i, slot) in routes.iter_mut().enumerate() {
                *slot = Some(RouteCtx {
                    shard: i,
                    part: part.clone(),
                    peers: peer_txs.clone(),
                });
            }
        } else {
            // Quantile calibration walks + sorts every vertex degree —
            // skip it when caching is disabled (cache_rows 0 never
            // admits).
            let classes = if spec.cache_rows > 0 {
                DegreeClasses::from_graph(&graph)
            } else {
                DegreeClasses::default()
            };
            let cache = Arc::new(FeatureCache::with_classes(
                spec.cache_rows,
                spec.model_cfg.f_in,
                classes,
            ));
            caches = vec![cache.clone()];
            shard_caches = vec![cache; shards];
            let shared = Arc::new(Mutex::new(rx));
            shard_rxs = vec![shared; shards];
        }

        // Shards signal here once their backend is built and every
        // model prepared; `start` blocks on all of them so the request
        // path never races engine construction and `stats()` always
        // reflects the shards' real backends.
        let (init_tx, init_rx) = mpsc::channel::<()>();
        for i in 0..shards {
            let route = routes[i].clone();
            if spec.pipeline.enabled {
                Self::spawn_pipelined_shard(
                    i,
                    spec,
                    &library,
                    &graph,
                    &shard_caches[i],
                    &shard_memo[i],
                    &counters,
                    &res_counters,
                    &status,
                    &init_tx,
                    &shard_rxs[i],
                    route,
                    &inflight,
                    &knobs,
                    &mut threads,
                )?;
            } else {
                let spec = spec.clone();
                let library = library.clone();
                let graph = graph.clone();
                let cache = shard_caches[i].clone();
                let memo = shard_memo[i].clone();
                let counters = counters.clone();
                let res_counters = res_counters.clone();
                let status = status.clone();
                let rx = shard_rxs[i].clone();
                let inflight = inflight.clone();
                let init_tx = init_tx.clone();
                let knobs = knobs.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("grip-shard-{i}"))
                    .spawn(move || {
                        shard_loop(
                            i,
                            &spec,
                            &library,
                            &graph,
                            &cache,
                            memo.as_deref(),
                            &counters,
                            &res_counters,
                            &status,
                            init_tx,
                            &rx,
                            route.as_ref(),
                            &inflight,
                            &knobs,
                        )
                    })
                    .map_err(|e| anyhow!("spawning shard {i}: {e}"))?;
                threads.push(handle);
            }
        }
        // Drop this thread's copies of the boundary senders (inside
        // `routes`) so the services exit once the shards' copies go.
        drop(routes);
        drop(init_tx);
        for _ in 0..shards {
            // Err only if a shard panicked during init; the join in
            // Drop will surface that — don't hang here.
            let _ = init_rx.recv();
        }
        let (edge_cut_fraction, partition_balance) = partitioning
            .as_ref()
            .map_or((0.0, 1.0), |p| (p.stats().edge_cut_fraction(), p.stats().balance));
        Ok(ShardPool {
            threads,
            caches,
            memo_caches,
            memo_router,
            counters,
            res_counters,
            residency: spec.residency,
            status,
            routed,
            partition: spec.partition,
            edge_cut_fraction,
            partition_balance,
            shards,
            telemetry: spec.telemetry.clone(),
            knobs,
        })
    }

    /// Spawn one phase-decoupled shard: prefetch threads over the
    /// shared job queue, a bounded ready queue, and the engine thread
    /// that owns the backend. Lane threads are spawned and the ready
    /// channel sized at the **knob caps** (`Knobs::max_lanes` /
    /// `Knobs::max_depth`) so the controller can widen either knob
    /// without respawning anything; lanes beyond the current knob park
    /// themselves and a narrowed depth gates admission before the
    /// channel. With fixed knobs the caps equal the configured values
    /// and both gates vanish. A free-list channel recycles
    /// `max_lanes + max_depth + 1` [`StagedFeatures`] buffers (every
    /// buffer a lane can hold + every queue slot + the one in the
    /// engine), so staging is allocation-free in steady state and the
    /// lanes can never outrun the pool.
    #[allow(clippy::too_many_arguments)]
    fn spawn_pipelined_shard(
        shard: usize,
        spec: &ShardSpec,
        library: &Arc<ModelLibrary>,
        graph: &Arc<CsrGraph>,
        cache: &Arc<FeatureCache>,
        memo: &Option<Arc<MemoCache>>,
        counters: &Arc<PoolCounters>,
        res_counters: &Arc<ResidencyCounters>,
        status: &Arc<Mutex<Vec<String>>>,
        init_tx: &mpsc::Sender<()>,
        rx: &Arc<Mutex<mpsc::Receiver<ExecJob>>>,
        route: Option<RouteCtx>,
        inflight: &Arc<AtomicU64>,
        knobs: &Arc<Knobs>,
        threads: &mut Vec<std::thread::JoinHandle<()>>,
    ) -> Result<()> {
        let lanes = knobs.max_lanes.max(1);
        let depth = knobs.max_depth.max(1);
        let (ready_tx, ready_rx) = mpsc::sync_channel::<StagedJob>(depth);
        let (free_tx, free_rx) = mpsc::channel::<StagedFeatures>();
        for _ in 0..(lanes + depth + 1) {
            free_tx.send(StagedFeatures::new()).expect("fresh channel accepts");
        }
        let free_rx = Arc::new(Mutex::new(free_rx));
        // Staged-but-not-yet-executed gauge for the occupancy metric
        // (per shard: one engine's queue, not the whole pool's).
        let ready_gauge = Arc::new(AtomicU64::new(0));

        for lane in 0..lanes {
            let spec = spec.clone();
            let library = library.clone();
            let graph = graph.clone();
            let cache = cache.clone();
            let counters = counters.clone();
            let rx = rx.clone();
            let ready_tx = ready_tx.clone();
            let free_rx = free_rx.clone();
            let ready_gauge = ready_gauge.clone();
            let route = route.clone();
            let knobs = knobs.clone();
            let handle = std::thread::Builder::new()
                .name(format!("grip-shard-{shard}-lane-{lane}"))
                .spawn(move || {
                    prefetch_lane_loop(
                        shard,
                        lane,
                        &spec,
                        &library,
                        &graph,
                        &cache,
                        &counters,
                        &rx,
                        &ready_tx,
                        &free_rx,
                        &ready_gauge,
                        route.as_ref(),
                        &knobs,
                    )
                })
                .map_err(|e| anyhow!("spawning shard {shard} lane {lane}: {e}"))?;
            threads.push(handle);
        }

        let spec_e = spec.clone();
        let library_e = library.clone();
        let memo_e = memo.clone();
        let counters_e = counters.clone();
        let res_counters_e = res_counters.clone();
        let status_e = status.clone();
        let init_tx = init_tx.clone();
        let inflight = inflight.clone();
        let knobs_e = knobs.clone();
        let handle = std::thread::Builder::new()
            .name(format!("grip-shard-{shard}-engine"))
            .spawn(move || {
                engine_loop(
                    shard, &spec_e, &library_e, memo_e.as_deref(), &counters_e,
                    &res_counters_e, &status_e, init_tx, ready_rx, free_tx, &ready_gauge,
                    &inflight, &knobs_e,
                )
            })
            .map_err(|e| anyhow!("spawning shard {shard} engine: {e}"))?;
        threads.push(handle);
        Ok(())
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The builders' handle to the activation-memo caches (`None` with
    /// `--memo-rows 0` or a non-exact backend). Consulting it during
    /// nodeflow construction is what turns cached rows into pruned
    /// subtrees.
    pub fn memo_router(&self) -> Option<MemoRouter> {
        self.memo_router.clone()
    }

    /// The shared knob cells this pool's lanes and engines read.
    pub fn knobs(&self) -> Arc<Knobs> {
        self.knobs.clone()
    }

    /// A cloneable [`SignalSource`] over this pool's counters for the
    /// control plane.
    pub fn signals(&self) -> PoolSignals {
        PoolSignals { counters: self.counters.clone(), knobs: self.knobs.clone() }
    }

    pub fn stats(&self) -> ServeStats {
        let c = &self.counters;
        let touched = c.sim_rows_touched.load(Ordering::Relaxed);
        let loaded = c.sim_rows_loaded.load(Ordering::Relaxed);
        let occ_samples = c.occupancy_samples.load(Ordering::Relaxed);
        let sim_busy = c.sim_busy_cycles.load(Ordering::Relaxed);
        let st = self.telemetry.stages();
        let rc = &self.res_counters;
        let shard_backends =
            self.status.lock().map(|s| s.clone()).unwrap_or_default();
        let cache_hits: u64 = self.caches.iter().map(|c| c.hits()).sum();
        let cache_misses: u64 = self.caches.iter().map(|c| c.misses()).sum();
        let shard_cache_rows: Vec<usize> =
            self.caches.iter().map(|c| c.capacity()).collect();
        let cache_rows_total = shard_cache_rows.iter().sum();
        let memo_hits: u64 = self.memo_caches.iter().map(|c| c.hits()).sum();
        let memo_misses: u64 = self.memo_caches.iter().map(|c| c.misses()).sum();
        let shard_memo_rows: Vec<usize> =
            self.memo_caches.iter().map(|c| c.capacity()).collect();
        let memo_rows_total: usize = shard_memo_rows.iter().sum();
        ServeStats {
            shards: self.shards,
            jobs: c.jobs.load(Ordering::Relaxed),
            timing_only_jobs: c.timing_only.load(Ordering::Relaxed),
            backend_fallbacks: c.backend_fallbacks.load(Ordering::Relaxed),
            shard_backends,
            cache_hits,
            cache_misses,
            cache_hit_rate: if cache_hits + cache_misses > 0 {
                cache_hits as f64 / (cache_hits + cache_misses) as f64
            } else {
                0.0
            },
            sim_feature_hit_rate: if touched > 0 {
                1.0 - loaded as f64 / touched as f64
            } else {
                0.0
            },
            staged_jobs: c.staged_jobs.load(Ordering::Relaxed),
            prefetch_stalls: c.prefetch_stalls.load(Ordering::Relaxed),
            engine_stalls: c.engine_stalls.load(Ordering::Relaxed),
            // Normalized by the *current* depth knob (== the configured
            // `pipeline.depth` whenever control is off or static).
            prefetch_occupancy: if occ_samples > 0 {
                c.occupancy_sum.load(Ordering::Relaxed) as f64
                    / (occ_samples as f64 * self.knobs.depth().max(1) as f64)
            } else {
                0.0
            },
            sim_phase_overlap: if sim_busy > 0 {
                c.sim_overlap_cycles.load(Ordering::Relaxed) as f64 / sim_busy as f64
            } else {
                0.0
            },
            partition: self.partition.name().to_string(),
            edge_cut_fraction: self.edge_cut_fraction,
            partition_balance: self.partition_balance,
            shard_cache_rows,
            cache_rows_total,
            shard_cache_hit_rate: self.caches.iter().map(|c| c.hit_rate()).collect(),
            routed_jobs: self.routed.iter().map(|r| r.load(Ordering::Relaxed)).collect(),
            boundary_fetches: c.boundary_fetches.load(Ordering::Relaxed),
            boundary_rows: c.boundary_rows.load(Ordering::Relaxed),
            boundary_fetch_p99_us: c
                .boundary_lat
                .lock()
                .map(|l| if l.count() > 0 { l.p99() } else { 0.0 })
                .unwrap_or(0.0),
            residency_budget_bytes: self.residency.budget_bytes as u64,
            residency_policy: if self.residency.budgeted() {
                self.residency.policy.name().to_string()
            } else {
                String::new()
            },
            residency_hits: rc.hits.load(Ordering::Relaxed),
            residency_misses: rc.misses.load(Ordering::Relaxed),
            residency_hit_rate: rc.hit_rate(),
            residency_evictions: rc.evictions.load(Ordering::Relaxed),
            residency_resident_bytes: rc.resident_bytes.load(Ordering::Relaxed),
            residency_resident_models: rc.resident_models.load(Ordering::Relaxed),
            residency_prepare_failures: rc.prepare_failures.load(Ordering::Relaxed),
            residency_prepare_p50_us: rc.prepare_lat.percentile_us(50.0),
            residency_prepare_p99_us: rc.prepare_lat.percentile_us(99.0),
            staged_rows: c.staged_rows.load(Ordering::Relaxed),
            memo_rows_total,
            shard_memo_rows,
            memo_hits,
            memo_misses,
            memo_hit_rate: if memo_hits + memo_misses > 0 {
                memo_hits as f64 / (memo_hits + memo_misses) as f64
            } else {
                0.0
            },
            memo_deposits: self.memo_caches.iter().map(|c| c.deposits()).sum(),
            memo_evictions: self.memo_caches.iter().map(|c| c.evictions()).sum(),
            memo_resident_rows: self
                .memo_caches
                .iter()
                .map(|c| c.resident_rows() as u64)
                .sum(),
            memo_resident_bytes: self.memo_caches.iter().map(|c| c.resident_bytes()).sum(),
            memo_pruned_vertices: c.memo_pruned_vertices.load(Ordering::Relaxed),
            memo_pruned_edges: c.memo_pruned_edges.load(Ordering::Relaxed),
            memo_dedup_hits: c.memo_dedup_hits.load(Ordering::Relaxed),
            queue_wait_p50_us: st.queue_wait.percentile_us(50.0),
            queue_wait_p99_us: st.queue_wait.percentile_us(99.0),
            prefetch_local_p50_us: st.prefetch_local.percentile_us(50.0),
            prefetch_local_p99_us: st.prefetch_local.percentile_us(99.0),
            boundary_wait_p50_us: st.boundary_wait.percentile_us(50.0),
            boundary_wait_p99_us: st.boundary_wait.percentile_us(99.0),
            compute_p50_us: st.compute.percentile_us(50.0),
            compute_p99_us: st.compute.percentile_us(99.0),
            reply_p50_us: st.reply.percentile_us(50.0),
            reply_p99_us: st.reply.percentile_us(99.0),
        }
    }
}

impl ServeStats {
    /// Full Prometheus text snapshot: the telemetry registry's
    /// counters, gauges, and stage histograms, followed by the
    /// pool-level counters this struct carries. The registry holds no
    /// jobs/cache counters of its own, so nothing renders twice.
    pub fn render_prometheus(&self, telemetry: &Telemetry) -> String {
        let mut out = telemetry.render_prometheus();
        let mut push = |name: &str, ty: &str, v: String| {
            out.push_str(&format!("# TYPE {name} {ty}\n{name} {v}\n"));
        };
        push("grip_jobs_total", "counter", self.jobs.to_string());
        push("grip_timing_only_jobs_total", "counter", self.timing_only_jobs.to_string());
        push("grip_backend_fallbacks_total", "counter", self.backend_fallbacks.to_string());
        push("grip_cache_hits_total", "counter", self.cache_hits.to_string());
        push("grip_cache_misses_total", "counter", self.cache_misses.to_string());
        push("grip_cache_hit_rate", "gauge", format!("{:.6}", self.cache_hit_rate));
        push("grip_staged_jobs_total", "counter", self.staged_jobs.to_string());
        push("grip_staged_rows_total", "counter", self.staged_rows.to_string());
        push("grip_prefetch_stalls_total", "counter", self.prefetch_stalls.to_string());
        push("grip_engine_stalls_total", "counter", self.engine_stalls.to_string());
        push("grip_prefetch_occupancy", "gauge", format!("{:.6}", self.prefetch_occupancy));
        push("grip_boundary_fetches_total", "counter", self.boundary_fetches.to_string());
        push("grip_boundary_rows_total", "counter", self.boundary_rows.to_string());
        push(
            "grip_boundary_fetch_p99_us",
            "gauge",
            format!("{:.3}", self.boundary_fetch_p99_us),
        );
        push("grip_shards", "gauge", self.shards.to_string());
        // Residency series render only when paging is on (budget > 0),
        // so unbudgeted Prometheus output stays byte-identical to
        // earlier PRs — the bench-gate schema check is bidirectional.
        if self.residency_budget_bytes > 0 {
            push(
                "grip_residency_budget_bytes",
                "gauge",
                self.residency_budget_bytes.to_string(),
            );
            push("grip_residency_hits_total", "counter", self.residency_hits.to_string());
            push("grip_residency_misses_total", "counter", self.residency_misses.to_string());
            push(
                "grip_residency_hit_rate",
                "gauge",
                format!("{:.6}", self.residency_hit_rate),
            );
            push(
                "grip_residency_evictions_total",
                "counter",
                self.residency_evictions.to_string(),
            );
            push(
                "grip_residency_resident_bytes",
                "gauge",
                self.residency_resident_bytes.to_string(),
            );
            push(
                "grip_residency_resident_models",
                "gauge",
                self.residency_resident_models.to_string(),
            );
            push(
                "grip_residency_prepare_failures_total",
                "counter",
                self.residency_prepare_failures.to_string(),
            );
            push(
                "grip_residency_prepare_p50_us",
                "gauge",
                format!("{:.3}", self.residency_prepare_p50_us),
            );
            push(
                "grip_residency_prepare_p99_us",
                "gauge",
                format!("{:.3}", self.residency_prepare_p99_us),
            );
        }
        // Activation-memo series render only when a memo budget is on
        // (`memo_rows_total > 0`, the same gating convention as
        // residency) — `--memo-rows 0` output stays byte-identical.
        if self.memo_rows_total > 0 {
            push("grip_memo_rows_total", "gauge", self.memo_rows_total.to_string());
            push("grip_memo_hits_total", "counter", self.memo_hits.to_string());
            push("grip_memo_misses_total", "counter", self.memo_misses.to_string());
            push("grip_memo_hit_rate", "gauge", format!("{:.6}", self.memo_hit_rate));
            push("grip_memo_deposits_total", "counter", self.memo_deposits.to_string());
            push("grip_memo_evictions_total", "counter", self.memo_evictions.to_string());
            push("grip_memo_resident_rows", "gauge", self.memo_resident_rows.to_string());
            push("grip_memo_resident_bytes", "gauge", self.memo_resident_bytes.to_string());
            push(
                "grip_memo_pruned_vertices_total",
                "counter",
                self.memo_pruned_vertices.to_string(),
            );
            push(
                "grip_memo_pruned_edges_total",
                "counter",
                self.memo_pruned_edges.to_string(),
            );
            push(
                "grip_memo_dedup_hits_total",
                "counter",
                self.memo_dedup_hits.to_string(),
            );
        }
        // Control-plane series render only when a controller ran, so
        // `--control off` output stays byte-identical to earlier PRs.
        if self.control.mode != "off" {
            let c = &self.control;
            push("grip_control_ticks_total", "counter", c.ticks.to_string());
            push("grip_control_actions_total", "counter", c.actions.to_string());
            push("grip_control_lane_actions_total", "counter", c.lane_actions.to_string());
            push("grip_control_depth_actions_total", "counter", c.depth_actions.to_string());
            push("grip_control_window_actions_total", "counter", c.window_actions.to_string());
            push("grip_control_shard_actions_total", "counter", c.shard_actions.to_string());
            push("grip_control_lanes", "gauge", c.final_lanes.to_string());
            push("grip_control_depth", "gauge", c.final_depth.to_string());
            push("grip_control_window_us", "gauge", format!("{:.3}", c.final_window_us));
            push("grip_control_active_shards", "gauge", c.final_active_shards.to_string());
        }
        out
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // The job sender must already be gone (the coordinator drops the
        // pipeline front-to-back); joining here never deadlocks because
        // the router (if any) exits on the closed upstream channel and
        // closes every home queue, each lane exits on its closed job
        // channel (dropping its boundary peer senders, which lets every
        // boundary service exit), which closes every ready queue, which
        // lets each engine exit.
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Prepare every library model on `backend` (per-shard weight
/// residency). The serving weights are derived deterministically from
/// each plan + the pool seed, so prepared state is identical across
/// shards.
fn prepare_all(
    backend: &mut dyn NumericsBackend,
    library: &ModelLibrary,
    weight_seed: u64,
) -> Result<Vec<PreparedModel>> {
    library
        .keys()
        .map(|k| {
            let plan = library.plan(k);
            let args = fixed_serving_args(plan, weight_seed);
            backend.prepare(plan, &args)
        })
        .collect()
}

/// One shard's prepared-model store: every model eagerly resident
/// forever (the pre-zoo behavior, budget 0), or the byte-budgeted
/// paging [`ResidencyManager`] (`--weight-budget-bytes > 0`). Both
/// hand [`execute_staged`] the same deterministic [`PreparedModel`]
/// bytes — residency moves *when* prepare runs, never *what* executes.
enum WeightStore {
    Eager(Vec<PreparedModel>),
    Managed(ResidencyManager),
}

impl WeightStore {
    /// Resolve `key` to its prepared state: an indexed slot (eager) or
    /// a residency lookup that may page the model in on `backend`
    /// (managed). `Err` carries the per-request prepare failure for the
    /// caller to reply + count — the slot stays empty and the tenant's
    /// next request retries.
    fn resolve(
        &mut self,
        key: ModelKey,
        backend: &mut dyn NumericsBackend,
        library: &ModelLibrary,
        weight_seed: u64,
    ) -> Result<&PreparedModel, String> {
        match self {
            WeightStore::Eager(prepared) => Ok(&prepared[key.index()]),
            WeightStore::Managed(m) => m.lookup_or_prepare(key, backend, library, weight_seed),
        }
    }
}

/// Build + prepare this shard's backend, degrading to the factory's
/// timing-only fallback on failure. Returns the engine, its weight
/// store, and the status string for [`ServeStats::shard_backends`];
/// `fell_back` drives the `backend_fallbacks` counter.
struct ShardEngine {
    backend: Box<dyn NumericsBackend>,
    store: WeightStore,
    status: String,
    fell_back: bool,
}

fn init_engine(
    shard: usize,
    spec: &ShardSpec,
    library: &ModelLibrary,
    res_counters: &Arc<ResidencyCounters>,
) -> ShardEngine {
    let factory = BackendFactory::new(spec.backend);
    if spec.residency.budgeted() {
        // Budgeted: nothing prepares at startup — models page in on
        // demand, so a prepare failure is per-request (counted into
        // `backend_fallbacks` at the miss) instead of writing the
        // whole shard off before it served anything.
        let budget = split_weight_budget(spec.residency.budget_bytes, spec.shards.max(1))[shard];
        let store = || {
            WeightStore::Managed(ResidencyManager::new(
                budget,
                spec.residency.policy,
                library,
                spec.weight_seed,
                res_counters.clone(),
            ))
        };
        return match factory.build(shard) {
            Ok(backend) => {
                let status = backend.name().to_string();
                ShardEngine { backend, store: store(), status, fell_back: false }
            }
            Err(e) => ShardEngine {
                backend: factory.fallback(),
                store: store(),
                status: format!("timing-only (fallback: {e})"),
                fell_back: true,
            },
        };
    }
    let attempt = factory.build(shard).and_then(|mut backend| {
        let prepared = prepare_all(backend.as_mut(), library, spec.weight_seed)?;
        Ok((backend, prepared))
    });
    match attempt {
        Ok((backend, prepared)) => {
            let status = backend.name().to_string();
            ShardEngine { backend, store: WeightStore::Eager(prepared), status, fell_back: false }
        }
        Err(e) => {
            let mut backend = factory.fallback();
            let prepared = prepare_all(backend.as_mut(), library, spec.weight_seed)
                .expect("timing-only prepare is infallible");
            ShardEngine {
                backend,
                store: WeightStore::Eager(prepared),
                status: format!("timing-only (fallback: {e})"),
                fell_back: true,
            }
        }
    }
}

/// Pull the next job off a (locked, shared) queue. An *active* thread
/// blocks on the channel, exactly the pre-control behavior; a *parked*
/// one — gated off by the lane or active-shards knob — polls with
/// `try_recv` instead. Work a parked thread happens to catch is still
/// served in full (a best-effort steal never changes any reply bytes;
/// parking only sheds standing concurrency), but an empty queue sends
/// it back to a short off-lock sleep. A thread that un-parks between
/// polls falls through to the blocking arm on its next pass. Returns
/// `None` when the channel closes.
fn next_job(
    rx: &Mutex<mpsc::Receiver<ExecJob>>,
    parked: impl Fn() -> bool,
) -> Option<ExecJob> {
    loop {
        let guard = rx.lock().ok()?;
        if parked() {
            match guard.try_recv() {
                Ok(j) => return Some(j),
                Err(mpsc::TryRecvError::Empty) => {
                    drop(guard);
                    std::thread::sleep(PARK_POLL);
                    continue;
                }
                Err(mpsc::TryRecvError::Disconnected) => return None,
            }
        }
        return match guard.recv() {
            Ok(j) => Some(j),
            Err(_) => None,
        };
    }
}

/// One edge-centric prefetch lane: pull a built nodeflow off the
/// shard's queue (shared across shards, or this shard's routed home
/// queue when partitioned), run its cycle sim, gather its layer-0
/// feature rows — through the shared cache, or through the local cache
/// + boundary pulls when partitioned — into a pooled [`StagedFeatures`]
/// buffer, and queue the staged job for this shard's vertex engine.
/// Lanes at or beyond the lane knob (or on a knob-quiesced shard) park
/// via [`next_job`]'s polling arm. Exits when the job queue closes (or
/// the engine is gone).
#[allow(clippy::too_many_arguments)]
fn prefetch_lane_loop(
    shard: usize,
    lane: usize,
    spec: &ShardSpec,
    library: &ModelLibrary,
    graph: &CsrGraph,
    cache: &FeatureCache,
    counters: &PoolCounters,
    rx: &Mutex<mpsc::Receiver<ExecJob>>,
    ready_tx: &mpsc::SyncSender<StagedJob>,
    free_rx: &Mutex<mpsc::Receiver<StagedFeatures>>,
    ready_gauge: &AtomicU64,
    route: Option<&RouteCtx>,
    knobs: &Knobs,
) {
    let telemetry = &spec.telemetry;
    loop {
        // Hold the queue lock only while waiting; staging runs unlocked
        // so sibling lanes (and sibling shards) overlap.
        let mut job = match next_job(rx, || {
            lane >= knobs.lanes() || shard >= knobs.active_shards()
        }) {
            Some(j) => j,
            None => break,
        };
        telemetry.stages().shard_wait.record_us(
            Instant::now().saturating_duration_since(job.t_built).as_secs_f64() * 1e6,
        );
        let dequeue_us = telemetry.now_us();
        for m in job.members.iter_mut() {
            if let Some(t) = m.trace.as_mut() {
                t.stamp(Stage::ShardDequeue, dequeue_us);
                t.shard = Some(shard);
                t.lane = Some(lane);
            }
        }
        let plan = library.plan(job.model);
        // The edge-centric window opens here: the cycle sim, the
        // staging-buffer wait, and the gather all run on this lane.
        let prefetch_start_us = telemetry.now_us();
        for m in job.members.iter_mut() {
            if let Some(t) = m.trace.as_mut() {
                t.stamp(Stage::PrefetchStart, prefetch_start_us);
            }
        }
        // Cycle-level accelerator timing runs here too: it only needs
        // (plan, nodeflow), so it belongs off the engine's critical
        // path with the rest of the edge-centric work.
        let sim = simulate(&spec.grip, plan, &job.nf);
        // A pooled staging buffer; blocks when every buffer is in
        // flight (the engine is behind — natural backpressure).
        let mut staged = {
            let guard = match free_rx.lock() {
                Ok(g) => g,
                Err(_) => break,
            };
            match guard.recv() {
                Ok(s) => s,
                Err(_) => break,
            }
        };
        let t_stage = Instant::now();
        let boundary_us = stage_features(
            &mut staged,
            &job.nf,
            plan.layers[0].in_dim,
            cache,
            graph,
            route,
            counters,
        );
        let staging_us = t_stage.elapsed().as_secs_f64() * 1e6;
        telemetry.stages().prefetch_local.record_us((staging_us - boundary_us).max(0.0));
        telemetry.stages().boundary_wait.record_us(boundary_us);
        let prefetch_end_us = telemetry.now_us();
        for m in job.members.iter_mut() {
            if let Some(t) = m.trace.as_mut() {
                t.stamp(Stage::PrefetchEnd, prefetch_end_us);
                t.boundary_wait_us = boundary_us;
            }
        }
        // Depth knob: the ready channel is sized at the cap, so a
        // narrowed knob gates admission here instead. Engaged only
        // when the knob sits below the cap (control off: knob == cap,
        // the gate vanishes and the `try_send` below keeps the
        // original stall accounting). Bounded so a wedged engine can't
        // spin a lane forever — past the limit the send falls through
        // to the channel's own backpressure.
        if knobs.depth() < knobs.max_depth {
            let mut stalled = false;
            for _ in 0..GATE_SPIN_LIMIT {
                if (ready_gauge.load(Ordering::Relaxed) as usize) < knobs.depth() {
                    break;
                }
                if !stalled {
                    counters.prefetch_stalls.fetch_add(1, Ordering::Relaxed);
                    stalled = true;
                }
                std::thread::sleep(GATE_POLL);
            }
        }
        // Gauge before send so the engine's decrement can never race
        // below zero; undone on shutdown paths.
        ready_gauge.fetch_add(1, Ordering::Relaxed);
        match ready_tx.try_send(StagedJob { job, staged, sim, t_staged: Instant::now() }) {
            Ok(()) => {}
            Err(mpsc::TrySendError::Full(sj)) => {
                // The engine is the bottleneck right now — the phases
                // are overlapping as designed; count it and wait.
                counters.prefetch_stalls.fetch_add(1, Ordering::Relaxed);
                if ready_tx.send(sj).is_err() {
                    ready_gauge.fetch_sub(1, Ordering::Relaxed);
                    break;
                }
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                ready_gauge.fetch_sub(1, Ordering::Relaxed);
                break;
            }
        }
    }
}

/// One shard's vertex engine: build the backend *in this thread*
/// (non-`Send` engines never cross threads), prepare every library
/// model once, signal readiness on `init_tx`, then drain the shard's
/// ready queue of staged jobs.
#[allow(clippy::too_many_arguments)]
fn engine_loop(
    shard: usize,
    spec: &ShardSpec,
    library: &ModelLibrary,
    memo: Option<&MemoCache>,
    counters: &PoolCounters,
    res_counters: &Arc<ResidencyCounters>,
    status: &Mutex<Vec<String>>,
    init_tx: mpsc::Sender<()>,
    ready_rx: mpsc::Receiver<StagedJob>,
    free_tx: mpsc::Sender<StagedFeatures>,
    ready_gauge: &AtomicU64,
    inflight: &AtomicU64,
    knobs: &Knobs,
) {
    let mut engine = init_engine(shard, spec, library, res_counters);
    if engine.fell_back {
        counters.backend_fallbacks.fetch_add(1, Ordering::Relaxed);
    }
    if let Ok(mut s) = status.lock() {
        s[shard] = engine.status.clone();
    }
    let mut scratch = BackendScratch::for_config(&spec.grip);
    // Init complete: unblock `ShardPool::start` (dropping the sender
    // right away so a sibling shard's panic can never wedge it).
    let _ = init_tx.send(());
    drop(init_tx);

    loop {
        let sj = match ready_rx.try_recv() {
            Ok(sj) => sj,
            Err(mpsc::TryRecvError::Empty) => {
                // Starved — but only count it when work actually exists
                // *upstream of the engines* (queued, building, or
                // staging — inflight beyond what sibling engines are
                // already executing): an idle pool's empty queue is not
                // a pipeline stall, and counting it would saturate the
                // gauge at any non-saturating load.
                let upstream = inflight.load(Ordering::Relaxed)
                    > counters.executing.load(Ordering::Relaxed);
                if upstream {
                    counters.engine_stalls.fetch_add(1, Ordering::Relaxed);
                }
                match ready_rx.recv() {
                    Ok(sj) => sj,
                    Err(_) => break,
                }
            }
            Err(mpsc::TryRecvError::Disconnected) => break,
        };
        // Occupancy sample: staged jobs still waiting after this one
        // (clamped to the current depth knob — a lane mid-handoff can
        // push the gauge one over).
        let queued = ready_gauge.fetch_sub(1, Ordering::Relaxed).saturating_sub(1);
        let depth = knobs.depth().max(1) as u64;
        counters.occupancy_sum.fetch_add(queued.min(depth), Ordering::Relaxed);
        counters.occupancy_samples.fetch_add(1, Ordering::Relaxed);
        counters.staged_jobs.fetch_add(1, Ordering::Relaxed);
        let StagedJob { job, staged, sim, t_staged } = sj;
        spec.telemetry
            .stages()
            .ready_wait
            .record_us(t_staged.elapsed().as_secs_f64() * 1e6);
        execute_staged(
            spec,
            library,
            counters,
            engine.backend.as_mut(),
            &mut engine.store,
            &mut scratch,
            &staged,
            &sim,
            memo,
            job,
        );
        // Recycle the staging buffer to the lane pool (ignore failure:
        // on shutdown the lanes are already gone).
        let _ = free_tx.send(staged);
        // Replies are out: this job no longer occupies the pipeline.
        inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// One legacy (sequential, `--pipeline off`) shard: build its backend
/// *in this thread*, prepare every library model once, signal
/// readiness on `init_tx`, then drain the shared queue, staging and
/// executing back-to-back.
#[allow(clippy::too_many_arguments)]
fn shard_loop(
    shard: usize,
    spec: &ShardSpec,
    library: &ModelLibrary,
    graph: &CsrGraph,
    cache: &FeatureCache,
    memo: Option<&MemoCache>,
    counters: &PoolCounters,
    res_counters: &Arc<ResidencyCounters>,
    status: &Mutex<Vec<String>>,
    init_tx: mpsc::Sender<()>,
    rx: &Mutex<mpsc::Receiver<ExecJob>>,
    route: Option<&RouteCtx>,
    inflight: &AtomicU64,
    knobs: &Knobs,
) {
    let mut engine = init_engine(shard, spec, library, res_counters);
    if engine.fell_back {
        counters.backend_fallbacks.fetch_add(1, Ordering::Relaxed);
    }
    if let Ok(mut s) = status.lock() {
        s[shard] = engine.status.clone();
    }
    let mut scratch = BackendScratch::for_config(&spec.grip);
    let mut staged = StagedFeatures::new();
    // Init complete: unblock `ShardPool::start` (dropping the sender
    // right away so a sibling shard's panic can never wedge it).
    let _ = init_tx.send(());
    drop(init_tx);

    loop {
        // Hold the queue lock only while waiting; execution runs
        // unlocked so shards overlap. A knob-quiesced shard parks on
        // the polling arm instead of camping on the blocking recv.
        let mut job = match next_job(rx, || shard >= knobs.active_shards()) {
            Some(j) => j,
            None => break,
        };
        spec.telemetry.stages().shard_wait.record_us(
            Instant::now().saturating_duration_since(job.t_built).as_secs_f64() * 1e6,
        );
        let dequeue_us = spec.telemetry.now_us();
        for m in job.members.iter_mut() {
            if let Some(t) = m.trace.as_mut() {
                t.stamp(Stage::ShardDequeue, dequeue_us);
                t.shard = Some(shard);
            }
        }
        execute_job(
            spec,
            library,
            graph,
            cache,
            memo,
            counters,
            engine.backend.as_mut(),
            &mut engine.store,
            &mut scratch,
            &mut staged,
            route,
            job,
        );
        // Replies are out: this job no longer occupies the pipeline.
        inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Sequential helper (the legacy loop and tests): run both phases
/// back-to-back — cycle sim + feature staging, then execution — on the
/// calling thread. The pipelined path runs the first half in a
/// prefetch lane and hands [`execute_staged`] the result.
#[allow(clippy::too_many_arguments)]
fn execute_job(
    spec: &ShardSpec,
    library: &ModelLibrary,
    graph: &CsrGraph,
    cache: &FeatureCache,
    memo: Option<&MemoCache>,
    counters: &PoolCounters,
    backend: &mut dyn NumericsBackend,
    store: &mut WeightStore,
    scratch: &mut BackendScratch,
    staged: &mut StagedFeatures,
    route: Option<&RouteCtx>,
    mut job: ExecJob,
) {
    let telemetry = &spec.telemetry;
    let plan = library.plan(job.model);
    // Sequential prefetch window: sim + gather back-to-back on the
    // calling thread (the pipelined path opens it in the lane instead).
    let prefetch_start_us = telemetry.now_us();
    for m in job.members.iter_mut() {
        if let Some(t) = m.trace.as_mut() {
            t.stamp(Stage::PrefetchStart, prefetch_start_us);
        }
    }
    let sim = simulate(&spec.grip, plan, &job.nf);
    let t_stage = Instant::now();
    let boundary_us =
        stage_features(staged, &job.nf, plan.layers[0].in_dim, cache, graph, route, counters);
    let staging_us = t_stage.elapsed().as_secs_f64() * 1e6;
    telemetry.stages().prefetch_local.record_us((staging_us - boundary_us).max(0.0));
    telemetry.stages().boundary_wait.record_us(boundary_us);
    let prefetch_end_us = telemetry.now_us();
    for m in job.members.iter_mut() {
        if let Some(t) = m.trace.as_mut() {
            t.stamp(Stage::PrefetchEnd, prefetch_end_us);
            t.boundary_wait_us = boundary_us;
        }
    }
    execute_staged(spec, library, counters, backend, store, scratch, staged, &sim, memo, job);
}

/// The vertex-centric phase: account the job's (already-run) cycle
/// sim, execute its numerics on `backend` from the staged feature
/// rows, and fan replies out to its members.
#[allow(clippy::too_many_arguments)]
fn execute_staged(
    spec: &ShardSpec,
    library: &ModelLibrary,
    counters: &PoolCounters,
    backend: &mut dyn NumericsBackend,
    store: &mut WeightStore,
    scratch: &mut BackendScratch,
    staged: &StagedFeatures,
    sim: &SimResult,
    memo: Option<&MemoCache>,
    job: ExecJob,
) {
    let ExecJob { model, nf, mut members, t_dequeue, t_built: _, memo: memo_plan } = job;
    let telemetry = &spec.telemetry;
    // Fold the build-side memo telemetry now: the pruning already
    // happened when the nodeflow was built, whatever execution does.
    if let Some(p) = &memo_plan {
        counters.memo_pruned_vertices.fetch_add(p.pruned_vertices, Ordering::Relaxed);
        counters.memo_pruned_edges.fetch_add(p.pruned_edges, Ordering::Relaxed);
        counters.memo_dedup_hits.fetch_add(p.dedup_hits, Ordering::Relaxed);
    }
    // This job is now on an engine, not upstream of one (see the
    // engine-stall accounting); the gauge drops again with the replies.
    counters.executing.fetch_add(1, Ordering::Relaxed);
    let engine_start_us = telemetry.now_us();
    for m in members.iter_mut() {
        if let Some(t) = m.trace.as_mut() {
            t.stamp(Stage::EngineStart, engine_start_us);
        }
    }

    // 1. Cycle-level accelerator timing (and the sim-side feature-cache
    //    + phase-overlap accounting mirrored into the pool stats).
    let accel_us = sim.us(&spec.grip);
    counters.jobs.fetch_add(1, Ordering::Relaxed);
    counters
        .sim_rows_touched
        .fetch_add(sim.counters.feature_rows_touched, Ordering::Relaxed);
    counters
        .sim_rows_loaded
        .fetch_add(sim.counters.feature_rows_loaded, Ordering::Relaxed);
    counters
        .sim_overlap_cycles
        .fetch_add(sim.counters.overlap_cycles, Ordering::Relaxed);
    counters.sim_busy_cycles.fetch_add(
        sim.counters.prefetch_cycles + sim.counters.compute_cycles,
        Ordering::Relaxed,
    );

    // 2. Weight residency: resolve the model's prepared state — an
    //    indexed slot (eager), or a residency lookup that may page the
    //    model in right here, charging the prepare cost to this
    //    request. A paging prepare failure is per-request: error
    //    replies fan out, `backend_fallbacks` counts it, and the
    //    tenant's next request retries an empty slot.
    let prepared = match store.resolve(model, backend, library, spec.weight_seed) {
        Ok(p) => p,
        Err(e) => {
            counters.backend_fallbacks.fetch_add(1, Ordering::Relaxed);
            for m in members {
                let _ = m.reply.send(Err(e.clone()));
            }
            counters.executing.fetch_sub(1, Ordering::Relaxed);
            return;
        }
    };

    // 3. Numerics: one backend call, whatever the engine, over the
    //    pre-gathered feature rows — splicing cached interior rows in
    //    (and harvesting fresh ones out) when a memo plan rode along.
    let mut harvest = MemoHarvest::default();
    let memo_ctx = match (&memo_plan, memo) {
        (Some(p), Some(_)) if !p.is_empty() => {
            Some(MemoCtx { plan: p, harvest: &mut harvest })
        }
        _ => None,
    };
    let t_exec = Instant::now();
    let outcome = backend.execute(prepared, &nf, staged, scratch, memo_ctx);
    telemetry.stages().compute.record_us(t_exec.elapsed().as_secs_f64() * 1e6);
    let engine_end_us = telemetry.now_us();

    // 4. Fan out per-member replies (a coalesced batch shares one
    //    nodeflow, one simulated pass, and one embedding buffer).
    match outcome {
        Err(e) => {
            let e = e.to_string();
            for m in members {
                let _ = m.reply.send(Err(e.clone()));
            }
        }
        Ok(out) => {
            // Deposit the harvested interior rows before fanning out:
            // the values are pure, so the very next request for the
            // same hub can already hit.
            if let Some(cache) = memo {
                if !harvest.rows.is_empty() {
                    cache.deposit(model, spec.weight_seed, harvest);
                }
            }
            let timing_only = !out.numerics.is_numeric();
            if timing_only {
                counters.timing_only.fetch_add(1, Ordering::Relaxed);
            }
            let service_us = t_dequeue.elapsed().as_secs_f64() * 1e6;
            let neighborhood = nf.neighborhood_size();
            let t_reply = Instant::now();
            let mut row = 0usize;
            for mut m in members {
                let embedding = if timing_only {
                    Vec::new()
                } else {
                    out.embeddings[row * out.f_out..(row + m.n_targets) * out.f_out].to_vec()
                };
                row += m.n_targets;
                let host_us = m.t_submit.elapsed().as_secs_f64() * 1e6;
                telemetry.stages().e2e.record_us(host_us);
                let resp = InferenceResponse {
                    id: m.id,
                    embedding,
                    accel_us,
                    host_us,
                    service_us,
                    neighborhood,
                    timing_only,
                };
                // Deposit the span before the send: the moment the
                // reply lands, a caller may drain the span sink.
                if let Some(mut t) = m.trace.take() {
                    t.stamp(Stage::EngineEnd, engine_end_us);
                    t.stamp(Stage::Reply, telemetry.now_us());
                    telemetry.push_span(t);
                }
                let _ = m.reply.send(Ok(resp));
            }
            telemetry.stages().reply.record_us(t_reply.elapsed().as_secs_f64() * 1e6);
        }
    }
    counters.executing.fetch_sub(1, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{FixedPointBackend, TimingOnlyBackend};
    use crate::graph::{generate, GeneratorParams};
    use crate::greta::GnnModel;
    use crate::nodeflow::Sampler;

    fn graph() -> Arc<CsrGraph> {
        Arc::new(generate(&GeneratorParams {
            nodes: 2_000,
            mean_degree: 8.0,
            ..Default::default()
        }))
    }

    /// An in-flight gauge pre-charged for `jobs` sends (the test
    /// harness enqueues directly, without the coordinator's increments).
    fn gauge(jobs: usize) -> Arc<AtomicU64> {
        Arc::new(AtomicU64::new(jobs as u64))
    }

    fn small_mc() -> ModelConfig {
        ModelConfig { sample1: 4, sample2: 3, f_in: 12, f_hid: 10, f_out: 6 }
    }

    fn submit(
        tx: &mpsc::Sender<ExecJob>,
        g: &CsrGraph,
        mc: &ModelConfig,
        model: GnnModel,
        id: u64,
        targets: &[u32],
    ) -> mpsc::Receiver<Result<InferenceResponse, String>> {
        let nf = Nodeflow::build(g, &Sampler::new(9), targets, mc);
        let (rtx, rrx) = mpsc::channel();
        tx.send(ExecJob {
            model: model.key(),
            nf,
            members: vec![ReplySlot {
                id,
                n_targets: targets.len(),
                t_submit: Instant::now(),
                reply: rtx,
                trace: None,
            }],
            t_dequeue: Instant::now(),
            t_built: Instant::now(),
            memo: None,
        })
        .unwrap();
        rrx
    }

    /// `submit` through the pool's [`MemoRouter`], the way the
    /// coordinator's builders do when `--memo-rows > 0`: consult the
    /// target's home cache while building, ship the splice plan with
    /// the job.
    fn submit_memo(
        tx: &mpsc::Sender<ExecJob>,
        router: &MemoRouter,
        g: &CsrGraph,
        mc: &ModelConfig,
        model: GnnModel,
        id: u64,
        targets: &[u32],
    ) -> mpsc::Receiver<Result<InferenceResponse, String>> {
        let scope = router.scope(model.key(), targets[0]);
        let (nf, plan) = Nodeflow::build_layers_memo(
            g,
            &Sampler::new(9),
            targets,
            &[mc.sample1, mc.sample2],
            Some(&scope),
        );
        let (rtx, rrx) = mpsc::channel();
        tx.send(ExecJob {
            model: model.key(),
            nf,
            members: vec![ReplySlot {
                id,
                n_targets: targets.len(),
                t_submit: Instant::now(),
                reply: rtx,
                trace: None,
            }],
            t_dequeue: Instant::now(),
            t_built: Instant::now(),
            memo: if plan.is_empty() { None } else { Some(plan) },
        })
        .unwrap();
        rrx
    }

    fn run_pool_on_graph(
        g: Arc<CsrGraph>,
        spec: ShardSpec,
        ids: &[u32],
    ) -> (Vec<InferenceResponse>, ServeStats) {
        let mc = spec.model_cfg;
        let (tx, rx) = mpsc::channel();
        let library = Arc::new(ModelLibrary::presets(&mc));
        let pool = ShardPool::start(&spec, library, g.clone(), rx, gauge(ids.len())).unwrap();
        let replies: Vec<_> = ids
            .iter()
            .enumerate()
            .map(|(i, &t)| submit(&tx, &g, &mc, GnnModel::Gcn, i as u64, &[t]))
            .collect();
        drop(tx);
        let out: Vec<InferenceResponse> =
            replies.into_iter().map(|r| r.recv().unwrap().unwrap()).collect();
        let stats = pool.stats();
        drop(pool);
        (out, stats)
    }

    fn run_pool_spec(
        spec: ShardSpec,
        ids: &[u32],
    ) -> (Vec<InferenceResponse>, ServeStats) {
        run_pool_on_graph(graph(), spec, ids)
    }

    fn run_pool_stats(
        shards: usize,
        backend: BackendChoice,
        ids: &[u32],
    ) -> (Vec<InferenceResponse>, ServeStats) {
        let spec = ShardSpec {
            shards,
            model_cfg: small_mc(),
            backend,
            cache_rows: 256,
            ..Default::default()
        };
        run_pool_spec(spec, ids)
    }

    fn run_pool(shards: usize, backend: BackendChoice, ids: &[u32]) -> Vec<InferenceResponse> {
        run_pool_stats(shards, backend, ids).0
    }

    #[test]
    fn fixed_point_pool_serves_embeddings() {
        let out = run_pool(2, BackendChoice::Fixed, &[1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(out.len(), 8);
        for r in &out {
            assert!(!r.timing_only);
            assert_eq!(r.embedding.len(), 6);
            assert!(r.accel_us > 0.0);
        }
    }

    #[test]
    fn pool_output_independent_of_shard_count() {
        let ids: Vec<u32> = (0..24).map(|i| i * 13 % 2000).collect();
        let one = run_pool(1, BackendChoice::Fixed, &ids);
        let four = run_pool(4, BackendChoice::Fixed, &ids);
        for (a, b) in one.iter().zip(four.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.embedding, b.embedding, "id {}", a.id);
            assert_eq!(a.accel_us, b.accel_us);
            assert_eq!(a.neighborhood, b.neighborhood);
        }
    }

    #[test]
    fn pipelined_pool_bit_identical_to_sequential_loop() {
        // THE tentpole property at pool level: any (lanes, depth) must
        // land on the sequential loop's exact bits, and the pipeline
        // counters must reflect which path ran.
        let ids: Vec<u32> = (0..24).map(|i| i * 17 % 2000).collect();
        let seq_spec = ShardSpec {
            shards: 2,
            model_cfg: small_mc(),
            backend: BackendChoice::Fixed,
            cache_rows: 256,
            pipeline: PipelineConfig::off(),
            ..Default::default()
        };
        let (seq, seq_stats) = run_pool_spec(seq_spec.clone(), &ids);
        assert_eq!(seq_stats.staged_jobs, 0, "legacy loop never stages across a queue");
        assert_eq!(seq_stats.prefetch_occupancy, 0.0);
        for (lanes, depth) in [(1, 1), (2, 2), (4, 3)] {
            let spec = ShardSpec {
                pipeline: PipelineConfig::lanes_depth(lanes, depth),
                ..seq_spec.clone()
            };
            let (pipe, stats) = run_pool_spec(spec, &ids);
            assert_eq!(stats.staged_jobs, ids.len() as u64, "{lanes}x{depth}");
            // (Tiny sampling fits one partition column, so the *sim*
            // overlap may legitimately be 0 here — the nonzero case is
            // pinned at paper sampling below.)
            assert!(stats.sim_phase_overlap >= 0.0);
            for (a, b) in seq.iter().zip(pipe.iter()) {
                assert_eq!(a.id, b.id);
                assert_eq!(
                    a.embedding, b.embedding,
                    "id {}: pipeline {lanes}x{depth} changed numerics",
                    a.id
                );
                assert_eq!(a.accel_us, b.accel_us, "id {}: timing changed", a.id);
                assert_eq!(a.neighborhood, b.neighborhood);
            }
        }
    }

    #[test]
    fn knob_narrowed_pool_stays_bit_identical() {
        // Every control gate at once: lanes knob below the spawn cap
        // (lane 1+ parks and polls), depth knob below the channel cap
        // (the admission gate engages), active-shards knob at 1 (shard
        // 1 parks). Replies must still match the ungated pool bit for
        // bit — parking sheds concurrency, never changes bytes.
        use crate::control::Knob;
        let ids: Vec<u32> = (0..24).map(|i| i * 17 % 2000).collect();
        let base = ShardSpec {
            shards: 2,
            model_cfg: small_mc(),
            backend: BackendChoice::Fixed,
            cache_rows: 256,
            pipeline: PipelineConfig::lanes_depth(2, 2),
            ..Default::default()
        };
        let (want, _) = run_pool_spec(base.clone(), &ids);
        let knobs = Arc::new(Knobs::adaptive(0.0, 0.0, 2, 2, 2));
        knobs.set(Knob::PrefetchLanes, 1);
        knobs.set(Knob::PipelineDepth, 1);
        knobs.set(Knob::ActiveShards, 1);
        let spec = ShardSpec { knobs: Some(knobs), ..base };
        let (got, stats) = run_pool_spec(spec, &ids);
        assert_eq!(stats.staged_jobs, ids.len() as u64, "all jobs served through the pipeline");
        for (a, b) in want.iter().zip(got.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.embedding, b.embedding, "id {}: knob gating changed numerics", a.id);
            assert_eq!(a.accel_us, b.accel_us);
            assert_eq!(a.neighborhood, b.neighborhood);
        }
    }

    #[test]
    fn pipeline_label_and_defaults() {
        assert_eq!(PipelineConfig::default().label(), "2x2");
        assert_eq!(PipelineConfig::off().label(), "off");
        assert_eq!(PipelineConfig::lanes_depth(0, 0).label(), "1x1", "clamped to 1");
        assert!(PipelineConfig::default().enabled);
    }

    #[test]
    fn without_numerics_replies_are_flagged_timing_only() {
        let (out, stats) = run_pool_stats(2, BackendChoice::TimingOnly, &[10, 20]);
        for r in &out {
            assert!(r.timing_only);
            assert!(r.embedding.is_empty());
            assert!(r.accel_us > 0.0, "timing still served");
        }
        // An explicitly-requested timing-only engine is not a fallback.
        assert_eq!(stats.backend_fallbacks, 0);
        assert_eq!(stats.shard_backends, vec!["timing-only", "timing-only"]);
    }

    #[test]
    fn pjrt_pool_runs_every_shard_and_reports_status() {
        // The acceptance path: `--backend pjrt --shards 4` must run all
        // 4 shards (no more shard-0 pinning) whatever happens to the
        // runtime. In default builds the stub executor fails to load,
        // so every shard reports a counted timing-only fallback instead
        // of an stderr-only message.
        let ids: Vec<u32> = (0..12).map(|i| i * 7 % 2000).collect();
        let (four, stats) = run_pool_stats(4, BackendChoice::Pjrt, &ids);
        assert_eq!(stats.shards, 4, "PJRT no longer pins the pool to one shard");
        assert_eq!(stats.shard_backends.len(), 4);
        if stats.backend_fallbacks > 0 {
            // Stub executor / no artifacts: all shards fall back, all
            // replies are tagged, and the status strings say why.
            assert_eq!(stats.backend_fallbacks, 4);
            assert!(stats
                .shard_backends
                .iter()
                .all(|s| s.starts_with("timing-only (fallback:")), "{:?}", stats.shard_backends);
            assert!(four.iter().all(|r| r.timing_only && r.embedding.is_empty()));
        } else {
            // Real PJRT runtime + artifacts: every shard serves float.
            assert!(stats.shard_backends.iter().all(|s| s == "pjrt"));
        }
        // Replies are shard-count-independent either way.
        let (one, _) = run_pool_stats(1, BackendChoice::Pjrt, &ids);
        for (a, b) in one.iter().zip(four.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.embedding, b.embedding, "id {}", a.id);
            assert_eq!(a.timing_only, b.timing_only);
        }
    }

    #[test]
    fn reference_pool_matches_fixed_pool() {
        let ids: Vec<u32> = (0..10).map(|i| i * 191 % 2000).collect();
        let fixed = run_pool(2, BackendChoice::Fixed, &ids);
        let reference = run_pool(2, BackendChoice::Reference, &ids);
        for (a, b) in fixed.iter().zip(reference.iter()) {
            assert_eq!(a.embedding, b.embedding, "id {}: hot path diverged from reference", a.id);
        }
    }

    #[test]
    fn timing_only_reply_never_leaks_a_previous_jobs_embedding() {
        // Timing-only executions share one scratch arena with numeric
        // jobs on the same shard; a stale embedding buffer must never
        // fan out to members.
        let g = graph();
        let mc = small_mc();
        let spec = ShardSpec { model_cfg: mc, ..Default::default() };
        let library = ModelLibrary::presets(&mc);
        let mut fixed: Box<dyn NumericsBackend> = Box::new(FixedPointBackend::new());
        let mut store_fx = WeightStore::Eager(
            prepare_all(fixed.as_mut(), &library, spec.weight_seed).unwrap(),
        );
        let mut timing: Box<dyn NumericsBackend> = Box::new(TimingOnlyBackend);
        let mut store_t = WeightStore::Eager(
            prepare_all(timing.as_mut(), &library, spec.weight_seed).unwrap(),
        );
        let cache = FeatureCache::new(64, mc.f_in);
        let counters = PoolCounters::default();
        let mut scratch = BackendScratch::new();
        let mut staged = StagedFeatures::new();

        let mk_job = |id: u64| {
            let nf = Nodeflow::build(&g, &Sampler::new(9), &[7], &mc);
            let (rtx, rrx) = mpsc::channel();
            let job = ExecJob {
                model: GnnModel::Gcn.key(),
                nf,
                members: vec![ReplySlot {
                    id,
                    n_targets: 1,
                    t_submit: Instant::now(),
                    reply: rtx,
                    trace: None,
                }],
                t_dequeue: Instant::now(),
                t_built: Instant::now(),
                memo: None,
            };
            (job, rrx)
        };

        // 1. A numeric job fills the shared embedding buffer.
        let (job, rx1) = mk_job(0);
        execute_job(
            &spec, &library, &g, &cache, None, &counters, fixed.as_mut(), &mut store_fx,
            &mut scratch, &mut staged, None, job,
        );
        let r1 = rx1.recv().unwrap().unwrap();
        assert!(!r1.timing_only && !r1.embedding.is_empty());

        // 2. A timing-only job reusing the same scratch must reply empty.
        let (job, rx2) = mk_job(1);
        execute_job(
            &spec, &library, &g, &cache, None, &counters, timing.as_mut(), &mut store_t,
            &mut scratch, &mut staged, None, job,
        );
        let r2 = rx2.recv().unwrap().unwrap();
        assert!(r2.timing_only, "no numeric path ran");
        assert!(r2.embedding.is_empty(), "stale embedding leaked from the previous job");
    }

    #[test]
    fn stats_track_cache_and_jobs() {
        let g = graph();
        let mc = small_mc();
        let spec = ShardSpec {
            shards: 2,
            model_cfg: mc,
            backend: BackendChoice::Fixed,
            cache_rows: 1024,
            ..Default::default()
        };
        let (tx, rx) = mpsc::channel();
        let library = Arc::new(ModelLibrary::presets(&mc));
        let pool = ShardPool::start(&spec, library, g.clone(), rx, gauge(2)).unwrap();
        // Same target twice: the second job's rows should mostly hit.
        let a = submit(&tx, &g, &mc, GnnModel::Gcn, 0, &[42]);
        a.recv().unwrap().unwrap();
        let b = submit(&tx, &g, &mc, GnnModel::Gcn, 1, &[42]);
        b.recv().unwrap().unwrap();
        drop(tx);
        let s = pool.stats();
        assert_eq!(s.jobs, 2);
        assert_eq!(s.timing_only_jobs, 0);
        assert_eq!(s.backend_fallbacks, 0);
        assert!(s.shard_backends.iter().all(|b| b == "fixed-q4.12"), "{:?}", s.shard_backends);
        assert!(s.cache_hits > 0, "repeat neighborhood must hit");
        assert!(s.cache_hit_rate > 0.0 && s.cache_hit_rate < 1.0);
        assert!(s.sim_feature_hit_rate >= 0.0);
        // The default pipeline served both jobs through a ready queue.
        assert_eq!(s.staged_jobs, 2);
        assert!(s.prefetch_occupancy >= 0.0 && s.prefetch_occupancy <= 1.0);
    }

    #[test]
    fn sim_phase_overlap_nonzero_at_paper_sampling() {
        // Paper sampling (25/10) spills a nodeflow across partition
        // columns, so the simulated prefetch/compute phases genuinely
        // overlap — the acceptance criterion's "nonzero overlap
        // counters at paper dims" (feature dims shrunk to keep the
        // fixed-point matmul test-sized; overlap depends on sampling).
        let mc = ModelConfig { f_in: 16, f_hid: 12, f_out: 8, ..ModelConfig::paper() };
        // The 2k-node test graph's mean degree (8) caps the sampled
        // fan-in below the paper graphs', and a single-target nodeflow
        // fills one output chunk at the paper's part_outputs = 11;
        // shrink both partition chunk dims so the nodeflow spans
        // several columns like batched paper-scale neighborhoods do.
        let mut grip = GripConfig::paper();
        grip.part_inputs = 32;
        grip.part_outputs = 4;
        let spec = ShardSpec {
            shards: 1,
            grip,
            model_cfg: mc,
            backend: BackendChoice::Fixed,
            cache_rows: 512,
            ..Default::default()
        };
        let ids: Vec<u32> = (0..4).map(|i| i * 401 % 2000).collect();
        let (out, stats) = run_pool_spec(spec, &ids);
        assert!(out.iter().all(|r| !r.timing_only));
        assert_eq!(stats.staged_jobs, ids.len() as u64);
        assert!(
            stats.sim_phase_overlap > 0.0,
            "multi-column nodeflows must overlap phases in the sim mirror"
        );
        assert!(stats.sim_phase_overlap < 1.0);
    }

    #[test]
    fn split_cache_rows_largest_remainder_is_exact() {
        assert_eq!(split_cache_rows(1000, 1), vec![1000]);
        assert_eq!(split_cache_rows(1000, 3), vec![334, 333, 333]);
        assert_eq!(split_cache_rows(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(split_cache_rows(2, 4), vec![1, 1, 0, 0]);
        assert_eq!(split_cache_rows(0, 3), vec![0, 0, 0]);
        for rows in [0usize, 1, 7, 4096, 4097] {
            for shards in 1..=8 {
                let split = split_cache_rows(rows, shards);
                assert_eq!(split.iter().sum::<usize>(), rows, "{rows}/{shards}");
                let min = *split.iter().min().unwrap();
                let max = *split.iter().max().unwrap();
                assert!(max - min <= 1, "{rows}/{shards}: {split:?}");
            }
        }
    }

    #[test]
    fn partitioned_pool_keeps_total_cache_rows_invariant() {
        // The memory-accounting satellite: the same --cache-rows budget
        // must stay resident whatever the shard count, split per shard
        // and reported per shard.
        let ids: Vec<u32> = (0..8).map(|i| i * 37 % 2000).collect();
        for shards in [1usize, 3, 4] {
            let spec = ShardSpec {
                shards,
                model_cfg: small_mc(),
                backend: BackendChoice::TimingOnly,
                cache_rows: 1000,
                partition: PartitionStrategy::Degree,
                ..Default::default()
            };
            let (_, stats) = run_pool_spec(spec, &ids);
            assert_eq!(stats.partition, "degree");
            assert_eq!(stats.shard_cache_rows.len(), shards);
            assert_eq!(stats.cache_rows_total, 1000, "shards={shards}");
            assert_eq!(stats.shard_cache_hit_rate.len(), shards);
            let min = *stats.shard_cache_rows.iter().min().unwrap();
            let max = *stats.shard_cache_rows.iter().max().unwrap();
            assert!(max - min <= 1, "{:?}", stats.shard_cache_rows);
            if shards > 1 {
                assert!(stats.edge_cut_fraction > 0.0);
            }
            assert!(stats.partition_balance >= 1.0 - 1e-12);
        }
        // Unpartitioned: one shared cache holds the whole budget.
        let spec = ShardSpec {
            shards: 4,
            model_cfg: small_mc(),
            backend: BackendChoice::TimingOnly,
            cache_rows: 1000,
            ..Default::default()
        };
        let (_, stats) = run_pool_spec(spec, &ids);
        assert_eq!(stats.partition, "off");
        assert_eq!(stats.shard_cache_rows, vec![1000]);
        assert_eq!(stats.cache_rows_total, 1000);
        assert_eq!(stats.edge_cut_fraction, 0.0);
        assert_eq!(stats.boundary_fetches, 0);
        assert_eq!(stats.routed_jobs, vec![0, 0, 0, 0]);
    }

    #[test]
    fn partitioned_pool_bit_identical_to_off() {
        // Pool-level spot check (the full strategy × shards × preset
        // matrix lives in tests/serve_props.rs): routing + local caches
        // + boundary pulls may never change a single bit.
        let ids: Vec<u32> = (0..24).map(|i| i * 13 % 2000).collect();
        let base = ShardSpec {
            shards: 2,
            model_cfg: small_mc(),
            backend: BackendChoice::Fixed,
            cache_rows: 256,
            ..Default::default()
        };
        let (off, _) = run_pool_spec(base.clone(), &ids);
        for strategy in [PartitionStrategy::Degree, PartitionStrategy::Hash] {
            let spec = ShardSpec { partition: strategy, ..base.clone() };
            let (part, stats) = run_pool_spec(spec, &ids);
            assert_eq!(stats.partition, strategy.name());
            assert_eq!(stats.routed_jobs.iter().sum::<u64>(), ids.len() as u64);
            for (a, b) in off.iter().zip(part.iter()) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.embedding, b.embedding, "id {}: {strategy:?}", a.id);
                assert_eq!(a.accel_us, b.accel_us);
                assert_eq!(a.neighborhood, b.neighborhood);
            }
        }
    }

    /// Serve a round-robin multi-model mix (all four presets) through
    /// a pool — the residency tests need lookups that churn more than
    /// one model per shard.
    fn run_pool_mixed(spec: ShardSpec, ids: &[u32]) -> (Vec<InferenceResponse>, ServeStats) {
        use crate::greta::ALL_MODELS;
        let g = graph();
        let mc = spec.model_cfg;
        let (tx, rx) = mpsc::channel();
        let library = Arc::new(ModelLibrary::presets(&mc));
        let pool = ShardPool::start(&spec, library, g.clone(), rx, gauge(ids.len())).unwrap();
        let replies: Vec<_> = ids
            .iter()
            .enumerate()
            .map(|(i, &t)| submit(&tx, &g, &mc, ALL_MODELS[i % ALL_MODELS.len()], i as u64, &[t]))
            .collect();
        drop(tx);
        let out: Vec<InferenceResponse> =
            replies.into_iter().map(|r| r.recv().unwrap().unwrap()).collect();
        let stats = pool.stats();
        drop(pool);
        (out, stats)
    }

    #[test]
    fn budgeted_pool_is_bit_identical_and_evicts() {
        use crate::residency::{plan_weight_bytes, EvictPolicy};
        let mc = small_mc();
        let library = ModelLibrary::presets(&mc);
        // Tight: fits the largest preset plus a sliver, so a 4-model
        // round robin must churn. Unlimited (0) is the baseline.
        let max_bytes = library
            .keys()
            .map(|k| plan_weight_bytes(&library, k, ShardSpec::default().weight_seed))
            .max()
            .unwrap();
        let ids: Vec<u32> = (0..24).map(|i| i * 13 % 2000).collect();
        let base = ShardSpec {
            shards: 1,
            model_cfg: mc,
            backend: BackendChoice::Fixed,
            cache_rows: 256,
            ..Default::default()
        };
        let (want, base_stats) = run_pool_mixed(base.clone(), &ids);
        assert_eq!(base_stats.residency_budget_bytes, 0);
        assert_eq!(base_stats.residency_misses, 0, "unbudgeted pool never pages");
        assert_eq!(base_stats.residency_policy, "");
        for policy in [EvictPolicy::Lru, EvictPolicy::Cost, EvictPolicy::SizeAware] {
            let spec = ShardSpec {
                residency: ResidencyConfig { budget_bytes: max_bytes + 1, policy },
                ..base.clone()
            };
            let (got, stats) = run_pool_mixed(spec, &ids);
            assert!(stats.residency_evictions >= 1, "{policy:?}: tight budget must evict");
            assert!(stats.residency_misses >= 4, "{policy:?}: every preset pages in at least once");
            assert!(
                stats.residency_resident_bytes <= stats.residency_budget_bytes,
                "{policy:?}: resident {} > budget {}",
                stats.residency_resident_bytes,
                stats.residency_budget_bytes
            );
            assert_eq!(stats.residency_policy, policy.name());
            assert_eq!(stats.residency_prepare_failures, 0);
            assert!(stats.residency_prepare_p99_us > 0.0, "{policy:?}: prepare cost recorded");
            for (a, b) in want.iter().zip(got.iter()) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.embedding, b.embedding, "id {}: paging changed numerics", a.id);
                assert_eq!(a.accel_us, b.accel_us);
                assert_eq!(a.neighborhood, b.neighborhood);
            }
        }
    }

    #[test]
    fn residency_series_render_only_when_budgeted() {
        let ids: Vec<u32> = (0..8).map(|i| i * 13 % 2000).collect();
        let base = ShardSpec {
            shards: 1,
            model_cfg: small_mc(),
            backend: BackendChoice::Fixed,
            cache_rows: 64,
            ..Default::default()
        };
        let (_, off) = run_pool_mixed(base.clone(), &ids);
        let prom_off = off.render_prometheus(&Telemetry::default());
        assert!(
            !prom_off.contains("grip_residency_"),
            "unbudgeted Prometheus output must not leak residency series"
        );
        let spec = ShardSpec {
            residency: ResidencyConfig { budget_bytes: 1 << 20, ..Default::default() },
            ..base
        };
        let (_, on) = run_pool_mixed(spec, &ids);
        let prom_on = on.render_prometheus(&Telemetry::default());
        for series in [
            "grip_residency_budget_bytes",
            "grip_residency_hits_total",
            "grip_residency_misses_total",
            "grip_residency_hit_rate",
            "grip_residency_evictions_total",
            "grip_residency_resident_bytes",
            "grip_residency_resident_models",
            "grip_residency_prepare_failures_total",
            "grip_residency_prepare_p50_us",
            "grip_residency_prepare_p99_us",
        ] {
            assert!(prom_on.contains(series), "missing {series}");
        }
    }

    /// A 4-vertex directed ring: every vertex has degree 1, so the LPT
    /// greedy deterministically assigns owners [0, 1, 0, 1] over 2
    /// parts — every 2-hop neighborhood {t, t+1, t+2} contains exactly
    /// one remote layer-0 input.
    fn ring4() -> Arc<CsrGraph> {
        Arc::new(CsrGraph::from_adjacency(vec![vec![1], vec![2], vec![3], vec![0]]))
    }

    #[test]
    fn boundary_fetch_counters_match_a_crafted_cut() {
        let g = ring4();
        let mc = small_mc();
        let part = Partitioning::build(PartitionStrategy::Degree, &g, 2);
        assert_eq!((0..4u32).map(|v| part.owner(v)).collect::<Vec<_>>(), vec![0, 1, 0, 1]);
        // Expected pulls, derived from the same deterministic nodeflows
        // the pool will build: one batched pull per remote peer per job.
        let targets = [0u32, 1, 2, 3];
        let (mut want_pulls, mut want_rows) = (0u64, 0u64);
        for &t in &targets {
            let nf = Nodeflow::build(&g, &Sampler::new(9), &[t], &mc);
            let home = part.owner(t);
            let mut per_peer = [0u64; 2];
            for &v in &nf.layers[0].inputs {
                if part.owner(v) != home {
                    per_peer[part.owner(v)] += 1;
                }
            }
            for c in per_peer {
                if c > 0 {
                    want_pulls += 1;
                    want_rows += c;
                }
            }
        }
        assert!(want_pulls >= 1, "the crafted cut must cross partitions");

        let spec = ShardSpec {
            shards: 2,
            model_cfg: mc,
            backend: BackendChoice::Fixed,
            cache_rows: 16,
            partition: PartitionStrategy::Degree,
            ..Default::default()
        };
        let (part_out, stats) = run_pool_on_graph(g.clone(), spec.clone(), &targets);
        assert_eq!(stats.boundary_fetches, want_pulls);
        assert_eq!(stats.boundary_rows, want_rows);
        assert!(stats.boundary_fetch_p99_us > 0.0, "pull latency was recorded");
        assert_eq!(stats.routed_jobs, vec![2, 2]);

        // Boundary-pulled rows are the exact bytes local synthesis
        // yields: replies match the unpartitioned pool bit for bit.
        let off_spec = ShardSpec { partition: PartitionStrategy::Off, ..spec };
        let (off_out, off_stats) = run_pool_on_graph(g, off_spec, &targets);
        assert_eq!(off_stats.boundary_fetches, 0);
        for (a, b) in off_out.iter().zip(part_out.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.embedding, b.embedding, "id {}", a.id);
            assert_eq!(a.accel_us, b.accel_us);
        }
    }

    #[test]
    fn router_steers_jobs_to_home_shards() {
        let g = ring4();
        let spec = ShardSpec {
            shards: 2,
            model_cfg: small_mc(),
            backend: BackendChoice::TimingOnly,
            cache_rows: 16,
            partition: PartitionStrategy::Degree,
            ..Default::default()
        };
        // Owners are [0, 1, 0, 1]; both targets live on shard 0, so
        // shard 1 gets nothing.
        let (_, stats) = run_pool_on_graph(g, spec, &[0, 2]);
        assert_eq!(stats.routed_jobs, vec![2, 0]);
    }

    /// The highest-degree vertices of the test graph — guaranteed to
    /// sit in the top degree classes the memo cache admits.
    fn hub_targets(g: &CsrGraph, n: usize) -> Vec<u32> {
        let mut by_degree: Vec<u32> = (0..g.num_vertices() as u32).collect();
        by_degree.sort_by_key(|&v| std::cmp::Reverse(g.degree(v)));
        by_degree.truncate(n);
        by_degree
    }

    #[test]
    fn memoized_pool_hits_prunes_and_stays_bit_identical() {
        // THE tentpole property at pool level: serving the same hub
        // targets twice through the memo path must (a) hit the cache,
        // (b) prune build work and stage fewer rows, and (c) change not
        // one bit of any reply relative to the memo-off pool.
        let g = graph();
        let mc = small_mc();
        let targets = hub_targets(&g, 4);
        // Each hub target twice, serially (reply awaited between
        // submissions so the first job's deposit precedes the second
        // job's build-time lookup — deterministic hits).
        let schedule: Vec<u32> = targets.iter().chain(targets.iter()).copied().collect();

        let run = |memo_rows: usize| {
            let spec = ShardSpec {
                shards: 1,
                model_cfg: mc,
                backend: BackendChoice::Fixed,
                cache_rows: 256,
                memo_rows,
                ..Default::default()
            };
            let (tx, rx) = mpsc::channel();
            let library = Arc::new(ModelLibrary::presets(&mc));
            let pool =
                ShardPool::start(&spec, library, g.clone(), rx, gauge(schedule.len())).unwrap();
            let router = pool.memo_router();
            assert_eq!(router.is_some(), memo_rows > 0, "router gated on the budget");
            let mut out = Vec::new();
            for (i, &t) in schedule.iter().enumerate() {
                let rrx = match &router {
                    Some(r) => submit_memo(&tx, r, &g, &mc, GnnModel::Gcn, i as u64, &[t]),
                    None => submit(&tx, &g, &mc, GnnModel::Gcn, i as u64, &[t]),
                };
                out.push(rrx.recv().unwrap().unwrap());
            }
            drop(tx);
            let stats = pool.stats();
            drop(pool);
            (out, stats)
        };

        let (want, base) = run(0);
        assert_eq!(base.memo_rows_total, 0);
        assert_eq!(base.memo_hits + base.memo_misses + base.memo_deposits, 0);
        assert_eq!(base.memo_pruned_vertices, 0);
        assert!(base.staged_rows > 0, "staged-row accounting is always on");

        let (got, stats) = run(4096);
        assert_eq!(stats.memo_rows_total, 4096);
        assert_eq!(stats.shard_memo_rows, vec![4096]);
        assert!(stats.memo_deposits > 0, "first pass harvested hub rows");
        assert!(stats.memo_hits > 0, "second pass must hit the deposited hubs");
        assert!(stats.memo_hit_rate > 0.0);
        assert!(stats.memo_pruned_vertices > 0);
        assert!(stats.memo_pruned_edges > 0);
        assert!(stats.memo_resident_rows > 0);
        assert!(stats.memo_resident_bytes > 0);
        assert!(
            stats.staged_rows < base.staged_rows,
            "subtree pruning must gather fewer layer-0 rows ({} vs {})",
            stats.staged_rows,
            base.staged_rows
        );
        for (a, b) in want.iter().zip(got.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.embedding, b.embedding, "id {}: memoization changed numerics", a.id);
            assert!(
                b.accel_us <= a.accel_us,
                "id {}: pruned nodeflow simulated slower ({} > {})",
                a.id,
                b.accel_us,
                a.accel_us
            );
        }
    }

    #[test]
    fn memo_budget_splits_and_series_gate_like_residency() {
        let g = graph();
        // Partitioned: the memo budget splits across shards by largest
        // remainder, exactly like --cache-rows.
        for shards in [1usize, 3, 4] {
            let spec = ShardSpec {
                shards,
                model_cfg: small_mc(),
                backend: BackendChoice::Fixed,
                cache_rows: 64,
                memo_rows: 1000,
                partition: PartitionStrategy::Degree,
                ..Default::default()
            };
            let (tx, rx) = mpsc::channel();
            let library = Arc::new(ModelLibrary::presets(&small_mc()));
            let pool = ShardPool::start(&spec, library, g.clone(), rx, gauge(0)).unwrap();
            drop(tx);
            let stats = pool.stats();
            drop(pool);
            assert_eq!(stats.shard_memo_rows.len(), shards);
            assert_eq!(stats.memo_rows_total, 1000, "shards={shards}");
            let min = *stats.shard_memo_rows.iter().min().unwrap();
            let max = *stats.shard_memo_rows.iter().max().unwrap();
            assert!(max - min <= 1, "{:?}", stats.shard_memo_rows);
            // Prometheus renders every memo series iff the budget is on.
            let prom = stats.render_prometheus(&Telemetry::default());
            for series in [
                "grip_memo_rows_total",
                "grip_memo_hits_total",
                "grip_memo_misses_total",
                "grip_memo_hit_rate",
                "grip_memo_deposits_total",
                "grip_memo_evictions_total",
                "grip_memo_resident_rows",
                "grip_memo_resident_bytes",
                "grip_memo_pruned_vertices_total",
                "grip_memo_pruned_edges_total",
                "grip_memo_dedup_hits_total",
            ] {
                assert!(prom.contains(series), "missing {series}");
            }
            assert!(prom.contains("grip_staged_rows_total"), "staged rows always render");
        }
        // A non-exact backend ignores the budget entirely: no caches,
        // no router, no leaked series — same bytes as --memo-rows 0.
        let spec = ShardSpec {
            shards: 2,
            model_cfg: small_mc(),
            backend: BackendChoice::TimingOnly,
            cache_rows: 64,
            memo_rows: 4096,
            ..Default::default()
        };
        let (tx, rx) = mpsc::channel();
        let library = Arc::new(ModelLibrary::presets(&small_mc()));
        let pool = ShardPool::start(&spec, library, g, rx, gauge(0)).unwrap();
        drop(tx);
        assert!(pool.memo_router().is_none());
        let stats = pool.stats();
        drop(pool);
        assert_eq!(stats.memo_rows_total, 0);
        let prom = stats.render_prometheus(&Telemetry::default());
        assert!(!prom.contains("grip_memo_"), "timing-only pool must not leak memo series");
    }
}
