//! SLO-aware dynamic batcher: coalesces compatible single-target
//! requests into multi-target batches **by deadline, not by count**.
//!
//! Count-based batching (wait for K requests) has unbounded worst-case
//! wait at low load; timer-based batching (flush every T) wastes
//! latency budget at high load. This batcher instead gives every
//! request a *dispatch deadline* — `arrival + slo_us - margin_us`,
//! where `margin_us` is the budget reserved for nodeflow build and
//! execution downstream — and dispatches a batch at the earliest of:
//!
//! * a compatible queue reaching `max_batch` (the AOT padding budget), or
//! * the oldest member's dispatch deadline arriving.
//!
//! "Compatible" means *same model*: a coalesced batch shares one
//! nodeflow build and one accelerator pass, which is only meaningful
//! within a model's plan. Multi-target requests submitted by callers
//! bypass the batcher (they are already batches).
//!
//! The struct is a pure state machine over an explicit clock (`now_us`)
//! — no threads, no `Instant` — so its deadline discipline is property-
//! tested in virtual time (`tests/serve_props.rs`); the coordinator
//! drives it with a real clock and `recv_timeout`.

use crate::greta::ModelKey;
use std::collections::VecDeque;

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchConfig {
    /// End-to-end latency budget per request, µs. The dispatch deadline
    /// is `arrival + slo_us - margin_us`.
    pub slo_us: f64,
    /// Budget reserved for build + execution after dispatch, µs.
    pub margin_us: f64,
    /// Maximum coalesced targets per batch (keep within the AOT
    /// artifact padding so batched numerics don't fall back to
    /// timing-only).
    pub max_batch: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        Self { slo_us: 5_000.0, margin_us: 1_500.0, max_batch: 8 }
    }
}

/// A queued request with its dispatch deadline.
#[derive(Debug, Clone)]
pub struct Pending<T> {
    pub item: T,
    pub arrival_us: f64,
    pub dispatch_by_us: f64,
}

/// The batcher state machine. `T` is the caller's per-request payload
/// (the coordinator stores its reply slot; tests store request ids).
/// Queues are keyed by [`ModelKey`] — presets and registered custom
/// specs alike — and materialize on first use, so the batcher needs no
/// knowledge of how many models the serving library holds.
pub struct Batcher<T> {
    cfg: BatchConfig,
    /// Dispatch window applied to newly offered requests, µs. Starts
    /// at `(slo_us - margin_us).max(0)` and is runtime-adjustable
    /// ([`Batcher::set_window_us`]) so the control plane can trade
    /// batching efficiency against SLO margin without restarting.
    window_us: f64,
    /// One FIFO per model, indexed by [`ModelKey::index`].
    queues: Vec<VecDeque<Pending<T>>>,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatchConfig) -> Self {
        let window_us = (cfg.slo_us - cfg.margin_us).max(0.0);
        Self { cfg, window_us, queues: Vec::new() }
    }

    pub fn config(&self) -> BatchConfig {
        self.cfg
    }

    pub fn window_us(&self) -> f64 {
        self.window_us
    }

    /// Adjust the dispatch window. Applies to requests offered from
    /// now on; already-queued deadlines stand (so a narrowing can
    /// never push an admitted request past the budget it was given).
    pub fn set_window_us(&mut self, window_us: f64) {
        if window_us.is_finite() {
            self.window_us = window_us.max(0.0);
        }
    }

    /// Queue a single-target request arriving at `now_us`.
    pub fn offer(&mut self, model: ModelKey, item: T, now_us: f64) {
        let headroom = self.window_us;
        let i = model.index();
        if i >= self.queues.len() {
            self.queues.resize_with(i + 1, VecDeque::new);
        }
        self.queues[i].push_back(Pending {
            item,
            arrival_us: now_us,
            dispatch_by_us: now_us + headroom,
        });
    }

    /// Earliest dispatch deadline across all queues (None when idle).
    /// The driver should wake no later than this time; a full queue is
    /// dispatchable immediately and is reported as "due now" by
    /// [`Batcher::pop_due`].
    pub fn next_deadline(&self) -> Option<f64> {
        self.queues
            .iter()
            .filter_map(|q| q.front().map(|p| p.dispatch_by_us))
            .min_by(|a, b| a.partial_cmp(b).expect("deadlines are finite"))
    }

    /// Dispatch one due batch: a queue that is full, or whose oldest
    /// member's deadline has arrived. Queues are drained oldest-
    /// deadline-first; members leave in FIFO order, at most `max_batch`
    /// at a time. Returns None when nothing is due at `now_us`.
    pub fn pop_due(&mut self, now_us: f64) -> Option<(ModelKey, Vec<Pending<T>>)> {
        let max_batch = self.cfg.max_batch.max(1);
        // Full queues first (they free padding-bounded capacity).
        for (i, q) in self.queues.iter_mut().enumerate() {
            if q.len() >= max_batch {
                let batch = q.drain(..max_batch).collect();
                return Some((ModelKey::from_index(i), batch));
            }
        }
        // Then the queue with the earliest expired deadline.
        let due = self
            .queues
            .iter()
            .enumerate()
            .filter_map(|(i, q)| q.front().map(|p| (i, p.dispatch_by_us)))
            .filter(|&(_, d)| d <= now_us)
            .min_by(|a, b| a.1.partial_cmp(&b.1).expect("deadlines are finite"));
        let (i, _) = due?;
        let q = &mut self.queues[i];
        let take = q.len().min(max_batch);
        let batch = q.drain(..take).collect();
        Some((ModelKey::from_index(i), batch))
    }

    /// Drain everything regardless of deadline (shutdown path).
    pub fn pop_all(&mut self) -> Option<(ModelKey, Vec<Pending<T>>)> {
        let max_batch = self.cfg.max_batch.max(1);
        for (i, q) in self.queues.iter_mut().enumerate() {
            if !q.is_empty() {
                let take = q.len().min(max_batch);
                let batch = q.drain(..take).collect();
                return Some((ModelKey::from_index(i), batch));
            }
        }
        None
    }

    /// Requests currently held.
    pub fn len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::greta::GnnModel;

    fn cfg(slo: f64, margin: f64, max_batch: usize) -> BatchConfig {
        BatchConfig { slo_us: slo, margin_us: margin, max_batch }
    }

    #[test]
    fn holds_until_deadline_then_dispatches() {
        let mut b = Batcher::new(cfg(1000.0, 200.0, 8));
        b.offer(GnnModel::Gcn.key(), 1u64, 0.0);
        b.offer(GnnModel::Gcn.key(), 2u64, 100.0);
        // Deadline of the oldest member: 0 + (1000 - 200) = 800.
        assert_eq!(b.next_deadline(), Some(800.0));
        assert!(b.pop_due(799.0).is_none(), "not due yet");
        let (m, batch) = b.pop_due(800.0).expect("due at the deadline");
        assert_eq!(m, GnnModel::Gcn.key());
        assert_eq!(batch.iter().map(|p| p.item).collect::<Vec<_>>(), vec![1, 2]);
        assert!(b.is_empty());
    }

    #[test]
    fn full_queue_dispatches_early() {
        let mut b = Batcher::new(cfg(10_000.0, 0.0, 3));
        for i in 0..3u64 {
            b.offer(GnnModel::Sage.key(), i, i as f64);
        }
        // Well before any deadline, the full queue goes out.
        let (m, batch) = b.pop_due(5.0).expect("full batch due immediately");
        assert_eq!(m, GnnModel::Sage.key());
        assert_eq!(batch.len(), 3);
    }

    #[test]
    fn models_never_mix() {
        let mut b = Batcher::new(cfg(100.0, 0.0, 8));
        b.offer(GnnModel::Gcn.key(), 1u64, 0.0);
        b.offer(GnnModel::Gin.key(), 2u64, 0.0);
        let mut seen = Vec::new();
        while let Some((m, batch)) = b.pop_due(1e9) {
            seen.push((m, batch.len()));
        }
        seen.sort_by_key(|&(m, _)| m);
        assert_eq!(seen, vec![(GnnModel::Gcn.key(), 1), (GnnModel::Gin.key(), 1)]);
    }

    #[test]
    fn custom_model_keys_get_their_own_queue() {
        // Keys beyond the four presets (registered custom specs) batch
        // independently, never mixing with preset queues.
        let custom = ModelKey::from_index(7);
        let mut b = Batcher::new(cfg(100.0, 0.0, 8));
        b.offer(GnnModel::Gcn.key(), 1u64, 0.0);
        b.offer(custom, 2u64, 0.0);
        let mut seen = Vec::new();
        while let Some((m, batch)) = b.pop_due(1e9) {
            seen.push((m, batch.len()));
        }
        seen.sort_by_key(|&(m, _)| m);
        assert_eq!(seen, vec![(GnnModel::Gcn.key(), 1), (custom, 1)]);
    }

    #[test]
    fn oversized_queue_dispatches_in_fifo_chunks() {
        let mut b = Batcher::new(cfg(100.0, 0.0, 4));
        for i in 0..10u64 {
            b.offer(GnnModel::Ggcn.key(), i, 0.0);
        }
        let mut out = Vec::new();
        while let Some((_, batch)) = b.pop_due(1e9) {
            assert!(batch.len() <= 4);
            out.extend(batch.into_iter().map(|p| p.item));
        }
        assert_eq!(out, (0..10).collect::<Vec<u64>>(), "FIFO across chunks");
    }

    #[test]
    fn margin_larger_than_slo_means_dispatch_now() {
        let mut b = Batcher::new(cfg(100.0, 500.0, 8));
        b.offer(GnnModel::Gcn.key(), 1u64, 42.0);
        assert_eq!(b.next_deadline(), Some(42.0), "no headroom left");
        assert!(b.pop_due(42.0).is_some());
    }

    #[test]
    fn runtime_window_applies_to_new_offers_only() {
        let mut b = Batcher::new(cfg(1000.0, 200.0, 8));
        assert_eq!(b.window_us(), 800.0);
        b.offer(GnnModel::Gcn.key(), 1u64, 0.0);
        b.set_window_us(100.0);
        b.offer(GnnModel::Gcn.key(), 2u64, 50.0);
        // The queued deadline (800) stands; the new offer got 50+100.
        assert_eq!(b.next_deadline(), Some(800.0));
        let (_, batch) = b.pop_due(800.0).expect("due");
        assert_eq!(
            batch.iter().map(|p| p.dispatch_by_us).collect::<Vec<_>>(),
            vec![800.0, 150.0]
        );
        // Negative/NaN inputs clamp instead of corrupting deadlines.
        b.set_window_us(-5.0);
        assert_eq!(b.window_us(), 0.0);
        b.set_window_us(f64::NAN);
        assert_eq!(b.window_us(), 0.0);
    }

    #[test]
    fn pop_all_drains_everything() {
        let mut b = Batcher::new(cfg(1e6, 0.0, 2));
        for i in 0..5u64 {
            b.offer(GnnModel::Gcn.key(), i, 0.0);
        }
        let mut n = 0;
        while let Some((_, batch)) = b.pop_all() {
            n += batch.len();
        }
        assert_eq!(n, 5);
        assert!(b.is_empty());
    }
}
