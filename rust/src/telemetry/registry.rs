//! Lock-light metric registry: named counters, gauges, and atomic
//! log₂ histograms.
//!
//! The registry itself is a mutex-guarded name table, but it is only
//! touched at get-or-create time — callers hold `Arc` handles and the
//! hot path is a handful of `Relaxed` atomic adds. Histograms share
//! the bucket math in [`super::histogram`], so a shard-local histogram
//! and the registry-wide one agree bucket for bucket and merge by
//! addition.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::histogram::{bucket_index, bucket_low, bucket_width, BUCKETS};

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value.
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Lock-free log₂ streaming histogram: the multi-threaded sibling of
/// [`super::histogram::StreamingHistogram`]. Recording is three
/// `Relaxed` atomic RMWs plus two min/max updates — O(1), bounded
/// memory, safe to hammer from every shard thread at once. Queries
/// take a relaxed snapshot; they are meant for end-of-run reporting,
/// not for reading concurrently-exact counts.
pub struct Histogram {
    buckets: Box<[AtomicU64; BUCKETS]>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    min_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        let buckets: Vec<AtomicU64> = (0..BUCKETS).map(|_| AtomicU64::new(0)).collect();
        let buckets: Box<[AtomicU64; BUCKETS]> = buckets
            .into_boxed_slice()
            .try_into()
            .unwrap_or_else(|_| unreachable!("BUCKETS-sized vec"));
        Self {
            buckets,
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            min_ns: AtomicU64::new(u64::MAX),
            max_ns: AtomicU64::new(0),
        }
    }

    pub fn record_ns(&self, ns: u64) {
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.min_ns.fetch_min(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Record a microsecond duration (the unit used across the serving
    /// stack); negative/NaN inputs clamp to zero.
    pub fn record_us(&self, us: f64) {
        let ns = (us * 1_000.0).round();
        let ns = if ns.is_finite() && ns > 0.0 {
            ns as u64
        } else {
            0
        };
        self.record_ns(ns);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / n as f64 / 1_000.0
        }
    }

    pub fn min_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.min_ns.load(Ordering::Relaxed) as f64 / 1_000.0
        }
    }

    pub fn max_us(&self) -> f64 {
        self.max_ns.load(Ordering::Relaxed) as f64 / 1_000.0
    }

    /// Nearest-rank percentile in microseconds over a relaxed bucket
    /// snapshot, clamped to the tracked [min, max].
    pub fn percentile_us(&self, p: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0).clamp(0.0, 1.0) * (n as f64 - 1.0)).round() as u64;
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum > rank {
                let mid = bucket_low(i) + (bucket_width(i) - 1) / 2;
                let us = mid as f64 / 1_000.0;
                return us.clamp(self.min_us(), self.max_us());
            }
        }
        self.max_us()
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count())
            .field("min_us", &self.min_us())
            .field("max_us", &self.max_us())
            .finish()
    }
}

/// A named metric held by the registry.
#[derive(Debug, Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// Name → metric table. The mutex guards registration only; recorded
/// values live behind the `Arc` handles it gives out, so steady-state
/// recording never contends on it.
#[derive(Debug, Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = self.metrics.lock().unwrap();
        let entry = map
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::default())));
        match entry {
            Metric::Counter(c) => Arc::clone(c),
            // Name/type collision: hand back a detached metric rather
            // than panic a serving thread.
            _ => Arc::new(Counter::default()),
        }
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = self.metrics.lock().unwrap();
        let entry = map
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::default())));
        match entry {
            Metric::Gauge(g) => Arc::clone(g),
            _ => Arc::new(Gauge::default()),
        }
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map = self.metrics.lock().unwrap();
        let entry = map
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::new())));
        match entry {
            Metric::Histogram(h) => Arc::clone(h),
            _ => Arc::new(Histogram::new()),
        }
    }

    /// Prometheus text exposition: counters and gauges as plain
    /// samples, histograms as quantile summaries (`{quantile="0.5"}`,
    /// `{quantile="0.99"}`, `_sum`, `_count`), sorted by name.
    pub fn render_prometheus(&self) -> String {
        let map = self.metrics.lock().unwrap();
        let mut out = String::new();
        for (name, metric) in map.iter() {
            match metric {
                Metric::Counter(c) => {
                    out.push_str(&format!("# TYPE {name} counter\n{name} {}\n", c.get()));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!("# TYPE {name} gauge\n{name} {}\n", g.get()));
                }
                Metric::Histogram(h) => {
                    out.push_str(&format!(
                        "# TYPE {name} summary\n\
                         {name}{{quantile=\"0.5\"}} {:.3}\n\
                         {name}{{quantile=\"0.99\"}} {:.3}\n\
                         {name}_sum {:.3}\n\
                         {name}_count {}\n",
                        h.percentile_us(50.0),
                        h.percentile_us(99.0),
                        h.mean_us() * h.count() as f64,
                        h.count(),
                    ));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_returns_shared_handles() {
        let reg = Registry::new();
        let a = reg.counter("grip_requests_total");
        let b = reg.counter("grip_requests_total");
        a.add(3);
        b.inc();
        assert_eq!(a.get(), 4);
        let g = reg.gauge("grip_trace_sample_every");
        g.set(64);
        assert_eq!(reg.gauge("grip_trace_sample_every").get(), 64);
    }

    #[test]
    fn atomic_histogram_matches_streaming_math() {
        use crate::telemetry::histogram::StreamingHistogram;
        let h = Histogram::new();
        let mut s = StreamingHistogram::new();
        for i in 1..=500 {
            let v = (i * 131 % 9000) as f64 + 0.25;
            h.record_us(v);
            s.record(v);
        }
        assert_eq!(h.count(), s.count());
        for p in [50.0, 90.0, 99.0] {
            let rel = (h.percentile_us(p) - s.percentile(p)).abs() / s.percentile(p);
            assert!(rel <= 0.05, "p{p}: atomic vs streaming off by {rel}");
        }
    }

    #[test]
    fn prometheus_render_has_all_sample_kinds() {
        let reg = Registry::new();
        reg.counter("grip_requests_total").add(7);
        reg.gauge("grip_shards").set(4);
        reg.histogram("grip_stage_e2e_us").record_us(123.0);
        let text = reg.render_prometheus();
        assert!(text.contains("# TYPE grip_requests_total counter"));
        assert!(text.contains("grip_requests_total 7"));
        assert!(text.contains("grip_shards 4"));
        assert!(text.contains("grip_stage_e2e_us{quantile=\"0.99\"}"));
        assert!(text.contains("grip_stage_e2e_us_count 1"));
    }
}
