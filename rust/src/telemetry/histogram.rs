//! Fixed-bucket log₂ streaming histograms — the bounded-memory core
//! under every latency metric in the serving stack.
//!
//! Layout (HDR-histogram style): values are recorded in integer
//! nanoseconds. The first 32 buckets hold 0..32 ns exactly; every
//! octave above that is split into 32 linear sub-buckets, so a bucket's
//! width is always `2^(msb-5)` and the worst-case quantile error is
//! half a bucket ≈ 1/64 ≈ 1.6% of the value — comfortably inside the
//! 5% envelope `coordinator::metrics` pins by test. 1920 buckets cover
//! the full `u64` range, so recording is O(1), memory is bounded, and
//! two histograms merge by adding counts — the three properties the
//! old clone-and-sort sample vector lacked.

/// Linear sub-buckets per octave (2^5 = 32).
const SUB_BITS: u32 = 5;
const SUB: usize = 1 << SUB_BITS;

/// Total bucket count: 32 exact low buckets + 59 octaves × 32.
pub const BUCKETS: usize = SUB + (64 - SUB_BITS as usize - 1) * SUB;

/// Bucket index of a nanosecond value. Contiguous and monotone:
/// `index == v` for `v < 64`, and the top bucket is `BUCKETS - 1`.
pub fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros();
    let sub = ((v >> (msb - SUB_BITS)) as usize) & (SUB - 1);
    SUB + (msb - SUB_BITS) as usize * SUB + sub
}

/// Inclusive lower bound of a bucket (the inverse of [`bucket_index`]).
pub fn bucket_low(index: usize) -> u64 {
    if index < SUB {
        return index as u64;
    }
    let oct = ((index - SUB) / SUB) as u32;
    let sub = ((index - SUB) % SUB) as u64;
    let msb = oct + SUB_BITS;
    (1u64 << msb) + (sub << (msb - SUB_BITS))
}

/// Width of a bucket in nanoseconds (1 for the exact low buckets).
pub fn bucket_width(index: usize) -> u64 {
    if index < SUB {
        1
    } else {
        let msb = ((index - SUB) / SUB) as u32 + SUB_BITS;
        1u64 << (msb - SUB_BITS)
    }
}

/// A bucket's representative value: its midpoint (its low bound for
/// width-1 buckets, so sub-64 ns values round-trip exactly).
fn representative(index: usize) -> u64 {
    bucket_low(index) + (bucket_width(index) - 1) / 2
}

/// Microseconds → clamped integer nanoseconds (the recording unit).
fn us_to_ns(us: f64) -> u64 {
    let ns = (us * 1_000.0).round();
    if ns.is_finite() && ns > 0.0 {
        ns as u64
    } else {
        0
    }
}

/// Single-threaded streaming histogram (microsecond API over the
/// nanosecond buckets). Backs [`crate::coordinator::LatencyStats`];
/// the lock-free serving-pipeline variant is
/// [`crate::telemetry::Histogram`], built on the same bucket math.
#[derive(Clone, Default)]
pub struct StreamingHistogram {
    /// Lazily allocated on first record so an empty recorder costs
    /// nothing (reports hold many).
    buckets: Vec<u64>,
    count: u64,
    sum_us: f64,
    min_us: f64,
    max_us: f64,
}

impl StreamingHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, us: f64) {
        if self.buckets.is_empty() {
            self.buckets = vec![0; BUCKETS];
        }
        self.buckets[bucket_index(us_to_ns(us))] += 1;
        if self.count == 0 {
            self.min_us = us;
            self.max_us = us;
        } else {
            self.min_us = self.min_us.min(us);
            self.max_us = self.max_us.max(us);
        }
        self.count += 1;
        self.sum_us += us;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            f64::INFINITY
        } else {
            self.min_us
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max_us
        }
    }

    /// Nearest-rank percentile (p in [0, 100]) over the bucket
    /// representatives, clamped to the exactly-tracked [min, max] — so
    /// a single-sample histogram reports that sample exactly, and the
    /// worst-case error is half a bucket (≈ 1.6%).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0).clamp(0.0, 1.0) * (self.count as f64 - 1.0)).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum > rank {
                let us = representative(i) as f64 / 1_000.0;
                return us.clamp(self.min_us, self.max_us);
            }
        }
        self.max_us
    }

    /// Add another histogram's population into this one (cross-shard
    /// aggregation).
    pub fn merge(&mut self, other: &StreamingHistogram) {
        if other.count == 0 {
            return;
        }
        if self.buckets.is_empty() {
            self.buckets = vec![0; BUCKETS];
        }
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        if self.count == 0 {
            self.min_us = other.min_us;
            self.max_us = other.max_us;
        } else {
            self.min_us = self.min_us.min(other.min_us);
            self.max_us = self.max_us.max(other.max_us);
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
    }
}

impl std::fmt::Debug for StreamingHistogram {
    /// The bucket vector is 1920 entries — summarize instead of
    /// spewing it into every report debug dump.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StreamingHistogram")
            .field("count", &self.count)
            .field("min_us", &self.min())
            .field("max_us", &self.max())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_contiguous_and_monotone() {
        // Exact region: identity.
        for v in 0..64u64 {
            assert_eq!(bucket_index(v), v as usize, "v={v}");
        }
        // Monotone non-decreasing, never skipping a bucket, across the
        // first few octaves.
        let mut prev = 0usize;
        for v in 0..100_000u64 {
            let i = bucket_index(v);
            assert!(i == prev || i == prev + 1, "v={v}: {prev} -> {i}");
            prev = i;
        }
        // Top of the range still lands inside the table.
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        assert_eq!(BUCKETS, 1920);
    }

    #[test]
    fn bucket_low_inverts_bucket_index() {
        for i in 0..BUCKETS {
            let low = bucket_low(i);
            assert_eq!(bucket_index(low), i, "bucket {i}");
            // The last value of the bucket still maps to it.
            let hi = low + bucket_width(i) - 1;
            assert_eq!(bucket_index(hi), i, "bucket {i} high end");
        }
    }

    #[test]
    fn relative_error_is_bounded() {
        // Midpoint representative: error ≤ half a bucket width, i.e.
        // ≤ 1/64 of the value above the exact region.
        for v in [100u64, 999, 12_345, 1_000_000, 987_654_321, u64::MAX / 3] {
            let rep = representative(bucket_index(v));
            let err = (rep as f64 - v as f64).abs() / v as f64;
            assert!(err <= 1.0 / 64.0 + 1e-12, "v={v} rep={rep} err={err}");
        }
    }

    #[test]
    fn percentiles_track_a_uniform_population() {
        let mut h = StreamingHistogram::new();
        for i in 1..=1000 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 500.5).abs() < 1e-9, "sum is tracked exactly");
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 1000.0);
        assert!(h.percentile(50.0) <= h.percentile(99.0));
        assert!((h.percentile(50.0) - 500.0).abs() / 500.0 <= 0.02);
        assert!((h.percentile(99.0) - 990.0).abs() / 990.0 <= 0.02);
    }

    #[test]
    fn single_sample_is_exact_and_empty_is_zero() {
        let empty = StreamingHistogram::new();
        assert_eq!(empty.percentile(99.0), 0.0);
        assert_eq!(empty.mean(), 0.0);
        let mut h = StreamingHistogram::new();
        h.record(7.5);
        // Clamping to [min, max] makes the one-sample case exact.
        assert_eq!(h.percentile(50.0), 7.5);
        assert_eq!(h.percentile(99.0), 7.5);
    }

    #[test]
    fn merge_is_sum_of_populations() {
        let mut a = StreamingHistogram::new();
        let mut b = StreamingHistogram::new();
        let mut whole = StreamingHistogram::new();
        for i in 1..=400 {
            let v = (i * 37 % 5000) as f64 + 0.5;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            whole.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
        assert_eq!(a.percentile(99.0), whole.percentile(99.0));
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
    }
}
