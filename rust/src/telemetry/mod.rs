//! Serving-wide observability: streaming histograms, a metric
//! registry, and sampled per-request lifecycle traces.
//!
//! The design splits telemetry into two tiers with different costs:
//!
//! * **Stage histograms** (always on): every pipeline stage records
//!   its duration into a lock-free log₂ [`Histogram`] — three relaxed
//!   atomic adds per record, bounded memory, mergeable. These feed the
//!   per-stage p50/p99 breakdowns in `ServeStats`, the Prometheus
//!   snapshot, and `BENCH_serve.json`.
//! * **Span traces** (sampled, default 1-in-64): a sampled request
//!   carries a [`SpanTrace`] that timestamps each [`Stage`] it passes.
//!   Collected spans export as Chrome `trace_event` JSON
//!   ([`chrome_trace_json`]) loadable in Perfetto.
//!
//! Neither tier touches request numerics: telemetry observes
//! timestamps on the side, so replies are bit-identical with tracing
//! on, off, or at any sample rate (pinned by
//! `tests/telemetry_props.rs`).

pub mod histogram;
pub mod registry;
pub mod span;

pub use histogram::StreamingHistogram;
pub use registry::{Counter, Gauge, Histogram, Registry};
pub use span::{chrome_trace_json, SpanTrace, Stage, STAGES};

use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Cap on retained sampled spans; beyond it spans are counted as
/// dropped instead of growing memory without bound.
const SPAN_CAP: usize = 65_536;

/// Pre-resolved histogram handles for every pipeline stage — the hot
/// path records through these `Arc`s and never touches the registry
/// mutex.
#[derive(Debug, Clone)]
pub struct StageHistograms {
    /// Submit → builder dequeue, per request.
    pub queue_wait: Arc<Histogram>,
    /// Job build (CSR gather of the batch), per job.
    pub build: Arc<Histogram>,
    /// Built job → shard/lane pickup, per job.
    pub shard_wait: Arc<Histogram>,
    /// Feature staging minus boundary wait, per job.
    pub prefetch_local: Arc<Histogram>,
    /// Wait on remote boundary rows, per job (0 when unpartitioned).
    pub boundary_wait: Arc<Histogram>,
    /// Staged job → engine pickup, per job (pipelined mode).
    pub ready_wait: Arc<Histogram>,
    /// Backend execute, per job.
    pub compute: Arc<Histogram>,
    /// Reply fan-out, per job.
    pub reply: Arc<Histogram>,
    /// End-to-end host latency, per request.
    pub e2e: Arc<Histogram>,
}

impl StageHistograms {
    fn new(registry: &Registry) -> Self {
        Self {
            queue_wait: registry.histogram("grip_stage_queue_wait_us"),
            build: registry.histogram("grip_stage_build_us"),
            shard_wait: registry.histogram("grip_stage_shard_wait_us"),
            prefetch_local: registry.histogram("grip_stage_prefetch_local_us"),
            boundary_wait: registry.histogram("grip_stage_boundary_wait_us"),
            ready_wait: registry.histogram("grip_stage_ready_wait_us"),
            compute: registry.histogram("grip_stage_compute_us"),
            reply: registry.histogram("grip_stage_reply_us"),
            e2e: registry.histogram("grip_stage_e2e_us"),
        }
    }
}

struct Inner {
    origin: Instant,
    /// Sample 1-in-N requests for span tracing; 0 disables spans
    /// entirely. Stage histograms record regardless.
    sample_every: u64,
    registry: Registry,
    stages: StageHistograms,
    batch_size: Arc<Histogram>,
    requests: Arc<Counter>,
    spans_sampled: Arc<Counter>,
    spans_dropped: Arc<Counter>,
    spans: Mutex<Vec<SpanTrace>>,
}

/// Shared telemetry handle, cloned into every pipeline thread.
/// Cheap to clone (one `Arc`); a default handle has span sampling off
/// but still collects stage histograms.
#[derive(Clone)]
pub struct Telemetry(Arc<Inner>);

impl Default for Telemetry {
    fn default() -> Self {
        Self::disabled()
    }
}

impl Telemetry {
    /// `sample_every` = N samples 1-in-N requests for span tracing;
    /// 0 turns span tracing off.
    pub fn new(sample_every: u64) -> Self {
        let registry = Registry::new();
        let stages = StageHistograms::new(&registry);
        let batch_size = registry.histogram("grip_batch_size");
        let requests = registry.counter("grip_requests_total");
        let spans_sampled = registry.counter("grip_spans_sampled_total");
        let spans_dropped = registry.counter("grip_spans_dropped_total");
        registry.gauge("grip_trace_sample_every").set(sample_every);
        Self(Arc::new(Inner {
            origin: Instant::now(),
            sample_every,
            registry,
            stages,
            batch_size,
            requests,
            spans_sampled,
            spans_dropped,
            spans: Mutex::new(Vec::new()),
        }))
    }

    /// Span tracing off, histograms on — the default for embedded use.
    pub fn disabled() -> Self {
        Self::new(0)
    }

    /// Microseconds since this handle was created (the span timebase).
    pub fn now_us(&self) -> f64 {
        self.0.origin.elapsed().as_secs_f64() * 1e6
    }

    pub fn sample_every(&self) -> u64 {
        self.0.sample_every
    }

    /// Count a request arrival and decide whether to trace it. Returns
    /// a span (with `Arrival` stamped) for sampled requests.
    pub fn start_span(&self, request_id: u64) -> Option<Box<SpanTrace>> {
        self.0.requests.inc();
        if self.0.sample_every == 0 || request_id % self.0.sample_every != 0 {
            return None;
        }
        self.0.spans_sampled.inc();
        let mut span = Box::new(SpanTrace::new(request_id));
        span.stamp(Stage::Arrival, self.now_us());
        Some(span)
    }

    /// Deposit a completed span into the sink (bounded by `SPAN_CAP`).
    pub fn push_span(&self, span: Box<SpanTrace>) {
        let mut spans = self.0.spans.lock().unwrap();
        if spans.len() >= SPAN_CAP {
            self.0.spans_dropped.inc();
            return;
        }
        spans.push(*span);
    }

    /// Drain all collected spans (end-of-run export).
    pub fn take_spans(&self) -> Vec<SpanTrace> {
        std::mem::take(&mut *self.0.spans.lock().unwrap())
    }

    pub fn stages(&self) -> &StageHistograms {
        &self.0.stages
    }

    /// Batch-size distribution at dispatch.
    pub fn batch_size(&self) -> &Arc<Histogram> {
        &self.0.batch_size
    }

    pub fn registry(&self) -> &Registry {
        &self.0.registry
    }

    /// Prometheus text snapshot of the registry (counters, gauges,
    /// stage histograms). `ServeStats::render_prometheus` appends the
    /// pool-level counters on top of this.
    pub fn render_prometheus(&self) -> String {
        self.0.registry.render_prometheus()
    }
}

impl std::fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Telemetry")
            .field("sample_every", &self.0.sample_every)
            .field("requests", &self.0.requests.get())
            .field("spans_sampled", &self.0.spans_sampled.get())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_rate_is_respected() {
        let t = Telemetry::new(4);
        let mut sampled = 0;
        for id in 0..64 {
            if let Some(span) = t.start_span(id) {
                sampled += 1;
                t.push_span(span);
            }
        }
        assert_eq!(sampled, 16);
        assert_eq!(t.take_spans().len(), 16);
        assert_eq!(t.registry().counter("grip_requests_total").get(), 64);
        assert_eq!(t.registry().counter("grip_spans_sampled_total").get(), 16);
    }

    #[test]
    fn disabled_records_histograms_but_no_spans() {
        let t = Telemetry::disabled();
        assert!(t.start_span(0).is_none());
        t.stages().compute.record_us(42.0);
        assert_eq!(t.stages().compute.count(), 1);
        assert!(t.take_spans().is_empty());
        let prom = t.render_prometheus();
        assert!(prom.contains("grip_stage_compute_us_count 1"));
        assert!(prom.contains("grip_trace_sample_every 0"));
    }

    #[test]
    fn now_us_is_monotone() {
        let t = Telemetry::disabled();
        let a = t.now_us();
        let b = t.now_us();
        assert!(b >= a);
    }
}
