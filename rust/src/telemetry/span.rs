//! Per-request lifecycle spans and the Chrome `trace_event` exporter.
//!
//! A [`SpanTrace`] rides a sampled request through the whole serving
//! pipeline, collecting one timestamp per [`Stage`]. Stamping is a
//! plain array store — no allocation, no locking — and untraced
//! requests carry `None`, so the unsampled path pays a branch.
//!
//! [`chrome_trace_json`] renders the collected spans as a Chrome
//! `trace_event` JSON document (loadable in Perfetto / `about:tracing`)
//! with one process per sweep point and one timeline lane per pipeline
//! unit: batcher, builders, each shard's prefetch lanes, each shard's
//! vertex engine.

/// Pipeline stages in the order a request traverses them. The
/// monotonicity property test (`tests/telemetry_props.rs`) pins that
/// stamps appear in exactly this order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Request accepted by the submitter.
    Arrival,
    /// Admitted into the batcher's open batch (== Arrival when the
    /// batcher is disabled).
    Admit,
    /// Batch dispatched toward the job builder.
    Dispatch,
    /// Job builder dequeued the submission.
    BuildStart,
    /// Built `ExecJob` enqueued toward its shard (router enqueue).
    RouteEnqueue,
    /// Shard (or prefetch lane) dequeued the job.
    ShardDequeue,
    /// Feature staging / gather began.
    PrefetchStart,
    /// Feature staging complete (includes any boundary-fetch wait).
    PrefetchEnd,
    /// Vertex engine began executing the job.
    EngineStart,
    /// Vertex engine finished.
    EngineEnd,
    /// Reply delivered to the requester's channel.
    Reply,
}

/// Every stage, in pipeline order.
pub const STAGES: [Stage; 11] = [
    Stage::Arrival,
    Stage::Admit,
    Stage::Dispatch,
    Stage::BuildStart,
    Stage::RouteEnqueue,
    Stage::ShardDequeue,
    Stage::PrefetchStart,
    Stage::PrefetchEnd,
    Stage::EngineStart,
    Stage::EngineEnd,
    Stage::Reply,
];

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Arrival => "arrival",
            Stage::Admit => "admit",
            Stage::Dispatch => "dispatch",
            Stage::BuildStart => "build_start",
            Stage::RouteEnqueue => "route_enqueue",
            Stage::ShardDequeue => "shard_dequeue",
            Stage::PrefetchStart => "prefetch_start",
            Stage::PrefetchEnd => "prefetch_end",
            Stage::EngineStart => "engine_start",
            Stage::EngineEnd => "engine_end",
            Stage::Reply => "reply",
        }
    }
}

/// One sampled request's journey: a timestamp (µs since the telemetry
/// origin) per stage, plus where it executed. Unset stages are NaN.
#[derive(Debug, Clone)]
pub struct SpanTrace {
    pub request_id: u64,
    stamps: [f64; STAGES.len()],
    /// Shard that executed the request (set at dequeue).
    pub shard: Option<usize>,
    /// Prefetch lane within the shard (pipelined mode only).
    pub lane: Option<usize>,
    /// Portion of the prefetch window spent waiting on remote
    /// boundary rows (partitioned mode; 0 otherwise).
    pub boundary_wait_us: f64,
}

impl SpanTrace {
    pub fn new(request_id: u64) -> Self {
        Self {
            request_id,
            stamps: [f64::NAN; STAGES.len()],
            shard: None,
            lane: None,
            boundary_wait_us: 0.0,
        }
    }

    pub fn stamp(&mut self, stage: Stage, t_us: f64) {
        self.stamps[stage as usize] = t_us;
    }

    /// Timestamp of a stage, if it was stamped.
    pub fn get(&self, stage: Stage) -> Option<f64> {
        let v = self.stamps[stage as usize];
        if v.is_nan() {
            None
        } else {
            Some(v)
        }
    }
}

/// Timeline lane (Chrome `tid`) assignment: fixed lanes for the
/// pre-shard pipeline, a block of 10 per shard beyond that.
const TID_BATCH: u64 = 1;
const TID_BUILD: u64 = 2;
const SHARD_TID_BASE: u64 = 100;
const SHARD_TID_STRIDE: u64 = 10;
/// Engine lane offset within a shard's tid block (lanes 0..9 are
/// prefetch lanes).
const ENGINE_TID_OFFSET: u64 = 9;

fn push_event(
    out: &mut String,
    name: &str,
    pid: usize,
    tid: u64,
    ts: f64,
    dur: f64,
    span: &SpanTrace,
) {
    out.push_str(&format!(
        "{{\"name\":\"{name}\",\"ph\":\"X\",\"pid\":{pid},\"tid\":{tid},\
         \"ts\":{ts:.3},\"dur\":{dur:.3},\"args\":{{\"request_id\":{},\
         \"shard\":{},\"lane\":{},\"boundary_wait_us\":{:.3}}}}},\n",
        span.request_id,
        span.shard.map(|s| s as i64).unwrap_or(-1),
        span.lane.map(|l| l as i64).unwrap_or(-1),
        span.boundary_wait_us,
    ));
}

fn push_meta(out: &mut String, kind: &str, pid: usize, tid: Option<u64>, label: &str) {
    let tid_field = tid.map(|t| format!(",\"tid\":{t}")).unwrap_or_default();
    out.push_str(&format!(
        "{{\"name\":\"{kind}\",\"ph\":\"M\",\"pid\":{pid}{tid_field},\
         \"args\":{{\"name\":\"{label}\"}}}},\n"
    ));
}

/// Render span groups as a Chrome `trace_event` JSON document. Each
/// group becomes one process (pid) labeled with the group's name —
/// `serve-bench` passes one group per sweep point.
pub fn chrome_trace_json(groups: &[(String, Vec<SpanTrace>)]) -> String {
    let mut out = String::from("{\"traceEvents\":[\n");
    for (pid, (label, spans)) in groups.iter().enumerate() {
        push_meta(&mut out, "process_name", pid, None, label);
        push_meta(&mut out, "thread_name", pid, Some(TID_BATCH), "batcher");
        push_meta(&mut out, "thread_name", pid, Some(TID_BUILD), "job-builder");
        let mut named_shards = std::collections::BTreeSet::new();
        for span in spans {
            if let Some(shard) = span.shard {
                let base = SHARD_TID_BASE + shard as u64 * SHARD_TID_STRIDE;
                if named_shards.insert(shard) {
                    for lane in 0..ENGINE_TID_OFFSET {
                        push_meta(
                            &mut out,
                            "thread_name",
                            pid,
                            Some(base + lane),
                            &format!("shard{shard}/prefetch-lane{lane}"),
                        );
                    }
                    push_meta(
                        &mut out,
                        "thread_name",
                        pid,
                        Some(base + ENGINE_TID_OFFSET),
                        &format!("shard{shard}/vertex-engine"),
                    );
                }
            }
            emit_span(&mut out, pid, span);
        }
    }
    // Drop the trailing ",\n" (valid even for an empty event list).
    if out.ends_with(",\n") {
        out.truncate(out.len() - 2);
        out.push('\n');
    }
    out.push_str("],\"displayTimeUnit\":\"ms\"}\n");
    out
}

fn emit_span(out: &mut String, pid: usize, span: &SpanTrace) {
    let slice = |a: Stage, b: Stage| -> Option<(f64, f64)> {
        let start = span.get(a)?;
        let end = span.get(b)?;
        Some((start, (end - start).max(0.0)))
    };
    if let Some((ts, dur)) = slice(Stage::Arrival, Stage::Dispatch) {
        push_event(out, "batch", pid, TID_BATCH, ts, dur, span);
    }
    if let Some((ts, dur)) = slice(Stage::BuildStart, Stage::RouteEnqueue) {
        push_event(out, "build", pid, TID_BUILD, ts, dur, span);
    }
    if let Some(shard) = span.shard {
        let base = SHARD_TID_BASE + shard as u64 * SHARD_TID_STRIDE;
        let lane_tid = base + span.lane.map(|l| l as u64 % ENGINE_TID_OFFSET).unwrap_or(0);
        if let Some((ts, dur)) = slice(Stage::PrefetchStart, Stage::PrefetchEnd) {
            push_event(out, "prefetch", pid, lane_tid, ts, dur, span);
            if span.boundary_wait_us > 0.0 {
                // Nested slice: the remote-row wait inside the gather.
                push_event(
                    out,
                    "boundary-wait",
                    pid,
                    lane_tid,
                    ts,
                    span.boundary_wait_us.min(dur),
                    span,
                );
            }
        }
        if let Some((ts, dur)) = slice(Stage::EngineStart, Stage::EngineEnd) {
            push_event(out, "execute", pid, base + ENGINE_TID_OFFSET, ts, dur, span);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full_span(id: u64) -> SpanTrace {
        let mut s = SpanTrace::new(id);
        for (i, st) in STAGES.iter().enumerate() {
            s.stamp(*st, 10.0 * (i as f64 + 1.0));
        }
        s.shard = Some(1);
        s.lane = Some(0);
        s.boundary_wait_us = 4.0;
        s
    }

    #[test]
    fn stamps_round_trip_in_order() {
        let s = full_span(3);
        let mut prev = f64::NEG_INFINITY;
        for st in STAGES {
            let t = s.get(st).expect("stamped");
            assert!(t >= prev, "{} out of order", st.name());
            prev = t;
        }
        assert_eq!(SpanTrace::new(9).get(Stage::Reply), None);
    }

    #[test]
    fn chrome_trace_parses_and_names_lanes() {
        let groups = vec![("poisson_r50_s4".to_string(), vec![full_span(0), full_span(64)])];
        let text = chrome_trace_json(&groups);
        let doc = crate::runtime::json::parse(&text).expect("valid JSON");
        let events = doc
            .get("traceEvents")
            .and_then(|v| v.as_arr())
            .expect("traceEvents array");
        // 2 spans × (batch, build, prefetch, boundary-wait, execute)
        // plus metadata records.
        let slices: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .collect();
        assert_eq!(slices.len(), 10);
        assert!(text.contains("shard1/vertex-engine"));
        assert!(text.contains("shard1/prefetch-lane0"));
        assert!(text.contains("poisson_r50_s4"));
    }

    #[test]
    fn empty_trace_is_still_valid_json() {
        let text = chrome_trace_json(&[]);
        assert!(crate::runtime::json::parse(&text).is_ok());
    }
}
