//! Cycle-level simulator of the GRIP microarchitecture (paper Sec. V/VI).
//!
//! This is the paper's own evaluation vehicle: the authors report all
//! performance numbers from a cycle-accurate simulator of their RTL, and
//! derive every comparison (CPU baseline, HyGCN, TPU+, Graphicionado) by
//! *reconfiguring that simulator* (Sec. VIII-B, VIII-F). We reproduce
//! that methodology: [`simulate`] models each hardware unit's occupancy
//! at cycle granularity and composes them with the pipeline/double-
//! buffering semantics of the control unit, and every baseline is a
//! [`crate::config::GripConfig`] perturbation.
//!
//! Units modeled (Fig. 5/6):
//! * memory controller + DDR4 channels — [`dram`]
//! * edge unit: prefetch lanes → N×M crossbar → reduce lanes — [`phases`]
//! * vertex unit: 16×32 broadcast/reduction-tree PE array, tile buffer,
//!   weight sequencer, vertex-tiling — [`phases`]
//! * update unit: ReLU / two-level LUT pipeline — [`phases`]
//! * control: command issue, barriers, partition pipelining — [`machine`]
//!
//! Activity counters for the energy model (Table IV) are collected in
//! [`counters`].

mod counters;
mod dram;
mod machine;
mod phases;

pub use counters::ActivityCounters;
pub use dram::DramModel;
pub use machine::{simulate, LayerTiming, SimResult};
pub use phases::{edge_accumulate_cycles, update_cycles, vertex_accumulate_cycles, VertexCost};
