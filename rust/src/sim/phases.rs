//! Per-unit cost models (paper Sec. V-B/C/D).
//!
//! Each function returns the busy cycles of one unit for one piece of
//! work plus the activity counters it generates; `machine.rs` composes
//! them with the control unit's pipelining semantics.

use super::counters::ActivityCounters;
use crate::config::GripConfig;

/// Edge unit: prefetch lanes feed an N×M crossbar feeding reduce lanes
/// (Fig. 6). Each edge moves `dim` elements; a gather unit accumulates
/// `xbar_width_elems` per cycle. Edges are spread across reduce lanes by
/// destination vertex, so parallelism is capped by the number of
/// *distinct destinations* as well as the lane count.
pub fn edge_accumulate_cycles(
    cfg: &GripConfig,
    edges: usize,
    dim: usize,
    active_outputs: usize,
    counters: &mut ActivityCounters,
) -> f64 {
    if edges == 0 || dim == 0 {
        return 0.0;
    }
    let lanes = cfg
        .reduce_lanes
        .min(active_outputs.max(1))
        .min(cfg.prefetch_lanes.max(1) * 4) // crossbar fan-out limit
        .max(1);
    let slices = dim.div_ceil(cfg.xbar_width_elems.max(1));
    let edges_per_lane = edges.div_ceil(lanes);
    // SRAM contention when the nodeflow buffer shares the weight SRAM
    // (the merged-SRAM baseline of Fig. 9a) halves effective bandwidth.
    let contention = if cfg.split_srams { 1.0 } else { 2.0 };

    counters.edge_alu_ops += (edges * dim) as u64;
    counters.nodeflow_sram_bytes += (edges * dim * cfg.elem_bytes) as u64 * 2; // read msg + r/m/w acc

    edges_per_lane as f64 * slices as f64 * contention
}

/// Vertex unit cost for one batch of `rows` output vertices through a
/// `in_dim → out_dim` transform (paper Sec. V-C + vertex-tiling VI-B).
#[derive(Debug, Clone, Copy)]
pub struct VertexCost {
    /// Busy cycles of the PE array (compute-bound component).
    pub cycles: f64,
    /// Cycles the weight sequencer needs to stream tiles from the global
    /// weight buffer; the tile buffer is double-buffered so the exposed
    /// time is max(compute, weights) per tile.
    pub weight_stream_cycles: f64,
}

pub fn vertex_accumulate_cycles(
    cfg: &GripConfig,
    rows: usize,
    in_dim: usize,
    out_dim: usize,
    counters: &mut ActivityCounters,
) -> VertexCost {
    if rows == 0 || in_dim == 0 || out_dim == 0 {
        return VertexCost { cycles: 0.0, weight_stream_cycles: 0.0 };
    }
    let (m_t, f_t) = cfg.effective_tile(in_dim);
    let o_t = cfg.pe_cols.max(1);

    let v_tiles = rows.div_ceil(m_t);
    let f_tiles = in_dim.div_ceil(f_t);
    let o_tiles = out_dim.div_ceil(o_t);

    // Compute: m vertices × ceil(f/16) PE-row passes per (f,o) tile; the
    // broadcast/reduction-tree array retires one (16 × 32) slab per
    // cycle, fully pipelined (6-cycle fill per column of tiles).
    let compute_per_tile = m_t as f64 * f_t.div_ceil(cfg.pe_rows.max(1)) as f64;
    // Weight streaming: each (f, o) tile is f_t × o_t values, fetched
    // once and reused across the m_t vertices of the tile (the 1/m
    // bandwidth saving of vertex-tiling).
    let weight_bytes_per_tile = (f_t * o_t * cfg.elem_bytes) as f64;
    let wbw = if cfg.split_srams {
        cfg.weight_bw_bytes_per_cycle
    } else {
        // Merged SRAM: weights contend with feature traffic (Fig. 9a:
        // splitting doubles available weight bandwidth).
        cfg.weight_bw_bytes_per_cycle / 2.0
    };
    let weights_per_tile = weight_bytes_per_tile / wbw.max(1e-9);

    let tiles = (v_tiles * f_tiles * o_tiles) as f64;
    let per_tile = compute_per_tile.max(weights_per_tile);
    let cycles = tiles * per_tile + cfg.pe_fill_cycles as f64 * o_tiles as f64;

    counters.macs += (rows * in_dim * out_dim) as u64;
    counters.weight_sram_bytes += (v_tiles * f_tiles * o_tiles) as u64
        * (f_t * o_t * cfg.elem_bytes) as u64;

    VertexCost { cycles, weight_stream_cycles: tiles * weights_per_tile }
}

/// Update unit: activate over `rows × dim` elements (paper Sec. V-D).
pub fn update_cycles(
    cfg: &GripConfig,
    rows: usize,
    dim: usize,
    counters: &mut ActivityCounters,
) -> f64 {
    let elems = rows * dim;
    counters.update_elems += elems as u64;
    elems as f64 / cfg.update_elems_per_cycle.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> GripConfig {
        GripConfig::paper()
    }

    #[test]
    fn edge_zero_is_free() {
        let mut c = ActivityCounters::default();
        assert_eq!(edge_accumulate_cycles(&cfg(), 0, 512, 4, &mut c), 0.0);
    }

    #[test]
    fn edge_scales_with_edges_and_dim() {
        let mut c = ActivityCounters::default();
        let t1 = edge_accumulate_cycles(&cfg(), 100, 128, 8, &mut c);
        let t2 = edge_accumulate_cycles(&cfg(), 200, 128, 8, &mut c);
        let t3 = edge_accumulate_cycles(&cfg(), 100, 256, 8, &mut c);
        assert!(t2 > 1.9 * t1);
        assert!(t3 > 1.9 * t1);
    }

    #[test]
    fn edge_single_output_serializes() {
        let mut c = ActivityCounters::default();
        let t1 = edge_accumulate_cycles(&cfg(), 64, 64, 1, &mut c);
        let t8 = edge_accumulate_cycles(&cfg(), 64, 64, 8, &mut c);
        assert!(t1 > 7.0 * t8, "{t1} vs {t8}");
    }

    #[test]
    fn wider_crossbar_fewer_cycles() {
        let mut cfg2 = cfg();
        cfg2.xbar_width_elems = 64;
        let mut c = ActivityCounters::default();
        let narrow = edge_accumulate_cycles(&cfg(), 100, 256, 8, &mut c);
        let wide = edge_accumulate_cycles(&cfg2, 100, 256, 8, &mut c);
        assert!(wide < narrow / 3.0);
    }

    #[test]
    fn vertex_tiling_removes_weight_bottleneck() {
        // Paper Sec. VI-B: with tiling the PE array is compute-bound;
        // without it, weight streaming dominates.
        let c_on = cfg();
        let mut c_off = cfg();
        c_off.vertex_tiling = false;
        let mut a = ActivityCounters::default();
        let mut b = ActivityCounters::default();
        let on = vertex_accumulate_cycles(&c_on, 11, 602, 512, &mut a);
        let off = vertex_accumulate_cycles(&c_off, 11, 602, 512, &mut b);
        assert!(off.cycles > 2.0 * on.cycles, "on {} off {}", on.cycles, off.cycles);
        // Tiling reduces weight-SRAM traffic by ~m.
        assert!(b.weight_sram_bytes > 5 * a.weight_sram_bytes);
    }

    #[test]
    fn vertex_mac_count_exact() {
        let mut c = ActivityCounters::default();
        vertex_accumulate_cycles(&cfg(), 11, 602, 512, &mut c);
        assert_eq!(c.macs, 11 * 602 * 512);
    }

    #[test]
    fn vertex_compute_bound_at_paper_point() {
        // At (m=11, f=64) the PE array should not stall on weights.
        let mut c = ActivityCounters::default();
        let v = vertex_accumulate_cycles(&cfg(), 11, 602, 512, &mut c);
        assert!(v.weight_stream_cycles < v.cycles);
    }

    #[test]
    fn low_weight_bw_becomes_bottleneck() {
        // Fig. 10b: below ~128 GiB/s weight loading dominates.
        let mut slow = cfg();
        slow.weight_bw_bytes_per_cycle = 16.0;
        let mut c = ActivityCounters::default();
        let v_fast = vertex_accumulate_cycles(&cfg(), 11, 602, 512, &mut c);
        let v_slow = vertex_accumulate_cycles(&slow, 11, 602, 512, &mut c);
        assert!(v_slow.cycles > 1.5 * v_fast.cycles);
    }

    #[test]
    fn update_throughput() {
        let mut c = ActivityCounters::default();
        let t = update_cycles(&cfg(), 11, 512, &mut c);
        assert!((t - (11.0 * 512.0 / 32.0)).abs() < 1e-9);
        assert_eq!(c.update_elems, 11 * 512);
    }
}
