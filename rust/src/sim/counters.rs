//! Activity counters collected during simulation — the inputs to the
//! energy model (paper Sec. VII: "Power estimates of each unit was
//! performed by generating activity factors from a cycle accurate
//! simulation").

/// Event counts for one simulated inference.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ActivityCounters {
    /// Bytes moved over the DRAM interface (features + weights,
    /// including burst waste).
    pub dram_bytes: u64,
    /// Bytes read from the global weight buffer into the tile buffer.
    pub weight_sram_bytes: u64,
    /// Bytes read/written in the nodeflow (feature) SRAMs by the edge
    /// unit and DMA.
    pub nodeflow_sram_bytes: u64,
    /// Multiply-accumulate operations in the vertex unit PE array.
    pub macs: u64,
    /// ALU operations in the edge unit (gather + reduce element ops).
    pub edge_alu_ops: u64,
    /// Elements processed by the update unit (ReLU / LUT evaluations).
    pub update_elems: u64,
    /// Input-layer feature rows *touched* by partition columns (every
    /// reference, resident or not) — the on-chip mirror of the serving
    /// layer's feature-cache accesses.
    pub feature_rows_touched: u64,
    /// Input-layer feature rows actually streamed from DRAM (touched
    /// minus the rows `cache_features` kept resident) — the mirror of
    /// the serving feature cache's misses, so simulated and host-side
    /// hit rates are directly comparable (`BENCH_serve.json`).
    pub feature_rows_loaded: u64,
    /// Cycles the edge-centric phase (feature prefetch streams over the
    /// DRAM channels) kept the memory system busy — the on-chip
    /// analogue of the serving layer's prefetch lanes.
    pub prefetch_cycles: u64,
    /// Cycles the vertex-centric phase (edge-accumulate + PE-array
    /// matmul + update) kept the compute units busy — the analogue of
    /// the serving layer's vertex engine.
    pub compute_cycles: u64,
    /// Busy cycles *hidden* by running the two phases concurrently
    /// (serial phase sum minus the exposed span) — GRIP's inter-phase
    /// pipelining win, mirrored host-side by the shard pipeline's
    /// occupancy/stall counters so simulated and measured phase overlap
    /// sit side by side in `BENCH_serve.json`.
    pub overlap_cycles: u64,
}

impl ActivityCounters {
    pub fn add(&mut self, other: &ActivityCounters) {
        self.dram_bytes += other.dram_bytes;
        self.weight_sram_bytes += other.weight_sram_bytes;
        self.nodeflow_sram_bytes += other.nodeflow_sram_bytes;
        self.macs += other.macs;
        self.edge_alu_ops += other.edge_alu_ops;
        self.update_elems += other.update_elems;
        self.feature_rows_touched += other.feature_rows_touched;
        self.feature_rows_loaded += other.feature_rows_loaded;
        self.prefetch_cycles += other.prefetch_cycles;
        self.compute_cycles += other.compute_cycles;
        self.overlap_cycles += other.overlap_cycles;
    }

    /// Fraction of feature-row touches served from the on-chip
    /// nodeflow buffer instead of DRAM (0.0 when nothing was touched).
    /// With `cache_features` off this is exactly 0.
    pub fn feature_hit_rate(&self) -> f64 {
        if self.feature_rows_touched == 0 {
            return 0.0;
        }
        1.0 - self.feature_rows_loaded as f64 / self.feature_rows_touched as f64
    }

    /// Total arithmetic operations (1 MAC = 2 ops) — for roofline plots.
    pub fn total_ops(&self) -> u64 {
        2 * self.macs + self.edge_alu_ops + self.update_elems
    }

    /// Fraction of phase-busy cycles hidden by edge/vertex overlap
    /// (0.0 = fully serial phases, e.g. `pipeline_partitions` off).
    /// The simulated counterpart of the serving pipeline's
    /// prefetch-occupancy metric.
    pub fn phase_overlap_rate(&self) -> f64 {
        let busy = self.prefetch_cycles + self.compute_cycles;
        if busy == 0 {
            return 0.0;
        }
        self.overlap_cycles as f64 / busy as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_accumulates() {
        let mut a = ActivityCounters { dram_bytes: 10, macs: 5, ..Default::default() };
        let b = ActivityCounters { dram_bytes: 1, macs: 2, update_elems: 3, ..Default::default() };
        a.add(&b);
        assert_eq!(a.dram_bytes, 11);
        assert_eq!(a.macs, 7);
        assert_eq!(a.update_elems, 3);
        assert_eq!(a.total_ops(), 17);
    }

    #[test]
    fn phase_overlap_rate_bounds() {
        let none = ActivityCounters::default();
        assert_eq!(none.phase_overlap_rate(), 0.0, "no busy cycles, no overlap");
        let some = ActivityCounters {
            prefetch_cycles: 60,
            compute_cycles: 140,
            overlap_cycles: 50,
            ..Default::default()
        };
        assert!((some.phase_overlap_rate() - 0.25).abs() < 1e-12);
        let mut sum = some;
        sum.add(&some);
        assert_eq!(sum.prefetch_cycles, 120);
        assert_eq!(sum.overlap_cycles, 100);
        assert!((sum.phase_overlap_rate() - 0.25).abs() < 1e-12, "rate is scale-invariant");
    }
}
