//! Activity counters collected during simulation — the inputs to the
//! energy model (paper Sec. VII: "Power estimates of each unit was
//! performed by generating activity factors from a cycle accurate
//! simulation").

/// Event counts for one simulated inference.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ActivityCounters {
    /// Bytes moved over the DRAM interface (features + weights,
    /// including burst waste).
    pub dram_bytes: u64,
    /// Bytes read from the global weight buffer into the tile buffer.
    pub weight_sram_bytes: u64,
    /// Bytes read/written in the nodeflow (feature) SRAMs by the edge
    /// unit and DMA.
    pub nodeflow_sram_bytes: u64,
    /// Multiply-accumulate operations in the vertex unit PE array.
    pub macs: u64,
    /// ALU operations in the edge unit (gather + reduce element ops).
    pub edge_alu_ops: u64,
    /// Elements processed by the update unit (ReLU / LUT evaluations).
    pub update_elems: u64,
    /// Input-layer feature rows *touched* by partition columns (every
    /// reference, resident or not) — the on-chip mirror of the serving
    /// layer's feature-cache accesses.
    pub feature_rows_touched: u64,
    /// Input-layer feature rows actually streamed from DRAM (touched
    /// minus the rows `cache_features` kept resident) — the mirror of
    /// the serving feature cache's misses, so simulated and host-side
    /// hit rates are directly comparable (`BENCH_serve.json`).
    pub feature_rows_loaded: u64,
}

impl ActivityCounters {
    pub fn add(&mut self, other: &ActivityCounters) {
        self.dram_bytes += other.dram_bytes;
        self.weight_sram_bytes += other.weight_sram_bytes;
        self.nodeflow_sram_bytes += other.nodeflow_sram_bytes;
        self.macs += other.macs;
        self.edge_alu_ops += other.edge_alu_ops;
        self.update_elems += other.update_elems;
        self.feature_rows_touched += other.feature_rows_touched;
        self.feature_rows_loaded += other.feature_rows_loaded;
    }

    /// Fraction of feature-row touches served from the on-chip
    /// nodeflow buffer instead of DRAM (0.0 when nothing was touched).
    /// With `cache_features` off this is exactly 0.
    pub fn feature_hit_rate(&self) -> f64 {
        if self.feature_rows_touched == 0 {
            return 0.0;
        }
        1.0 - self.feature_rows_loaded as f64 / self.feature_rows_touched as f64
    }

    /// Total arithmetic operations (1 MAC = 2 ops) — for roofline plots.
    pub fn total_ops(&self) -> u64 {
        2 * self.macs + self.edge_alu_ops + self.update_elems
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_accumulates() {
        let mut a = ActivityCounters { dram_bytes: 10, macs: 5, ..Default::default() };
        let b = ActivityCounters { dram_bytes: 1, macs: 2, update_elems: 3, ..Default::default() };
        a.add(&b);
        assert_eq!(a.dram_bytes, 11);
        assert_eq!(a.macs, 7);
        assert_eq!(a.update_elems, 3);
        assert_eq!(a.total_ops(), 17);
    }
}
