//! DDR4 memory-controller timing model (paper Sec. V-A "Memory
//! Controller", Sec. VIII-C/D).
//!
//! The host statically schedules bulk transfers from the nodeflow, so
//! feature loads are channel-parallel streams of per-vertex rows. The
//! two efficiency effects the paper analyzes are modeled explicitly:
//!
//! * a feature row smaller than the DRAM interface wastes the remainder
//!   of the burst (Fig. 11a: below 64×2-byte elements "DRAM bandwidth is
//!   poorly utilized due to many random accesses");
//! * each non-contiguous row costs a row-activation penalty, amortized
//!   across channel parallelism for scheduled bulk transfers and paid
//!   serially for on-demand accesses (the unoptimized baseline of
//!   Fig. 13a).

use crate::config::GripConfig;

/// Timing model for the memory controller + channels.
#[derive(Debug, Clone)]
pub struct DramModel {
    channels: usize,
    bytes_per_cycle_per_ch: f64,
    interface_bytes: usize,
    random_penalty: f64,
}

impl DramModel {
    pub fn new(cfg: &GripConfig) -> Self {
        Self {
            channels: cfg.dram_channels.max(1),
            bytes_per_cycle_per_ch: cfg.dram_ch_bytes_per_cycle,
            interface_bytes: cfg.dram_interface_bytes.max(1),
            random_penalty: cfg.dram_random_penalty_cycles,
        }
    }

    /// Cycles to transfer `rows` feature rows of `row_bytes` each as a
    /// statically-scheduled bulk transfer (vertices pre-partitioned
    /// across channels, one prefetch lane per channel).
    ///
    /// Returns (cycles, bytes_transferred_incl_waste).
    pub fn bulk_rows(&self, rows: usize, row_bytes: usize) -> (f64, u64) {
        if rows == 0 || row_bytes == 0 {
            return (0.0, 0);
        }
        // Each row occupies whole bursts on its channel.
        let bursts_per_row = row_bytes.div_ceil(self.interface_bytes);
        let burst_bytes = bursts_per_row * self.interface_bytes;
        let rows_per_ch = rows.div_ceil(self.channels);
        // Bulk scheduling overlaps activation with streaming: the
        // penalty is paid once per channel queue, not per row.
        let cycles = rows_per_ch as f64 * burst_bytes as f64 / self.bytes_per_cycle_per_ch
            + self.random_penalty;
        (cycles, (rows * burst_bytes) as u64)
    }

    /// Cycles for *on-demand* row fetches (no static schedule): the
    /// activation penalty serializes per row on its channel.
    pub fn on_demand_rows(&self, rows: usize, row_bytes: usize) -> (f64, u64) {
        if rows == 0 || row_bytes == 0 {
            return (0.0, 0);
        }
        let bursts_per_row = row_bytes.div_ceil(self.interface_bytes);
        let burst_bytes = bursts_per_row * self.interface_bytes;
        let rows_per_ch = rows.div_ceil(self.channels);
        let per_row = burst_bytes as f64 / self.bytes_per_cycle_per_ch + self.random_penalty;
        (rows_per_ch as f64 * per_row, (rows * burst_bytes) as u64)
    }

    /// Cycles to stream `bytes` contiguously (weight loads): full
    /// bandwidth, one activation.
    pub fn stream(&self, bytes: usize) -> (f64, u64) {
        if bytes == 0 {
            return (0.0, 0);
        }
        let cycles = bytes as f64 / (self.bytes_per_cycle_per_ch * self.channels as f64)
            + self.random_penalty;
        (cycles, bytes as u64)
    }

    /// Peak bytes/cycle across all channels.
    pub fn peak_bytes_per_cycle(&self) -> f64 {
        self.bytes_per_cycle_per_ch * self.channels as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> DramModel {
        DramModel::new(&GripConfig::paper())
    }

    #[test]
    fn bulk_scales_with_rows() {
        let d = model();
        let (t1, _) = d.bulk_rows(100, 1204);
        let (t2, _) = d.bulk_rows(200, 1204);
        assert!(t2 > t1 * 1.7, "{t1} {t2}");
    }

    #[test]
    fn small_rows_waste_bandwidth() {
        let d = model();
        // 16-byte rows burn a full 128-byte burst each: 8× waste.
        let (t_small, b_small) = d.bulk_rows(1000, 16);
        let (t_big, b_big) = d.bulk_rows(1000, 128);
        assert_eq!(b_small, b_big);
        assert!((t_small - t_big).abs() < 1e-9);
    }

    #[test]
    fn on_demand_slower_than_bulk() {
        let d = model();
        let (bulk, _) = d.bulk_rows(500, 256);
        let (demand, _) = d.on_demand_rows(500, 256);
        assert!(demand > 2.0 * bulk, "bulk {bulk} vs demand {demand}");
    }

    #[test]
    fn stream_hits_peak_bandwidth() {
        let d = model();
        let (t, _) = d.stream(768_000);
        // 768 KB at 76.8 B/cycle = 10_000 cycles + penalty
        assert!((t - 10_030.0).abs() < 1.0, "{t}");
    }

    #[test]
    fn more_channels_faster() {
        let mut cfg = GripConfig::paper();
        let d4 = DramModel::new(&cfg);
        cfg.dram_channels = 8;
        cfg.prefetch_lanes = 8;
        let d8 = DramModel::new(&cfg);
        let (t4, _) = d4.bulk_rows(1000, 1204);
        let (t8, _) = d8.bulk_rows(1000, 1204);
        assert!(t8 < t4 * 0.6);
    }

    #[test]
    fn zero_work_is_free() {
        let d = model();
        assert_eq!(d.bulk_rows(0, 128).0, 0.0);
        assert_eq!(d.stream(0).0, 0.0);
    }
}
