//! Whole-accelerator composition: the control unit's command schedule
//! over partitioned nodeflows with double buffering and pipelining
//! (paper Sec. V-A "Control", Sec. VI-A).
//!
//! Execution of one layer:
//!   1. stream the layer's weights from DRAM into the global weight
//!      buffer (overlapped with the previous layer when
//!      `preload_weights`, paper "inter-layer pipelining");
//!   2. per partition column: bulk-load the column's new feature rows
//!      (overlapped across columns when `pipeline_partitions`; skipped
//!      for already-resident rows when `cache_features`), run per-input
//!      programs (identity nodeflows) on the vertex unit, run
//!      edge-accumulate on the edge unit, vertex-accumulate on the PE
//!      array (tile-interleaved with the edge unit when vertex-tiling is
//!      on), and vertex-update (overlapped when `pipeline_update`).

use super::counters::ActivityCounters;
use super::dram::DramModel;
use super::phases::{edge_accumulate_cycles, update_cycles, vertex_accumulate_cycles};
use crate::config::GripConfig;
use crate::greta::{Activate, Domain, ModelPlan, Src};
use crate::nodeflow::{Nodeflow, PartitionedLayer};

/// Timing of one simulated layer (busy cycles per unit + exposed span).
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerTiming {
    /// Exposed (wall-clock) cycles of the layer.
    pub span: f64,
    /// Busy cycles per unit.
    pub dram_feature: f64,
    pub dram_weight: f64,
    pub edge: f64,
    pub vertex: f64,
    pub update: f64,
}

/// Result of simulating one inference.
#[derive(Debug, Clone, Default)]
pub struct SimResult {
    /// End-to-end latency in cycles.
    pub cycles: f64,
    pub layers: Vec<LayerTiming>,
    pub counters: ActivityCounters,
}

impl SimResult {
    pub fn us(&self, cfg: &GripConfig) -> f64 {
        cfg.cycles_to_us(self.cycles)
    }

    /// Fraction of wall-clock time the vertex unit (matmul) is busy —
    /// Fig. 11a's y-axis.
    pub fn pct_vertex(&self) -> f64 {
        let v: f64 = self.layers.iter().map(|l| l.vertex).sum();
        if self.cycles > 0.0 {
            (v / self.cycles).min(1.0)
        } else {
            0.0
        }
    }

    /// Fraction of wall-clock time spent in edge-accumulate + feature
    /// loads — Fig. 11b's y-axis.
    pub fn pct_edge(&self) -> f64 {
        let e: f64 = self.layers.iter().map(|l| l.edge + l.dram_feature).sum();
        if self.cycles > 0.0 {
            (e / self.cycles).min(1.0)
        } else {
            0.0
        }
    }
}

/// Per-column work extracted from the partitioned nodeflow.
struct ColumnWork {
    /// New feature rows first touched in this column (loaded from DRAM).
    new_rows: usize,
    /// Rows touched in this column (reloaded when caching is off).
    touched_rows: usize,
    /// Output vertices in this column's chunk.
    out_rows: usize,
    /// Edges in this column (all blocks).
    edges: usize,
}

fn column_work(part: &PartitionedLayer, cache: bool) -> Vec<ColumnWork> {
    let n = part.chunk_inputs;
    let total_rows = part.num_input_chunks * n;
    let mut seen = vec![false; total_rows];
    // Epoch-stamped touch marks: `touched[g] == epoch` ⇔ row g was
    // touched in the current column. One flat Vec reused across columns
    // (epoch = column index + 1) replaces the seed's per-column HashSet
    // — no hashing, no per-column allocation, no clearing pass.
    let mut touched = vec![0u32; total_rows];
    let mut cols = Vec::with_capacity(part.num_output_chunks);
    for j in 0..part.num_output_chunks {
        let epoch = j as u32 + 1;
        let mut touched_rows = 0usize;
        let mut new_rows = 0usize;
        let mut edges = 0usize;
        for (i, block) in part.column(j).iter().enumerate() {
            edges += block.edges.len();
            for &(u_local, _) in &block.edges {
                let g = i * n + u_local as usize;
                if touched[g] != epoch {
                    touched[g] = epoch;
                    touched_rows += 1;
                    if !seen[g] {
                        new_rows += 1;
                        if cache {
                            seen[g] = true;
                        }
                    }
                }
            }
        }
        cols.push(ColumnWork {
            new_rows,
            touched_rows,
            out_rows: part.chunk_output_sizes[j],
            edges,
        });
    }
    cols
}

/// Simulate one inference of `plan` over `nf` on the configuration
/// `cfg`. Deterministic; returns cycle-level timing plus activity
/// counters for the energy model.
pub fn simulate(cfg: &GripConfig, plan: &ModelPlan, nf: &Nodeflow) -> SimResult {
    assert_eq!(plan.layers.len(), nf.layers.len());
    let dram = DramModel::new(cfg);
    let mut counters = ActivityCounters::default();
    let mut layers = Vec::with_capacity(plan.layers.len());
    let mut total = 0.0f64;
    // DRAM idle cycles of the previous layer, available for preloading
    // this layer's weights (paper's inter-layer pipelining).
    let mut prev_idle_dram = 0.0f64;

    for (li, (lp, nl)) in plan.layers.iter().zip(nf.layers.iter()).enumerate() {
        let part = PartitionedLayer::new(nl, cfg.part_inputs, cfg.part_outputs);
        let cols = column_work(&part, cfg.cache_features);
        let mut t = LayerTiming::default();

        // ---------------- layer weight load (DRAM -> global weight buf)
        let weight_bytes: usize = lp
            .programs
            .iter()
            .filter_map(|p| p.transform.as_ref())
            .map(|tr| tr.in_dim * tr.out_dim * cfg.elem_bytes)
            .sum();
        let (w_cycles, w_bytes) = dram.stream(weight_bytes);
        counters.dram_bytes += w_bytes;
        t.dram_weight = w_cycles;
        let exposed_weight = if cfg.preload_weights && li > 0 {
            // Preloaded during the previous layer's DRAM idle time; only
            // the remainder that did not fit is exposed.
            (w_cycles - prev_idle_dram).max(0.0)
        } else {
            w_cycles
        };

        // Only layer 0 reads features from DRAM; later layers consume the
        // previous layer's outputs from the nodeflow buffer.
        let feature_rows_from_dram = li == 0;
        let row_bytes = lp.in_dim * cfg.elem_bytes;

        // ---------------- per-column phase durations
        let mut load_c = Vec::with_capacity(cols.len());
        let mut core_c = Vec::with_capacity(cols.len());
        let mut update_tail = 0.0f64;
        for cw in &cols {
            // Feature load for this column.
            let rows = if cfg.cache_features { cw.new_rows } else { cw.touched_rows };
            if feature_rows_from_dram {
                // Mirror of the serving feature cache's accounting:
                // touches vs actual DRAM loads at the input layer.
                counters.feature_rows_touched += cw.touched_rows as u64;
                counters.feature_rows_loaded += rows as u64;
            }
            // With vertex-tiling the edge unit consumes features in
            // f-element slices, so DRAM serves each row as ceil(in_dim/f)
            // chunks of f*elem bytes — below the 128 B interface a chunk
            // wastes its burst (paper Fig. 13b: performance degrades for
            // F < 64 because "more random DRAM accesses are required").
            let (load_rows, chunk_bytes) = if cfg.vertex_tiling {
                let (_, f_t) = cfg.effective_tile(lp.in_dim);
                (rows * lp.in_dim.div_ceil(f_t), f_t * cfg.elem_bytes)
            } else {
                (rows, row_bytes)
            };
            let (lc, lb) = if feature_rows_from_dram && rows > 0 {
                if cfg.pipeline_partitions {
                    dram.bulk_rows(load_rows, chunk_bytes)
                } else {
                    // Unoptimized baseline: on-demand loads.
                    dram.on_demand_rows(load_rows, chunk_bytes)
                }
            } else {
                (0.0, 0)
            };
            counters.dram_bytes += lb;
            // DMA writes the rows into the nodeflow buffer.
            counters.nodeflow_sram_bytes += (rows * row_bytes) as u64;
            t.dram_feature += lc;
            load_c.push(lc);

            // Per-input (identity-nodeflow) programs: run once per
            // first-touched input row, scheduled with the column that
            // brings the row on-chip.
            let mut vpre = 0.0f64;
            let mut edge = 0.0f64;
            let mut vpost = 0.0f64;
            let mut upd = 0.0f64;
            for prog in &lp.programs {
                let src_dim = match prog.source {
                    Src::LayerInput => lp.in_dim,
                    Src::Program(k) => lp.programs[k]
                        .transform
                        .as_ref()
                        .map(|tr| tr.out_dim)
                        .unwrap_or(lp.in_dim),
                };
                match prog.domain {
                    Domain::AllInputs => {
                        // Per-input programs stream one transform per
                        // *edge source occurrence* (the hardware does not
                        // dedup across edges), so their cost follows the
                        // fixed sampled edge count — which is why Table
                        // III's GS/G-GCN latencies barely vary across
                        // datasets while GCN's loads do.
                        let rows_here = cw.edges;
                        if let Some(tr) = &prog.transform {
                            let vc = vertex_accumulate_cycles(cfg, rows_here, tr.in_dim, tr.out_dim, &mut counters);
                            vpre += vc.cycles;
                        }
                        if prog.activate != Activate::None {
                            let d = prog.transform.as_ref().map(|tr| tr.out_dim).unwrap_or(src_dim);
                            upd += update_cycles(cfg, rows_here, d, &mut counters);
                        }
                    }
                    Domain::Edges => {
                        edge += edge_accumulate_cycles(cfg, cw.edges, src_dim, cw.out_rows, &mut counters);
                        if let Some(tr) = &prog.transform {
                            let vc = vertex_accumulate_cycles(cfg, cw.out_rows, tr.in_dim, tr.out_dim, &mut counters);
                            vpost += vc.cycles;
                        }
                        if prog.activate != Activate::None {
                            let d = prog.transform.as_ref().map(|tr| tr.out_dim).unwrap_or(src_dim);
                            upd += update_cycles(cfg, cw.out_rows, d, &mut counters);
                        }
                    }
                    Domain::Outputs => {
                        if let Some(tr) = &prog.transform {
                            let vc = vertex_accumulate_cycles(cfg, cw.out_rows, tr.in_dim, tr.out_dim, &mut counters);
                            vpost += vc.cycles;
                        }
                        if prog.activate != Activate::None {
                            let d = prog.transform.as_ref().map(|tr| tr.out_dim).unwrap_or(src_dim);
                            upd += update_cycles(cfg, cw.out_rows, d, &mut counters);
                        }
                    }
                }
            }
            t.edge += edge;
            t.vertex += vpre + vpost;
            t.update += upd;

            // Edge/vertex composition within the column.
            let ev = if cfg.overlap_phases && cfg.vertex_tiling && edge > 0.0 {
                // Vertex-tiling interleaves tile production/consumption;
                // the slower unit dominates, plus one tile of fill.
                let f_tiles = lp.in_dim.div_ceil(cfg.tile_f.max(1)).max(1) as f64;
                edge.max(vpost) + edge / f_tiles
            } else if cfg.overlap_phases {
                // Without tiling the vertex unit waits for full feature
                // vectors (HyGCN-style serialization).
                edge + vpost
            } else {
                edge + vpost
            };
            let mut core = vpre + ev;
            if cfg.pipeline_update {
                // Update streams behind the vertex unit; only the last
                // column's tail is exposed.
                update_tail = upd * 0.1;
            } else {
                core += upd;
            }
            core_c.push(core);
        }

        // ---------------- compose columns (partition pipelining)
        let span = if cfg.overlap_phases && cfg.pipeline_partitions {
            // Loads stream on DRAM while compute runs: 2-stage pipeline.
            // DRAM is serialized: exposed weight load first, then column
            // feature loads in order.
            let mut dram_cum = exposed_weight;
            let mut finish = 0.0f64;
            for (lc, cc) in load_c.iter().zip(core_c.iter()) {
                dram_cum += lc;
                // Compute for a column starts when its data is resident
                // and the units are free.
                finish = dram_cum.max(finish) + cc;
            }
            finish + update_tail
        } else {
            // Fully serial: every phase back to back.
            exposed_weight + load_c.iter().sum::<f64>() + core_c.iter().sum::<f64>() + update_tail
        };

        // DRAM idle time of this layer = span minus its own DRAM busy
        // time; available for preloading the next layer's weights.
        let dram_busy: f64 = load_c.iter().sum::<f64>() + exposed_weight;
        prev_idle_dram = (span - dram_busy).max(0.0);

        // Phase-overlap accounting (mirrored host-side by the serving
        // shard pipeline's prefetch/engine counters): the edge-centric
        // phase is the feature prefetch streams, the vertex-centric
        // phase is the per-column compute; whatever the serial phase
        // sum exceeds the exposed span by was hidden by pipelining.
        let prefetch: f64 = load_c.iter().sum();
        let compute: f64 = core_c.iter().sum::<f64>() + update_tail;
        let serial = exposed_weight + prefetch + compute;
        counters.prefetch_cycles += prefetch as u64;
        counters.compute_cycles += compute as u64;
        counters.overlap_cycles += (serial - span).max(0.0) as u64;

        t.span = span;
        total += span;
        layers.push(t);
    }

    SimResult { cycles: total, layers, counters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::graph::Dataset;
    use crate::greta::{compile, GnnModel};
    use crate::nodeflow::Sampler;

    fn sim_for(model: GnnModel, ds: Dataset, cfg: &GripConfig) -> SimResult {
        let mc = ModelConfig::paper();
        let g = ds.generate(0.002, 11);
        let nf = Nodeflow::build(&g, &Sampler::new(7), &[123], &mc);
        let plan = compile(model, &mc);
        simulate(cfg, &plan, &nf)
    }

    #[test]
    fn gcn_latency_in_paper_range() {
        // Paper Table III: GCN 15.4–16.3 µs. Accept the right decade and
        // shape; exact constants are calibrated in the repro harness.
        let cfg = GripConfig::paper();
        let r = sim_for(GnnModel::Gcn, Dataset::Pokec, &cfg);
        let us = r.us(&cfg);
        assert!(us > 4.0 && us < 60.0, "GCN latency {us} µs");
    }

    #[test]
    fn model_ordering_matches_paper() {
        // Table III: GCN < GIN < {SAGE, G-GCN}. The paper puts G-GCN 18%
        // above GraphSAGE-max; our cost model places them within ~5% of
        // each other (documented deviation, EXPERIMENTS.md): both are
        // dominated by the same per-edge transform stream.
        let cfg = GripConfig::paper();
        let gcn = sim_for(GnnModel::Gcn, Dataset::Pokec, &cfg).cycles;
        let gin = sim_for(GnnModel::Gin, Dataset::Pokec, &cfg).cycles;
        let sage = sim_for(GnnModel::Sage, Dataset::Pokec, &cfg).cycles;
        let ggcn = sim_for(GnnModel::Ggcn, Dataset::Pokec, &cfg).cycles;
        assert!(gcn < gin, "gcn {gcn} gin {gin}");
        assert!(gin < sage, "gin {gin} sage {sage}");
        assert!(gin < ggcn, "gin {gin} ggcn {ggcn}");
        assert!(ggcn > 0.85 * sage, "sage {sage} ggcn {ggcn}");
    }

    #[test]
    fn vertex_tiling_speeds_up() {
        let on = GripConfig::paper();
        let mut off = GripConfig::paper();
        off.vertex_tiling = false;
        let t_on = sim_for(GnnModel::Gcn, Dataset::Pokec, &on).cycles;
        let t_off = sim_for(GnnModel::Gcn, Dataset::Pokec, &off).cycles;
        assert!(t_off > 1.5 * t_on, "on {t_on} off {t_off}");
    }

    #[test]
    fn pipelining_speeds_up() {
        let on = GripConfig::paper();
        let mut off = GripConfig::paper();
        off.pipeline_partitions = false;
        off.cache_features = false;
        off.preload_weights = false;
        let t_on = sim_for(GnnModel::Gcn, Dataset::Reddit, &on).cycles;
        let t_off = sim_for(GnnModel::Gcn, Dataset::Reddit, &off).cycles;
        assert!(t_off > 1.2 * t_on, "on {t_on} off {t_off}");
    }

    #[test]
    fn more_channels_help_until_knee() {
        // Fig. 10a: strong scaling to ~8 channels, then flat.
        let mk = |ch: usize| {
            let mut c = GripConfig::paper();
            c.dram_channels = ch;
            c.prefetch_lanes = ch;
            c
        };
        let t1 = sim_for(GnnModel::Gcn, Dataset::Pokec, &mk(1)).cycles;
        let t4 = sim_for(GnnModel::Gcn, Dataset::Pokec, &mk(4)).cycles;
        let t16 = sim_for(GnnModel::Gcn, Dataset::Pokec, &mk(16)).cycles;
        assert!(t1 > 2.0 * t4, "1ch {t1} 4ch {t4}");
        assert!(t16 > 0.3 * t4, "16ch {t16} should saturate");
    }

    #[test]
    fn larger_neighborhood_larger_latency() {
        let cfg = GripConfig::paper();
        let mc = ModelConfig::paper();
        let g = Dataset::Livejournal.generate(0.002, 11);
        let s = Sampler::new(7);
        let plan = compile(GnnModel::Gcn, &mc);
        // find a small and a large neighborhood target
        let mut sizes: Vec<(usize, u32)> = (0..200u32)
            .map(|v| (Nodeflow::build(&g, &s, &[v], &mc).neighborhood_size(), v))
            .collect();
        sizes.sort();
        let small = sizes[5].1;
        let large = sizes[sizes.len() - 5].1;
        let t_small = simulate(&cfg, &plan, &Nodeflow::build(&g, &s, &[small], &mc)).cycles;
        let t_large = simulate(&cfg, &plan, &Nodeflow::build(&g, &s, &[large], &mc)).cycles;
        assert!(t_large > t_small, "{t_small} !< {t_large}");
    }

    #[test]
    fn counters_populated() {
        let cfg = GripConfig::paper();
        let r = sim_for(GnnModel::Gcn, Dataset::Youtube, &cfg);
        assert!(r.counters.dram_bytes > 0);
        assert!(r.counters.macs > 0);
        assert!(r.counters.weight_sram_bytes > 0);
        assert!(r.counters.update_elems > 0);
        // DRAM bytes should be dominated by weights + features ~ 1-2 MB.
        assert!(r.counters.dram_bytes > 500_000, "{}", r.counters.dram_bytes);
        assert!(r.counters.dram_bytes < 20_000_000);
    }

    #[test]
    fn feature_cache_accounting_mirrors_policy() {
        let on = GripConfig::paper();
        let mut off = GripConfig::paper();
        off.cache_features = false;
        let r_on = sim_for(GnnModel::Gcn, Dataset::Pokec, &on);
        let r_off = sim_for(GnnModel::Gcn, Dataset::Pokec, &off);
        // Same nodeflow → same touches; caching only changes loads.
        assert_eq!(
            r_on.counters.feature_rows_touched,
            r_off.counters.feature_rows_touched
        );
        assert!(r_on.counters.feature_rows_loaded <= r_on.counters.feature_rows_touched);
        assert!(r_on.counters.feature_rows_touched > 0);
        // With caching off every touch is a DRAM load: hit rate 0.
        assert_eq!(r_off.counters.feature_rows_loaded, r_off.counters.feature_rows_touched);
        assert_eq!(r_off.counters.feature_hit_rate(), 0.0);
        assert!(r_on.counters.feature_hit_rate() >= 0.0);
        assert!(r_on.counters.feature_hit_rate() < 1.0);
    }

    #[test]
    fn phase_overlap_mirrors_pipelining_knob() {
        // With partition pipelining on, feature prefetch overlaps
        // compute and the hidden cycles are counted; fully serial
        // composition hides nothing. (Partition chunks shrunk so the
        // single-target nodeflow definitely spans several partition
        // columns — a single-column layer has nothing to overlap.)
        let mut on = GripConfig::paper();
        on.part_inputs = 64;
        on.part_outputs = 4;
        let mut off = on.clone();
        off.pipeline_partitions = false;
        off.overlap_phases = false;
        let r_on = sim_for(GnnModel::Gcn, Dataset::Reddit, &on);
        let r_off = sim_for(GnnModel::Gcn, Dataset::Reddit, &off);
        assert!(r_on.counters.overlap_cycles > 0, "pipelined run hides prefetch cycles");
        assert!(r_on.counters.phase_overlap_rate() > 0.0);
        assert!(r_on.counters.phase_overlap_rate() < 1.0);
        assert_eq!(r_off.counters.overlap_cycles, 0, "serial phases hide nothing");
        assert_eq!(r_off.counters.phase_overlap_rate(), 0.0);
        assert!(r_on.counters.prefetch_cycles > 0);
        assert!(r_on.counters.compute_cycles > 0);
    }

    #[test]
    fn deterministic() {
        let cfg = GripConfig::paper();
        let a = sim_for(GnnModel::Ggcn, Dataset::Youtube, &cfg);
        let b = sim_for(GnnModel::Ggcn, Dataset::Youtube, &cfg);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.counters, b.counters);
    }
}
