//! Graph substrate: CSR storage, synthetic dataset generators calibrated
//! to the paper's Table I, and the dataset registry used by every
//! experiment.
//!
//! The paper evaluates on SNAP/UF graphs (Youtube, LiveJournal, Pokec,
//! Reddit). Those downloads are unavailable here, so we generate seeded
//! synthetic graphs matched on the three statistics the evaluation
//! actually depends on — node count, edge count, and the median number of
//! unique vertices in a sampled 2-hop neighborhood ("2-Hop" in Table I) —
//! which together determine every workload quantity in the paper
//! (DESIGN.md §Substitutions).

mod csr;
mod datasets;
mod generator;
mod partition;

pub use csr::CsrGraph;
pub use datasets::{Dataset, DatasetSpec, TABLE1};
pub use generator::{generate, GeneratorParams};
pub use partition::{PartitionStats, PartitionStrategy, Partitioning};
