//! Seeded synthetic graph generator with controllable locality.
//!
//! The model: each vertex draws a power-law out-degree (zipf exponent
//! `zipf_s`, scaled to hit `mean_degree`), and picks neighbors mostly
//! from a local *community pool* of `pool_size` consecutive vertex ids,
//! with probability `rewire` of a uniform long-range endpoint instead.
//!
//! The pool size directly controls how much 2-hop neighborhoods dedup
//! (draws from a pool of P vertices have expected unique count
//! P·(1−(1−1/P)^k)), which is what Table I's "2-Hop" column measures;
//! the degree distribution controls how many draws there are. Those are
//! the only graph statistics the paper's evaluation consumes, so
//! calibrating them reproduces the workload (DESIGN.md §Substitutions).

use super::csr::CsrGraph;
use crate::rng::SplitMix64;

/// Parameters for [`generate`].
#[derive(Debug, Clone)]
pub struct GeneratorParams {
    pub nodes: usize,
    /// Target mean out-degree (edges ≈ nodes × mean_degree).
    pub mean_degree: f64,
    /// Community pool size (locality → 2-hop dedup).
    pub pool_size: usize,
    /// Degree-distribution skew (zipf exponent, >1; higher = more even).
    pub zipf_s: f64,
    /// Probability an edge endpoint is uniform over all vertices.
    pub rewire: f64,
    pub seed: u64,
}

impl Default for GeneratorParams {
    fn default() -> Self {
        Self { nodes: 10_000, mean_degree: 8.0, pool_size: 150, zipf_s: 2.0, rewire: 0.05, seed: 1 }
    }
}

/// Generate a seeded synthetic graph. Deterministic per parameters.
pub fn generate(p: &GeneratorParams) -> CsrGraph {
    assert!(p.nodes > 1, "need at least 2 vertices");
    let mut rng = SplitMix64::new(p.seed);
    let pool = p.pool_size.clamp(2, p.nodes);

    // Degree model: most vertices sit near the mean (real social graphs
    // post-GraphSAGE preprocessing have a compressed body: the sampler
    // caps the useful degree anyway), with a zipf-distributed hub tail
    // (15%). This keeps the *median* degree ≈ mean (what the sampled
    // 2-hop statistic depends on) while preserving a heavy tail (what
    // the Fig. 12 neighborhood spread depends on).
    const HUB_FRACTION: f64 = 0.15;
    let probe = 4096.min(p.nodes * 4).max(1024);
    let mut probe_rng = SplitMix64::new(p.seed ^ 0x5eed);
    let mean_w: f64 = (0..probe).map(|_| probe_rng.gen_zipf(64, p.zipf_s) as f64).sum::<f64>() / probe as f64;

    let mut adj: Vec<Vec<u32>> = Vec::with_capacity(p.nodes);
    for v in 0..p.nodes {
        let d = if rng.gen_f64() < HUB_FRACTION {
            let w = rng.gen_zipf(64, p.zipf_s) as f64 / mean_w;
            ((p.mean_degree * w * 1.5).round() as usize).max(1)
        } else {
            // body: uniform in [0.75, 1.25] x mean
            let u = 0.75 + 0.5 * rng.gen_f64();
            ((p.mean_degree * u).round() as usize).max(1)
        };
        // Community base: centered window, clamped at the id range ends.
        let half = pool / 2;
        let base = (v.saturating_sub(half)).min(p.nodes - pool);
        let mut neigh = Vec::with_capacity(d);
        for _ in 0..d {
            let t = if rng.gen_f64() < p.rewire {
                rng.gen_range(p.nodes)
            } else {
                base + rng.gen_range(pool)
            };
            if t != v {
                neigh.push(t as u32);
            }
        }
        if neigh.is_empty() {
            // Guarantee no isolated vertex (the sampler needs 1+ neighbor).
            let t = if v + 1 < p.nodes { v + 1 } else { v - 1 };
            neigh.push(t as u32);
        }
        adj.push(neigh);
    }
    CsrGraph::from_adjacency(adj)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let p = GeneratorParams { nodes: 500, ..Default::default() };
        let a = generate(&p);
        let b = generate(&p);
        assert_eq!(a.num_edges(), b.num_edges());
        for v in 0..500u32 {
            assert_eq!(a.neighbors(v), b.neighbors(v));
        }
    }

    #[test]
    fn different_seeds_differ() {
        let p = GeneratorParams { nodes: 500, ..Default::default() };
        let q = GeneratorParams { seed: 2, ..p.clone() };
        let a = generate(&p);
        let b = generate(&q);
        let same = (0..500u32).all(|v| a.neighbors(v) == b.neighbors(v));
        assert!(!same);
    }

    #[test]
    fn mean_degree_close_to_target() {
        let p = GeneratorParams { nodes: 20_000, mean_degree: 10.0, ..Default::default() };
        let g = generate(&p);
        let md = g.mean_degree();
        assert!((md - 10.0).abs() / 10.0 < 0.25, "mean degree {md}");
    }

    #[test]
    fn no_isolated_vertices() {
        let p = GeneratorParams { nodes: 2_000, mean_degree: 1.2, ..Default::default() };
        let g = generate(&p);
        for v in 0..g.num_vertices() as u32 {
            assert!(g.degree(v) >= 1, "vertex {v} isolated");
        }
    }

    #[test]
    fn locality_pool_respected() {
        // With rewire = 0 every neighbor lies within the pool window.
        let p = GeneratorParams {
            nodes: 5_000,
            pool_size: 100,
            rewire: 0.0,
            ..Default::default()
        };
        let g = generate(&p);
        for v in 0..g.num_vertices() as u32 {
            for &t in g.neighbors(v) {
                assert!((t as i64 - v as i64).unsigned_abs() <= 100, "edge {v}->{t} too long");
            }
        }
    }

    #[test]
    fn no_self_loops() {
        let g = generate(&GeneratorParams { nodes: 3_000, ..Default::default() });
        for v in 0..g.num_vertices() as u32 {
            assert!(!g.neighbors(v).contains(&v));
        }
    }
}
