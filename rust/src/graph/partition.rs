//! Vertex partitioning for partition-local serving (PR 6).
//!
//! GRIP's prefetch engines win because each one streams features for a
//! bounded slice of the graph; giving every executor shard the whole
//! graph and one shared cache throws that locality away. This module
//! produces deterministic vertex partitions over a [`CsrGraph`] so each
//! shard of the serving pool can own a **partition-local** feature
//! cache and only pull boundary rows from its peers.
//!
//! Two strategies:
//!
//! * **Degree-balanced** — LPT greedy over out-degree: vertices are
//!   assigned in descending degree order to the partition with the
//!   least accumulated degree. This balances *edge work* (feature
//!   gathers scale with degree, not vertex count), the quantity GNNIE's
//!   degree-aware load balancing targets. The classic LPT bound gives
//!   `max_load <= mean_load + max_degree`, which the unit tests pin.
//! * **Hash baseline** — SplitMix64-finalizer of the vertex id, modulo
//!   the part count. Near-perfect vertex-count balance, oblivious to
//!   degree and locality; the control arm for the bench sweep.
//!
//! Both are pure functions of `(graph, parts)` — no RNG state — so the
//! same graph always routes the same way, which the bit-identity
//! property tests rely on.

use crate::graph::CsrGraph;

/// Which vertex-partitioning pass the serving pool should run.
/// `Off` preserves the PR-5 behavior: every shard sees the whole graph
/// and shares one feature cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PartitionStrategy {
    Degree,
    Hash,
    #[default]
    Off,
}

impl PartitionStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            PartitionStrategy::Degree => "degree",
            PartitionStrategy::Hash => "hash",
            PartitionStrategy::Off => "off",
        }
    }

    /// Parse a CLI spelling (`degree|hash|off`).
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "degree" => Some(PartitionStrategy::Degree),
            "hash" => Some(PartitionStrategy::Hash),
            "off" => Some(PartitionStrategy::Off),
            _ => None,
        }
    }
}

/// Per-partition occupancy and cut statistics, computed once at build
/// time and surfaced through `ServeStats` / `BENCH_serve.json`.
#[derive(Debug, Clone, Default)]
pub struct PartitionStats {
    pub parts: usize,
    /// Vertices owned by each partition.
    pub vertices: Vec<usize>,
    /// Sum of owned out-degrees per partition (the "edge work" LPT
    /// balances).
    pub edges: Vec<u64>,
    /// Edges whose endpoint lives on a different partition than its
    /// source — each one is a potential boundary fetch.
    pub cut_edges: u64,
    pub total_edges: u64,
    /// `max(edges) / mean(edges)`: 1.0 is perfect degree balance.
    pub balance: f64,
}

impl PartitionStats {
    /// Fraction of edges crossing partitions (0.0 for 1 part or an
    /// edgeless graph).
    pub fn edge_cut_fraction(&self) -> f64 {
        if self.total_edges == 0 {
            0.0
        } else {
            self.cut_edges as f64 / self.total_edges as f64
        }
    }
}

/// A vertex → partition assignment plus its stats.
#[derive(Debug, Clone)]
pub struct Partitioning {
    strategy: PartitionStrategy,
    parts: usize,
    /// `owner[v]` = partition owning vertex `v`.
    owner: Vec<u32>,
    stats: PartitionStats,
}

/// SplitMix64 finalizer: a stateless avalanche of the vertex id, so the
/// hash baseline needs no RNG object and stays order-independent.
fn mix64(v: u64) -> u64 {
    let mut z = v.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Partitioning {
    /// Partition `g` into `parts` pieces. `parts == 0` is treated as 1;
    /// `Off` degenerates to one part owning everything (so callers can
    /// route unconditionally).
    pub fn build(strategy: PartitionStrategy, g: &CsrGraph, parts: usize) -> Self {
        let parts = match strategy {
            PartitionStrategy::Off => 1,
            _ => parts.max(1),
        };
        let n = g.num_vertices();
        let mut owner = vec![0u32; n];
        match strategy {
            PartitionStrategy::Off => {}
            PartitionStrategy::Hash => {
                for (v, o) in owner.iter_mut().enumerate() {
                    *o = (mix64(v as u64) % parts as u64) as u32;
                }
            }
            PartitionStrategy::Degree => {
                // LPT greedy: highest degree first, ties by vertex id,
                // each into the currently lightest part (ties by part
                // index). Deterministic and O(n log n + n·p); p is the
                // shard count (single digits), so the linear min scan
                // beats a heap here.
                let mut order: Vec<u32> = (0..n as u32).collect();
                order.sort_unstable_by(|&a, &b| {
                    g.degree(b).cmp(&g.degree(a)).then(a.cmp(&b))
                });
                let mut load = vec![0u64; parts];
                for v in order {
                    let mut best = 0;
                    for p in 1..parts {
                        if load[p] < load[best] {
                            best = p;
                        }
                    }
                    owner[v as usize] = best as u32;
                    load[best] += g.degree(v) as u64;
                }
            }
        }
        let stats = Self::compute_stats(g, &owner, parts);
        Self { strategy, parts, owner, stats }
    }

    fn compute_stats(g: &CsrGraph, owner: &[u32], parts: usize) -> PartitionStats {
        let mut vertices = vec![0usize; parts];
        let mut edges = vec![0u64; parts];
        let mut cut_edges = 0u64;
        for v in 0..g.num_vertices() as u32 {
            let p = owner[v as usize] as usize;
            vertices[p] += 1;
            edges[p] += g.degree(v) as u64;
            for &dst in g.neighbors(v) {
                if owner[dst as usize] != owner[v as usize] {
                    cut_edges += 1;
                }
            }
        }
        let total_edges = g.num_edges() as u64;
        let max = edges.iter().copied().max().unwrap_or(0) as f64;
        let mean = total_edges as f64 / parts as f64;
        let balance = if mean > 0.0 { max / mean } else { 1.0 };
        PartitionStats { parts, vertices, edges, cut_edges, total_edges, balance }
    }

    pub fn strategy(&self) -> PartitionStrategy {
        self.strategy
    }

    pub fn parts(&self) -> usize {
        self.parts
    }

    /// Home partition of vertex `v`.
    #[inline]
    pub fn owner(&self, v: u32) -> usize {
        self.owner[v as usize] as usize
    }

    pub fn stats(&self) -> &PartitionStats {
        &self.stats
    }

    /// Out-degrees of the vertices owned by partition `p` — the input
    /// for recalibrating `DegreeClasses` per partition.
    pub fn owned_degrees(&self, g: &CsrGraph, p: usize) -> Vec<usize> {
        (0..g.num_vertices() as u32)
            .filter(|&v| self.owner[v as usize] as usize == p)
            .map(|v| g.degree(v))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, GeneratorParams};

    fn zipf_graph(nodes: usize) -> CsrGraph {
        generate(&GeneratorParams { nodes, mean_degree: 8.0, ..Default::default() })
    }

    #[test]
    fn strategy_names_round_trip() {
        for s in [PartitionStrategy::Degree, PartitionStrategy::Hash, PartitionStrategy::Off] {
            assert_eq!(PartitionStrategy::from_name(s.name()), Some(s));
        }
        assert_eq!(PartitionStrategy::from_name("metis"), None);
        assert_eq!(PartitionStrategy::default(), PartitionStrategy::Off);
    }

    #[test]
    fn off_is_a_single_part_owning_everything() {
        let g = zipf_graph(500);
        let p = Partitioning::build(PartitionStrategy::Off, &g, 4);
        assert_eq!(p.parts(), 1);
        assert!((0..500u32).all(|v| p.owner(v) == 0));
        assert_eq!(p.stats().cut_edges, 0);
        assert_eq!(p.stats().edge_cut_fraction(), 0.0);
        assert!((p.stats().balance - 1.0).abs() < 1e-12);
    }

    #[test]
    fn degree_partition_meets_the_lpt_balance_bound() {
        // LPT guarantee: when the greedy assigns vertex v to the
        // lightest part, that part's load is <= the running mean, so
        // max_load <= mean_load + max_degree. Pin it on a zipf graph
        // whose hubs make naive round-robin badly unbalanced.
        let g = zipf_graph(4_000);
        for parts in [2usize, 3, 4, 7] {
            let p = Partitioning::build(PartitionStrategy::Degree, &g, parts);
            let stats = p.stats();
            let mean = stats.total_edges as f64 / parts as f64;
            let max_degree =
                (0..g.num_vertices() as u32).map(|v| g.degree(v)).max().unwrap() as f64;
            let max_load = *stats.edges.iter().max().unwrap() as f64;
            assert!(
                max_load <= mean + max_degree,
                "parts={parts}: max {max_load} > mean {mean} + max_degree {max_degree}"
            );
            assert_eq!(stats.vertices.iter().sum::<usize>(), g.num_vertices());
            assert_eq!(stats.edges.iter().sum::<u64>(), stats.total_edges);
            assert!(stats.balance >= 1.0 - 1e-12);
        }
    }

    #[test]
    fn degree_beats_hash_on_edge_balance() {
        let g = zipf_graph(4_000);
        let deg = Partitioning::build(PartitionStrategy::Degree, &g, 4);
        let hash = Partitioning::build(PartitionStrategy::Hash, &g, 4);
        assert!(
            deg.stats().balance <= hash.stats().balance + 1e-9,
            "degree balance {} vs hash {}",
            deg.stats().balance,
            hash.stats().balance
        );
    }

    #[test]
    fn hash_partition_is_vertex_balanced_and_deterministic() {
        let g = zipf_graph(2_000);
        let a = Partitioning::build(PartitionStrategy::Hash, &g, 4);
        let b = Partitioning::build(PartitionStrategy::Hash, &g, 4);
        assert_eq!(a.owner, b.owner, "stateless hash must be reproducible");
        let min = *a.stats().vertices.iter().min().unwrap();
        let max = *a.stats().vertices.iter().max().unwrap();
        // 2000 vertices over 4 parts: splitmix spreads within a few
        // percent of 500 each.
        assert!(min > 400 && max < 600, "hash spread {min}..{max}");
    }

    #[test]
    fn cut_edges_match_a_direct_count() {
        let g = zipf_graph(600);
        let p = Partitioning::build(PartitionStrategy::Degree, &g, 3);
        let mut cut = 0u64;
        for v in 0..g.num_vertices() as u32 {
            for &dst in g.neighbors(v) {
                if p.owner(dst) != p.owner(v) {
                    cut += 1;
                }
            }
        }
        assert_eq!(p.stats().cut_edges, cut);
        assert!(p.stats().edge_cut_fraction() > 0.0, "3 parts must cut something");
        assert!(p.stats().edge_cut_fraction() <= 1.0);
    }

    #[test]
    fn owned_degrees_cover_the_partition() {
        let g = zipf_graph(800);
        let p = Partitioning::build(PartitionStrategy::Degree, &g, 4);
        for part in 0..4 {
            let ds = p.owned_degrees(&g, part);
            assert_eq!(ds.len(), p.stats().vertices[part]);
            assert_eq!(ds.iter().map(|&d| d as u64).sum::<u64>(), p.stats().edges[part]);
        }
    }

    #[test]
    fn single_part_owns_everything_under_any_strategy() {
        let g = zipf_graph(300);
        for s in [PartitionStrategy::Degree, PartitionStrategy::Hash] {
            let p = Partitioning::build(s, &g, 1);
            assert_eq!(p.parts(), 1);
            assert_eq!(p.stats().cut_edges, 0);
            assert!((p.stats().balance - 1.0).abs() < 1e-12);
        }
    }
}
