//! Dataset registry calibrated to the paper's Table I.
//!
//! | Dataset          | Nodes     | Edges      | 2-Hop |
//! |------------------|-----------|------------|-------|
//! | Youtube (YT)     | 1,134,890 | 2,987,624  | 25    |
//! | Livejournal (LJ) | 3,997,962 | 34,681,189 | 65    |
//! | Pokec (PO)       | 1,632,803 | 30,622,564 | 167   |
//! | Reddit (RD)      | 232,383   | 47,396,905 | 239   |
//!
//! `pool_size`/`zipf_s` were calibrated (rust/tests/integration.rs
//! asserts it) so that the *sampled* 2-hop median under the paper's
//! 25/10 GraphSAGE sampling lands near the table.

use super::csr::CsrGraph;
use super::generator::{generate, GeneratorParams};

/// The four evaluation datasets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataset {
    Youtube,
    Livejournal,
    Pokec,
    Reddit,
}

pub const TABLE1: [Dataset; 4] =
    [Dataset::Youtube, Dataset::Livejournal, Dataset::Pokec, Dataset::Reddit];

/// Static calibration record for one dataset.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub short: &'static str,
    pub nodes: usize,
    pub edges: usize,
    /// Paper Table I "2-Hop" median (under 25/10 sampling).
    pub two_hop_median: usize,
    /// Generator calibration.
    pub pool_size: usize,
    pub zipf_s: f64,
    pub rewire: f64,
}

impl Dataset {
    pub fn spec(&self) -> DatasetSpec {
        match self {
            Dataset::Youtube => DatasetSpec {
                name: "youtube",
                short: "YT",
                nodes: 1_134_890,
                edges: 2_987_624,
                two_hop_median: 25,
                pool_size: 150,
                zipf_s: 1.6,
                rewire: 0.03,
            },
            Dataset::Livejournal => DatasetSpec {
                name: "livejournal",
                short: "LJ",
                nodes: 3_997_962,
                edges: 34_681_189,
                two_hop_median: 65,
                pool_size: 75,
                zipf_s: 1.8,
                rewire: 0.08,
            },
            Dataset::Pokec => DatasetSpec {
                name: "pokec",
                short: "PO",
                nodes: 1_632_803,
                edges: 30_622_564,
                two_hop_median: 167,
                pool_size: 600,
                zipf_s: 2.0,
                rewire: 0.05,
            },
            Dataset::Reddit => DatasetSpec {
                name: "reddit",
                short: "RD",
                nodes: 232_383,
                edges: 47_396_905,
                two_hop_median: 239,
                pool_size: 2000,
                zipf_s: 2.2,
                rewire: 0.05,
            },
        }
    }

    pub fn from_name(name: &str) -> Option<Dataset> {
        match name.to_ascii_lowercase().as_str() {
            "youtube" | "yt" => Some(Dataset::Youtube),
            "livejournal" | "lj" => Some(Dataset::Livejournal),
            "pokec" | "po" => Some(Dataset::Pokec),
            "reddit" | "rd" => Some(Dataset::Reddit),
            _ => None,
        }
    }

    /// Generate the synthetic equivalent at `scale` of the full node
    /// count (scale = 1.0 is the paper-size graph). Local statistics
    /// (degree distribution, pool locality, hence sampled 2-hop size)
    /// are scale-invariant, so experiments default to a smaller scale.
    pub fn generate(&self, scale: f64, seed: u64) -> CsrGraph {
        let spec = self.spec();
        let nodes = ((spec.nodes as f64 * scale) as usize).max(2 * spec.pool_size).max(1000);
        // GraphSAGE preprocessing treats edges as undirected: each edge
        // contributes a neighbor to both endpoints, so the sampler sees
        // twice the directed mean degree.
        let mean_degree = 2.0 * spec.edges as f64 / spec.nodes as f64;
        generate(&GeneratorParams {
            nodes,
            mean_degree,
            pool_size: spec.pool_size,
            zipf_s: spec.zipf_s,
            rewire: spec.rewire,
            seed: seed ^ (spec.nodes as u64),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_table1() {
        let yt = Dataset::Youtube.spec();
        assert_eq!(yt.nodes, 1_134_890);
        assert_eq!(yt.edges, 2_987_624);
        assert_eq!(yt.two_hop_median, 25);
        let rd = Dataset::Reddit.spec();
        assert_eq!(rd.edges, 47_396_905);
    }

    #[test]
    fn from_name_aliases() {
        assert_eq!(Dataset::from_name("LJ"), Some(Dataset::Livejournal));
        assert_eq!(Dataset::from_name("pokec"), Some(Dataset::Pokec));
        assert_eq!(Dataset::from_name("nope"), None);
    }

    #[test]
    fn scaled_generation_preserves_mean_degree() {
        let g = Dataset::Youtube.generate(0.01, 7);
        let want = 2.0 * 2_987_624.0 / 1_134_890.0;
        let got = g.mean_degree();
        assert!((got - want).abs() / want < 0.3, "mean degree {got} vs {want}");
    }

    #[test]
    fn generation_deterministic() {
        let a = Dataset::Pokec.generate(0.005, 9);
        let b = Dataset::Pokec.generate(0.005, 9);
        assert_eq!(a.num_edges(), b.num_edges());
    }
}
