//! Compressed sparse row graph storage — the substrate under the sampler
//! and nodeflow builder. Vertices are `u32`; edges are directed (an
//! undirected input is stored with both arcs).

/// A directed graph in CSR form.
#[derive(Debug, Clone)]
pub struct CsrGraph {
    /// offsets[v]..offsets[v+1] indexes `targets` for v's out-neighbors.
    offsets: Vec<u64>,
    targets: Vec<u32>,
    /// Maximum out-degree, computed once at construction (partition
    /// sizing heuristics query it on the request path).
    max_degree: usize,
}

impl CsrGraph {
    fn from_parts(offsets: Vec<u64>, targets: Vec<u32>) -> Self {
        let max_degree =
            offsets.windows(2).map(|w| (w[1] - w[0]) as usize).max().unwrap_or(0);
        Self { offsets, targets, max_degree }
    }

    /// Build from an adjacency-list iterator. Neighbor lists are kept in
    /// given order (samplers use index-based selection, so order matters
    /// only for determinism).
    pub fn from_adjacency(adj: Vec<Vec<u32>>) -> Self {
        let mut offsets = Vec::with_capacity(adj.len() + 1);
        let mut targets = Vec::new();
        offsets.push(0u64);
        for neigh in &adj {
            targets.extend_from_slice(neigh);
            offsets.push(targets.len() as u64);
        }
        Self::from_parts(offsets, targets)
    }

    /// Build from an edge list (u -> v), grouping by source.
    pub fn from_edges(num_vertices: usize, edges: &[(u32, u32)]) -> Self {
        let mut degree = vec![0u64; num_vertices];
        for &(u, _) in edges {
            degree[u as usize] += 1;
        }
        let mut offsets = vec![0u64; num_vertices + 1];
        for v in 0..num_vertices {
            offsets[v + 1] = offsets[v] + degree[v];
        }
        let mut cursor = offsets.clone();
        let mut targets = vec![0u32; edges.len()];
        for &(u, v) in edges {
            let c = &mut cursor[u as usize];
            targets[*c as usize] = v;
            *c += 1;
        }
        Self::from_parts(offsets, targets)
    }

    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn num_edges(&self) -> usize {
        self.targets.len()
    }

    pub fn degree(&self, v: u32) -> usize {
        let v = v as usize;
        (self.offsets[v + 1] - self.offsets[v]) as usize
    }

    pub fn neighbors(&self, v: u32) -> &[u32] {
        let v = v as usize;
        &self.targets[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }

    /// Mean out-degree.
    pub fn mean_degree(&self) -> f64 {
        self.num_edges() as f64 / self.num_vertices().max(1) as f64
    }

    /// Maximum out-degree (used by partition sizing heuristics).
    /// Precomputed at construction; O(1).
    pub fn max_degree(&self) -> usize {
        self.max_degree
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> CsrGraph {
        // 0 -> 1,2 ; 1 -> 3 ; 2 -> 3 ; 3 -> (none)
        CsrGraph::from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3)])
    }

    #[test]
    fn from_edges_basic() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(3), &[] as &[u32]);
        assert_eq!(g.degree(1), 1);
    }

    #[test]
    fn from_adjacency_matches_from_edges() {
        let a = CsrGraph::from_adjacency(vec![vec![1, 2], vec![3], vec![3], vec![]]);
        let b = diamond();
        for v in 0..4u32 {
            assert_eq!(a.neighbors(v), b.neighbors(v));
        }
    }

    #[test]
    fn isolated_vertices_allowed() {
        let g = CsrGraph::from_edges(5, &[(0, 4)]);
        assert_eq!(g.degree(2), 0);
        assert_eq!(g.neighbors(2), &[] as &[u32]);
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn mean_degree() {
        let g = diamond();
        assert!((g.mean_degree() - 1.0).abs() < 1e-12);
        assert_eq!(g.max_degree(), 2);
    }

    #[test]
    fn max_degree_cached_in_both_constructors() {
        let g = CsrGraph::from_edges(6, &[(0, 1), (0, 2), (0, 3), (2, 1), (4, 5)]);
        assert_eq!(g.max_degree(), 3);
        let a = CsrGraph::from_adjacency(vec![vec![], vec![0, 2, 3, 4], vec![1]]);
        assert_eq!(a.max_degree(), 4);
        let empty = CsrGraph::from_edges(0, &[]);
        assert_eq!(empty.max_degree(), 0);
    }
}
