//! The GReTA programming model (paper Sec. IV) and the GRIP "compiler".
//!
//! GReTA decomposes a GNN layer into four stateless UDFs — gather,
//! reduce, transform, activate — invoked across three phases
//! (edge-accumulate, vertex-accumulate, vertex-update). Complex layers
//! are split into multiple *programs* whose outputs feed later programs'
//! features or accumulators (paper Fig. 3/4).
//!
//! * [`ops`] — the UDF vocabulary our PE implementation supports
//!   (paper Sec. V-A "PE Implementation").
//! * [`spec`] — the data-driven model IR: [`ModelSpec`] (typed builder
//!   + JSON loader), the validation/lowering pass into [`ModelPlan`],
//!   and the serving [`ModelLibrary`] / [`ModelKey`] registry.
//! * [`program`] — executable plans plus the [`GnnModel`] preset
//!   factory: GCN, GraphSAGE-max, GIN, G-GCN specs exactly mirroring
//!   Fig. 4.
//! * [`exec`] — the bit-accurate functional executor: runs a compiled
//!   plan over a nodeflow on the 16-bit fixed-point datapath ([`crate::fixed`]),
//!   validated against the float PJRT path in integration tests.

mod exec;
mod ops;
mod program;
mod spec;

pub use exec::{
    exec_test_args, execute_model, execute_model_into, execute_model_into_memo, execute_model_ref,
    execute_model_ref_memo, Args as ExecArgs, ExecError, ExecScratch, PlanArgs,
};
pub use ops::{Activate, Domain, GatherOp, ReduceOp, SelfScale};
pub use program::{
    compile, GnnModel, LayerPlan, MatMul, ModelPlan, Program, Src, ALL_MODELS, MODEL_NAME_HELP,
};
pub use spec::{
    LayerSpec, ModelEntry, ModelKey, ModelLibrary, ModelSpec, ModelSpecBuilder, ProgramSpec,
    SpecError,
};
