//! Data-driven model IR: [`ModelSpec`] is the programmable surface of
//! GReTA (paper Sec. IV) — a typed description of arbitrary layer
//! counts, dims, gather/reduce/activate ops, self-scale terms, and
//! owned weight names — compiled by a single validation + lowering
//! pass ([`ModelSpec::compile`]) into the executable [`ModelPlan`].
//!
//! Before this redesign the four paper models were hardcoded behind a
//! closed `GnnModel` enum; every new scenario meant editing match arms
//! across the crate. Now `GnnModel` is only a *preset factory*
//! ([`GnnModel::spec`] yields the four Fig. 4 specs) and everything
//! downstream — executor, cycle simulator, baselines, serving stack —
//! consumes plans generically. Specs come from three places:
//!
//! * the typed builder: `ModelSpec::builder("x").layer(...)...build()`;
//! * the preset factory (`GnnModel::Gcn.spec(&mc)`);
//! * JSON ([`ModelSpec::from_json_str`], schema documented in
//!   `examples/MODEL_SPEC.md`; parsed with the crate's own
//!   [`crate::runtime::json`] — no new dependencies).
//!
//! [`ModelLibrary`] is the serving-side registry: the four presets plus
//! any registered custom specs, each compiled once and addressed by a
//! cheap [`ModelKey`] that requests, the batcher, and the load
//! generator carry instead of the old enum.

use super::ops::{Activate, Domain, GatherOp, ReduceOp, SelfScale};
use super::program::{GnnModel, LayerPlan, MatMul, ModelPlan, Program, Src, ALL_MODELS};
use crate::config::ModelConfig;
use crate::runtime::json::{parse, Json};
use std::collections::HashMap;

// ---------------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------------

/// Spec validation / parse errors. Every variant names the offending
/// layer/program so a bad JSON file is debuggable without a stack trace.
#[derive(Debug, Clone, PartialEq)]
pub enum SpecError {
    /// A required collection is empty ("layers", "programs in layer 1").
    Empty(String),
    /// Adjacent layers disagree on the chained dimension.
    LayerChain { layer: usize, out_dim: usize, next_in_dim: usize },
    /// A program references a program that is not strictly earlier in
    /// the same layer (dangling `Src::Program` / gather / add ref).
    Dangling { layer: usize, program: usize, what: &'static str, reference: usize },
    /// A dimension contract is violated.
    DimMismatch { layer: usize, program: String, what: &'static str, expected: usize, got: usize },
    /// The same weight name is declared with two different shapes.
    WeightConflict { weight: String },
    /// The layer's output program is unusable (wrong rows/index).
    BadProgram { layer: usize, why: String },
    /// Registering a spec under a name the library already holds.
    DuplicateName(String),
    /// JSON-level failure (syntax, missing/unknown key, bad enum tag).
    Parse(String),
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecError::Empty(what) => write!(f, "model spec has no {what}"),
            SpecError::LayerChain { layer, out_dim, next_in_dim } => write!(
                f,
                "layer {layer} out_dim {out_dim} != layer {} in_dim {next_in_dim}",
                layer + 1
            ),
            SpecError::Dangling { layer, program, what, reference } => write!(
                f,
                "layer {layer} program {program}: dangling {what} reference to program \
                 {reference} (must reference an earlier program of the same layer)"
            ),
            SpecError::DimMismatch { layer, program, what, expected, got } => write!(
                f,
                "layer {layer} program {program:?}: {what} dim mismatch (expected {expected}, \
                 got {got})"
            ),
            SpecError::WeightConflict { weight } => {
                write!(f, "weight {weight:?} declared with conflicting shapes")
            }
            SpecError::BadProgram { layer, why } => write!(f, "layer {layer}: {why}"),
            SpecError::DuplicateName(name) => {
                write!(f, "model {name:?} is already registered")
            }
            SpecError::Parse(msg) => write!(f, "model spec parse error: {msg}"),
        }
    }
}

impl std::error::Error for SpecError {}

// ---------------------------------------------------------------------------
// Spec types + builder
// ---------------------------------------------------------------------------

/// One program of a layer, pre-validation. Field-for-field the shape of
/// the executable [`Program`]; the builder methods give it a fluent
/// construction surface and [`ModelSpec::compile`] checks it.
#[derive(Debug, Clone)]
pub struct ProgramSpec {
    pub name: String,
    pub domain: Domain,
    pub source: Src,
    pub gather: GatherOp,
    pub reduce: ReduceOp,
    pub self_scale: Option<SelfScale>,
    pub transform: Option<MatMul>,
    pub add_program: Option<usize>,
    pub activate: Activate,
}

impl ProgramSpec {
    /// A program with the most common defaults: edge domain over the
    /// layer input, identity gather, sum reduce, no transform, no
    /// activation.
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            domain: Domain::Edges,
            source: Src::LayerInput,
            gather: GatherOp::Identity,
            reduce: ReduceOp::Sum,
            self_scale: None,
            transform: None,
            add_program: None,
            activate: Activate::None,
        }
    }

    pub fn domain(mut self, d: Domain) -> Self {
        self.domain = d;
        self
    }

    pub fn source(mut self, s: Src) -> Self {
        self.source = s;
        self
    }

    /// Source the features from an earlier program's output.
    pub fn source_program(self, k: usize) -> Self {
        self.source(Src::Program(k))
    }

    pub fn gather(mut self, g: GatherOp) -> Self {
        self.gather = g;
        self
    }

    pub fn reduce(mut self, r: ReduceOp) -> Self {
        self.reduce = r;
        self
    }

    pub fn self_scale(mut self, s: SelfScale) -> Self {
        self.self_scale = Some(s);
        self
    }

    /// Vertex-accumulate matmul with a named weight.
    pub fn transform(mut self, weight: impl Into<String>, in_dim: usize, out_dim: usize) -> Self {
        self.transform = Some(MatMul { weight: weight.into(), in_dim, out_dim });
        self
    }

    /// Accumulate program `k`'s output before activation (Fig. 4 plus-box).
    pub fn add_program(mut self, k: usize) -> Self {
        self.add_program = Some(k);
        self
    }

    pub fn activate(mut self, a: Activate) -> Self {
        self.activate = a;
        self
    }

    fn lower(&self) -> Program {
        Program {
            name: self.name.clone(),
            domain: self.domain,
            source: self.source,
            gather: self.gather,
            reduce: self.reduce,
            self_scale: self.self_scale.clone(),
            transform: self.transform.clone(),
            add_program: self.add_program,
            activate: self.activate,
        }
    }
}

/// One message-passing layer of a spec.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub in_dim: usize,
    pub out_dim: usize,
    /// Neighbor-sampling fan-out used when building nodeflows for this
    /// layer (`None` → the serving `ModelConfig` default by position:
    /// `sample1` for layer 0, `sample2` after).
    pub sample: Option<usize>,
    pub programs: Vec<ProgramSpec>,
    /// Which program's result is the layer output Z (default: last).
    pub output_program: Option<usize>,
}

impl LayerSpec {
    pub fn new(in_dim: usize, out_dim: usize) -> Self {
        Self { in_dim, out_dim, sample: None, programs: Vec::new(), output_program: None }
    }

    pub fn sample(mut self, s: usize) -> Self {
        self.sample = Some(s);
        self
    }

    pub fn program(mut self, p: ProgramSpec) -> Self {
        self.programs.push(p);
        self
    }

    pub fn output_program(mut self, k: usize) -> Self {
        self.output_program = Some(k);
        self
    }
}

/// A complete model description: named, arbitrary depth.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub layers: Vec<LayerSpec>,
}

/// Fluent constructor for [`ModelSpec`].
pub struct ModelSpecBuilder {
    name: String,
    layers: Vec<LayerSpec>,
}

impl ModelSpecBuilder {
    pub fn layer(mut self, l: LayerSpec) -> Self {
        self.layers.push(l);
        self
    }

    pub fn build(self) -> ModelSpec {
        ModelSpec { name: self.name, layers: self.layers }
    }
}

// Row domain of a program's result: U input rows or V output rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Rows {
    U,
    V,
}

impl ModelSpec {
    pub fn builder(name: impl Into<String>) -> ModelSpecBuilder {
        ModelSpecBuilder { name: name.into(), layers: Vec::new() }
    }

    /// Number of message-passing layers.
    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    /// Validate and lower the spec into an executable [`ModelPlan`].
    ///
    /// Checks, in order: non-empty layers/programs, the inter-layer
    /// dimension chain, back-reference discipline (sources, gather
    /// operands, and `add_program` must reference strictly earlier
    /// programs), gather-operand row/dim compatibility, transform
    /// input dims, weight-shape consistency across the whole model, and
    /// that each layer's output program yields `[V × out_dim]`.
    pub fn compile(&self) -> Result<ModelPlan, SpecError> {
        if self.layers.is_empty() {
            return Err(SpecError::Empty("layers".into()));
        }
        for (li, w) in self.layers.windows(2).enumerate() {
            if w[0].out_dim != w[1].in_dim {
                return Err(SpecError::LayerChain {
                    layer: li,
                    out_dim: w[0].out_dim,
                    next_in_dim: w[1].in_dim,
                });
            }
        }
        let mut weights: HashMap<&str, (usize, usize)> = HashMap::new();
        let mut layers = Vec::with_capacity(self.layers.len());
        for (li, ls) in self.layers.iter().enumerate() {
            layers.push(compile_layer(li, ls, &mut weights)?);
        }
        Ok(ModelPlan { name: self.name.clone(), layers })
    }

    /// Parse a spec from JSON text (see `examples/MODEL_SPEC.md` for the
    /// schema). Parsing alone does not validate program structure — call
    /// [`ModelSpec::compile`] (or register with a [`ModelLibrary`]) to
    /// validate.
    pub fn from_json_str(text: &str) -> Result<ModelSpec, SpecError> {
        let v = parse(text).map_err(SpecError::Parse)?;
        ModelSpec::from_json(&v)
    }

    /// Parse a spec from an already-parsed [`Json`] value.
    pub fn from_json(v: &Json) -> Result<ModelSpec, SpecError> {
        let obj = as_obj(v, "model spec")?;
        check_keys(obj, &["name", "layers"], "model spec")?;
        let name = req_str(obj, "name", "model spec")?;
        let layers_json = obj
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or_else(|| perr("model spec: \"layers\" must be an array"))?;
        let mut layers = Vec::with_capacity(layers_json.len());
        for (li, lj) in layers_json.iter().enumerate() {
            layers.push(layer_from_json(li, lj)?);
        }
        Ok(ModelSpec { name, layers })
    }
}

fn compile_layer<'a>(
    li: usize,
    ls: &'a LayerSpec,
    weights: &mut HashMap<&'a str, (usize, usize)>,
) -> Result<LayerPlan, SpecError> {
    if ls.programs.is_empty() {
        return Err(SpecError::Empty(format!("programs in layer {li}")));
    }
    if ls.in_dim == 0 || ls.out_dim == 0 {
        return Err(SpecError::BadProgram { layer: li, why: "zero layer dimension".into() });
    }
    // (rows, dim) of every already-validated program of this layer.
    let mut shapes: Vec<(Rows, usize)> = Vec::with_capacity(ls.programs.len());
    for (pi, p) in ls.programs.iter().enumerate() {
        let back_ref = |what: &'static str, k: usize| -> Result<(Rows, usize), SpecError> {
            if k < pi {
                Ok(shapes[k])
            } else {
                Err(SpecError::Dangling { layer: li, program: pi, what, reference: k })
            }
        };

        // Feature source.
        let (src_rows, src_dim) = match p.source {
            Src::LayerInput => (Rows::U, ls.in_dim),
            Src::Program(k) => back_ref("source", k)?,
        };
        // Edge iteration indexes the source by input-vertex id, so an
        // edge-domain program cannot read a V-rowed source.
        if p.domain == Domain::Edges && src_rows != Rows::U {
            return Err(SpecError::BadProgram {
                layer: li,
                why: format!(
                    "program {pi} ({:?}) gathers over edges from a source with output-vertex \
                     rows; edge sources must cover all input vertices",
                    p.name
                ),
            });
        }

        // Gather operands are also indexed by input-vertex id.
        match p.gather {
            GatherOp::ProductWith(k) => {
                let (rows, dim) = back_ref("gather operand", k)?;
                if rows != Rows::U {
                    return Err(SpecError::BadProgram {
                        layer: li,
                        why: format!(
                            "program {pi}: gather operand {k} must be a per-input program"
                        ),
                    });
                }
                // dim 1 broadcasts (scalar gate), otherwise must match.
                if dim != 1 && dim != src_dim {
                    return Err(SpecError::DimMismatch {
                        layer: li,
                        program: p.name.clone(),
                        what: "gather operand",
                        expected: src_dim,
                        got: dim,
                    });
                }
            }
            GatherOp::SumWith(k) => {
                let (rows, dim) = back_ref("gather operand", k)?;
                if rows != Rows::U {
                    return Err(SpecError::BadProgram {
                        layer: li,
                        why: format!(
                            "program {pi}: gather operand {k} must be a per-input program"
                        ),
                    });
                }
                if dim != src_dim {
                    return Err(SpecError::DimMismatch {
                        layer: li,
                        program: p.name.clone(),
                        what: "gather operand",
                        expected: src_dim,
                        got: dim,
                    });
                }
            }
            GatherOp::Identity | GatherOp::Scale(_) => {}
        }

        // Edge-accumulate result shape.
        let acc_rows = match p.domain {
            Domain::AllInputs => src_rows,
            Domain::Edges | Domain::Outputs => Rows::V,
        };

        // Vertex-accumulate transform.
        let dim = if let Some(t) = &p.transform {
            if t.in_dim == 0 || t.out_dim == 0 {
                return Err(SpecError::BadProgram {
                    layer: li,
                    why: format!("program {pi}: zero transform dimension"),
                });
            }
            if t.in_dim != src_dim {
                return Err(SpecError::DimMismatch {
                    layer: li,
                    program: p.name.clone(),
                    what: "transform in_dim",
                    expected: src_dim,
                    got: t.in_dim,
                });
            }
            match weights.get(t.weight.as_str()) {
                Some(&shape) if shape != (t.in_dim, t.out_dim) => {
                    return Err(SpecError::WeightConflict { weight: t.weight.clone() });
                }
                Some(_) => {}
                None => {
                    weights.insert(t.weight.as_str(), (t.in_dim, t.out_dim));
                }
            }
            t.out_dim
        } else {
            src_dim
        };

        // Vertex-accumulator chaining.
        if let Some(k) = p.add_program {
            let (rows, adim) = back_ref("add_program", k)?;
            if adim != dim {
                return Err(SpecError::DimMismatch {
                    layer: li,
                    program: p.name.clone(),
                    what: "add_program operand",
                    expected: dim,
                    got: adim,
                });
            }
            // The operand needs at least as many rows as this result;
            // V-rowed operands cannot feed a U-rowed accumulator.
            if acc_rows == Rows::U && rows == Rows::V {
                return Err(SpecError::BadProgram {
                    layer: li,
                    why: format!(
                        "program {pi}: add_program {k} has output-vertex rows but this \
                         program accumulates over all inputs"
                    ),
                });
            }
        }

        shapes.push((acc_rows, dim));
    }

    // Layer output contract: [V × out_dim].
    let output_program = ls.output_program.unwrap_or(ls.programs.len() - 1);
    let Some(&(rows, dim)) = shapes.get(output_program) else {
        return Err(SpecError::BadProgram {
            layer: li,
            why: format!(
                "output_program {output_program} out of range ({} programs)",
                ls.programs.len()
            ),
        });
    };
    if rows != Rows::V {
        return Err(SpecError::BadProgram {
            layer: li,
            why: format!(
                "output program {output_program} produces one row per *input* vertex; the \
                 layer output needs one row per output vertex (domain edges/outputs)"
            ),
        });
    }
    if dim != ls.out_dim {
        return Err(SpecError::DimMismatch {
            layer: li,
            program: ls.programs[output_program].name.clone(),
            what: "layer output",
            expected: ls.out_dim,
            got: dim,
        });
    }

    Ok(LayerPlan {
        programs: ls.programs.iter().map(ProgramSpec::lower).collect(),
        output_program,
        in_dim: ls.in_dim,
        out_dim: ls.out_dim,
    })
}

// ---------------------------------------------------------------------------
// JSON decoding
// ---------------------------------------------------------------------------

fn perr(msg: impl Into<String>) -> SpecError {
    SpecError::Parse(msg.into())
}

fn as_obj<'a>(v: &'a Json, ctx: &str) -> Result<&'a HashMap<String, Json>, SpecError> {
    v.as_obj().ok_or_else(|| perr(format!("{ctx}: expected an object")))
}

/// Reject unknown keys (typo detection) except `_`-prefixed ones, which
/// serve as inline comments — JSON has no comment syntax.
fn check_keys(
    obj: &HashMap<String, Json>,
    allowed: &[&str],
    ctx: &str,
) -> Result<(), SpecError> {
    for k in obj.keys() {
        if !k.starts_with('_') && !allowed.contains(&k.as_str()) {
            return Err(perr(format!(
                "{ctx}: unknown key {k:?} (allowed: {allowed:?}; prefix with '_' for comments)"
            )));
        }
    }
    Ok(())
}

/// Tagged-union objects must name exactly one variant — two variants at
/// once would otherwise silently resolve to whichever is checked first.
fn check_one_variant(
    obj: &HashMap<String, Json>,
    variants: &[&str],
    what: &str,
    ctx: &str,
) -> Result<(), SpecError> {
    let present: Vec<&str> =
        variants.iter().copied().filter(|v| obj.contains_key(*v)).collect();
    if present.len() != 1 {
        return Err(perr(format!(
            "{ctx}: {what} must name exactly one of {variants:?} (found {present:?})"
        )));
    }
    Ok(())
}

fn req_str(obj: &HashMap<String, Json>, key: &str, ctx: &str) -> Result<String, SpecError> {
    obj.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| perr(format!("{ctx}: missing string {key:?}")))
}

/// Strict non-negative integer: `Json::as_usize` would truncate 4.5 to
/// 4 and saturate -1 to 0 — silent spec corruption in a parser that
/// otherwise rejects typos loudly.
fn json_strict_usize(v: &Json) -> Option<usize> {
    let n = v.as_f64()?;
    (n.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(&n)).then_some(n as usize)
}

fn req_usize(obj: &HashMap<String, Json>, key: &str, ctx: &str) -> Result<usize, SpecError> {
    obj.get(key)
        .and_then(json_strict_usize)
        .ok_or_else(|| perr(format!("{ctx}: {key:?} must be a non-negative integer")))
}

fn opt_usize(
    obj: &HashMap<String, Json>,
    key: &str,
    ctx: &str,
) -> Result<Option<usize>, SpecError> {
    match obj.get(key) {
        None => Ok(None),
        Some(v) => json_strict_usize(v)
            .map(Some)
            .ok_or_else(|| perr(format!("{ctx}: {key:?} must be a non-negative integer"))),
    }
}

fn layer_from_json(li: usize, v: &Json) -> Result<LayerSpec, SpecError> {
    let ctx = format!("layer {li}");
    let obj = as_obj(v, &ctx)?;
    check_keys(obj, &["in_dim", "out_dim", "sample", "programs", "output_program"], &ctx)?;
    let mut layer =
        LayerSpec::new(req_usize(obj, "in_dim", &ctx)?, req_usize(obj, "out_dim", &ctx)?);
    layer.sample = opt_usize(obj, "sample", &ctx)?;
    layer.output_program = opt_usize(obj, "output_program", &ctx)?;
    let programs = obj
        .get("programs")
        .and_then(Json::as_arr)
        .ok_or_else(|| perr(format!("{ctx}: \"programs\" must be an array")))?;
    for (pi, pj) in programs.iter().enumerate() {
        layer.programs.push(program_from_json(li, pi, pj)?);
    }
    Ok(layer)
}

fn program_from_json(li: usize, pi: usize, v: &Json) -> Result<ProgramSpec, SpecError> {
    let ctx = format!("layer {li} program {pi}");
    let obj = as_obj(v, &ctx)?;
    check_keys(
        obj,
        &[
            "name", "domain", "source", "gather", "reduce", "self_scale", "transform",
            "add_program", "activate",
        ],
        &ctx,
    )?;
    let name = match obj.get("name") {
        Some(v) => v
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| perr(format!("{ctx}: \"name\" must be a string")))?,
        None => format!("l{li}p{pi}"),
    };
    let mut p = ProgramSpec::new(name);

    if let Some(d) = obj.get("domain") {
        p.domain = match d.as_str() {
            Some("edges") => Domain::Edges,
            Some("all_inputs") => Domain::AllInputs,
            Some("outputs") => Domain::Outputs,
            _ => return Err(perr(format!("{ctx}: domain must be edges|all_inputs|outputs"))),
        };
    }
    if let Some(s) = obj.get("source") {
        p.source = match s {
            Json::Str(tag) if tag == "layer_input" => Src::LayerInput,
            Json::Obj(m) => {
                check_keys(m, &["program"], &ctx)?;
                Src::Program(req_usize(m, "program", &ctx)?)
            }
            _ => {
                return Err(perr(format!(
                    "{ctx}: source must be \"layer_input\" or {{\"program\": k}}"
                )))
            }
        };
    }
    if let Some(g) = obj.get("gather") {
        p.gather = match g {
            Json::Str(tag) if tag == "identity" => GatherOp::Identity,
            Json::Obj(m) => {
                check_keys(m, &["product_with", "sum_with", "scale"], &ctx)?;
                check_one_variant(m, &["product_with", "sum_with", "scale"], "gather", &ctx)?;
                if let Some(k) = m.get("product_with").and_then(json_strict_usize) {
                    GatherOp::ProductWith(k)
                } else if let Some(k) = m.get("sum_with").and_then(json_strict_usize) {
                    GatherOp::SumWith(k)
                } else if let Some(c) = m.get("scale").and_then(Json::as_f64) {
                    GatherOp::Scale(c as f32)
                } else {
                    return Err(perr(format!(
                        "{ctx}: gather object must be {{\"product_with\"|\"sum_with\": k}} or \
                         {{\"scale\": x}}"
                    )));
                }
            }
            _ => return Err(perr(format!("{ctx}: bad gather"))),
        };
    }
    if let Some(r) = obj.get("reduce") {
        p.reduce = match r.as_str() {
            Some("sum") => ReduceOp::Sum,
            Some("max") => ReduceOp::Max,
            Some("mean") => ReduceOp::Mean,
            _ => return Err(perr(format!("{ctx}: reduce must be sum|max|mean"))),
        };
    }
    if let Some(s) = obj.get("self_scale") {
        let m = as_obj(s, &ctx)?;
        check_keys(m, &["one_plus_arg", "const"], &ctx)?;
        check_one_variant(m, &["one_plus_arg", "const"], "self_scale", &ctx)?;
        p.self_scale = if let Some(arg) = m.get("one_plus_arg").and_then(Json::as_str) {
            Some(SelfScale::OnePlusArg(arg.to_string()))
        } else if let Some(c) = m.get("const").and_then(Json::as_f64) {
            Some(SelfScale::Const(c as f32))
        } else {
            return Err(perr(format!(
                "{ctx}: self_scale must be {{\"one_plus_arg\": name}} or {{\"const\": x}}"
            )));
        };
    }
    if let Some(t) = obj.get("transform") {
        let m = as_obj(t, &ctx)?;
        check_keys(m, &["weight", "in_dim", "out_dim"], &ctx)?;
        p.transform = Some(MatMul {
            weight: req_str(m, "weight", &ctx)?,
            in_dim: req_usize(m, "in_dim", &ctx)?,
            out_dim: req_usize(m, "out_dim", &ctx)?,
        });
    }
    p.add_program = opt_usize(obj, "add_program", &ctx)?;
    if let Some(a) = obj.get("activate") {
        p.activate = match a.as_str() {
            Some("none") => Activate::None,
            Some("relu") => Activate::Relu,
            Some("sigmoid") => Activate::Sigmoid,
            _ => return Err(perr(format!("{ctx}: activate must be none|relu|sigmoid"))),
        };
    }
    Ok(p)
}

// ---------------------------------------------------------------------------
// Model library / keys
// ---------------------------------------------------------------------------

/// A cheap, `Copy` reference to a model registered in a
/// [`ModelLibrary`] — what [`crate::coordinator::InferenceRequest`],
/// the SLO batcher, and the load generator carry. The four paper
/// presets always occupy keys `0..4` (in [`ALL_MODELS`] order), so
/// `GnnModel::Gcn.key()` / `ModelKey::from(GnnModel::Gcn)` are valid
/// against every library; custom specs follow in registration order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelKey(u16);

impl ModelKey {
    pub fn index(self) -> usize {
        self.0 as usize
    }

    pub fn from_index(i: usize) -> ModelKey {
        ModelKey(u16::try_from(i).expect("model library holds < 65536 models"))
    }
}

impl From<GnnModel> for ModelKey {
    fn from(m: GnnModel) -> ModelKey {
        ModelKey(ALL_MODELS.iter().position(|&x| x == m).expect("preset in ALL_MODELS") as u16)
    }
}

/// One registered model: the source spec, the compiled plan, and the
/// per-layer sampling fan-outs its nodeflows are built with.
#[derive(Debug)]
pub struct ModelEntry {
    pub spec: ModelSpec,
    pub plan: ModelPlan,
    pub samples: Vec<usize>,
}

/// The set of models a serving stack can execute: the four paper
/// presets (always, keys `0..4`) plus registered custom specs. Compiled
/// once at registration — the request path only indexes.
#[derive(Debug)]
pub struct ModelLibrary {
    mc: ModelConfig,
    entries: Vec<ModelEntry>,
    by_name: HashMap<String, ModelKey>,
}

impl ModelLibrary {
    /// A library holding exactly the four paper presets compiled for
    /// `mc`'s dims and sampling.
    pub fn presets(mc: &ModelConfig) -> ModelLibrary {
        let mut lib =
            ModelLibrary { mc: *mc, entries: Vec::new(), by_name: HashMap::new() };
        for m in ALL_MODELS {
            lib.register(m.spec(mc)).expect("paper preset specs are valid");
        }
        lib
    }

    /// The presets plus `specs`, with the key assigned to each spec —
    /// exactly the library a coordinator configured with these
    /// `custom_specs` will serve. The single home of the "presets
    /// first, customs in list order" key contract: callers that need a
    /// spec's key *before* starting a coordinator (CLI, harnesses) use
    /// this instead of re-deriving the ordering.
    pub fn with_customs(
        mc: &ModelConfig,
        specs: &[ModelSpec],
    ) -> Result<(ModelLibrary, Vec<ModelKey>), SpecError> {
        let mut lib = ModelLibrary::presets(mc);
        let keys = specs
            .iter()
            .map(|s| lib.register(s.clone()))
            .collect::<Result<Vec<_>, _>>()?;
        Ok((lib, keys))
    }

    /// Validate, compile, and register a spec; returns its key. Layer
    /// sampling defaults to the library `ModelConfig` by position when
    /// the spec leaves `sample` unset.
    pub fn register(&mut self, spec: ModelSpec) -> Result<ModelKey, SpecError> {
        if self.by_name.contains_key(&spec.name) {
            return Err(SpecError::DuplicateName(spec.name.clone()));
        }
        let plan = spec.compile()?;
        let samples = spec
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                l.sample.unwrap_or(if i == 0 { self.mc.sample1 } else { self.mc.sample2 })
            })
            .collect();
        let key = ModelKey::from_index(self.entries.len());
        self.by_name.insert(spec.name.clone(), key);
        self.entries.push(ModelEntry { spec, plan, samples });
        Ok(key)
    }

    pub fn contains(&self, key: ModelKey) -> bool {
        key.index() < self.entries.len()
    }

    pub fn plan(&self, key: ModelKey) -> &ModelPlan {
        &self.entries[key.index()].plan
    }

    pub fn spec(&self, key: ModelKey) -> &ModelSpec {
        &self.entries[key.index()].spec
    }

    /// Per-layer sampling fan-outs for nodeflow construction.
    pub fn samples(&self, key: ModelKey) -> &[usize] {
        &self.entries[key.index()].samples
    }

    pub fn name(&self, key: ModelKey) -> &str {
        &self.entries[key.index()].spec.name
    }

    pub fn key(&self, name: &str) -> Option<ModelKey> {
        self.by_name.get(name).copied()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn keys(&self) -> impl Iterator<Item = ModelKey> + '_ {
        (0..self.entries.len()).map(ModelKey::from_index)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mc() -> ModelConfig {
        ModelConfig { sample1: 4, sample2: 3, f_in: 12, f_hid: 10, f_out: 6 }
    }

    #[test]
    fn presets_compile_and_keys_are_stable() {
        let lib = ModelLibrary::presets(&mc());
        assert_eq!(lib.len(), 4);
        for m in ALL_MODELS {
            let key = m.key();
            assert_eq!(lib.name(key), m.name());
            assert_eq!(lib.key(m.name()), Some(key));
            assert_eq!(lib.samples(key), &[4, 3]);
        }
    }

    #[test]
    fn builder_three_layer_spec_compiles() {
        let spec = ModelSpec::builder("deep")
            .layer(
                LayerSpec::new(8, 6)
                    .sample(3)
                    .program(
                        ProgramSpec::new("l0")
                            .reduce(ReduceOp::Mean)
                            .transform("d0", 8, 6)
                            .activate(Activate::Relu),
                    ),
            )
            .layer(LayerSpec::new(6, 5).sample(2).program(
                ProgramSpec::new("l1").transform("d1", 6, 5).activate(Activate::Relu),
            ))
            .layer(LayerSpec::new(5, 4).sample(2).program(
                ProgramSpec::new("l2").transform("d2", 5, 4).activate(Activate::Relu),
            ))
            .build();
        let plan = spec.compile().unwrap();
        assert_eq!(plan.layers.len(), 3);
        assert_eq!(plan.name, "deep");
        assert_eq!(plan.weight_names(), vec!["d0", "d1", "d2"]);
        let mut lib = ModelLibrary::presets(&mc());
        let key = lib.register(spec).unwrap();
        assert_eq!(key.index(), 4, "customs follow the presets");
        assert_eq!(lib.samples(key), &[3, 2, 2]);
    }

    #[test]
    fn dangling_source_rejected() {
        let spec = ModelSpec::builder("bad")
            .layer(LayerSpec::new(4, 4).program(
                ProgramSpec::new("p").source_program(0).transform("w", 4, 4),
            ))
            .build();
        let err = spec.compile().unwrap_err();
        assert!(matches!(err, SpecError::Dangling { what: "source", reference: 0, .. }), "{err}");
    }

    #[test]
    fn transform_dim_mismatch_rejected() {
        let spec = ModelSpec::builder("bad")
            .layer(LayerSpec::new(4, 4).program(ProgramSpec::new("p").transform("w", 5, 4)))
            .build();
        let err = spec.compile().unwrap_err();
        assert!(
            matches!(
                err,
                SpecError::DimMismatch { what: "transform in_dim", expected: 4, got: 5, .. }
            ),
            "{err}"
        );
    }

    #[test]
    fn layer_chain_mismatch_rejected() {
        let spec = ModelSpec::builder("bad")
            .layer(LayerSpec::new(4, 4).program(ProgramSpec::new("a").transform("w0", 4, 4)))
            .layer(LayerSpec::new(5, 3).program(ProgramSpec::new("b").transform("w1", 5, 3)))
            .build();
        assert!(matches!(spec.compile().unwrap_err(), SpecError::LayerChain { .. }));
    }

    #[test]
    fn weight_shape_conflict_rejected() {
        let spec = ModelSpec::builder("bad")
            .layer(
                LayerSpec::new(4, 3)
                    .program(ProgramSpec::new("a").domain(Domain::AllInputs).transform("w", 4, 3))
                    .program(ProgramSpec::new("b").transform("w", 4, 4))
                    .output_program(0),
            )
            .build();
        // Program b's transform in_dim matches (4) but redeclares "w"
        // at 4x4 vs a's 4x3.
        let err = spec.compile().unwrap_err();
        assert!(matches!(err, SpecError::WeightConflict { .. }), "{err}");
    }

    #[test]
    fn all_inputs_output_program_rejected() {
        let spec = ModelSpec::builder("bad")
            .layer(LayerSpec::new(4, 4).program(
                ProgramSpec::new("p").domain(Domain::AllInputs).transform("w", 4, 4),
            ))
            .build();
        let err = spec.compile().unwrap_err();
        assert!(matches!(err, SpecError::BadProgram { .. }), "{err}");
    }

    #[test]
    fn json_round_trip_matches_builder() {
        let text = r#"{
            "_doc": "two-layer mean-aggregate model",
            "name": "tiny",
            "layers": [
                {"in_dim": 6, "out_dim": 4, "sample": 3, "programs": [
                    {"name": "agg", "reduce": "mean",
                     "transform": {"weight": "w1", "in_dim": 6, "out_dim": 4},
                     "activate": "relu"}
                ]},
                {"in_dim": 4, "out_dim": 2, "programs": [
                    {"reduce": "mean",
                     "transform": {"weight": "w2", "in_dim": 4, "out_dim": 2},
                     "activate": "relu"}
                ]}
            ]
        }"#;
        let spec = ModelSpec::from_json_str(text).unwrap();
        assert_eq!(spec.name, "tiny");
        assert_eq!(spec.layers[0].sample, Some(3));
        let plan = spec.compile().unwrap();
        assert_eq!(plan.weight_names(), vec!["w1", "w2"]);
        assert_eq!(plan.layers[1].programs[0].name, "l1p0", "default program name");
    }

    #[test]
    fn json_unknown_key_rejected_but_comments_pass() {
        let bad = r#"{"name": "x", "layerz": []}"#;
        let err = ModelSpec::from_json_str(bad).unwrap_err();
        assert!(err.to_string().contains("unknown key"), "{err}");
        let ok = r#"{"name": "x", "_note": "fine", "layers": []}"#;
        assert!(ModelSpec::from_json_str(ok).is_ok());
    }

    #[test]
    fn json_rejects_non_integer_dims() {
        for layer in [
            r#"{"in_dim":4.5,"out_dim":2,"programs":[{}]}"#,
            r#"{"in_dim":4,"out_dim":-1,"programs":[{}]}"#,
            r#"{"in_dim":4,"out_dim":2,"sample":2.5,"programs":[{}]}"#,
        ] {
            let text = format!(r#"{{"name":"x","layers":[{layer}]}}"#);
            let err = ModelSpec::from_json_str(&text).unwrap_err();
            assert!(err.to_string().contains("non-negative integer"), "{layer}: {err}");
        }
    }

    #[test]
    fn json_bad_tags_rejected() {
        for (program, what) in [
            (r#"{"domain":"loops"}"#, "domain"),
            (r#"{"reduce":"avg"}"#, "reduce"),
            (r#"{"activate":"tanh"}"#, "activate"),
            (r#"{"gather":{"mystery":1}}"#, "mystery"),
            (r#"{"source":"programs"}"#, "source"),
        ] {
            let text = format!(
                r#"{{"name":"x","layers":[{{"in_dim":2,"out_dim":2,"programs":[{program}]}}]}}"#
            );
            let err = ModelSpec::from_json_str(&text).unwrap_err();
            assert!(err.to_string().contains(what), "{what}: {err}");
        }
    }

    #[test]
    fn json_ambiguous_or_unknown_variant_objects_rejected() {
        for (program, what) in [
            // Two variants at once must not silently pick one.
            (r#"{"gather":{"product_with":0,"sum_with":1}}"#, "exactly one"),
            (r#"{"self_scale":{"one_plus_arg":"e","const":2.0}}"#, "exactly one"),
            // Unknown keys inside nested objects are typos, not comments.
            (r#"{"source":{"program":1,"programs":2}}"#, "unknown key"),
            (r#"{"gather":{"scale_by":2.0}}"#, "unknown key"),
        ] {
            let text = format!(
                r#"{{"name":"x","layers":[{{"in_dim":2,"out_dim":2,"programs":[{program}]}}]}}"#
            );
            let err = ModelSpec::from_json_str(&text).unwrap_err();
            assert!(err.to_string().contains(what), "{program}: {err}");
        }
    }

    #[test]
    fn duplicate_registration_rejected() {
        let mut lib = ModelLibrary::presets(&mc());
        let err = lib.register(GnnModel::Gcn.spec(&mc())).unwrap_err();
        assert!(matches!(err, SpecError::DuplicateName(_)));
    }
}
