//! GRIP programs and the model compiler (paper Sec. IV-A, Fig. 3/4).
//!
//! Each [`Program`] is one pass of the three GReTA phases over a domain;
//! a [`LayerPlan`] is the program sequence implementing one
//! message-passing layer; a [`ModelPlan`] is the full 2-layer model. The
//! compiler output feeds both the functional executor (`exec.rs`) and
//! the cycle simulator (`crate::sim`), so the cost model and the
//! numerics always agree on program structure.

use super::ops::{Activate, Domain, GatherOp, ReduceOp, SelfScale};
use crate::config::ModelConfig;

/// The four GNN models evaluated by the paper (Sec. VII).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GnnModel {
    Gcn,
    Sage,
    Gin,
    Ggcn,
}

pub const ALL_MODELS: [GnnModel; 4] = [GnnModel::Gcn, GnnModel::Sage, GnnModel::Gin, GnnModel::Ggcn];

impl GnnModel {
    pub fn name(&self) -> &'static str {
        match self {
            GnnModel::Gcn => "gcn",
            GnnModel::Sage => "sage",
            GnnModel::Gin => "gin",
            GnnModel::Ggcn => "ggcn",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "gcn" => Some(GnnModel::Gcn),
            "sage" | "gs" | "graphsage" => Some(GnnModel::Sage),
            "gin" => Some(GnnModel::Gin),
            "ggcn" | "g-gcn" => Some(GnnModel::Ggcn),
            _ => None,
        }
    }
}

/// Transform UDF: matrix multiply with a named weight (paper: transform
/// is the only UDF with weight access).
#[derive(Debug, Clone)]
pub struct MatMul {
    /// Manifest parameter name (resolved by the runtime/executor).
    pub weight: &'static str,
    pub in_dim: usize,
    pub out_dim: usize,
}

/// One GRIP program (paper Alg. 2 semantics).
#[derive(Debug, Clone)]
pub struct Program {
    pub name: &'static str,
    pub domain: Domain,
    /// Feature source: the layer's input features or a previous
    /// program's output (program composition, Fig. 4 plus-boxes).
    pub source: Src,
    pub gather: GatherOp,
    pub reduce: ReduceOp,
    /// Self-contribution folded into the edge accumulator (GIN).
    pub self_scale: Option<SelfScale>,
    /// Vertex-accumulate transform; `None` for pure edge programs.
    pub transform: Option<MatMul>,
    /// Accumulate another program's output into the vertex accumulator
    /// before activation (rows must match this program's domain rows).
    pub add_program: Option<usize>,
    pub activate: Activate,
}

/// Feature source of a program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Src {
    /// The layer's input feature matrix H (U rows).
    LayerInput,
    /// Output of a previous program in the same layer plan.
    Program(usize),
}

/// Program sequence for one message-passing layer. `output_program`
/// names which program's result is the layer output Z.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    pub programs: Vec<Program>,
    pub output_program: usize,
    pub in_dim: usize,
    pub out_dim: usize,
}

/// Compiled model: one plan per layer, outermost (largest U) first.
#[derive(Debug, Clone)]
pub struct ModelPlan {
    pub model: GnnModel,
    pub layers: Vec<LayerPlan>,
}

impl ModelPlan {
    /// Total weight bytes across all transforms (drives weight-load time
    /// and the Table II global-weight-buffer sizing).
    pub fn weight_bytes(&self, elem_bytes: usize) -> usize {
        self.layers
            .iter()
            .flat_map(|l| l.programs.iter())
            .filter_map(|p| p.transform.as_ref())
            .map(|t| t.in_dim * t.out_dim * elem_bytes)
            .sum()
    }

    /// Names of all weight parameters in execution order.
    pub fn weight_names(&self) -> Vec<&'static str> {
        self.layers
            .iter()
            .flat_map(|l| l.programs.iter())
            .filter_map(|p| p.transform.as_ref().map(|t| t.weight))
            .collect()
    }
}

/// Compile a model to its GRIP program sequence (Fig. 4).
pub fn compile(model: GnnModel, mc: &ModelConfig) -> ModelPlan {
    let dims = mc.layers();
    let layers = dims
        .iter()
        .enumerate()
        .map(|(i, &(_, in_dim, out_dim))| compile_layer(model, i, in_dim, mc.f_hid, out_dim))
        .collect();
    ModelPlan { model, layers }
}

fn compile_layer(model: GnnModel, layer: usize, in_dim: usize, mid: usize, out_dim: usize) -> LayerPlan {
    // Weight names match python/compile/model.py::param_names.
    macro_rules! w {
        ($a:expr, $b:expr) => {
            if layer == 0 {
                $a
            } else {
                $b
            }
        };
    }
    let programs = match model {
        // Z = relu((Â_mean H) W) — single program, the canonical case.
        GnnModel::Gcn => vec![Program {
            name: "gcn",
            domain: Domain::Edges,
            source: Src::LayerInput,
            gather: GatherOp::Identity,
            reduce: ReduceOp::Mean,
            self_scale: None,
            transform: Some(MatMul { weight: w!("w1", "w2"), in_dim, out_dim }),
            add_program: None,
            activate: Activate::Relu,
        }],

        // a_v = max_u relu(h_u W_pool); z = relu(h_v W_s + a_v W_n).
        GnnModel::Sage => vec![
            Program {
                name: "sage-pool",
                domain: Domain::AllInputs,
                source: Src::LayerInput,
                gather: GatherOp::Identity,
                reduce: ReduceOp::Sum,
                self_scale: None,
                transform: Some(MatMul { weight: w!("wp1", "wp2"), in_dim, out_dim: mid }),
                add_program: None,
                activate: Activate::Relu,
            },
            Program {
                name: "sage-agg",
                domain: Domain::Edges,
                source: Src::Program(0),
                gather: GatherOp::Identity,
                reduce: ReduceOp::Max,
                self_scale: None,
                transform: Some(MatMul { weight: w!("wn1", "wn2"), in_dim: mid, out_dim }),
                add_program: None,
                activate: Activate::None,
            },
            Program {
                name: "sage-update",
                domain: Domain::Outputs,
                source: Src::LayerInput,
                gather: GatherOp::Identity,
                reduce: ReduceOp::Sum,
                self_scale: None,
                transform: Some(MatMul { weight: w!("ws1", "ws2"), in_dim, out_dim }),
                add_program: Some(1),
                activate: Activate::Relu,
            },
        ],

        // z = relu(W2 relu(W1 ((1+eps) h_v + Σ h_u))).
        GnnModel::Gin => vec![
            Program {
                name: "gin-agg",
                domain: Domain::Edges,
                source: Src::LayerInput,
                gather: GatherOp::Identity,
                reduce: ReduceOp::Sum,
                self_scale: Some(SelfScale::OnePlusArg(w!("eps1", "eps2"))),
                transform: Some(MatMul { weight: w!("w1a", "w2a"), in_dim, out_dim: mid }),
                add_program: None,
                activate: Activate::Relu,
            },
            Program {
                name: "gin-mlp2",
                domain: Domain::Outputs,
                source: Src::Program(0),
                gather: GatherOp::Identity,
                reduce: ReduceOp::Sum,
                self_scale: None,
                transform: Some(MatMul { weight: w!("w1b", "w2b"), in_dim: mid, out_dim }),
                add_program: None,
                activate: Activate::Relu,
            },
        ],

        // gate = σ(H wg) (scalar per source, Marcheggiani & Titov);
        // msg = H Wm; z = relu(Σ (gate ⊙ msg) + h_v Ws).
        GnnModel::Ggcn => vec![
            Program {
                name: "ggcn-gate",
                domain: Domain::AllInputs,
                source: Src::LayerInput,
                gather: GatherOp::Identity,
                reduce: ReduceOp::Sum,
                self_scale: None,
                transform: Some(MatMul { weight: w!("wg1", "wg2"), in_dim, out_dim: 1 }),
                add_program: None,
                activate: Activate::Sigmoid,
            },
            Program {
                name: "ggcn-msg",
                domain: Domain::AllInputs,
                source: Src::LayerInput,
                gather: GatherOp::Identity,
                reduce: ReduceOp::Sum,
                self_scale: None,
                transform: Some(MatMul { weight: w!("wm1", "wm2"), in_dim, out_dim }),
                add_program: None,
                activate: Activate::None,
            },
            Program {
                name: "ggcn-reduce",
                domain: Domain::Edges,
                source: Src::Program(1),
                gather: GatherOp::ProductWith(0),
                reduce: ReduceOp::Sum,
                self_scale: None,
                transform: None,
                add_program: None,
                activate: Activate::None,
            },
            Program {
                name: "ggcn-update",
                domain: Domain::Outputs,
                source: Src::LayerInput,
                gather: GatherOp::Identity,
                reduce: ReduceOp::Sum,
                self_scale: None,
                transform: Some(MatMul { weight: w!("ws1", "ws2"), in_dim, out_dim }),
                add_program: Some(2),
                activate: Activate::Relu,
            },
        ],
    };
    let output_program = programs.len() - 1;
    LayerPlan { programs, output_program, in_dim, out_dim }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mc() -> ModelConfig {
        ModelConfig::paper()
    }

    #[test]
    fn gcn_is_single_program() {
        let plan = compile(GnnModel::Gcn, &mc());
        assert_eq!(plan.layers.len(), 2);
        assert_eq!(plan.layers[0].programs.len(), 1);
        assert_eq!(plan.layers[0].programs[0].reduce, ReduceOp::Mean);
        assert_eq!(plan.weight_names(), vec!["w1", "w2"]);
    }

    #[test]
    fn ggcn_splits_into_four_programs() {
        // Fig. 3: weighted send ops must split into identity-nodeflow
        // programs because gather/reduce have no weight access.
        let plan = compile(GnnModel::Ggcn, &mc());
        let l0 = &plan.layers[0];
        assert_eq!(l0.programs.len(), 4);
        assert_eq!(l0.programs[0].domain, Domain::AllInputs);
        assert_eq!(l0.programs[2].gather, GatherOp::ProductWith(0));
        assert!(l0.programs[2].transform.is_none());
        assert_eq!(l0.programs[3].add_program, Some(2));
    }

    #[test]
    fn sage_uses_max_reduce() {
        let plan = compile(GnnModel::Sage, &mc());
        assert_eq!(plan.layers[0].programs[1].reduce, ReduceOp::Max);
        assert_eq!(plan.layers[0].programs[1].source, Src::Program(0));
    }

    #[test]
    fn gin_self_scale() {
        let plan = compile(GnnModel::Gin, &mc());
        assert!(matches!(
            plan.layers[0].programs[0].self_scale,
            Some(SelfScale::OnePlusArg("eps1"))
        ));
        assert_eq!(plan.weight_names(), vec!["w1a", "w1b", "w2a", "w2b"]);
    }

    #[test]
    fn weight_bytes_match_dims() {
        let plan = compile(GnnModel::Gcn, &mc());
        // (602*512 + 512*256) * 2 bytes
        assert_eq!(plan.weight_bytes(2), (602 * 512 + 512 * 256) * 2);
    }

    #[test]
    fn layer_dims_follow_model_config() {
        for m in ALL_MODELS {
            let plan = compile(m, &mc());
            assert_eq!(plan.layers[0].in_dim, 602);
            assert_eq!(plan.layers[0].out_dim, 512);
            assert_eq!(plan.layers[1].out_dim, 256);
        }
    }

    #[test]
    fn model_name_roundtrip() {
        for m in ALL_MODELS {
            assert_eq!(GnnModel::from_name(m.name()), Some(m));
        }
        assert_eq!(GnnModel::from_name("GS"), Some(GnnModel::Sage));
    }
}
