//! Executable GRIP plans and the paper-model preset factory (paper
//! Sec. IV-A, Fig. 3/4).
//!
//! Each [`Program`] is one pass of the three GReTA phases over a domain;
//! a [`LayerPlan`] is the program sequence implementing one
//! message-passing layer; a [`ModelPlan`] is the full compiled model
//! (any depth). Plans are produced by the [`super::spec::ModelSpec`]
//! validation/lowering pass — from the typed builder, from JSON, or
//! from the [`GnnModel`] preset factory below, which yields the four
//! models evaluated by the paper. The plan feeds both the functional
//! executor (`exec.rs`) and the cycle simulator (`crate::sim`), so the
//! cost model and the numerics always agree on program structure.

use super::ops::{Activate, Domain, GatherOp, ReduceOp, SelfScale};
use super::spec::{LayerSpec, ModelKey, ModelSpec, ProgramSpec};
use crate::config::ModelConfig;

/// The four GNN models evaluated by the paper (Sec. VII). Since the
/// `ModelSpec` redesign this enum is a *preset factory only*: it names
/// the paper specs ([`GnnModel::spec`]) and nothing else matches on it
/// to derive program structure.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GnnModel {
    Gcn,
    Sage,
    Gin,
    Ggcn,
}

pub const ALL_MODELS: [GnnModel; 4] = [GnnModel::Gcn, GnnModel::Sage, GnnModel::Gin, GnnModel::Ggcn];

/// Accepted `--model` spellings, for CLI usage/error text.
pub const MODEL_NAME_HELP: &str = "gcn | sage (aliases: gs, graphsage) | gin | ggcn (alias: g-gcn)";

impl GnnModel {
    pub fn name(&self) -> &'static str {
        match self {
            GnnModel::Gcn => "gcn",
            GnnModel::Sage => "sage",
            GnnModel::Gin => "gin",
            GnnModel::Ggcn => "ggcn",
        }
    }

    /// Parse a model name. Accepted spellings: [`MODEL_NAME_HELP`].
    pub fn from_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "gcn" => Some(GnnModel::Gcn),
            "sage" | "gs" | "graphsage" => Some(GnnModel::Sage),
            "gin" => Some(GnnModel::Gin),
            "ggcn" | "g-gcn" => Some(GnnModel::Ggcn),
            _ => None,
        }
    }

    /// This preset's [`ModelKey`] — valid in every
    /// [`super::spec::ModelLibrary`] (presets always occupy keys 0..4).
    pub fn key(self) -> ModelKey {
        ModelKey::from(self)
    }

    /// The preset's data-driven spec: the Fig. 4 program sequences over
    /// `mc`'s dims and sampling. `compile(model, mc)` lowers it.
    pub fn spec(self, mc: &ModelConfig) -> ModelSpec {
        let mut b = ModelSpec::builder(self.name());
        for (i, &(sample, in_dim, out_dim)) in mc.layers().iter().enumerate() {
            b = b.layer(preset_layer(self, i, in_dim, mc.f_hid, out_dim).sample(sample));
        }
        b.build()
    }
}

/// Transform UDF: matrix multiply with a named weight (paper: transform
/// is the only UDF with weight access). The name is owned so manifest /
/// argument resolution works for spec-defined models, not just the
/// presets' literal names.
#[derive(Debug, Clone)]
pub struct MatMul {
    /// Runtime argument / manifest parameter name.
    pub weight: String,
    pub in_dim: usize,
    pub out_dim: usize,
}

/// One GRIP program (paper Alg. 2 semantics).
#[derive(Debug, Clone)]
pub struct Program {
    pub name: String,
    pub domain: Domain,
    /// Feature source: the layer's input features or a previous
    /// program's output (program composition, Fig. 4 plus-boxes).
    pub source: Src,
    pub gather: GatherOp,
    pub reduce: ReduceOp,
    /// Self-contribution folded into the edge accumulator (GIN).
    pub self_scale: Option<SelfScale>,
    /// Vertex-accumulate transform; `None` for pure edge programs.
    pub transform: Option<MatMul>,
    /// Accumulate another program's output into the vertex accumulator
    /// before activation (rows must match this program's domain rows).
    pub add_program: Option<usize>,
    pub activate: Activate,
}

/// Feature source of a program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Src {
    /// The layer's input feature matrix H (U rows).
    LayerInput,
    /// Output of a previous program in the same layer plan.
    Program(usize),
}

/// Program sequence for one message-passing layer. `output_program`
/// names which program's result is the layer output Z.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    pub programs: Vec<Program>,
    pub output_program: usize,
    pub in_dim: usize,
    pub out_dim: usize,
}

/// Compiled model: one plan per layer, outermost (largest U) first.
#[derive(Debug, Clone)]
pub struct ModelPlan {
    /// Model name (a preset name or the source spec's name).
    pub name: String,
    pub layers: Vec<LayerPlan>,
}

impl ModelPlan {
    /// Total weight bytes across all transforms (drives weight-load time
    /// and the Table II global-weight-buffer sizing).
    pub fn weight_bytes(&self, elem_bytes: usize) -> usize {
        self.layers
            .iter()
            .flat_map(|l| l.programs.iter())
            .filter_map(|p| p.transform.as_ref())
            .map(|t| t.in_dim * t.out_dim * elem_bytes)
            .sum()
    }

    /// Names of all weight parameters in execution order.
    pub fn weight_names(&self) -> Vec<&str> {
        self.layers
            .iter()
            .flat_map(|l| l.programs.iter())
            .filter_map(|p| p.transform.as_ref().map(|t| t.weight.as_str()))
            .collect()
    }

    /// Total programs across layers (framework-dispatch proxy for the
    /// analytical baselines).
    pub fn num_programs(&self) -> usize {
        self.layers.iter().map(|l| l.programs.len()).sum()
    }

    /// Programs iterating real edges (per-neighborhood gather passes).
    pub fn num_edge_programs(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|l| l.programs.iter())
            .filter(|p| p.domain == Domain::Edges)
            .count()
    }
}

/// Compile a preset model to its GRIP program sequence (Fig. 4) —
/// sugar for `model.spec(mc).compile()`.
pub fn compile(model: GnnModel, mc: &ModelConfig) -> ModelPlan {
    model.spec(mc).compile().expect("paper preset specs are valid")
}

/// The Fig. 4 program sequence of one preset layer, as a spec.
fn preset_layer(
    model: GnnModel,
    layer: usize,
    in_dim: usize,
    mid: usize,
    out_dim: usize,
) -> LayerSpec {
    // Weight names match python/compile/model.py::param_names.
    macro_rules! w {
        ($a:expr, $b:expr) => {
            if layer == 0 {
                $a
            } else {
                $b
            }
        };
    }
    match model {
        // Z = relu((Â_mean H) W) — single program, the canonical case.
        GnnModel::Gcn => LayerSpec::new(in_dim, out_dim).program(
            ProgramSpec::new("gcn")
                .reduce(ReduceOp::Mean)
                .transform(w!("w1", "w2"), in_dim, out_dim)
                .activate(Activate::Relu),
        ),

        // a_v = max_u relu(h_u W_pool); z = relu(h_v W_s + a_v W_n).
        GnnModel::Sage => LayerSpec::new(in_dim, out_dim)
            .program(
                ProgramSpec::new("sage-pool")
                    .domain(Domain::AllInputs)
                    .transform(w!("wp1", "wp2"), in_dim, mid)
                    .activate(Activate::Relu),
            )
            .program(
                ProgramSpec::new("sage-agg")
                    .source_program(0)
                    .reduce(ReduceOp::Max)
                    .transform(w!("wn1", "wn2"), mid, out_dim),
            )
            .program(
                ProgramSpec::new("sage-update")
                    .domain(Domain::Outputs)
                    .transform(w!("ws1", "ws2"), in_dim, out_dim)
                    .add_program(1)
                    .activate(Activate::Relu),
            ),

        // z = relu(W2 relu(W1 ((1+eps) h_v + Σ h_u))).
        GnnModel::Gin => LayerSpec::new(in_dim, out_dim)
            .program(
                ProgramSpec::new("gin-agg")
                    .self_scale(SelfScale::OnePlusArg(w!("eps1", "eps2").into()))
                    .transform(w!("w1a", "w2a"), in_dim, mid)
                    .activate(Activate::Relu),
            )
            .program(
                ProgramSpec::new("gin-mlp2")
                    .domain(Domain::Outputs)
                    .source_program(0)
                    .transform(w!("w1b", "w2b"), mid, out_dim)
                    .activate(Activate::Relu),
            ),

        // gate = σ(H wg) (scalar per source, Marcheggiani & Titov);
        // msg = H Wm; z = relu(Σ (gate ⊙ msg) + h_v Ws).
        GnnModel::Ggcn => LayerSpec::new(in_dim, out_dim)
            .program(
                ProgramSpec::new("ggcn-gate")
                    .domain(Domain::AllInputs)
                    .transform(w!("wg1", "wg2"), in_dim, 1)
                    .activate(Activate::Sigmoid),
            )
            .program(
                ProgramSpec::new("ggcn-msg")
                    .domain(Domain::AllInputs)
                    .transform(w!("wm1", "wm2"), in_dim, out_dim),
            )
            .program(
                ProgramSpec::new("ggcn-reduce")
                    .source_program(1)
                    .gather(GatherOp::ProductWith(0)),
            )
            .program(
                ProgramSpec::new("ggcn-update")
                    .domain(Domain::Outputs)
                    .transform(w!("ws1", "ws2"), in_dim, out_dim)
                    .add_program(2)
                    .activate(Activate::Relu),
            ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mc() -> ModelConfig {
        ModelConfig::paper()
    }

    #[test]
    fn gcn_is_single_program() {
        let plan = compile(GnnModel::Gcn, &mc());
        assert_eq!(plan.layers.len(), 2);
        assert_eq!(plan.layers[0].programs.len(), 1);
        assert_eq!(plan.layers[0].programs[0].reduce, ReduceOp::Mean);
        assert_eq!(plan.weight_names(), vec!["w1", "w2"]);
        assert_eq!(plan.name, "gcn");
    }

    #[test]
    fn ggcn_splits_into_four_programs() {
        // Fig. 3: weighted send ops must split into identity-nodeflow
        // programs because gather/reduce have no weight access.
        let plan = compile(GnnModel::Ggcn, &mc());
        let l0 = &plan.layers[0];
        assert_eq!(l0.programs.len(), 4);
        assert_eq!(l0.programs[0].domain, Domain::AllInputs);
        assert_eq!(l0.programs[2].gather, GatherOp::ProductWith(0));
        assert!(l0.programs[2].transform.is_none());
        assert_eq!(l0.programs[3].add_program, Some(2));
    }

    #[test]
    fn sage_uses_max_reduce() {
        let plan = compile(GnnModel::Sage, &mc());
        assert_eq!(plan.layers[0].programs[1].reduce, ReduceOp::Max);
        assert_eq!(plan.layers[0].programs[1].source, Src::Program(0));
    }

    #[test]
    fn gin_self_scale() {
        let plan = compile(GnnModel::Gin, &mc());
        assert!(matches!(
            &plan.layers[0].programs[0].self_scale,
            Some(SelfScale::OnePlusArg(name)) if name == "eps1"
        ));
        assert_eq!(plan.weight_names(), vec!["w1a", "w1b", "w2a", "w2b"]);
    }

    #[test]
    fn weight_bytes_match_dims() {
        let plan = compile(GnnModel::Gcn, &mc());
        // (602*512 + 512*256) * 2 bytes
        assert_eq!(plan.weight_bytes(2), (602 * 512 + 512 * 256) * 2);
    }

    #[test]
    fn layer_dims_follow_model_config() {
        for m in ALL_MODELS {
            let plan = compile(m, &mc());
            assert_eq!(plan.layers[0].in_dim, 602);
            assert_eq!(plan.layers[0].out_dim, 512);
            assert_eq!(plan.layers[1].out_dim, 256);
        }
    }

    #[test]
    fn model_name_roundtrip() {
        for m in ALL_MODELS {
            assert_eq!(GnnModel::from_name(m.name()), Some(m));
        }
        assert_eq!(GnnModel::from_name("GS"), Some(GnnModel::Sage));
        assert_eq!(GnnModel::from_name("g-gcn"), Some(GnnModel::Ggcn));
        // The usage string names every alias.
        for alias in ["gs", "graphsage", "g-gcn"] {
            assert!(MODEL_NAME_HELP.contains(alias), "{alias} missing from MODEL_NAME_HELP");
        }
    }

    #[test]
    fn preset_specs_carry_sampling() {
        let spec = GnnModel::Gcn.spec(&mc());
        assert_eq!(spec.layers[0].sample, Some(25));
        assert_eq!(spec.layers[1].sample, Some(10));
    }

    #[test]
    fn structural_counts() {
        assert_eq!(compile(GnnModel::Gcn, &mc()).num_programs(), 2);
        assert_eq!(compile(GnnModel::Ggcn, &mc()).num_programs(), 8);
        assert_eq!(compile(GnnModel::Gcn, &mc()).num_edge_programs(), 2);
        assert_eq!(compile(GnnModel::Sage, &mc()).num_edge_programs(), 2);
    }
}
