//! Bit-accurate functional executor: runs a compiled [`ModelPlan`] over a
//! [`Nodeflow`] on GRIP's 16-bit fixed-point datapath (paper Alg. 2).
//!
//! This is the *numerics* half of the simulator (the cycle model in
//! `crate::sim` is the timing half). Integration tests validate it
//! against the float PJRT path executing the AOT'd JAX models, closing
//! the loop: Pallas kernel ≍ jnp reference ≍ HLO-on-PJRT ≍ this
//! fixed-point datapath (within quantization error).

use std::collections::HashMap;

use super::ops::{Activate, Domain, GatherOp, ReduceOp, SelfScale};
use super::program::{ModelPlan, Program, Src};
use crate::fixed::{Fx16, LutConfig, TwoLevelLut};
use crate::nodeflow::Nodeflow;

/// Execution errors (argument resolution / shape mismatches).
#[derive(Debug)]
pub enum ExecError {
    MissingArg(String),
    DimMismatch { program: &'static str, expected: usize, got: usize },
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::MissingArg(a) => write!(f, "missing argument {a}"),
            ExecError::DimMismatch { program, expected, got } => {
                write!(f, "{program}: expected dim {expected}, got {got}")
            }
        }
    }
}

impl std::error::Error for ExecError {}

/// Named runtime arguments: scalars (GIN's eps) and weight matrices,
/// shapes as (rows, cols), data row-major f32 (quantized on load).
pub type Args = HashMap<String, (Vec<usize>, Vec<f32>)>;

/// Deterministic random weights for every transform in a plan (used by
/// tests and benches; serving uses `runtime::serving_weights` instead).
pub fn exec_test_args(plan: &ModelPlan, seed: u64) -> Args {
    let mut lcg = crate::rng::GoldenLcg::new(seed);
    let mut args = Args::new();
    for l in &plan.layers {
        for p in &l.programs {
            if let Some(t) = &p.transform {
                let data: Vec<f32> =
                    lcg.fill(t.in_dim * t.out_dim).iter().map(|x| x * 0.4).collect();
                args.insert(t.weight.to_string(), (vec![t.in_dim, t.out_dim], data));
            }
        }
    }
    args
}

struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<Fx16>,
}

impl Matrix {
    fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![Fx16::ZERO; rows * cols] }
    }

    fn row(&self, r: usize) -> &[Fx16] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    fn row_mut(&mut self, r: usize) -> &mut [Fx16] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }
}

fn get_matrix(args: &Args, name: &str) -> Result<Matrix, ExecError> {
    let (shape, data) = args.get(name).ok_or_else(|| ExecError::MissingArg(name.into()))?;
    let (rows, cols) = match shape.as_slice() {
        [r, c] => (*r, *c),
        _ => return Err(ExecError::MissingArg(format!("{name}: not a matrix"))),
    };
    Ok(Matrix { rows, cols, data: data.iter().map(|&x| Fx16::from_f32(x)).collect() })
}

fn get_scalar(args: &Args, name: &str) -> Result<f32, ExecError> {
    let (_, data) = args.get(name).ok_or_else(|| ExecError::MissingArg(name.into()))?;
    Ok(data[0])
}

/// Execute the full model over the nodeflow.
///
/// * `h` — input features, row-major `[U_layer0 × in_dim]` f32
///   (quantized to Q4.12 on entry, as the DMA engine does).
/// * `args` — named weights/scalars (see [`Args`]).
///
/// Returns the target embeddings, `[targets × out_dim]` f32.
pub fn execute_model(
    plan: &ModelPlan,
    nf: &Nodeflow,
    h: &[f32],
    args: &Args,
) -> Result<Vec<f32>, ExecError> {
    assert_eq!(plan.layers.len(), nf.layers.len(), "plan/nodeflow layer count");
    let sigmoid = TwoLevelLut::new(LutConfig::sigmoid());

    let l0 = &nf.layers[0];
    let in_dim = plan.layers[0].in_dim;
    assert_eq!(h.len(), l0.num_inputs() * in_dim, "feature matrix shape");
    let mut features = Matrix {
        rows: l0.num_inputs(),
        cols: in_dim,
        data: h.iter().map(|&x| Fx16::from_f32(x)).collect(),
    };

    for (lp, nl) in plan.layers.iter().zip(nf.layers.iter()) {
        let mut outputs: Vec<Matrix> = Vec::with_capacity(lp.programs.len());
        for prog in &lp.programs {
            let out = run_program(prog, nl, &features, &outputs, args, &sigmoid)?;
            outputs.push(out);
        }
        features = outputs.swap_remove(lp.output_program);
        // The layer output has V rows = next layer's U rows.
        debug_assert_eq!(features.rows, nl.num_outputs);
    }

    Ok(features.data.iter().map(|x| x.to_f32()).collect())
}

fn run_program(
    prog: &Program,
    nl: &crate::nodeflow::NodeflowLayer,
    features: &Matrix,
    outputs: &[Matrix],
    args: &Args,
    sigmoid: &TwoLevelLut,
) -> Result<Matrix, ExecError> {
    let src: &Matrix = match prog.source {
        Src::LayerInput => features,
        Src::Program(k) => &outputs[k],
    };
    let dim = src.cols;
    let v = nl.num_outputs;

    // ---------------------------------------------- edge-accumulate phase
    let mut acc = match prog.domain {
        Domain::AllInputs => Matrix { rows: src.rows, cols: dim, data: src.data.clone() },
        Domain::Outputs => Matrix { rows: v, cols: dim, data: src.data[..v * dim].to_vec() },
        Domain::Edges => {
            let mut acc = Matrix::zeros(v, dim);
            let mut counts = vec![0u32; v];
            let mut msg = vec![Fx16::ZERO; dim];
            for &(u, dst) in &nl.edges {
                let (u, dst) = (u as usize, dst as usize);
                // gather UDF
                match prog.gather {
                    GatherOp::Identity => msg.copy_from_slice(src.row(u)),
                    GatherOp::ProductWith(k) => {
                        let other = outputs[k].row(u);
                        if other.len() == 1 {
                            // Scalar gate broadcast (G-GCN).
                            let gmul = other[0];
                            for (m, a) in msg.iter_mut().zip(src.row(u).iter()) {
                                *m = a.sat_mul(gmul);
                            }
                        } else {
                            for (m, (a, b)) in msg.iter_mut().zip(src.row(u).iter().zip(other)) {
                                *m = a.sat_mul(*b);
                            }
                        }
                    }
                    GatherOp::SumWith(k) => {
                        let other = outputs[k].row(u);
                        for (m, (a, b)) in msg.iter_mut().zip(src.row(u).iter().zip(other)) {
                            *m = a.sat_add(*b);
                        }
                    }
                    GatherOp::Scale(c) => {
                        let c = Fx16::from_f32(c);
                        for (m, a) in msg.iter_mut().zip(src.row(u).iter()) {
                            *m = a.sat_mul(c);
                        }
                    }
                }
                // reduce UDF
                let row = acc.row_mut(dst);
                match prog.reduce {
                    ReduceOp::Sum | ReduceOp::Mean => {
                        for (r, m) in row.iter_mut().zip(msg.iter()) {
                            *r = r.sat_add(*m);
                        }
                    }
                    ReduceOp::Max => {
                        if counts[dst] == 0 {
                            row.copy_from_slice(&msg);
                        } else {
                            for (r, m) in row.iter_mut().zip(msg.iter()) {
                                *r = (*r).max(*m);
                            }
                        }
                    }
                }
                counts[dst] += 1;
            }
            if prog.reduce == ReduceOp::Mean {
                // The reduce PE divides by the in-degree (computed as a
                // reciprocal multiply in hardware).
                for dst in 0..v {
                    if counts[dst] > 1 {
                        let inv = Fx16::from_f32(1.0 / counts[dst] as f32);
                        for r in acc.row_mut(dst) {
                            *r = r.sat_mul(inv);
                        }
                    }
                }
            }
            acc
        }
    };

    // Self contribution (GIN): acc[v] += (1+eps) * src[v].
    if let Some(ss) = prog.self_scale {
        let scale = match ss {
            SelfScale::OnePlusArg(name) => Fx16::from_f32(1.0 + get_scalar(args, name)?),
            SelfScale::Const(c) => Fx16::from_f32(c),
        };
        for r in 0..acc.rows {
            let s_row: Vec<Fx16> = src.row(r).iter().map(|x| x.sat_mul(scale)).collect();
            for (a, s) in acc.row_mut(r).iter_mut().zip(s_row) {
                *a = a.sat_add(s);
            }
        }
    }

    // -------------------------------------------- vertex-accumulate phase
    let mut result = if let Some(t) = &prog.transform {
        if t.in_dim != dim {
            return Err(ExecError::DimMismatch { program: prog.name, expected: t.in_dim, got: dim });
        }
        let w = get_matrix(args, t.weight)?;
        if w.rows != t.in_dim || w.cols != t.out_dim {
            return Err(ExecError::DimMismatch { program: prog.name, expected: t.in_dim * t.out_dim, got: w.rows * w.cols });
        }
        let mut y = Matrix::zeros(acc.rows, t.out_dim);
        for r in 0..acc.rows {
            let a_row = acc.row(r);
            let y_row = y.row_mut(r);
            for (o, y_cell) in y_row.iter_mut().enumerate() {
                // Wide accumulate down the PE column reduction tree.
                let mut wide: i64 = 0;
                for (i, a) in a_row.iter().enumerate() {
                    wide = a.mac_into(w.data[i * w.cols + o], wide);
                }
                *y_cell = Fx16::from_acc(wide);
            }
        }
        y
    } else {
        acc
    };

    // Vertex-accumulator chaining (Fig. 4 plus-boxes).
    if let Some(k) = prog.add_program {
        let other = &outputs[k];
        assert_eq!(other.cols, result.cols, "add_program dim");
        for r in 0..result.rows {
            let o_row: Vec<Fx16> = other.row(r).to_vec();
            for (a, b) in result.row_mut(r).iter_mut().zip(o_row) {
                *a = a.sat_add(b);
            }
        }
    }

    // ------------------------------------------------ vertex-update phase
    match prog.activate {
        Activate::None => {}
        Activate::Relu => {
            for x in result.data.iter_mut() {
                *x = x.relu();
            }
        }
        Activate::Sigmoid => {
            for x in result.data.iter_mut() {
                *x = sigmoid.eval(*x);
            }
        }
    }

    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::graph::{generate, GeneratorParams};
    use crate::greta::program::{compile, GnnModel};
    use crate::nodeflow::Sampler;
    use crate::rng::GoldenLcg;

    fn small_mc() -> ModelConfig {
        ModelConfig { sample1: 4, sample2: 3, f_in: 12, f_hid: 10, f_out: 6 }
    }

    fn setup(mc: &ModelConfig) -> (Nodeflow, Vec<f32>) {
        let g = generate(&GeneratorParams { nodes: 500, mean_degree: 6.0, ..Default::default() });
        let nf = Nodeflow::build(&g, &Sampler::new(3), &[17], mc);
        let mut lcg = GoldenLcg::new(7);
        let h: Vec<f32> = lcg.fill(nf.layers[0].num_inputs() * mc.f_in).iter().map(|x| x * 0.5).collect();
        (nf, h)
    }

    fn weights_for(model: GnnModel, mc: &ModelConfig) -> Args {
        let plan = compile(model, mc);
        let mut lcg = GoldenLcg::new(99);
        let mut args = Args::new();
        for l in &plan.layers {
            for p in &l.programs {
                if let Some(t) = &p.transform {
                    let data: Vec<f32> =
                        lcg.fill(t.in_dim * t.out_dim).iter().map(|x| x * 0.4).collect();
                    args.insert(t.weight.to_string(), (vec![t.in_dim, t.out_dim], data));
                }
            }
        }
        args.insert("eps1".into(), (vec![], vec![0.1]));
        args.insert("eps2".into(), (vec![], vec![0.2]));
        args
    }

    /// Float reference of GCN over the same nodeflow for cross-checking.
    fn gcn_float_ref(nf: &Nodeflow, h: &[f32], args: &Args, mc: &ModelConfig) -> Vec<f32> {
        let mut cur: Vec<Vec<f32>> = h.chunks(mc.f_in).map(|r| r.to_vec()).collect();
        for (li, w_name) in ["w1", "w2"].iter().enumerate() {
            let (shape, w) = &args[*w_name];
            let (ind, outd) = (shape[0], shape[1]);
            let l = &nf.layers[li];
            let mut agg = vec![vec![0f32; ind]; l.num_outputs];
            let mut counts = vec![0usize; l.num_outputs];
            for &(u, v) in &l.edges {
                for i in 0..ind {
                    agg[v as usize][i] += cur[u as usize][i];
                }
                counts[v as usize] += 1;
            }
            for v in 0..l.num_outputs {
                if counts[v] > 0 {
                    for x in agg[v].iter_mut() {
                        *x /= counts[v] as f32;
                    }
                }
            }
            let mut next = vec![vec![0f32; outd]; l.num_outputs];
            for v in 0..l.num_outputs {
                for o in 0..outd {
                    let mut s = 0f32;
                    for i in 0..ind {
                        s += agg[v][i] * w[i * outd + o];
                    }
                    next[v][o] = s.max(0.0);
                }
            }
            cur = next;
        }
        cur.into_iter().flatten().collect()
    }

    #[test]
    fn gcn_matches_float_reference() {
        let mc = small_mc();
        let (nf, h) = setup(&mc);
        let args = weights_for(GnnModel::Gcn, &mc);
        let plan = compile(GnnModel::Gcn, &mc);
        let got = execute_model(&plan, &nf, &h, &args).unwrap();
        let want = gcn_float_ref(&nf, &h, &args, &mc);
        assert_eq!(got.len(), mc.f_out);
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 0.02, "{g} vs {w}");
        }
    }

    #[test]
    fn all_models_execute() {
        let mc = small_mc();
        let (nf, h) = setup(&mc);
        for model in [GnnModel::Gcn, GnnModel::Sage, GnnModel::Gin, GnnModel::Ggcn] {
            let args = weights_for(model, &mc);
            let plan = compile(model, &mc);
            let out = execute_model(&plan, &nf, &h, &args).unwrap();
            assert_eq!(out.len(), mc.f_out, "{model:?}");
            assert!(out.iter().all(|x| x.is_finite()));
            // All four models end in ReLU — outputs nonnegative.
            assert!(out.iter().all(|&x| x >= 0.0), "{model:?}");
        }
    }

    #[test]
    fn missing_weight_errors() {
        let mc = small_mc();
        let (nf, h) = setup(&mc);
        let plan = compile(GnnModel::Gcn, &mc);
        let err = execute_model(&plan, &nf, &h, &Args::new());
        assert!(matches!(err, Err(ExecError::MissingArg(_))));
    }

    #[test]
    fn gin_eps_changes_output() {
        let mc = small_mc();
        let (nf, h) = setup(&mc);
        let plan = compile(GnnModel::Gin, &mc);
        let mut args = weights_for(GnnModel::Gin, &mc);
        let a = execute_model(&plan, &nf, &h, &args).unwrap();
        args.insert("eps1".into(), (vec![], vec![2.0]));
        let b = execute_model(&plan, &nf, &h, &args).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn ggcn_gate_bounds() {
        // The gate program output (sigmoid LUT) must lie in [0, 1]; we
        // indirectly verify via monotonicity: scaling the message weights
        // up scales outputs up (gates fixed).
        let mc = small_mc();
        let (nf, h) = setup(&mc);
        let plan = compile(GnnModel::Ggcn, &mc);
        let args = weights_for(GnnModel::Ggcn, &mc);
        let out = execute_model(&plan, &nf, &h, &args).unwrap();
        assert!(out.iter().all(|x| x.is_finite()));
    }
}
