//! Bit-accurate functional executor: runs a compiled [`ModelPlan`] over a
//! [`Nodeflow`] on GRIP's 16-bit fixed-point datapath (paper Alg. 2).
//!
//! This is the *numerics* half of the simulator (the cycle model in
//! `crate::sim` is the timing half). Integration tests validate it
//! against the float PJRT path executing the AOT'd JAX models, closing
//! the loop: Pallas kernel ≍ jnp reference ≍ HLO-on-PJRT ≍ this
//! fixed-point datapath (within quantization error).
//!
//! # Hot path (PR 1)
//!
//! The serving-path entry point is [`execute_model_into`]: weights are
//! pre-quantized once into a resolved [`PlanArgs`] (no per-call
//! `HashMap` lookup or `Fx16::from_f32` re-quantization), all working
//! matrices live in a reusable [`ExecScratch`] arena (zero heap
//! allocations per request once buffer capacities have warmed up), edges
//! stream per output vertex from the nodeflow's destination-sorted CSR
//! view, and the transform matmul is vertex-tiled: the `out_dim` loop is
//! blocked into tiles of `Vt` outputs (matching the PE-array column
//! count, [`crate::config::GripConfig::pe_cols`]) with a contiguous,
//! autovectorizable inner MAC loop — the software mirror of the paper's
//! vertex-tiling optimization.
//!
//! [`execute_model_ref`] keeps the seed edge-list implementation as the
//! bit-identical reference for property tests and the `bench_exec`
//! before/after microbenchmark.

use std::collections::HashMap;

use super::ops::{Activate, Domain, GatherOp, ReduceOp, SelfScale};
use super::program::{ModelPlan, Program, Src};
use crate::config::GripConfig;
use crate::fixed::{Fx16, LutConfig, TwoLevelLut};
use crate::nodeflow::{HarvestRow, MemoHarvest, MemoPlan, Nodeflow, NodeflowLayer};

/// Execution errors (argument resolution / shape mismatches).
#[derive(Debug)]
pub enum ExecError {
    MissingArg(String),
    /// An argument was present but not matrix-shaped.
    BadShape { name: String, shape: Vec<usize> },
    DimMismatch { program: String, expected: usize, got: usize },
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::MissingArg(a) => write!(f, "missing argument {a}"),
            ExecError::BadShape { name, shape } => {
                write!(f, "{name}: not a matrix (shape {shape:?})")
            }
            ExecError::DimMismatch { program, expected, got } => {
                write!(f, "{program}: expected dim {expected}, got {got}")
            }
        }
    }
}

impl std::error::Error for ExecError {}

/// Named runtime arguments: scalars (GIN's eps) and weight matrices,
/// shapes as (rows, cols), data row-major f32 (quantized on load).
pub type Args = HashMap<String, (Vec<usize>, Vec<f32>)>;

/// Deterministic random weights for every transform in a plan (used by
/// tests and benches; serving uses `runtime::serving_weights` instead).
pub fn exec_test_args(plan: &ModelPlan, seed: u64) -> Args {
    let mut lcg = crate::rng::GoldenLcg::new(seed);
    let mut args = Args::new();
    for l in &plan.layers {
        for p in &l.programs {
            if let Some(t) = &p.transform {
                let data: Vec<f32> =
                    lcg.fill(t.in_dim * t.out_dim).iter().map(|x| x * 0.4).collect();
                args.insert(t.weight.clone(), (vec![t.in_dim, t.out_dim], data));
            }
        }
    }
    args
}

struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<Fx16>,
}

impl Matrix {
    fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![Fx16::ZERO; rows * cols] }
    }

    fn row(&self, r: usize) -> &[Fx16] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    fn row_mut(&mut self, r: usize) -> &mut [Fx16] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }
}

fn get_matrix(args: &Args, name: &str) -> Result<Matrix, ExecError> {
    let (shape, data) = args.get(name).ok_or_else(|| ExecError::MissingArg(name.into()))?;
    let (rows, cols) = match shape.as_slice() {
        [r, c] => (*r, *c),
        _ => return Err(ExecError::BadShape { name: name.into(), shape: shape.clone() }),
    };
    Ok(Matrix { rows, cols, data: data.iter().map(|&x| Fx16::from_f32(x)).collect() })
}

fn get_scalar(args: &Args, name: &str) -> Result<f32, ExecError> {
    let (_, data) = args.get(name).ok_or_else(|| ExecError::MissingArg(name.into()))?;
    Ok(data[0])
}

/// A [`ModelPlan`]'s runtime arguments resolved once: every transform
/// weight quantized to Q4.12 and shape-checked, every self-scale scalar
/// folded to its fixed-point multiplier. Indexed by (layer, program) —
/// the request path never touches the `Args` `HashMap` again.
pub struct PlanArgs {
    weights: Vec<Vec<Option<Matrix>>>,
    self_scales: Vec<Vec<Option<Fx16>>>,
}

impl PlanArgs {
    /// Resolve and validate `args` against `plan`. Shape errors surface
    /// here instead of mid-execution.
    pub fn resolve(plan: &ModelPlan, args: &Args) -> Result<PlanArgs, ExecError> {
        let mut weights = Vec::with_capacity(plan.layers.len());
        let mut self_scales = Vec::with_capacity(plan.layers.len());
        for lp in &plan.layers {
            let mut wrow = Vec::with_capacity(lp.programs.len());
            let mut srow = Vec::with_capacity(lp.programs.len());
            for prog in &lp.programs {
                let w = match &prog.transform {
                    Some(t) => {
                        let m = get_matrix(args, &t.weight)?;
                        if m.rows != t.in_dim || m.cols != t.out_dim {
                            return Err(ExecError::DimMismatch {
                                program: prog.name.clone(),
                                expected: t.in_dim * t.out_dim,
                                got: m.rows * m.cols,
                            });
                        }
                        Some(m)
                    }
                    None => None,
                };
                let s = match &prog.self_scale {
                    Some(SelfScale::OnePlusArg(name)) => {
                        Some(Fx16::from_f32(1.0 + get_scalar(args, name)?))
                    }
                    Some(SelfScale::Const(c)) => Some(Fx16::from_f32(*c)),
                    None => None,
                };
                wrow.push(w);
                srow.push(s);
            }
            weights.push(wrow);
            self_scales.push(srow);
        }
        Ok(PlanArgs { weights, self_scales })
    }

    fn weight(&self, layer: usize, prog: usize) -> Option<&Matrix> {
        self.weights[layer][prog].as_ref()
    }

    fn self_scale(&self, layer: usize, prog: usize) -> Option<Fx16> {
        self.self_scales[layer][prog]
    }
}

/// Reusable working memory for [`execute_model_into`]. Holds the
/// activation LUT, a buffer pool for the per-program matrices, and the
/// vertex-tile accumulators. After the first few requests every buffer
/// has reached its steady-state capacity and the executor performs no
/// heap allocation per request.
pub struct ExecScratch {
    sigmoid: TwoLevelLut,
    pool: Vec<Vec<Fx16>>,
    outputs: Vec<Matrix>,
    msg: Vec<Fx16>,
    tile: Vec<i64>,
    vt: usize,
}

impl ExecScratch {
    /// Default vertex-tile width = the paper PE array's 32 columns.
    pub fn new() -> Self {
        Self::with_tile(GripConfig::paper().pe_cols)
    }

    /// Tile width from an explicit architecture configuration.
    pub fn for_config(cfg: &GripConfig) -> Self {
        Self::with_tile(cfg.pe_cols)
    }

    /// Explicit vertex-tile width (`vt >= 1`).
    pub fn with_tile(vt: usize) -> Self {
        Self {
            sigmoid: TwoLevelLut::new(LutConfig::sigmoid()),
            pool: Vec::new(),
            outputs: Vec::new(),
            msg: Vec::new(),
            tile: Vec::new(),
            vt: vt.max(1),
        }
    }

    /// Take a zero-filled matrix buffer from the pool (no allocation
    /// once the pooled capacity covers `rows * cols`).
    fn matrix(&mut self, rows: usize, cols: usize) -> Matrix {
        let mut m = self.matrix_empty(rows, cols);
        m.data.resize(rows * cols, Fx16::ZERO);
        m
    }

    /// Take an *empty* (len 0) buffer with capacity for `rows * cols`
    /// elements — for callers that write every element sequentially,
    /// skipping the zero-fill pass. The caller must fill it completely
    /// before `row()` is usable.
    fn matrix_empty(&mut self, rows: usize, cols: usize) -> Matrix {
        let mut data = self.pool.pop().unwrap_or_default();
        data.clear();
        data.reserve(rows * cols);
        Matrix { rows, cols, data }
    }

    /// Take a buffer initialized as a copy of `src` (one copy pass, no
    /// zero-fill).
    fn matrix_from_slice(&mut self, rows: usize, cols: usize, src: &[Fx16]) -> Matrix {
        debug_assert_eq!(src.len(), rows * cols);
        let mut m = self.matrix_empty(rows, cols);
        m.data.extend_from_slice(src);
        m
    }

    fn give(&mut self, data: Vec<Fx16>) {
        self.pool.push(data);
    }
}

impl Default for ExecScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Execute the full model over the nodeflow (convenience wrapper: one
/// fresh [`PlanArgs`] + [`ExecScratch`] per call).
///
/// * `h` — input features, row-major `[U_layer0 × in_dim]` f32
///   (quantized to Q4.12 on entry, as the DMA engine does).
/// * `args` — named weights/scalars (see [`Args`]).
///
/// Returns the target embeddings, `[targets × out_dim]` f32.
pub fn execute_model(
    plan: &ModelPlan,
    nf: &Nodeflow,
    h: &[f32],
    args: &Args,
) -> Result<Vec<f32>, ExecError> {
    let pargs = PlanArgs::resolve(plan, args)?;
    let mut scratch = ExecScratch::new();
    let mut out = Vec::new();
    execute_model_into(plan, nf, h, &pargs, &mut scratch, &mut out)?;
    Ok(out)
}

/// Steady-state-zero-allocation executor: resolved weights, reusable
/// scratch arena, CSR edge streaming, vertex-tiled matmul. Writes the
/// target embeddings into `out` (cleared first). Bit-identical to
/// [`execute_model_ref`].
pub fn execute_model_into(
    plan: &ModelPlan,
    nf: &Nodeflow,
    h: &[f32],
    pargs: &PlanArgs,
    scratch: &mut ExecScratch,
    out: &mut Vec<f32>,
) -> Result<(), ExecError> {
    execute_model_into_memo(plan, nf, h, pargs, scratch, out, None)
}

/// Overwrite memo-hit rows of a just-computed layer output with their
/// cached values, then copy out the rows the cache wants deposited.
///
/// A memo-hit row was left at reduce-identity garbage by the pruned
/// nodeflow (its sampling was skipped, so it has zero in-edges); the
/// splice happens *before* the next layer consumes the matrix, so every
/// downstream value is computed from exact inputs. Inject and harvest
/// rows are disjoint (see [`MemoPlan`]), so harvested rows are always
/// freshly computed, never garbage — by induction the whole execution
/// is bit-identical to the unpruned one.
fn splice_memo(m: &mut Matrix, li: usize, plan: &MemoPlan, harvest: &mut MemoHarvest) {
    let li = li as u32;
    for inj in plan.inject.iter().filter(|r| r.layer == li) {
        debug_assert_eq!(inj.values.len(), m.cols, "memo row dim");
        m.row_mut(inj.row as usize).copy_from_slice(&inj.values);
    }
    for slot in plan.harvest.iter().filter(|s| s.layer == li) {
        harvest.rows.push(HarvestRow {
            layer: slot.layer,
            vertex: slot.vertex,
            degree: slot.degree,
            values: m.row(slot.row as usize).to_vec(),
        });
    }
}

/// [`execute_model_into`] with activation memoization: interior-layer
/// outputs listed in the [`MemoPlan`] are spliced in from the cache
/// (hits) or copied out for deposit (admissible misses) as each layer
/// completes. `memo = None` is exactly the plain executor.
pub fn execute_model_into_memo(
    plan: &ModelPlan,
    nf: &Nodeflow,
    h: &[f32],
    pargs: &PlanArgs,
    scratch: &mut ExecScratch,
    out: &mut Vec<f32>,
    mut memo: Option<(&MemoPlan, &mut MemoHarvest)>,
) -> Result<(), ExecError> {
    assert_eq!(plan.layers.len(), nf.layers.len(), "plan/nodeflow layer count");
    let l0 = &nf.layers[0];
    let in_dim = plan.layers[0].in_dim;
    assert_eq!(h.len(), l0.num_inputs() * in_dim, "feature matrix shape");

    let mut features = scratch.matrix_empty(l0.num_inputs(), in_dim);
    features.data.extend(h.iter().map(|&x| Fx16::from_f32(x)));

    let mut outputs = std::mem::take(&mut scratch.outputs);
    for (li, (lp, nl)) in plan.layers.iter().zip(nf.layers.iter()).enumerate() {
        // Guard against a desynced CSR view (layers must be built via
        // NodeflowLayer::new, not mutated through the pub fields).
        debug_assert_eq!(nl.edge_srcs.len(), nl.edges.len(), "stale CSR edge view");
        for (pi, prog) in lp.programs.iter().enumerate() {
            let result = run_program(
                prog,
                nl,
                &features,
                &outputs,
                pargs.weight(li, pi),
                pargs.self_scale(li, pi),
                scratch,
            )?;
            outputs.push(result);
        }
        let mut next = outputs.swap_remove(lp.output_program);
        // The layer output has V rows = next layer's U rows.
        debug_assert_eq!(next.rows, nl.num_outputs);
        if let Some((mplan, hv)) = memo.as_mut() {
            splice_memo(&mut next, li, mplan, hv);
        }
        for m in outputs.drain(..) {
            scratch.give(m.data);
        }
        scratch.give(std::mem::replace(&mut features, next).data);
    }

    out.clear();
    out.extend(features.data.iter().map(|x| x.to_f32()));
    scratch.give(features.data);
    scratch.outputs = outputs;
    Ok(())
}

fn run_program(
    prog: &Program,
    nl: &NodeflowLayer,
    features: &Matrix,
    outputs: &[Matrix],
    weight: Option<&Matrix>,
    self_scale: Option<Fx16>,
    scratch: &mut ExecScratch,
) -> Result<Matrix, ExecError> {
    let src: &Matrix = match prog.source {
        Src::LayerInput => features,
        Src::Program(k) => &outputs[k],
    };
    let dim = src.cols;
    let v = nl.num_outputs;

    // ---------------------------------------------- edge-accumulate phase
    let mut acc = match prog.domain {
        Domain::AllInputs => scratch.matrix_from_slice(src.rows, dim, &src.data),
        Domain::Outputs => scratch.matrix_from_slice(v, dim, &src.data[..v * dim]),
        Domain::Edges => {
            let mut acc = scratch.matrix(v, dim);
            if prog.gather == GatherOp::Identity {
                // Fast path: the message is the source row itself; stream
                // each output vertex's sources straight out of the CSR
                // view with no per-edge staging copy.
                for dst in 0..v {
                    let row = acc.row_mut(dst);
                    match prog.reduce {
                        ReduceOp::Sum | ReduceOp::Mean => {
                            for &u in nl.edge_srcs_of(dst) {
                                for (r, m) in row.iter_mut().zip(src.row(u as usize)) {
                                    *r = r.sat_add(*m);
                                }
                            }
                        }
                        ReduceOp::Max => {
                            for (ei, &u) in nl.edge_srcs_of(dst).iter().enumerate() {
                                let s = src.row(u as usize);
                                if ei == 0 {
                                    row.copy_from_slice(s);
                                } else {
                                    for (r, m) in row.iter_mut().zip(s) {
                                        *r = (*r).max(*m);
                                    }
                                }
                            }
                        }
                    }
                }
            } else {
                // General gather UDFs stage the per-edge message once.
                scratch.msg.clear();
                scratch.msg.resize(dim, Fx16::ZERO);
                let msg = &mut scratch.msg;
                for dst in 0..v {
                    let row = acc.row_mut(dst);
                    for (ei, &u) in nl.edge_srcs_of(dst).iter().enumerate() {
                        let u = u as usize;
                        match prog.gather {
                            GatherOp::Identity => {
                                unreachable!("identity gather takes the staging-free fast path")
                            }
                            GatherOp::ProductWith(k) => {
                                let other = outputs[k].row(u);
                                if other.len() == 1 {
                                    // Scalar gate broadcast (G-GCN).
                                    let gmul = other[0];
                                    for (m, a) in msg.iter_mut().zip(src.row(u).iter()) {
                                        *m = a.sat_mul(gmul);
                                    }
                                } else {
                                    for (m, (a, b)) in
                                        msg.iter_mut().zip(src.row(u).iter().zip(other))
                                    {
                                        *m = a.sat_mul(*b);
                                    }
                                }
                            }
                            GatherOp::SumWith(k) => {
                                let other = outputs[k].row(u);
                                for (m, (a, b)) in msg.iter_mut().zip(src.row(u).iter().zip(other))
                                {
                                    *m = a.sat_add(*b);
                                }
                            }
                            GatherOp::Scale(c) => {
                                let c = Fx16::from_f32(c);
                                for (m, a) in msg.iter_mut().zip(src.row(u).iter()) {
                                    *m = a.sat_mul(c);
                                }
                            }
                        }
                        match prog.reduce {
                            ReduceOp::Sum | ReduceOp::Mean => {
                                for (r, m) in row.iter_mut().zip(msg.iter()) {
                                    *r = r.sat_add(*m);
                                }
                            }
                            ReduceOp::Max => {
                                if ei == 0 {
                                    row.copy_from_slice(msg);
                                } else {
                                    for (r, m) in row.iter_mut().zip(msg.iter()) {
                                        *r = (*r).max(*m);
                                    }
                                }
                            }
                        }
                    }
                }
            }
            if prog.reduce == ReduceOp::Mean {
                // The reduce PE divides by the in-degree (computed as a
                // reciprocal multiply in hardware); the CSR view gives
                // the degree in O(1).
                for dst in 0..v {
                    let deg = nl.in_degree(dst);
                    if deg > 1 {
                        let inv = Fx16::from_f32(1.0 / deg as f32);
                        for r in acc.row_mut(dst) {
                            *r = r.sat_mul(inv);
                        }
                    }
                }
            }
            acc
        }
    };

    // Self contribution (GIN): acc[v] += (1+eps) * src[v].
    if let Some(scale) = self_scale {
        for r in 0..acc.rows {
            let s_row = src.row(r);
            for (a, s) in acc.row_mut(r).iter_mut().zip(s_row) {
                *a = a.sat_add(s.sat_mul(scale));
            }
        }
    }

    // -------------------------------------------- vertex-accumulate phase
    let mut result = if let Some(t) = &prog.transform {
        if t.in_dim != dim {
            return Err(ExecError::DimMismatch {
                program: prog.name.clone(),
                expected: t.in_dim,
                got: dim,
            });
        }
        let w = weight.expect("resolved PlanArgs carries every transform weight");
        let out_dim = w.cols;
        // Vertex-tiled matmul: block the output dimension into Vt-wide
        // tiles (the PE array column count) and run the contraction with
        // the weight row contiguous in the inner loop — cache-friendly
        // and autovectorizable, vs the seed's column-strided walk. The
        // accumulator is the PE column reduction tree's wide (i64)
        // accumulate; integer adds reassociate freely, so tiling cannot
        // change the collapsed Q4.12 result.
        let mut y = scratch.matrix_empty(acc.rows, out_dim);
        let vt = scratch.vt;
        scratch.tile.clear();
        scratch.tile.resize(vt, 0i64);
        for r in 0..acc.rows {
            let a_row = acc.row(r);
            let mut o0 = 0usize;
            while o0 < out_dim {
                let tw = vt.min(out_dim - o0);
                let tile = &mut scratch.tile[..tw];
                tile.fill(0);
                for (i, &a) in a_row.iter().enumerate() {
                    if a.0 == 0 {
                        continue;
                    }
                    let a64 = a.0 as i64;
                    let w_row = &w.data[i * out_dim + o0..i * out_dim + o0 + tw];
                    for (t_acc, &wv) in tile.iter_mut().zip(w_row) {
                        *t_acc += a64 * wv.0 as i64;
                    }
                }
                // Tiles collapse left-to-right, rows top-to-bottom: the
                // append order is exactly row-major.
                y.data.extend(tile.iter().map(|&t_acc| Fx16::from_acc(t_acc)));
                o0 += tw;
            }
        }
        debug_assert_eq!(y.data.len(), y.rows * y.cols);
        scratch.give(acc.data);
        y
    } else {
        acc
    };

    // Vertex-accumulator chaining (Fig. 4 plus-boxes).
    if let Some(k) = prog.add_program {
        let other = &outputs[k];
        assert_eq!(other.cols, result.cols, "add_program dim");
        for r in 0..result.rows {
            for (a, b) in result.row_mut(r).iter_mut().zip(other.row(r)) {
                *a = a.sat_add(*b);
            }
        }
    }

    // ------------------------------------------------ vertex-update phase
    match prog.activate {
        Activate::None => {}
        Activate::Relu => {
            for x in result.data.iter_mut() {
                *x = x.relu();
            }
        }
        Activate::Sigmoid => {
            for x in result.data.iter_mut() {
                *x = scratch.sigmoid.eval(*x);
            }
        }
    }

    Ok(result)
}

// ---------------------------------------------------------------------------
// Reference (seed) implementation: unsorted edge-list walk
// ---------------------------------------------------------------------------

/// The seed executor, preserved verbatim as the bit-identical reference:
/// walks the unsorted `(u, v)` edge multiset with per-edge staging and
/// per-call weight quantization. Property tests pin the CSR hot path to
/// this, and `bench_exec` measures the speedup against it.
pub fn execute_model_ref(
    plan: &ModelPlan,
    nf: &Nodeflow,
    h: &[f32],
    args: &Args,
) -> Result<Vec<f32>, ExecError> {
    execute_model_ref_memo(plan, nf, h, args, None)
}

/// [`execute_model_ref`] with the same memo splice as
/// [`execute_model_into_memo`] — keeps the reference backend usable as
/// a second independent witness that memoized replies are bit-exact.
pub fn execute_model_ref_memo(
    plan: &ModelPlan,
    nf: &Nodeflow,
    h: &[f32],
    args: &Args,
    mut memo: Option<(&MemoPlan, &mut MemoHarvest)>,
) -> Result<Vec<f32>, ExecError> {
    assert_eq!(plan.layers.len(), nf.layers.len(), "plan/nodeflow layer count");
    let sigmoid = TwoLevelLut::new(LutConfig::sigmoid());

    let l0 = &nf.layers[0];
    let in_dim = plan.layers[0].in_dim;
    assert_eq!(h.len(), l0.num_inputs() * in_dim, "feature matrix shape");
    let mut features = Matrix {
        rows: l0.num_inputs(),
        cols: in_dim,
        data: h.iter().map(|&x| Fx16::from_f32(x)).collect(),
    };

    for (li, (lp, nl)) in plan.layers.iter().zip(nf.layers.iter()).enumerate() {
        let mut outputs: Vec<Matrix> = Vec::with_capacity(lp.programs.len());
        for prog in &lp.programs {
            let out = run_program_ref(prog, nl, &features, &outputs, args, &sigmoid)?;
            outputs.push(out);
        }
        features = outputs.swap_remove(lp.output_program);
        debug_assert_eq!(features.rows, nl.num_outputs);
        if let Some((mplan, hv)) = memo.as_mut() {
            splice_memo(&mut features, li, mplan, hv);
        }
    }

    Ok(features.data.iter().map(|x| x.to_f32()).collect())
}

fn run_program_ref(
    prog: &Program,
    nl: &NodeflowLayer,
    features: &Matrix,
    outputs: &[Matrix],
    args: &Args,
    sigmoid: &TwoLevelLut,
) -> Result<Matrix, ExecError> {
    let src: &Matrix = match prog.source {
        Src::LayerInput => features,
        Src::Program(k) => &outputs[k],
    };
    let dim = src.cols;
    let v = nl.num_outputs;

    // ---------------------------------------------- edge-accumulate phase
    let mut acc = match prog.domain {
        Domain::AllInputs => Matrix { rows: src.rows, cols: dim, data: src.data.clone() },
        Domain::Outputs => Matrix { rows: v, cols: dim, data: src.data[..v * dim].to_vec() },
        Domain::Edges => {
            let mut acc = Matrix::zeros(v, dim);
            let mut counts = vec![0u32; v];
            let mut msg = vec![Fx16::ZERO; dim];
            for &(u, dst) in &nl.edges {
                let (u, dst) = (u as usize, dst as usize);
                // gather UDF
                match prog.gather {
                    GatherOp::Identity => msg.copy_from_slice(src.row(u)),
                    GatherOp::ProductWith(k) => {
                        let other = outputs[k].row(u);
                        if other.len() == 1 {
                            // Scalar gate broadcast (G-GCN).
                            let gmul = other[0];
                            for (m, a) in msg.iter_mut().zip(src.row(u).iter()) {
                                *m = a.sat_mul(gmul);
                            }
                        } else {
                            for (m, (a, b)) in msg.iter_mut().zip(src.row(u).iter().zip(other)) {
                                *m = a.sat_mul(*b);
                            }
                        }
                    }
                    GatherOp::SumWith(k) => {
                        let other = outputs[k].row(u);
                        for (m, (a, b)) in msg.iter_mut().zip(src.row(u).iter().zip(other)) {
                            *m = a.sat_add(*b);
                        }
                    }
                    GatherOp::Scale(c) => {
                        let c = Fx16::from_f32(c);
                        for (m, a) in msg.iter_mut().zip(src.row(u).iter()) {
                            *m = a.sat_mul(c);
                        }
                    }
                }
                // reduce UDF
                let row = acc.row_mut(dst);
                match prog.reduce {
                    ReduceOp::Sum | ReduceOp::Mean => {
                        for (r, m) in row.iter_mut().zip(msg.iter()) {
                            *r = r.sat_add(*m);
                        }
                    }
                    ReduceOp::Max => {
                        if counts[dst] == 0 {
                            row.copy_from_slice(&msg);
                        } else {
                            for (r, m) in row.iter_mut().zip(msg.iter()) {
                                *r = (*r).max(*m);
                            }
                        }
                    }
                }
                counts[dst] += 1;
            }
            if prog.reduce == ReduceOp::Mean {
                for dst in 0..v {
                    if counts[dst] > 1 {
                        let inv = Fx16::from_f32(1.0 / counts[dst] as f32);
                        for r in acc.row_mut(dst) {
                            *r = r.sat_mul(inv);
                        }
                    }
                }
            }
            acc
        }
    };

    // Self contribution (GIN): acc[v] += (1+eps) * src[v].
    if let Some(ss) = &prog.self_scale {
        let scale = match ss {
            SelfScale::OnePlusArg(name) => Fx16::from_f32(1.0 + get_scalar(args, name)?),
            SelfScale::Const(c) => Fx16::from_f32(*c),
        };
        for r in 0..acc.rows {
            let s_row: Vec<Fx16> = src.row(r).iter().map(|x| x.sat_mul(scale)).collect();
            for (a, s) in acc.row_mut(r).iter_mut().zip(s_row) {
                *a = a.sat_add(s);
            }
        }
    }

    // -------------------------------------------- vertex-accumulate phase
    let mut result = if let Some(t) = &prog.transform {
        if t.in_dim != dim {
            return Err(ExecError::DimMismatch {
                program: prog.name.clone(),
                expected: t.in_dim,
                got: dim,
            });
        }
        let w = get_matrix(args, &t.weight)?;
        if w.rows != t.in_dim || w.cols != t.out_dim {
            return Err(ExecError::DimMismatch {
                program: prog.name.clone(),
                expected: t.in_dim * t.out_dim,
                got: w.rows * w.cols,
            });
        }
        let mut y = Matrix::zeros(acc.rows, t.out_dim);
        for r in 0..acc.rows {
            let a_row = acc.row(r);
            let y_row = y.row_mut(r);
            for (o, y_cell) in y_row.iter_mut().enumerate() {
                // Wide accumulate down the PE column reduction tree.
                let mut wide: i64 = 0;
                for (i, a) in a_row.iter().enumerate() {
                    wide = a.mac_into(w.data[i * w.cols + o], wide);
                }
                *y_cell = Fx16::from_acc(wide);
            }
        }
        y
    } else {
        acc
    };

    // Vertex-accumulator chaining (Fig. 4 plus-boxes).
    if let Some(k) = prog.add_program {
        let other = &outputs[k];
        assert_eq!(other.cols, result.cols, "add_program dim");
        for r in 0..result.rows {
            let o_row: Vec<Fx16> = other.row(r).to_vec();
            for (a, b) in result.row_mut(r).iter_mut().zip(o_row) {
                *a = a.sat_add(b);
            }
        }
    }

    // ------------------------------------------------ vertex-update phase
    match prog.activate {
        Activate::None => {}
        Activate::Relu => {
            for x in result.data.iter_mut() {
                *x = x.relu();
            }
        }
        Activate::Sigmoid => {
            for x in result.data.iter_mut() {
                *x = sigmoid.eval(*x);
            }
        }
    }

    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::graph::{generate, GeneratorParams};
    use crate::greta::program::{compile, GnnModel};
    use crate::nodeflow::Sampler;
    use crate::rng::GoldenLcg;

    fn small_mc() -> ModelConfig {
        ModelConfig { sample1: 4, sample2: 3, f_in: 12, f_hid: 10, f_out: 6 }
    }

    fn setup(mc: &ModelConfig) -> (Nodeflow, Vec<f32>) {
        let g = generate(&GeneratorParams { nodes: 500, mean_degree: 6.0, ..Default::default() });
        let nf = Nodeflow::build(&g, &Sampler::new(3), &[17], mc);
        let mut lcg = GoldenLcg::new(7);
        let h: Vec<f32> =
            lcg.fill(nf.layers[0].num_inputs() * mc.f_in).iter().map(|x| x * 0.5).collect();
        (nf, h)
    }

    fn weights_for(model: GnnModel, mc: &ModelConfig) -> Args {
        let plan = compile(model, mc);
        let mut lcg = GoldenLcg::new(99);
        let mut args = Args::new();
        for l in &plan.layers {
            for p in &l.programs {
                if let Some(t) = &p.transform {
                    let data: Vec<f32> =
                        lcg.fill(t.in_dim * t.out_dim).iter().map(|x| x * 0.4).collect();
                    args.insert(t.weight.to_string(), (vec![t.in_dim, t.out_dim], data));
                }
            }
        }
        args.insert("eps1".into(), (vec![], vec![0.1]));
        args.insert("eps2".into(), (vec![], vec![0.2]));
        args
    }

    /// Float reference of GCN over the same nodeflow for cross-checking.
    fn gcn_float_ref(nf: &Nodeflow, h: &[f32], args: &Args, mc: &ModelConfig) -> Vec<f32> {
        let mut cur: Vec<Vec<f32>> = h.chunks(mc.f_in).map(|r| r.to_vec()).collect();
        for (li, w_name) in ["w1", "w2"].iter().enumerate() {
            let (shape, w) = &args[*w_name];
            let (ind, outd) = (shape[0], shape[1]);
            let l = &nf.layers[li];
            let mut agg = vec![vec![0f32; ind]; l.num_outputs];
            let mut counts = vec![0usize; l.num_outputs];
            for &(u, v) in &l.edges {
                for i in 0..ind {
                    agg[v as usize][i] += cur[u as usize][i];
                }
                counts[v as usize] += 1;
            }
            for v in 0..l.num_outputs {
                if counts[v] > 0 {
                    for x in agg[v].iter_mut() {
                        *x /= counts[v] as f32;
                    }
                }
            }
            let mut next = vec![vec![0f32; outd]; l.num_outputs];
            for v in 0..l.num_outputs {
                for o in 0..outd {
                    let mut s = 0f32;
                    for i in 0..ind {
                        s += agg[v][i] * w[i * outd + o];
                    }
                    next[v][o] = s.max(0.0);
                }
            }
            cur = next;
        }
        cur.into_iter().flatten().collect()
    }

    #[test]
    fn gcn_matches_float_reference() {
        let mc = small_mc();
        let (nf, h) = setup(&mc);
        let args = weights_for(GnnModel::Gcn, &mc);
        let plan = compile(GnnModel::Gcn, &mc);
        let got = execute_model(&plan, &nf, &h, &args).unwrap();
        let want = gcn_float_ref(&nf, &h, &args, &mc);
        assert_eq!(got.len(), mc.f_out);
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 0.02, "{g} vs {w}");
        }
    }

    #[test]
    fn all_models_execute() {
        let mc = small_mc();
        let (nf, h) = setup(&mc);
        for model in [GnnModel::Gcn, GnnModel::Sage, GnnModel::Gin, GnnModel::Ggcn] {
            let args = weights_for(model, &mc);
            let plan = compile(model, &mc);
            let out = execute_model(&plan, &nf, &h, &args).unwrap();
            assert_eq!(out.len(), mc.f_out, "{model:?}");
            assert!(out.iter().all(|x| x.is_finite()));
            // All four models end in ReLU — outputs nonnegative.
            assert!(out.iter().all(|&x| x >= 0.0), "{model:?}");
        }
    }

    #[test]
    fn csr_path_matches_reference_path() {
        let mc = small_mc();
        let (nf, h) = setup(&mc);
        for model in [GnnModel::Gcn, GnnModel::Sage, GnnModel::Gin, GnnModel::Ggcn] {
            let args = weights_for(model, &mc);
            let plan = compile(model, &mc);
            let fast = execute_model(&plan, &nf, &h, &args).unwrap();
            let slow = execute_model_ref(&plan, &nf, &h, &args).unwrap();
            assert_eq!(fast, slow, "{model:?}");
        }
    }

    #[test]
    fn tile_width_does_not_change_numerics() {
        let mc = small_mc();
        let (nf, h) = setup(&mc);
        let args = weights_for(GnnModel::Sage, &mc);
        let plan = compile(GnnModel::Sage, &mc);
        let pargs = PlanArgs::resolve(&plan, &args).unwrap();
        let mut want: Option<Vec<f32>> = None;
        for vt in [1usize, 3, 7, 32, 1024] {
            let mut scratch = ExecScratch::with_tile(vt);
            let mut out = Vec::new();
            execute_model_into(&plan, &nf, &h, &pargs, &mut scratch, &mut out).unwrap();
            match &want {
                None => want = Some(out),
                Some(w) => assert_eq!(&out, w, "vt={vt}"),
            }
        }
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        let mc = small_mc();
        let (nf, h) = setup(&mc);
        let args = weights_for(GnnModel::Ggcn, &mc);
        let plan = compile(GnnModel::Ggcn, &mc);
        let pargs = PlanArgs::resolve(&plan, &args).unwrap();
        let mut scratch = ExecScratch::new();
        let mut first = Vec::new();
        execute_model_into(&plan, &nf, &h, &pargs, &mut scratch, &mut first).unwrap();
        let mut again = Vec::new();
        for _ in 0..3 {
            execute_model_into(&plan, &nf, &h, &pargs, &mut scratch, &mut again).unwrap();
            assert_eq!(again, first);
        }
    }

    #[test]
    fn memo_inject_and_harvest_reproduce_baseline() {
        use crate::nodeflow::MemoProbe;
        use crate::runtime::fill_feature_row;
        let mc = small_mc();
        let g = generate(&GeneratorParams { nodes: 500, mean_degree: 6.0, ..Default::default() });
        let sampler = Sampler::new(3);
        let samples = [mc.sample1, mc.sample2];
        let plan = compile(GnnModel::Gcn, &mc);
        let args = weights_for(GnnModel::Gcn, &mc);
        let pargs = PlanArgs::resolve(&plan, &args).unwrap();
        // Vertex-keyed features (as staging synthesizes them), so the
        // pruned nodeflow's smaller input set stays consistent.
        let feats = |nf: &Nodeflow| -> Vec<f32> {
            let mut h = vec![0f32; nf.layers[0].num_inputs() * mc.f_in];
            for (i, &v) in nf.layers[0].inputs.iter().enumerate() {
                fill_feature_row(v, &mut h[i * mc.f_in..(i + 1) * mc.f_in]);
            }
            h
        };

        // Pass 1 (cold cache): harvest every interior row.
        struct HarvestAll;
        impl MemoProbe for HarvestAll {
            fn admits(&self, _l: usize, _v: u32, _d: usize) -> bool {
                true
            }
            fn lookup(&self, _l: usize, _v: u32) -> Option<Vec<Fx16>> {
                None
            }
        }
        let (nf, mplan) =
            Nodeflow::build_layers_memo(&g, &sampler, &[17], &samples, Some(&HarvestAll));
        let h = feats(&nf);
        let mut scratch = ExecScratch::new();
        let mut want = Vec::new();
        let mut harvest = MemoHarvest::default();
        execute_model_into_memo(
            &plan,
            &nf,
            &h,
            &pargs,
            &mut scratch,
            &mut want,
            Some((&mplan, &mut harvest)),
        )
        .unwrap();
        assert!(!harvest.rows.is_empty());

        // Pass 2 (warm cache): replay with every interior row cached —
        // the whole input layer's sampling is pruned away.
        struct Replay(HashMap<(u32, u32), Vec<Fx16>>);
        impl MemoProbe for Replay {
            fn admits(&self, _l: usize, _v: u32, _d: usize) -> bool {
                true
            }
            fn lookup(&self, l: usize, v: u32) -> Option<Vec<Fx16>> {
                self.0.get(&(l as u32, v)).cloned()
            }
        }
        let map: HashMap<(u32, u32), Vec<Fx16>> =
            harvest.rows.iter().map(|r| ((r.layer, r.vertex), r.values.clone())).collect();
        let (nf2, mplan2) =
            Nodeflow::build_layers_memo(&g, &sampler, &[17], &samples, Some(&Replay(map)));
        assert!(mplan2.pruned_vertices > 0);
        assert!(mplan2.harvest.is_empty(), "all interior rows hit");
        assert!(nf2.layers[0].edges.is_empty(), "every interior output pruned");
        assert!(nf2.total_edges() < nf.total_edges());
        assert!(nf2.neighborhood_size() <= nf.neighborhood_size());
        let h2 = feats(&nf2);
        let mut got = Vec::new();
        let mut hv2 = MemoHarvest::default();
        execute_model_into_memo(
            &plan,
            &nf2,
            &h2,
            &pargs,
            &mut scratch,
            &mut got,
            Some((&mplan2, &mut hv2)),
        )
        .unwrap();
        assert_eq!(got, want, "cached-row replay must be bit-identical");
        // The reference executor agrees over the same pruned flow.
        let mut hv3 = MemoHarvest::default();
        let got_ref =
            execute_model_ref_memo(&plan, &nf2, &h2, &args, Some((&mplan2, &mut hv3))).unwrap();
        assert_eq!(got_ref, want);
    }

    #[test]
    fn missing_weight_errors() {
        let mc = small_mc();
        let (nf, h) = setup(&mc);
        let plan = compile(GnnModel::Gcn, &mc);
        let err = execute_model(&plan, &nf, &h, &Args::new());
        assert!(matches!(err, Err(ExecError::MissingArg(_))));
    }

    #[test]
    fn non_matrix_weight_is_bad_shape() {
        let mc = small_mc();
        let (nf, h) = setup(&mc);
        let plan = compile(GnnModel::Gcn, &mc);
        let mut args = Args::new();
        // 1-D shape: present but not a matrix.
        args.insert("w1".into(), (vec![mc.f_in * mc.f_hid], vec![0.0; mc.f_in * mc.f_hid]));
        args.insert("w2".into(), (vec![mc.f_hid, mc.f_out], vec![0.0; mc.f_hid * mc.f_out]));
        let err = execute_model(&plan, &nf, &h, &args);
        match err {
            Err(ExecError::BadShape { name, shape }) => {
                assert_eq!(name, "w1");
                assert_eq!(shape, vec![mc.f_in * mc.f_hid]);
            }
            other => panic!("expected BadShape, got {other:?}"),
        }
        // And the message names the argument.
        let mut args3 = args.clone();
        args3.insert("w1".into(), (vec![2, 3, 4], vec![0.0; 24]));
        let msg = execute_model(&plan, &nf, &h, &args3).unwrap_err().to_string();
        assert!(msg.contains("w1") && msg.contains("not a matrix"), "{msg}");
    }

    #[test]
    fn gin_eps_changes_output() {
        let mc = small_mc();
        let (nf, h) = setup(&mc);
        let plan = compile(GnnModel::Gin, &mc);
        let mut args = weights_for(GnnModel::Gin, &mc);
        let a = execute_model(&plan, &nf, &h, &args).unwrap();
        args.insert("eps1".into(), (vec![], vec![2.0]));
        let b = execute_model(&plan, &nf, &h, &args).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn ggcn_gate_bounds() {
        // The gate program output (sigmoid LUT) must lie in [0, 1]; we
        // indirectly verify via monotonicity: scaling the message weights
        // up scales outputs up (gates fixed).
        let mc = small_mc();
        let (nf, h) = setup(&mc);
        let plan = compile(GnnModel::Ggcn, &mc);
        let args = weights_for(GnnModel::Ggcn, &mc);
        let out = execute_model(&plan, &nf, &h, &args).unwrap();
        assert!(out.iter().all(|x| x.is_finite()));
    }
}
