//! The UDF vocabulary of our PE implementation (paper Sec. V-A):
//! gather ∈ {identity, element-wise sum/product, scale-by-constant};
//! reduce ∈ {sum, max, mean}; transform = matmul (+ element-wise sum);
//! activate ∈ {ReLU, two-level LUT}.


/// What a program iterates over, determining its nodeflow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Domain {
    /// The layer's bipartite nodeflow edges (edge-accumulate is real
    /// gather/reduce work).
    Edges,
    /// An identity nodeflow over all U input vertices (paper Fig. 3a:
    /// per-vertex programs such as G-GCN's gate computation).
    AllInputs,
    /// An identity nodeflow over the V output vertices (e.g. the self
    /// term of GraphSAGE's update).
    Outputs,
}

/// Gather UDF: forms the per-edge message.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GatherOp {
    /// Pass the source feature through (most models).
    Identity,
    /// Element-wise product of the source feature with another program's
    /// output for the same source vertex (G-GCN's gate ⊙ message).
    ProductWith(usize),
    /// Element-wise sum with another program's output.
    SumWith(usize),
    /// Scale the source feature by a constant.
    Scale(f32),
}

/// Reduce UDF: accumulates messages per output vertex.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Max,
    Mean,
}

/// Optional self-contribution folded into the edge accumulator before
/// transform (GIN's `(1 + eps) · h_v`). Argument names are owned so
/// data-driven [`crate::greta::ModelSpec`]s can name their scalars
/// freely (the pre-redesign IR pinned them to `&'static str` literals).
#[derive(Debug, Clone, PartialEq)]
pub enum SelfScale {
    /// `1 + eps` with eps supplied as a runtime scalar argument.
    OnePlusArg(String),
    /// Fixed constant.
    Const(f32),
}

/// Activate UDF (vertex-update phase).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activate {
    None,
    Relu,
    /// Two-level LUT programmed with sigmoid (G-GCN).
    Sigmoid,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ops_are_copy_and_comparable() {
        let g = GatherOp::ProductWith(0);
        assert_eq!(g, GatherOp::ProductWith(0));
        assert_ne!(g, GatherOp::Identity);
        assert_eq!(ReduceOp::Max, ReduceOp::Max);
    }
}
