//! `grip` — CLI for the GRIP reproduction.
//!
//! Subcommands:
//!   repro       --exp <id>|--all [--scale S] [--targets N]  regenerate paper tables/figures
//!   serve       --model M --dataset D [--requests N]        end-to-end serving (timing + PJRT numerics)
//!   serve-bench --dataset D [--rates R1,R2,..] [--shards S1,S2,..]
//!                                                           open-loop rate × shard sweep → BENCH_serve.json
//!   sim         --model M --dataset D                       one simulated inference, unit breakdown
//!   verify                                                  golden-vector check of every HLO artifact
//!   info                                                    Table II configuration dump
//!
//! (Hand-rolled argument parsing: the build environment is offline and
//! the vendored crate set has no clap.)

use grip::backend::{BackendChoice, BACKEND_NAME_HELP};
use grip::config::{GripConfig, ModelConfig};
use grip::coordinator::{
    run_workload, ControlConfig, ControlMode, Coordinator, InferenceRequest, ServeConfig,
};
use grip::graph::{Dataset, PartitionStrategy};
use grip::greta::{compile, GnnModel, ModelKey, ModelLibrary, ModelSpec, MODEL_NAME_HELP};
use grip::nodeflow::{Nodeflow, Sampler};
use grip::repro::ReproCtx;
use grip::residency::EvictPolicy;
use grip::rng::SplitMix64;
use grip::runtime::{Executor, Manifest};
use grip::sim::simulate;

fn usage() -> ! {
    eprintln!(
        "usage: grip <cmd> [options]\n\
         \n\
         commands:\n\
           repro   --exp <table1|table2|table3|table4|fig2|fig9a|fig9b|fig10a..d|fig11a|fig11b|fig12|fig13a|fig13b|all>\n\
                   [--scale S=0.01] [--targets N=128] [--seed K=17]\n\
           serve   [--model M] [--model-spec FILE.json] [--dataset yt|lj|po|rd] [--requests N=256]\n\
                   [--scale S=0.01] [--backend B] [--no-numerics] [--shards K=1]\n\
                   [--partition degree|hash|off] [--cache-rows N]\n\
                   [--pipeline on|off] [--prefetch-lanes N=2] [--pipeline-depth K=2]\n\
                   [--control off|static|adaptive] [--control-interval-ms T=50]\n\
                   [--tenants N=0] [--weight-budget-bytes B=0 (unlimited)]\n\
                   [--evict lru|cost|size-aware] [--memo-rows N=0 (off)]\n\
                   [--trace-sample N=64] [--trace-out FILE.json] [--metrics-out FILE.prom]\n\
           serve-bench  [--dataset yt|lj|po|rd] [--scale S=0.01] [--requests N=160]\n\
                   [--rates R1,R2,..=25,50,100] [--shards S1,S2,..=1,4] [--slo-us U=5000]\n\
                   [--partition P1,P2,..=off (degree|hash|off)] [--target-skew S=0 (Zipf exponent)]\n\
                   [--no-batching] [--bursty] [--paper-dims] [--model-spec FILE.json]\n\
                   [--backend B=fixed] [--seed K=17] [--out PATH] [--cache-rows N]\n\
                   [--pipeline on|off] [--prefetch-lanes N=2] [--pipeline-depth K=2]\n\
                   [--control C1,C2,..=off (off|static|adaptive)] [--control-interval-ms T=50]\n\
                   [--tenants N=0] [--tenant-skew S=0 (Zipf exponent over models)]\n\
                   [--weight-budgets B1,B2,..=0] [--evict E1,E2,..=lru (lru|cost|size-aware)]\n\
                   [--memo-rows B1,B2,..=0 (row budgets; 0 = off)]\n\
                   [--submit-lanes W=0 (auto)]\n\
                   [--trace-sample N=64] [--trace-out FILE.json] [--metrics-out FILE.prom]\n\
           sim     [--model M] [--model-spec FILE.json] [--dataset D] [--scale S]\n\
           verify\n\
           info\n\
         \n\
         --model M accepts: {MODEL_NAME_HELP}\n\
         --model-spec loads a custom model description (JSON schema: examples/MODEL_SPEC.md);\n\
           by default a spec serves on the Q4.12 fixed-point path (no AOT artifact exists for it)\n\
         --backend B selects the per-shard execution engine: {BACKEND_NAME_HELP}\n\
           (contract: examples/BACKENDS.md; serve defaults to pjrt for presets, fixed for specs;\n\
           --no-numerics is the legacy spelling of --backend timing)\n\
         --prefetch-lanes/--pipeline-depth shape each shard's phase pipeline (edge-centric\n\
           feature-prefetch lanes feeding the vertex engine; --pipeline off = sequential loop;\n\
           replies are bit-identical either way)\n\
         --partition shards the graph: degree (LPT degree-balanced) or hash partitions with\n\
           partition-local feature caches, home-shard routing, and cross-shard boundary\n\
           fetches; off = one shared queue + cache (examples/SHARDING.md; replies are\n\
           bit-identical in every mode)\n\
         --control runs the adaptive SLO control plane (examples/CONTROL.md): off = no\n\
           controller (default; historical behavior), static = controller observes and logs\n\
           but holds every knob, adaptive = hysteresis/AIMD policy retunes batcher window,\n\
           prefetch lanes, pipeline depth, and active shards from stage telemetry; replies\n\
           are bit-identical in every mode (serve-bench accepts a comma list to sweep)\n\
         --target-skew draws serve-bench targets Zipf(s) instead of uniformly (0 = uniform)\n\
         --tenants registers N generated tenant models alongside the four presets and spreads\n\
           the request mix across every model (examples/TENANCY.md); --tenant-skew draws the\n\
           per-request model Zipf(s) over keys, hottest first (0 = equal weight) — arrival\n\
           times and targets never move, only the model column\n\
         --weight-budget-bytes caps each pool's prepared-weight bytes (split across shards\n\
           like --cache-rows); models page in on demand and evict under --evict (lru, cost =\n\
           cheapest bytes x prepare-cost per age, size-aware = largest first); 0 = unlimited\n\
           eager store (historical behavior); replies are bit-identical for any budget\n\
           (serve-bench sweeps comma lists via --weight-budgets and --evict)\n\
         --memo-rows caps the cross-request hub-embedding memo cache in cached interior-layer\n\
           rows (examples/MEMOIZATION.md): builders reuse exact Q4.12 activations for hot\n\
           high-degree vertices and prune the whole sampled subtree under each hit; exact\n\
           reuse, so replies are bit-identical for any budget; 0 = off (historical behavior);\n\
           only the fixed/reference backends memoize (serve-bench sweeps a comma list)\n\
         --trace-sample traces 1-in-N requests through every pipeline stage (0 = off; stage\n\
           histograms record regardless; examples/OBSERVABILITY.md); --trace-out writes the\n\
           sampled spans as Chrome trace_event JSON (load in Perfetto), --metrics-out writes\n\
           the end-of-run Prometheus text snapshot"
    );
    std::process::exit(2);
}

/// Tiny flag parser: --key value pairs plus boolean flags.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                eprintln!("unexpected argument: {a}");
                usage();
            }
        }
        Args { flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    fn model(&self) -> GnnModel {
        self.get("model")
            .map(|s| {
                GnnModel::from_name(s).unwrap_or_else(|| {
                    eprintln!("unknown model {s:?}; accepted names: {MODEL_NAME_HELP}");
                    usage()
                })
            })
            .unwrap_or(GnnModel::Gcn)
    }

    /// Load + validate the `--model-spec` file, if given.
    fn model_spec(&self) -> anyhow::Result<Option<ModelSpec>> {
        let Some(path) = self.get("model-spec") else { return Ok(None) };
        anyhow::ensure!(
            !self.has("model"),
            "--model and --model-spec are mutually exclusive; the spec file names its own model"
        );
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading model spec {path}: {e}"))?;
        let spec = ModelSpec::from_json_str(&text)
            .map_err(|e| anyhow::anyhow!("parsing model spec {path}: {e}"))?;
        // Surface validation errors now, with the file name attached.
        spec.compile().map_err(|e| anyhow::anyhow!("invalid model spec {path}: {e}"))?;
        Ok(Some(spec))
    }

    /// Parse `--backend`, if given (`--no-numerics` remains as the
    /// legacy spelling of `--backend timing` and must not conflict).
    fn backend(&self) -> anyhow::Result<Option<BackendChoice>> {
        let Some(name) = self.get("backend") else {
            return Ok(if self.has("no-numerics") {
                Some(BackendChoice::TimingOnly)
            } else {
                None
            });
        };
        anyhow::ensure!(
            !self.has("no-numerics"),
            "--backend and --no-numerics are mutually exclusive"
        );
        BackendChoice::from_name(name).map(Some).ok_or_else(|| {
            anyhow::anyhow!("unknown backend {name:?}; accepted: {BACKEND_NAME_HELP}")
        })
    }

    /// Parse the shard phase-pipeline flags (`--pipeline on|off`,
    /// `--prefetch-lanes`, `--pipeline-depth`).
    fn pipeline(&self) -> anyhow::Result<grip::coordinator::PipelineConfig> {
        use grip::coordinator::PipelineConfig;
        let mut pc = PipelineConfig::default();
        match self.get("pipeline") {
            None | Some("on") | Some("true") => {}
            Some("off") | Some("none") | Some("false") => pc.enabled = false,
            Some(v) => anyhow::bail!("unknown --pipeline {v:?}; accepted: on | off"),
        }
        for (flag, slot) in [
            ("prefetch-lanes", &mut pc.prefetch_lanes),
            ("pipeline-depth", &mut pc.depth),
        ] {
            if let Some(v) = self.get(flag) {
                *slot = v
                    .parse::<usize>()
                    .ok()
                    .filter(|&n| n >= 1)
                    .ok_or_else(|| {
                        anyhow::anyhow!("--{flag} wants a positive integer, got {v:?}")
                    })?;
            }
        }
        anyhow::ensure!(
            pc.enabled || (!self.has("prefetch-lanes") && !self.has("pipeline-depth")),
            "--pipeline off conflicts with --prefetch-lanes/--pipeline-depth"
        );
        Ok(pc)
    }

    /// Parse the single-mode `--control` + `--control-interval-ms`
    /// pair (serve; default `off` spawns no controller).
    fn control_cfg(&self) -> anyhow::Result<ControlConfig> {
        let mode = match self.get("control") {
            None => ControlMode::Off,
            Some(name) => ControlMode::from_name(name).ok_or_else(|| {
                anyhow::anyhow!("unknown --control {name:?}; accepted: off | static | adaptive")
            })?,
        };
        let interval_ms = self.get_usize("control-interval-ms", 50) as u64;
        anyhow::ensure!(interval_ms >= 1, "--control-interval-ms wants a positive integer");
        Ok(ControlConfig { mode, interval_ms })
    }

    /// Parse the comma-separated `--control` sweep list (serve-bench;
    /// default `off` keeps the historical label set and sweep cost).
    fn control_list(&self) -> anyhow::Result<Vec<ControlMode>> {
        let s = self.get("control").unwrap_or("off");
        let mut out = Vec::new();
        for tok in s.split(',') {
            let name = tok.trim();
            let m = ControlMode::from_name(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown --control entry {name:?}; accepted: off | static | adaptive"
                )
            })?;
            if !out.contains(&m) {
                out.push(m);
            }
        }
        anyhow::ensure!(!out.is_empty(), "--control list is empty");
        Ok(out)
    }

    /// Parse the single `--evict` policy (serve; default LRU — inert
    /// unless `--weight-budget-bytes` is set).
    fn evict(&self) -> anyhow::Result<EvictPolicy> {
        match self.get("evict") {
            None => Ok(EvictPolicy::default()),
            Some(name) => EvictPolicy::from_name(name).ok_or_else(|| {
                anyhow::anyhow!("unknown --evict {name:?}; accepted: lru | cost | size-aware")
            }),
        }
    }

    /// Parse the comma-separated `--evict` sweep list (serve-bench;
    /// default `lru`).
    fn evict_list(&self) -> anyhow::Result<Vec<EvictPolicy>> {
        let s = self.get("evict").unwrap_or("lru");
        let mut out = Vec::new();
        for tok in s.split(',') {
            let name = tok.trim();
            let p = EvictPolicy::from_name(name).ok_or_else(|| {
                anyhow::anyhow!("unknown --evict entry {name:?}; accepted: lru | cost | size-aware")
            })?;
            if !out.contains(&p) {
                out.push(p);
            }
        }
        anyhow::ensure!(!out.is_empty(), "--evict list is empty");
        Ok(out)
    }

    /// Parse a single `--partition` strategy (serve; default `off`).
    fn partition(&self) -> anyhow::Result<PartitionStrategy> {
        match self.get("partition") {
            None => Ok(PartitionStrategy::Off),
            Some(name) => PartitionStrategy::from_name(name).ok_or_else(|| {
                anyhow::anyhow!("unknown --partition {name:?}; accepted: degree | hash | off")
            }),
        }
    }

    /// Parse the comma-separated `--partition` sweep list (serve-bench;
    /// default `off` keeps the PR-5 label set and sweep cost).
    fn partition_list(&self) -> anyhow::Result<Vec<PartitionStrategy>> {
        let s = self.get("partition").unwrap_or("off");
        let mut out = Vec::new();
        for tok in s.split(',') {
            let name = tok.trim();
            let p = PartitionStrategy::from_name(name).ok_or_else(|| {
                anyhow::anyhow!("unknown --partition entry {name:?}; accepted: degree | hash | off")
            })?;
            if !out.contains(&p) {
                out.push(p);
            }
        }
        anyhow::ensure!(!out.is_empty(), "--partition list is empty");
        Ok(out)
    }

    fn dataset(&self) -> Dataset {
        self.get("dataset")
            .map(|s| Dataset::from_name(s).unwrap_or_else(|| usage()))
            .unwrap_or(Dataset::Pokec)
    }
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else { usage() };
    let args = Args::parse(&argv[1..]);

    match cmd.as_str() {
        "repro" => cmd_repro(&args),
        "serve" => cmd_serve(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "sim" => cmd_sim(&args),
        "verify" => cmd_verify(),
        "info" => cmd_info(&args),
        _ => usage(),
    }
}

fn ctx_from(args: &Args) -> ReproCtx {
    ReproCtx {
        scale: args.get_f64("scale", 0.01),
        targets_per_dataset: args.get_usize("targets", 128),
        seed: args.get_usize("seed", 17) as u64,
        grip: GripConfig::paper(),
        mc: ModelConfig::paper(),
    }
}

fn cmd_repro(args: &Args) -> anyhow::Result<()> {
    let exp = if args.has("all") { "all" } else { args.get("exp").unwrap_or("all") };
    let ctx = ctx_from(args);
    let mut out = std::io::stdout().lock();
    grip::repro::run(exp, &ctx, &mut out)
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let model = args.model();
    let spec = args.model_spec()?;
    let dataset = args.dataset();
    let n = args.get_usize("requests", 256);
    let scale = args.get_f64("scale", 0.01);
    // Default engine: PJRT float for presets; a spec-defined model has
    // no AOT artifact yet, so it defaults to the Q4.12 fixed-point
    // path. `--backend` overrides either.
    let backend = args.backend()?.unwrap_or(if spec.is_some() {
        BackendChoice::Fixed
    } else {
        BackendChoice::Pjrt
    });

    let pipeline = args.pipeline()?;
    let partition = args.partition()?;
    let control = args.control_cfg()?;
    let tenants = args.get_usize("tenants", 0);
    let weight_budget_bytes = args.get_usize("weight-budget-bytes", 0);
    let evict = args.evict()?;
    let memo_rows = args.get_usize("memo-rows", 0);

    eprintln!("generating {dataset:?} graph (scale {scale}) ...");
    let graph = dataset.generate(scale, 17);
    let num_v = graph.num_vertices();
    let defaults = ServeConfig::default();
    let mut custom_specs: Vec<ModelSpec> = spec.iter().cloned().collect();
    custom_specs.extend(grip::residency::tenant_zoo(tenants, &defaults.model_cfg));
    let cfg = ServeConfig {
        backend,
        pipeline,
        partition,
        control,
        shards: args.get_usize("shards", defaults.shards),
        cache_rows: args.get_usize("cache-rows", defaults.cache_rows),
        custom_specs,
        trace_sample: args.get_usize("trace-sample", defaults.trace_sample as usize) as u64,
        weight_budget_bytes,
        evict,
        memo_rows,
        ..defaults
    };
    let coord = Coordinator::start(graph, 17, cfg)?;
    let (key, model_name) = match &spec {
        Some(s) => (coord.model_key(&s.name).expect("spec registered at start"), s.name.clone()),
        None => (model.key(), model.name().to_string()),
    };

    let mut rng = SplitMix64::new(99);
    let targets: Vec<u32> = (0..n).map(|_| rng.gen_range(num_v) as u32).collect();
    let t0 = std::time::Instant::now();
    // Multi-tenant mix: round-robin the request stream over every
    // registered model (presets + spec + zoo) so the weight store pages
    // under live traffic; without --tenants the historical single-model
    // workload runs unchanged.
    let (accel, host, responses) = if tenants > 0 {
        let keys: Vec<ModelKey> =
            (0..coord.library().len()).map(ModelKey::from_index).collect();
        let mut pending = Vec::with_capacity(targets.len());
        for (i, &t) in targets.iter().enumerate() {
            pending.push(coord.submit(InferenceRequest::single(
                i as u64,
                keys[i % keys.len()],
                t,
            ))?);
        }
        let mut accel = grip::coordinator::LatencyStats::new();
        let mut host = grip::coordinator::LatencyStats::new();
        let mut responses = Vec::with_capacity(pending.len());
        for rx in pending {
            let r = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("pipeline dropped"))?
                .map_err(|e| anyhow::anyhow!(e))?;
            accel.record(r.accel_us);
            host.record(r.host_us);
            responses.push(r);
        }
        (accel, host, responses)
    } else {
        run_workload(&coord, key, &targets)?
    };
    let wall = t0.elapsed().as_secs_f64();

    let mix_name = if tenants > 0 {
        format!("{model_name} + {tenants}-tenant zoo (round-robin over {} models)", coord.library().len())
    } else {
        model_name.clone()
    };
    println!("== serve: {mix_name} on {dataset:?}, {n} requests ==");
    println!(
        "accelerator latency (simulated): p50 {:.1} µs  p99 {:.1} µs  mean {:.1} µs",
        accel.p50(),
        accel.p99(),
        accel.mean()
    );
    // Per-request service time (build + exec, no queue wait) — the
    // closed-loop workload saturates the queue, so submit-to-response
    // percentiles would measure backlog instead.
    let mut service = grip::coordinator::LatencyStats::new();
    for r in &responses {
        service.record(r.service_us);
    }
    println!(
        "host service (nodeflow+sim+PJRT): p50 {:.1} µs  p99 {:.1} µs",
        service.p50(),
        service.p99()
    );
    println!(
        "end-to-end incl. queue (closed-loop): p50 {:.1} µs  p99 {:.1} µs",
        host.p50(),
        host.p99()
    );
    println!("throughput: {:.0} req/s (host wall clock)", n as f64 / wall);
    // Per-shard backend status: construction failures no longer hide
    // in stderr — they are part of the serving stats.
    let stats = coord.serve_stats();
    println!(
        "backend: requested {backend}, per-shard [{}]{}",
        stats.shard_backends.join(", "),
        if stats.backend_fallbacks > 0 {
            format!(" — {} shard(s) fell back to timing-only", stats.backend_fallbacks)
        } else {
            String::new()
        }
    );
    // Phase-pipeline health: which side of the lane → engine queue
    // waited, and how full it ran (next to the sim's phase overlap).
    if pipeline.enabled {
        println!(
            "pipeline {}: {} staged jobs, occupancy {:.2}, stalls prefetch {} / engine {}, \
             sim phase overlap {:.1}%",
            pipeline.label(),
            stats.staged_jobs,
            stats.prefetch_occupancy,
            stats.prefetch_stalls,
            stats.engine_stalls,
            stats.sim_phase_overlap * 100.0
        );
    } else {
        println!("pipeline off (sequential shard loop)");
    }
    // Partitioned serving: locality + routing health per partition.
    if partition != PartitionStrategy::Off {
        println!(
            "partition {}: edge-cut {:.1}%, balance {:.2}, cache rows {:?} (total {}), \
             routed {:?}, boundary fetches {} ({} rows, p99 {:.1} µs)",
            stats.partition,
            stats.edge_cut_fraction * 100.0,
            stats.partition_balance,
            stats.shard_cache_rows,
            stats.cache_rows_total,
            stats.routed_jobs,
            stats.boundary_fetches,
            stats.boundary_rows,
            stats.boundary_fetch_p99_us
        );
    }
    // Control-plane summary: what the controller saw and did (knob
    // moves reshape scheduling only — replies stay bit-identical).
    if control.mode != ControlMode::Off {
        let c = &stats.control;
        println!(
            "control {} (tick {} ms): {} ticks, {} actions (lanes {} / depth {} / window {} / \
             shards {}), final lanes {} depth {} window {:.0} µs active shards {}",
            c.mode,
            control.interval_ms,
            c.ticks,
            c.actions,
            c.lane_actions,
            c.depth_actions,
            c.window_actions,
            c.shard_actions,
            c.final_lanes,
            c.final_depth,
            c.final_window_us,
            c.final_active_shards
        );
        for line in c.log.iter().take(8) {
            println!("  {line}");
        }
        if c.log.len() > 8 {
            println!("  ... and {} more actions", c.log.len() - 8);
        }
    }
    // Weight-residency health: how the byte-budgeted store paged under
    // the mix (absent with the unlimited eager store).
    if stats.residency_budget_bytes > 0 {
        println!(
            "residency {} (budget {} B): hit rate {:.1}% ({} hits / {} misses), {} evictions, \
             resident {} B / {} models, prepare p50 {:.0} µs p99 {:.0} µs{}",
            stats.residency_policy,
            stats.residency_budget_bytes,
            stats.residency_hit_rate * 100.0,
            stats.residency_hits,
            stats.residency_misses,
            stats.residency_evictions,
            stats.residency_resident_bytes,
            stats.residency_resident_models,
            stats.residency_prepare_p50_us,
            stats.residency_prepare_p99_us,
            if stats.residency_prepare_failures > 0 {
                format!(" — {} prepare failure(s)", stats.residency_prepare_failures)
            } else {
                String::new()
            }
        );
    }
    // Memoization health: exact activation reuse and how much sampling
    // work the pruned subtrees saved (absent with --memo-rows 0).
    if stats.memo_rows_total > 0 {
        println!(
            "memo {} rows: hit rate {:.1}% ({} hits / {} misses), {} deposits / {} evictions, \
             resident {} rows ({} B), pruned {} vertices / {} edges, dedup {} — staged {} rows",
            stats.memo_rows_total,
            stats.memo_hit_rate * 100.0,
            stats.memo_hits,
            stats.memo_misses,
            stats.memo_deposits,
            stats.memo_evictions,
            stats.memo_resident_rows,
            stats.memo_resident_bytes,
            stats.memo_pruned_vertices,
            stats.memo_pruned_edges,
            stats.memo_dedup_hits,
            stats.staged_rows
        );
    }
    // Per-stage latency breakdown from the always-on stage histograms:
    // where a request's time went, not just how long it took.
    println!(
        "stages (p50/p99 µs): queue {:.0}/{:.0} | prefetch-local {:.0}/{:.0} | \
         boundary {:.0}/{:.0} | compute {:.0}/{:.0} | reply {:.0}/{:.0}",
        stats.queue_wait_p50_us,
        stats.queue_wait_p99_us,
        stats.prefetch_local_p50_us,
        stats.prefetch_local_p99_us,
        stats.boundary_wait_p50_us,
        stats.boundary_wait_p99_us,
        stats.compute_p50_us,
        stats.compute_p99_us,
        stats.reply_p50_us,
        stats.reply_p99_us
    );
    if let Some(path) = args.get("trace-out") {
        let spans = coord.telemetry().take_spans();
        let n_spans = spans.len();
        let groups = vec![(format!("serve/{model_name}"), spans)];
        std::fs::write(path, grip::telemetry::chrome_trace_json(&groups))?;
        println!("wrote {path} ({n_spans} spans)");
    }
    if let Some(path) = args.get("metrics-out") {
        std::fs::write(path, stats.render_prometheus(coord.telemetry()))?;
        println!("wrote {path}");
    }
    if let Some(r) = responses.first() {
        if !r.embedding.is_empty() {
            let norm: f32 = r.embedding.iter().map(|x| x * x).sum::<f32>().sqrt();
            println!(
                "first embedding: dim {} l2 {:.4} (numeric path live)",
                r.embedding.len(),
                norm
            );
        }
    }
    Ok(())
}

/// Open-loop serving sweep: arrival rate × shard count, fixed-point
/// numerics, SLO-aware batching — writes per-point p50/p99 latency and
/// feature-cache hit rates into `BENCH_serve.json`.
fn cmd_serve_bench(args: &Args) -> anyhow::Result<()> {
    use grip::benchutil::write_bench_json;
    use grip::coordinator::BatchConfig;
    use grip::serve::{run_sweep, ArrivalProcess, ModelMix, OpenLoopConfig};

    let dataset = args.dataset();
    let scale = args.get_f64("scale", 0.01);
    let requests = args.get_usize("requests", 160);
    let seed = args.get_usize("seed", 17) as u64;
    let slo_us = args.get_f64("slo-us", 5_000.0);
    // Fixed-point numerics by default; `--backend pjrt` sweeps one
    // PJRT client per shard (shards degrade to counted timing-only
    // fallbacks when the runtime is unavailable).
    let backend = args.backend()?.unwrap_or(BackendChoice::Fixed);
    let rates = parse_list(args.get("rates").unwrap_or("25,50,100"))?;
    let shard_counts: Vec<usize> = parse_list(args.get("shards").unwrap_or("1,4"))?
        .into_iter()
        .map(|x| x as usize)
        .collect();

    // The paper's 602→512→256 dims put one fixed-point inference in the
    // tens of milliseconds — fine for overnight runs (--paper-dims),
    // too slow for a CI sweep, so the default shrinks feature dims
    // while keeping the paper's 25/10 sampling (locality, and thus
    // cache behavior, depends on sampling, not feature width).
    let model_cfg = if args.has("paper-dims") {
        grip::ModelConfig::paper()
    } else {
        grip::ModelConfig { f_in: 64, f_hid: 48, f_out: 16, ..grip::ModelConfig::paper() }
    };

    eprintln!("generating {dataset:?} graph (scale {scale}) ...");
    let graph = dataset.generate(scale, seed);
    // --model-spec: sweep the custom model alone instead of the
    // four-preset mix (its key follows the presets, resolved exactly as
    // the coordinator will assign it).
    let (custom_specs, mix) = match args.model_spec()? {
        Some(spec) => {
            let (_, keys) = ModelLibrary::with_customs(&model_cfg, std::slice::from_ref(&spec))
                .map_err(|e| anyhow::anyhow!("registering model spec: {e}"))?;
            eprintln!("serving custom spec {:?} ({} layers)", spec.name, spec.depth());
            (vec![spec], ModelMix::only(keys[0]))
        }
        None => (Vec::new(), ModelMix::default()),
    };
    let pipeline = args.pipeline()?;
    let partitions = args.partition_list()?;
    let controls = args.control_list()?;
    let control_interval_ms = {
        let v = args.get_usize("control-interval-ms", 50) as u64;
        anyhow::ensure!(v >= 1, "--control-interval-ms wants a positive integer");
        v
    };
    let budgets = parse_budget_list(args.get("weight-budgets").unwrap_or("0"))?;
    let evicts = args.evict_list()?;
    let memo_budgets = parse_budget_list(args.get("memo-rows").unwrap_or("0"))?;
    let defaults = OpenLoopConfig::default();
    let base = OpenLoopConfig {
        requests,
        mix,
        model_cfg,
        custom_specs,
        backend,
        pipeline,
        cache_rows: args.get_usize("cache-rows", defaults.cache_rows),
        target_skew: args.get_f64("target-skew", 0.0),
        tenants: args.get_usize("tenants", 0),
        tenant_skew: args.get_f64("tenant-skew", 0.0),
        submit_lanes: args.get_usize("submit-lanes", 0),
        trace_sample: args.get_usize("trace-sample", defaults.trace_sample as usize) as u64,
        batch: if args.has("no-batching") {
            None
        } else {
            Some(BatchConfig { slo_us, ..Default::default() })
        },
        seed,
        ..defaults
    };

    println!(
        "== serve-bench: {:?} scale {scale}, {} requests/point, {} rates x {} shard counts x \
         {} partition strategies x {} control modes x {} weight budgets x {} memo budgets, \
         backend {backend}, pipeline {}, target-skew {}, tenants {} (skew {}) ==",
        dataset,
        requests,
        rates.len(),
        shard_counts.len(),
        partitions.len(),
        controls.len(),
        budgets.len(),
        memo_budgets.len(),
        pipeline.label(),
        base.target_skew,
        base.tenants,
        base.tenant_skew
    );
    let bursty = args.has("bursty");
    let mut points = Vec::new();
    for &partition in &partitions {
        for &cmode in &controls {
            for &budget in &budgets {
                // Eviction is inert without a budget: the 0-budget
                // point runs once, keeping its historical label.
                let policies: &[EvictPolicy] =
                    if budget == 0 { std::slice::from_ref(&evicts[0]) } else { &evicts };
                for &policy in policies {
                    for &memo in &memo_budgets {
                        let point_base = OpenLoopConfig {
                            partition,
                            control: ControlConfig {
                                mode: cmode,
                                interval_ms: control_interval_ms,
                            },
                            weight_budget_bytes: budget,
                            evict: policy,
                            memo_rows: memo,
                            ..base.clone()
                        };
                        points.extend(run_sweep(
                            &graph,
                            &rates,
                            &shard_counts,
                            &point_base,
                            |rate| {
                                if bursty {
                                    ArrivalProcess::Bursty {
                                        base_rps: rate,
                                        burst_rps: rate * 4.0,
                                        base_dwell_ms: 200.0,
                                        burst_dwell_ms: 50.0,
                                    }
                                } else {
                                    ArrivalProcess::Poisson { rate_rps: rate }
                                }
                            },
                        )?);
                    }
                }
            }
        }
    }
    for (label, r) in &points {
        println!(
            "{label:<40} offered {:>7.0} rps | e2e p50 {:>9.0} µs p99 {:>9.0} µs | \
             cache hit {:>5.1}% (sim {:>5.1}%) | occ {:.2} stalls p{}/e{} overlap {:>4.1}% | \
             backends [{}]{}",
            r.offered_rps,
            r.e2e.p50(),
            r.e2e.p99(),
            r.stats.cache_hit_rate * 100.0,
            r.stats.sim_feature_hit_rate * 100.0,
            r.stats.prefetch_occupancy,
            r.stats.prefetch_stalls,
            r.stats.engine_stalls,
            r.stats.sim_phase_overlap * 100.0,
            r.stats.shard_backends.join(", "),
            if r.stats.backend_fallbacks > 0 {
                format!(" ({} fallback(s))", r.stats.backend_fallbacks)
            } else {
                String::new()
            }
        );
        if r.stats.partition != "off" {
            println!(
                "{:<40} partition {}: cut {:.1}% balance {:.2} | per-shard hit [{}] | \
                 routed {:?} | boundary {} pulls / {} rows, p99 {:.1} µs",
                "",
                r.stats.partition,
                r.stats.edge_cut_fraction * 100.0,
                r.stats.partition_balance,
                r.stats
                    .shard_cache_hit_rate
                    .iter()
                    .map(|h| format!("{:.1}%", h * 100.0))
                    .collect::<Vec<_>>()
                    .join(", "),
                r.stats.routed_jobs,
                r.stats.boundary_fetches,
                r.stats.boundary_rows,
                r.stats.boundary_fetch_p99_us
            );
        }
        if r.stats.residency_budget_bytes > 0 {
            println!(
                "{:<40} residency {}: budget {} B | hit {:.1}% ({} hits / {} misses) | \
                 {} evictions | resident {} B / {} models | prepare p50 {:.0} µs p99 {:.0} µs{}",
                "",
                r.stats.residency_policy,
                r.stats.residency_budget_bytes,
                r.stats.residency_hit_rate * 100.0,
                r.stats.residency_hits,
                r.stats.residency_misses,
                r.stats.residency_evictions,
                r.stats.residency_resident_bytes,
                r.stats.residency_resident_models,
                r.stats.residency_prepare_p50_us,
                r.stats.residency_prepare_p99_us,
                if r.stats.residency_prepare_failures > 0 {
                    format!(" | {} prepare failure(s)", r.stats.residency_prepare_failures)
                } else {
                    String::new()
                }
            );
        }
        if r.stats.memo_rows_total > 0 {
            println!(
                "{:<40} memo {} rows: hit {:.1}% ({} hits / {} misses) | {} deposits / {} \
                 evictions | resident {} rows ({} B) | pruned {} v / {} e | dedup {} | \
                 staged {} rows",
                "",
                r.stats.memo_rows_total,
                r.stats.memo_hit_rate * 100.0,
                r.stats.memo_hits,
                r.stats.memo_misses,
                r.stats.memo_deposits,
                r.stats.memo_evictions,
                r.stats.memo_resident_rows,
                r.stats.memo_resident_bytes,
                r.stats.memo_pruned_vertices,
                r.stats.memo_pruned_edges,
                r.stats.memo_dedup_hits,
                r.stats.staged_rows
            );
        }
        if r.stats.control.mode != "off" {
            println!(
                "{:<40} control {}: {} ticks / {} actions (lanes {} depth {} window {} \
                 shards {}) | final lanes {} depth {} window {:.0} µs shards {}",
                "",
                r.stats.control.mode,
                r.stats.control.ticks,
                r.stats.control.actions,
                r.stats.control.lane_actions,
                r.stats.control.depth_actions,
                r.stats.control.window_actions,
                r.stats.control.shard_actions,
                r.stats.control.final_lanes,
                r.stats.control.final_depth,
                r.stats.control.final_window_us,
                r.stats.control.final_active_shards
            );
        }
        println!(
            "{:<40} stages p99 µs: queue {:.0} | prefetch-local {:.0} | boundary {:.0} | \
             compute {:.0} | reply {:.0}",
            "",
            r.stats.queue_wait_p99_us,
            r.stats.prefetch_local_p99_us,
            r.stats.boundary_wait_p99_us,
            r.stats.compute_p99_us,
            r.stats.reply_p99_us
        );
    }
    let sections: Vec<(&str, Vec<(String, f64)>)> =
        points.iter().map(|(label, r)| (label.as_str(), r.metrics())).collect();
    let out_path = std::path::PathBuf::from(
        args.get("out").unwrap_or(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json")),
    );
    write_bench_json(&out_path, &sections)?;
    println!("wrote {}", out_path.display());
    // Exporters: one Chrome-trace process per sweep point; the
    // Prometheus snapshot is the last point's (each run has its own
    // registry — merged reporting lives in BENCH_serve.json).
    if let Some(path) = args.get("trace-out") {
        let groups: Vec<(String, Vec<grip::telemetry::SpanTrace>)> =
            points.iter().map(|(l, r)| (l.clone(), r.spans.clone())).collect();
        let n_spans: usize = groups.iter().map(|(_, s)| s.len()).sum();
        std::fs::write(path, grip::telemetry::chrome_trace_json(&groups))?;
        println!("wrote {path} ({n_spans} spans across {} points)", groups.len());
    }
    if let Some(path) = args.get("metrics-out") {
        if let Some((label, last)) = points.last() {
            std::fs::write(path, &last.prom)?;
            println!("wrote {path} (snapshot of {label})");
        }
    }
    Ok(())
}

/// Parse a comma-separated budget list ("0,65536") — bytes for
/// `--weight-budgets`, rows for `--memo-rows`. Unlike [`parse_list`]
/// zero is legal — budget 0 means the feature is off (unlimited eager
/// store / no memo cache) — and duplicates collapse so one sweep point
/// runs per budget.
fn parse_budget_list(s: &str) -> anyhow::Result<Vec<usize>> {
    let mut out = Vec::new();
    for tok in s.split(',') {
        let v: usize = tok
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad budget entry {tok:?} in {s:?}"))?;
        if !out.contains(&v) {
            out.push(v);
        }
    }
    anyhow::ensure!(!out.is_empty(), "budget list is empty");
    Ok(out)
}

/// Parse a comma-separated numeric list ("25,50,100"). Rejects — rather
/// than silently drops — malformed or non-positive entries, so a typo'd
/// `--rates` cannot shrink a sweep unnoticed.
fn parse_list(s: &str) -> anyhow::Result<Vec<f64>> {
    let mut out = Vec::new();
    for tok in s.split(',') {
        let v: f64 = tok
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad numeric list entry {tok:?} in {s:?}"))?;
        anyhow::ensure!(v > 0.0, "list entries must be positive, got {v}");
        out.push(v);
    }
    Ok(out)
}

fn cmd_sim(args: &Args) -> anyhow::Result<()> {
    let model = args.model();
    let dataset = args.dataset();
    let ctx = ctx_from(args);
    let g = dataset.generate(ctx.scale, ctx.seed);
    let sampler = Sampler::new(ctx.seed);
    let mut rng = SplitMix64::new(1);
    let target = rng.gen_range(g.num_vertices()) as u32;
    // A spec supplies its own plan, depth, and per-layer sampling;
    // presets use the 2-layer paper scheme.
    let (plan, samples) = match args.model_spec()? {
        Some(spec) => {
            let (lib, keys) = ModelLibrary::with_customs(&ctx.mc, std::slice::from_ref(&spec))
                .map_err(|e| anyhow::anyhow!("registering model spec: {e}"))?;
            (lib.plan(keys[0]).clone(), lib.samples(keys[0]).to_vec())
        }
        None => (compile(model, &ctx.mc), vec![ctx.mc.sample1, ctx.mc.sample2]),
    };
    let nf = Nodeflow::build_layers(&g, &sampler, &[target], &samples);
    let r = simulate(&ctx.grip, &plan, &nf);
    println!("== sim: {} on {:?}, target {target} ==", plan.name, dataset);
    println!("neighborhood: {} unique vertices, {} edges", nf.neighborhood_size(), nf.total_edges());
    println!("latency: {:.2} µs ({:.0} cycles)", r.us(&ctx.grip), r.cycles);
    for (i, l) in r.layers.iter().enumerate() {
        println!(
            "  layer {i}: span {:>9.0}cy  dram-feat {:>8.0}  dram-w {:>8.0}  edge {:>8.0}  vertex {:>9.0}  update {:>7.0}",
            l.span, l.dram_feature, l.dram_weight, l.edge, l.vertex, l.update
        );
    }
    let c = &r.counters;
    println!(
        "counters: dram {} B, weight-sram {} B, nodeflow-sram {} B, {} MACs",
        c.dram_bytes, c.weight_sram_bytes, c.nodeflow_sram_bytes, c.macs
    );
    Ok(())
}

fn cmd_verify() -> anyhow::Result<()> {
    println!("loading artifacts from {:?}", Manifest::default_dir());
    let exec = Executor::load(&Manifest::default_dir())?;
    let mut worst = 0f32;
    for name in exec.model_names() {
        let err = exec.verify_golden(name)?;
        println!("{name:<6} golden max|err| = {err:.3e}");
        worst = worst.max(err);
    }
    anyhow::ensure!(worst < 1e-3, "golden verification failed: {worst}");
    println!("all artifacts verified against python golden vectors");
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let ctx = ctx_from(args);
    let mut out = std::io::stdout().lock();
    grip::repro::run("table2", &ctx, &mut out)
}
