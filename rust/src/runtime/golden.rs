//! Golden argument generation — bit-for-bit the same stream as
//! `python/compile/aot.py::golden_args`, used to (a) verify every HLO
//! artifact end-to-end against the manifest's expected output and (b)
//! provide deterministic "pretrained" weights for serving.

use super::manifest::ModelArtifact;
use crate::rng::GoldenLcg;

/// Concrete golden arguments in manifest order. The first two args
/// (a1, a2) are thresholded to a 0/1 incidence at ~15% density; the
/// rest are dense values scaled by 0.25 — exactly what aot.py does.
pub fn golden_args(artifact: &ModelArtifact) -> Vec<Vec<f32>> {
    let mut lcg = GoldenLcg::new(artifact.golden_seed);
    artifact
        .args
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            let vals = lcg.fill(spec.numel());
            if i < 2 {
                vals.into_iter().map(|v| if v > 0.35 { 1.0 } else { 0.0 }).collect()
            } else {
                vals.into_iter().map(|v| v * 0.25).collect()
            }
        })
        .collect()
}

/// Deterministic model parameters for serving (everything after a1, a2,
/// h in the manifest): the golden weights scaled down by 0.4, so the
/// numeric path is reproducible without a training checkpoint *and*
/// activations stay inside the Q4.12 datapath range (the quantization-
/// scale calibration a real deployment performs; GIN's two-deep MLP over
/// 25-way sums otherwise saturates ±8).
pub fn serving_weights(artifact: &ModelArtifact) -> Vec<Vec<f32>> {
    let mut w = golden_args(artifact).split_off(3);
    for buf in &mut w {
        for x in buf.iter_mut() {
            *x *= 0.4;
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{ArgSpec, ModelArtifact};

    fn fake_artifact() -> ModelArtifact {
        ModelArtifact {
            name: "t".into(),
            hlo_path: "/dev/null".into(),
            hlo_pallas_path: None,
            args: vec![
                ArgSpec { name: "a1".into(), shape: vec![2, 3] },
                ArgSpec { name: "a2".into(), shape: vec![1, 2] },
                ArgSpec { name: "h".into(), shape: vec![3, 4] },
                ArgSpec { name: "w".into(), shape: vec![4, 2] },
            ],
            output_shape: vec![1, 2],
            golden_seed: 42,
            golden_row0: vec![],
        }
    }

    #[test]
    fn adjacency_args_are_binary() {
        let args = golden_args(&fake_artifact());
        assert!(args[0].iter().all(|&x| x == 0.0 || x == 1.0));
        assert!(args[1].iter().all(|&x| x == 0.0 || x == 1.0));
    }

    #[test]
    fn dense_args_scaled() {
        let args = golden_args(&fake_artifact());
        assert!(args[2].iter().all(|&x| x.abs() <= 0.125 + 1e-6));
        assert_eq!(args[3].len(), 8);
    }

    #[test]
    fn deterministic() {
        let a = golden_args(&fake_artifact());
        let b = golden_args(&fake_artifact());
        assert_eq!(a, b);
    }

    #[test]
    fn serving_weights_skip_nodeflow_args() {
        let w = serving_weights(&fake_artifact());
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].len(), 8);
    }
}
