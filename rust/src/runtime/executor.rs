//! PJRT executor: loads the AOT-compiled HLO text artifacts and runs
//! them on the CPU PJRT client. This is the only place the `xla` crate
//! is touched; Python never runs here.
//!
//! The `xla` dependency (and its downloaded xla_extension runtime) is
//! gated behind the `pjrt` cargo feature so the rest of the stack
//! builds fully offline — enabling the feature additionally requires
//! `cargo add xla` in a network-equipped environment (even an optional
//! registry dep would break offline lockfile generation). Without the
//! feature an API-compatible stub is compiled whose `Executor::load`
//! always fails; every caller already degrades gracefully (the
//! coordinator serves timing-only, the e2e tests skip).
//!
//! HLO *text* (not serialized HloModuleProto) is the interchange format:
//! jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example).

#[cfg(feature = "pjrt")]
mod imp {
    use crate::runtime::golden::{golden_args, serving_weights};
    use crate::runtime::manifest::{Manifest, ModelArtifact};
    use anyhow::{anyhow, Context, Result};
    use std::collections::HashMap;
    use std::path::Path;

    /// A loaded, compiled model executable with its serving weights
    /// resident on the device (transferred once at load; the request path
    /// only uploads the per-request nodeflow + features — EXPERIMENTS.md
    /// §Perf "weight-resident execution").
    pub struct LoadedModel {
        pub artifact: ModelArtifact,
        exe: xla::PjRtLoadedExecutable,
        weight_buffers: Vec<xla::PjRtBuffer>,
    }

    /// The PJRT runtime: one CPU client, one compiled executable per model.
    pub struct Executor {
        #[allow(dead_code)]
        client: xla::PjRtClient,
        models: HashMap<String, LoadedModel>,
        pub manifest: Manifest,
    }

    impl Executor {
        /// Load every model in the manifest and compile it on the CPU PJRT
        /// client (done once at startup; the request path only executes).
        pub fn load(artifact_dir: &Path) -> Result<Executor> {
            let manifest = Manifest::load(artifact_dir)?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
            let mut models = HashMap::new();
            for (name, artifact) in &manifest.models {
                let proto = xla::HloModuleProto::from_text_file(
                    artifact.hlo_path.to_str().context("hlo path utf-8")?,
                )
                .map_err(|e| anyhow!("{name}: loading HLO text: {e:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow!("{name}: compiling: {e:?}"))?;
                // Transfer the serving weights to device once. A
                // batch-1 variant must carry the SAME weight values as
                // its base artifact — the serving-weight stream first
                // consumes the pad-dependent (a1, a2, h) element
                // counts, so generating from the variant's own pads
                // would silently serve a different model whenever
                // `PjrtBackend::execute` picks the small shapes. The
                // weight arg shapes themselves are pad-independent, so
                // the base values fit the variant exactly.
                let weight_source = Manifest::base_name(name)
                    .and_then(|base| manifest.models.get(base))
                    .unwrap_or(artifact);
                let mut weight_buffers = Vec::new();
                for (spec, w) in artifact.args[3..].iter().zip(serving_weights(weight_source)) {
                    let buf = client
                        .buffer_from_host_buffer::<f32>(&w, &spec.shape, None)
                        .map_err(|e| anyhow!("{name}.{}: to device: {e:?}", spec.name))?;
                    weight_buffers.push(buf);
                }
                models.insert(
                    name.clone(),
                    LoadedModel { artifact: artifact.clone(), exe, weight_buffers },
                );
            }
            Ok(Executor { client, models, manifest })
        }

        pub fn model(&self, name: &str) -> Result<&LoadedModel> {
            self.models
                .get(name)
                .ok_or_else(|| anyhow!("model {name} not in manifest"))
        }

        pub fn model_names(&self) -> Vec<&str> {
            let mut v: Vec<&str> = self.models.keys().map(|s| s.as_str()).collect();
            v.sort_unstable();
            v
        }

        /// Execute a model with concrete arguments (manifest order, row-major
        /// f32 buffers matching each `ArgSpec`). Returns the flat output
        /// `[v2 × f_out]`.
        pub fn run(&self, name: &str, args: &[Vec<f32>]) -> Result<Vec<f32>> {
            let lm = self.model(name)?;
            anyhow::ensure!(
                args.len() == lm.artifact.args.len(),
                "{name}: expected {} args, got {}",
                lm.artifact.args.len(),
                args.len()
            );
            let mut literals = Vec::with_capacity(args.len());
            for (buf, spec) in args.iter().zip(lm.artifact.args.iter()) {
                anyhow::ensure!(
                    buf.len() == spec.numel(),
                    "{name}.{}: expected {} elements, got {}",
                    spec.name,
                    spec.numel(),
                    buf.len()
                );
                let lit = if spec.shape.is_empty() {
                    xla::Literal::from(buf[0])
                } else {
                    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(buf)
                        .reshape(&dims)
                        .map_err(|e| anyhow!("{name}.{}: reshape: {e:?}", spec.name))?
                };
                literals.push(lit);
            }
            let result = lm
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("{name}: execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("{name}: readback: {e:?}"))?;
            // Lowered with return_tuple=True: unwrap the 1-tuple.
            let out = result
                .to_tuple1()
                .map_err(|e| anyhow!("{name}: tuple unwrap: {e:?}"))?;
            out.to_vec::<f32>().map_err(|e| anyhow!("{name}: to_vec: {e:?}"))
        }

        /// Hot-path execution: per-request dynamic args (a1, a2, h) are
        /// uploaded; the model's serving weights are already device-resident.
        pub fn run_prepared(&self, name: &str, dynamic: &[Vec<f32>]) -> Result<Vec<f32>> {
            let lm = self.model(name)?;
            anyhow::ensure!(dynamic.len() == 3, "{name}: expected (a1, a2, h)");
            let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(3);
            for (buf, spec) in dynamic.iter().zip(lm.artifact.args.iter()) {
                anyhow::ensure!(
                    buf.len() == spec.numel(),
                    "{name}.{}: expected {} elements, got {}",
                    spec.name,
                    spec.numel(),
                    buf.len()
                );
                bufs.push(
                    self.client
                        .buffer_from_host_buffer::<f32>(buf, &spec.shape, None)
                        .map_err(|e| anyhow!("{name}.{}: to device: {e:?}", spec.name))?,
                );
            }
            let mut args: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
            args.extend(lm.weight_buffers.iter());
            let result = lm
                .exe
                .execute_b(&args)
                .map_err(|e| anyhow!("{name}: execute_b: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("{name}: readback: {e:?}"))?;
            let out = result
                .to_tuple1()
                .map_err(|e| anyhow!("{name}: tuple unwrap: {e:?}"))?;
            out.to_vec::<f32>().map_err(|e| anyhow!("{name}: to_vec: {e:?}"))
        }

        /// Compile and run the *Pallas-bodied* variant of `name` once with
        /// the given full argument list — structural validation that the L1
        /// vertex-tiling kernel lowers to executable HLO and computes the
        /// same numbers as the fused serving artifact. (Interpret-mode
        /// Pallas loops are slow on CPU; this is a validation path, not the
        /// request path.)
        pub fn run_pallas_variant(&self, name: &str, args: &[Vec<f32>]) -> Result<Vec<f32>> {
            let lm = self.model(name)?;
            let path = lm
                .artifact
                .hlo_pallas_path
                .as_ref()
                .ok_or_else(|| anyhow!("{name}: no pallas artifact in manifest"))?;
            let proto = xla::HloModuleProto::from_text_file(path.to_str().context("path utf-8")?)
                .map_err(|e| anyhow!("{name}: loading pallas HLO: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("{name}: compiling pallas variant: {e:?}"))?;
            let mut literals = Vec::with_capacity(args.len());
            for (buf, spec) in args.iter().zip(lm.artifact.args.iter()) {
                let lit = if spec.shape.is_empty() {
                    xla::Literal::from(buf[0])
                } else {
                    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(buf)
                        .reshape(&dims)
                        .map_err(|e| anyhow!("{name}.{}: reshape: {e:?}", spec.name))?
                };
                literals.push(lit);
            }
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("{name}: execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("{name}: readback: {e:?}"))?;
            let out = result.to_tuple1().map_err(|e| anyhow!("{name}: tuple: {e:?}"))?;
            out.to_vec::<f32>().map_err(|e| anyhow!("{name}: to_vec: {e:?}"))
        }

        /// Run the golden vector for `name` and compare the first output row
        /// against the manifest's expectation. Returns the max abs error.
        pub fn verify_golden(&self, name: &str) -> Result<f32> {
            let lm = self.model(name)?;
            let args = golden_args(&lm.artifact);
            let out = self.run(name, &args)?;
            let f_out = *lm.artifact.output_shape.last().unwrap_or(&1);
            anyhow::ensure!(
                lm.artifact.golden_row0.len() == f_out,
                "{name}: golden row length mismatch"
            );
            let mut max_err = 0f32;
            for (got, want) in out[..f_out].iter().zip(lm.artifact.golden_row0.iter()) {
                max_err = max_err.max((got - want).abs());
            }
            Ok(max_err)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use crate::runtime::manifest::{Manifest, ModelArtifact};
    use anyhow::{bail, Result};
    use std::path::Path;

    /// Stub of the PJRT [`LoadedModel`] — never constructed; exists so
    /// non-`pjrt` builds typecheck every caller.
    pub struct LoadedModel {
        pub artifact: ModelArtifact,
    }

    /// Stub of the PJRT [`Executor`]. `load` always fails, so the other
    /// methods are unreachable at runtime but keep callers compiling.
    pub struct Executor {
        pub manifest: Manifest,
    }

    impl Executor {
        pub fn load(_artifact_dir: &Path) -> Result<Executor> {
            bail!("PJRT runtime not compiled in (build with `--features pjrt`)")
        }

        pub fn model(&self, name: &str) -> Result<&LoadedModel> {
            bail!("PJRT runtime not compiled in; no model {name}")
        }

        pub fn model_names(&self) -> Vec<&str> {
            Vec::new()
        }

        pub fn run(&self, name: &str, _args: &[Vec<f32>]) -> Result<Vec<f32>> {
            bail!("PJRT runtime not compiled in; cannot run {name}")
        }

        pub fn run_prepared(&self, name: &str, _dynamic: &[Vec<f32>]) -> Result<Vec<f32>> {
            bail!("PJRT runtime not compiled in; cannot run {name}")
        }

        pub fn run_pallas_variant(&self, name: &str, _args: &[Vec<f32>]) -> Result<Vec<f32>> {
            bail!("PJRT runtime not compiled in; cannot run {name}")
        }

        pub fn verify_golden(&self, name: &str) -> Result<f32> {
            bail!("PJRT runtime not compiled in; cannot verify {name}")
        }
    }
}

pub use imp::{Executor, LoadedModel};
