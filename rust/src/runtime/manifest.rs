//! AOT manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Describes, per model, the HLO artifact path, the
//! ordered argument list with shapes, the output shape, and the golden
//! test vector pinning numerics.

use super::json::{parse, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One argument of a lowered model, in call order.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    /// Empty = scalar.
    pub shape: Vec<usize>,
}

impl ArgSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One model's artifact record.
#[derive(Debug, Clone)]
pub struct ModelArtifact {
    pub name: String,
    pub hlo_path: PathBuf,
    /// The Pallas-bodied (hardware-structural) variant of the same
    /// model, if the AOT bundle includes it.
    pub hlo_pallas_path: Option<PathBuf>,
    pub args: Vec<ArgSpec>,
    pub output_shape: Vec<usize>,
    /// Golden seed + expected first output row (from aot.py).
    pub golden_seed: u64,
    pub golden_row0: Vec<f32>,
}

/// Padded nodeflow shapes shared by all artifacts.
#[derive(Debug, Clone, Copy)]
pub struct PadShapes {
    pub u1: usize,
    pub v1: usize,
    pub u2: usize,
    pub v2: usize,
    pub f_in: usize,
    pub f_hid: usize,
    pub f_out: usize,
}

impl PadShapes {
    /// The largest number of coalesced targets whose nodeflow is
    /// *guaranteed* to fit these padded shapes under `mc`'s sampling
    /// (worst case: every sample hits a distinct vertex). The SLO
    /// batcher's `max_batch` is clamped to this on the PJRT path, so a
    /// coalesced batch can never silently degrade to a `timing_only`
    /// reply — the original batch-1 artifact padding capped this at 1;
    /// the PR-4 pads (`python/compile/model.py`: u1 2304, v1/u2 96,
    /// v2 8) admit 8 coalesced targets at paper sampling, and the cap
    /// keeps tracking whatever shapes artifacts are recompiled with.
    pub fn max_coalesced_targets(&self, mc: &crate::config::ModelConfig) -> usize {
        let fan1 = mc.sample1 + 1;
        let fan2 = mc.sample2 + 1;
        [self.v2, self.u2 / fan2, self.v1 / fan2, self.u1 / (fan1 * fan2)]
            .into_iter()
            .min()
            .unwrap_or(1)
            .max(1)
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub pad: PadShapes,
    pub models: HashMap<String, ModelArtifact>,
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let root = parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;

        let ps = root.get("pad_shapes").ok_or_else(|| anyhow!("missing pad_shapes"))?;
        let dim = |k: &str| -> Result<usize> {
            ps.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("pad_shapes.{k}"))
        };
        let pad = PadShapes {
            u1: dim("u1")?,
            v1: dim("v1")?,
            u2: dim("u2")?,
            v2: dim("v2")?,
            f_in: dim("f_in")?,
            f_hid: dim("f_hid")?,
            f_out: dim("f_out")?,
        };

        let mut models = HashMap::new();
        let mobj = root
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("missing models"))?;
        for (name, m) in mobj {
            let hlo = m
                .get("hlo")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("{name}: missing hlo"))?;
            let hlo_pallas = m.get("hlo_pallas").and_then(Json::as_str);
            let mut args = Vec::new();
            for a in m.get("args").and_then(Json::as_arr).unwrap_or(&[]) {
                let aname = a
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("{name}: arg name"))?;
                let shape: Vec<usize> = a
                    .get("shape")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect();
                args.push(ArgSpec { name: aname.to_string(), shape });
            }
            if args.len() < 3 {
                bail!("{name}: expected at least (a1, a2, h) args");
            }
            let output_shape: Vec<usize> = m
                .get("output")
                .and_then(|o| o.get("shape"))
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let golden = m.get("golden");
            let golden_seed = golden
                .and_then(|g| g.get("seed"))
                .and_then(Json::as_f64)
                .unwrap_or(42.0) as u64;
            let golden_row0: Vec<f32> = golden
                .and_then(|g| g.get("row0"))
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_f64().map(|x| x as f32))
                .collect();
            models.insert(
                name.clone(),
                ModelArtifact {
                    name: name.clone(),
                    hlo_path: dir.join(hlo),
                    hlo_pallas_path: hlo_pallas.map(|h| dir.join(h)),
                    args,
                    output_shape,
                    golden_seed,
                    golden_row0,
                },
            );
        }
        Ok(Manifest { pad, models })
    }

    /// Default artifact directory (repo-root `artifacts/`).
    pub fn default_dir() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    /// Manifest key under which `aot.py` registers the batch-1 (online
    /// single-target) variant of `model` — compiled with ~8× smaller
    /// dense pads than the batch-8 serving artifact, selected by
    /// `PjrtBackend::execute` for single-target nodeflows. Optional:
    /// AOT bundles that predate PR 5 simply lack these entries.
    pub fn batch1_name(model: &str) -> String {
        format!("{model}_b1")
    }

    /// Is `name` a batch-1 variant entry rather than a primary model?
    pub fn is_batch1_name(name: &str) -> bool {
        name.ends_with("_b1")
    }

    /// The primary model a batch-1 variant derives from (`gcn_b1` →
    /// `gcn`); `None` for primary entries. Load-bearing for numerics:
    /// `serving_weights` draws from one sequential stream that first
    /// consumes the pad-dependent `(a1, a2, h)` element counts, so a
    /// variant's weights must be generated from its *base* artifact or
    /// the two would serve different models (see `Executor::load`).
    pub fn base_name(name: &str) -> Option<&str> {
        name.strip_suffix("_b1")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        Manifest::load(&Manifest::default_dir()).ok()
    }

    #[test]
    fn loads_all_four_models() {
        let Some(m) = manifest() else { return };
        for name in ["gcn", "sage", "gin", "ggcn"] {
            assert!(m.models.contains_key(name), "{name} missing");
        }
    }

    #[test]
    fn arg_order_contract() {
        let Some(m) = manifest() else { return };
        for a in m.models.values() {
            assert_eq!(a.args[0].name, "a1");
            assert_eq!(a.args[1].name, "a2");
            assert_eq!(a.args[2].name, "h");
            if Manifest::is_batch1_name(&a.name) {
                // Batch-1 variants carry their own (smaller) pads; only
                // the feature dims must agree with the global block.
                assert_eq!(a.args[2].shape[1], m.pad.f_in, "{}", a.name);
                assert!(a.args[0].shape[1] <= m.pad.u1, "{}", a.name);
            } else {
                // Primary artifacts' nodeflow shapes match pad_shapes.
                assert_eq!(a.args[0].shape, vec![m.pad.v1, m.pad.u1]);
                assert_eq!(a.args[1].shape, vec![m.pad.v2, m.pad.u2]);
                assert_eq!(a.args[2].shape, vec![m.pad.u1, m.pad.f_in]);
            }
        }
    }

    #[test]
    fn batch1_names_round_trip() {
        assert_eq!(Manifest::batch1_name("gcn"), "gcn_b1");
        assert!(Manifest::is_batch1_name("gcn_b1"));
        assert!(!Manifest::is_batch1_name("gcn"));
        for m in ["gcn", "sage", "gin", "ggcn"] {
            let v = Manifest::batch1_name(m);
            assert!(Manifest::is_batch1_name(&v));
            assert_eq!(Manifest::base_name(&v), Some(m), "variant resolves to its base");
        }
        assert_eq!(Manifest::base_name("gcn"), None, "primary entries have no base");
    }

    #[test]
    fn serving_weights_are_pad_dependent_hence_base_sourced() {
        // The reason Executor::load sources a _b1 variant's weights
        // from its base artifact: the serving-weight stream consumes
        // the pad-dependent (a1, a2, h) counts first, so the same
        // model at different pads would otherwise get different
        // weight values.
        use crate::runtime::golden::serving_weights;
        let mk = |u1: usize, v1: usize| ModelArtifact {
            name: "t".into(),
            hlo_path: "/dev/null".into(),
            hlo_pallas_path: None,
            args: vec![
                ArgSpec { name: "a1".into(), shape: vec![v1, u1] },
                ArgSpec { name: "a2".into(), shape: vec![2, v1] },
                ArgSpec { name: "h".into(), shape: vec![u1, 6] },
                ArgSpec { name: "w".into(), shape: vec![6, 4] },
            ],
            output_shape: vec![2, 4],
            golden_seed: 42,
            golden_row0: Vec::new(),
        };
        let full = serving_weights(&mk(32, 8));
        let b1 = serving_weights(&mk(16, 4));
        assert_eq!(full[0].len(), b1[0].len(), "weight shapes are pad-independent");
        assert_ne!(full, b1, "values ARE pad-dependent — base sourcing is load-bearing");
    }

    #[test]
    fn golden_vectors_present() {
        let Some(m) = manifest() else { return };
        for a in m.models.values() {
            assert_eq!(a.golden_row0.len(), m.pad.f_out, "{}", a.name);
            assert_eq!(a.golden_seed, 42);
        }
    }

    #[test]
    fn padded_batch_cap() {
        use crate::config::ModelConfig;
        let pad = PadShapes { u1: 288, v1: 16, u2: 16, v2: 8, f_in: 602, f_hid: 512, f_out: 256 };
        // Paper sampling (25/10): the old batch-1 padding capped
        // coalescing at 1.
        assert_eq!(pad.max_coalesced_targets(&ModelConfig::paper()), 1);
        // The PR-4 aot.py pads admit 8-target batches at paper sampling.
        let grown = PadShapes { u1: 2304, v1: 96, u2: 96, v2: 8, ..pad };
        assert_eq!(grown.max_coalesced_targets(&ModelConfig::paper()), 8);
        // 4x larger padding at light sampling admits real batches.
        let big = PadShapes { u1: 1200, v1: 120, u2: 120, v2: 32, ..pad };
        let light = ModelConfig { sample1: 4, sample2: 3, ..ModelConfig::paper() };
        assert_eq!(big.max_coalesced_targets(&light), 30);
        // Degenerate padding still returns at least 1.
        let tiny = PadShapes { u1: 1, v1: 1, u2: 1, v2: 1, ..pad };
        assert_eq!(tiny.max_coalesced_targets(&ModelConfig::paper()), 1);
    }

    #[test]
    fn hlo_files_exist() {
        let Some(m) = manifest() else { return };
        for a in m.models.values() {
            assert!(a.hlo_path.exists(), "{:?}", a.hlo_path);
        }
    }
}
