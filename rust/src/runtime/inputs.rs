//! Nodeflow → padded dense argument marshalling for the AOT'd models.
//!
//! Builds the `(a1, a2, h, *weights)` argument vector the executor
//! feeds a model: the nodeflow rendered with the model's normalization
//! (mean for GCN, sum for GIN/G-GCN, mask for GraphSAGE), features
//! gathered from the feature store, and the deterministic serving
//! weights.

use super::golden::serving_weights;
use super::manifest::ModelArtifact;
use crate::greta::{Domain, ModelPlan, ReduceOp};
use crate::nodeflow::{Nodeflow, NormKind};
use crate::rng::GoldenLcg;
use anyhow::{ensure, Result};

/// Normalization a plan expects in its dense nodeflow matrices, derived
/// from program structure instead of a closed model enum: the first
/// edge-domain program's reduce op determines how the AOT'd dense
/// matmul must encode edge multiplicity (mean → row-normalized, max →
/// 0/1 mask, sum → raw counts). Matches python/compile/model.py's
/// conventions for the four presets.
pub fn norm_for_plan(plan: &ModelPlan) -> NormKind {
    let reduce = plan
        .layers
        .iter()
        .flat_map(|l| l.programs.iter())
        .find(|p| p.domain == Domain::Edges)
        .map(|p| p.reduce);
    match reduce {
        Some(ReduceOp::Mean) => NormKind::Mean,
        Some(ReduceOp::Max) => NormKind::Mask,
        _ => NormKind::Sum,
    }
}

/// Synthesize vertex `v`'s deterministic feature row into `dst`
/// (`dst.len()` = `f_in`). The single source of truth for the
/// "embedding table" stand-in: [`FeatureStore`], the serving
/// [`crate::serve::FeatureCache`], and [`feature_rows`] all call this,
/// so every layer of the stack agrees bit-for-bit. Scaled to ±0.1 so
/// GIN's 25-way multiset edge sums stay inside the Q4.12 accumulator
/// range (the input-scaling step of fixed-point deployment).
pub fn fill_feature_row(v: u32, dst: &mut [f32]) {
    let mut lcg = GoldenLcg::new(0x5EED_0000_0000 + v as u64);
    for x in dst.iter_mut() {
        *x = lcg.next_f32() * 0.2;
    }
}

/// Deterministic per-vertex feature rows, padded to `pad_u` rows (real
/// deployments read these from device DRAM; we synthesize them seeded
/// by vertex id — see [`fill_feature_row`]).
pub fn feature_rows(vertices: &[u32], f_in: usize, pad_u: usize) -> Vec<f32> {
    let mut h = vec![0f32; pad_u * f_in];
    for (i, &v) in vertices.iter().enumerate() {
        fill_feature_row(v, &mut h[i * f_in..(i + 1) * f_in]);
    }
    h
}

/// Memoizing feature store — the on-device "embedding table". Real
/// deployments keep features resident in accelerator DRAM; regenerating
/// a row per request cost ~40% of the marshalling path before this
/// cache existed (EXPERIMENTS.md §Perf).
#[derive(Debug, Default)]
pub struct FeatureStore {
    cache: std::collections::HashMap<u32, Vec<f32>>,
}

impl FeatureStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn row(&mut self, v: u32, f_in: usize) -> &[f32] {
        self.cache.entry(v).or_insert_with(|| {
            let mut row = vec![0f32; f_in];
            fill_feature_row(v, &mut row);
            row
        })
    }

    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }
}

/// Anything that can materialize a vertex's feature row into a caller
/// buffer: the unbounded per-thread [`FeatureStore`], or the shared
/// degree-aware [`crate::serve::FeatureCache`] (via
/// [`crate::serve::CachedFeatures`]). Lets the marshalling path below
/// stay agnostic about which tier serves it.
pub trait FeatureSource {
    fn fill_row(&mut self, v: u32, dst: &mut [f32]);
}

impl FeatureSource for FeatureStore {
    fn fill_row(&mut self, v: u32, dst: &mut [f32]) {
        dst.copy_from_slice(self.row(v, dst.len()));
    }
}

/// Does `nf` fit the artifact's padded dense shapes? The single home
/// of the padding contract (`args[0]`/`args[1]` are the `[pad_v ×
/// pad_u]` layer matrices) — the coordinator pre-checks with this to
/// degrade gracefully instead of tripping `to_dense`'s panic.
pub fn fits_padding(artifact: &ModelArtifact, nf: &Nodeflow) -> bool {
    if nf.layers.len() != 2 {
        return false;
    }
    let a1 = &artifact.args[0].shape;
    let a2 = &artifact.args[1].shape;
    nf.layers[0].num_outputs <= a1[0]
        && nf.layers[0].num_inputs() <= a1[1]
        && nf.layers[1].num_outputs <= a2[0]
        && nf.layers[1].num_inputs() <= a2[1]
}

/// Reusable arena for the PJRT marshalling path: the three padded
/// dense buffers `(a1, a2, h)` that [`build_dynamic_args`] used to
/// allocate per request (the ROADMAP open item). Buffer capacities
/// reach the artifact's padded sizes after the first request and are
/// then only zero-filled and rewritten — zero steady-state allocations,
/// the same discipline [`crate::greta::ExecScratch`] applies to the
/// fixed-point executor.
#[derive(Debug, Default)]
pub struct MarshalScratch {
    bufs: Vec<Vec<f32>>,
}

impl MarshalScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// The marshalled `(a1, a2, h)` argument slice from the last
    /// [`build_dynamic_args_into`] call.
    pub fn args(&self) -> &[Vec<f32>] {
        &self.bufs
    }
}

/// Build only the per-request dynamic args (a1, a2, h) for
/// [`crate::runtime::Executor::run_prepared`] — weights stay
/// device-resident. Feature rows come from the memoizing
/// [`FeatureStore`]. (Convenience wrapper over
/// [`build_dynamic_args_into`] with a fresh arena.)
pub fn build_dynamic_args(
    plan: &ModelPlan,
    artifact: &ModelArtifact,
    nf: &Nodeflow,
    store: &mut FeatureStore,
) -> Result<Vec<Vec<f32>>> {
    let mut scratch = MarshalScratch::new();
    build_dynamic_args_into(plan, artifact, nf, store, &mut scratch)?;
    Ok(scratch.bufs)
}

/// Render the padded dense layer matrices `(a1, a2)` into the arena
/// and size the `h` slot; returns `(pad_u1, f_in)` for the caller's
/// feature fill. Shared by the two marshalling entry points below.
fn marshal_frames(
    plan: &ModelPlan,
    artifact: &ModelArtifact,
    nf: &Nodeflow,
    scratch: &mut MarshalScratch,
) -> Result<(usize, usize)> {
    ensure!(nf.layers.len() == 2, "AOT artifacts are 2-layer");
    ensure!(fits_padding(artifact, nf), "nodeflow exceeds the artifact's padded shapes");
    let a1_shape = &artifact.args[0].shape;
    let a2_shape = &artifact.args[1].shape;
    let h_shape = &artifact.args[2].shape;
    let (pad_v1, pad_u1) = (a1_shape[0], a1_shape[1]);
    let (pad_v2, pad_u2) = (a2_shape[0], a2_shape[1]);
    let f_in = h_shape[1];

    scratch.bufs.resize_with(3, Vec::new);
    let norm = norm_for_plan(plan);
    let [a1, a2, h] = scratch.bufs.as_mut_slice() else {
        unreachable!("scratch sized to 3 above")
    };
    nf.to_dense_into(0, pad_v1, pad_u1, norm, a1);
    nf.to_dense_into(1, pad_v2, pad_u2, norm, a2);
    h.clear();
    h.resize(pad_u1 * f_in, 0f32);
    Ok((pad_u1, f_in))
}

/// Allocation-free marshalling: render `(a1, a2, h)` into the reusable
/// `scratch` arena (available afterwards via [`MarshalScratch::args`]).
/// `features` is any [`FeatureSource`] tier; the nodeflow normalization
/// is derived from the plan ([`norm_for_plan`]).
pub fn build_dynamic_args_into(
    plan: &ModelPlan,
    artifact: &ModelArtifact,
    nf: &Nodeflow,
    features: &mut dyn FeatureSource,
    scratch: &mut MarshalScratch,
) -> Result<()> {
    let (_, f_in) = marshal_frames(plan, artifact, nf, scratch)?;
    let h = &mut scratch.bufs[2];
    for (i, &v) in nf.layers[0].inputs.iter().enumerate() {
        features.fill_row(v, &mut h[i * f_in..(i + 1) * f_in]);
    }
    Ok(())
}

/// [`build_dynamic_args_into`] for a pre-gathered feature block — the
/// phase-decoupled serving path. `h_rows` is the `num_inputs × f_in`
/// row block a prefetch lane already staged
/// (`crate::backend::StagedFeatures`), copied into the padded `h`
/// argument instead of re-gathering row by row; values are identical
/// to the gather-in-place path bit for bit.
pub fn build_dynamic_args_staged(
    plan: &ModelPlan,
    artifact: &ModelArtifact,
    nf: &Nodeflow,
    h_rows: &[f32],
    scratch: &mut MarshalScratch,
) -> Result<()> {
    let (_, f_in) = marshal_frames(plan, artifact, nf, scratch)?;
    let want = nf.layers[0].num_inputs() * f_in;
    ensure!(
        h_rows.len() == want,
        "staged feature block holds {} values, the artifact needs {want}",
        h_rows.len()
    );
    scratch.bufs[2][..want].copy_from_slice(h_rows);
    Ok(())
}

/// Hot-path variant of [`build_args`]: weights are pre-generated once
/// per model and feature rows come from the memoizing [`FeatureStore`].
pub fn build_args_cached(
    plan: &ModelPlan,
    artifact: &ModelArtifact,
    nf: &Nodeflow,
    weights: &[Vec<f32>],
    store: &mut FeatureStore,
) -> Result<Vec<Vec<f32>>> {
    let mut args = build_dynamic_args(plan, artifact, nf, store)?;
    args.extend(weights.iter().cloned());
    Ok(args)
}

/// Build the full argument vector for one inference over `nf`
/// (uncached convenience path; the coordinator uses
/// [`build_args_cached`]).
pub fn build_args(
    plan: &ModelPlan,
    artifact: &ModelArtifact,
    nf: &Nodeflow,
) -> Result<Vec<Vec<f32>>> {
    ensure!(nf.layers.len() == 2, "AOT artifacts are 2-layer");
    let a1_shape = &artifact.args[0].shape;
    let a2_shape = &artifact.args[1].shape;
    let h_shape = &artifact.args[2].shape;
    let (pad_v1, pad_u1) = (a1_shape[0], a1_shape[1]);
    let (pad_v2, pad_u2) = (a2_shape[0], a2_shape[1]);
    let f_in = h_shape[1];

    let norm = norm_for_plan(plan);
    let a1 = nf.to_dense(0, pad_v1, pad_u1, norm);
    let a2 = nf.to_dense(1, pad_v2, pad_u2, norm);
    let h = feature_rows(&nf.layers[0].inputs, f_in, pad_u1);

    let mut args = vec![a1, a2, h];
    args.extend(serving_weights(artifact));
    Ok(args)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::graph::{generate, GeneratorParams};
    use crate::greta::GnnModel;
    use crate::nodeflow::Sampler;
    use crate::runtime::manifest::ArgSpec;

    /// A hand-built 2-layer artifact with the given padded shapes (no
    /// HLO on disk — marshalling never touches the file).
    fn test_artifact(pad_v1: usize, pad_u1: usize, pad_v2: usize, pad_u2: usize) -> ModelArtifact {
        let f_in = 12;
        ModelArtifact {
            name: "test".into(),
            hlo_path: std::path::PathBuf::from("unused.hlo"),
            hlo_pallas_path: None,
            args: vec![
                ArgSpec { name: "a1".into(), shape: vec![pad_v1, pad_u1] },
                ArgSpec { name: "a2".into(), shape: vec![pad_v2, pad_u2] },
                ArgSpec { name: "h".into(), shape: vec![pad_u1, f_in] },
            ],
            output_shape: vec![pad_v2, 6],
            golden_seed: 42,
            golden_row0: Vec::new(),
        }
    }

    fn small_mc() -> ModelConfig {
        ModelConfig { sample1: 4, sample2: 3, f_in: 12, f_hid: 10, f_out: 6 }
    }

    fn small_nf() -> Nodeflow {
        let g = generate(&GeneratorParams { nodes: 500, mean_degree: 6.0, ..Default::default() });
        Nodeflow::build(&g, &Sampler::new(3), &[17], &small_mc())
    }

    #[test]
    fn marshal_scratch_reuse_matches_fresh_path() {
        let nf = small_nf();
        let art = test_artifact(64, 256, 8, 64);
        assert!(fits_padding(&art, &nf));
        let mut store = FeatureStore::new();
        let gcn = crate::greta::compile(GnnModel::Gcn, &small_mc());
        let gin = crate::greta::compile(GnnModel::Gin, &small_mc());
        let fresh = build_dynamic_args(&gcn, &art, &nf, &mut store).unwrap();
        let mut scratch = MarshalScratch::new();
        // Marshal twice through the same arena (second pass over dirty
        // buffers) and once for a different model; every pass must equal
        // the allocate-fresh result.
        for plan in [&gcn, &gcn, &gin] {
            build_dynamic_args_into(plan, &art, &nf, &mut store, &mut scratch).unwrap();
            let want = build_dynamic_args(plan, &art, &nf, &mut store).unwrap();
            assert_eq!(scratch.args(), &want[..], "{}", plan.name);
        }
        assert_eq!(scratch.args().len(), 3);
        assert_eq!(fresh.len(), 3);
    }

    #[test]
    fn staged_marshalling_matches_gather_in_place() {
        let nf = small_nf();
        let art = test_artifact(64, 256, 8, 64);
        let mc = small_mc();
        let gcn = crate::greta::compile(GnnModel::Gcn, &mc);
        let mut store = FeatureStore::new();
        let want = build_dynamic_args(&gcn, &art, &nf, &mut store).unwrap();
        // Pre-gather the rows exactly as a prefetch lane would.
        let mut rows = vec![0f32; nf.layers[0].num_inputs() * mc.f_in];
        for (i, &v) in nf.layers[0].inputs.iter().enumerate() {
            fill_feature_row(v, &mut rows[i * mc.f_in..(i + 1) * mc.f_in]);
        }
        let mut scratch = MarshalScratch::new();
        build_dynamic_args_staged(&gcn, &art, &nf, &rows, &mut scratch).unwrap();
        assert_eq!(scratch.args(), &want[..], "staged path diverged");
        // Re-marshalling over the dirty arena stays exact, and a
        // wrong-sized block is rejected.
        build_dynamic_args_staged(&gcn, &art, &nf, &rows, &mut scratch).unwrap();
        assert_eq!(scratch.args(), &want[..]);
        assert!(build_dynamic_args_staged(&gcn, &art, &nf, &rows[1..], &mut scratch).is_err());
    }

    #[test]
    fn undersized_artifact_fails_padding() {
        let nf = small_nf();
        let art = test_artifact(2, 3, 1, 2);
        assert!(!fits_padding(&art, &nf));
        let mut store = FeatureStore::new();
        let gcn = crate::greta::compile(GnnModel::Gcn, &small_mc());
        assert!(build_dynamic_args(&gcn, &art, &nf, &mut store).is_err());
    }

    #[test]
    fn fill_feature_row_matches_feature_rows() {
        let mut dst = vec![0f32; 8];
        fill_feature_row(9, &mut dst);
        let want = feature_rows(&[9], 8, 1);
        assert_eq!(dst, want);
    }

    #[test]
    fn norms_match_python_conventions() {
        // Derived from program structure, not the preset enum — but the
        // presets must land exactly on python/compile/model.py's norms.
        let mc = small_mc();
        let norm = |m: GnnModel| norm_for_plan(&crate::greta::compile(m, &mc));
        assert_eq!(norm(GnnModel::Gcn), NormKind::Mean);
        assert_eq!(norm(GnnModel::Sage), NormKind::Mask);
        assert_eq!(norm(GnnModel::Gin), NormKind::Sum);
        assert_eq!(norm(GnnModel::Ggcn), NormKind::Sum);
    }

    #[test]
    fn feature_rows_deterministic_per_vertex() {
        let a = feature_rows(&[5, 9], 8, 4);
        let b = feature_rows(&[9, 5], 8, 4);
        // vertex 9's row is the same wherever it lands
        assert_eq!(&a[8..16], &b[0..8]);
        // padding rows are zero
        assert!(a[16..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn feature_values_bounded() {
        let h = feature_rows(&[1, 2, 3], 16, 3);
        assert!(h.iter().all(|x| x.abs() <= 0.1));
    }
}
