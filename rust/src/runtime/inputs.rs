//! Nodeflow → padded dense argument marshalling for the AOT'd models.
//!
//! Builds the `(a1, a2, h, *weights)` argument vector the executor
//! feeds a model: the nodeflow rendered with the model's normalization
//! (mean for GCN, sum for GIN/G-GCN, mask for GraphSAGE), features
//! gathered from the feature store, and the deterministic serving
//! weights.

use super::golden::serving_weights;
use super::manifest::ModelArtifact;
use crate::greta::GnnModel;
use crate::nodeflow::{Nodeflow, NormKind};
use crate::rng::GoldenLcg;
use anyhow::{ensure, Result};

/// Normalization each model expects in its dense nodeflow matrices
/// (must match python/compile/model.py's conventions).
pub fn norm_for(model: GnnModel) -> NormKind {
    match model {
        GnnModel::Gcn => NormKind::Mean,
        GnnModel::Sage => NormKind::Mask,
        GnnModel::Gin | GnnModel::Ggcn => NormKind::Sum,
    }
}

/// Deterministic per-vertex feature row — the "embedding table" stand-in
/// (real deployments read these from device DRAM; we synthesize them
/// seeded by vertex id so every layer of the stack agrees). Scaled to
/// ±0.1 so GIN's 25-way multiset edge sums stay inside the Q4.12
/// accumulator range (the input-scaling step of fixed-point deployment).
pub fn feature_rows(vertices: &[u32], f_in: usize, pad_u: usize) -> Vec<f32> {
    let mut h = vec![0f32; pad_u * f_in];
    for (i, &v) in vertices.iter().enumerate() {
        let mut lcg = GoldenLcg::new(0x5EED_0000_0000 + v as u64);
        for (j, x) in lcg.fill(f_in).into_iter().enumerate() {
            h[i * f_in + j] = x * 0.2;
        }
    }
    h
}

/// Memoizing feature store — the on-device "embedding table". Real
/// deployments keep features resident in accelerator DRAM; regenerating
/// a row per request cost ~40% of the marshalling path before this
/// cache existed (EXPERIMENTS.md §Perf).
#[derive(Debug, Default)]
pub struct FeatureStore {
    cache: std::collections::HashMap<u32, Vec<f32>>,
}

impl FeatureStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn row(&mut self, v: u32, f_in: usize) -> &[f32] {
        self.cache.entry(v).or_insert_with(|| {
            let mut lcg = GoldenLcg::new(0x5EED_0000_0000 + v as u64);
            lcg.fill(f_in).into_iter().map(|x| x * 0.2).collect()
        })
    }

    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }
}

/// Does `nf` fit the artifact's padded dense shapes? The single home
/// of the padding contract (`args[0]`/`args[1]` are the `[pad_v ×
/// pad_u]` layer matrices) — the coordinator pre-checks with this to
/// degrade gracefully instead of tripping `to_dense`'s panic.
pub fn fits_padding(artifact: &ModelArtifact, nf: &Nodeflow) -> bool {
    if nf.layers.len() != 2 {
        return false;
    }
    let a1 = &artifact.args[0].shape;
    let a2 = &artifact.args[1].shape;
    nf.layers[0].num_outputs <= a1[0]
        && nf.layers[0].num_inputs() <= a1[1]
        && nf.layers[1].num_outputs <= a2[0]
        && nf.layers[1].num_inputs() <= a2[1]
}

/// Build only the per-request dynamic args (a1, a2, h) for
/// [`crate::runtime::Executor::run_prepared`] — weights stay
/// device-resident. Feature rows come from the memoizing
/// [`FeatureStore`].
pub fn build_dynamic_args(
    model: GnnModel,
    artifact: &ModelArtifact,
    nf: &Nodeflow,
    store: &mut FeatureStore,
) -> Result<Vec<Vec<f32>>> {
    ensure!(nf.layers.len() == 2, "AOT artifacts are 2-layer");
    ensure!(fits_padding(artifact, nf), "nodeflow exceeds the artifact's padded shapes");
    let a1_shape = &artifact.args[0].shape;
    let a2_shape = &artifact.args[1].shape;
    let h_shape = &artifact.args[2].shape;
    let (pad_v1, pad_u1) = (a1_shape[0], a1_shape[1]);
    let (pad_v2, pad_u2) = (a2_shape[0], a2_shape[1]);
    let f_in = h_shape[1];

    let norm = norm_for(model);
    let a1 = nf.to_dense(0, pad_v1, pad_u1, norm);
    let a2 = nf.to_dense(1, pad_v2, pad_u2, norm);
    let mut h = vec![0f32; pad_u1 * f_in];
    for (i, &v) in nf.layers[0].inputs.iter().enumerate() {
        h[i * f_in..(i + 1) * f_in].copy_from_slice(store.row(v, f_in));
    }
    Ok(vec![a1, a2, h])
}

/// Hot-path variant of [`build_args`]: weights are pre-generated once
/// per model and feature rows come from the memoizing [`FeatureStore`].
pub fn build_args_cached(
    model: GnnModel,
    artifact: &ModelArtifact,
    nf: &Nodeflow,
    weights: &[Vec<f32>],
    store: &mut FeatureStore,
) -> Result<Vec<Vec<f32>>> {
    let mut args = build_dynamic_args(model, artifact, nf, store)?;
    args.extend(weights.iter().cloned());
    Ok(args)
}

/// Build the full argument vector for one inference over `nf`
/// (uncached convenience path; the coordinator uses
/// [`build_args_cached`]).
pub fn build_args(
    model: GnnModel,
    artifact: &ModelArtifact,
    nf: &Nodeflow,
) -> Result<Vec<Vec<f32>>> {
    ensure!(nf.layers.len() == 2, "AOT artifacts are 2-layer");
    let a1_shape = &artifact.args[0].shape;
    let a2_shape = &artifact.args[1].shape;
    let h_shape = &artifact.args[2].shape;
    let (pad_v1, pad_u1) = (a1_shape[0], a1_shape[1]);
    let (pad_v2, pad_u2) = (a2_shape[0], a2_shape[1]);
    let f_in = h_shape[1];

    let norm = norm_for(model);
    let a1 = nf.to_dense(0, pad_v1, pad_u1, norm);
    let a2 = nf.to_dense(1, pad_v2, pad_u2, norm);
    let h = feature_rows(&nf.layers[0].inputs, f_in, pad_u1);

    let mut args = vec![a1, a2, h];
    args.extend(serving_weights(artifact));
    Ok(args)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms_match_python_conventions() {
        assert_eq!(norm_for(GnnModel::Gcn), NormKind::Mean);
        assert_eq!(norm_for(GnnModel::Sage), NormKind::Mask);
        assert_eq!(norm_for(GnnModel::Gin), NormKind::Sum);
        assert_eq!(norm_for(GnnModel::Ggcn), NormKind::Sum);
    }

    #[test]
    fn feature_rows_deterministic_per_vertex() {
        let a = feature_rows(&[5, 9], 8, 4);
        let b = feature_rows(&[9, 5], 8, 4);
        // vertex 9's row is the same wherever it lands
        assert_eq!(&a[8..16], &b[0..8]);
        // padding rows are zero
        assert!(a[16..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn feature_values_bounded() {
        let h = feature_rows(&[1, 2, 3], 16, 3);
        assert!(h.iter().all(|x| x.abs() <= 0.1));
    }
}
