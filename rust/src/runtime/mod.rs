//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts
//! from the L3 coordinator. Python runs only at build time (`make
//! artifacts`); this module is the entire request-path numeric stack.
//!
//! * [`json`] — minimal JSON parser (offline build: no serde).
//! * [`manifest`] — the aot.py ↔ runtime contract.
//! * [`golden`] — shared-LCG golden vectors and serving weights.
//! * [`executor`] — PJRT CPU client, one compiled executable per model.
//! * [`inputs`] — nodeflow → padded dense argument marshalling.

pub mod executor;
pub mod golden;
pub mod inputs;
pub mod json;
pub mod manifest;

pub use executor::{Executor, LoadedModel};
pub use golden::{golden_args, serving_weights};
pub use inputs::{
    build_args, build_args_cached, build_dynamic_args, build_dynamic_args_into,
    build_dynamic_args_staged, feature_rows, fill_feature_row, fits_padding, norm_for_plan,
    FeatureSource, FeatureStore, MarshalScratch,
};
pub use manifest::{ArgSpec, Manifest, ModelArtifact, PadShapes};
