//! Nodeflow substrate (paper Sec. II-A "Nodeflow", Sec. VI-A).
//!
//! A nodeflow is the bipartite structure describing feature propagation
//! for one message-passing layer: `(U, V, E)` with U the vertices read, V
//! the vertices updated, and E ⊆ U×V. It is built during preprocessing
//! from the graph + the deterministic GraphSAGE sampler, then partitioned
//! into N×M blocks for execution (paper Fig. 7).
//!
//! Conventions shared with the L2 JAX models and the AOT manifest:
//! the first |V| entries of U *are* V (self-features at `h[:V]`).

mod build;
mod partition;
mod sampler;

pub use build::{
    HarvestRow, MemoHarvest, MemoPlan, MemoProbe, MemoRow, MemoSlot, Nodeflow, NodeflowLayer,
    NormKind,
};
pub use partition::{PartitionedLayer, Block};
pub use sampler::Sampler;
