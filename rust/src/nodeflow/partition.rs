//! Execution partitioning (paper Sec. VI-A, Fig. 7).
//!
//! The nodeflow's input vertices are split into chunks of size N and the
//! output vertices into chunks of size M; edges land in the (i, j) block
//! connecting input chunk i to output chunk j. GRIP processes blocks
//! column-wise — all incoming edges of an output chunk are accumulated
//! (skipping empty blocks) before vertex-accumulate runs once for the
//! column.

use super::build::NodeflowLayer;

/// One N×M edge block.
#[derive(Debug, Clone, Default)]
pub struct Block {
    /// Edges as (input index *local to chunk i*, output index *local to
    /// chunk j*).
    pub edges: Vec<(u32, u32)>,
}

/// A partitioned nodeflow layer.
#[derive(Debug, Clone)]
pub struct PartitionedLayer {
    pub chunk_inputs: usize,
    pub chunk_outputs: usize,
    pub num_input_chunks: usize,
    pub num_output_chunks: usize,
    /// blocks[j * num_input_chunks + i] = block (i, j); column-major so a
    /// column's blocks are contiguous in execution order.
    pub blocks: Vec<Block>,
    /// Unique input vertices (global nodeflow indices) touched per input
    /// chunk — what the memory controller must load for that chunk.
    pub chunk_input_sizes: Vec<usize>,
    /// Output vertices per output chunk.
    pub chunk_output_sizes: Vec<usize>,
}

impl PartitionedLayer {
    /// Partition `layer` into N×M blocks, streaming the layer's
    /// destination-sorted CSR edge view so each output chunk's blocks
    /// fill contiguously (edges within a block are grouped by
    /// destination; no consumer depends on intra-block order).
    pub fn new(layer: &NodeflowLayer, n: usize, m: usize) -> Self {
        assert!(n > 0 && m > 0);
        let num_input_chunks = layer.num_inputs().div_ceil(n).max(1);
        let num_output_chunks = layer.num_outputs.div_ceil(m).max(1);
        let mut blocks = vec![Block::default(); num_input_chunks * num_output_chunks];
        for v in 0..layer.num_outputs {
            let (j, v_local) = (v / m, (v % m) as u32);
            for &u in layer.edge_srcs_of(v) {
                let i = u as usize / n;
                blocks[j * num_input_chunks + i].edges.push((u % n as u32, v_local));
            }
        }
        let mut chunk_input_sizes = vec![0usize; num_input_chunks];
        for i in 0..num_input_chunks {
            chunk_input_sizes[i] = (layer.num_inputs() - i * n).min(n);
        }
        let mut chunk_output_sizes = vec![0usize; num_output_chunks];
        for j in 0..num_output_chunks {
            chunk_output_sizes[j] = (layer.num_outputs - j * m).min(m);
        }
        Self {
            chunk_inputs: n,
            chunk_outputs: m,
            num_input_chunks,
            num_output_chunks,
            blocks,
            chunk_input_sizes,
            chunk_output_sizes,
        }
    }

    pub fn block(&self, i: usize, j: usize) -> &Block {
        &self.blocks[j * self.num_input_chunks + i]
    }

    /// Blocks of column j in execution order.
    pub fn column(&self, j: usize) -> &[Block] {
        &self.blocks[j * self.num_input_chunks..(j + 1) * self.num_input_chunks]
    }

    /// Non-empty blocks in column j (GRIP skips empty blocks).
    pub fn column_nonempty(&self, j: usize) -> usize {
        self.column(j).iter().filter(|b| !b.edges.is_empty()).count()
    }

    pub fn total_edges(&self) -> usize {
        self.blocks.iter().map(|b| b.edges.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer() -> NodeflowLayer {
        // 10 inputs, 4 outputs, a spread of edges
        NodeflowLayer::new(
            (0..10).collect(),
            4,
            vec![(0, 0), (9, 0), (3, 1), (4, 1), (4, 1), (7, 2), (2, 3), (8, 3)],
        )
    }

    #[test]
    fn all_edges_exactly_once() {
        let l = layer();
        let p = PartitionedLayer::new(&l, 4, 2);
        assert_eq!(p.total_edges(), l.edges.len());
    }

    #[test]
    fn block_locals_in_bounds() {
        let l = layer();
        let p = PartitionedLayer::new(&l, 4, 2);
        for j in 0..p.num_output_chunks {
            for i in 0..p.num_input_chunks {
                for &(u, v) in &p.block(i, j).edges {
                    assert!((u as usize) < p.chunk_inputs);
                    assert!((v as usize) < p.chunk_outputs);
                }
            }
        }
    }

    #[test]
    fn chunk_counts() {
        let l = layer();
        let p = PartitionedLayer::new(&l, 4, 2);
        assert_eq!(p.num_input_chunks, 3); // ceil(10/4)
        assert_eq!(p.num_output_chunks, 2); // ceil(4/2)
        assert_eq!(p.chunk_input_sizes, vec![4, 4, 2]);
        assert_eq!(p.chunk_output_sizes, vec![2, 2]);
    }

    #[test]
    fn edge_block_assignment() {
        let l = layer();
        let p = PartitionedLayer::new(&l, 4, 2);
        // edge (9, 0): input chunk 2, output chunk 0, locals (1, 0)
        assert!(p.block(2, 0).edges.contains(&(1, 0)));
        // multi-edge (4,1) retained twice
        let c = p.block(1, 0).edges.iter().filter(|&&e| e == (0, 1)).count();
        assert_eq!(c, 2);
    }

    #[test]
    fn single_chunk_degenerate() {
        let l = layer();
        let p = PartitionedLayer::new(&l, 100, 100);
        assert_eq!(p.num_input_chunks, 1);
        assert_eq!(p.num_output_chunks, 1);
        assert_eq!(p.block(0, 0).edges.len(), l.edges.len());
    }

    #[test]
    fn empty_block_skipping() {
        let l = layer();
        let p = PartitionedLayer::new(&l, 2, 1);
        // column 0 (output 0) has edges from inputs 0 and 9 only ->
        // chunks 0 and 4 non-empty out of 5.
        assert_eq!(p.column_nonempty(0), 2);
    }
}
