//! Deterministic GraphSAGE-style neighborhood sampler (paper Sec. VII:
//! "we deterministically map a given vertex to a fixed-sized, uniform
//! sample of its neighbors", samples independent between layers).

use crate::graph::CsrGraph;
use crate::rng::SplitMix64;

/// Deterministic uniform neighbor sampler. The same (vertex, layer)
/// always yields the same sample — precomputing the neighborhood
/// function into the nodeflow, as the paper describes.
#[derive(Debug, Clone)]
pub struct Sampler {
    seed: u64,
}

impl Sampler {
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }

    /// Sample up to `k` neighbors of `v` uniformly **with replacement**
    /// (GraphSAGE's sampler), independently per `layer`.
    /// Degree-0 vertices yield an empty sample.
    pub fn sample(&self, g: &CsrGraph, v: u32, k: usize, layer: usize) -> Vec<u32> {
        let neigh = g.neighbors(v);
        if neigh.is_empty() {
            return Vec::new();
        }
        let mut rng = SplitMix64::new(
            self.seed ^ (v as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ ((layer as u64) << 56),
        );
        (0..k).map(|_| neigh[rng.gen_range(neigh.len())]).collect()
    }

    /// The number of *unique* vertices in v's sampled 2-hop neighborhood
    /// under (s1, s2) sampling — Table I's "2-Hop" statistic.
    pub fn two_hop_unique(&self, g: &CsrGraph, v: u32, s1: usize, s2: usize) -> usize {
        let mut seen = std::collections::HashSet::new();
        seen.insert(v);
        let hop1 = self.sample(g, v, s2, 1);
        for &u in &hop1 {
            seen.insert(u);
        }
        // Unique hop-1 vertices fan out independently at layer 0.
        let hop1_unique: std::collections::HashSet<u32> = hop1.into_iter().collect();
        for u in hop1_unique {
            for w in self.sample(g, u, s1, 0) {
                seen.insert(w);
            }
        }
        seen.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, GeneratorParams};

    fn small_graph() -> CsrGraph {
        generate(&GeneratorParams { nodes: 2_000, mean_degree: 6.0, ..Default::default() })
    }

    #[test]
    fn sampling_is_deterministic() {
        let g = small_graph();
        let s = Sampler::new(3);
        assert_eq!(s.sample(&g, 42, 25, 0), s.sample(&g, 42, 25, 0));
    }

    #[test]
    fn layers_are_independent() {
        let g = small_graph();
        let s = Sampler::new(3);
        // Find a vertex with enough neighbors that identical samples
        // across layers would be a (vanishingly unlikely) coincidence.
        let v = (0..g.num_vertices() as u32).find(|&v| g.degree(v) >= 4).unwrap();
        assert_ne!(s.sample(&g, v, 25, 0), s.sample(&g, v, 25, 1));
    }

    #[test]
    fn samples_are_neighbors() {
        let g = small_graph();
        let s = Sampler::new(9);
        for v in (0..200u32).step_by(7) {
            let neigh = g.neighbors(v);
            for u in s.sample(&g, v, 10, 0) {
                assert!(neigh.contains(&u));
            }
        }
    }

    #[test]
    fn sample_size_fixed() {
        let g = small_graph();
        let s = Sampler::new(1);
        assert_eq!(s.sample(&g, 5, 25, 0).len(), 25);
    }

    #[test]
    fn two_hop_unique_bounds() {
        let g = small_graph();
        let s = Sampler::new(1);
        for v in 0..50u32 {
            let n = s.two_hop_unique(&g, v, 25, 10);
            assert!(n >= 1);
            assert!(n <= 1 + 10 + 10 * 25, "n = {n}");
        }
    }

    #[test]
    fn degree_zero_yields_empty() {
        let g = CsrGraph::from_edges(3, &[(0, 1)]);
        let s = Sampler::new(1);
        assert!(s.sample(&g, 2, 25, 0).is_empty());
    }
}
