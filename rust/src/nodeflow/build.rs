//! Nodeflow construction from a graph + sampler, and conversion to the
//! padded dense matrices the AOT'd models consume.
//!
//! Since PR 1 every layer also carries a **destination-sorted CSR** view
//! of its edge multiset (`edge_offsets` + `edge_srcs`), built once here
//! by a stable counting sort. The functional executor and the cycle
//! simulator stream edges per output vertex from this view instead of
//! re-walking the unsorted `(u, v)` list with per-edge bookkeeping —
//! the software analogue of the paper's edge-unit specialization.

use super::sampler::Sampler;
use crate::config::ModelConfig;
use crate::fixed::Fx16;
use crate::graph::CsrGraph;
use std::cell::RefCell;

/// Consulted during nodeflow construction for cross-request activation
/// memoization (PR 10). Implemented by the serving layer's memo cache
/// (`serve::MemoScope`) so the nodeflow crate stays independent of the
/// cache policy: the builder only needs "would you store this vertex's
/// layer output?" and "do you have it right now?".
///
/// Soundness rests on sampler purity: `Sampler::sample` is
/// deterministic per `(vertex, fanout, layer)`, and serving weights are
/// derived from a seed, so the post-layer embedding of a vertex is a
/// pure function of `(plan, weight_seed, layer, vertex)` — a cached row
/// is bit-for-bit the row the executor would have produced.
pub trait MemoProbe {
    /// Would a freshly computed row for `vertex` at `layer` be admitted?
    /// (Degree-class gate; misses that pass become harvest slots.)
    fn admits(&self, layer: usize, vertex: u32, degree: usize) -> bool;
    /// The exact cached post-`layer` row for `vertex`, if resident.
    fn lookup(&self, layer: usize, vertex: u32) -> Option<Vec<Fx16>>;
}

/// One memo hit: the executor must overwrite output `row` of `layer`
/// with `values` instead of trusting the (pruned, garbage) computed row.
#[derive(Debug, Clone)]
pub struct MemoRow {
    pub layer: u32,
    pub row: u32,
    pub values: Vec<Fx16>,
}

/// One memo miss that passed admission: after executing `layer`, the
/// freshly computed output `row` (vertex `vertex`, graph out-degree
/// `degree`) should be deposited back into the cache.
#[derive(Debug, Clone)]
pub struct MemoSlot {
    pub layer: u32,
    pub row: u32,
    pub vertex: u32,
    pub degree: u32,
}

/// Everything the executor needs to splice cached activations into one
/// nodeflow's execution, plus the build-side pruning telemetry.
///
/// `inject` and `harvest` rows are disjoint by construction (a vertex
/// either hit — injected, subtree pruned — or missed — harvested).
#[derive(Debug, Clone, Default)]
pub struct MemoPlan {
    pub inject: Vec<MemoRow>,
    pub harvest: Vec<MemoSlot>,
    /// Output vertices whose sampling (and therefore whole subtree
    /// expansion) was skipped because their row was cached.
    pub pruned_vertices: u64,
    /// Sampled edges *directly* skipped at memo-hit vertices. The
    /// transitive subtree saving is larger (unexpanded sources never
    /// enter U, so outer layers shrink too) and shows up in the
    /// staged-rows delta rather than this counter.
    pub pruned_edges: u64,
    /// Repeated within-request neighbor expansions answered by the
    /// epoch-stamped dedup buffer instead of a hash probe.
    pub dedup_hits: u64,
}

impl MemoPlan {
    pub fn is_empty(&self) -> bool {
        self.inject.is_empty() && self.harvest.is_empty()
    }
}

/// Freshly computed interior-layer rows collected by the executor for
/// deposit into the memo cache (one entry per satisfied [`MemoSlot`]).
#[derive(Debug, Default)]
pub struct MemoHarvest {
    pub rows: Vec<HarvestRow>,
}

#[derive(Debug)]
pub struct HarvestRow {
    pub layer: u32,
    pub vertex: u32,
    pub degree: u32,
    pub values: Vec<Fx16>,
}

/// Per-thread epoch-stamped dedup buffer for `build_layers` (PR 10).
/// Replaces the per-layer `HashMap<u32, u32>` u-index: membership is
/// one array read (`stamp[v] == epoch`), and "clearing" between layers
/// is an epoch bump instead of an O(n) reset or reallocation. Sized to
/// the graph once per thread and reused across every request that
/// thread builds.
struct BuildScratch {
    stamp: Vec<u32>,
    slot: Vec<u32>,
    epoch: u32,
}

thread_local! {
    static BUILD_SCRATCH: RefCell<BuildScratch> =
        RefCell::new(BuildScratch { stamp: Vec::new(), slot: Vec::new(), epoch: 0 });
}

/// One message-passing layer's bipartite structure.
///
/// Invariants (asserted by tests and relied on by the runtime):
/// * `inputs[..num_outputs]` are exactly this layer's output vertices.
/// * every edge is `(src_idx < inputs.len(), dst_idx < num_outputs)`.
/// * edges form a multiset (the sampler draws with replacement); the
///   multiplicity is the sample weight.
/// * `edge_offsets`/`edge_srcs` are the destination-sorted CSR view of
///   `edges`, stable within each destination (so per-destination edge
///   order matches the original list — first-touch reduce semantics and
///   saturating-sum order are preserved bit-for-bit). Construct layers
///   through [`NodeflowLayer::new`] to keep the two views consistent.
#[derive(Debug, Clone)]
pub struct NodeflowLayer {
    /// Global vertex ids of U; the first `num_outputs` are V.
    pub inputs: Vec<u32>,
    pub num_outputs: usize,
    /// Edges as (index into `inputs`, index into V), in sample order.
    pub edges: Vec<(u32, u32)>,
    /// CSR row pointers: `edge_srcs[edge_offsets[v]..edge_offsets[v+1]]`
    /// are the source indices of output vertex `v`'s incoming edges.
    pub edge_offsets: Vec<u32>,
    /// Edge sources, grouped by destination (destination-sorted CSR).
    pub edge_srcs: Vec<u32>,
}

impl NodeflowLayer {
    /// Build a layer, deriving the destination-sorted CSR edge view.
    pub fn new(inputs: Vec<u32>, num_outputs: usize, edges: Vec<(u32, u32)>) -> Self {
        let (edge_offsets, edge_srcs) = dest_sorted_csr(num_outputs, &edges);
        Self { inputs, num_outputs, edges, edge_offsets, edge_srcs }
    }

    pub fn num_inputs(&self) -> usize {
        self.inputs.len()
    }

    /// Incoming edge sources (with multiplicity, original sample order)
    /// of output vertex `v` — the CSR fast path.
    pub fn edge_srcs_of(&self, v: usize) -> &[u32] {
        &self.edge_srcs[self.edge_offsets[v] as usize..self.edge_offsets[v + 1] as usize]
    }

    /// In-degree (with multiplicity) of output vertex `v`, O(1).
    pub fn in_degree(&self, v: usize) -> usize {
        (self.edge_offsets[v + 1] - self.edge_offsets[v]) as usize
    }

    /// In-degree (with multiplicity) per output vertex.
    pub fn in_degrees(&self) -> Vec<usize> {
        (0..self.num_outputs).map(|v| self.in_degree(v)).collect()
    }

    /// An identity nodeflow over n vertices (paper Fig. 3a: per-vertex
    /// programs iterate over self-edges only).
    pub fn identity(n: usize) -> Self {
        Self::new(
            (0..n as u32).collect(),
            n,
            (0..n as u32).map(|i| (i, i)).collect(),
        )
    }
}

/// Stable counting sort of the edge multiset by destination. Returns
/// `(offsets, srcs)` with `offsets.len() == num_outputs + 1`.
fn dest_sorted_csr(num_outputs: usize, edges: &[(u32, u32)]) -> (Vec<u32>, Vec<u32>) {
    let mut offsets = vec![0u32; num_outputs + 1];
    for &(_, v) in edges {
        offsets[v as usize + 1] += 1;
    }
    for i in 1..offsets.len() {
        offsets[i] += offsets[i - 1];
    }
    let mut cursor: Vec<u32> = offsets[..num_outputs].to_vec();
    let mut srcs = vec![0u32; edges.len()];
    for &(u, v) in edges {
        let c = &mut cursor[v as usize];
        srcs[*c as usize] = u;
        *c += 1;
    }
    (offsets, srcs)
}

/// How the dense nodeflow matrix encodes edge multiplicity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NormKind {
    /// Rows normalized to sum 1 (GCN's mean aggregation).
    Mean,
    /// Raw multiplicities (GIN / G-GCN sum aggregation).
    Sum,
    /// 0/1 incidence mask (GraphSAGE max aggregation).
    Mask,
}

/// A complete K-layer nodeflow for one inference request.
#[derive(Debug, Clone)]
pub struct Nodeflow {
    /// layers[0] is the *input* layer (largest U), matching the order the
    /// accelerator executes them.
    pub layers: Vec<NodeflowLayer>,
    /// The target vertices this nodeflow updates.
    pub targets: Vec<u32>,
}

impl Nodeflow {
    /// Build the 2-layer nodeflow for a batch of target vertices with the
    /// paper's sampling scheme: `s2` neighbors at the top layer, `s1` at
    /// the input layer, samples independent between layers.
    pub fn build(g: &CsrGraph, sampler: &Sampler, targets: &[u32], mc: &ModelConfig) -> Self {
        Self::build_layers(g, sampler, targets, &[mc.sample1, mc.sample2])
    }

    /// Build a K-layer nodeflow, one bipartite layer per sampling
    /// fan-out in `samples` (outermost first, matching
    /// `ModelConfig::layers()` / `ModelSpec` layer order). The sampler
    /// keys draws by (vertex, layer index), so for `samples.len() == 2`
    /// this is bit-identical to the original 2-layer builder. This is
    /// what lets spec-defined models of any depth run through the whole
    /// serving path.
    pub fn build_layers(
        g: &CsrGraph,
        sampler: &Sampler,
        targets: &[u32],
        samples: &[usize],
    ) -> Self {
        Self::build_layers_memo(g, sampler, targets, samples, None).0
    }

    /// [`Nodeflow::build_layers`] with an optional activation-memo
    /// probe. Interior layers (every `li` with `li + 1 <
    /// samples.len()`; the final layer's outputs are the reply itself)
    /// consult the probe per output vertex:
    ///
    /// * **hit** — the vertex's sampling is skipped entirely, pruning
    ///   its whole subtree (the skipped sources never enter U, so every
    ///   outer layer shrinks too). Its V-row, left as reduce-identity
    ///   garbage by the executor, is overwritten by the recorded
    ///   [`MemoRow`]. Edges *other* outputs draw to the vertex still
    ///   read its U-row normally, so it keeps expanding at outer layers
    ///   — correctness never depends on who else sampled it.
    /// * **admissible miss** — a [`MemoSlot`] records where the freshly
    ///   computed row will live so the executor can deposit it back.
    ///
    /// With `probe = None` this is exactly the historical builder
    /// (first-touch U ordering is preserved bit-for-bit by the epoch
    /// dedup buffer, which replaces the old per-layer hash map).
    pub fn build_layers_memo(
        g: &CsrGraph,
        sampler: &Sampler,
        targets: &[u32],
        samples: &[usize],
        probe: Option<&dyn MemoProbe>,
    ) -> (Self, MemoPlan) {
        assert!(!samples.is_empty(), "nodeflow needs at least one layer");
        let mut plan = MemoPlan::default();
        // Build from the innermost layer (V = targets) outward; each
        // layer's input set becomes the next-outer layer's output set.
        let nf = BUILD_SCRATCH.with(|cell| {
            let scratch = &mut *cell.borrow_mut();
            let n = g.num_vertices();
            if scratch.stamp.len() < n {
                scratch.stamp.resize(n, 0);
                scratch.slot.resize(n, 0);
            }
            let mut layers_rev: Vec<NodeflowLayer> = Vec::with_capacity(samples.len());
            let mut v: Vec<u32> = targets.to_vec();
            for (li, &fanout) in samples.iter().enumerate().rev() {
                scratch.epoch = scratch.epoch.wrapping_add(1);
                if scratch.epoch == 0 {
                    // u32 epoch wrapped: hard-reset the stamps once every
                    // ~4B layers so stale stamps can't alias.
                    scratch.stamp.iter_mut().for_each(|s| *s = 0);
                    scratch.epoch = 1;
                }
                let epoch = scratch.epoch;
                let stamp = &mut scratch.stamp;
                let slot = &mut scratch.slot;
                let mut u = v.clone();
                for (i, &t) in u.iter().enumerate() {
                    // Duplicate targets: last occurrence wins, matching
                    // the historical HashMap::insert behavior.
                    stamp[t as usize] = epoch;
                    slot[t as usize] = i as u32;
                }
                let mut edges: Vec<(u32, u32)> = Vec::new();
                let interior = li + 1 < samples.len();
                for (vi, &t) in v.iter().enumerate() {
                    if interior {
                        if let Some(p) = probe {
                            let degree = g.degree(t);
                            if p.admits(li, t, degree) {
                                if let Some(values) = p.lookup(li, t) {
                                    plan.inject.push(MemoRow {
                                        layer: li as u32,
                                        row: vi as u32,
                                        values,
                                    });
                                    plan.pruned_vertices += 1;
                                    if degree > 0 {
                                        plan.pruned_edges += fanout as u64;
                                    }
                                    continue;
                                }
                                plan.harvest.push(MemoSlot {
                                    layer: li as u32,
                                    row: vi as u32,
                                    vertex: t,
                                    degree: degree as u32,
                                });
                            }
                        }
                    }
                    for s in sampler.sample(g, t, fanout, li) {
                        let su = s as usize;
                        let idx = if stamp[su] == epoch {
                            plan.dedup_hits += 1;
                            slot[su]
                        } else {
                            stamp[su] = epoch;
                            let i = u.len() as u32;
                            slot[su] = i;
                            u.push(s);
                            i
                        };
                        edges.push((idx, vi as u32));
                    }
                }
                let layer = NodeflowLayer::new(u, v.len(), edges);
                v = layer.inputs.clone();
                layers_rev.push(layer);
            }
            layers_rev.reverse();
            Nodeflow { layers: layers_rev, targets: targets.to_vec() }
        });
        (nf, plan)
    }

    /// Unique vertices read at the input layer — the "neighborhood size"
    /// of Fig. 12 and Table I's 2-hop statistic.
    pub fn neighborhood_size(&self) -> usize {
        self.layers[0].num_inputs()
    }

    /// Total edges across layers (with multiplicity).
    pub fn total_edges(&self) -> usize {
        self.layers.iter().map(|l| l.edges.len()).sum()
    }

    /// Render one layer as a padded row-major dense matrix
    /// `[pad_v × pad_u]` with the given normalization. Panics if the
    /// layer exceeds the padded shape (the AOT contract).
    pub fn to_dense(&self, layer: usize, pad_v: usize, pad_u: usize, norm: NormKind) -> Vec<f32> {
        let mut m = Vec::new();
        self.to_dense_into(layer, pad_v, pad_u, norm, &mut m);
        m
    }

    /// [`Nodeflow::to_dense`] writing into a caller-owned buffer — the
    /// marshalling hot path reuses one arena per executor thread
    /// instead of allocating a padded dense matrix per request
    /// ([`crate::runtime::MarshalScratch`]). The buffer is cleared and
    /// zero-filled to `pad_v * pad_u`.
    pub fn to_dense_into(
        &self,
        layer: usize,
        pad_v: usize,
        pad_u: usize,
        norm: NormKind,
        m: &mut Vec<f32>,
    ) {
        let l = &self.layers[layer];
        assert!(
            l.num_outputs <= pad_v && l.num_inputs() <= pad_u,
            "nodeflow layer {layer} ({}x{}) exceeds padded shape ({pad_v}x{pad_u})",
            l.num_outputs,
            l.num_inputs()
        );
        m.clear();
        m.resize(pad_v * pad_u, 0f32);
        for &(u, v) in &l.edges {
            let cell = &mut m[v as usize * pad_u + u as usize];
            match norm {
                NormKind::Mask => *cell = 1.0,
                _ => *cell += 1.0,
            }
        }
        if norm == NormKind::Mean {
            for v in 0..l.num_outputs {
                let row = &mut m[v * pad_u..(v + 1) * pad_u];
                let s: f32 = row.iter().sum();
                if s > 0.0 {
                    for x in row.iter_mut() {
                        *x /= s;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, GeneratorParams};

    fn setup() -> (CsrGraph, Sampler, ModelConfig) {
        let g = generate(&GeneratorParams { nodes: 3_000, mean_degree: 8.0, ..Default::default() });
        (g, Sampler::new(5), ModelConfig::paper())
    }

    #[test]
    fn v_prefix_of_u_convention() {
        let (g, s, mc) = setup();
        let nf = Nodeflow::build(&g, &s, &[100], &mc);
        // layer2: first input is the target itself
        assert_eq!(nf.layers[1].inputs[0], 100);
        assert_eq!(nf.layers[1].num_outputs, 1);
        // layer1: V = U2
        let v1: Vec<u32> = nf.layers[0].inputs[..nf.layers[0].num_outputs].to_vec();
        assert_eq!(v1, nf.layers[1].inputs);
    }

    #[test]
    fn edge_indices_in_bounds() {
        let (g, s, mc) = setup();
        let nf = Nodeflow::build(&g, &s, &[7, 21], &mc);
        for l in &nf.layers {
            for &(u, v) in &l.edges {
                assert!((u as usize) < l.num_inputs());
                assert!((v as usize) < l.num_outputs);
            }
        }
    }

    #[test]
    fn inputs_unique() {
        let (g, s, mc) = setup();
        let nf = Nodeflow::build(&g, &s, &[55], &mc);
        for l in &nf.layers {
            let mut sorted = l.inputs.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), l.inputs.len(), "duplicate inputs");
        }
    }

    #[test]
    fn edge_counts_match_samples() {
        let (g, s, mc) = setup();
        let nf = Nodeflow::build(&g, &s, &[55], &mc);
        // top layer: exactly sample2 edges per (non-isolated) target
        assert_eq!(nf.layers[1].edges.len(), mc.sample2);
        // input layer: sample1 per layer-1 output vertex
        assert_eq!(nf.layers[0].edges.len(), nf.layers[0].num_outputs * mc.sample1);
    }

    #[test]
    fn csr_view_is_stable_destination_sort() {
        let (g, s, mc) = setup();
        let nf = Nodeflow::build(&g, &s, &[7, 21, 90], &mc);
        for l in &nf.layers {
            // offsets cover the edge multiset exactly
            assert_eq!(l.edge_offsets.len(), l.num_outputs + 1);
            assert_eq!(l.edge_offsets[0], 0);
            assert_eq!(*l.edge_offsets.last().unwrap() as usize, l.edges.len());
            assert_eq!(l.edge_srcs.len(), l.edges.len());
            // per destination: same sources, same relative order as the
            // unsorted list (stability)
            for v in 0..l.num_outputs {
                let want: Vec<u32> =
                    l.edges.iter().filter(|&&(_, d)| d as usize == v).map(|&(u, _)| u).collect();
                assert_eq!(l.edge_srcs_of(v), &want[..], "dst {v}");
                assert_eq!(l.in_degree(v), want.len());
            }
        }
    }

    #[test]
    fn in_degrees_match_edge_list() {
        let (g, s, mc) = setup();
        let nf = Nodeflow::build(&g, &s, &[13, 44], &mc);
        for l in &nf.layers {
            let mut want = vec![0usize; l.num_outputs];
            for &(_, v) in &l.edges {
                want[v as usize] += 1;
            }
            assert_eq!(l.in_degrees(), want);
        }
    }

    #[test]
    fn dense_mean_rows_sum_to_one() {
        let (g, s, mc) = setup();
        let nf = Nodeflow::build(&g, &s, &[3], &mc);
        let l = &nf.layers[0];
        let d = nf.to_dense(0, 16, 288, NormKind::Mean);
        for v in 0..l.num_outputs {
            let s: f32 = d[v * 288..(v + 1) * 288].iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {v} sums to {s}");
        }
        // padded rows are all zero
        let s_pad: f32 = d[l.num_outputs * 288..].iter().sum();
        assert_eq!(s_pad, 0.0);
    }

    #[test]
    fn dense_sum_preserves_multiplicity() {
        let (g, s, mc) = setup();
        let nf = Nodeflow::build(&g, &s, &[3], &mc);
        let d = nf.to_dense(1, 8, 16, NormKind::Sum);
        let total: f32 = d.iter().sum();
        assert_eq!(total as usize, nf.layers[1].edges.len());
    }

    #[test]
    fn dense_mask_is_binary() {
        let (g, s, mc) = setup();
        let nf = Nodeflow::build(&g, &s, &[3], &mc);
        let d = nf.to_dense(0, 16, 288, NormKind::Mask);
        assert!(d.iter().all(|&x| x == 0.0 || x == 1.0));
    }

    #[test]
    fn identity_nodeflow() {
        let l = NodeflowLayer::identity(5);
        assert_eq!(l.num_inputs(), 5);
        assert_eq!(l.num_outputs, 5);
        assert_eq!(l.edges.len(), 5);
        assert!(l.edges.iter().all(|&(u, v)| u == v));
        for v in 0..5 {
            assert_eq!(l.edge_srcs_of(v), &[v as u32]);
        }
    }

    #[test]
    fn batch_builds_share_structure() {
        let (g, s, mc) = setup();
        let nf = Nodeflow::build(&g, &s, &[1, 2, 3], &mc);
        assert_eq!(nf.layers[1].num_outputs, 3);
        assert_eq!(nf.targets, vec![1, 2, 3]);
        assert!(nf.neighborhood_size() >= 3);
    }

    #[test]
    fn to_dense_into_reuses_buffer_and_matches() {
        let (g, s, mc) = setup();
        let nf = Nodeflow::build(&g, &s, &[3], &mc);
        let want = nf.to_dense(0, 16, 288, NormKind::Mean);
        // A dirty, differently-sized buffer must come out identical.
        let mut buf = vec![7.0f32; 10];
        nf.to_dense_into(0, 16, 288, NormKind::Mean, &mut buf);
        assert_eq!(buf, want);
        // Reuse for a different layer/norm also matches the fresh path.
        nf.to_dense_into(1, 8, 16, NormKind::Sum, &mut buf);
        assert_eq!(buf, nf.to_dense(1, 8, 16, NormKind::Sum));
    }

    #[test]
    fn memo_off_build_is_identical_and_counts_dedup() {
        let (g, s, mc) = setup();
        let a = Nodeflow::build(&g, &s, &[7, 21, 90], &mc);
        let (b, plan) =
            Nodeflow::build_layers_memo(&g, &s, &[7, 21, 90], &[mc.sample1, mc.sample2], None);
        assert_eq!(a.targets, b.targets);
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.inputs, lb.inputs, "epoch dedup must preserve first-touch order");
            assert_eq!(la.num_outputs, lb.num_outputs);
            assert_eq!(la.edges, lb.edges);
        }
        assert!(plan.is_empty(), "no probe, no inject/harvest");
        assert_eq!(plan.pruned_vertices, 0);
        assert!(
            plan.dedup_hits > 0,
            "25/10 replacement sampling on a zipf graph must repeat sources"
        );
    }

    #[test]
    fn memo_hit_prunes_subtree_and_miss_records_harvest() {
        let (g, s, mc) = setup();
        let samples = [mc.sample1, mc.sample2];
        let base = Nodeflow::build_layers(&g, &s, &[42], &samples);
        // Interior layer 0's outputs are the 1-hop set (incl. the
        // target); "cache" one non-target output with out-edges.
        let l0 = &base.layers[0];
        let hit = (1..l0.num_outputs)
            .map(|i| l0.inputs[i])
            .find(|&v| g.degree(v) > 0)
            .expect("some sampled neighbor has out-edges");
        struct Probe {
            hit: u32,
            row: Vec<Fx16>,
        }
        impl MemoProbe for Probe {
            fn admits(&self, _layer: usize, _v: u32, degree: usize) -> bool {
                degree > 0
            }
            fn lookup(&self, _layer: usize, v: u32) -> Option<Vec<Fx16>> {
                if v == self.hit {
                    Some(self.row.clone())
                } else {
                    None
                }
            }
        }
        let probe = Probe { hit, row: vec![Fx16(7); 4] };
        let (nf, plan) = Nodeflow::build_layers_memo(&g, &s, &[42], &samples, Some(&probe));
        // Exactly one hit (V entries are unique for a single target),
        // recorded at the interior layer with its fanout pruned.
        assert_eq!(plan.pruned_vertices, 1);
        assert_eq!(plan.pruned_edges, mc.sample1 as u64);
        assert_eq!(plan.inject.len(), 1);
        let inj = &plan.inject[0];
        assert_eq!(inj.layer, 0);
        assert_eq!(nf.layers[0].inputs[inj.row as usize], hit);
        // The hit row's sampling was skipped: zero in-edges, and the
        // layer lost exactly that vertex's fanout.
        assert_eq!(nf.layers[0].in_degree(inj.row as usize), 0);
        assert_eq!(nf.layers[0].edges.len() + mc.sample1, base.layers[0].edges.len());
        assert!(nf.neighborhood_size() <= base.neighborhood_size());
        // The final layer is never consulted, so its structure and the
        // reply targets are untouched.
        assert_eq!(nf.layers[1].edges, base.layers[1].edges);
        assert_eq!(nf.targets, base.targets);
        // Admissible misses became harvest slots (never for the hit).
        assert!(!plan.harvest.is_empty());
        assert!(plan.harvest.iter().all(|h| h.layer == 0 && h.vertex != hit));
    }

    #[test]
    #[should_panic(expected = "exceeds padded shape")]
    fn to_dense_panics_on_overflow() {
        let (g, s, mc) = setup();
        let nf = Nodeflow::build(&g, &s, &[3], &mc);
        let _ = nf.to_dense(0, 1, 2, NormKind::Sum);
    }
}
