//! Deterministic PRNGs. No external `rand` dependency: every experiment
//! in the paper repro must be bit-reproducible from a seed, and the
//! golden-vector LCG must match `python/compile/aot.py` bit for bit.

/// splitmix64 — used for graph generation and sampling decisions.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, n). Uses the widening-multiply trick (unbiased
    /// enough for simulation purposes).
    pub fn gen_range(&mut self, n: usize) -> usize {
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Zipf-like sample in [1, n] with exponent `s` via inverse-CDF
    /// approximation (power-law degree distributions).
    pub fn gen_zipf(&mut self, n: usize, s: f64) -> usize {
        let u = self.gen_f64().max(1e-12);
        let x = (1.0 - u * (1.0 - (n as f64).powf(1.0 - s))).powf(1.0 / (1.0 - s));
        (x as usize).clamp(1, n)
    }
}

/// The golden-vector LCG shared with `python/compile/aot.py::_lcg_stream`.
///
/// state' = state * 6364136223846793005 + 1442695040888963407 (mod 2^64);
/// value  = ((state' >> 33) & 0x7FFFFFFF) / 2^31 - 0.5  ∈ [-0.5, 0.5).
#[derive(Debug, Clone)]
pub struct GoldenLcg {
    state: u64,
}

impl GoldenLcg {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_f32(&mut self) -> f32 {
        self.state = self
            .state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        (((self.state >> 33) & 0x7FFF_FFFF) as f64 / (1u64 << 31) as f64 - 0.5) as f32
    }

    /// Fill a buffer in manifest order, matching python's golden_args.
    pub fn fill(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_f32()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = SplitMix64::new(3);
        for _ in 0..10_000 {
            assert!(r.gen_range(17) < 17);
        }
    }

    #[test]
    fn zipf_skews_low() {
        let mut r = SplitMix64::new(5);
        let n = 10_000;
        let small = (0..n).filter(|_| r.gen_zipf(1000, 2.0) <= 3).count();
        assert!(small > n / 2, "zipf(2.0) should concentrate mass at small values: {small}");
    }

    #[test]
    fn golden_lcg_first_values_match_python_spec() {
        // Reference values computed from the spec in aot.py (seed 42).
        let mut lcg = GoldenLcg::new(42);
        let v: Vec<f32> = (0..4).map(|_| lcg.next_f32()).collect();
        // Recompute by hand once: the first state is
        // 42*6364136223846793005 + 1442695040888963407 mod 2^64.
        let s1 = 42u64
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let want0 = (((s1 >> 33) & 0x7FFF_FFFF) as f64 / (1u64 << 31) as f64 - 0.5) as f32;
        assert_eq!(v[0], want0);
        assert!(v.iter().all(|x| (-0.5..0.5).contains(x)));
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = SplitMix64::new(11);
        for _ in 0..1000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }
}
