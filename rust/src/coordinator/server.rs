//! The low-latency serving coordinator (L3), organized as a parallel
//! pipeline since PR 1:
//!
//! ```text
//!   submit() ──▶ bounded job queue ──▶ N nodeflow-builder threads
//!                (backpressure)        (sampling + CSR build; the
//!                                       graph and sampler are
//!                                       read-only, so builds for
//!                                       different requests proceed
//!                                       fully in parallel)
//!                                             │
//!                                             ▼
//!                                      bounded built-nodeflow channel
//!                                             │
//!                                             ▼
//!                                      executor thread (owns the
//!                                      non-Send PJRT executor +
//!                                      feature store; cycle-sims the
//!                                      accelerator and runs the real
//!                                      numerics) ──▶ per-request reply
//! ```
//!
//! Nodeflow construction — the dominant host-side cost — overlaps with
//! execution of earlier requests instead of serializing in front of it.
//! Requests may complete out of submission order; each reply travels on
//! its own channel, so callers are unaffected. The deterministic
//! sampler keys samples by (vertex, layer), so moving builds across
//! threads cannot change any request's nodeflow.
//!
//! Requests carry a batch of target vertices: a multi-target request
//! shares one nodeflow build and one simulated accelerator pass
//! ([`run_workload_batched`] drives this). The AOT artifacts are padded
//! for the paper's batch-1 online-inference regime, so batched requests
//! fall back to timing-only responses when their nodeflow exceeds the
//! artifact padding.

use super::metrics::LatencyStats;
use crate::config::{GripConfig, ModelConfig};
use crate::graph::CsrGraph;
use crate::greta::{compile, GnnModel, ModelPlan, ALL_MODELS};
use crate::nodeflow::{Nodeflow, Sampler};
use crate::runtime::{build_dynamic_args, fits_padding, Executor, FeatureStore};
use crate::sim::simulate;
use anyhow::{anyhow, ensure, Result};
use std::collections::HashMap;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// One inference request: a batch of target vertices served from one
/// shared nodeflow (single-target is the common online case).
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    pub model: GnnModel,
    pub targets: Vec<u32>,
}

impl InferenceRequest {
    /// The common single-target request.
    pub fn single(id: u64, model: GnnModel, target: u32) -> Self {
        Self { id, model, targets: vec![target] }
    }
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    /// Target embeddings (`targets.len() × f_out` values, row-major)
    /// from the PJRT numeric path; empty when numerics are off or the
    /// batched nodeflow exceeds the AOT padding.
    pub embedding: Vec<f32>,
    /// Simulated GRIP accelerator latency (µs) for this nodeflow.
    pub accel_us: f64,
    /// Wall-clock host-side latency (µs) from submission to response:
    /// queue wait + nodeflow build + execution. Under a closed-loop
    /// workload that submits everything up front this is dominated by
    /// queue backlog; use [`InferenceResponse::service_us`] for the
    /// per-request serving cost.
    pub host_us: f64,
    /// Wall-clock service time (µs) excluding queue wait: measured from
    /// the moment a builder thread dequeues the request (nodeflow build
    /// + pipeline handoff + execution). Comparable across load levels.
    pub service_us: f64,
    /// Unique 2-hop neighborhood size of the request.
    pub neighborhood: usize,
}

/// A submitted request travelling through the pipeline.
struct Job {
    req: InferenceRequest,
    reply: mpsc::Sender<Result<InferenceResponse, String>>,
    t_submit: Instant,
}

/// A job with its nodeflow built, ready for the executor stage.
struct Built {
    job: Job,
    nf: Nodeflow,
    /// When a builder dequeued the job (start of service time).
    t_dequeue: Instant,
}

/// Serving coordinator handle. Owns the builder pool and the executor
/// thread; dropping it drains and joins the pipeline.
pub struct Coordinator {
    tx: Option<mpsc::SyncSender<Job>>,
    builders: Vec<std::thread::JoinHandle<()>>,
    executor: Option<std::thread::JoinHandle<()>>,
}

/// Configuration of the serving loop.
pub struct ServeConfig {
    pub grip: GripConfig,
    pub model_cfg: ModelConfig,
    /// Bounded submission-queue depth (backpressure).
    pub queue_depth: usize,
    /// Run the PJRT numeric path (disable for pure-timing benches).
    pub numerics: bool,
    /// Nodeflow-builder threads (sampling + CSR build are read-only
    /// over the graph, so they scale near-linearly).
    pub builders: usize,
    /// Bounded depth of the built-nodeflow channel between the builder
    /// pool and the executor thread.
    pub built_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            grip: GripConfig::paper(),
            model_cfg: ModelConfig::paper(),
            queue_depth: 256,
            numerics: true,
            builders: 4,
            built_depth: 64,
        }
    }
}

impl Coordinator {
    /// Start the coordinator over `graph`. Loads and compiles all AOT
    /// artifacts up front (when `numerics`), so the request path never
    /// compiles.
    pub fn start(graph: CsrGraph, sampler_seed: u64, cfg: ServeConfig) -> Result<Coordinator> {
        let graph = Arc::new(graph);
        let (tx, rx) = mpsc::sync_channel::<Job>(cfg.queue_depth.max(1));
        let (built_tx, built_rx) = mpsc::sync_channel::<Built>(cfg.built_depth.max(1));
        let jobs = Arc::new(Mutex::new(rx));

        let mut builders = Vec::new();
        for i in 0..cfg.builders.max(1) {
            let graph = graph.clone();
            let jobs = jobs.clone();
            let built_tx = built_tx.clone();
            let sampler = Sampler::new(sampler_seed);
            let mc = cfg.model_cfg;
            let handle = std::thread::Builder::new()
                .name(format!("grip-nf-builder-{i}"))
                .spawn(move || builder_loop(&graph, &sampler, &mc, &jobs, &built_tx))
                .map_err(|e| anyhow!("spawning builder {i}: {e}"))?;
            builders.push(handle);
        }
        // The executor's channel closes when the last builder exits.
        drop(built_tx);

        let executor = std::thread::Builder::new()
            .name("grip-executor".into())
            .spawn(move || executor_loop(cfg, built_rx))
            .map_err(|e| anyhow!("spawning executor: {e}"))?;

        Ok(Coordinator { tx: Some(tx), builders, executor: Some(executor) })
    }

    /// Submit a request; returns a receiver for the response. Blocks if
    /// the submission queue is full (backpressure).
    pub fn submit(
        &self,
        req: InferenceRequest,
    ) -> Result<mpsc::Receiver<Result<InferenceResponse, String>>> {
        ensure!(!req.targets.is_empty(), "request {} has no targets", req.id);
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("coordinator stopped"))?
            .send(Job { req, reply: rtx, t_submit: Instant::now() })
            .map_err(|_| anyhow!("coordinator stopped"))?;
        Ok(rrx)
    }

    /// Convenience: submit and wait.
    pub fn infer(&self, req: InferenceRequest) -> Result<InferenceResponse> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| anyhow!("pipeline dropped"))?.map_err(|e| anyhow!(e))
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Closing the job queue unwinds the pipeline stage by stage:
        // builders see a closed receiver and exit, which closes the
        // built channel, which stops the executor.
        drop(self.tx.take());
        for b in self.builders.drain(..) {
            let _ = b.join();
        }
        if let Some(e) = self.executor.take() {
            let _ = e.join();
        }
    }
}

/// Stage 1: pull jobs off the shared queue, build nodeflows in parallel.
fn builder_loop(
    graph: &CsrGraph,
    sampler: &Sampler,
    mc: &ModelConfig,
    jobs: &Mutex<mpsc::Receiver<Job>>,
    built_tx: &mpsc::SyncSender<Built>,
) {
    loop {
        // Hold the lock only while waiting for a job; the build itself
        // runs unlocked so the pool scales.
        let job = {
            let guard = match jobs.lock() {
                Ok(g) => g,
                Err(_) => break,
            };
            match guard.recv() {
                Ok(j) => j,
                Err(_) => break,
            }
        };
        let t_dequeue = Instant::now();
        let nf = Nodeflow::build(graph, sampler, &job.req.targets, mc);
        if built_tx.send(Built { job, nf, t_dequeue }).is_err() {
            break;
        }
    }
}

/// Stage 2: cycle-sim + numerics on the single executor thread (the
/// PJRT executor is not Send; weights stay device-resident).
fn executor_loop(cfg: ServeConfig, built_rx: mpsc::Receiver<Built>) {
    let executor = if cfg.numerics {
        match Executor::load(&crate::runtime::Manifest::default_dir()) {
            Ok(e) => Some(e),
            Err(e) => {
                eprintln!("coordinator: PJRT unavailable ({e}); serving timing-only");
                None
            }
        }
    } else {
        None
    };
    // Compile plans once per model.
    let plans: HashMap<GnnModel, ModelPlan> =
        ALL_MODELS.into_iter().map(|m| (m, compile(m, &cfg.model_cfg))).collect();
    // Memoizing on-device feature store (§Perf; weights are already
    // device-resident inside the Executor).
    let mut store = FeatureStore::new();

    while let Ok(Built { job, nf, t_dequeue }) = built_rx.recv() {
        let result = execute_built(&cfg, &plans, executor.as_ref(), &mut store, &job.req, &nf)
            .map_err(|e| e.to_string())
            .map(|mut r| {
                r.host_us = job.t_submit.elapsed().as_secs_f64() * 1e6;
                r.service_us = t_dequeue.elapsed().as_secs_f64() * 1e6;
                r
            });
        let _ = job.reply.send(result);
    }
}

fn execute_built(
    cfg: &ServeConfig,
    plans: &HashMap<GnnModel, ModelPlan>,
    executor: Option<&Executor>,
    store: &mut FeatureStore,
    req: &InferenceRequest,
    nf: &Nodeflow,
) -> Result<InferenceResponse> {
    // 1. Cycle-level accelerator timing over the prebuilt nodeflow.
    let plan = &plans[&req.model];
    let sim = simulate(&cfg.grip, plan, nf);
    let accel_us = sim.us(&cfg.grip);

    // 2. Real numerics via PJRT (the embeddings a client would receive).
    let embedding = match executor {
        Some(exec) => {
            let artifact = &exec.model(req.model.name())?.artifact;
            if fits_padding(artifact, nf) {
                let dynamic = build_dynamic_args(req.model, artifact, nf, store)?;
                let out = exec.run_prepared(req.model.name(), &dynamic)?;
                let f_out = *artifact.output_shape.last().unwrap_or(&1);
                out[..f_out * nf.targets.len()].to_vec()
            } else {
                // A batched nodeflow can exceed the batch-1 AOT padding;
                // serve the timing result rather than failing.
                Vec::new()
            }
        }
        None => Vec::new(),
    };

    Ok(InferenceResponse {
        id: req.id,
        embedding,
        accel_us,
        host_us: 0.0,
        service_us: 0.0,
        neighborhood: nf.neighborhood_size(),
    })
}

/// Drive a workload of single-target requests through a coordinator and
/// collect latency stats — the end-to-end harness used by examples and
/// benches. All requests are submitted up front so the builder pool and
/// executor stay saturated; responses are collected afterwards.
pub fn run_workload(
    coord: &Coordinator,
    model: GnnModel,
    targets: &[u32],
) -> Result<(LatencyStats, LatencyStats, Vec<InferenceResponse>)> {
    run_workload_batched(coord, model, targets, 1)
}

/// [`run_workload`] with `batch` targets per request: each batch shares
/// one nodeflow build and one simulated accelerator pass.
pub fn run_workload_batched(
    coord: &Coordinator,
    model: GnnModel,
    targets: &[u32],
    batch: usize,
) -> Result<(LatencyStats, LatencyStats, Vec<InferenceResponse>)> {
    let batch = batch.max(1);
    let mut pending = Vec::with_capacity(targets.len().div_ceil(batch));
    for (i, chunk) in targets.chunks(batch).enumerate() {
        pending.push(coord.submit(InferenceRequest {
            id: i as u64,
            model,
            targets: chunk.to_vec(),
        })?);
    }
    let mut accel = LatencyStats::new();
    let mut host = LatencyStats::new();
    let mut responses = Vec::with_capacity(pending.len());
    for rx in pending {
        let resp = rx.recv().map_err(|_| anyhow!("pipeline dropped"))?.map_err(|e| anyhow!(e))?;
        accel.record(resp.accel_us);
        host.record(resp.host_us);
        responses.push(resp);
    }
    Ok((accel, host, responses))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, GeneratorParams};

    fn graph() -> CsrGraph {
        generate(&GeneratorParams { nodes: 2_000, mean_degree: 8.0, ..Default::default() })
    }

    fn timing_cfg() -> ServeConfig {
        ServeConfig { numerics: false, builders: 3, ..Default::default() }
    }

    #[test]
    fn pipeline_serves_and_shuts_down() {
        let coord = Coordinator::start(graph(), 7, timing_cfg()).unwrap();
        let resp = coord.infer(InferenceRequest::single(1, GnnModel::Gcn, 42)).unwrap();
        assert!(resp.accel_us > 0.0);
        assert!(resp.host_us > 0.0);
        assert!(resp.service_us > 0.0);
        // Service time excludes queue wait, so it never exceeds the
        // submit-to-response latency.
        assert!(resp.service_us <= resp.host_us);
        assert!(resp.neighborhood >= 1);
        assert!(resp.embedding.is_empty(), "numerics disabled");
        // Drop joins the pipeline without hanging.
    }

    #[test]
    fn parallel_builds_are_deterministic() {
        let coord = Coordinator::start(graph(), 7, timing_cfg()).unwrap();
        let a = coord.infer(InferenceRequest::single(1, GnnModel::Sage, 99)).unwrap();
        // Saturate the pool with interleaved traffic, then re-ask.
        let targets: Vec<u32> = (0..64).collect();
        let _ = run_workload(&coord, GnnModel::Sage, &targets).unwrap();
        let b = coord.infer(InferenceRequest::single(2, GnnModel::Sage, 99)).unwrap();
        assert_eq!(a.accel_us, b.accel_us, "same target → same nodeflow → same timing");
        assert_eq!(a.neighborhood, b.neighborhood);
    }

    #[test]
    fn workload_pipelines_many_requests() {
        let coord = Coordinator::start(graph(), 3, timing_cfg()).unwrap();
        let targets: Vec<u32> = (0..200u32).map(|i| i * 7 % 2000).collect();
        let (accel, host, responses) = run_workload(&coord, GnnModel::Gcn, &targets).unwrap();
        assert_eq!(responses.len(), 200);
        assert_eq!(accel.count(), 200);
        assert!(accel.p99() >= accel.p50());
        assert!(host.p99() >= host.p50());
        // Responses arrive in submission order (collection order).
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn batched_requests_share_one_nodeflow() {
        let coord = Coordinator::start(graph(), 3, timing_cfg()).unwrap();
        let targets: Vec<u32> = (0..32u32).collect();
        let (accel_b, _, responses) =
            run_workload_batched(&coord, GnnModel::Gcn, &targets, 8).unwrap();
        assert_eq!(responses.len(), 4, "32 targets in batches of 8");
        assert_eq!(accel_b.count(), 4);
        // A batch's neighborhood covers at least its own targets.
        assert!(responses.iter().all(|r| r.neighborhood >= 8));
    }

    #[test]
    fn empty_target_list_is_rejected() {
        let coord = Coordinator::start(graph(), 3, timing_cfg()).unwrap();
        let err = coord.submit(InferenceRequest { id: 0, model: GnnModel::Gcn, targets: vec![] });
        assert!(err.is_err());
    }

    #[test]
    fn single_builder_still_works() {
        let cfg = ServeConfig { numerics: false, builders: 1, built_depth: 1, ..Default::default() };
        let coord = Coordinator::start(graph(), 5, cfg).unwrap();
        let targets: Vec<u32> = (0..32).collect();
        let (accel, _, _) = run_workload(&coord, GnnModel::Gin, &targets).unwrap();
        assert_eq!(accel.count(), 32);
    }
}
