//! The low-latency serving coordinator (L3): request queue → batcher →
//! nodeflow builder → {cycle simulator for accelerator timing, PJRT
//! executor for real numerics} → response with latency metrics.
//!
//! Architecture mirrors a vLLM-style router scaled to GRIP's batch-1
//! regime: a bounded submission queue provides backpressure, a worker
//! thread owns the (non-Send) PJRT executor and drains the queue in
//! micro-batches. The AOT artifacts are compiled for batch-1 nodeflows
//! (the paper's online-inference setting), so the batcher currently
//! admits one request per execution while still amortizing queue and
//! nodeflow work.

use super::metrics::LatencyStats;
use crate::config::{GripConfig, ModelConfig};
use crate::graph::CsrGraph;
use crate::greta::{compile, GnnModel, ModelPlan};
use crate::nodeflow::{Nodeflow, Sampler};
use crate::runtime::{build_dynamic_args, Executor, FeatureStore};
use crate::sim::simulate;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::mpsc;
use std::time::Instant;

/// One inference request.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    pub model: GnnModel,
    pub target: u32,
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    /// Target embedding (f_out values) from the PJRT numeric path.
    pub embedding: Vec<f32>,
    /// Simulated GRIP accelerator latency (µs) for this nodeflow.
    pub accel_us: f64,
    /// Wall-clock host-side latency (µs): queue + nodeflow + execution.
    pub host_us: f64,
    /// Unique 2-hop neighborhood size of the request.
    pub neighborhood: usize,
}

enum Msg {
    Req(InferenceRequest, mpsc::Sender<Result<InferenceResponse, String>>),
    Shutdown,
}

/// Serving coordinator handle. Owns the worker thread.
pub struct Coordinator {
    tx: mpsc::SyncSender<Msg>,
    worker: Option<std::thread::JoinHandle<()>>,
}

/// Configuration of the serving loop.
pub struct ServeConfig {
    pub grip: GripConfig,
    pub model_cfg: ModelConfig,
    /// Bounded queue depth (backpressure).
    pub queue_depth: usize,
    /// Run the PJRT numeric path (disable for pure-timing benches).
    pub numerics: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            grip: GripConfig::paper(),
            model_cfg: ModelConfig::paper(),
            queue_depth: 256,
            numerics: true,
        }
    }
}

impl Coordinator {
    /// Start the coordinator over `graph`. Loads and compiles all AOT
    /// artifacts up front (when `numerics`), so the request path never
    /// compiles.
    pub fn start(graph: CsrGraph, sampler_seed: u64, cfg: ServeConfig) -> Result<Coordinator> {
        let (tx, rx) = mpsc::sync_channel::<Msg>(cfg.queue_depth);
        let worker = std::thread::Builder::new()
            .name("grip-coordinator".into())
            .spawn(move || worker_loop(graph, sampler_seed, cfg, rx))
            .map_err(|e| anyhow!("spawning worker: {e}"))?;
        Ok(Coordinator { tx, worker: Some(worker) })
    }

    /// Submit a request; returns a receiver for the response. Blocks if
    /// the queue is full (backpressure).
    pub fn submit(
        &self,
        req: InferenceRequest,
    ) -> Result<mpsc::Receiver<Result<InferenceResponse, String>>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Req(req, rtx)).map_err(|_| anyhow!("coordinator stopped"))?;
        Ok(rrx)
    }

    /// Convenience: submit and wait.
    pub fn infer(&self, req: InferenceRequest) -> Result<InferenceResponse> {
        let rx = self.submit(req)?;
        rx.recv()
            .map_err(|_| anyhow!("worker dropped"))?
            .map_err(|e| anyhow!(e))
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(graph: CsrGraph, sampler_seed: u64, cfg: ServeConfig, rx: mpsc::Receiver<Msg>) {
    let sampler = Sampler::new(sampler_seed);
    let executor = if cfg.numerics {
        match Executor::load(&crate::runtime::Manifest::default_dir()) {
            Ok(e) => Some(e),
            Err(e) => {
                eprintln!("coordinator: PJRT unavailable ({e}); serving timing-only");
                None
            }
        }
    } else {
        None
    };
    // Compile plans once per model.
    let plans: HashMap<GnnModel, ModelPlan> = [GnnModel::Gcn, GnnModel::Sage, GnnModel::Gin, GnnModel::Ggcn]
        .into_iter()
        .map(|m| (m, compile(m, &cfg.model_cfg)))
        .collect();
    // Memoizing on-device feature store (§Perf; weights are already
    // device-resident inside the Executor).
    let mut store = FeatureStore::new();

    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Shutdown => break,
            Msg::Req(req, reply) => {
                let start = Instant::now();
                let result = serve_one(&graph, &sampler, &cfg, &plans, executor.as_ref(), &mut store, &req)
                    .map_err(|e| e.to_string())
                    .map(|mut r| {
                        r.host_us = start.elapsed().as_secs_f64() * 1e6;
                        r
                    });
                let _ = reply.send(result);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn serve_one(
    graph: &CsrGraph,
    sampler: &Sampler,
    cfg: &ServeConfig,
    plans: &HashMap<GnnModel, ModelPlan>,
    executor: Option<&Executor>,
    store: &mut FeatureStore,
    req: &InferenceRequest,
) -> Result<InferenceResponse> {
    // 1. Nodeflow construction (preprocessing in the paper's flow).
    let nf = Nodeflow::build(graph, sampler, &[req.target], &cfg.model_cfg);

    // 2. Cycle-level accelerator timing.
    let plan = &plans[&req.model];
    let sim = simulate(&cfg.grip, plan, &nf);
    let accel_us = sim.us(&cfg.grip);

    // 3. Real numerics via PJRT (the embedding a client would receive).
    let embedding = if let Some(exec) = executor {
        let artifact = &exec.model(req.model.name())?.artifact;
        let dynamic = build_dynamic_args(req.model, artifact, &nf, store)?;
        let out = exec.run_prepared(req.model.name(), &dynamic)?;
        let f_out = *artifact.output_shape.last().unwrap_or(&1);
        out[..f_out].to_vec()
    } else {
        Vec::new()
    };

    Ok(InferenceResponse {
        id: req.id,
        embedding,
        accel_us,
        host_us: 0.0,
        neighborhood: nf.neighborhood_size(),
    })
}

/// Drive `n` requests through a coordinator and collect latency stats —
/// the end-to-end harness used by examples and benches.
pub fn run_workload(
    coord: &Coordinator,
    model: GnnModel,
    targets: &[u32],
) -> Result<(LatencyStats, LatencyStats, Vec<InferenceResponse>)> {
    let mut accel = LatencyStats::new();
    let mut host = LatencyStats::new();
    let mut responses = Vec::with_capacity(targets.len());
    for (i, &t) in targets.iter().enumerate() {
        let resp = coord.infer(InferenceRequest { id: i as u64, model, target: t })?;
        accel.record(resp.accel_us);
        host.record(resp.host_us);
        responses.push(resp);
    }
    Ok((accel, host, responses))
}
